//! Analytical models and report formatting.
//!
//! [`speedup`] implements the closed-form cycle/speedup models of
//! Sections IV-D and IV-E (Figures 8 and 9); [`sota`] encodes the
//! state-of-the-art comparison of Table I; [`codesign`] prices per-layer
//! design assignments against Table III's FPGA resource increments (the
//! cost axis of the explorer's Pareto frontier); [`report`] renders
//! aligned text tables/series for the bench harness output.

pub mod codesign;
pub mod energy;
pub mod report;
pub mod sota;
pub mod speedup;

pub use energy::{EnergyModel, EnergyReport};
pub use report::Table;
pub use speedup::{
    csa_analytical_speedup, sssa_analytical_speedup, ussa_analytical_cycles,
    ussa_observed_cycles, ussa_speedup_analytical, ussa_speedup_observed,
};
