//! Closed-form speedup models (Sections IV-D / IV-E).
//!
//! With IID element sparsity `x`, the number of non-zero weights in a
//! 4-block is Binomial(4, 1-x). The baseline sequential MAC always takes
//! 4 cycles; the ideal accelerator takes one cycle per non-zero weight:
//!
//! `c_a = Σ_k C(4,k) x^k (1-x)^(4-k) (4-k)`            (= 4(1-x))
//!
//! USSA still spends one cycle on an all-zero block:
//!
//! `c_o = Σ_{k=0}^{3} C(4,k) x^k (1-x)^(4-k) (4-k) + x^4`
//!
//! and the speedups are `s_a = 4/c_a`, `s_o = 4/c_o`.

/// Binomial coefficient C(4, k).
fn c4(k: u32) -> f64 {
    match k {
        0 | 4 => 1.0,
        1 | 3 => 4.0,
        2 => 6.0,
        _ => 0.0,
    }
}

/// Binomial coefficient C(n, k) (for the INT4/INT2 generalization of
/// Section IV-D, where a register holds n = 8 or 16 lanes).
fn binom(n: u32, k: u32) -> f64 {
    if k > n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut num = 1.0f64;
    let mut den = 1.0f64;
    for i in 0..k {
        num *= (n - i) as f64;
        den *= (i + 1) as f64;
    }
    num / den
}

/// Generalized observed cycles for an n-lane variable-cycle MAC: one
/// cycle per non-zero lane, one idle cycle for an all-zero word.
pub fn vc_observed_cycles_n(x: f64, n: u32) -> f64 {
    assert!((0.0..=1.0).contains(&x));
    let partial: f64 = (0..n)
        .map(|k| binom(n, k) * x.powi(k as i32) * (1.0 - x).powi((n - k) as i32) * (n - k) as f64)
        .sum();
    partial + x.powi(n as i32)
}

/// Generalized observed speedup `n / c_o(x, n)` — saturates at n.
pub fn vc_speedup_observed_n(x: f64, n: u32) -> f64 {
    n as f64 / vc_observed_cycles_n(x, n)
}

/// Analytical (ideal) average cycles per block at element sparsity `x`.
pub fn ussa_analytical_cycles(x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x));
    (0..=4)
        .map(|k| c4(k) * x.powi(k as i32) * (1.0 - x).powi(4 - k as i32) * (4 - k) as f64)
        .sum()
}

/// Observed average cycles per block: all-zero blocks still cost 1.
pub fn ussa_observed_cycles(x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x));
    let partial: f64 = (0..=3)
        .map(|k| c4(k) * x.powi(k as i32) * (1.0 - x).powi(4 - k as i32) * (4 - k) as f64)
        .sum();
    partial + x.powi(4)
}

/// `s_a = 4 / c_a` (unbounded as x → 1).
pub fn ussa_speedup_analytical(x: f64) -> f64 {
    4.0 / ussa_analytical_cycles(x)
}

/// `s_o = 4 / c_o` (saturates at 4 as x → 1 due to the 1-cycle floor).
pub fn ussa_speedup_observed(x: f64) -> f64 {
    4.0 / ussa_observed_cycles(x)
}

/// SSSA analytical speedup at 4:4 block sparsity `x_ss`: the ratio of
/// total weights to weights in non-zero blocks (Section IV-E).
pub fn sssa_analytical_speedup(x_ss: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x_ss));
    if x_ss >= 1.0 {
        f64::INFINITY
    } else {
        1.0 / (1.0 - x_ss)
    }
}

/// CSA analytical speedup against the 4-cycle sequential baseline at
/// block sparsity `x_ss` and intra-block unstructured sparsity `x_us`:
/// visited fraction `(1-x_ss)` of blocks, each costing
/// `c_o(x_us)` MAC cycles plus one `inc_indvar` cycle, versus 4 baseline
/// MAC cycles per block.
pub fn csa_analytical_speedup(x_us: f64, x_ss: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x_us));
    assert!((0.0..=1.0).contains(&x_ss));
    let per_visited = ussa_observed_cycles(x_us) + 1.0;
    4.0 / ((1.0 - x_ss) * per_visited).max(f64::MIN_POSITIVE)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytical_cycles_closed_form() {
        // c_a = 4(1-x) — the binomial mean.
        for x in [0.0, 0.25, 0.5, 0.75, 0.9, 1.0] {
            assert!((ussa_analytical_cycles(x) - 4.0 * (1.0 - x)).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn observed_equals_analytical_plus_zero_block_term() {
        for x in [0.0, 0.3, 0.6, 0.9] {
            let diff = ussa_observed_cycles(x) - ussa_analytical_cycles(x);
            assert!((diff - x.powi(4)).abs() < 1e-12, "x={x}");
        }
    }

    #[test]
    fn speedups_at_paper_points() {
        // Dense: no speedup.
        assert!((ussa_speedup_analytical(0.0) - 1.0).abs() < 1e-12);
        assert!((ussa_speedup_observed(0.0) - 1.0).abs() < 1e-12);
        // x = 0.75 → c_a = 1 → s_a = 4.
        assert!((ussa_speedup_analytical(0.75) - 4.0).abs() < 1e-12);
        // Fully sparse: observed saturates at 4 (1-cycle zero blocks),
        // analytical diverges.
        assert!((ussa_speedup_observed(1.0) - 4.0).abs() < 1e-12);
        assert!(ussa_speedup_analytical(0.999) > 100.0);
        // Paper: USSA offers "speedups of up to a factor of 3" at high
        // sparsity — s_o crosses 3 near x ≈ 0.75.
        assert!(ussa_speedup_observed(0.75) > 3.0);
    }

    #[test]
    fn observed_below_analytical_only_at_high_sparsity() {
        for x in [0.1, 0.3, 0.5] {
            let gap = ussa_speedup_analytical(x) - ussa_speedup_observed(x);
            assert!(gap >= 0.0 && gap < 0.1, "x={x} gap={gap}");
        }
        let gap_hi = ussa_speedup_analytical(0.95) - ussa_speedup_observed(0.95);
        assert!(gap_hi > 1.0, "divergence should be visible at x=0.95, gap={gap_hi}");
    }

    #[test]
    fn sssa_speedup_examples() {
        assert!((sssa_analytical_speedup(0.0) - 1.0).abs() < 1e-12);
        assert!((sssa_analytical_speedup(0.5) - 2.0).abs() < 1e-12);
        // Paper: SSSA "speedups of up to a factor of 4" — x_ss = 0.75.
        assert!((sssa_analytical_speedup(0.75) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn csa_reaches_paper_range() {
        // Paper: combined design "speedups of up to a factor of 5"
        // at moderate combined sparsity.
        let s = csa_analytical_speedup(0.8, 0.6);
        assert!(s > 4.0 && s < 7.0, "csa speedup {s}");
        // Dense: the +1 inc_indvar cycle costs ~20% vs 4-cycle baseline.
        let dense = csa_analytical_speedup(0.0, 0.0);
        assert!((dense - 0.8).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn out_of_range_rejected() {
        ussa_analytical_cycles(1.5);
    }

    #[test]
    fn generalized_n4_matches_specialized() {
        for x in [0.0, 0.3, 0.6, 0.9, 1.0] {
            assert!(
                (vc_observed_cycles_n(x, 4) - ussa_observed_cycles(x)).abs() < 1e-12,
                "x={x}"
            );
        }
    }

    #[test]
    fn int4_extension_speedups() {
        // Section IV-D: 8 lanes per register → saturation at 8×.
        assert!((vc_speedup_observed_n(1.0, 8) - 8.0).abs() < 1e-12);
        // 7 of 8 zero (x = 7/8): close to the "single cycle" regime.
        let s = vc_speedup_observed_n(0.875, 8);
        assert!(s > 5.0 && s < 8.0, "{s}");
        // INT2: 16 lanes.
        assert!((vc_speedup_observed_n(1.0, 16) - 16.0).abs() < 1e-12);
        // Dense: no speedup at any width.
        assert!((vc_speedup_observed_n(0.0, 8) - 1.0).abs() < 1e-12);
    }
}
