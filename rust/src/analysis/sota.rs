//! Table I — comparison with the state of the art.
//!
//! IndexMAC [17] and the Lu et al. [27] FPGA accelerator are *published
//! baselines*; their speedup ranges are taken from their papers (as
//! Table I does). Our designs' ranges are *measured* by the bench
//! harness (`table1_sota`), which sweeps sparsity and reports the
//! resulting min–max speedups next to the published rows.

/// One row of Table I.
#[derive(Debug, Clone)]
pub struct SotaEntry {
    /// Method name.
    pub method: &'static str,
    /// Supports semi-structured sparsity.
    pub semi_structured: bool,
    /// Supports unstructured sparsity.
    pub unstructured: bool,
    /// Sparsity pattern constraint.
    pub pattern: &'static str,
    /// Published / measured speedup range.
    pub speedup: (f64, f64),
    /// Sparsity regime label from the paper.
    pub sparsity_regime: &'static str,
    /// Architecture class.
    pub architecture: &'static str,
}

/// The published baseline rows of Table I.
pub fn published_baselines() -> Vec<SotaEntry> {
    vec![
        SotaEntry {
            method: "IndexMAC [17]",
            semi_structured: true,
            unstructured: false,
            pattern: "2:4",
            speedup: (2.0, 3.0),
            sparsity_regime: "Moderate",
            architecture: "CPU+HW",
        },
        SotaEntry {
            method: "Lu et al. [27]",
            semi_structured: false,
            unstructured: true,
            pattern: "NA",
            speedup: (2.4, 12.9),
            sparsity_regime: "Low",
            architecture: "HW",
        },
    ]
}

/// The paper's rows for our three designs (for comparison against
/// measured ranges).
pub fn paper_our_rows() -> Vec<SotaEntry> {
    vec![
        SotaEntry {
            method: "Ours (USSA)",
            semi_structured: false,
            unstructured: true,
            pattern: "NA",
            speedup: (2.0, 3.0),
            sparsity_regime: "High",
            architecture: "CPU+HW",
        },
        SotaEntry {
            method: "Ours (SSSA)",
            semi_structured: true,
            unstructured: false,
            pattern: "4:4",
            speedup: (2.0, 4.0),
            sparsity_regime: "Low",
            architecture: "CPU+HW",
        },
        SotaEntry {
            method: "Ours (CSA)",
            semi_structured: true,
            unstructured: true,
            pattern: "4:4, random",
            speedup: (4.0, 5.0),
            sparsity_regime: "Moderate",
            architecture: "CPU+HW",
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_rows_complete() {
        assert_eq!(published_baselines().len(), 2);
        assert_eq!(paper_our_rows().len(), 3);
    }

    #[test]
    fn csa_supports_both_sparsity_types() {
        let csa = &paper_our_rows()[2];
        assert!(csa.semi_structured && csa.unstructured);
        assert!(csa.speedup.1 >= 5.0);
    }

    #[test]
    fn ranges_ordered() {
        for e in published_baselines().iter().chain(paper_our_rows().iter()) {
            assert!(e.speedup.0 <= e.speedup.1, "{}", e.method);
        }
    }
}
