//! Energy estimation for the TinyML deployment story.
//!
//! The paper motivates pruning by "decreasing memory utilization,
//! latency, and energy consumption" (Section I). We estimate energy per
//! inference from the simulator's instruction/cycle counts using
//! per-event costs typical of a 28 nm-class embedded core (order-of-
//! magnitude figures from Horowitz, ISSCC'14 "Computing's Energy
//! Problem", scaled to a small in-order pipeline):
//!
//! - integer op        ~ 1 pJ
//! - 8×8 multiply      ~ 0.2 pJ (datapath only; counted per MAC cycle)
//! - 32-bit SRAM read  ~ 5 pJ (on-chip cache/BRAM)
//! - 32-bit SRAM write ~ 5 pJ
//! - pipeline overhead ~ 2 pJ per cycle (fetch/decode/clock tree)
//!
//! Absolute joules are indicative; the *ratios* across designs are the
//! deliverable (fewer visited blocks ⇒ fewer loads and cycles ⇒
//! proportionally less energy, which the lookahead designs deliver on
//! top of their latency wins).

use crate::cpu::{CycleCounter, InstrClass};

/// Per-event energy costs in picojoules.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyModel {
    /// Integer ALU / branch instruction.
    pub int_op_pj: f64,
    /// One MAC-unit cycle (single 8×8 multiply + accumulate).
    pub mac_cycle_pj: f64,
    /// 32-bit SRAM read.
    pub sram_read_pj: f64,
    /// 32-bit SRAM write.
    pub sram_write_pj: f64,
    /// Static/pipeline overhead per clock cycle.
    pub per_cycle_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            int_op_pj: 1.0,
            mac_cycle_pj: 0.2,
            sram_read_pj: 5.0,
            sram_write_pj: 5.0,
            per_cycle_pj: 2.0,
        }
    }
}

/// Energy breakdown of one simulated run.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyReport {
    /// Compute (ALU + branches + MAC datapath) energy, pJ.
    pub compute_pj: f64,
    /// Memory (loads + stores) energy, pJ.
    pub memory_pj: f64,
    /// Pipeline/static energy, pJ.
    pub pipeline_pj: f64,
}

impl EnergyReport {
    /// Total picojoules.
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.memory_pj + self.pipeline_pj
    }

    /// Total microjoules (per-inference scale for TinyML).
    pub fn total_uj(&self) -> f64 {
        self.total_pj() * 1e-6
    }
}

impl EnergyModel {
    /// Estimate energy for a counter's activity.
    pub fn estimate(&self, counter: &CycleCounter) -> EnergyReport {
        let int_ops =
            counter.instr_count(InstrClass::Alu) + counter.instr_count(InstrClass::Branch);
        let compute_pj = int_ops as f64 * self.int_op_pj
            + counter.cfu_cycles() as f64 * self.mac_cycle_pj;
        let memory_pj = (counter.loaded_bytes() / 4) as f64 * self.sram_read_pj
            + (counter.stored_bytes() / 4) as f64 * self.sram_write_pj;
        let pipeline_pj = counter.cycles() as f64 * self.per_cycle_pj;
        EnergyReport { compute_pj, memory_pj, pipeline_pj }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfu::CfuResponse;
    use crate::cpu::CostModel;

    fn counter_with(alu: u64, loads: u64, stores: u64, cfu_cycles: u32) -> CycleCounter {
        let mut c = CycleCounter::new(CostModel::vexriscv());
        c.alu(alu);
        c.load_words(loads);
        c.store_words(stores);
        if cfu_cycles > 0 {
            c.cfu(&CfuResponse { rd: 0, cycles: cfu_cycles });
        }
        c
    }

    #[test]
    fn breakdown_matches_hand_calculation() {
        let c = counter_with(10, 4, 2, 3);
        let m = EnergyModel::default();
        let e = m.estimate(&c);
        // compute: 10 int ops * 1 + 3 mac cycles * 0.2
        assert!((e.compute_pj - (10.0 + 0.6)).abs() < 1e-9);
        // memory: 4 reads * 5 + 2 writes * 5
        assert!((e.memory_pj - 30.0).abs() < 1e-9);
        // pipeline: cycles = 10 + 4 + 2 + 3 = 19 → 38
        assert!((e.pipeline_pj - 38.0).abs() < 1e-9);
        assert!((e.total_pj() - (10.6 + 30.0 + 38.0)).abs() < 1e-9);
    }

    #[test]
    fn sparse_design_saves_energy() {
        // SSSA on a block-sparse conv must save memory + pipeline energy
        // proportionally to the skipped blocks.
        use crate::isa::DesignKind;
        use crate::kernels::PreparedConv;
        use crate::nn::conv2d::{Conv2dOp, Padding};
        use crate::sparsity::prune::prune_blocks_magnitude;
        use crate::tensor::quant::QuantParams;
        use crate::tensor::{QTensor, Shape};
        use crate::util::Pcg32;

        let act = QuantParams::new(0.05, 0).unwrap();
        let mut rng = Pcg32::new(7);
        let mut weights: Vec<i8> =
            (0..8 * 9 * 16).map(|_| rng.range_i32(1, 63) as i8).collect();
        prune_blocks_magnitude(&mut weights, 16, 0.6);
        let op = Conv2dOp::new(
            "e", weights, vec![0; 8], 8, 16, 3, 3, 1, Padding::Same, false, act, 0.02, act,
            false,
        )
        .unwrap();
        let data: Vec<i8> = (0..6 * 6 * 16).map(|_| rng.range_i32(-128, 127) as i8).collect();
        let input = QTensor::new(Shape::nhwc(1, 6, 6, 16), data, act).unwrap();
        let m = EnergyModel::default();
        let run_base = PreparedConv::new(&op, DesignKind::BaselineSimd)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap();
        let run_sssa = PreparedConv::new(&op, DesignKind::Sssa)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap();
        let e_base = m.estimate(&run_base.counter).total_pj();
        let e_sssa = m.estimate(&run_sssa.counter).total_pj();
        assert!(
            e_sssa < 0.75 * e_base,
            "sssa {e_sssa} pJ should be well below baseline {e_base} pJ"
        );
    }

    #[test]
    fn zero_activity_zero_energy() {
        let c = CycleCounter::new(CostModel::vexriscv());
        let e = EnergyModel::default().estimate(&c);
        assert_eq!(e.total_pj(), 0.0);
    }
}
