//! FPGA resource model for per-layer co-design (the cost axis of the
//! explorer's Pareto frontier).
//!
//! The paper's Table III reports each CFU's LUT/FF/DSP *increment* over
//! the baseline VexRiscv + LiteX SoC. A heterogeneous
//! [`DesignAssignment`](crate::isa::DesignAssignment) must instantiate
//! every design it uses in the combined CFU build (the `funct3` design
//! selector of Section III-B1 lets them coexist), so its resource cost
//! is the sum of the distinct designs' increments — a slightly
//! conservative union (shared operand registers are counted per design)
//! that preserves the orderings Table III establishes.
//!
//! Published numbers are used where the paper reports them (USSA, SSSA,
//! CSA); the structural estimator of [`crate::resources::fpga`] fills in
//! the rest (the sequential baseline, and the SIMD baseline whose MAC is
//! already part of the baseline SoC, i.e. a zero increment).
//!
//! ```
//! use sparse_riscv::analysis::codesign::{assignment_cost, design_cost};
//! use sparse_riscv::isa::{DesignAssignment, DesignKind};
//!
//! // Table III: the CSA CFU adds 108 LUTs and 2 DSPs.
//! assert_eq!(design_cost(DesignKind::Csa).luts, 108);
//! assert_eq!(design_cost(DesignKind::Csa).dsps, 2);
//! // The SIMD baseline is free — its MAC ships with the baseline SoC.
//! assert_eq!(design_cost(DesignKind::BaselineSimd).luts, 0);
//! // A mixed assignment pays for every design it uses.
//! let mixed = DesignAssignment::per_layer(vec![
//!     DesignKind::Sssa,
//!     DesignKind::BaselineSimd,
//! ]);
//! let cost = assignment_cost(&mixed);
//! assert_eq!(cost.luts, design_cost(DesignKind::Sssa).luts);
//! ```

use crate::isa::{DesignAssignment, DesignKind};
use crate::resources::fpga::{estimate_cfu, paper_increment, ResourceUsage};

/// LUT/FF/DSP increment of one design's CFU over the baseline SoC:
/// the paper's Table III where published, the structural estimate
/// ([`estimate_cfu`]) otherwise.
pub fn design_cost(design: DesignKind) -> ResourceUsage {
    paper_increment(design).unwrap_or_else(|| estimate_cfu(design))
}

/// Resource cost of instantiating every design in `designs` in one
/// combined CFU build (duplicates are counted once; callers normally
/// pass [`DesignAssignment::designs_used`]).
pub fn designs_cost(designs: &[DesignKind]) -> ResourceUsage {
    DesignKind::ALL
        .into_iter()
        .filter(|d| designs.contains(d))
        .fold(ResourceUsage::default(), |acc, d| acc.add(&design_cost(d)))
}

/// Resource cost of a (possibly heterogeneous) per-layer assignment.
pub fn assignment_cost(assignment: &DesignAssignment) -> ResourceUsage {
    designs_cost(&assignment.designs_used())
}

/// Does `cost` fit within `budget` in every dimension? (BRAM included
/// for completeness; all CFUs use none.)
pub fn within_budget(cost: &ResourceUsage, budget: &ResourceUsage) -> bool {
    cost.luts <= budget.luts
        && cost.ffs <= budget.ffs
        && cost.brams <= budget.brams
        && cost.dsps <= budget.dsps
}

/// Parse a CLI budget spec like `"luts=100,ffs=200,dsps=2"`. Omitted
/// dimensions default to unlimited (`u32::MAX`); an empty string is a
/// fully-unlimited budget.
pub fn parse_budget(spec: &str) -> Option<ResourceUsage> {
    let mut budget = ResourceUsage {
        luts: u32::MAX,
        ffs: u32::MAX,
        brams: u32::MAX,
        dsps: u32::MAX,
    };
    for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let (key, value) = part.split_once('=')?;
        let value: u32 = value.trim().parse().ok()?;
        match key.trim().to_ascii_lowercase().as_str() {
            "luts" | "lut" => budget.luts = value,
            "ffs" | "ff" => budget.ffs = value,
            "brams" | "bram" => budget.brams = value,
            "dsps" | "dsp" => budget.dsps = value,
            _ => return None,
        }
    }
    Some(budget)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_increments_take_precedence() {
        // Table III values, verbatim.
        assert_eq!(design_cost(DesignKind::Ussa).luts, 34);
        assert_eq!(design_cost(DesignKind::Sssa).luts, 95);
        assert_eq!(design_cost(DesignKind::Csa).dsps, 2);
        // Baselines fall back to the structural estimate.
        assert_eq!(design_cost(DesignKind::BaselineSimd), ResourceUsage::default());
        assert!(design_cost(DesignKind::BaselineSequential).dsps >= 1);
    }

    #[test]
    fn assignment_cost_sums_distinct_designs_once() {
        let a = DesignAssignment::per_layer(vec![
            DesignKind::Sssa,
            DesignKind::Ussa,
            DesignKind::Sssa,
            DesignKind::BaselineSimd,
        ]);
        let cost = assignment_cost(&a);
        let expect = design_cost(DesignKind::Sssa).add(&design_cost(DesignKind::Ussa));
        assert_eq!(cost, expect);
        // Uniform SIMD is free; uniform CSA is Table III's increment.
        assert_eq!(
            assignment_cost(&DesignAssignment::Uniform(DesignKind::BaselineSimd)),
            ResourceUsage::default()
        );
        assert_eq!(
            assignment_cost(&DesignAssignment::Uniform(DesignKind::Csa)).luts,
            108
        );
    }

    #[test]
    fn budget_parse_and_check() {
        let b = parse_budget("luts=100, dsps=1").unwrap();
        assert_eq!(b.luts, 100);
        assert_eq!(b.dsps, 1);
        assert_eq!(b.ffs, u32::MAX);
        assert!(within_budget(&design_cost(DesignKind::Ussa), &b));
        assert!(!within_budget(&design_cost(DesignKind::Csa), &b)); // 2 DSPs
        assert!(parse_budget("").is_some());
        assert!(parse_budget("bogus=3").is_none());
        assert!(parse_budget("luts=abc").is_none());
    }
}
