//! Aligned text tables for bench output (the same rows/series the paper
//! reports).

/// A simple aligned text table.
#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: format heterogeneous cells.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:width$}", cells[i], width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }
}

/// Format a metric value compactly: integral values print as integers
/// (sharing [`crate::config::value::is_integral`] with the JSON
/// serializer, so tables and the persisted JSON agree), fractional ones
/// with 4 decimals.
pub fn fmt_compact(x: f64) -> String {
    if crate::config::value::is_integral(x) {
        format!("{}", x as i64)
    } else {
        format!("{x:.4}")
    }
}

/// Render metric records as a long-form aligned table (record, metric,
/// value, gate) — the human view of what `bench-e2e --json` persists.
pub fn render_metric_records(title: &str, records: &[crate::metrics::MetricRecord]) -> String {
    let mut t = Table::new(title, &["record", "metric", "value", "gate"]);
    for rec in records {
        for (name, v) in &rec.values {
            let gated = crate::metrics::spec_for(name).gate;
            t.row(&[
                rec.id.clone(),
                name.clone(),
                fmt_compact(*v),
                if gated { "yes" } else { "info" }.to_string(),
            ]);
        }
    }
    t.render()
}

/// Format a float with 2 decimals (speedups, ratios).
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// Format a float with 3 decimals.
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Format a percentage.
pub fn pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2.50".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer-name"));
        // all data lines have the same column start for "value"
        let lines: Vec<&str> = s.lines().collect();
        let header_pos = lines[1].find("value").unwrap();
        assert_eq!(lines[3].find('1').unwrap(), header_pos);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(f2(1.23456), "1.23");
        assert_eq!(f3(0.5), "0.500");
        assert_eq!(pct(0.0384), "3.84%");
    }

    #[test]
    fn metric_records_render_long_form() {
        let rec = crate::metrics::MetricRecord::new("e2e/x")
            .with_value("total_cycles", 42.0)
            .with_value("wall_s", 0.5);
        let s = render_metric_records("telemetry", &[rec]);
        assert!(s.contains("e2e/x"), "{s}");
        assert!(s.contains("total_cycles"), "{s}");
        assert!(s.contains("info"), "{s}");
        assert!(s.contains("yes"), "{s}");
    }
}
