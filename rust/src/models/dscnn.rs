//! DSCNN (Google Speech Commands keyword spotting): the MLPerf-Tiny /
//! TFLite-Micro depthwise-separable CNN — a 10×4 strided stem conv over
//! the 49×10 MFCC spectrogram, four depthwise-separable blocks at 64
//! channels, global average pooling, and a 12-way softmax head
//! (10 keywords + "silence" + "unknown").

use super::builder::{GraphBuilder, ModelConfig};
use crate::error::Result;
use crate::nn::conv2d::Padding;
use crate::nn::graph::{Graph, Layer};
use crate::tensor::Shape;

/// GSC spectrogram input: 49 frames × 10 MFCCs, padded to 4 channels.
pub fn input_shape() -> Shape {
    Shape::nhwc(1, 49, 10, 4)
}

/// Number of output classes.
pub const CLASSES: usize = 12;

/// Build DSCNN at the configured width.
pub fn build(cfg: &ModelConfig) -> Result<Graph> {
    let mut b = GraphBuilder::new(cfg);
    let ch = cfg.ch(64);
    // Stem: 10×4 conv, stride 2, padding same.
    let mut c = b.conv_rect("stem", ch, 4, 10, 4, 2, Padding::Same, true)?;
    for blk in 1..=4 {
        c = b.dwconv(&format!("b{blk}dw"), c, 3, 1, true)?;
        c = b.conv(&format!("b{blk}pw"), ch, c, 1, 1, Padding::Same, true)?;
    }
    b.push(Layer::GlobalAvgPool);
    b.fc("head", CLASSES, c, false)?;
    Ok(b.finish("dscnn", CLASSES))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::random_input;
    use crate::util::Pcg32;

    #[test]
    fn builds_and_runs() {
        let cfg = ModelConfig::default();
        let g = build(&cfg).unwrap();
        // stem + 4×(dw+pw) + fc = 10 MAC layers
        assert_eq!(g.mac_layers(), 10);
        let mut rng = Pcg32::new(4);
        let input = random_input(input_shape(), cfg.act_params(), &mut rng);
        let out = g.forward_ref(&input).unwrap();
        assert_eq!(out.shape().numel(), CLASSES);
    }

    #[test]
    fn stem_is_rectangular() {
        let cfg = ModelConfig::default();
        let g = build(&cfg).unwrap();
        if let Layer::Conv(op) = &g.layers[0] {
            assert_eq!((op.kh, op.kw), (10, 4));
            assert_eq!(op.stride, 2);
        } else {
            panic!("first layer must be the stem conv");
        }
    }
}
