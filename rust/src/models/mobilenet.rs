//! MobileNetV2 (Visual Wake Words person detection): inverted residual
//! bottlenecks (1×1 expand → 3×3 depthwise → 1×1 project) with the
//! standard (t, c, n, s) schedule, on a 96×96 VWW-style input.

use super::builder::{GraphBuilder, ModelConfig};
use crate::error::Result;
use crate::nn::conv2d::Padding;
use crate::nn::graph::{Graph, Layer};
use crate::tensor::Shape;

/// VWW-style input: 96×96 RGB padded to 4 channels.
pub fn input_shape() -> Shape {
    Shape::nhwc(1, 96, 96, 4)
}

/// Standard MobileNetV2 schedule: (expansion t, channels c, repeats n,
/// stride s).
const SCHEDULE: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// Build MobileNetV2 at the configured width.
pub fn build(cfg: &ModelConfig) -> Result<Graph> {
    let mut b = GraphBuilder::new(cfg);
    let mut c_in = b.conv("stem", cfg.ch(32), 4, 3, 2, Padding::Same, true)?;
    let mut block_id = 0usize;
    for &(t, c, n, s) in &SCHEDULE {
        let c_out = cfg.ch(c);
        for rep in 0..n {
            block_id += 1;
            let stride = if rep == 0 { s } else { 1 };
            let hidden = (c_in * t).div_ceil(4) * 4;
            let residual = stride == 1 && c_in == c_out;
            if residual {
                b.push(Layer::Shortcut { conv: None, slot: 0 });
            }
            if t != 1 {
                b.conv(&format!("ir{block_id}expand"), hidden, c_in, 1, 1, Padding::Same, true)?;
            }
            let dw_in = if t != 1 { hidden } else { c_in };
            b.dwconv(&format!("ir{block_id}dw"), dw_in, 3, stride, true)?;
            b.conv(&format!("ir{block_id}proj"), c_out, dw_in, 1, 1, Padding::Same, false)?;
            if residual {
                let params = b.act_params();
                b.push(Layer::ResidualAdd { slot: 0, out_params: params });
            }
            c_in = c_out;
        }
    }
    let last = b.conv("head_conv", cfg.ch(1280), c_in, 1, 1, Padding::Same, true)?;
    b.push(Layer::GlobalAvgPool);
    // Person detection: 2 classes (padded to 4 outputs).
    b.fc("head", 4, last, false)?;
    Ok(b.finish("mobilenetv2", 2))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::random_input;
    use crate::util::Pcg32;

    #[test]
    fn builds_and_runs_small() {
        let cfg = ModelConfig { scale: 0.125, ..Default::default() };
        let g = build(&cfg).unwrap();
        let mut rng = Pcg32::new(3);
        // Use a reduced input for test speed (the graph is input-size
        // agnostic as long as strides divide cleanly).
        let input = random_input(Shape::nhwc(1, 32, 32, 4), cfg.act_params(), &mut rng);
        let out = g.forward_ref(&input).unwrap();
        assert_eq!(out.shape().numel(), 4);
    }

    #[test]
    fn has_17_inverted_residual_blocks() {
        let cfg = ModelConfig { scale: 0.125, ..Default::default() };
        let g = build(&cfg).unwrap();
        let dw = g
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::Conv(op) if op.depthwise))
            .count();
        assert_eq!(dw, 17); // Σ n over the schedule
    }

    #[test]
    fn residual_blocks_present() {
        let cfg = ModelConfig { scale: 0.125, ..Default::default() };
        let g = build(&cfg).unwrap();
        let adds =
            g.layers.iter().filter(|l| matches!(l, Layer::ResidualAdd { .. })).count();
        assert!(adds >= 5, "expected inverted-residual adds, got {adds}");
    }
}
