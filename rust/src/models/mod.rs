//! Model zoo: the paper's four evaluation networks (Section IV-B).
//!
//! - **VGG16** and **ResNet-56** for CIFAR-10-class image classification,
//! - **MobileNetV2** for Visual-Wake-Words person detection,
//! - **DSCNN** for Google-Speech-Commands keyword spotting.
//!
//! Models are built as [`crate::nn::Graph`]s with synthetic (seeded)
//! weights at configurable width `scale` — cycle counts depend only on
//! shapes and sparsity patterns, not on weight values, so scaled-down
//! variants reproduce the paper's *speedup ratios* while keeping the
//! cycle-accurate simulation tractable. Trained weights for the accuracy
//! experiments (Table II) are imported from the Python layer instead
//! (see `python/compile/train.py` and [`crate::runtime`]).
//!
//! All channel counts are padded to multiples of 4 (the CFU block size);
//! the image input is zero-padded from 3 to 4 channels, spectrograms
//! from 1 to 4.

pub mod builder;
pub mod dscnn;
pub mod mobilenet;
pub mod resnet;
pub mod vgg;
pub mod zoo;

pub use builder::{apply_sparsity, ModelConfig};
pub use zoo::{build_model, model_names, ModelInfo};
