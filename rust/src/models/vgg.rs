//! VGG16 (CIFAR-10 variant): thirteen 3×3 conv layers in five blocks
//! with max-pooling, then the classifier head.

use super::builder::{GraphBuilder, ModelConfig};
use crate::error::Result;
use crate::nn::conv2d::Padding;
use crate::nn::graph::{Graph, Layer};
use crate::tensor::Shape;

/// CIFAR-style input: 32×32 RGB padded to 4 channels.
pub fn input_shape() -> Shape {
    Shape::nhwc(1, 32, 32, 4)
}

/// Build VGG16 at the configured width.
pub fn build(cfg: &ModelConfig) -> Result<Graph> {
    let mut b = GraphBuilder::new(cfg);
    let mut c_in = 4usize;
    // (block channels, convs per block)
    let blocks: [(usize, usize); 5] =
        [(cfg.ch(64), 2), (cfg.ch(128), 2), (cfg.ch(256), 3), (cfg.ch(512), 3), (cfg.ch(512), 3)];
    for (bi, (ch, convs)) in blocks.iter().enumerate() {
        for ci in 0..*convs {
            let name = format!("b{}c{}", bi + 1, ci + 1);
            c_in = b.conv(&name, *ch, c_in, 3, 1, Padding::Same, true)?;
        }
        b.push(Layer::MaxPool { k: 2, stride: 2 });
    }
    // After five pools: 1×1 spatial → flatten = c_in features.
    let h = b.fc("fc1", cfg.ch(512), c_in, true)?;
    b.fc("head", 12, h, false)?;
    Ok(b.finish("vgg16", 10))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::random_input;
    use crate::util::Pcg32;

    #[test]
    fn builds_and_runs() {
        let cfg = ModelConfig { scale: 0.125, ..Default::default() };
        let g = build(&cfg).unwrap();
        assert_eq!(g.mac_layers(), 15); // 13 convs + 2 fc
        let mut rng = Pcg32::new(1);
        let input = random_input(input_shape(), cfg.act_params(), &mut rng);
        let out = g.forward_ref(&input).unwrap();
        assert_eq!(out.shape().numel(), 12);
    }

    #[test]
    fn full_scale_channel_counts() {
        let g = build(&ModelConfig::full()).unwrap();
        // first conv: 64 out channels × 3×3 × 4 in
        if let Layer::Conv(op) = &g.layers[0] {
            assert_eq!(op.out_c, 64);
            assert_eq!(op.in_c, 4);
        } else {
            panic!("first layer should be conv");
        }
        // ~15M weights at full scale (vgg16 CIFAR variant)
        assert!(g.total_weights() > 10_000_000);
    }
}
