//! ResNet-56 (CIFAR-10): 3 stages × 9 basic blocks (2 convs each) at
//! 16/32/64 channels, identity residuals, 1×1 strided projection
//! shortcuts at stage transitions.

use super::builder::{GraphBuilder, ModelConfig};
use crate::error::Result;
use crate::nn::conv2d::Padding;
use crate::nn::graph::{Graph, Layer};
use crate::tensor::Shape;

/// CIFAR-style input.
pub fn input_shape() -> Shape {
    Shape::nhwc(1, 32, 32, 4)
}

/// Blocks per stage for ResNet-56: (56 - 2) / 6 = 9.
pub const BLOCKS_PER_STAGE: usize = 9;

/// Build ResNet-56 at the configured width.
pub fn build(cfg: &ModelConfig) -> Result<Graph> {
    let mut b = GraphBuilder::new(cfg);
    let stage_ch = [cfg.ch(16), cfg.ch(32), cfg.ch(64)];
    let mut c_in = b.conv("stem", stage_ch[0], 4, 3, 1, Padding::Same, true)?;
    for (si, &ch) in stage_ch.iter().enumerate() {
        for bi in 0..BLOCKS_PER_STAGE {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            let needs_proj = stride != 1 || c_in != ch;
            let proj = if needs_proj {
                Some(Box::new(b.make_conv(
                    &format!("s{}b{}proj", si + 1, bi + 1),
                    ch,
                    c_in,
                    1,
                    stride,
                    Padding::Same,
                    false,
                )?))
            } else {
                None
            };
            b.push(Layer::Shortcut { conv: proj, slot: 0 });
            b.conv(
                &format!("s{}b{}c1", si + 1, bi + 1),
                ch,
                c_in,
                3,
                stride,
                Padding::Same,
                true,
            )?;
            b.conv(&format!("s{}b{}c2", si + 1, bi + 1), ch, ch, 3, 1, Padding::Same, false)?;
            let params = b.act_params();
            b.push(Layer::ResidualAdd { slot: 0, out_params: params });
            b.push(Layer::Relu);
            c_in = ch;
        }
    }
    b.push(Layer::GlobalAvgPool);
    b.fc("head", 12, c_in, false)?;
    Ok(b.finish("resnet56", 10))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::random_input;
    use crate::util::Pcg32;

    #[test]
    fn builds_and_runs() {
        let cfg = ModelConfig { scale: 0.25, ..Default::default() };
        let g = build(&cfg).unwrap();
        // 1 stem + 27 blocks × 2 convs + 2 projections + 1 fc
        assert_eq!(g.mac_layers(), 1 + 27 * 2 + 2 + 1);
        let mut rng = Pcg32::new(2);
        let input = random_input(input_shape(), cfg.act_params(), &mut rng);
        let out = g.forward_ref(&input).unwrap();
        assert_eq!(out.shape().numel(), 12);
    }

    #[test]
    fn stage_transitions_project() {
        let cfg = ModelConfig { scale: 0.25, ..Default::default() };
        let g = build(&cfg).unwrap();
        let projections = g
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::Shortcut { conv: Some(_), .. }))
            .count();
        assert_eq!(projections, 2);
        let identity_shortcuts = g
            .layers
            .iter()
            .filter(|l| matches!(l, Layer::Shortcut { conv: None, .. }))
            .count();
        assert_eq!(identity_shortcuts, 25);
    }
}
