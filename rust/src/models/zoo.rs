//! Model registry.

use super::builder::ModelConfig;
use super::{dscnn, mobilenet, resnet, vgg};
use crate::error::{Error, Result};
use crate::nn::graph::Graph;
use crate::tensor::Shape;

/// A zoo entry: graph + canonical input shape + dataset label.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    /// The built graph.
    pub graph: Graph,
    /// Canonical input shape.
    pub input_shape: Shape,
    /// Dataset the paper pairs the model with.
    pub dataset: &'static str,
}

/// Names accepted by [`build_model`].
pub fn model_names() -> [&'static str; 4] {
    ["vgg16", "resnet56", "mobilenetv2", "dscnn"]
}

/// Canonical input shape for a model name, without constructing the
/// graph (the shape is scale-invariant; used by the batch engine to
/// synthesize requests before any prepared model exists).
pub fn input_shape(name: &str) -> Result<Shape> {
    match name.to_ascii_lowercase().as_str() {
        "vgg16" => Ok(vgg::input_shape()),
        "resnet56" => Ok(resnet::input_shape()),
        "mobilenetv2" => Ok(mobilenet::input_shape()),
        "dscnn" => Ok(dscnn::input_shape()),
        other => Err(Error::Model(format!(
            "unknown model '{other}' (expected one of {:?})",
            model_names()
        ))),
    }
}

/// Build a model by name.
pub fn build_model(name: &str, cfg: &ModelConfig) -> Result<ModelInfo> {
    match name.to_ascii_lowercase().as_str() {
        "vgg16" => Ok(ModelInfo {
            graph: vgg::build(cfg)?,
            input_shape: vgg::input_shape(),
            dataset: "CIFAR-10",
        }),
        "resnet56" => Ok(ModelInfo {
            graph: resnet::build(cfg)?,
            input_shape: resnet::input_shape(),
            dataset: "CIFAR-10",
        }),
        "mobilenetv2" => Ok(ModelInfo {
            graph: mobilenet::build(cfg)?,
            input_shape: mobilenet::input_shape(),
            dataset: "VWW",
        }),
        "dscnn" => Ok(ModelInfo {
            graph: dscnn::build(cfg)?,
            input_shape: dscnn::input_shape(),
            dataset: "GSC",
        }),
        other => Err(Error::Model(format!(
            "unknown model '{other}' (expected one of {:?})",
            model_names()
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_models_build() {
        let cfg = ModelConfig { scale: 0.125, ..Default::default() };
        for name in model_names() {
            let info = build_model(name, &cfg).unwrap();
            assert!(info.graph.mac_layers() > 0, "{name}");
            assert_eq!(info.input_shape.rank(), 4, "{name}");
        }
    }

    #[test]
    fn unknown_model_rejected() {
        assert!(build_model("alexnet", &ModelConfig::default()).is_err());
    }

    #[test]
    fn case_insensitive() {
        assert!(build_model("DSCNN", &ModelConfig::default()).is_ok());
    }
}
