//! Shared model-construction helpers.

use crate::error::{Error, Result};
use crate::nn::conv2d::{Conv2dOp, Padding};
use crate::nn::fully_connected::FullyConnectedOp;
use crate::nn::graph::{Graph, Layer};
use crate::sparsity::prune::prune_combined;
use crate::tensor::quant::QuantParams;
use crate::tensor::{QTensor, Shape};
use crate::util::Pcg32;

/// Configuration for synthetic model construction.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Width multiplier (1.0 = paper-size model). Channel counts are
    /// scaled then rounded up to a multiple of 4.
    pub scale: f64,
    /// Weight RNG seed.
    pub seed: u64,
    /// Default activation scale.
    pub act_scale: f32,
    /// Default weight scale.
    pub weight_scale: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        // scale 0.25 keeps full-model cycle simulation tractable while
        // preserving every layer type and the channel-blocking structure.
        ModelConfig { scale: 0.25, seed: 0x5EED, act_scale: 0.05, weight_scale: 0.02 }
    }
}

impl ModelConfig {
    /// Paper-size model (scale 1.0).
    pub fn full() -> Self {
        ModelConfig { scale: 1.0, ..Default::default() }
    }

    /// Scale a channel count, rounding up to a multiple of 4 (min 4).
    pub fn ch(&self, base: usize) -> usize {
        let scaled = (base as f64 * self.scale).round().max(1.0) as usize;
        scaled.div_ceil(4) * 4
    }

    /// Default activation quant params.
    pub fn act_params(&self) -> QuantParams {
        QuantParams::new(self.act_scale, 0).unwrap()
    }
}

/// Stateful helper threading RNG + quant params through layer building.
pub struct GraphBuilder {
    cfg: ModelConfig,
    rng: Pcg32,
    layers: Vec<Layer>,
}

impl GraphBuilder {
    /// Start a builder.
    pub fn new(cfg: &ModelConfig) -> Self {
        GraphBuilder { cfg: cfg.clone(), rng: Pcg32::new(cfg.seed), layers: Vec::new() }
    }

    fn random_weights(&mut self, n: usize) -> Vec<i8> {
        // INT7-ranged so every design runs identical effective weights.
        (0..n)
            .map(|_| {
                let w = self.rng.range_i32(-64, 63) as i8;
                if w == 0 {
                    1
                } else {
                    w
                }
            })
            .collect()
    }

    fn random_bias(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.rng.range_i32(-256, 256)).collect()
    }

    /// Construct a conv op without pushing it (projection shortcuts).
    #[allow(clippy::too_many_arguments)]
    pub fn make_conv(
        &mut self,
        name: &str,
        out_c: usize,
        in_c: usize,
        k: usize,
        stride: usize,
        padding: Padding,
        relu: bool,
    ) -> Result<Conv2dOp> {
        let weights = self.random_weights(out_c * k * k * in_c);
        let bias = self.random_bias(out_c);
        Conv2dOp::new(
            name,
            weights,
            bias,
            out_c,
            in_c,
            k,
            k,
            stride,
            padding,
            false,
            self.cfg.act_params(),
            self.cfg.weight_scale,
            self.cfg.act_params(),
            relu,
        )
    }

    /// Add a conv layer (normal).
    pub fn conv(
        &mut self,
        name: &str,
        out_c: usize,
        in_c: usize,
        k: usize,
        stride: usize,
        padding: Padding,
        relu: bool,
    ) -> Result<usize> {
        let weights = self.random_weights(out_c * k * k * in_c);
        let bias = self.random_bias(out_c);
        let op = Conv2dOp::new(
            name,
            weights,
            bias,
            out_c,
            in_c,
            k,
            k,
            stride,
            padding,
            false,
            self.cfg.act_params(),
            self.cfg.weight_scale,
            self.cfg.act_params(),
            relu,
        )?;
        self.layers.push(Layer::Conv(op));
        Ok(out_c)
    }

    /// Add a non-square conv (DSCNN's 10×4 first layer).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_rect(
        &mut self,
        name: &str,
        out_c: usize,
        in_c: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        padding: Padding,
        relu: bool,
    ) -> Result<usize> {
        let weights = self.random_weights(out_c * kh * kw * in_c);
        let bias = self.random_bias(out_c);
        let op = Conv2dOp::new(
            name,
            weights,
            bias,
            out_c,
            in_c,
            kh,
            kw,
            stride,
            padding,
            false,
            self.cfg.act_params(),
            self.cfg.weight_scale,
            self.cfg.act_params(),
            relu,
        )?;
        self.layers.push(Layer::Conv(op));
        Ok(out_c)
    }

    /// Add a depthwise conv layer.
    pub fn dwconv(
        &mut self,
        name: &str,
        ch: usize,
        k: usize,
        stride: usize,
        relu: bool,
    ) -> Result<usize> {
        let weights = self.random_weights(ch * k * k);
        let bias = self.random_bias(ch);
        let op = Conv2dOp::new(
            name,
            weights,
            bias,
            ch,
            ch,
            k,
            k,
            stride,
            Padding::Same,
            true,
            self.cfg.act_params(),
            self.cfg.weight_scale,
            self.cfg.act_params(),
            relu,
        )?;
        self.layers.push(Layer::Conv(op));
        Ok(ch)
    }

    /// Add a fully-connected layer.
    pub fn fc(&mut self, name: &str, out_n: usize, in_n: usize, relu: bool) -> Result<usize> {
        let weights = self.random_weights(out_n * in_n);
        let bias = self.random_bias(out_n);
        let op = FullyConnectedOp::new(
            name,
            weights,
            bias,
            out_n,
            in_n,
            self.cfg.act_params(),
            self.cfg.weight_scale,
            self.cfg.act_params(),
            relu,
        )?;
        self.layers.push(Layer::Fc(op));
        Ok(out_n)
    }

    /// Push a raw layer.
    pub fn push(&mut self, layer: Layer) {
        self.layers.push(layer);
    }

    /// Output quant params used for residual adds.
    pub fn act_params(&self) -> QuantParams {
        self.cfg.act_params()
    }

    /// Finish the graph.
    pub fn finish(self, name: &str, classes: usize) -> Graph {
        Graph::new(name, self.layers, classes)
    }
}

/// One entry of a per-layer prune plan: how to sparsify a MAC layer's
/// weights at model-build time (the `--sparsity` grammar of the CLI).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LayerPrune {
    /// Combined magnitude pruning (Figure 10): `x_ss` of 4:4 blocks
    /// zeroed, then `x_us` unstructured zeros within survivors.
    Combined {
        /// Unstructured sparsity within surviving blocks.
        x_us: f64,
        /// 4:4 block sparsity.
        x_ss: f64,
    },
    /// N:M semi-structured enforcement: keep the `n` largest-magnitude
    /// weights of every `m` consecutive weights.
    Nm {
        /// Non-zeros kept per group.
        n: usize,
        /// Group width; must divide the layer's lane length.
        m: usize,
    },
    /// Bank-balanced pruning: reach `target` element sparsity while
    /// keeping the kept-weight count balanced across `banks` word banks.
    BankBalanced {
        /// Target element sparsity in `[0, 1]`.
        target: f64,
        /// Bank count (a word's bank is its index modulo `banks`).
        banks: usize,
    },
}

impl LayerPrune {
    /// Representative `(x_us, x_ss)` ratios for metric/report contexts:
    /// the element sparsity the recipe aims at, with block sparsity 0
    /// for the structured formats.
    pub fn context_ratios(&self) -> (f64, f64) {
        match *self {
            LayerPrune::Combined { x_us, x_ss } => (x_us, x_ss),
            LayerPrune::Nm { n, m } => (1.0 - n as f64 / m as f64, 0.0),
            LayerPrune::BankBalanced { target, .. } => (target, 0.0),
        }
    }
}

/// Apply one prune recipe to a flat weight buffer of `lane`-length
/// rows, validating structured-recipe geometry against the layer.
fn prune_ws(ws: &mut [i8], lane: usize, label: &str, prune: LayerPrune) -> Result<()> {
    match prune {
        LayerPrune::Combined { x_us, x_ss } => {
            prune_combined(ws, lane, x_ss, x_us);
            Ok(())
        }
        LayerPrune::Nm { n, m } => {
            if m == 0 || n > m || lane % m != 0 {
                return Err(Error::Cli(format!(
                    "nm{n}:{m} does not fit layer '{label}' (lane length {lane})"
                )));
            }
            crate::sparsity::prune_nm(ws, lane, n, m);
            Ok(())
        }
        LayerPrune::BankBalanced { target, banks } => {
            if banks == 0 || !(0.0..=1.0).contains(&target) {
                return Err(Error::Cli(format!(
                    "bank{target}:{banks} is not a valid bank-balanced recipe for layer \
                     '{label}' (need banks >= 1 and target in [0, 1])"
                )));
            }
            crate::sparsity::prune_bank_balanced(ws, lane, target, banks);
            Ok(())
        }
    }
}

/// Prune one layer's weights in place if it is a MAC layer; returns
/// whether it was one. Shared by the uniform, per-layer and
/// format-aware sparsity entry points.
fn prune_mac_layer_with(layer: &mut Layer, prune: LayerPrune) -> Result<bool> {
    match layer {
        Layer::Conv(op) => {
            let lane = op.lane_len();
            if op.depthwise {
                // depthwise lanes are kh*kw (may not be %4); prune at
                // element granularity only.
                let n = op.weights.len();
                let padded_lane = lane.div_ceil(4) * 4;
                let mut padded = vec![0i8; (n / lane) * padded_lane];
                for (i, chunk) in op.weights.chunks(lane).enumerate() {
                    padded[i * padded_lane..i * padded_lane + lane].copy_from_slice(chunk);
                }
                prune_ws(&mut padded, padded_lane, &op.name, prune)?;
                for (i, chunk) in op.weights.chunks_mut(lane).enumerate() {
                    chunk.copy_from_slice(&padded[i * padded_lane..i * padded_lane + lane]);
                }
            } else {
                prune_ws(&mut op.weights, lane, &op.name, prune)?;
            }
            Ok(true)
        }
        Layer::Fc(op) => {
            prune_ws(&mut op.weights, op.in_n, &op.name, prune)?;
            Ok(true)
        }
        Layer::Shortcut { conv: Some(op), .. } => {
            let lane = op.lane_len();
            prune_ws(&mut op.weights, lane, &op.name, prune)?;
            Ok(true)
        }
        _ => Ok(false),
    }
}

fn prune_mac_layer(layer: &mut Layer, x_us: f64, x_ss: f64) -> bool {
    prune_mac_layer_with(layer, LayerPrune::Combined { x_us, x_ss })
        .expect("combined pruning is infallible")
}

/// Apply combined sparsity to every MAC layer of a graph in place
/// (Figure 10's (x_us, x_ss) parameterization: x_ss of blocks zeroed,
/// then x_us unstructured zeros within surviving blocks).
pub fn apply_sparsity(graph: &mut Graph, x_us: f64, x_ss: f64) {
    for layer in &mut graph.layers {
        prune_mac_layer(layer, x_us, x_ss);
    }
}

/// Apply a *per-layer* sparsity plan: MAC layer `i` (graph order —
/// convolutions, fully-connected layers, projection shortcuts) is
/// pruned to `plan[i % plan.len()] = (x_us, x_ss)`. The plan is cycled
/// when shorter than the model, mirroring
/// [`crate::isa::DesignAssignment::design_for`], so compact specs apply
/// to any model. A no-op on an empty plan.
///
/// Mixed plans are the workload the co-design explorer
/// ([`crate::explorer`]) exists for: real pruned networks do not share
/// one sparsity structure across layers.
pub fn apply_sparsity_plan(graph: &mut Graph, plan: &[(f64, f64)]) {
    if plan.is_empty() {
        return;
    }
    let mut mac_idx = 0usize;
    for layer in &mut graph.layers {
        let (x_us, x_ss) = plan[mac_idx % plan.len()];
        if prune_mac_layer(layer, x_us, x_ss) {
            mac_idx += 1;
        }
    }
}

/// Apply a *per-layer* prune plan mixing combined, N:M and
/// bank-balanced recipes — the format-aware superset of
/// [`apply_sparsity_plan`], cycled over MAC layers the same way. Errors
/// when a structured recipe does not fit a layer's lane geometry (e.g.
/// an `m` that does not divide the lane length). A no-op on an empty
/// plan.
pub fn apply_prune_plan(graph: &mut Graph, plan: &[LayerPrune]) -> Result<()> {
    if plan.is_empty() {
        return Ok(());
    }
    let mut mac_idx = 0usize;
    for layer in &mut graph.layers {
        if prune_mac_layer_with(layer, plan[mac_idx % plan.len()])? {
            mac_idx += 1;
        }
    }
    Ok(())
}

/// Push the listed MAC layers' non-zero weights outside the INT7
/// dynamic range (saturating ±64 shift: `w → w ± 64`), leaving zero
/// weights — and therefore every sparsity pattern, lookahead skip chain
/// and per-design cycle count — untouched.
///
/// This models layers whose quantized weights genuinely need the full
/// INT8 range (typically stems and classifier heads, which calibrate to
/// wider per-layer scales). On such layers the SSSA/CSA lookahead
/// designs must clamp to INT7 (the paper's Section III-B dynamic-range
/// restriction) and stop being bit-exact — the fidelity constraint the
/// explorer's lossless mode enforces. Indices outside the model's
/// MAC-layer count are ignored.
pub fn widen_weights_to_int8(graph: &mut Graph, mac_indices: &[usize]) {
    let widen = |ws: &mut [i8]| {
        for w in ws {
            *w = match (*w as i32).signum() {
                1 => ((*w as i32) + 64).min(127) as i8,
                -1 => ((*w as i32) - 64).max(-128) as i8,
                _ => 0,
            };
        }
    };
    for (mac_idx, ws) in graph.mac_weights_mut().into_iter().enumerate() {
        if mac_indices.contains(&mac_idx) {
            widen(ws.as_mut_slice());
        }
    }
}

/// Generate a random input activation tensor for a model input shape.
pub fn random_input(shape: Shape, params: QuantParams, rng: &mut Pcg32) -> QTensor {
    let data: Vec<i8> = (0..shape.numel()).map(|_| rng.range_i32(-128, 127) as i8).collect();
    QTensor::new(shape, data, params).unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn channel_scaling_multiple_of_4() {
        let cfg = ModelConfig { scale: 0.3, ..Default::default() };
        assert_eq!(cfg.ch(64) % 4, 0);
        assert!(cfg.ch(64) >= 4);
        let full = ModelConfig::full();
        assert_eq!(full.ch(64), 64);
        assert_eq!(full.ch(3), 4); // rounds up to block size
    }

    #[test]
    fn builder_produces_runnable_graph() {
        let cfg = ModelConfig::default();
        let mut b = GraphBuilder::new(&cfg);
        let c = b.conv("c1", 8, 4, 3, 1, Padding::Same, true).unwrap();
        b.push(Layer::MaxPool { k: 2, stride: 2 });
        let c = b.conv("c2", 8, c, 3, 1, Padding::Same, true).unwrap();
        b.push(Layer::GlobalAvgPool);
        b.fc("head", 10, c, false).unwrap();
        let g = b.finish("tiny", 10);
        let mut rng = Pcg32::new(1);
        let input = random_input(Shape::nhwc(1, 8, 8, 4), cfg.act_params(), &mut rng);
        let out = g.forward_ref(&input).unwrap();
        assert_eq!(out.shape().dims(), &[1, 10]);
    }

    #[test]
    fn per_layer_plan_prunes_each_mac_layer_differently() {
        let cfg = ModelConfig::default();
        let mut b = GraphBuilder::new(&cfg);
        b.conv("c1", 16, 16, 3, 1, Padding::Same, true).unwrap();
        b.conv("c2", 16, 16, 3, 1, Padding::Same, true).unwrap();
        let mut g = b.finish("t", 16);
        apply_sparsity_plan(&mut g, &[(0.0, 0.6), (0.0, 0.0)]);
        let blocks: Vec<f64> = g
            .layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv(op) => Some(
                    crate::sparsity::stats::SparsityProfile::measure(&op.weights, op.in_c).block,
                ),
                _ => None,
            })
            .collect();
        assert!((blocks[0] - 0.6).abs() < 0.05, "layer 0 block {}", blocks[0]);
        assert!(blocks[1] < 0.05, "layer 1 block {}", blocks[1]);
        // Empty plan is a no-op.
        let before: Vec<i8> = match &g.layers[0] {
            Layer::Conv(op) => op.weights.clone(),
            _ => unreachable!(),
        };
        apply_sparsity_plan(&mut g, &[]);
        match &g.layers[0] {
            Layer::Conv(op) => assert_eq!(op.weights, before),
            _ => unreachable!(),
        }
    }

    #[test]
    fn widen_weights_preserves_sparsity_pattern() {
        let cfg = ModelConfig::default();
        let mut b = GraphBuilder::new(&cfg);
        b.conv("c1", 8, 8, 3, 1, Padding::Same, true).unwrap();
        b.fc("head", 8, 32, false).unwrap();
        let mut g = b.finish("t", 8);
        apply_sparsity(&mut g, 0.5, 0.2);
        let zeros = |g: &Graph, i: usize| -> Vec<bool> {
            match &g.layers[i] {
                Layer::Conv(op) => op.weights.iter().map(|&w| w == 0).collect(),
                Layer::Fc(op) => op.weights.iter().map(|&w| w == 0).collect(),
                _ => unreachable!(),
            }
        };
        let conv_zero_pattern = zeros(&g, 0);
        widen_weights_to_int8(&mut g, &[0]);
        assert_eq!(zeros(&g, 0), conv_zero_pattern, "zero pattern must survive widening");
        // Widened layer: every non-zero weight is outside INT7 range.
        match &g.layers[0] {
            Layer::Conv(op) => {
                assert!(op.weights.iter().any(|&w| w != 0));
                for &w in &op.weights {
                    assert!(w == 0 || !crate::encoding::int7::is_int7(w), "{w}");
                }
            }
            _ => unreachable!(),
        }
        // Untouched layer (index 1) stays INT7.
        match &g.layers[1] {
            Layer::Fc(op) => {
                assert!(op.weights.iter().all(|&w| crate::encoding::int7::is_int7(w)));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn prune_plan_applies_formats_and_rejects_bad_geometry() {
        let build = || {
            let cfg = ModelConfig::default();
            let mut b = GraphBuilder::new(&cfg);
            b.conv("c1", 16, 16, 3, 1, Padding::Same, true).unwrap();
            b.fc("fc", 16, 64, false).unwrap();
            b.finish("t", 16)
        };
        // N:M on the conv, bank-balanced on the fc (plan cycled in MAC
        // order).
        let mut g = build();
        apply_prune_plan(
            &mut g,
            &[LayerPrune::Nm { n: 1, m: 4 }, LayerPrune::BankBalanced { target: 0.5, banks: 4 }],
        )
        .unwrap();
        for layer in &g.layers {
            match layer {
                Layer::Conv(op) => {
                    for group in op.weights.chunks(4) {
                        assert!(group.iter().filter(|&&w| w != 0).count() <= 1);
                    }
                }
                Layer::Fc(op) => {
                    for lane in op.weights.chunks(op.in_n) {
                        let mut per_bank = [0usize; 4];
                        for (i, &w) in lane.iter().enumerate() {
                            if w != 0 {
                                per_bank[(i / 4) % 4] += 1;
                            }
                        }
                        let (min, max) =
                            (per_bank.iter().min().unwrap(), per_bank.iter().max().unwrap());
                        assert!(max - min <= 1, "banks {per_bank:?}");
                    }
                }
                _ => {}
            }
        }
        // Context ratios summarize each recipe as an element sparsity.
        assert_eq!(LayerPrune::Nm { n: 1, m: 4 }.context_ratios(), (0.75, 0.0));
        assert_eq!(
            LayerPrune::BankBalanced { target: 0.5, banks: 4 }.context_ratios(),
            (0.5, 0.0)
        );
        // m = 5 cannot divide this shape's 144-weight conv lanes.
        let mut g = build();
        assert!(apply_prune_plan(&mut g, &[LayerPrune::Nm { n: 1, m: 5 }]).is_err());
    }

    #[test]
    fn apply_sparsity_reaches_targets() {
        let cfg = ModelConfig::default();
        let mut b = GraphBuilder::new(&cfg);
        b.conv("c1", 16, 16, 3, 1, Padding::Same, true).unwrap();
        b.fc("fc", 16, 64, false).unwrap();
        let mut g = b.finish("t", 16);
        apply_sparsity(&mut g, 0.5, 0.4);
        for layer in &g.layers {
            if let Layer::Conv(op) = layer {
                let p = crate::sparsity::stats::SparsityProfile::measure(&op.weights, op.in_c);
                assert!((p.block - 0.4).abs() < 0.05, "block {}", p.block);
            }
        }
    }
}
