//! Quantized INT8 tensor with NHWC storage.

use super::quant::{dequantize_i8, quantize_f32, QuantParams};
use super::shape::Shape;
use crate::error::{Error, Result};

/// An INT8 tensor + its quantization parameters.
///
/// Storage is row-major over the shape dims; for rank-4 activations this
/// is NHWC (channels innermost — the layout the paper's kernels walk in
/// blocks of 4 along the input-channel dimension).
#[derive(Debug, Clone)]
pub struct QTensor {
    shape: Shape,
    data: Vec<i8>,
    params: QuantParams,
}

impl QTensor {
    /// Create from raw data (length must match shape).
    pub fn new(shape: Shape, data: Vec<i8>, params: QuantParams) -> Result<Self> {
        if data.len() != shape.numel() {
            return Err(Error::Shape(format!(
                "data length {} != shape {} numel {}",
                data.len(),
                shape,
                shape.numel()
            )));
        }
        Ok(QTensor { shape, data, params })
    }

    /// All-zero-point tensor ("real zero").
    pub fn zeros(shape: Shape, params: QuantParams) -> Self {
        let n = shape.numel();
        let zp = params.zero_point.clamp(-128, 127) as i8;
        QTensor { shape, data: vec![zp; n], params }
    }

    /// Quantize a float slice.
    pub fn from_f32(shape: Shape, xs: &[f32], params: QuantParams) -> Result<Self> {
        if xs.len() != shape.numel() {
            return Err(Error::Shape(format!(
                "float data length {} != shape numel {}",
                xs.len(),
                shape.numel()
            )));
        }
        let data = xs.iter().map(|&x| quantize_f32(x, &params)).collect();
        Ok(QTensor { shape, data, params })
    }

    /// Shape accessor.
    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    /// Quantization params accessor.
    pub fn params(&self) -> &QuantParams {
        &self.params
    }

    /// Raw data accessor.
    pub fn data(&self) -> &[i8] {
        &self.data
    }

    /// Mutable raw data accessor.
    pub fn data_mut(&mut self) -> &mut [i8] {
        &mut self.data
    }

    /// Flat element access.
    #[inline]
    pub fn at(&self, idx: &[usize]) -> i8 {
        self.data[self.shape.index(idx)]
    }

    /// Flat element set.
    #[inline]
    pub fn set(&mut self, idx: &[usize], v: i8) {
        let flat = self.shape.index(idx);
        self.data[flat] = v;
    }

    /// Dequantize all elements.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&q| dequantize_i8(q, &self.params)).collect()
    }

    /// Fraction of elements equal to the *quantized zero* (for weights:
    /// literal 0 since weights are symmetric). This is the paper's
    /// "sparsity ratio x".
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let zero = if self.params.zero_point == 0 {
            0i8
        } else {
            self.params.zero_point.clamp(-128, 127) as i8
        };
        let zeros = self.data.iter().filter(|&&q| q == zero).count();
        zeros as f64 / self.data.len() as f64
    }

    /// Reinterpret with a new shape of identical numel (e.g. flatten).
    pub fn reshaped(&self, shape: Shape) -> Result<QTensor> {
        if shape.numel() != self.shape.numel() {
            return Err(Error::Shape(format!(
                "reshape {} -> {} changes numel",
                self.shape, shape
            )));
        }
        Ok(QTensor { shape, data: self.data.clone(), params: self.params })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> QuantParams {
        QuantParams::new(0.1, 0).unwrap()
    }

    #[test]
    fn length_checked() {
        assert!(QTensor::new(Shape::d2(2, 3), vec![0; 5], p()).is_err());
        assert!(QTensor::new(Shape::d2(2, 3), vec![0; 6], p()).is_ok());
    }

    #[test]
    fn zeros_uses_zero_point() {
        let params = QuantParams::new(0.1, -7).unwrap();
        let t = QTensor::zeros(Shape::d1(4), params);
        assert!(t.data().iter().all(|&q| q == -7));
        assert!(t.to_f32().iter().all(|&x| x.abs() < 1e-6));
    }

    #[test]
    fn from_f32_roundtrip() {
        let xs = [0.0f32, 0.1, -0.3, 1.25, -12.8, 12.7];
        let t = QTensor::from_f32(Shape::d1(6), &xs, p()).unwrap();
        let back = t.to_f32();
        for (a, b) in xs.iter().zip(&back) {
            assert!((a - b).abs() <= 0.05 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn sparsity_counts_zero_point() {
        let t = QTensor::new(Shape::d1(8), vec![0, 0, 1, 0, -3, 0, 0, 5], p()).unwrap();
        assert!((t.sparsity() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn at_set_roundtrip_nhwc() {
        let mut t = QTensor::zeros(Shape::nhwc(1, 2, 2, 4), p());
        t.set(&[0, 1, 0, 3], 42);
        assert_eq!(t.at(&[0, 1, 0, 3]), 42);
        // NHWC flat position: ((0*2+1)*2+0)*4+3 = 11
        assert_eq!(t.data()[11], 42);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = QTensor::new(Shape::d2(2, 6), (0..12).map(|i| i as i8).collect(), p()).unwrap();
        let r = t.reshaped(Shape::nhwc(1, 2, 2, 3)).unwrap();
        assert_eq!(r.data(), t.data());
        assert!(t.reshaped(Shape::d1(11)).is_err());
    }
}
