//! Quantized tensor substrate.
//!
//! The paper's kernels run TFLite-Micro style INT8 inference on the
//! RISC-V core: per-tensor affine activations (`real = scale * (q - zp)`),
//! symmetric per-tensor weights (`zp = 0`), INT32 accumulators, and
//! gemmlowp fixed-point requantization. [`quant`] reproduces that
//! arithmetic bit-for-bit; [`qtensor`] stores NHWC-laid-out INT8 data;
//! [`shape`] provides dimension bookkeeping.

pub mod qtensor;
pub mod quant;
pub mod shape;

pub use qtensor::QTensor;
pub use quant::{quantize_f32, dequantize_i8, QuantParams, Requantizer};
pub use shape::Shape;
