//! Tensor shape bookkeeping (row-major / NHWC).

use crate::error::{Error, Result};

/// A tensor shape: up to 4 dimensions stored as `[N, H, W, C]` for
/// activations and `[Out, Kh, Kw, In]` for convolution filters.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Shape {
    dims: Vec<usize>,
}

impl Shape {
    /// Create a shape; rejects empty and zero-sized dims.
    pub fn new(dims: &[usize]) -> Result<Self> {
        if dims.is_empty() {
            return Err(Error::Shape("shape must have at least one dim".into()));
        }
        if dims.iter().any(|&d| d == 0) {
            return Err(Error::Shape(format!("zero-sized dim in {dims:?}")));
        }
        Ok(Shape { dims: dims.to_vec() })
    }

    /// 1-D shape.
    pub fn d1(a: usize) -> Self {
        Shape { dims: vec![a] }
    }

    /// 2-D shape.
    pub fn d2(a: usize, b: usize) -> Self {
        Shape { dims: vec![a, b] }
    }

    /// 4-D NHWC shape.
    pub fn nhwc(n: usize, h: usize, w: usize, c: usize) -> Self {
        Shape { dims: vec![n, h, w, c] }
    }

    /// Dimension list.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Number of dimensions.
    pub fn rank(&self) -> usize {
        self.dims.len()
    }

    /// Total element count.
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.dims.len()];
        for i in (0..self.dims.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.dims[i + 1];
        }
        s
    }

    /// Flat index of a multi-index; debug-checked.
    #[inline]
    pub fn index(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.dims.len());
        let mut flat = 0usize;
        for (i, (&ix, &d)) in idx.iter().zip(&self.dims).enumerate() {
            debug_assert!(ix < d, "index {ix} out of bound {d} at dim {i}");
            flat = flat * d + ix;
        }
        flat
    }

    /// NHWC accessor helpers for rank-4 shapes.
    pub fn n(&self) -> usize {
        self.dims[0]
    }
    /// Height (rank-4).
    pub fn h(&self) -> usize {
        self.dims[1]
    }
    /// Width (rank-4).
    pub fn w(&self) -> usize {
        self.dims[2]
    }
    /// Channels (rank-4, innermost).
    pub fn c(&self) -> usize {
        self.dims[3]
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, "x")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_strides() {
        let s = Shape::nhwc(1, 4, 5, 8);
        assert_eq!(s.numel(), 160);
        assert_eq!(s.strides(), vec![160, 40, 8, 1]);
    }

    #[test]
    fn flat_index_matches_strides() {
        let s = Shape::nhwc(2, 3, 4, 5);
        let strides = s.strides();
        for n in 0..2 {
            for h in 0..3 {
                for w in 0..4 {
                    for c in 0..5 {
                        let flat = s.index(&[n, h, w, c]);
                        let expect =
                            n * strides[0] + h * strides[1] + w * strides[2] + c * strides[3];
                        assert_eq!(flat, expect);
                    }
                }
            }
        }
    }

    #[test]
    fn zero_dim_rejected() {
        assert!(Shape::new(&[4, 0, 2]).is_err());
        assert!(Shape::new(&[]).is_err());
    }

    #[test]
    fn display_format() {
        assert_eq!(Shape::nhwc(1, 2, 3, 4).to_string(), "[1x2x3x4]");
    }
}
