//! TFLite / gemmlowp quantized arithmetic, bit-exact.
//!
//! - Activations: `real = scale * (q - zero_point)`, `q ∈ [-128, 127]`.
//! - Weights: symmetric per-tensor (`zero_point = 0`). The paper's SSSA
//!   design additionally restricts weights to INT7 range `[-64, 63]`
//!   (Section III-B) so the post-sign MSB can carry lookahead bits.
//! - Accumulation in `i32`, then requantization via
//!   `SaturatingRoundingDoublingHighMul` + rounding divide-by-power-of-two,
//!   exactly as TFLite's `MultiplyByQuantizedMultiplier`.

use crate::error::{Error, Result};

/// Affine quantization parameters of one tensor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantParams {
    /// Positive real scale.
    pub scale: f32,
    /// Zero point in `[-128, 127]` (0 for symmetric weights).
    pub zero_point: i32,
}

impl QuantParams {
    /// Construct with validation.
    pub fn new(scale: f32, zero_point: i32) -> Result<Self> {
        if !(scale.is_finite() && scale > 0.0) {
            return Err(Error::Quant(format!("scale must be positive finite, got {scale}")));
        }
        if !(-128..=127).contains(&zero_point) {
            return Err(Error::Quant(format!("zero_point out of i8 range: {zero_point}")));
        }
        Ok(QuantParams { scale, zero_point })
    }

    /// Symmetric params (zero_point = 0).
    pub fn symmetric(scale: f32) -> Result<Self> {
        QuantParams::new(scale, 0)
    }

    /// Choose params covering `[lo, hi]` for asymmetric INT8 activations
    /// (TFLite `ChooseQuantizationParams`).
    pub fn from_range(lo: f32, hi: f32) -> Result<Self> {
        let lo = lo.min(0.0); // range must include 0
        let hi = hi.max(0.0);
        let scale = ((hi - lo) / 255.0).max(1e-9);
        let zp_real = -128.0 - lo / scale;
        let zero_point = zp_real.round().clamp(-128.0, 127.0) as i32;
        QuantParams::new(scale, zero_point)
    }

    /// Symmetric params covering `[-max_abs, max_abs]` for INT8 weights.
    pub fn symmetric_from_max_abs(max_abs: f32) -> Result<Self> {
        QuantParams::symmetric((max_abs / 127.0).max(1e-9))
    }

    /// Symmetric params for INT7 weights (range `[-64, 63]`, the paper's
    /// "sacrificed bit" precision).
    pub fn symmetric_int7_from_max_abs(max_abs: f32) -> Result<Self> {
        QuantParams::symmetric((max_abs / 63.0).max(1e-9))
    }
}

/// Quantize a real value to i8 under `params`.
#[inline]
pub fn quantize_f32(x: f32, params: &QuantParams) -> i8 {
    let q = (x / params.scale).round() as i32 + params.zero_point;
    q.clamp(-128, 127) as i8
}

/// Dequantize an i8 value.
#[inline]
pub fn dequantize_i8(q: i8, params: &QuantParams) -> f32 {
    params.scale * (q as i32 - params.zero_point) as f32
}

/// gemmlowp `SaturatingRoundingDoublingHighMul`.
#[inline]
pub fn sat_rounding_doubling_high_mul(a: i32, b: i32) -> i32 {
    if a == i32::MIN && b == i32::MIN {
        return i32::MAX; // the single overflow case
    }
    let ab: i64 = a as i64 * b as i64;
    let nudge: i64 = if ab >= 0 { 1 << 30 } else { 1 - (1 << 30) };
    // gemmlowp divides (truncation toward zero), not an arithmetic shift —
    // the two differ by one for negative operands.
    ((ab + nudge) / (1i64 << 31)) as i32
}

/// gemmlowp `RoundingDivideByPOT` (round-half-away-from-zero).
#[inline]
pub fn rounding_divide_by_pot(x: i32, exponent: i32) -> i32 {
    debug_assert!((0..=31).contains(&exponent));
    if exponent == 0 {
        return x;
    }
    let mask: i32 = (1i64 << exponent).wrapping_sub(1) as i32;
    let remainder = x & mask;
    let threshold = (mask >> 1) + (if x < 0 { 1 } else { 0 });
    (x >> exponent) + (if remainder > threshold { 1 } else { 0 })
}

/// TFLite `MultiplyByQuantizedMultiplier`: `x * mult * 2^shift` where
/// `mult` is Q31 and `shift` may be negative (right) or positive (left).
#[inline]
pub fn multiply_by_quantized_multiplier(x: i32, quantized_multiplier: i32, shift: i32) -> i32 {
    let left_shift = if shift > 0 { shift } else { 0 };
    let right_shift = if shift > 0 { 0 } else { -shift };
    rounding_divide_by_pot(
        sat_rounding_doubling_high_mul(x << left_shift, quantized_multiplier),
        right_shift,
    )
}

/// Decompose a positive real multiplier into (Q31 quantized multiplier,
/// shift) — TFLite `QuantizeMultiplier`.
pub fn quantize_multiplier(real: f64) -> Result<(i32, i32)> {
    if real <= 0.0 || !real.is_finite() {
        return Err(Error::Quant(format!("multiplier must be positive finite, got {real}")));
    }
    // real = m * 2^e with m in [0.5, 1)
    let (mut m, mut e) = {
        let e = real.log2().floor() as i32 + 1;
        (real / 2f64.powi(e), e)
    };
    debug_assert!((0.5..1.0).contains(&m) || (m - 1.0).abs() < 1e-15);
    let mut q = (m * (1i64 << 31) as f64).round() as i64;
    if q == 1i64 << 31 {
        q /= 2;
        e += 1;
        m = 0.5;
    }
    let _ = m;
    if e > 30 {
        return Err(Error::Quant(format!("multiplier too large: {real}")));
    }
    if e < -31 {
        // Effectively zero at i32 precision.
        return Ok((0, 0));
    }
    Ok((q as i32, e))
}

/// A requantization stage: output scale conversion + zero point + clamp.
///
/// Folds `acc_real = in_scale * w_scale * acc_i32` into
/// `q_out = clamp(zp_out + MBQM(acc, mult, shift))`.
#[derive(Debug, Clone, Copy)]
pub struct Requantizer {
    /// Q31 multiplier.
    pub multiplier: i32,
    /// Binary exponent (shift).
    pub shift: i32,
    /// Output zero point.
    pub output_zp: i32,
    /// Activation clamp low (after zp), e.g. -128 or zp for ReLU.
    pub qmin: i32,
    /// Activation clamp high.
    pub qmax: i32,
}

impl Requantizer {
    /// Build from real scales. `relu` clamps the real output at 0.
    pub fn new(
        input_scale: f32,
        weight_scale: f32,
        output: &QuantParams,
        relu: bool,
    ) -> Result<Self> {
        let real_mult = input_scale as f64 * weight_scale as f64 / output.scale as f64;
        let (multiplier, shift) = quantize_multiplier(real_mult)?;
        let qmin = if relu { output.zero_point.max(-128) } else { -128 };
        Ok(Requantizer { multiplier, shift, output_zp: output.zero_point, qmin, qmax: 127 })
    }

    /// Requantize an i32 accumulator to i8.
    #[inline]
    pub fn apply(&self, acc: i32) -> i8 {
        let scaled = multiply_by_quantized_multiplier(acc, self.multiplier, self.shift);
        (scaled + self.output_zp).clamp(self.qmin, self.qmax) as i8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    #[test]
    fn quantize_dequantize_roundtrip_error_below_half_scale() {
        let p = QuantParams::new(0.05, 10).unwrap();
        for i in -100..=100 {
            let x = i as f32 * 0.033;
            let q = quantize_f32(x, &p);
            let back = dequantize_i8(q, &p);
            if (-128 - p.zero_point) as f32 * p.scale < x
                && x < (127 - p.zero_point) as f32 * p.scale
            {
                assert!((back - x).abs() <= p.scale * 0.5 + 1e-6, "x={x} back={back}");
            }
        }
    }

    #[test]
    fn from_range_contains_zero_exactly() {
        let p = QuantParams::from_range(-1.0, 3.0).unwrap();
        // zero must be exactly representable
        let q0 = quantize_f32(0.0, &p);
        assert!((dequantize_i8(q0, &p)).abs() < 1e-7);
    }

    #[test]
    fn srdhm_reference_values() {
        // SRDHM(x, q) = round(x * q / 2^31); with q = 2^30 (0.5 in Q31)
        // the result is x/2.
        assert_eq!(sat_rounding_doubling_high_mul(1 << 20, 1 << 30), 1 << 19);
        assert_eq!(sat_rounding_doubling_high_mul(i32::MIN, i32::MIN), i32::MAX);
        assert_eq!(sat_rounding_doubling_high_mul(0, 12345), 0);
        // Negative symmetric (truncating-division semantics).
        assert_eq!(sat_rounding_doubling_high_mul(-(1 << 20), 1 << 30), -(1 << 19));
        // Rounding at .5: gemmlowp is asymmetric here — +1.5 → 2 but
        // -1.5 → -1 (nudge + truncating division), bit-exact with the
        // C++ reference.
        assert_eq!(sat_rounding_doubling_high_mul(3, 1 << 30), 2);
        assert_eq!(sat_rounding_doubling_high_mul(-3, 1 << 30), -1);
    }

    #[test]
    fn rounding_divide_matches_round_half_away() {
        assert_eq!(rounding_divide_by_pot(5, 1), 3); // 2.5 → 3
        assert_eq!(rounding_divide_by_pot(-5, 1), -3); // -2.5 → -3 (away from 0... gemmlowp: -2.5 → -2? )
        assert_eq!(rounding_divide_by_pot(4, 1), 2);
        assert_eq!(rounding_divide_by_pot(7, 2), 2); // 1.75 → 2
        assert_eq!(rounding_divide_by_pot(x_ref(), 0), x_ref());
    }

    fn x_ref() -> i32 {
        123456
    }

    #[test]
    fn quantize_multiplier_identity() {
        let (q, s) = quantize_multiplier(1.0).unwrap();
        // 1.0 = 0.5 * 2^1 → q = 2^30, shift = 1... our convention: m in [0.5,1), e such that real = m*2^e
        assert_eq!(s, 1);
        assert_eq!(q, 1 << 30);
        // Apply: x * 1.0 == x
        for x in [-1000, -1, 0, 1, 999, 65536] {
            assert_eq!(multiply_by_quantized_multiplier(x, q, s), x);
        }
    }

    #[test]
    fn quantize_multiplier_small_values() {
        let (q, s) = quantize_multiplier(0.0009765625).unwrap(); // 2^-10
        for x in [-4096, -1024, 0, 1024, 1 << 20] {
            let got = multiply_by_quantized_multiplier(x, q, s);
            let expect = (x as f64 * 0.0009765625).round() as i32;
            assert!((got - expect).abs() <= 1, "x={x} got={got} expect={expect}");
        }
    }

    #[test]
    fn prop_mbqm_close_to_real_product() {
        check(
            Config::default().cases(256),
            |r: &mut Pcg32| {
                let x = r.range_i32(-1 << 20, 1 << 20);
                let m = r.range_i32(1, 1000);
                (x, m)
            },
            |&(x, m)| {
                if m < 1 {
                    return true; // shrink candidates may leave the domain
                }
                let real = m as f64 / 1024.0; // multipliers in (0, ~1)
                let (q, s) = quantize_multiplier(real).unwrap();
                let got = multiply_by_quantized_multiplier(x, q, s) as f64;
                let expect = x as f64 * real;
                (got - expect).abs() <= 1.0 + expect.abs() * 1e-6
            },
        );
    }

    #[test]
    fn requantizer_clamps_and_offsets() {
        let out = QuantParams::new(0.1, -10).unwrap();
        let rq = Requantizer::new(0.05, 0.02, &out, false).unwrap();
        // acc=1000 → real = 1.0 → q = -10 + 10 = 0
        assert_eq!(rq.apply(1000), 0);
        // Huge accumulator saturates at 127.
        assert_eq!(rq.apply(i32::MAX / 2), 127);
        assert_eq!(rq.apply(i32::MIN / 2), -128);
    }

    #[test]
    fn requantizer_relu_clamps_at_zero_point() {
        let out = QuantParams::new(0.1, -10).unwrap();
        let rq = Requantizer::new(0.05, 0.02, &out, true).unwrap();
        // Negative real output → clamped to zp (-10), i.e. real 0.
        assert_eq!(rq.apply(-100_000), -10);
    }

    #[test]
    fn int7_params_span() {
        let p = QuantParams::symmetric_int7_from_max_abs(6.3).unwrap();
        assert!((p.scale - 0.1).abs() < 1e-6);
        // 6.3 / 0.1 = 63 → fits INT7
        assert_eq!(quantize_f32(6.3, &p), 63);
        assert_eq!(quantize_f32(-6.4, &p), -64);
    }
}
