//! `sparse-riscv` — leader binary: encode weights, run experiments,
//! serve inference, estimate resources.

use sparse_riscv::analysis::codesign::{design_cost, parse_budget, within_budget};
use sparse_riscv::analysis::report::{f2, pct, render_metric_records, Table};
use sparse_riscv::bench::e2e::{render as render_e2e, run_e2e, to_records, E2eConfig};
use sparse_riscv::bench::explore::{run_explore_bench, to_record as explore_record};
use sparse_riscv::cli::{ArgSpec, Command, ParsedArgs};
use sparse_riscv::config::experiment::{ExperimentConfig, SimOptions};
use sparse_riscv::config::value::Value;
use sparse_riscv::coordinator::batch::{BatchEngine, BatchOptions, BatchSpec};
use sparse_riscv::coordinator::fleet::{run_tenant_trace, Fleet, FleetOptions, TenantTrace};
use sparse_riscv::coordinator::loadgen::{self, Arrival, TraceConfig};
use sparse_riscv::coordinator::net::{NetOptions, NetServer};
use sparse_riscv::coordinator::runner::run_experiment;
use sparse_riscv::coordinator::serve::{Server, ServeOptions};
use sparse_riscv::encoding::lookahead::encode_lanes;
use sparse_riscv::explorer::{explore, profile_graph, ExplorerOptions};
use sparse_riscv::faults::{FaultPlan, FaultRates};
use sparse_riscv::isa::{DesignAssignment, DesignKind};
use sparse_riscv::kernels::{ExecMode, HostKernel};
use sparse_riscv::metrics::{diff as metrics_diff, BaselineStore, Tolerances};
use sparse_riscv::models::builder::{
    apply_prune_plan, random_input, widen_weights_to_int8, LayerPrune, ModelConfig,
};
use sparse_riscv::models::zoo::{build_model, model_names};
use sparse_riscv::resources::fpga::{estimate_cfu, paper_increment, BASELINE_SOC};
use sparse_riscv::sparsity::generator::gen_combined_sparse;
use sparse_riscv::util::Pcg32;
use std::time::Duration;

fn cli() -> Command {
    Command::new("sparse-riscv", "RISC-V sparse-DNN CFU co-design simulator")
        .subcommand(
            Command::new("experiment", "simulate a model on the accelerator designs")
                .arg(ArgSpec::opt("model", "dscnn", "model (vgg16|resnet56|mobilenetv2|dscnn)"))
                .arg(ArgSpec::opt("designs", "sssa,ussa,csa", "comma-separated designs"))
                .arg(ArgSpec::opt("x-us", "0.5", "unstructured sparsity within blocks"))
                .arg(ArgSpec::opt("x-ss", "0.3", "4:4 block sparsity"))
                .arg(ArgSpec::opt("scale", "0.125", "model width multiplier"))
                .arg(ArgSpec::opt("batch", "1", "inference requests"))
                .arg(ArgSpec::opt("threads", "0", "worker threads (0=auto)"))
                .arg(ArgSpec::opt("seed", "42", "rng seed"))
                .arg(ArgSpec::flag("verify", "verify kernels against reference ops"))
                .arg(ArgSpec::opt("config", "", "JSON experiment config file (overrides flags)")),
        )
        .subcommand(
            Command::new("serve", "serve a stream of inference requests in batches")
                .arg(ArgSpec::opt("model", "dscnn", "model name"))
                .arg(ArgSpec::opt("design", "csa", "accelerator design"))
                .arg(ArgSpec::opt("requests", "16", "number of requests"))
                .arg(ArgSpec::opt("batch", "8", "requests scheduled per batch"))
                .arg(ArgSpec::opt("x-us", "0.5", "unstructured sparsity"))
                .arg(ArgSpec::opt("x-ss", "0.3", "block sparsity"))
                .arg(ArgSpec::opt("scale", "0.125", "model width multiplier"))
                .arg(ArgSpec::opt("threads", "0", "worker threads"))
                .arg(ArgSpec::opt("seed", "42", "rng seed"))
                .arg(ArgSpec::opt(
                    "cache-cap",
                    "64",
                    "LRU capacity of the prepared-model cache",
                ))
                .arg(ArgSpec::opt(
                    "assignment",
                    "",
                    "per-layer design assignment ('sssa,simd,…' or 'hetero:sb…'; overrides --design)",
                ))
                .arg(ArgSpec::opt(
                    "tile-threads",
                    "0",
                    "intra-layer tile workers (>1 splits each inference's lanes across cores)",
                ))
                .arg(ArgSpec::flag(
                    "per-lane",
                    "force the per-lane compiled walk instead of batch-amortized execution",
                ))
                .arg(ArgSpec::flag(
                    "interpreted",
                    "force the interpreted CFU oracle instead of compiled lane schedules",
                ))
                .arg(ArgSpec::opt(
                    "host-kernel",
                    "auto",
                    "host multiply kernel for batched lanes (auto|scalar|swar|sse2|neon)",
                )),
        )
        .subcommand(with_fault_args(
            Command::new("serve-tcp", "TCP/HTTP serving front-end with continuous batching")
                .arg(ArgSpec::opt("addr", "127.0.0.1:0", "bind address (port 0 = ephemeral)"))
                .arg(ArgSpec::opt("batch-max", "16", "batch size that fires immediately"))
                .arg(ArgSpec::opt(
                    "deadline-ms",
                    "5",
                    "max wait (ms) before a partial batch fires",
                ))
                .arg(ArgSpec::opt(
                    "queue-cap",
                    "256",
                    "bounded queue depth; beyond it requests shed with 503",
                ))
                .arg(ArgSpec::opt("read-timeout-ms", "5000", "socket read timeout (ms)"))
                .arg(ArgSpec::opt("max-body", "1048576", "max request body bytes"))
                .arg(ArgSpec::opt("threads", "0", "engine worker threads (0=auto)"))
                .arg(ArgSpec::opt("tile-threads", "0", "intra-layer tile workers"))
                .arg(ArgSpec::opt("cache-cap", "64", "prepared-model LRU capacity"))
                .arg(ArgSpec::opt(
                    "host-kernel",
                    "auto",
                    "host multiply kernel (auto|scalar|swar|sse2|neon)",
                ))
                .arg(ArgSpec::opt(
                    "max-seconds",
                    "0",
                    "auto-shutdown after this many seconds (0 = run until POST /shutdown)",
                ))
                .arg(ArgSpec::opt(
                    "fleet",
                    "0",
                    "serve over a fleet of N simulated devices with placement + replica \
                     failover (0 = single engine)",
                ))
                .arg(ArgSpec::opt("json", "", "upsert serving metric records into this store")),
        ))
        .subcommand(with_fault_args(
            Command::new("fleet-sim", "replay a seeded multi-tenant trace through a device fleet")
                .arg(ArgSpec::opt("devices", "3", "simulated devices in the fleet"))
                .arg(ArgSpec::opt("replicas", "2", "replication factor for hot models"))
                .arg(ArgSpec::opt("hot-threshold", "8", "spec hits before replication kicks in"))
                .arg(ArgSpec::opt(
                    "device-queue",
                    "64",
                    "per-device backlog bound; admission sheds when every replica is at it",
                ))
                .arg(ArgSpec::opt("probe-every", "4", "health-probe period in submissions"))
                .arg(ArgSpec::opt("deadline-ms", "50", "virtual request deadline (ms)"))
                .arg(ArgSpec::opt("tenants", "6", "tenant model specs in the traffic mix"))
                .arg(ArgSpec::opt("requests", "96", "requests in the trace"))
                .arg(ArgSpec::opt("rate", "400", "mean offered load (requests/s, virtual)"))
                .arg(ArgSpec::opt("zipf", "1.1", "Zipf skew of tenant popularity"))
                .arg(ArgSpec::opt("seed", "990951", "trace seed (popularity/arrivals/inputs)"))
                .arg(ArgSpec::opt("scale", "0.07", "model width multiplier"))
                .arg(ArgSpec::opt("threads", "0", "engine worker threads per device (0=auto)"))
                .arg(ArgSpec::opt("cache-cap", "64", "prepared-model LRU capacity per device"))
                .arg(ArgSpec::opt("json", "", "upsert fleet metric records into this store")),
        ))
        .subcommand(
            Command::new("loadgen", "replay a deterministic open-loop trace against serve-tcp")
                .arg(ArgSpec::opt("addr", "", "server address, e.g. 127.0.0.1:8080 (required)"))
                .arg(ArgSpec::opt("requests", "64", "requests in the trace"))
                .arg(ArgSpec::opt("rate", "200", "mean offered load (requests/s)"))
                .arg(ArgSpec::opt("arrival", "poisson", "arrival process (poisson|burst)"))
                .arg(ArgSpec::opt("burst", "8", "burst size for --arrival burst"))
                .arg(ArgSpec::opt("seed", "7", "trace + request seed"))
                .arg(ArgSpec::opt("model", "dscnn", "model requested"))
                .arg(ArgSpec::opt("design", "csa", "accelerator design requested"))
                .arg(ArgSpec::opt("x-us", "0.5", "unstructured sparsity"))
                .arg(ArgSpec::opt("x-ss", "0.3", "block sparsity"))
                .arg(ArgSpec::opt("scale", "0.125", "model width multiplier"))
                .arg(ArgSpec::opt("timeout-ms", "30000", "per-request client timeout (ms)"))
                .arg(ArgSpec::opt(
                    "retries",
                    "0",
                    "retries per request with jittered backoff (a 503's Retry-After is honored)",
                ))
                .arg(ArgSpec::flag("shutdown", "POST /shutdown after the trace completes"))
                .arg(ArgSpec::opt("json", "", "upsert client-side metric records here")),
        )
        .subcommand(
            Command::new("explore", "per-layer co-design: Pareto frontier + argmin assignment")
                .arg(ArgSpec::opt("model", "dscnn", "model (vgg16|resnet56|mobilenetv2|dscnn)"))
                .arg(ArgSpec::opt(
                    "designs",
                    "simd,seq,sssa,ussa,csa,nm,bsr,bbs",
                    "candidate designs",
                ))
                .arg(ArgSpec::opt(
                    "sparsity",
                    "",
                    "per-layer prune plan: 'x_us:x_ss' (combined), 'nm[N:M]' (semi-structured, \
                     default 2:4), 'bankT[:K]' (bank-balanced to sparsity T over K banks, \
                     default 4), comma-separated and cycled over MAC layers; overrides \
                     --x-us/--x-ss",
                ))
                .arg(ArgSpec::opt("x-us", "0.5", "uniform unstructured sparsity"))
                .arg(ArgSpec::opt("x-ss", "0.3", "uniform 4:4 block sparsity"))
                .arg(ArgSpec::opt(
                    "int8-layers",
                    "",
                    "MAC-layer indices widened to the full INT8 weight range",
                ))
                .arg(ArgSpec::opt("scale", "0.125", "model width multiplier"))
                .arg(ArgSpec::opt(
                    "budget",
                    "",
                    "FPGA resource budget, e.g. 'luts=100,ffs=200,dsps=1'",
                ))
                .arg(ArgSpec::flag(
                    "lossy",
                    "allow INT7 clamping on INT8-range layers (drop the fidelity constraint)",
                ))
                .arg(ArgSpec::opt("json", "", "write explorer metric records to this store path"))
                .arg(ArgSpec::flag(
                    "apply",
                    "serve a request batch with the chosen assignment vs the best uniform design",
                ))
                .arg(ArgSpec::opt("requests", "8", "requests served by --apply"))
                .arg(ArgSpec::opt("threads", "0", "worker threads for --apply"))
                .arg(ArgSpec::opt("seed", "42", "request rng seed for --apply")),
        )
        .subcommand(
            Command::new("bench-e2e", "batched end-to-end throughput across the model zoo")
                .arg(ArgSpec::opt(
                    "models",
                    "dscnn,resnet56,mobilenetv2,vgg16",
                    "comma-separated zoo models",
                ))
                .arg(ArgSpec::opt("designs", "simd,sssa,ussa,csa", "comma-separated designs"))
                .arg(ArgSpec::opt("batch", "8", "requests per batch"))
                .arg(ArgSpec::opt("threads", "0", "multi-threaded side workers (0=auto)"))
                .arg(ArgSpec::opt("scale", "0.1", "model width multiplier"))
                .arg(ArgSpec::opt("x-us", "0.5", "unstructured sparsity"))
                .arg(ArgSpec::opt("x-ss", "0.3", "block sparsity"))
                .arg(ArgSpec::opt("seed", "42", "request rng seed"))
                .arg(ArgSpec::opt("json", "", "write fresh metric records to this store path"))
                .arg(ArgSpec::opt("baseline", "", "committed BENCH_*.json store to diff against"))
                .arg(ArgSpec::flag("check", "exit non-zero on regression beyond tolerance"))
                .arg(ArgSpec::opt("tol-scale", "1.0", "tolerance multiplier (0 = exact match)")),
        )
        .subcommand(
            Command::new("metrics", "inspect and diff BENCH_*.json metric stores")
                .subcommand(
                    Command::new("diff", "compare two stores: metrics diff <old> <new>")
                        .arg(ArgSpec::opt("tol-scale", "1.0", "tolerance multiplier (0 = exact)"))
                        .arg(ArgSpec::opt("json-verdict", "", "write machine verdict JSON here")),
                )
                .subcommand(Command::new("show", "print a store as a table: metrics show <path>")),
        )
        .subcommand(
            Command::new("encode", "demonstrate the lookahead encoding on synthetic weights")
                .arg(ArgSpec::opt("blocks", "8", "number of 4-weight blocks"))
                .arg(ArgSpec::opt("x-us", "0.2", "unstructured sparsity"))
                .arg(ArgSpec::opt("x-ss", "0.4", "block sparsity"))
                .arg(ArgSpec::opt("seed", "7", "rng seed")),
        )
        .subcommand(Command::new("resources", "print the FPGA resource estimate (Table III)"))
        .subcommand(Command::new("models", "list the model zoo"))
}

/// Chaos-plan flags shared by `serve-tcp` and `fleet-sim`: a non-empty
/// `--chaos-seed` arms the plan; each `--fault-*` rate is a per-event
/// probability in `[0, 1]`.
fn with_fault_args(cmd: Command) -> Command {
    cmd.arg(ArgSpec::opt(
        "chaos-seed",
        "",
        "arm the deterministic fault-injection plan with this seed (empty = off)",
    ))
    .arg(ArgSpec::opt(
        "fault-weight-flip",
        "0",
        "per-batch probability of a packed-weight bit flip in the cached model",
    ))
    .arg(ArgSpec::opt(
        "fault-arena-flip",
        "0",
        "per-batch probability of a schedule-arena bit flip in the cached model",
    ))
    .arg(ArgSpec::opt(
        "fault-lane",
        "0",
        "per-request probability of a transient lane compute fault",
    ))
    .arg(ArgSpec::opt(
        "fault-panic",
        "0",
        "per-batch probability of an injected batcher-thread panic",
    ))
    .arg(ArgSpec::opt(
        "fault-conn-drop",
        "0",
        "per-infer probability of dropping the connection before admission",
    ))
    .arg(ArgSpec::opt(
        "fault-conn-stall",
        "0",
        "per-infer probability of stalling the response by 5-45 ms",
    ))
    .arg(ArgSpec::opt(
        "fault-conn-truncate",
        "0",
        "per-infer probability of truncating the response mid-write",
    ))
    .arg(ArgSpec::opt(
        "fault-device-crash",
        "0",
        "per-submission probability of crashing the fleet device a batch was routed to",
    ))
    .arg(ArgSpec::opt(
        "fault-device-slow",
        "0",
        "per-submission probability of starting a slow spell on a fleet device",
    ))
    .arg(ArgSpec::opt(
        "fault-device-corrupt",
        "0",
        "per-submission probability of a corruption storm confined to one fleet device",
    ))
}

fn parse_designs(s: &str) -> Result<Vec<DesignKind>, String> {
    s.split(',')
        .map(|tok| {
            DesignKind::parse(tok.trim()).ok_or_else(|| format!("unknown design '{tok}'"))
        })
        .collect()
}

/// Parse `--host-kernel`, rejecting kernels this host cannot run with a
/// message that names the ones it can.
fn parse_host_kernel(s: &str) -> sparse_riscv::Result<HostKernel> {
    let kernel = HostKernel::parse(s).ok_or_else(|| {
        sparse_riscv::Error::Cli(format!(
            "unknown --host-kernel '{s}' (want auto|scalar|swar|sse2|neon)"
        ))
    })?;
    if !kernel.available() {
        let available: Vec<&str> =
            HostKernel::available_kernels().iter().map(|k| k.name()).collect();
        return Err(sparse_riscv::Error::Cli(format!(
            "--host-kernel {s} is not available on this host (available: auto, {})",
            available.join(", ")
        )));
    }
    Ok(kernel)
}

fn cmd_experiment(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let cfg = {
        let path = args.get("config")?;
        if !path.is_empty() {
            ExperimentConfig::from_json(&std::fs::read_to_string(path)?)?
        } else {
            ExperimentConfig {
                name: "cli".into(),
                model: args.get("model")?.to_string(),
                designs: parse_designs(args.get("designs")?)
                    .map_err(sparse_riscv::Error::Cli)?,
                x_us: args.get_f64("x-us")?,
                x_ss: args.get_f64("x-ss")?,
                batch: args.get_usize("batch")?,
                sim: SimOptions {
                    seed: args.get_u64("seed")?,
                    threads: args.get_usize("threads")?,
                    verify: args.get_flag("verify")?,
                    clock_hz: 100_000_000,
                },
            }
        }
    };
    let model_cfg = ModelConfig { scale: args.get_f64("scale")?, ..Default::default() };
    println!(
        "experiment: model={} x_us={} x_ss={} batch={} scale={}",
        cfg.model, cfg.x_us, cfg.x_ss, cfg.batch, model_cfg.scale
    );
    let res = run_experiment(&cfg, &model_cfg)?;
    println!(
        "achieved sparsity: element={} block={}",
        pct(res.element_sparsity),
        pct(res.block_sparsity)
    );
    let mut t = Table::new(
        "results",
        &["design", "cycles", "mac-cycles", "speedup-vs-simd", "speedup-vs-seq"],
    );
    for d in &res.designs {
        t.row(&[
            d.design.name().to_string(),
            d.total_cycles.to_string(),
            d.mac_cycles.to_string(),
            f2(d.speedup_vs_simd),
            f2(d.speedup_vs_seq),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_serve(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let design = DesignKind::parse(args.get("design")?)
        .ok_or_else(|| sparse_riscv::Error::Cli("unknown design".into()))?;
    let assignment = {
        let spec = args.get("assignment")?;
        if spec.is_empty() {
            DesignAssignment::Uniform(design)
        } else {
            DesignAssignment::parse(spec).ok_or_else(|| {
                sparse_riscv::Error::Cli(format!(
                    "bad --assignment '{spec}' (want 'sssa,simd,…' or 'hetero:sb…')"
                ))
            })?
        }
    };
    let model = args.get("model")?.to_string();
    let batch = args.get_usize("batch")?.max(1);
    let spec = BatchSpec {
        x_us: args.get_f64("x-us")?,
        x_ss: args.get_f64("x-ss")?,
        scale: args.get_f64("scale")?,
        ..BatchSpec::assigned(&model, assignment)
    };
    let exec_mode = if args.get_flag("interpreted")? {
        ExecMode::Interpreted
    } else if args.get_flag("per-lane")? {
        ExecMode::Compiled
    } else {
        ExecMode::default()
    };
    let host_kernel = parse_host_kernel(args.get("host-kernel")?)?;
    let engine = BatchEngine::new(BatchOptions {
        threads: args.get_usize("threads")?,
        clock_hz: 100_000_000,
        verify: false,
        exec_mode,
        cache_capacity: args.get_usize("cache-cap")?,
        tile_threads: args.get_usize("tile-threads")?,
        host_kernel,
        faults: None,
    });
    let n = args.get_usize("requests")?;
    let reqs = BatchEngine::gen_requests(&model, n, args.get_u64("seed")?)?;
    let report = engine.run_stream(&spec, reqs, batch)?;
    println!(
        "served {} requests on {} ({} lanes, {} host kernel) in batches of {batch} across \
         {} workers + {} tile workers (prepared-model cache: {} builds, {} hits, {} \
         evictions, cap {})",
        report.completed,
        report.design_label(),
        exec_mode.name(),
        host_kernel.resolve().name(),
        engine.workers(),
        engine.tile_workers(),
        report.cache_misses,
        report.cache_hits,
        report.cache_evictions,
        engine.cache().capacity(),
    );
    println!(
        "simulated latency: mean {:.3} ms  p50 {:.3} ms  p99 {:.3} ms (at 100 MHz)",
        report.latency.mean() * 1e3,
        report.p50 * 1e3,
        report.p99 * 1e3,
    );
    println!(
        "total simulated cycles: {}   cfu stalls: {}   loaded: {:.2} MB   host wall: {:.3} s",
        report.total_cycles,
        report.cfu_stalls,
        report.loaded_bytes as f64 / 1e6,
        report.wall_seconds
    );
    println!(
        "throughput: host {} inf/s   simulated device {} inf/s",
        f2(report.host_throughput()),
        f2(report.sim_throughput(100_000_000)),
    );
    let hist: std::collections::BTreeMap<usize, usize> =
        report.predictions.iter().fold(Default::default(), |mut m, &p| {
            *m.entry(p).or_default() += 1;
            m
        });
    println!("prediction histogram: {hist:?}");
    Ok(())
}

/// Build the serve-tcp chaos plan from CLI flags: a non-empty
/// `--chaos-seed` arms it; the per-site `--fault-*` rates are
/// probabilities in `[0, 1]`. With the same seed and rates the whole
/// fault schedule replays identically.
fn parse_fault_plan(args: &ParsedArgs) -> sparse_riscv::Result<Option<std::sync::Arc<FaultPlan>>> {
    let seed_spec = args.get("chaos-seed")?;
    if seed_spec.is_empty() {
        return Ok(None);
    }
    let seed: u64 = seed_spec.parse().map_err(|e| {
        sparse_riscv::Error::Cli(format!("bad --chaos-seed '{seed_spec}': {e}"))
    })?;
    let rate = |name: &str| -> sparse_riscv::Result<f64> {
        let v = args.get_f64(name)?;
        if !(0.0..=1.0).contains(&v) {
            return Err(sparse_riscv::Error::Cli(format!("--{name} {v} outside [0, 1]")));
        }
        Ok(v)
    };
    let rates = FaultRates {
        weight_flip: rate("fault-weight-flip")?,
        arena_flip: rate("fault-arena-flip")?,
        lane_transient: rate("fault-lane")?,
        batcher_panic: rate("fault-panic")?,
        conn_drop: rate("fault-conn-drop")?,
        conn_stall: rate("fault-conn-stall")?,
        conn_truncate: rate("fault-conn-truncate")?,
        device_crash: rate("fault-device-crash")?,
        device_slow: rate("fault-device-slow")?,
        device_corrupt: rate("fault-device-corrupt")?,
    };
    Ok(Some(std::sync::Arc::new(FaultPlan::new(seed, rates))))
}

fn cmd_serve_tcp(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    use std::io::Write as _;
    let host_kernel = parse_host_kernel(args.get("host-kernel")?)?;
    let faults = parse_fault_plan(args)?;
    let engine_opts = BatchOptions {
        threads: args.get_usize("threads")?,
        clock_hz: 100_000_000,
        verify: false,
        exec_mode: ExecMode::default(),
        cache_capacity: args.get_usize("cache-cap")?,
        tile_threads: args.get_usize("tile-threads")?,
        host_kernel,
        faults: faults.clone(),
    };
    let opts = NetOptions {
        batch_max: args.get_usize("batch-max")?,
        batch_deadline: Duration::from_millis(args.get_u64("deadline-ms")?),
        queue_capacity: args.get_usize("queue-cap")?,
        read_timeout: Duration::from_millis(args.get_u64("read-timeout-ms")?.max(1)),
        max_body: args.get_usize("max-body")?,
        faults: faults.clone(),
        ..Default::default()
    };
    if let Some(plan) = &faults {
        println!("serve-tcp: chaos plan armed — {plan:?}");
    }
    let fleet_n = args.get_usize("fleet")?;
    let fleet = if fleet_n > 0 {
        Some(std::sync::Arc::new(Fleet::new(FleetOptions {
            devices: fleet_n,
            engine: engine_opts.clone(),
            faults: faults.clone(),
            ..FleetOptions::default()
        })))
    } else {
        None
    };
    let server = match &fleet {
        Some(f) => {
            println!("serve-tcp: fleet of {} devices behind the front-end", f.device_count());
            NetServer::bind_fleet(args.get("addr")?, std::sync::Arc::clone(f), opts)?
        }
        None => NetServer::bind(args.get("addr")?, BatchEngine::new(engine_opts), opts)?,
    };
    // The exact line automation scrapes for the ephemeral port — flush
    // so a piped stdout delivers it before the server blocks in join().
    println!("serve-tcp: listening on {}", server.addr());
    std::io::stdout().flush()?;
    let max_seconds = args.get_u64("max-seconds")?;
    if max_seconds > 0 {
        let handle = server.handle();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_secs(max_seconds));
            handle.shutdown();
        });
    }
    let stats = server.join();
    println!(
        "serve-tcp: drained — accepted {} completed {} failed {} shed {} rejected {} \
         over {} batches (mean batch {:.2}, max queue depth {})",
        stats.accepted,
        stats.completed,
        stats.failed,
        stats.shed,
        stats.rejected,
        stats.batches,
        stats.mean_batch_size(),
        stats.queue_depth_max,
    );
    println!(
        "serve-tcp: wall latency p50 {:.3} ms  p99 {:.3} ms  p99.9 {:.3} ms",
        stats.wall_p50_ms, stats.wall_p99_ms, stats.wall_p999_ms,
    );
    println!(
        "serve-tcp: recovery — integrity_fails {} degraded_runs {} batcher_restarts {} \
         transient_corrected {} faults_injected {}",
        stats.integrity_fails,
        stats.degraded_runs,
        stats.batcher_restarts,
        stats.transient_corrected,
        faults.as_ref().map_or(0, |p| p.total_injected()),
    );
    let mut records = vec![stats.to_record("serve/net")];
    if let Some(f) = &fleet {
        let fr = f.report();
        println!(
            "serve-tcp: fleet — devices {} alive {} failovers {} rebalances {} crashes {}",
            fr.devices, fr.alive, fr.failovers, fr.rebalances, fr.crashes,
        );
        records.extend(fr.to_records("serve/fleet"));
    }
    let note = "regenerate: cargo run --release -- serve-tcp (plus a loadgen trace)";
    if let Some(path) = sparse_riscv::metrics::sink_records_env(note, &records)? {
        println!("metrics: wrote {} record(s) into {path}", records.len());
    }
    let json_path = args.get("json")?;
    if !json_path.is_empty() {
        let n = records.len();
        BaselineStore::upsert_file(json_path, note, records)?;
        println!("metrics: upserted {n} record(s) into {json_path}");
    }
    Ok(())
}

fn cmd_fleet_sim(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let faults = parse_fault_plan(args)?;
    let engine = BatchOptions {
        threads: args.get_usize("threads")?,
        clock_hz: 100_000_000,
        verify: false,
        exec_mode: ExecMode::default(),
        cache_capacity: args.get_usize("cache-cap")?,
        tile_threads: 0,
        host_kernel: HostKernel::Auto,
        faults: faults.clone(),
    };
    let opts = FleetOptions {
        devices: args.get_usize("devices")?.max(1),
        replicas: args.get_usize("replicas")?.max(1),
        hot_threshold: args.get_u64("hot-threshold")?,
        device_queue: args.get_usize("device-queue")?.max(1),
        probe_every: args.get_u64("probe-every")?.max(1),
        deadline_s: args.get_f64("deadline-ms")?.max(0.0) / 1e3,
        engine,
        faults: faults.clone(),
        ..FleetOptions::default()
    };
    let trace = TenantTrace {
        tenants: args.get_usize("tenants")?.max(1),
        requests: args.get_usize("requests")?,
        rate: args.get_f64("rate")?,
        zipf_s: args.get_f64("zipf")?,
        seed: args.get_u64("seed")?,
        scale: args.get_f64("scale")?,
    };
    if trace.rate <= 0.0 {
        return Err(sparse_riscv::Error::Cli("--rate must be positive".into()));
    }
    if let Some(plan) = &faults {
        println!("fleet-sim: chaos plan armed — {plan:?}");
    }
    println!(
        "fleet-sim: {} devices, {} tenants, {} requests at {} req/s (seed {})",
        opts.devices, trace.tenants, trace.requests, trace.rate, trace.seed,
    );
    let fleet = Fleet::new(opts);
    let outcomes = run_tenant_trace(&fleet, &trace)?;
    let report = fleet.report();
    let failed_over = outcomes.iter().filter(|o| o.failed_over).count();
    println!(
        "fleet-sim: drained — accepted {} completed {} failed {} shed {} over {} devices \
         ({} alive)",
        report.accepted, report.completed, report.failed, report.shed, report.devices, report.alive,
    );
    println!(
        "fleet-sim: failover — failovers {} rebalances {} replications {} crashes {} \
         slow_spells {} storms {} deadline_misses {}",
        report.failovers,
        report.rebalances,
        report.replications,
        report.crashes,
        report.slow_spells,
        report.storms,
        report.deadline_misses,
    );
    println!(
        "fleet-sim: throughput {:.1} req/s over {:.4} s virtual span ({} requests failed \
         over, total cycles {})",
        report.throughput(),
        report.span_s,
        failed_over,
        report.total_cycles,
    );
    for d in &report.per_device {
        println!(
            "fleet-sim: dev{} alive={} placed={} completed={} util={:.3} cache_hit_rate={:.3}",
            d.device, d.alive, d.placed, d.completed, d.utilization, d.cache_hit_rate,
        );
    }
    let records = report.to_records("fleet/sim");
    let note = "regenerate: cargo run --release -- fleet-sim --json <path>";
    if let Some(path) = sparse_riscv::metrics::sink_records_env(note, &records)? {
        println!("metrics: wrote {} record(s) into {path}", records.len());
    }
    let json_path = args.get("json")?;
    if !json_path.is_empty() {
        let n = records.len();
        BaselineStore::upsert_file(json_path, note, records)?;
        println!("metrics: upserted {n} record(s) into {json_path}");
    }
    if !report.ledger_holds() || report.failed > 0 {
        eprintln!(
            "fleet-sim: ledger violated — accepted {} != completed {} + failed {} (or failures)",
            report.accepted, report.completed, report.failed
        );
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_loadgen(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let addr = args.get("addr")?.to_string();
    if addr.is_empty() {
        return Err(sparse_riscv::Error::Cli(
            "--addr is required (e.g. 127.0.0.1:8080)".into(),
        ));
    }
    let arrival = Arrival::parse(args.get("arrival")?).ok_or_else(|| {
        sparse_riscv::Error::Cli(format!(
            "bad --arrival '{}' (want poisson|burst)",
            args.get("arrival").unwrap_or_default()
        ))
    })?;
    let trace = TraceConfig {
        requests: args.get_usize("requests")?,
        rate: args.get_f64("rate")?,
        arrival,
        burst: args.get_usize("burst")?,
        seed: args.get_u64("seed")?,
        retries: args.get_usize("retries")?,
    };
    if trace.rate <= 0.0 {
        return Err(sparse_riscv::Error::Cli("--rate must be positive".into()));
    }
    let model = args.get("model")?.to_string();
    let design = args.get("design")?.to_string();
    if DesignKind::parse(&design).is_none() {
        return Err(sparse_riscv::Error::Cli(format!("unknown design '{design}'")));
    }
    let (x_us, x_ss) = (args.get_f64("x-us")?, args.get_f64("x-ss")?);
    let scale = args.get_f64("scale")?;
    // One body per request with a distinct deterministic input seed, so
    // a replayed trace exercises the same inputs every run.
    let bodies: Vec<String> = (0..trace.requests)
        .map(|i| {
            Value::obj(vec![
                ("model", Value::Str(model.clone())),
                ("design", Value::Str(design.clone())),
                ("x_us", Value::Num(x_us)),
                ("x_ss", Value::Num(x_ss)),
                ("scale", Value::Num(scale)),
                ("seed", Value::Num(trace.seed.wrapping_add(i as u64) as f64)),
            ])
            .to_json()
        })
        .collect();
    let timeout = Duration::from_millis(args.get_u64("timeout-ms")?.max(1));
    println!(
        "loadgen: {} requests at {} req/s ({}, seed {}) against {addr}",
        trace.requests,
        trace.rate,
        arrival.name(),
        trace.seed,
    );
    let report = loadgen::run_trace(&addr, &trace, &bodies, timeout);
    println!("loadgen: {}", report.to_value().to_json());
    if args.get_flag("shutdown")? {
        match loadgen::http_request(&addr, "POST", "/shutdown", "{}", timeout) {
            Ok(resp) if resp.code == 200 => println!("loadgen: server draining"),
            Ok(resp) => eprintln!("warning: shutdown returned HTTP {}", resp.code),
            Err(e) => eprintln!("warning: shutdown request failed: {e}"),
        }
    }
    let json_path = args.get("json")?;
    if !json_path.is_empty() {
        let rec = report.to_record(&format!("loadgen/{model}"));
        BaselineStore::upsert_file(
            json_path,
            "regenerate: cargo run --release -- loadgen --json <path>",
            vec![rec],
        )?;
        println!("metrics: upserted 1 record into {json_path}");
    }
    if !report.well_formed() {
        eprintln!(
            "loadgen: trace not clean — ok {} shed {} failed {} malformed {} of {} sent",
            report.ok, report.shed, report.failed, report.malformed, report.sent
        );
        std::process::exit(1);
    }
    Ok(())
}

/// Parse one `--sparsity` token into a prune recipe:
/// `x_us:x_ss` (combined), `nm` / `nmN:M` (semi-structured, default
/// 2:4), `bankT` / `bankT:K` (bank-balanced to element sparsity `T`
/// over `K` banks, default 4).
fn parse_prune_token(tok: &str) -> Result<LayerPrune, String> {
    let in_range = |name: &str, v: f64| -> Result<f64, String> {
        if (0.0..=1.0).contains(&v) {
            Ok(v)
        } else {
            Err(format!("{name} {v} in '{tok}' out of range [0, 1]"))
        }
    };
    if let Some(rest) = tok.strip_prefix("nm") {
        if rest.is_empty() {
            return Ok(LayerPrune::Nm { n: 2, m: 4 });
        }
        let (n, m) = rest
            .split_once(':')
            .ok_or_else(|| format!("bad N:M entry '{tok}' (want nmN:M, e.g. nm2:4)"))?;
        let n: usize = n.trim().parse().map_err(|e| format!("bad N in '{tok}': {e}"))?;
        let m: usize = m.trim().parse().map_err(|e| format!("bad M in '{tok}': {e}"))?;
        if m == 0 || n > m {
            return Err(format!("bad N:M entry '{tok}' (need 0 < M and N <= M)"));
        }
        return Ok(LayerPrune::Nm { n, m });
    }
    if let Some(rest) = tok.strip_prefix("bank") {
        let (t, k) = match rest.split_once(':') {
            Some((t, k)) => (t, Some(k)),
            None => (rest, None),
        };
        let target: f64 =
            t.trim().parse().map_err(|e| format!("bad bank target in '{tok}': {e}"))?;
        let target = in_range("target", target)?;
        let banks: usize = match k {
            Some(k) => k.trim().parse().map_err(|e| format!("bad bank count in '{tok}': {e}"))?,
            None => 4,
        };
        if banks == 0 {
            return Err(format!("bad bank count in '{tok}' (need >= 1)"));
        }
        return Ok(LayerPrune::BankBalanced { target, banks });
    }
    let (us, ss) = tok.split_once(':').ok_or_else(|| {
        format!("bad sparsity entry '{tok}' (want x_us:x_ss, nm[N:M], or bankT[:K])")
    })?;
    let us: f64 = us.trim().parse().map_err(|e| format!("bad x_us in '{tok}': {e}"))?;
    let ss: f64 = ss.trim().parse().map_err(|e| format!("bad x_ss in '{tok}': {e}"))?;
    Ok(LayerPrune::Combined { x_us: in_range("x_us", us)?, x_ss: in_range("x_ss", ss)? })
}

/// Parse a per-layer prune plan: `"0.5:0.4,nm,bank0.5:4"` → one
/// [`LayerPrune`] entry per comma-separated token (cycled over MAC
/// layers at apply time). Fractions must lie in `[0, 1]` (the pruning
/// library asserts the same range).
fn parse_prune_plan(s: &str) -> Result<Vec<LayerPrune>, String> {
    s.split(',').map(str::trim).filter(|t| !t.is_empty()).map(parse_prune_token).collect()
}

fn cmd_explore(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let model = args.get("model")?.to_string();
    let scale = args.get_f64("scale")?;

    // Pure string parsing first, so malformed flags error before any
    // model is built or pruned.
    let plan_spec = args.get("sparsity")?;
    let plan: Vec<LayerPrune> = if plan_spec.is_empty() {
        parse_prune_plan(&format!("{}:{}", args.get("x-us")?, args.get("x-ss")?))
            .map_err(sparse_riscv::Error::Cli)?
    } else {
        parse_prune_plan(plan_spec).map_err(sparse_riscv::Error::Cli)?
    };
    if plan.is_empty() {
        return Err(sparse_riscv::Error::Cli("--sparsity parsed to an empty plan".into()));
    }
    let int8_indices: Vec<usize> = {
        let spec = args.get("int8-layers")?;
        if spec.is_empty() {
            Vec::new()
        } else {
            spec.split(',')
                .map(|t| t.trim().parse::<usize>())
                .collect::<Result<_, _>>()
                .map_err(|e| {
                    sparse_riscv::Error::Cli(format!("--int8-layers expects MAC indices: {e}"))
                })?
        }
    };
    let candidates = parse_designs(args.get("designs")?).map_err(sparse_riscv::Error::Cli)?;
    let budget_spec = args.get("budget")?;
    let budget = if budget_spec.is_empty() {
        None
    } else {
        Some(parse_budget(budget_spec).ok_or_else(|| {
            sparse_riscv::Error::Cli(format!(
                "bad --budget '{budget_spec}' (want e.g. 'luts=100,dsps=1')"
            ))
        })?)
    };
    // A design over budget on its own can never appear in any subset
    // (subset costs are sums), so drop it before paying a full profiling
    // inference for it.
    let candidates: Vec<DesignKind> = match &budget {
        Some(b) => {
            candidates.into_iter().filter(|&d| within_budget(&design_cost(d), b)).collect()
        }
        None => candidates,
    };
    if candidates.is_empty() {
        return Err(sparse_riscv::Error::Cli(format!(
            "no candidate design fits --budget '{budget_spec}'"
        )));
    }

    let cfg = ModelConfig { scale, ..Default::default() };
    let mut info = build_model(&model, &cfg)?;
    let mac_layers = info.graph.mac_layers();
    apply_prune_plan(&mut info.graph, &plan)?;
    if let Some(&bad) = int8_indices.iter().find(|&&i| i >= mac_layers) {
        return Err(sparse_riscv::Error::Cli(format!(
            "--int8-layers index {bad} out of range ({model} has {mac_layers} MAC layers)"
        )));
    }
    widen_weights_to_int8(&mut info.graph, &int8_indices);
    let opts = ExplorerOptions {
        candidates,
        lossless: !args.get_flag("lossy")?,
        budget,
        ..Default::default()
    };
    println!(
        "explore: model={model} scale={scale} mac-layers={mac_layers} lossless={} \
         plan-entries={}",
        opts.lossless,
        plan.len()
    );
    let table = profile_graph(&info.graph, &info.input_shape, &opts.candidates, &opts.cost_model)?;
    let result = explore(&table, &opts)?;
    print!("{}", result.render());

    let json_path = args.get("json")?;
    if !json_path.is_empty() {
        // The record's sparsity context is the plan's leading entry —
        // the representative ratio of this actual CLI configuration.
        // Upsert (not overwrite): pointing --json at a shared store like
        // BENCH_e2e.json must never drop the other records in it — and
        // the id carries a `-cli` marker so an ad-hoc configuration can
        // never shadow the canonical `explore/<model>` sweep record.
        let mut rec = explore_record(&model, scale, plan[0].context_ratios(), &result);
        rec.id = format!("explore-cli/{model}");
        let records = vec![rec];
        BaselineStore::upsert_file(
            json_path,
            "regenerate: cargo run --release -- explore --json <path>",
            records.clone(),
        )?;
        println!("metrics: upserted {} record(s) into {json_path}", records.len());
    }

    if args.get_flag("apply")? {
        // Feed the chosen assignment straight into the serving loop and
        // compare against the best uniform design on the same requests.
        let serve_opts = ServeOptions {
            threads: args.get_usize("threads")?,
            clock_hz: 100_000_000,
            verify: true,
            host_kernel: HostKernel::Auto,
        };
        let mut rng = Pcg32::new(args.get_u64("seed")?);
        let n = args.get_usize("requests")?.max(1);
        let reqs: Vec<_> = (0..n)
            .map(|_| random_input(info.input_shape.clone(), cfg.act_params(), &mut rng))
            .collect();
        let best = Server::new_assigned(&info.graph, &result.best.assignment, &serve_opts)?;
        let (_, mut chosen) = best.serve_batch(reqs.clone())?;
        let uniform =
            Server::new_assigned(&info.graph, &result.best_uniform.assignment, &serve_opts)?;
        let (_, baseline) = uniform.serve_batch(reqs)?;
        println!(
            "apply: served {n} verified requests — {} cycles on {} vs {} cycles on {} \
             ({}x, p50 {:.3} ms)",
            chosen.total_cycles,
            result.best.assignment.label(),
            baseline.total_cycles,
            result.best_uniform.assignment.label(),
            f2(baseline.total_cycles as f64 / chosen.total_cycles.max(1) as f64),
            chosen.sim_percentiles.percentile(50.0) * 1e3,
        );
    }
    Ok(())
}

fn cmd_bench_e2e(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let designs = args
        .get_list("designs")?
        .iter()
        .map(|s| {
            DesignKind::parse(s)
                .ok_or_else(|| sparse_riscv::Error::Cli(format!("unknown design '{s}'")))
        })
        .collect::<sparse_riscv::Result<Vec<_>>>()?;
    let cfg = E2eConfig {
        models: args.get_list("models")?,
        designs,
        batch: args.get_usize("batch")?.max(1),
        threads: args.get_usize("threads")?,
        scale: args.get_f64("scale")?,
        x_us: args.get_f64("x-us")?,
        x_ss: args.get_f64("x-ss")?,
        seed: args.get_u64("seed")?,
        clock_hz: 100_000_000,
    };
    if cfg.models.is_empty() {
        return Err(sparse_riscv::Error::Cli("at least one model required".into()));
    }
    if cfg.designs.is_empty() {
        return Err(sparse_riscv::Error::Cli("at least one design required".into()));
    }
    let summary = run_e2e(&cfg)?;
    print!("{}", render_e2e(&cfg, &summary));

    let mut records = to_records(&cfg, &summary);
    // Informational explorer records ride along in the same sink so the
    // perf gates can track explored-vs-uniform speedups once seeded. A
    // failure here degrades to a warning for this run's own output; note
    // that once a committed baseline contains explore/<model> records,
    // omitting them still trips the diff's lost-coverage rule — which is
    // deliberate: seeded coverage must not silently shrink.
    match run_explore_bench(&cfg.models, cfg.scale) {
        Ok(explore_records) => {
            for rec in &explore_records {
                println!(
                    "explore: {} best={} speedup={}x (informational)",
                    rec.model,
                    rec.design,
                    f2(rec.get("explore_speedup").unwrap_or(1.0)),
                );
            }
            records.extend(explore_records);
        }
        Err(e) => eprintln!("warning: explorer sweep skipped ({e})"),
    }
    let note = "regenerate: cargo run --release -- bench-e2e --json BENCH_e2e.json";
    let json_path = args.get("json")?;
    if !json_path.is_empty() {
        BaselineStore::from_records(note, records.clone()).save(json_path)?;
        println!("metrics: wrote {} record(s) to {json_path}", records.len());
    }
    let baseline_path = args.get("baseline")?;
    if !baseline_path.is_empty() {
        check_against_baseline(baseline_path, note, records, args)?;
    }
    Ok(())
}

/// Diff fresh records against the committed baseline store. An empty or
/// absent baseline is a bootstrap placeholder: it is seeded from this
/// run (exit 0) so the first release run on a toolchain machine arms
/// the gate; thereafter regressions beyond tolerance exit non-zero when
/// `--check` is set.
fn check_against_baseline(
    path: &str,
    note: &str,
    records: Vec<sparse_riscv::metrics::MetricRecord>,
    args: &ParsedArgs,
) -> sparse_riscv::Result<()> {
    let baseline = if std::path::Path::new(path).exists() {
        BaselineStore::load(path)?
    } else {
        BaselineStore::new(note)
    };
    if baseline.is_empty() {
        let mut seeded = baseline;
        seeded.note = note.to_string();
        seeded.merge(records);
        seeded.save(path)?;
        println!(
            "baseline '{path}' had no records (bootstrap) — seeded {} record(s) from this run; \
             commit the file to arm the perf gate",
            seeded.len()
        );
        return Ok(());
    }
    let fresh = BaselineStore::from_records(note, records);
    let tol = Tolerances { scale: args.get_f64("tol-scale")? };
    let report = metrics_diff(&baseline, &fresh, &tol);
    print!("{}", report.render());
    if args.get_flag("check")? && !report.passed() {
        eprintln!(
            "perf gate: regression vs '{path}' — if intentional, regenerate the baseline \
             with `bench-e2e --json {path}` and commit it"
        );
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_metrics_diff(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let [old_path, new_path] = args.positionals.as_slice() else {
        return Err(sparse_riscv::Error::Cli(
            "usage: metrics diff <old.json> <new.json>".into(),
        ));
    };
    let old = BaselineStore::load(old_path)?;
    let new = BaselineStore::load(new_path)?;
    let tol = Tolerances { scale: args.get_f64("tol-scale")? };
    let report = metrics_diff(&old, &new, &tol);
    print!("{}", report.render());
    let verdict_path = args.get("json-verdict")?;
    if !verdict_path.is_empty() {
        std::fs::write(verdict_path, report.to_verdict_json())?;
        println!("verdict written to {verdict_path}");
    }
    if !report.passed() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_metrics_show(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let [path] = args.positionals.as_slice() else {
        return Err(sparse_riscv::Error::Cli("usage: metrics show <store.json>".into()));
    };
    let store = BaselineStore::load(path)?;
    let records: Vec<_> = store.records.values().cloned().collect();
    print!("{}", render_metric_records(&format!("metric store {path}"), &records));
    if !store.note.is_empty() {
        println!("note: {}", store.note);
    }
    Ok(())
}

fn cmd_encode(args: &ParsedArgs) -> sparse_riscv::Result<()> {
    let blocks = args.get_usize("blocks")?;
    let mut rng = Pcg32::new(args.get_u64("seed")?);
    let ws = gen_combined_sparse(
        blocks * 4,
        args.get_f64("x-us")?,
        args.get_f64("x-ss")?,
        &mut rng,
    );
    let enc = encode_lanes(&ws, ws.len())?;
    println!("weights ({} blocks):", blocks);
    for (i, b) in ws.chunks(4).enumerate() {
        let eb = &enc.encoded[i * 4..i * 4 + 4];
        let arr: [i8; 4] = eb.try_into().unwrap();
        let skip = sparse_riscv::encoding::lookahead::decode_skip(&arr);
        println!(
            "  block {i:2}: {b:?} -> encoded {:?} (skip={skip})",
            eb.iter().map(|&w| format!("{:#04x}", w as u8)).collect::<Vec<_>>()
        );
    }
    println!(
        "total blocks {}  zero blocks {}  visited by SSSA loop {}",
        enc.total_blocks, enc.zero_blocks, enc.visited_blocks
    );
    Ok(())
}

fn cmd_resources() {
    let mut t = Table::new(
        "Table III — FPGA resource increments (estimated vs paper)",
        &["design", "LUTs est", "LUTs paper", "FFs est", "FFs paper", "DSPs est", "DSPs paper"],
    );
    for d in [DesignKind::Ussa, DesignKind::Sssa, DesignKind::Csa] {
        let est = estimate_cfu(d);
        let paper = paper_increment(d).unwrap();
        t.row(&[
            d.name().to_string(),
            est.luts.to_string(),
            paper.luts.to_string(),
            est.ffs.to_string(),
            paper.ffs.to_string(),
            est.dsps.to_string(),
            paper.dsps.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "baseline SoC (w/o CFU): {} LUTs, {} FFs, {} BRAMs, {} DSPs",
        BASELINE_SOC.luts, BASELINE_SOC.ffs, BASELINE_SOC.brams, BASELINE_SOC.dsps
    );
}

fn cmd_models() -> sparse_riscv::Result<()> {
    let cfg = ModelConfig { scale: 0.125, ..Default::default() };
    let mut t = Table::new(
        "model zoo (at scale 0.125)",
        &["model", "dataset", "mac-layers", "weights", "input"],
    );
    for name in model_names() {
        let info = build_model(name, &cfg)?;
        t.row(&[
            name.to_string(),
            info.dataset.to_string(),
            info.graph.mac_layers().to_string(),
            info.graph.total_weights().to_string(),
            info.input_shape.to_string(),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn main() {
    sparse_riscv::util::logging::init();
    let parsed = match cli().parse_env() {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("try 'sparse-riscv --help'");
            std::process::exit(2);
        }
    };
    if let Some(help) = &parsed.help {
        println!("{help}");
        return;
    }
    // Dispatch on the full command path so nested leaves (metrics
    // diff/show) cannot collide with future top-level names.
    let path: Vec<&str> = parsed.command_path.iter().map(|s| s.as_str()).collect();
    let result = match path.as_slice() {
        [_, "experiment"] => cmd_experiment(&parsed),
        [_, "serve"] => cmd_serve(&parsed),
        [_, "serve-tcp"] => cmd_serve_tcp(&parsed),
        [_, "fleet-sim"] => cmd_fleet_sim(&parsed),
        [_, "loadgen"] => cmd_loadgen(&parsed),
        [_, "explore"] => cmd_explore(&parsed),
        [_, "bench-e2e"] => cmd_bench_e2e(&parsed),
        [_, "metrics", "diff"] => cmd_metrics_diff(&parsed),
        [_, "metrics", "show"] => cmd_metrics_show(&parsed),
        [_, "encode"] => cmd_encode(&parsed),
        [_, "resources"] => {
            cmd_resources();
            Ok(())
        }
        [_, "models"] => cmd_models(),
        other => {
            eprintln!("unknown subcommand '{}'", other.last().copied().unwrap_or(""));
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
