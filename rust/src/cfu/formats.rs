//! CFUs for the format-extension designs: NM-SSA, BSR, and BBS.
//!
//! The paper's four designs cover unstructured and lookahead-encoded
//! sparsity; these three units model the other structured formats the
//! literature deploys on the same CPU–CFU interface:
//!
//! - [`NmCfu`] — N:M semi-structured (2:4). `nm_mac` (`f0 = 0`) is a
//!   plain 4-lane INT8 MAC over a group that prepare-time pruning has
//!   already constrained to ≤ 2 non-zeros; `nm_lookahead` (`f0 = 1`)
//!   is the fixed one-cycle group probe that reports whether the group
//!   has any non-zero at all, letting the walk skip all-zero groups.
//! - [`BsrCfu`] — 8×8 block-sparse. `bsr_mac` is a 4-lane INT8 MAC;
//!   block skipping lives in the schedule (the occupancy bitmap is a
//!   pack-time artefact, not a per-issue decision), so the unit itself
//!   is fixed-cycle.
//! - [`BbsCfu`] — bank-balanced. `bbs_mac` is a 4-lane INT8 MAC on a
//!   word fetched from one of K weight banks; the bank imbalance cost
//!   is charged by the walk (the busiest bank bounds the lane), not by
//!   the multiplier itself.
//!
//! All three consume plain packed INT8 weights — none uses the
//! lookahead encoding, so they impose no INT7 clamping.

use super::{dot4, Cfu, CfuResponse};
use crate::encoding::pack::unpack4_i8;
use crate::error::{Error, Result};
use crate::isa::{CfuOpcode, DesignKind};

/// The `nm_lookahead` datapath: 1 iff the packed group has a non-zero.
#[inline]
pub fn nm_group_occupied(rs1: u32) -> u32 {
    u32::from(rs1 != 0)
}

/// The NM-SSA CFU (2:4 semi-structured groups).
#[derive(Debug, Clone)]
pub struct NmCfu {
    input_offset: i32,
}

impl NmCfu {
    /// New unit.
    pub fn new(input_offset: i32) -> Self {
        NmCfu { input_offset }
    }
}

impl Cfu for NmCfu {
    fn design(&self) -> DesignKind {
        DesignKind::NmSsa
    }

    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match op {
            CfuOpcode::NmMac => {
                let w = unpack4_i8(rs1);
                let x = unpack4_i8(rs2);
                Ok(CfuResponse { rd: dot4(w, x, self.input_offset) as u32, cycles: 1 })
            }
            CfuOpcode::NmLookahead => {
                Ok(CfuResponse { rd: nm_group_occupied(rs1), cycles: 1 })
            }
            other => {
                Err(Error::Sim(format!("NM-SSA CFU cannot execute {}", other.mnemonic())))
            }
        }
    }
}

/// The BSR CFU (8×8 block-sparse).
#[derive(Debug, Clone)]
pub struct BsrCfu {
    input_offset: i32,
}

impl BsrCfu {
    /// New unit.
    pub fn new(input_offset: i32) -> Self {
        BsrCfu { input_offset }
    }
}

impl Cfu for BsrCfu {
    fn design(&self) -> DesignKind {
        DesignKind::Bsr
    }

    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match op {
            CfuOpcode::BsrMac => {
                let w = unpack4_i8(rs1);
                let x = unpack4_i8(rs2);
                Ok(CfuResponse { rd: dot4(w, x, self.input_offset) as u32, cycles: 1 })
            }
            other => {
                Err(Error::Sim(format!("BSR CFU cannot execute {}", other.mnemonic())))
            }
        }
    }
}

/// The BBS CFU (bank-balanced sparsity).
#[derive(Debug, Clone)]
pub struct BbsCfu {
    input_offset: i32,
}

impl BbsCfu {
    /// New unit.
    pub fn new(input_offset: i32) -> Self {
        BbsCfu { input_offset }
    }
}

impl Cfu for BbsCfu {
    fn design(&self) -> DesignKind {
        DesignKind::Bbs
    }

    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match op {
            CfuOpcode::BbsMac => {
                let w = unpack4_i8(rs1);
                let x = unpack4_i8(rs2);
                Ok(CfuResponse { rd: dot4(w, x, self.input_offset) as u32, cycles: 1 })
            }
            other => {
                Err(Error::Sim(format!("BBS CFU cannot execute {}", other.mnemonic())))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::pack::pack4_i8;

    #[test]
    fn nm_mac_matches_scalar_dot() {
        let mut cfu = NmCfu::new(7);
        let w = [-128i8, 0, 0, 127];
        let x = [4i8, -5, 6, -7];
        let r = cfu.execute(CfuOpcode::NmMac, pack4_i8(&w), pack4_i8(&x)).unwrap();
        let expect: i32 = (0..4).map(|i| w[i] as i32 * (x[i] as i32 + 7)).sum();
        assert_eq!(r.rd as i32, expect);
        assert_eq!(r.cycles, 1);
    }

    #[test]
    fn nm_lookahead_probes_group_occupancy() {
        let mut cfu = NmCfu::new(0);
        let zero = cfu.execute(CfuOpcode::NmLookahead, 0, 0).unwrap();
        assert_eq!(zero.rd, 0);
        assert_eq!(zero.cycles, 1);
        let occupied = cfu
            .execute(CfuOpcode::NmLookahead, pack4_i8(&[0, 0, -1, 0]), 0)
            .unwrap();
        assert_eq!(occupied.rd, 1);
        assert_eq!(occupied.cycles, 1);
    }

    #[test]
    fn bsr_and_bbs_macs_match_scalar_dot() {
        let w = [9i8, -9, 0, 1];
        let x = [-1i8, 2, -3, 4];
        let expect: i32 = (0..4).map(|i| w[i] as i32 * (x[i] as i32 - 3)).sum();
        let r = BsrCfu::new(-3)
            .execute(CfuOpcode::BsrMac, pack4_i8(&w), pack4_i8(&x))
            .unwrap();
        assert_eq!(r.rd as i32, expect);
        assert_eq!(r.cycles, 1);
        let r = BbsCfu::new(-3)
            .execute(CfuOpcode::BbsMac, pack4_i8(&w), pack4_i8(&x))
            .unwrap();
        assert_eq!(r.rd as i32, expect);
        assert_eq!(r.cycles, 1);
    }

    #[test]
    fn foreign_ops_rejected() {
        assert!(NmCfu::new(0).execute(CfuOpcode::BsrMac, 0, 0).is_err());
        assert!(BsrCfu::new(0).execute(CfuOpcode::NmMac, 0, 0).is_err());
        assert!(BbsCfu::new(0).execute(CfuOpcode::CfuSimdMac, 0, 0).is_err());
    }
}
