//! Host-side multi-row dot kernels for the batched lane walk.
//!
//! The batched execution path ([`crate::kernels::lane::run_lane_batched`])
//! streams B packed input rows against each visited `(j, w_word)` block.
//! The scalar loop calls [`super::dot4_words`] once per row — four i8
//! multiplies each. The kernels here compute the same per-row dot for a
//! whole slice of rows per call, amortizing the weight-word decode and
//! (for the SIMD variants) multiplying several operand lanes per
//! instruction. All of them are bit-identical to the scalar oracle: the
//! per-block contribution `Σ w_i * (x_i + off)` has magnitude ≤ 4 · 128 ·
//! 382 < 2^18, so every intermediate is exact in i32 and only the
//! cross-block accumulation wraps — which all paths perform with
//! `wrapping_add` on the same i32 accumulator.
//!
//! None of this touches simulated time: cycle totals come from
//! prepare-time [`crate::cpu::BulkCharge`]s, so the host kernel choice is
//! cycle-invariant by construction (pinned by the differential tier).

use crate::encoding::pack::unpack4_i8;

/// Scalar reference: one [`super::dot4_words`] per row — the host-side
/// oracle the SWAR/SIMD variants are differentially pinned against.
#[inline]
pub(crate) fn dot4_rows_scalar(w_word: u32, input_offset: i32, xs: &[u32], accs: &mut [i32]) {
    for (acc, &x) in accs.iter_mut().zip(xs) {
        *acc = acc.wrapping_add(super::dot4_words(w_word, x, input_offset));
    }
}

/// Byte-wise `+128` bias: flipping the sign bit of each i8 lane maps it
/// to the unsigned value `v + 128` in [0, 255].
const BIAS: u32 = 0x8080_8080;

/// Per-block SWAR precomputation, amortized over all rows of a batch.
///
/// Layout: the four biased weight bytes `a_i = w_i + 128` sit in two u64s
/// with 32-bit fields (`a0 | a1 << 32` and `a2 | a3 << 32`). One u64
/// multiply against the *swapped* biased input fields (`u1 | u0 << 32`)
/// yields `a0*u1` in the low field and `a0*u0 + a1*u1` in the high field
/// — exact, because each product ≤ 255² < 2^32 never carries across the
/// field boundary and the `a1*u0 * 2^64` term wraps off the top. Two such
/// multiplies replace four scalar ones per row.
///
/// Sign handling (the "bias trick"): with `a = w + 128`, `u = x + 128`,
/// `Σ a_i u_i = Σ w_i x_i + 128 Σ w + 128 Σ u`, so
/// `Σ w_i (x_i + off) = Σ a_i u_i − 128 Σ u + Σ w · (off − 128)`.
/// The last term is the per-block constant `kw` below.
struct SwarBlock {
    /// Biased weight lanes 0, 1 in 32-bit fields.
    a01: u64,
    /// Biased weight lanes 2, 3 in 32-bit fields.
    a23: u64,
    /// `Σ w_i · (input_offset − 128)`.
    kw: i32,
}

impl SwarBlock {
    #[inline]
    fn new(w_word: u32, input_offset: i32) -> SwarBlock {
        let [w0, w1, w2, w3] = unpack4_i8(w_word);
        let a = w_word ^ BIAS;
        let a0 = (a & 0xff) as u64;
        let a1 = ((a >> 8) & 0xff) as u64;
        let a2 = ((a >> 16) & 0xff) as u64;
        let a3 = (a >> 24) as u64;
        let wsum = w0 as i32 + w1 as i32 + w2 as i32 + w3 as i32;
        SwarBlock {
            a01: a0 | (a1 << 32),
            a23: a2 | (a3 << 32),
            kw: wsum.wrapping_mul(input_offset.wrapping_sub(128)),
        }
    }

    #[inline]
    fn dot(&self, x_word: u32) -> i32 {
        let u = x_word ^ BIAS;
        let u0 = (u & 0xff) as u64;
        let u1 = ((u >> 8) & 0xff) as u64;
        let u2 = ((u >> 16) & 0xff) as u64;
        let u3 = (u >> 24) as u64;
        let s01 = self.a01.wrapping_mul(u1 | (u0 << 32)) >> 32;
        let s23 = self.a23.wrapping_mul(u3 | (u2 << 32)) >> 32;
        // Each field sum ≤ 2 · 255² = 130050, the pair ≤ 260100: exact
        // in i32, as is 128 · Σu ≤ 130560.
        let s_au = (s01 + s23) as i32;
        let sum_u = (u0 + u1 + u2 + u3) as i32;
        s_au.wrapping_sub(sum_u.wrapping_mul(128)).wrapping_add(self.kw)
    }
}

/// Portable u64-SWAR kernel: two 32-bit-field multiplies per row instead
/// of four scalar ones, available on every target.
#[inline]
pub(crate) fn dot4_rows_swar(w_word: u32, input_offset: i32, xs: &[u32], accs: &mut [i32]) {
    let blk = SwarBlock::new(w_word, input_offset);
    for (acc, &x) in accs.iter_mut().zip(xs) {
        *acc = acc.wrapping_add(blk.dot(x));
    }
}

/// SSE2 kernel: two rows per `pmaddwd`.
///
/// The weight word is broadcast to both 4-lane halves of an 8×i16 vector
/// (sign-extended SSE2-only via interleave + arithmetic shift — no
/// `pmovsxbw` before SSE4.1); each iteration packs two rows' input words
/// into the other operand and one `_mm_madd_epi16` produces the four
/// pairwise i16×i16 sums, horizontally added to the two per-row dots.
/// `pmaddwd`'s only saturation case (both products = (−32768)²) cannot
/// occur with i8-range operands, so the result is exact.
#[cfg(target_arch = "x86_64")]
pub(crate) fn dot4_rows_sse2(w_word: u32, input_offset: i32, xs: &[u32], accs: &mut [i32]) {
    // SAFETY: SSE2 is part of the x86_64 baseline ISA, so the
    // `target_feature(enable = "sse2")` function below is always callable
    // on this target (and `HostKernel::available` re-checks at run time).
    unsafe { dot4_rows_sse2_impl(w_word, input_offset, xs, accs) }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
unsafe fn dot4_rows_sse2_impl(w_word: u32, input_offset: i32, xs: &[u32], accs: &mut [i32]) {
    use std::arch::x86_64::*;
    let [w0, w1, w2, w3] = unpack4_i8(w_word);
    let kw = (w0 as i32 + w1 as i32 + w2 as i32 + w3 as i32).wrapping_mul(input_offset);
    // [w0..w3, w0..w3] as i16: duplicate the word, interleave each byte
    // with itself and shift the high copy out arithmetically.
    let w_pair = (w_word as u64 | ((w_word as u64) << 32)) as i64;
    let wv = _mm_set_epi64x(0, w_pair);
    let w16 = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(wv, wv));
    let pairs_n = xs.len() / 2;
    for p in 0..pairs_n {
        let x_pair = (xs[2 * p] as u64 | ((xs[2 * p + 1] as u64) << 32)) as i64;
        let xv = _mm_set_epi64x(0, x_pair);
        let x16 = _mm_srai_epi16::<8>(_mm_unpacklo_epi8(xv, xv));
        // [r0p01, r0p23, r1p01, r1p23] → swap adjacent lanes and add.
        let partial = _mm_madd_epi16(w16, x16);
        let sums = _mm_add_epi32(partial, _mm_shuffle_epi32::<0b10_11_00_01>(partial));
        let r0 = _mm_cvtsi128_si32(sums);
        let r1 = _mm_cvtsi128_si32(_mm_shuffle_epi32::<0b10_10_10_10>(sums));
        accs[2 * p] = accs[2 * p].wrapping_add(r0.wrapping_add(kw));
        accs[2 * p + 1] = accs[2 * p + 1].wrapping_add(r1.wrapping_add(kw));
    }
    if xs.len() % 2 == 1 {
        let last = xs.len() - 1;
        accs[last] = accs[last].wrapping_add(super::dot4_words(w_word, xs[last], input_offset));
    }
}

/// NEON kernel: two rows per `smull` (`vmull_s8`) — eight i8×i8 products
/// widened to i16 at once, pairwise-added twice down to the two per-row
/// dots. NEON (ASIMD) is part of the aarch64 baseline ISA.
#[cfg(target_arch = "aarch64")]
pub(crate) fn dot4_rows_neon(w_word: u32, input_offset: i32, xs: &[u32], accs: &mut [i32]) {
    // SAFETY: NEON is mandatory on aarch64, so the intrinsics below are
    // always available on this target.
    unsafe {
        use std::arch::aarch64::*;
        let [w0, w1, w2, w3] = unpack4_i8(w_word);
        let kw = (w0 as i32 + w1 as i32 + w2 as i32 + w3 as i32).wrapping_mul(input_offset);
        let w8 = vcreate_s8(w_word as u64 | ((w_word as u64) << 32));
        let pairs_n = xs.len() / 2;
        for p in 0..pairs_n {
            let x8 = vcreate_s8(xs[2 * p] as u64 | ((xs[2 * p + 1] as u64) << 32));
            let prod = vmull_s8(w8, x8); // 8 × i16, exact
            let pairs = vpaddlq_s16(prod); // [r0p01, r0p23, r1p01, r1p23]
            let sums = vpaddq_s32(pairs, pairs); // [r0, r1, r0, r1]
            let r0 = vgetq_lane_s32::<0>(sums);
            let r1 = vgetq_lane_s32::<1>(sums);
            accs[2 * p] = accs[2 * p].wrapping_add(r0.wrapping_add(kw));
            accs[2 * p + 1] = accs[2 * p + 1].wrapping_add(r1.wrapping_add(kw));
        }
        if xs.len() % 2 == 1 {
            let last = xs.len() - 1;
            accs[last] =
                accs[last].wrapping_add(super::dot4_words(w_word, xs[last], input_offset));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    /// Every kernel this target can run, as (name, fn) pairs.
    #[allow(unused_mut)] // no push on targets without a SIMD variant
    fn kernels() -> Vec<(&'static str, fn(u32, i32, &[u32], &mut [i32]))> {
        let mut ks: Vec<(&'static str, fn(u32, i32, &[u32], &mut [i32]))> =
            vec![("scalar", dot4_rows_scalar), ("swar", dot4_rows_swar)];
        #[cfg(target_arch = "x86_64")]
        ks.push(("sse2", dot4_rows_sse2));
        #[cfg(target_arch = "aarch64")]
        ks.push(("neon", dot4_rows_neon));
        ks
    }

    #[test]
    fn all_kernels_match_scalar_on_random_rows() {
        let mut rng = Pcg32::new(0x5A4D);
        for round in 0..256 {
            let w_word = rng.next_u32();
            let off = rng.range_i32(0, 255);
            let rows = (round % 7) + 1; // covers odd tails and row 1
            let xs: Vec<u32> = (0..rows).map(|_| rng.next_u32()).collect();
            let seed_accs: Vec<i32> = (0..rows).map(|_| rng.range_i32(-1000, 1000)).collect();
            let mut expect = seed_accs.clone();
            dot4_rows_scalar(w_word, off, &xs, &mut expect);
            for (name, f) in kernels() {
                let mut got = seed_accs.clone();
                f(w_word, off, &xs, &mut got);
                assert_eq!(got, expect, "{name}: w={w_word:#010x} off={off}");
            }
        }
    }

    #[test]
    fn kernels_agree_on_extreme_operands() {
        // i8 extremes, all-zero weights, max offset: the corners where a
        // sign-extension or bias slip would show first.
        let words = [
            0x8080_8080u32, // all −128
            0x7f7f_7f7fu32, // all +127
            0x0000_0000u32, // all zero
            0x80ff_017fu32, // mixed extremes
        ];
        for &w in &words {
            for &x in &words {
                for off in [0, 1, 128, 255] {
                    let xs = [x; 5];
                    let mut expect = [0i32; 5];
                    dot4_rows_scalar(w, off, &xs, &mut expect);
                    for (name, f) in kernels() {
                        let mut got = [0i32; 5];
                        f(w, off, &xs, &mut got);
                        assert_eq!(got, expect, "{name}: w={w:#010x} x={x:#010x} off={off}");
                    }
                }
            }
        }
    }

    #[test]
    fn kernels_wrap_with_the_accumulator() {
        // Near-overflow accumulators must wrap identically everywhere.
        let xs = [0xdead_beefu32, 0x0102_0304, 0x8081_7f00];
        for (name, f) in kernels() {
            let mut a = [i32::MAX - 7, i32::MIN + 3, 0];
            let mut b = a;
            dot4_rows_scalar(0x7f80_2a15, 200, &xs, &mut a);
            f(0x7f80_2a15, 200, &xs, &mut b);
            assert_eq!(a, b, "{name}");
        }
    }
}
