//! Case-signal control logic + alignment multiplexers of the USSA
//! datapath (Fig 7).
//!
//! Each of the four weights is compared to zero in parallel, producing
//! the case signal `(c3, c2, c1, c0)` (bit i set ⇔ weight i non-zero).
//! The control logic derives mux selects `(cl0..cl3)` that compact the
//! non-zero `(w, x)` pairs to the front of the sequential MAC's input
//! queue, so the MAC runs exactly `popcount(case)` cycles (one per
//! non-zero weight), or a single idle cycle for an all-zero block.

/// Zero-compare stage: case signal bits (bit i ⇔ `w[i] != 0`).
#[inline]
pub fn case_signal(weights: &[i8; 4]) -> u8 {
    let mut c = 0u8;
    for (i, &w) in weights.iter().enumerate() {
        if w != 0 {
            c |= 1 << i;
        }
    }
    c
}

/// Control logic + muxes: compact the lanes selected by `case` to the
/// front, preserving order. Returns the aligned pairs and their count.
#[inline]
pub fn align_nonzero(
    weights: &[i8; 4],
    inputs: &[i8; 4],
    case: u8,
) -> ([i8; 4], [i8; 4], usize) {
    let mut w_out = [0i8; 4];
    let mut x_out = [0i8; 4];
    let mut n = 0usize;
    for i in 0..4 {
        if case & (1 << i) != 0 {
            w_out[n] = weights[i];
            x_out[n] = inputs[i];
            n += 1;
        }
    }
    (w_out, x_out, n)
}

/// MAC cycle count dictated by the case signal: one cycle per non-zero
/// weight; an all-zero block still costs one (idle) cycle — the paper's
/// `c_o` model (Section IV-D).
#[inline]
pub fn mac_cycles(case: u8) -> u32 {
    (case.count_ones()).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    #[test]
    fn case_signal_bits() {
        assert_eq!(case_signal(&[0, 0, 0, 0]), 0b0000);
        assert_eq!(case_signal(&[1, 0, 0, 0]), 0b0001);
        assert_eq!(case_signal(&[0, 2, 0, -3]), 0b1010);
        assert_eq!(case_signal(&[1, 1, 1, 1]), 0b1111);
    }

    #[test]
    fn align_compacts_in_order() {
        let (w, x, n) = align_nonzero(&[0, 5, 0, -7], &[10, 20, 30, 40], 0b1010);
        assert_eq!(n, 2);
        assert_eq!(&w[..2], &[5, -7]);
        assert_eq!(&x[..2], &[20, 40]);
    }

    #[test]
    fn cycles_per_case() {
        assert_eq!(mac_cycles(0b0000), 1); // all-zero block: single idle cycle
        assert_eq!(mac_cycles(0b0001), 1);
        assert_eq!(mac_cycles(0b0110), 2);
        assert_eq!(mac_cycles(0b1111), 4);
    }

    #[test]
    fn prop_alignment_preserves_dot_product() {
        check(
            Config::default().cases(256),
            |r: &mut Pcg32| {
                let mut v = Vec::with_capacity(8);
                for _ in 0..8 {
                    v.push(if r.bernoulli(0.4) { 0 } else { r.range_i32(-128, 127) });
                }
                v
            },
            |v| {
                let w = [v[0] as i8, v[1] as i8, v[2] as i8, v[3] as i8];
                let x = [v[4] as i8, v[5] as i8, v[6] as i8, v[7] as i8];
                let case = case_signal(&w);
                let (wa, xa, n) = align_nonzero(&w, &x, case);
                let full: i32 = (0..4).map(|i| w[i] as i32 * x[i] as i32).sum();
                let aligned: i32 = (0..n).map(|i| wa[i] as i32 * xa[i] as i32).sum();
                full == aligned && n as u32 == case.count_ones()
            },
        );
    }
}
