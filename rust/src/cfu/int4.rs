//! INT4 extension of the variable-cycle MAC (Section IV-D).
//!
//! *"Our approach can be extended to cases involving INT4 and INT2
//! weights, where the speedup over the baseline would be higher. For
//! example, one 32-bit register can contain eight INT4 weights, and if
//! seven of them are zeros, then the USSA will take a single clock
//! cycle, whereas the baseline will take eight clock cycles."*
//!
//! This module models that extension: 8 signed INT4 lanes per 32-bit
//! operand, a sparsity-blind 8-cycle sequential baseline, and a
//! variable-cycle unit taking `max(1, #nonzero)` cycles. The
//! `ablation_int4` bench sweeps sparsity against the generalized
//! binomial model ([`crate::analysis::speedup::vc_speedup_observed_n`]).

/// Lanes per register word for INT4.
pub const INT4_LANES: usize = 8;

/// Pack 8 signed INT4 values (each in `[-8, 7]`) into a u32, lane i at
/// bits `4i+3..4i`.
pub fn pack8_i4(lanes: &[i8; INT4_LANES]) -> u32 {
    let mut w = 0u32;
    for (i, &v) in lanes.iter().enumerate() {
        debug_assert!((-8..=7).contains(&v), "INT4 out of range: {v}");
        w |= ((v as u8 & 0xF) as u32) << (4 * i);
    }
    w
}

/// Unpack 8 signed INT4 lanes.
pub fn unpack8_i4(word: u32) -> [i8; INT4_LANES] {
    let mut out = [0i8; INT4_LANES];
    for (i, o) in out.iter_mut().enumerate() {
        let nib = ((word >> (4 * i)) & 0xF) as u8;
        // sign-extend from 4 bits
        *o = ((nib << 4) as i8) >> 4;
    }
    out
}

/// Result of one INT4 MAC block: value + cycles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Int4MacResponse {
    /// Dot product (i32).
    pub acc: i32,
    /// Cycles consumed.
    pub cycles: u32,
}

/// Sparsity-blind sequential INT4 MAC: always 8 cycles.
pub fn int4_seq_mac(w_word: u32, x_word: u32) -> Int4MacResponse {
    let w = unpack8_i4(w_word);
    let x = unpack8_i4(x_word);
    let acc: i32 = (0..INT4_LANES).map(|i| w[i] as i32 * x[i] as i32).sum();
    Int4MacResponse { acc, cycles: INT4_LANES as u32 }
}

/// Variable-cycle INT4 MAC: `max(1, #nonzero weights)` cycles.
pub fn int4_vc_mac(w_word: u32, x_word: u32) -> Int4MacResponse {
    let w = unpack8_i4(w_word);
    let x = unpack8_i4(x_word);
    let mut acc = 0i32;
    let mut nz = 0u32;
    for i in 0..INT4_LANES {
        if w[i] != 0 {
            acc += w[i] as i32 * x[i] as i32;
            nz += 1;
        }
    }
    Int4MacResponse { acc, cycles: nz.max(1) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    #[test]
    fn pack_unpack_roundtrip() {
        let lanes = [-8i8, 7, 0, -1, 3, -4, 5, -6];
        assert_eq!(unpack8_i4(pack8_i4(&lanes)), lanes);
    }

    #[test]
    fn seq_always_eight_cycles() {
        let zero = pack8_i4(&[0; 8]);
        assert_eq!(int4_seq_mac(zero, zero).cycles, 8);
        let dense = pack8_i4(&[1; 8]);
        assert_eq!(int4_seq_mac(dense, dense).cycles, 8);
    }

    #[test]
    fn paper_example_seven_zeros_single_cycle() {
        // "if seven of them are zeros, then the USSA will take a single
        // clock cycle, whereas the baseline will take eight".
        let w = pack8_i4(&[0, 0, 0, 5, 0, 0, 0, 0]);
        let x = pack8_i4(&[1, 2, 3, 4, 5, 6, 7, -8]);
        let vc = int4_vc_mac(w, x);
        assert_eq!(vc.cycles, 1);
        assert_eq!(vc.acc, 20);
        assert_eq!(int4_seq_mac(w, x).cycles, 8);
    }

    #[test]
    fn prop_vc_matches_seq_value() {
        check(
            Config::default().cases(512),
            |r: &mut Pcg32| {
                let mut v = Vec::with_capacity(16);
                for _ in 0..8 {
                    v.push(if r.bernoulli(0.6) { 0 } else { r.range_i32(-8, 7) });
                }
                for _ in 0..8 {
                    v.push(r.range_i32(-8, 7));
                }
                v
            },
            |v| {
                let w: [i8; 8] = std::array::from_fn(|i| v[i] as i8);
                let x: [i8; 8] = std::array::from_fn(|i| v[8 + i] as i8);
                let ww = pack8_i4(&w);
                let xw = pack8_i4(&x);
                let vc = int4_vc_mac(ww, xw);
                let seq = int4_seq_mac(ww, xw);
                let nz = w.iter().filter(|&&wi| wi != 0).count() as u32;
                vc.acc == seq.acc && vc.cycles == nz.max(1)
            },
        );
    }
}
