//! CSA — Combined Sparsity Accelerator (Section III-D).
//!
//! Integrates both prior designs:
//! - `csa_vcmac`: variable-cycle sequential MAC like USSA's, except the
//!   weights are lookahead-encoded INT7 values (decoded from bits `[7:1]`
//!   of each byte). The zero-compare operates on the *decoded* weights so
//!   the lookahead bits never inflate the cycle count.
//! - `csa_inc_indvar`: identical behaviour to `sssa_inc_indvar`.
//!
//! Because the surrounding kernel (Listing 3) skips all-zero blocks via
//! the induction-variable increment, the USSA's one-cycle all-zero-block
//! penalty "can be avoided using CSA" (Section IV-D).

use super::case_logic::{align_nonzero, case_signal, mac_cycles};
use super::sssa::{decode_weights, indvar_increment};
use super::{Cfu, CfuResponse};
use crate::encoding::pack::unpack4_i8;
use crate::error::{Error, Result};
use crate::isa::{CfuOpcode, DesignKind};

/// Cycles one `csa_vcmac` takes for a packed *encoded* weight word: one
/// per non-zero decoded weight, floored at 1 — the lookahead bits never
/// inflate the count. Pure function of the word (prepare-time schedule
/// compiler oracle).
#[inline]
pub fn vcmac_cycles(rs1: u32) -> u32 {
    mac_cycles(case_signal(&decode_weights(rs1)))
}

/// The CSA CFU.
#[derive(Debug, Clone)]
pub struct CsaCfu {
    input_offset: i32,
}

impl CsaCfu {
    /// New unit.
    pub fn new(input_offset: i32) -> Self {
        CsaCfu { input_offset }
    }
}

impl Cfu for CsaCfu {
    fn design(&self) -> DesignKind {
        DesignKind::Csa
    }

    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match op {
            CfuOpcode::CsaVcMac => {
                let w = decode_weights(rs1);
                let x = unpack4_i8(rs2);
                let case = case_signal(&w);
                let (wa, xa, n) = align_nonzero(&w, &x, case);
                let mut acc = 0i32;
                for i in 0..n {
                    acc = acc
                        .wrapping_add((wa[i] as i32).wrapping_mul(xa[i] as i32 + self.input_offset));
                }
                Ok(CfuResponse { rd: acc as u32, cycles: mac_cycles(case) })
            }
            CfuOpcode::CsaIncIndvar => {
                Ok(CfuResponse { rd: rs2.wrapping_add(indvar_increment(rs1)), cycles: 1 })
            }
            other => Err(Error::Sim(format!("CSA CFU cannot execute {}", other.mnemonic()))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::lookahead::encode_last_bits;
    use crate::encoding::pack::pack4_i8;
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    fn encoded_word(weights: [i8; 4], skip: u8) -> u32 {
        let mut enc = weights;
        encode_last_bits(&mut enc, skip).unwrap();
        pack4_i8(&enc)
    }

    #[test]
    fn vcmac_cycles_use_decoded_zeros() {
        let mut cfu = CsaCfu::new(0);
        let x = pack4_i8(&[1, 1, 1, 1]);
        // Weights [0,0,5,0] with skip bits 0b1111: encoded bytes are all
        // non-zero, but only one *decoded* weight is non-zero → 1 cycle.
        let rs1 = encoded_word([0, 0, 5, 0], 0b1111);
        let r = cfu.execute(CfuOpcode::CsaVcMac, rs1, x).unwrap();
        assert_eq!(r.cycles, 1);
        assert_eq!(r.rd as i32, 5);
    }

    #[test]
    fn vcmac_cycles_fn_matches_executed_unit() {
        let mut rng = Pcg32::new(0xACD);
        let mut cfu = CsaCfu::new(0);
        for _ in 0..256 {
            let w: [i8; 4] = std::array::from_fn(|_| {
                if rng.bernoulli(0.5) {
                    0
                } else {
                    rng.range_i32(-64, 63) as i8
                }
            });
            let rs1 = encoded_word(w, rng.range_i32(0, 15) as u8);
            let r = cfu.execute(CfuOpcode::CsaVcMac, rs1, 0).unwrap();
            assert_eq!(vcmac_cycles(rs1), r.cycles, "w={w:?}");
        }
    }

    #[test]
    fn inc_indvar_matches_sssa() {
        use crate::cfu::sssa::SssaCfu;
        let mut csa = CsaCfu::new(0);
        let mut sssa = SssaCfu::new(0);
        for skip in 0..=15u8 {
            let rs1 = encoded_word([1, -1, 2, -2], skip);
            let a = csa.execute(CfuOpcode::CsaIncIndvar, rs1, 100).unwrap().rd;
            let b = sssa.execute(CfuOpcode::SssaIncIndvar, rs1, 100).unwrap().rd;
            assert_eq!(a, b);
        }
    }

    #[test]
    fn prop_vcmac_value_and_cycles() {
        check(
            Config::default().cases(512),
            |r: &mut Pcg32| {
                let mut v = Vec::with_capacity(9);
                for _ in 0..4 {
                    v.push(if r.bernoulli(0.5) { 0 } else { r.range_i32(-64, 63) });
                }
                for _ in 0..4 {
                    v.push(r.range_i32(-128, 127));
                }
                v.push(r.range_i32(0, 15));
                v
            },
            |v| {
                let w = [v[0] as i8, v[1] as i8, v[2] as i8, v[3] as i8];
                let x = [v[4] as i8, v[5] as i8, v[6] as i8, v[7] as i8];
                let skip = v[8] as u8;
                let mut cfu = CsaCfu::new(128);
                let r = cfu
                    .execute(CfuOpcode::CsaVcMac, encoded_word(w, skip), pack4_i8(&x))
                    .unwrap();
                let expect: i32 =
                    (0..4).map(|i| w[i] as i32 * (x[i] as i32 + 128)).sum();
                let nz = w.iter().filter(|&&wi| wi != 0).count() as u32;
                r.rd as i32 == expect && r.cycles == nz.max(1)
            },
        );
    }
}
