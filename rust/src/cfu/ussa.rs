//! USSA — Unstructured Sparsity Accelerator (Section III-C, Fig 7).
//!
//! `ussa_vcmac`: a variable-cycle sequential MAC. The four INT8 weights
//! in `rs1` are zero-compared in parallel (case signal); alignment muxes
//! compact the non-zero `(w, x)` pairs onto a single sequential
//! multiplier, which takes one cycle per non-zero weight — one idle cycle
//! for an all-zero block. No assumptions on the structure or number of
//! zeros.

use super::case_logic::{align_nonzero, case_signal, mac_cycles};
use super::{Cfu, CfuResponse};
use crate::encoding::pack::unpack4_i8;
use crate::error::{Error, Result};
use crate::isa::{CfuOpcode, DesignKind};

/// Cycles one `ussa_vcmac` takes for a packed weight word: one per
/// non-zero weight, floored at 1 for an all-zero block. Pure function of
/// the word — the prepare-time lane-schedule compiler charges stalls from
/// this without executing the unit.
#[inline]
pub fn vcmac_cycles(rs1: u32) -> u32 {
    mac_cycles(case_signal(&unpack4_i8(rs1)))
}

/// The USSA CFU.
#[derive(Debug, Clone)]
pub struct UssaCfu {
    input_offset: i32,
}

impl UssaCfu {
    /// New unit.
    pub fn new(input_offset: i32) -> Self {
        UssaCfu { input_offset }
    }
}

impl Cfu for UssaCfu {
    fn design(&self) -> DesignKind {
        DesignKind::Ussa
    }

    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match op {
            CfuOpcode::UssaVcMac => {
                let w = unpack4_i8(rs1);
                let x = unpack4_i8(rs2);
                let case = case_signal(&w);
                let (wa, xa, n) = align_nonzero(&w, &x, case);
                // Sequential MAC over the aligned non-zero lanes.
                let mut acc = 0i32;
                for i in 0..n {
                    acc = acc
                        .wrapping_add((wa[i] as i32).wrapping_mul(xa[i] as i32 + self.input_offset));
                }
                Ok(CfuResponse { rd: acc as u32, cycles: mac_cycles(case) })
            }
            other => {
                Err(Error::Sim(format!("USSA CFU cannot execute {}", other.mnemonic())))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfu::dot4;
    use crate::encoding::pack::pack4_i8;
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    #[test]
    fn cycles_equal_nonzero_count() {
        let mut cfu = UssaCfu::new(0);
        let x = pack4_i8(&[1, 1, 1, 1]);
        let cases: [([i8; 4], u32); 5] = [
            ([0, 0, 0, 0], 1), // all-zero: single cycle
            ([5, 0, 0, 0], 1),
            ([5, 0, -3, 0], 2),
            ([5, 1, -3, 0], 3),
            ([5, 1, -3, 9], 4),
        ];
        for (w, expect_cycles) in cases {
            let r = cfu.execute(CfuOpcode::UssaVcMac, pack4_i8(&w), x).unwrap();
            assert_eq!(r.cycles, expect_cycles, "weights {w:?}");
        }
    }

    #[test]
    fn vcmac_cycles_fn_matches_executed_unit() {
        let mut rng = Pcg32::new(0xACC);
        let mut cfu = UssaCfu::new(0);
        for _ in 0..256 {
            let w: [i8; 4] = std::array::from_fn(|_| {
                if rng.bernoulli(0.5) {
                    0
                } else {
                    rng.range_i32(-128, 127) as i8
                }
            });
            let rs1 = pack4_i8(&w);
            let r = cfu.execute(CfuOpcode::UssaVcMac, rs1, 0).unwrap();
            assert_eq!(vcmac_cycles(rs1), r.cycles, "w={w:?}");
        }
    }

    #[test]
    fn zero_weight_lanes_do_not_contribute_offset() {
        // Critical: with input_offset != 0, a zero weight must contribute
        // 0 (w * (x + off) = 0), so skipping it is arithmetically safe.
        let mut cfu = UssaCfu::new(128);
        let w = [0i8, 7, 0, -9];
        let x = [55i8, -66, 77, -88];
        let r = cfu.execute(CfuOpcode::UssaVcMac, pack4_i8(&w), pack4_i8(&x)).unwrap();
        assert_eq!(r.rd as i32, dot4(w, x, 128));
    }

    #[test]
    fn matches_baseline_simd_value() {
        use crate::cfu::baseline::BaselineSimdMac;
        let mut ussa = UssaCfu::new(3);
        let mut base = BaselineSimdMac::new(3);
        let w = pack4_i8(&[-128, 0, 127, 1]);
        let x = pack4_i8(&[9, 9, -9, 0]);
        assert_eq!(
            ussa.execute(CfuOpcode::UssaVcMac, w, x).unwrap().rd,
            base.execute(CfuOpcode::CfuSimdMac, w, x).unwrap().rd
        );
    }

    #[test]
    fn prop_value_and_cycles() {
        check(
            Config::default().cases(512),
            |r: &mut Pcg32| {
                let mut v = Vec::with_capacity(8);
                for _ in 0..4 {
                    v.push(if r.bernoulli(0.5) { 0 } else { r.range_i32(-128, 127) });
                }
                for _ in 0..4 {
                    v.push(r.range_i32(-128, 127));
                }
                v
            },
            |v| {
                let w = [v[0] as i8, v[1] as i8, v[2] as i8, v[3] as i8];
                let x = [v[4] as i8, v[5] as i8, v[6] as i8, v[7] as i8];
                let mut cfu = UssaCfu::new(128);
                let r = cfu.execute(CfuOpcode::UssaVcMac, pack4_i8(&w), pack4_i8(&x)).unwrap();
                let nz = w.iter().filter(|&&wi| wi != 0).count() as u32;
                r.rd as i32 == dot4(w, x, 128) && r.cycles == nz.max(1)
            },
        );
    }
}
