//! SSSA — Semi-Structured Sparsity Accelerator (Section III-B, Fig 4).
//!
//! Two instructions share the datapath, selected by `funct7`'s LSB:
//!
//! - `sssa_mac` (`f0 = 0`): `rs1` carries four lookahead-encoded weights;
//!   the hardware extracts each 7-bit weight from bits `[7:1]` of its byte
//!   (arithmetic right shift by one) and performs a four-multiplier
//!   parallel MAC against the four INT8 inputs in `rs2`. One cycle.
//! - `sssa_inc_indvar` (`f0 = 1`): the four lookahead bits
//!   `(b24, b16, b8, b0)` of `rs1` form `skip_blocks`; the unit returns
//!   `rs2 + ((skip_blocks + 1) << 2)` — "adding one to the bits encoding
//!   skip blocks information and left shifting by two to multiply by
//!   four". One cycle.

use super::{dot4, Cfu, CfuResponse};
use crate::encoding::pack::{pack4_u32_skip_bits, unpack4_i8};
use crate::error::{Error, Result};
use crate::isa::{CfuOpcode, DesignKind};

/// Decode the four 7-bit weights of an encoded register word.
#[inline]
pub fn decode_weights(rs1: u32) -> [i8; 4] {
    let enc = unpack4_i8(rs1);
    // bits [7:1] sign-extended = arithmetic shift right by 1.
    [enc[0] >> 1, enc[1] >> 1, enc[2] >> 1, enc[3] >> 1]
}

/// The induction-variable increment datapath: `(skip + 1) << 2`.
#[inline]
pub fn indvar_increment(rs1: u32) -> u32 {
    ((pack4_u32_skip_bits(rs1) as u32) + 1) << 2
}

/// The SSSA CFU.
#[derive(Debug, Clone)]
pub struct SssaCfu {
    input_offset: i32,
}

impl SssaCfu {
    /// New unit.
    pub fn new(input_offset: i32) -> Self {
        SssaCfu { input_offset }
    }
}

impl Cfu for SssaCfu {
    fn design(&self) -> DesignKind {
        DesignKind::Sssa
    }

    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match op {
            CfuOpcode::SssaMac => {
                let w = decode_weights(rs1);
                let x = unpack4_i8(rs2);
                Ok(CfuResponse { rd: dot4(w, x, self.input_offset) as u32, cycles: 1 })
            }
            CfuOpcode::SssaIncIndvar => {
                Ok(CfuResponse { rd: rs2.wrapping_add(indvar_increment(rs1)), cycles: 1 })
            }
            other => {
                Err(Error::Sim(format!("SSSA CFU cannot execute {}", other.mnemonic())))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::lookahead::encode_last_bits;
    use crate::encoding::pack::pack4_i8;
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    fn encoded_word(weights: [i8; 4], skip: u8) -> u32 {
        let mut enc = weights;
        encode_last_bits(&mut enc, skip).unwrap();
        pack4_i8(&enc)
    }

    #[test]
    fn mac_decodes_weights_exactly() {
        let mut cfu = SssaCfu::new(0);
        let w = [-64i8, 63, 0, -1];
        let x = [3i8, -2, 100, 50];
        let rs1 = encoded_word(w, 0b1111); // skip bits must not disturb MAC
        let r = cfu.execute(CfuOpcode::SssaMac, rs1, pack4_i8(&x)).unwrap();
        let expect: i32 = (0..4).map(|i| w[i] as i32 * x[i] as i32).sum();
        assert_eq!(r.rd as i32, expect);
        assert_eq!(r.cycles, 1);
    }

    #[test]
    fn inc_indvar_adds_skip_plus_one_blocks() {
        let mut cfu = SssaCfu::new(0);
        for skip in 0..=15u8 {
            let rs1 = encoded_word([1, 2, 3, 4], skip);
            let i0 = 36u32;
            let r = cfu.execute(CfuOpcode::SssaIncIndvar, rs1, i0).unwrap();
            assert_eq!(r.rd, i0 + 4 * (skip as u32 + 1), "skip={skip}");
            assert_eq!(r.cycles, 1);
        }
    }

    #[test]
    fn increment_is_seven_bit_datapath() {
        // max skip 15 → increment (15+1)*4 = 64 = (a4..a0,0,0) with a4=1:
        // fits the 7-bit increment of Fig 4.
        assert_eq!(indvar_increment(encoded_word([0, 0, 0, 0], 15)), 64);
        assert_eq!(indvar_increment(encoded_word([0, 0, 0, 0], 0)), 4);
    }

    #[test]
    fn mac_with_input_offset() {
        let mut cfu = SssaCfu::new(128);
        let w = [2i8, -3, 0, 1];
        let x = [-128i8, 0, 5, 127];
        let r = cfu
            .execute(CfuOpcode::SssaMac, encoded_word(w, 0), pack4_i8(&x))
            .unwrap();
        let expect: i32 = (0..4).map(|i| w[i] as i32 * (x[i] as i32 + 128)).sum();
        assert_eq!(r.rd as i32, expect);
    }

    #[test]
    fn prop_mac_equals_int7_dot() {
        check(
            Config::default().cases(256),
            |r: &mut Pcg32| {
                let mut v = Vec::with_capacity(9);
                for _ in 0..4 {
                    v.push(r.range_i32(-64, 63));
                }
                for _ in 0..4 {
                    v.push(r.range_i32(-128, 127));
                }
                v.push(r.range_i32(0, 15));
                v
            },
            |v| {
                let w = [v[0] as i8, v[1] as i8, v[2] as i8, v[3] as i8];
                let x = [v[4] as i8, v[5] as i8, v[6] as i8, v[7] as i8];
                let skip = v[8] as u8;
                let mut cfu = SssaCfu::new(0);
                let r = cfu
                    .execute(CfuOpcode::SssaMac, encoded_word(w, skip), pack4_i8(&x))
                    .unwrap();
                let expect: i32 = (0..4).map(|i| w[i] as i32 * x[i] as i32).sum();
                r.rd as i32 == expect
            },
        );
    }
}
