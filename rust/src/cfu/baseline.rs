//! Baseline CFUs.
//!
//! - [`BaselineSimdMac`] — the CFU Playground TFLite starting point
//!   (Section III-A): four INT8×INT8 multipliers in parallel, one cycle
//!   per 4-weight block regardless of sparsity.
//! - [`BaselineSequentialMac`] — the USSA comparison baseline
//!   (Section III-C1): a *single* multiplier applied over four cycles,
//!   "consistently requires four clock cycles regardless of the presence
//!   of zeros".

use super::{dot4, Cfu, CfuResponse};
use crate::encoding::pack::unpack4_i8;
use crate::error::{Error, Result};
use crate::isa::{CfuOpcode, DesignKind};

/// Cycles of one `cfu_simd_mac`: always 1, sparsity-blind. Exposed so the
/// prepare-time lane-schedule compiler can charge per-word cycles without
/// instantiating the unit.
#[inline]
pub const fn simd_mac_cycles() -> u32 {
    1
}

/// Cycles of one `cfu_seq_mac`: always 4 (single multiplier, four lanes).
#[inline]
pub const fn seq_mac_cycles() -> u32 {
    4
}

/// Parallel SIMD MAC: 1 cycle per block (4 DSP multipliers).
#[derive(Debug, Clone)]
pub struct BaselineSimdMac {
    input_offset: i32,
}

impl BaselineSimdMac {
    /// New unit with a hardware input-offset constant.
    pub fn new(input_offset: i32) -> Self {
        BaselineSimdMac { input_offset }
    }
}

impl Cfu for BaselineSimdMac {
    fn design(&self) -> DesignKind {
        DesignKind::BaselineSimd
    }

    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match op {
            CfuOpcode::CfuSimdMac => {
                let w = unpack4_i8(rs1);
                let x = unpack4_i8(rs2);
                Ok(CfuResponse { rd: dot4(w, x, self.input_offset) as u32, cycles: 1 })
            }
            other => Err(Error::Sim(format!(
                "baseline-simd CFU cannot execute {}",
                other.mnemonic()
            ))),
        }
    }
}

/// Sequential single-multiplier MAC: always 4 cycles per block.
#[derive(Debug, Clone)]
pub struct BaselineSequentialMac {
    input_offset: i32,
}

impl BaselineSequentialMac {
    /// New unit.
    pub fn new(input_offset: i32) -> Self {
        BaselineSequentialMac { input_offset }
    }
}

impl Cfu for BaselineSequentialMac {
    fn design(&self) -> DesignKind {
        DesignKind::BaselineSequential
    }

    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match op {
            CfuOpcode::CfuSeqMac => {
                let w = unpack4_i8(rs1);
                let x = unpack4_i8(rs2);
                // One multiply per cycle, four cycles, sparsity-blind.
                Ok(CfuResponse { rd: dot4(w, x, self.input_offset) as u32, cycles: 4 })
            }
            other => Err(Error::Sim(format!(
                "baseline-seq CFU cannot execute {}",
                other.mnemonic()
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::pack::pack4_i8;

    #[test]
    fn simd_mac_one_cycle_any_sparsity() {
        let mut cfu = BaselineSimdMac::new(128);
        for w in [[0i8; 4], [1, 0, 0, 0], [1, 2, 3, 4]] {
            let r = cfu.execute(CfuOpcode::CfuSimdMac, pack4_i8(&w), pack4_i8(&[1, 1, 1, 1]))
                .unwrap();
            assert_eq!(r.cycles, 1);
        }
    }

    #[test]
    fn seq_mac_always_four_cycles() {
        let mut cfu = BaselineSequentialMac::new(0);
        for w in [[0i8; 4], [1, 0, 0, 0], [1, 2, 3, 4]] {
            let r = cfu.execute(CfuOpcode::CfuSeqMac, pack4_i8(&w), pack4_i8(&[9, 9, 9, 9]))
                .unwrap();
            assert_eq!(r.cycles, 4);
        }
    }

    #[test]
    fn mac_value_negative_weights() {
        let mut cfu = BaselineSimdMac::new(0);
        let r = cfu
            .execute(
                CfuOpcode::CfuSimdMac,
                pack4_i8(&[-128, 127, -1, 2]),
                pack4_i8(&[127, -128, 3, -4]),
            )
            .unwrap();
        let expect = (-128i32 * 127) + (127 * -128) + (-1 * 3) + (2 * -4);
        assert_eq!(r.rd as i32, expect);
    }

    #[test]
    fn simd_and_seq_agree() {
        let mut a = BaselineSimdMac::new(77);
        let mut b = BaselineSequentialMac::new(77);
        let w = pack4_i8(&[-5, 0, 63, -64]);
        let x = pack4_i8(&[100, -100, 5, 0]);
        assert_eq!(
            a.execute(CfuOpcode::CfuSimdMac, w, x).unwrap().rd,
            b.execute(CfuOpcode::CfuSeqMac, w, x).unwrap().rd
        );
    }
}
