//! Custom Functional Unit models.
//!
//! Each CFU is modelled at the CPU–CFU contract level (Fig 3): it
//! receives two 32-bit operands (`rs1`, `rs2`) plus the `funct` fields,
//! and returns a 32-bit result after a number of clock cycles. The cycle
//! count is part of the architectural contract (the CPU stalls on the
//! valid/ready handshake), so each model returns `(rd, cycles)` and the
//! CPU timing model ([`crate::cpu`]) charges the stall.
//!
//! Functional semantics are bit-exact to the paper:
//! - [`baseline`] — `cfu_simd_mac` (4 parallel INT8×INT8, 1 cycle) and the
//!   sequential single-multiplier MAC (always 4 cycles; USSA's baseline),
//! - [`sssa`] — `sssa_mac` (4 parallel INT7×INT8 on lookahead-encoded
//!   weights, 1 cycle) + `sssa_inc_indvar` (Fig 4 datapath),
//! - [`ussa`] — `ussa_vcmac`, the variable-cycle sequential MAC with
//!   zero-compare case signals and alignment muxes (Fig 7),
//! - [`csa`] — `csa_vcmac` (variable-cycle over decoded INT7 weights) +
//!   `csa_inc_indvar`,
//! - [`formats`] — the format-extension units: `nm_mac`/`nm_lookahead`
//!   (2:4 semi-structured), `bsr_mac` (8×8 block-sparse), `bbs_mac`
//!   (bank-balanced).

pub mod baseline;
pub mod case_logic;
pub mod csa;
pub mod formats;
pub(crate) mod hostdot;
pub mod int4;
pub mod sssa;
pub mod ussa;

use crate::error::Result;
use crate::isa::{CfuOpcode, DesignKind};

/// Result of one CFU instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CfuResponse {
    /// Value written back to `rd`.
    pub rd: u32,
    /// Clock cycles from issue to `valid` (≥ 1).
    pub cycles: u32,
}

/// A CFU design: executes the custom instructions it implements.
pub trait Cfu: Send {
    /// Which design this is.
    fn design(&self) -> DesignKind;

    /// Execute one custom instruction. Errors if the op does not belong
    /// to this design.
    fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse>;
}

/// Instantiate the CFU for a design.
///
/// `input_offset` is the activation zero-point correction the CFU adds to
/// each input lane before multiplying (CFU Playground's TFLite CFU bakes
/// this in as a hardware constant; TFLite conv computes
/// `w * (x + input_offset)` with `input_offset = -input_zero_point`).
pub fn build_cfu(design: DesignKind, input_offset: i32) -> Box<dyn Cfu> {
    match design {
        DesignKind::BaselineSimd => Box::new(baseline::BaselineSimdMac::new(input_offset)),
        DesignKind::BaselineSequential => {
            Box::new(baseline::BaselineSequentialMac::new(input_offset))
        }
        DesignKind::Sssa => Box::new(sssa::SssaCfu::new(input_offset)),
        DesignKind::Ussa => Box::new(ussa::UssaCfu::new(input_offset)),
        DesignKind::Csa => Box::new(csa::CsaCfu::new(input_offset)),
        DesignKind::NmSsa => Box::new(formats::NmCfu::new(input_offset)),
        DesignKind::Bsr => Box::new(formats::BsrCfu::new(input_offset)),
        DesignKind::Bbs => Box::new(formats::BbsCfu::new(input_offset)),
    }
}

/// Statically-dispatched CFU (enum devirtualization of [`build_cfu`]) —
/// the simulator hot path executes two CFU ops per visited block, so
/// removing the vtable indirection is a measurable win
/// (EXPERIMENTS.md §Perf). Semantics are identical to the boxed trait
/// objects (delegates to the same implementations).
#[derive(Debug, Clone)]
pub enum AnyCfu {
    /// Baseline SIMD MAC.
    BaselineSimd(baseline::BaselineSimdMac),
    /// Baseline sequential MAC.
    BaselineSequential(baseline::BaselineSequentialMac),
    /// SSSA.
    Sssa(sssa::SssaCfu),
    /// USSA.
    Ussa(ussa::UssaCfu),
    /// CSA.
    Csa(csa::CsaCfu),
    /// NM-SSA (2:4 semi-structured).
    NmSsa(formats::NmCfu),
    /// BSR (8×8 block-sparse).
    Bsr(formats::BsrCfu),
    /// BBS (bank-balanced).
    Bbs(formats::BbsCfu),
}

impl AnyCfu {
    /// Build for a design.
    pub fn new(design: DesignKind, input_offset: i32) -> AnyCfu {
        match design {
            DesignKind::BaselineSimd => {
                AnyCfu::BaselineSimd(baseline::BaselineSimdMac::new(input_offset))
            }
            DesignKind::BaselineSequential => {
                AnyCfu::BaselineSequential(baseline::BaselineSequentialMac::new(input_offset))
            }
            DesignKind::Sssa => AnyCfu::Sssa(sssa::SssaCfu::new(input_offset)),
            DesignKind::Ussa => AnyCfu::Ussa(ussa::UssaCfu::new(input_offset)),
            DesignKind::Csa => AnyCfu::Csa(csa::CsaCfu::new(input_offset)),
            DesignKind::NmSsa => AnyCfu::NmSsa(formats::NmCfu::new(input_offset)),
            DesignKind::Bsr => AnyCfu::Bsr(formats::BsrCfu::new(input_offset)),
            DesignKind::Bbs => AnyCfu::Bbs(formats::BbsCfu::new(input_offset)),
        }
    }

    /// Execute one custom instruction (static dispatch).
    #[inline]
    pub fn execute(&mut self, op: CfuOpcode, rs1: u32, rs2: u32) -> Result<CfuResponse> {
        match self {
            AnyCfu::BaselineSimd(c) => c.execute(op, rs1, rs2),
            AnyCfu::BaselineSequential(c) => c.execute(op, rs1, rs2),
            AnyCfu::Sssa(c) => c.execute(op, rs1, rs2),
            AnyCfu::Ussa(c) => c.execute(op, rs1, rs2),
            AnyCfu::Csa(c) => c.execute(op, rs1, rs2),
            AnyCfu::NmSsa(c) => c.execute(op, rs1, rs2),
            AnyCfu::Bsr(c) => c.execute(op, rs1, rs2),
            AnyCfu::Bbs(c) => c.execute(op, rs1, rs2),
        }
    }
}

/// Shared MAC arithmetic: `Σ w_i * (x_i + input_offset)` over 4 lanes,
/// wrapping i32 (the hardware accumulator width).
#[inline]
pub(crate) fn dot4(weights: [i8; 4], inputs: [i8; 4], input_offset: i32) -> i32 {
    let mut acc = 0i32;
    for i in 0..4 {
        acc = acc.wrapping_add((weights[i] as i32).wrapping_mul(inputs[i] as i32 + input_offset));
    }
    acc
}

/// [`dot4`] over packed operand words — the single multiply every MAC
/// design reduces to once its weights are decoded (zero weights
/// contribute `0 * (x + off) = 0`, so the variable-cycle units' lane
/// compaction never changes the value). The compiled lane schedules run
/// their inner loop through this.
#[inline]
pub(crate) fn dot4_words(w_word: u32, x_word: u32, input_offset: i32) -> i32 {
    dot4(
        crate::encoding::pack::unpack4_i8(w_word),
        crate::encoding::pack::unpack4_i8(x_word),
        input_offset,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::pack::pack4_i8;

    #[test]
    fn build_all_designs() {
        for d in DesignKind::ALL {
            let cfu = build_cfu(d, 0);
            assert_eq!(cfu.design(), d);
        }
    }

    #[test]
    fn wrong_op_rejected() {
        let mut cfu = build_cfu(DesignKind::BaselineSimd, 0);
        assert!(cfu.execute(CfuOpcode::SssaMac, 0, 0).is_err());
    }

    #[test]
    fn dot4_matches_scalar() {
        let w = [1i8, -2, 3, -4];
        let x = [10i8, 20, -30, 40];
        let off = 12;
        let expect: i32 =
            (0..4).map(|i| w[i] as i32 * (x[i] as i32 + off)).sum();
        assert_eq!(dot4(w, x, off), expect);
    }

    #[test]
    fn all_macs_agree_on_dense_int7_blocks() {
        // For INT7 weights (encoded for SSSA/CSA), every design's MAC
        // must produce the same arithmetic result.
        let w = [5i8, -60, 0, 33];
        let x = [-120i8, 7, 99, -1];
        let off = 128;
        let expect = dot4(w, x, off) as u32;

        let mut enc = w;
        crate::encoding::lookahead::encode_last_bits(&mut enc, 0b1010).unwrap();

        let cases: Vec<(DesignKind, CfuOpcode, u32)> = vec![
            (DesignKind::BaselineSimd, CfuOpcode::CfuSimdMac, pack4_i8(&w)),
            (DesignKind::BaselineSequential, CfuOpcode::CfuSeqMac, pack4_i8(&w)),
            (DesignKind::Sssa, CfuOpcode::SssaMac, pack4_i8(&enc)),
            (DesignKind::Ussa, CfuOpcode::UssaVcMac, pack4_i8(&w)),
            (DesignKind::Csa, CfuOpcode::CsaVcMac, pack4_i8(&enc)),
            (DesignKind::NmSsa, CfuOpcode::NmMac, pack4_i8(&w)),
            (DesignKind::Bsr, CfuOpcode::BsrMac, pack4_i8(&w)),
            (DesignKind::Bbs, CfuOpcode::BbsMac, pack4_i8(&w)),
        ];
        for (design, op, rs1) in cases {
            let mut cfu = build_cfu(design, off);
            let resp = cfu.execute(op, rs1, pack4_i8(&x)).unwrap();
            assert_eq!(resp.rd, expect, "{design} mac mismatch");
        }
    }
}
