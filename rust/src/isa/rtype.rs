//! Bit-exact R-type instruction encoding (RISC-V base format).
//!
//! Layout (Fig 3 of the paper / RISC-V spec):
//!
//! ```text
//!  31      25 24  20 19  15 14  12 11   7 6      0
//! +----------+------+------+------+------+--------+
//! |  funct7  | rs2  | rs1  |funct3|  rd  | opcode |
//! +----------+------+------+------+------+--------+
//! ```
//!
//! CFU Playground routes `custom-0` (opcode `0b0001011`) to the CFU; the
//! CFU sees `funct7`, `funct3` and the two resolved source registers.

use crate::error::{Error, Result};

/// The `custom-0` major opcode reserved by the RISC-V spec for custom
/// instruction extensions.
pub const CUSTOM0_OPCODE: u32 = 0b000_1011;

/// Decoded R-type instruction fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RType {
    /// 7-bit function code (CFU sub-operation select).
    pub funct7: u8,
    /// Second source register index (0–31).
    pub rs2: u8,
    /// First source register index (0–31).
    pub rs1: u8,
    /// 3-bit function code.
    pub funct3: u8,
    /// Destination register index (0–31).
    pub rd: u8,
    /// 7-bit major opcode.
    pub opcode: u8,
}

impl RType {
    /// Construct a `custom-0` CFU instruction.
    pub fn custom0(funct7: u8, funct3: u8, rd: u8, rs1: u8, rs2: u8) -> Result<Self> {
        let it = RType { funct7, rs2, rs1, funct3, rd, opcode: CUSTOM0_OPCODE as u8 };
        it.validate()?;
        Ok(it)
    }

    /// Check field ranges.
    pub fn validate(&self) -> Result<()> {
        if self.funct7 > 0x7F {
            return Err(Error::Encoding(format!("funct7 out of range: {}", self.funct7)));
        }
        if self.funct3 > 0x7 {
            return Err(Error::Encoding(format!("funct3 out of range: {}", self.funct3)));
        }
        for (name, v) in [("rs1", self.rs1), ("rs2", self.rs2), ("rd", self.rd)] {
            if v > 31 {
                return Err(Error::Encoding(format!("{name} out of range: {v}")));
            }
        }
        if self.opcode > 0x7F {
            return Err(Error::Encoding(format!("opcode out of range: {}", self.opcode)));
        }
        Ok(())
    }

    /// Pack into a 32-bit instruction word.
    pub fn encode(&self) -> u32 {
        ((self.funct7 as u32) << 25)
            | ((self.rs2 as u32) << 20)
            | ((self.rs1 as u32) << 15)
            | ((self.funct3 as u32) << 12)
            | ((self.rd as u32) << 7)
            | self.opcode as u32
    }

    /// Unpack from a 32-bit instruction word.
    pub fn decode(word: u32) -> Self {
        RType {
            funct7: ((word >> 25) & 0x7F) as u8,
            rs2: ((word >> 20) & 0x1F) as u8,
            rs1: ((word >> 15) & 0x1F) as u8,
            funct3: ((word >> 12) & 0x7) as u8,
            rd: ((word >> 7) & 0x1F) as u8,
            opcode: (word & 0x7F) as u8,
        }
    }

    /// True if this instruction is routed to the CFU (`custom-0` space).
    pub fn is_cfu(&self) -> bool {
        self.opcode as u32 == CUSTOM0_OPCODE
    }

    /// The 10-bit CFU function id = `{funct7, funct3}` as CFU Playground
    /// presents it to the CFU.
    pub fn cfu_function_id(&self) -> u16 {
        ((self.funct7 as u16) << 3) | self.funct3 as u16
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    #[test]
    fn encode_decode_roundtrip_exhaustive_fields() {
        for funct7 in [0u8, 1, 0x55, 0x7F] {
            for funct3 in 0..8u8 {
                for reg in [0u8, 1, 15, 31] {
                    let it = RType::custom0(funct7, funct3, reg, reg, reg).unwrap();
                    assert_eq!(RType::decode(it.encode()), it);
                }
            }
        }
    }

    #[test]
    fn custom0_recognized() {
        let it = RType::custom0(0, 0, 1, 2, 3).unwrap();
        assert!(it.is_cfu());
        assert_eq!(it.encode() & 0x7F, CUSTOM0_OPCODE);
    }

    #[test]
    fn non_custom_not_cfu() {
        // `add x1, x2, x3` has opcode 0b0110011
        let add = RType { funct7: 0, rs2: 3, rs1: 2, funct3: 0, rd: 1, opcode: 0b011_0011 };
        assert!(!add.is_cfu());
    }

    #[test]
    fn known_encoding_value() {
        // funct7=1, rs2=4, rs1=3, funct3=2, rd=5, opcode=custom-0
        let it = RType::custom0(1, 2, 5, 3, 4).unwrap();
        let w = it.encode();
        assert_eq!(w, (1 << 25) | (4 << 20) | (3 << 15) | (2 << 12) | (5 << 7) | 0b000_1011);
    }

    #[test]
    fn function_id_packs_funct7_funct3() {
        let it = RType::custom0(0x7F, 0x7, 0, 0, 0).unwrap();
        assert_eq!(it.cfu_function_id(), 0x3FF);
        let it = RType::custom0(0x01, 0x0, 0, 0, 0).unwrap();
        assert_eq!(it.cfu_function_id(), 0x8);
    }

    #[test]
    fn out_of_range_rejected() {
        assert!(RType::custom0(0, 0, 32, 0, 0).is_err());
        assert!(RType::custom0(0, 8, 0, 0, 0).is_err());
        assert!(RType::custom0(0x80, 0, 0, 0, 0).is_err());
    }

    #[test]
    fn prop_roundtrip_random_words() {
        // Any 32-bit word decodes; re-encoding preserves all R-type fields.
        check(
            Config::default().cases(512),
            |r: &mut Pcg32| r.next_u32(),
            |&w| {
                let d = RType::decode(w);
                RType::decode(d.encode()) == d
            },
        );
    }
}
