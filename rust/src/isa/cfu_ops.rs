//! Concrete CFU instruction assignments for the four designs.
//!
//! The paper differentiates sub-operations by the LSB of `funct7`
//! (Section III-B1): `f0 = 0` selects the MAC operation, `f0 = 1` selects
//! the induction-variable increment. `funct3` selects the design family so
//! that all designs can coexist in one combined CFU build (as CFU
//! Playground does).

use super::rtype::RType;
use crate::error::Result;

/// Which accelerator design a kernel is compiled against.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DesignKind {
    /// Parallel 4×INT8 SIMD MAC, 1 cycle/block (Listing 1 baseline).
    BaselineSimd,
    /// Sequential single-multiplier MAC, always 4 cycles/block
    /// (the USSA comparison baseline, Section III-C1).
    BaselineSequential,
    /// Semi-Structured Sparsity Accelerator (Section III-B).
    Sssa,
    /// Unstructured Sparsity Accelerator (Section III-C).
    Ussa,
    /// Combined Sparsity Accelerator (Section III-D).
    Csa,
    /// N:M semi-structured accelerator: at most 2 non-zeros per
    /// 4-weight group (enforced at prepare time), with a fixed
    /// per-group lookahead probe that skips all-zero groups.
    NmSsa,
    /// 8×8 block-sparse (BSR) accelerator: an occupancy bitmap over
    /// 8-lane × 8-weight tiles lets the walk skip empty tiles
    /// wholesale (ACCEL-v1-style block skipping).
    Bsr,
    /// Bank-balanced sparsity accelerator: non-zeros are spread across
    /// K=4 word banks so the busiest bank bounds the lane's cycles
    /// (MCBBS-style load balancing).
    Bbs,
}

impl DesignKind {
    /// All designs, in presentation order (the paper's four families
    /// first, then the format extensions).
    pub const ALL: [DesignKind; 8] = [
        DesignKind::BaselineSimd,
        DesignKind::BaselineSequential,
        DesignKind::Sssa,
        DesignKind::Ussa,
        DesignKind::Csa,
        DesignKind::NmSsa,
        DesignKind::Bsr,
        DesignKind::Bbs,
    ];

    /// Human-readable name as used in the paper.
    pub fn name(&self) -> &'static str {
        match self {
            DesignKind::BaselineSimd => "baseline-simd",
            DesignKind::BaselineSequential => "baseline-seq",
            DesignKind::Sssa => "SSSA",
            DesignKind::Ussa => "USSA",
            DesignKind::Csa => "CSA",
            DesignKind::NmSsa => "NM-SSA",
            DesignKind::Bsr => "BSR",
            DesignKind::Bbs => "BBS",
        }
    }

    /// One-letter code for compact per-layer assignment labels
    /// (`hetero:sbc…` — see [`crate::isa::DesignAssignment::label`]).
    pub fn code(&self) -> char {
        match self {
            DesignKind::BaselineSimd => 'b',
            DesignKind::BaselineSequential => 'q',
            DesignKind::Sssa => 's',
            DesignKind::Ussa => 'u',
            DesignKind::Csa => 'c',
            DesignKind::NmSsa => 'n',
            DesignKind::Bsr => 'r',
            DesignKind::Bbs => 'k',
        }
    }

    /// Inverse of [`DesignKind::code`].
    pub fn from_code(c: char) -> Option<DesignKind> {
        DesignKind::ALL.into_iter().find(|d| d.code() == c)
    }

    /// Does the design consume lookahead-encoded (INT7) weights?
    pub fn uses_lookahead_encoding(&self) -> bool {
        matches!(self, DesignKind::Sssa | DesignKind::Csa)
    }

    /// Does the design skip zero weights inside a block (variable-cycle MAC)?
    pub fn variable_cycle_mac(&self) -> bool {
        matches!(self, DesignKind::Ussa | DesignKind::Csa)
    }

    /// Does preparing weights for this design *modify* them (beyond a
    /// lossless re-encoding)? True only for [`DesignKind::NmSsa`],
    /// which zeroes excess non-zeros to enforce the 2:4 group
    /// constraint — its outputs are bit-exact against its own prepared
    /// weights, but not against the original dense reference.
    pub fn enforces_structure(&self) -> bool {
        matches!(self, DesignKind::NmSsa)
    }

    /// `funct3` value assigned to the design family.
    pub fn funct3(&self) -> u8 {
        match self {
            DesignKind::BaselineSimd => 0,
            DesignKind::BaselineSequential => 1,
            DesignKind::Sssa => 2,
            DesignKind::Ussa => 3,
            DesignKind::Csa => 4,
            DesignKind::NmSsa => 5,
            DesignKind::Bsr => 6,
            DesignKind::Bbs => 7,
        }
    }

    /// Parse from CLI/config string.
    pub fn parse(s: &str) -> Option<DesignKind> {
        match s.to_ascii_lowercase().as_str() {
            "baseline" | "baseline-simd" | "simd" => Some(DesignKind::BaselineSimd),
            "baseline-seq" | "sequential" | "seq" => Some(DesignKind::BaselineSequential),
            "sssa" => Some(DesignKind::Sssa),
            "ussa" => Some(DesignKind::Ussa),
            "csa" => Some(DesignKind::Csa),
            "nm-ssa" | "nmssa" | "nm" => Some(DesignKind::NmSsa),
            "bsr" | "block" => Some(DesignKind::Bsr),
            "bbs" | "bank" => Some(DesignKind::Bbs),
            _ => None,
        }
    }
}

impl std::fmt::Display for DesignKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// CFU sub-operations across the designs, as named in the paper's
/// listings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CfuOpcode {
    /// `cfu_simd_mac` — 4×(INT8×INT8) parallel MAC (Listing 1).
    CfuSimdMac,
    /// Sequential 4-cycle single-multiplier MAC (USSA baseline).
    CfuSeqMac,
    /// `sssa_mac` — 4×(INT7×INT8) parallel MAC on encoded weights.
    SssaMac,
    /// `sssa_inc_indvar` — lookahead-driven induction-variable increment.
    SssaIncIndvar,
    /// `ussa_vcmac` — variable-cycle sequential MAC (INT8 weights).
    UssaVcMac,
    /// `csa_vcmac` — variable-cycle sequential MAC (INT7 encoded weights).
    CsaVcMac,
    /// `csa_inc_indvar` — same behaviour as `sssa_inc_indvar`.
    CsaIncIndvar,
    /// `nm_mac` — 4×(INT8×INT8) MAC over a 2:4-enforced weight group.
    NmMac,
    /// `nm_lookahead` — fixed-cycle group probe: `rd = 1` iff the
    /// weight group has any non-zero (the walk skips all-zero groups).
    NmLookahead,
    /// `bsr_mac` — 4×(INT8×INT8) MAC inside an occupied 8×8 block.
    BsrMac,
    /// `bbs_mac` — 4×(INT8×INT8) MAC on a bank-resident weight word.
    BbsMac,
}

impl CfuOpcode {
    /// Assembly-level mnemonic from the paper.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            CfuOpcode::CfuSimdMac => "cfu_simd_mac",
            CfuOpcode::CfuSeqMac => "cfu_seq_mac",
            CfuOpcode::SssaMac => "sssa_mac",
            CfuOpcode::SssaIncIndvar => "sssa_inc_indvar",
            CfuOpcode::UssaVcMac => "ussa_vcmac",
            CfuOpcode::CsaVcMac => "csa_vcmac",
            CfuOpcode::CsaIncIndvar => "csa_inc_indvar",
            CfuOpcode::NmMac => "nm_mac",
            CfuOpcode::NmLookahead => "nm_lookahead",
            CfuOpcode::BsrMac => "bsr_mac",
            CfuOpcode::BbsMac => "bbs_mac",
        }
    }

    /// Design family this op belongs to.
    pub fn design(&self) -> DesignKind {
        match self {
            CfuOpcode::CfuSimdMac => DesignKind::BaselineSimd,
            CfuOpcode::CfuSeqMac => DesignKind::BaselineSequential,
            CfuOpcode::SssaMac | CfuOpcode::SssaIncIndvar => DesignKind::Sssa,
            CfuOpcode::UssaVcMac => DesignKind::Ussa,
            CfuOpcode::CsaVcMac | CfuOpcode::CsaIncIndvar => DesignKind::Csa,
            CfuOpcode::NmMac | CfuOpcode::NmLookahead => DesignKind::NmSsa,
            CfuOpcode::BsrMac => DesignKind::Bsr,
            CfuOpcode::BbsMac => DesignKind::Bbs,
        }
    }

    /// `funct7` value: LSB (`f0`) distinguishes MAC (0) from
    /// `inc_indvar` (1), per Section III-B1.
    pub fn funct7(&self) -> u8 {
        match self {
            CfuOpcode::CfuSimdMac
            | CfuOpcode::CfuSeqMac
            | CfuOpcode::SssaMac
            | CfuOpcode::UssaVcMac
            | CfuOpcode::CsaVcMac
            | CfuOpcode::NmMac
            | CfuOpcode::BsrMac
            | CfuOpcode::BbsMac => 0b0000000,
            CfuOpcode::SssaIncIndvar | CfuOpcode::CsaIncIndvar | CfuOpcode::NmLookahead => {
                0b0000001
            }
        }
    }

    /// Encode this op as a full `custom-0` R-type instruction over
    /// registers `(rd, rs1, rs2)`.
    pub fn instruction(&self, rd: u8, rs1: u8, rs2: u8) -> Result<RType> {
        RType::custom0(self.funct7(), self.design().funct3(), rd, rs1, rs2)
    }

    /// Decode a `custom-0` instruction back into the CFU op it selects.
    pub fn from_instruction(it: &RType) -> Option<CfuOpcode> {
        if !it.is_cfu() {
            return None;
        }
        let inc = it.funct7 & 1 == 1;
        match (it.funct3, inc) {
            (0, false) => Some(CfuOpcode::CfuSimdMac),
            (1, false) => Some(CfuOpcode::CfuSeqMac),
            (2, false) => Some(CfuOpcode::SssaMac),
            (2, true) => Some(CfuOpcode::SssaIncIndvar),
            (3, false) => Some(CfuOpcode::UssaVcMac),
            (4, false) => Some(CfuOpcode::CsaVcMac),
            (4, true) => Some(CfuOpcode::CsaIncIndvar),
            (5, false) => Some(CfuOpcode::NmMac),
            (5, true) => Some(CfuOpcode::NmLookahead),
            (6, false) => Some(CfuOpcode::BsrMac),
            (7, false) => Some(CfuOpcode::BbsMac),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL_OPS: [CfuOpcode; 11] = [
        CfuOpcode::CfuSimdMac,
        CfuOpcode::CfuSeqMac,
        CfuOpcode::SssaMac,
        CfuOpcode::SssaIncIndvar,
        CfuOpcode::UssaVcMac,
        CfuOpcode::CsaVcMac,
        CfuOpcode::CsaIncIndvar,
        CfuOpcode::NmMac,
        CfuOpcode::NmLookahead,
        CfuOpcode::BsrMac,
        CfuOpcode::BbsMac,
    ];

    #[test]
    fn op_instruction_roundtrip() {
        for op in ALL_OPS {
            let it = op.instruction(1, 2, 3).unwrap();
            assert_eq!(CfuOpcode::from_instruction(&it), Some(op), "{}", op.mnemonic());
        }
    }

    #[test]
    fn funct7_lsb_selects_incindvar() {
        assert_eq!(CfuOpcode::SssaIncIndvar.funct7() & 1, 1);
        assert_eq!(CfuOpcode::SssaMac.funct7() & 1, 0);
        assert_eq!(CfuOpcode::CsaIncIndvar.funct7() & 1, 1);
        assert_eq!(CfuOpcode::CsaVcMac.funct7() & 1, 0);
        assert_eq!(CfuOpcode::NmLookahead.funct7() & 1, 1);
        assert_eq!(CfuOpcode::NmMac.funct7() & 1, 0);
    }

    #[test]
    fn design_properties() {
        assert!(DesignKind::Sssa.uses_lookahead_encoding());
        assert!(DesignKind::Csa.uses_lookahead_encoding());
        assert!(!DesignKind::Ussa.uses_lookahead_encoding());
        assert!(DesignKind::Ussa.variable_cycle_mac());
        assert!(DesignKind::Csa.variable_cycle_mac());
        assert!(!DesignKind::Sssa.variable_cycle_mac());
        assert!(!DesignKind::BaselineSimd.variable_cycle_mac());
        // The format extensions consume plain INT8 words, not the
        // lookahead encoding, and use fixed-cycle MACs.
        for d in [DesignKind::NmSsa, DesignKind::Bsr, DesignKind::Bbs] {
            assert!(!d.uses_lookahead_encoding(), "{d}");
            assert!(!d.variable_cycle_mac(), "{d}");
        }
        assert!(DesignKind::NmSsa.enforces_structure());
        assert!(!DesignKind::Bsr.enforces_structure());
        assert!(!DesignKind::Bbs.enforces_structure());
    }

    #[test]
    fn design_parse_roundtrip() {
        for d in DesignKind::ALL {
            assert_eq!(DesignKind::parse(d.name()), Some(d));
        }
        assert_eq!(DesignKind::parse("nonsense"), None);
    }

    #[test]
    fn design_code_roundtrip_and_unique() {
        // `hetero:` labels and cache keys serialize designs by their
        // one-letter code; a collision or a non-round-tripping letter
        // would silently corrupt both.
        let mut seen = std::collections::HashSet::new();
        for d in DesignKind::ALL {
            assert!(seen.insert(d.code()), "code letter collision for {d}");
            assert_eq!(DesignKind::from_code(d.code()), Some(d), "{d}");
        }
        assert_eq!(DesignKind::from_code('z'), None);
    }

    #[test]
    fn non_cfu_instruction_decodes_to_none() {
        let add = RType { funct7: 0, rs2: 3, rs1: 2, funct3: 0, rd: 1, opcode: 0b011_0011 };
        assert_eq!(CfuOpcode::from_instruction(&add), None);
    }

    #[test]
    fn funct3_unique_per_design() {
        let mut seen = std::collections::HashSet::new();
        for d in DesignKind::ALL {
            assert!(seen.insert(d.funct3()), "funct3 collision for {d}");
        }
    }
}
