//! RISC-V instruction-set plumbing for the CFU interface.
//!
//! The paper drives every accelerator through the RISC-V *R-type*
//! `custom-0` instruction (Fig 3): `funct7 | rs2 | rs1 | funct3 | rd |
//! opcode`. [`rtype`] implements bit-exact encode/decode of that format,
//! and [`cfu_ops`] defines the concrete instruction assignments used by
//! the four CFU designs (baseline SIMD MAC, SSSA, USSA, CSA).
//! [`assignment`] lifts [`DesignKind`] to a per-MAC-layer
//! [`DesignAssignment`] — the unit the co-design explorer optimizes and
//! the heterogeneous execution path consumes.

pub mod assignment;
pub mod cfu_ops;
pub mod rtype;

pub use assignment::DesignAssignment;
pub use cfu_ops::{CfuOpcode, DesignKind};
pub use rtype::{RType, CUSTOM0_OPCODE};
