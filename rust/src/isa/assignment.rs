//! Per-layer accelerator assignment — the co-design unit of the
//! explorer.
//!
//! The paper evaluates each accelerator ([`DesignKind`]) uniformly over
//! a whole model, but the best design depends on each layer's sparsity
//! *structure*: block-sparse layers favour SSSA's lookahead skipping,
//! while layers whose weights need the full INT8 dynamic range cannot
//! use the INT7 lookahead designs at all without clamping. A
//! [`DesignAssignment`] captures that choice as a per-MAC-layer design
//! vector, and the whole execution stack (prepare → simulate → batch →
//! serve) is generic over it.
//!
//! ```
//! use sparse_riscv::isa::{DesignAssignment, DesignKind};
//!
//! // A uniform assignment behaves exactly like the plain design.
//! let uniform = DesignAssignment::parse("csa").unwrap();
//! assert_eq!(uniform.uniform_design(), Some(DesignKind::Csa));
//!
//! // A per-layer assignment cycles over the model's MAC layers.
//! let hetero = DesignAssignment::parse("sssa,simd").unwrap();
//! assert_eq!(hetero.design_for(0), DesignKind::Sssa);
//! assert_eq!(hetero.design_for(1), DesignKind::BaselineSimd);
//! assert_eq!(hetero.design_for(2), DesignKind::Sssa);
//! assert_eq!(hetero.label(), "hetero:sb");
//! ```

use super::cfu_ops::DesignKind;

/// Which accelerator design each MAC layer of a model runs on.
///
/// `Uniform` is the paper's original model-wide choice; `PerLayer` holds
/// one design per MAC layer (convolutions, fully-connected layers and
/// projection shortcuts, in graph order). A `PerLayer` vector shorter
/// than the model's MAC-layer count is *cycled* — `design_for(i)` reads
/// entry `i % len` — so compact specs like `"sssa,simd"` apply to any
/// model.
///
/// Equality/hashing are structural, and [`DesignAssignment::per_layer`]
/// canonicalizes an all-equal vector to `Uniform`, so a prepared-model
/// cache keyed by assignment never aliases two different weight
/// preparations (see `simulator::ModelKey`). Note the converse sharp
/// edge of cycling: `[s, b]` and its expansion `[s, b, s, b]` execute
/// identically on a 4-MAC-layer model but are *distinct* values — they
/// key separate (bit-identical) cache entries and do not satisfy the
/// engine's prepared-for check interchangeably. Pick one spelling per
/// model; [`DesignAssignment::expand`] produces the explicit form.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum DesignAssignment {
    /// One design for every MAC layer.
    Uniform(DesignKind),
    /// One design per MAC layer, cycled when shorter than the model.
    ///
    /// Prefer constructing through [`DesignAssignment::per_layer`] (or
    /// [`DesignAssignment::parse`]): building this variant directly
    /// skips canonicalization, so an all-equal vector compares unequal
    /// to its `Uniform` spelling and keys a duplicate (bit-identical)
    /// cache entry. An empty vector degrades to the SIMD baseline in
    /// [`DesignAssignment::design_for`].
    PerLayer(Vec<DesignKind>),
}

impl DesignAssignment {
    /// Uniform assignment.
    pub fn uniform(design: DesignKind) -> Self {
        DesignAssignment::Uniform(design)
    }

    /// Per-layer assignment. An empty vector or an all-equal vector
    /// canonicalizes to the equivalent `Uniform` form (empty falls back
    /// to the SIMD baseline), so structurally-identical assignments
    /// compare and hash equal.
    pub fn per_layer(designs: Vec<DesignKind>) -> Self {
        match designs.first() {
            None => DesignAssignment::Uniform(DesignKind::BaselineSimd),
            Some(&d0) if designs.iter().all(|&d| d == d0) => DesignAssignment::Uniform(d0),
            _ => DesignAssignment::PerLayer(designs),
        }
    }

    /// Design of MAC layer `mac_idx` (per-layer vectors are cycled; a
    /// directly-constructed empty vector degrades to the SIMD baseline,
    /// matching [`DesignAssignment::per_layer`]'s canonicalization).
    pub fn design_for(&self, mac_idx: usize) -> DesignKind {
        match self {
            DesignAssignment::Uniform(d) => *d,
            DesignAssignment::PerLayer(v) if v.is_empty() => DesignKind::BaselineSimd,
            DesignAssignment::PerLayer(v) => v[mac_idx % v.len()],
        }
    }

    /// The single design when uniform, `None` when heterogeneous.
    pub fn uniform_design(&self) -> Option<DesignKind> {
        match self {
            DesignAssignment::Uniform(d) => Some(*d),
            DesignAssignment::PerLayer(_) => None,
        }
    }

    /// True for the uniform (model-wide) form.
    pub fn is_uniform(&self) -> bool {
        matches!(self, DesignAssignment::Uniform(_))
    }

    /// The per-layer design vector expanded to `mac_layers` entries.
    pub fn expand(&self, mac_layers: usize) -> Vec<DesignKind> {
        (0..mac_layers).map(|i| self.design_for(i)).collect()
    }

    /// Distinct designs the assignment uses, in [`DesignKind::ALL`]
    /// order — the CFU inventory an FPGA build of this assignment must
    /// instantiate (see `analysis::codesign`).
    pub fn designs_used(&self) -> Vec<DesignKind> {
        DesignKind::ALL
            .into_iter()
            .filter(|d| match self {
                DesignAssignment::Uniform(u) => u == d,
                DesignAssignment::PerLayer(v) => v.contains(d),
            })
            .collect()
    }

    /// Compact label for reports and metric records: the design name
    /// when uniform, `hetero:` plus one [`DesignKind::code`] letter per
    /// layer otherwise (e.g. `hetero:sbc`).
    pub fn label(&self) -> String {
        match self {
            DesignAssignment::Uniform(d) => d.name().to_string(),
            DesignAssignment::PerLayer(v) => {
                let codes: String = v.iter().map(|d| d.code()).collect();
                format!("hetero:{codes}")
            }
        }
    }

    /// Round-trippable spec string accepted by [`DesignAssignment::parse`]
    /// (a comma-separated design-name list, or one name when uniform) —
    /// what `explore` prints for pasting into `serve --assignment`.
    pub fn spec(&self) -> String {
        match self {
            DesignAssignment::Uniform(d) => d.name().to_string(),
            DesignAssignment::PerLayer(v) => {
                v.iter().map(|d| d.name()).collect::<Vec<_>>().join(",")
            }
        }
    }

    /// Parse from a CLI/config string: a single design name (uniform), a
    /// comma-separated per-layer name list, or a `hetero:<codes>` label
    /// as printed by [`DesignAssignment::label`]. Case-insensitive, like
    /// [`DesignKind::parse`].
    pub fn parse(s: &str) -> Option<Self> {
        let lower = s.trim().to_ascii_lowercase();
        let s = lower.as_str();
        if let Some(codes) = s.strip_prefix("hetero:") {
            let v: Option<Vec<DesignKind>> =
                codes.trim().chars().map(DesignKind::from_code).collect();
            return v.filter(|v| !v.is_empty()).map(DesignAssignment::per_layer);
        }
        if s.contains(',') {
            let v: Option<Vec<DesignKind>> =
                s.split(',').map(str::trim).filter(|t| !t.is_empty()).map(DesignKind::parse).collect();
            return v.filter(|v| !v.is_empty()).map(DesignAssignment::per_layer);
        }
        DesignKind::parse(s).map(DesignAssignment::Uniform)
    }
}

impl From<DesignKind> for DesignAssignment {
    fn from(d: DesignKind) -> Self {
        DesignAssignment::Uniform(d)
    }
}

impl std::fmt::Display for DesignAssignment {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_roundtrip() {
        for d in DesignKind::ALL {
            let a = DesignAssignment::uniform(d);
            assert!(a.is_uniform());
            assert_eq!(a.uniform_design(), Some(d));
            assert_eq!(a.design_for(0), d);
            assert_eq!(a.design_for(17), d);
            assert_eq!(DesignAssignment::parse(&a.spec()), Some(a.clone()));
            assert_eq!(DesignAssignment::parse(&a.label()), Some(a));
        }
    }

    #[test]
    fn per_layer_cycles_and_roundtrips() {
        let a = DesignAssignment::per_layer(vec![
            DesignKind::Sssa,
            DesignKind::BaselineSimd,
            DesignKind::Csa,
        ]);
        assert!(!a.is_uniform());
        assert_eq!(a.uniform_design(), None);
        assert_eq!(a.design_for(0), DesignKind::Sssa);
        assert_eq!(a.design_for(2), DesignKind::Csa);
        assert_eq!(a.design_for(3), DesignKind::Sssa); // cycled
        assert_eq!(a.expand(4).len(), 4);
        assert_eq!(a.label(), "hetero:sbc");
        assert_eq!(DesignAssignment::parse(&a.spec()), Some(a.clone()));
        assert_eq!(DesignAssignment::parse(&a.label()), Some(a));
    }

    #[test]
    fn all_equal_canonicalizes_to_uniform() {
        let a = DesignAssignment::per_layer(vec![DesignKind::Csa; 3]);
        assert_eq!(a, DesignAssignment::Uniform(DesignKind::Csa));
        // parse() goes through per_layer, so the comma form canonicalizes
        // too — "csa,csa" and "csa" are the same cache key.
        assert_eq!(DesignAssignment::parse("csa,csa"), Some(a));
        // Case-insensitive everywhere, including hetero codes.
        assert_eq!(
            DesignAssignment::parse("HETERO:SB"),
            DesignAssignment::parse("hetero:sb")
        );
        assert_eq!(
            DesignAssignment::parse("SSSA,SIMD"),
            DesignAssignment::parse("sssa,simd")
        );
        assert_eq!(DesignAssignment::parse(""), None);
        assert_eq!(DesignAssignment::parse("bogus"), None);
        assert_eq!(DesignAssignment::parse("sssa,bogus"), None);
    }

    #[test]
    fn assignments_differing_in_one_layer_are_unequal() {
        let a = DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::Ussa]);
        let b = DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::Csa]);
        assert_ne!(a, b);
        use std::collections::HashSet;
        let set: HashSet<DesignAssignment> = [a.clone(), b.clone(), a.clone()].into();
        assert_eq!(set.len(), 2);
    }

    #[test]
    fn designs_used_dedups_in_all_order() {
        let a = DesignAssignment::per_layer(vec![
            DesignKind::Csa,
            DesignKind::BaselineSimd,
            DesignKind::Csa,
            DesignKind::Sssa,
        ]);
        assert_eq!(
            a.designs_used(),
            vec![DesignKind::BaselineSimd, DesignKind::Sssa, DesignKind::Csa]
        );
        assert_eq!(
            DesignAssignment::uniform(DesignKind::Ussa).designs_used(),
            vec![DesignKind::Ussa]
        );
    }
}
