//! Register-word packing: four INT8 lanes in one 32-bit operand.
//!
//! The CFU receives operands through two 32-bit registers (`rs1`, `rs2`).
//! Byte *i* of the word carries lane *i* (`w0` in bits 7..0, `w1` in
//! 15..8, …), so the lookahead bits of an encoded block sit at
//! `b0, b8, b16, b24` exactly as in Figure 4.

/// Pack four i8 lanes into a u32 (lane i → byte i, little-endian order).
#[inline]
pub fn pack4_i8(lanes: &[i8; 4]) -> u32 {
    u32::from_le_bytes([lanes[0] as u8, lanes[1] as u8, lanes[2] as u8, lanes[3] as u8])
}

/// Pack a 4-byte slice into a u32 (lane i → byte i). The slice form costs
/// one bounds check at the call site instead of the four indexed loads of
/// `pack4_i8(&[x[p], x[p+1], x[p+2], x[p+3]])` — the kernels' inner loops
/// pack every input word through this.
///
/// Panics if `lanes.len() != 4` (kernel lane lengths are multiples of 4
/// by construction).
#[inline]
pub fn pack4_le(lanes: &[i8]) -> u32 {
    let arr: [i8; 4] = lanes.try_into().expect("pack4_le needs exactly 4 bytes");
    pack4_i8(&arr)
}

/// Unpack a u32 into four i8 lanes.
#[inline]
pub fn unpack4_i8(word: u32) -> [i8; 4] {
    let b = word.to_le_bytes();
    [b[0] as i8, b[1] as i8, b[2] as i8, b[3] as i8]
}

/// Extract the four lookahead bits (`b0, b8, b16, b24`) of a packed
/// encoded-weight word into a 4-bit skip counter — the hardware path of
/// Figure 4.
#[inline]
pub fn pack4_u32_skip_bits(word: u32) -> u8 {
    ((word & 1) | ((word >> 8) & 1) << 1 | ((word >> 16) & 1) << 2 | ((word >> 24) & 1) << 3)
        as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoding::lookahead::{decode_skip, encode_last_bits};
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    #[test]
    fn pack_unpack_roundtrip() {
        let lanes = [-1i8, 0, 63, -64];
        assert_eq!(unpack4_i8(pack4_i8(&lanes)), lanes);
    }

    #[test]
    fn byte_positions() {
        let w = pack4_i8(&[1, 2, 3, 4]);
        assert_eq!(w, 0x04_03_02_01);
    }

    #[test]
    fn skip_bits_match_software_decode() {
        for skip in 0..=15u8 {
            let mut block = [7i8, -3, 0, 21];
            encode_last_bits(&mut block, skip).unwrap();
            let word = pack4_i8(&block);
            assert_eq!(pack4_u32_skip_bits(word), skip);
            assert_eq!(pack4_u32_skip_bits(word), decode_skip(&block));
        }
    }

    #[test]
    fn pack4_le_matches_pack4_i8() {
        let xs: Vec<i8> = vec![-1, 0, 63, -64, 17, -128, 127, 5];
        for p in 0..=4 {
            let arr: [i8; 4] = xs[p..p + 4].try_into().unwrap();
            assert_eq!(pack4_le(&xs[p..p + 4]), pack4_i8(&arr));
        }
    }

    #[test]
    #[should_panic]
    fn pack4_le_rejects_short_slices() {
        pack4_le(&[1i8, 2, 3]);
    }

    #[test]
    fn prop_pack_roundtrip() {
        check(
            Config::default().cases(256),
            |r: &mut Pcg32| r.next_u32(),
            |&w| pack4_i8(&unpack4_i8(w)) == w,
        );
    }
}
