//! INT7 weight range (`[-64, 63]`).
//!
//! The paper (Section III-B): *"The dynamic range of INT8 weights is
//! limited to [-64, 63] so as to not use the most significant bit after
//! the signed bit, effectively simulating INT7 precision."* Table II shows
//! this costs no accuracy on the considered applications.

/// Minimum INT7 value.
pub const INT7_MIN: i8 = -64;
/// Maximum INT7 value.
pub const INT7_MAX: i8 = 63;

/// Is the weight already within INT7 dynamic range?
#[inline]
pub fn is_int7(w: i8) -> bool {
    (INT7_MIN..=INT7_MAX).contains(&w)
}

/// Clamp an INT8 weight into INT7 range.
#[inline]
pub fn clamp_int7(w: i8) -> i8 {
    w.clamp(INT7_MIN, INT7_MAX)
}

/// Clamp a whole slice in place; returns how many weights were clamped
/// (useful to report quantization impact).
pub fn clamp_slice_int7(ws: &mut [i8]) -> usize {
    let mut clamped = 0;
    for w in ws {
        if !is_int7(*w) {
            *w = clamp_int7(*w);
            clamped += 1;
        }
    }
    clamped
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds() {
        assert!(is_int7(-64));
        assert!(is_int7(63));
        assert!(!is_int7(-65));
        assert!(!is_int7(64));
        assert!(!is_int7(i8::MIN));
        assert!(!is_int7(i8::MAX));
    }

    #[test]
    fn clamp_values() {
        assert_eq!(clamp_int7(100), 63);
        assert_eq!(clamp_int7(-100), -64);
        assert_eq!(clamp_int7(5), 5);
        assert_eq!(clamp_int7(0), 0);
    }

    #[test]
    fn clamp_slice_counts() {
        let mut ws = [127i8, -128, 0, 63, -64, 64, -65];
        let n = clamp_slice_int7(&mut ws);
        assert_eq!(n, 4);
        assert_eq!(ws, [63, -64, 0, 63, -64, 63, -64]);
    }
}
