//! Algorithms 1 & 2: lookahead encoding of CNN kernel weights.
//!
//! Bit layout of an **encoded** weight byte (Figure 6):
//!
//! ```text
//!   bit:   7     6   5   4   3   2   1     0
//!        sign   b5  b4  b3  b2  b1  b0   skip
//! ```
//!
//! where `sign b5..b0` is exactly the 7-bit two's-complement
//! representation of the original INT7 weight, and `skip` is one bit of
//! the 4-bit `skip_blocks` counter (bit *i* of the counter goes to the
//! LSB of weight *i* in the block, per Figure 6). The hardware therefore
//! recovers the weight as `encoded >> 1` (arithmetic, 7-bit) and the skip
//! counter from the four block LSBs.

use super::int7::is_int7;
use crate::error::{Error, Result};

/// A block is 4 weights (one 32-bit register operand).
pub const BLOCK: usize = 4;

/// Maximum number of succeeding all-zero blocks encodable in 4 bits.
pub const MAX_SKIP_BLOCKS: u8 = 15;

/// `checkBlkSkip` of Algorithm 1: is the 4-weight block all zero?
#[inline]
pub fn block_is_zero(block: &[i8]) -> bool {
    debug_assert_eq!(block.len(), BLOCK);
    block.iter().all(|&w| w == 0)
}

/// Algorithm 2 `encodeLastBits`, bit-for-bit: embed the 4-bit
/// `skip_blocks` value into a block of four INT7 weights.
///
/// Returns an error if any weight is outside INT7 range (the model must
/// be INT7-quantized *before* encoding; see [`super::int7`]).
pub fn encode_last_bits(weights: &mut [i8; BLOCK], skip_blocks: u8) -> Result<()> {
    if skip_blocks > MAX_SKIP_BLOCKS {
        return Err(Error::Encoding(format!("skip_blocks {skip_blocks} > {MAX_SKIP_BLOCKS}")));
    }
    for (i, w) in weights.iter_mut().enumerate() {
        if !is_int7(*w) {
            return Err(Error::Encoding(format!(
                "weight {w} at lane {i} outside INT7 range [-64, 63]"
            )));
        }
        let bits = *w as u8;
        // Isolate the sign bit.
        let sign_bit = (bits >> 7) & 0b1;
        // Extract skip bit i.
        let skip_bit = (skip_blocks >> i) & 0b1;
        // Remove the MSB after the sign bit.
        let mut v = bits & 0b1011_1111;
        // Shift bits one position to the left.
        v = (v << 1) & 0b0111_1110;
        // Insert skip bit.
        v |= skip_bit;
        // Restore the sign bit.
        v |= sign_bit << 7;
        *w = v as i8;
    }
    Ok(())
}

/// Hardware-side weight decode: bits `[7:1]` of the encoded byte,
/// sign-extended from 7 bits — i.e. an arithmetic shift right by one.
#[inline]
pub fn decode_weight(encoded: i8) -> i8 {
    encoded >> 1
}

/// Hardware-side skip decode: gather the LSB of each of the four encoded
/// weights, bit *i* from weight *i* (`b0, b8, b16, b24` of the packed
/// register word).
#[inline]
pub fn decode_skip(block: &[i8; BLOCK]) -> u8 {
    let mut skip = 0u8;
    for (i, &w) in block.iter().enumerate() {
        skip |= ((w as u8) & 1) << i;
    }
    skip
}

/// Compute the skip counter for the block starting at `block_idx` within
/// `row` (a lane of `C` weights walked in steps of 4): the number of
/// immediately-following all-zero blocks, saturated at
/// [`MAX_SKIP_BLOCKS`]. Lines 5–14 of Algorithm 1.
pub fn skip_of_block(row: &[i8], block_idx: usize) -> u8 {
    skip_of_block_with_max(row, block_idx, MAX_SKIP_BLOCKS)
}

/// [`skip_of_block`] with a configurable saturation limit — the design
/// ablation over the lookahead field width (a w-bit field saturates at
/// `2^w - 1`; the paper fixes w = 4).
pub fn skip_of_block_with_max(row: &[i8], block_idx: usize, max_skip: u8) -> u8 {
    let c = row.len();
    let mut i_nxt = (block_idx + 1) * BLOCK;
    let mut skip_blocks = 0u8;
    while i_nxt + BLOCK <= c && skip_blocks < max_skip {
        if block_is_zero(&row[i_nxt..i_nxt + BLOCK]) {
            skip_blocks += 1;
            i_nxt += BLOCK;
        } else {
            break;
        }
    }
    skip_blocks
}

/// Number of blocks the SSSA while-loop visits in `row` when the skip
/// field saturates at `max_skip` (ablation helper).
pub fn visited_blocks_with_max(row: &[i8], max_skip: u8) -> usize {
    let nblocks = row.len() / BLOCK;
    let mut visited = 0usize;
    let mut b = 0usize;
    while b < nblocks {
        visited += 1;
        b += 1 + skip_of_block_with_max(row, b, max_skip) as usize;
    }
    visited
}

/// The block indices the SSSA/CSA while-loop actually visits in `row`
/// (4-bit skip field) — the walk the compiled lane schedules materialize
/// at prepare time. Computed from the *decoded* weights, so it is the
/// software-side oracle for the hardware walk over packed skip bits.
pub fn visited_indices(row: &[i8]) -> Vec<usize> {
    let nblocks = row.len() / BLOCK;
    let mut out = Vec::new();
    let mut b = 0usize;
    while b < nblocks {
        out.push(b);
        b += 1 + skip_of_block(row, b) as usize;
    }
    out
}

/// Result of encoding a weight tensor: encoded bytes plus bookkeeping.
#[derive(Debug, Clone)]
pub struct EncodedLanes {
    /// Encoded weights, same layout as the input.
    pub encoded: Vec<i8>,
    /// Lane (row) length in weights — the input-channel extent `C`.
    pub lane_len: usize,
    /// Total number of 4-weight blocks.
    pub total_blocks: usize,
    /// Number of all-zero blocks (skippable work).
    pub zero_blocks: usize,
    /// Number of blocks actually *visited* by the SSSA while-loop
    /// (non-zero blocks + zero blocks not covered by any lookahead,
    /// e.g. leading zero blocks or runs longer than 15).
    pub visited_blocks: usize,
}

impl EncodedLanes {
    /// Fraction of blocks that are all-zero (the semi-structured
    /// sparsity ratio at block granularity).
    pub fn block_sparsity(&self) -> f64 {
        if self.total_blocks == 0 {
            return 0.0;
        }
        self.zero_blocks as f64 / self.total_blocks as f64
    }
}

/// Algorithm 1 over a flat weight buffer interpreted as rows ("lanes") of
/// length `lane_len` — one lane per (filter, kh, kw) position walked along
/// input channels. `lane_len` must be a multiple of 4.
///
/// Every block (including all-zero ones) receives its lookahead code; the
/// decoded weight of an all-zero block is still zero because only the
/// LSBs change.
pub fn encode_lanes(weights: &[i8], lane_len: usize) -> Result<EncodedLanes> {
    if lane_len == 0 || lane_len % BLOCK != 0 {
        return Err(Error::Encoding(format!("lane_len {lane_len} not a positive multiple of 4")));
    }
    if weights.len() % lane_len != 0 {
        return Err(Error::Encoding(format!(
            "weight buffer length {} not divisible by lane_len {lane_len}",
            weights.len()
        )));
    }
    let mut encoded = weights.to_vec();
    let blocks_per_lane = lane_len / BLOCK;
    let mut total_blocks = 0;
    let mut zero_blocks = 0;
    let mut visited_blocks = 0;
    for lane in encoded.chunks_mut(lane_len) {
        // First pass: compute skip counters from the *original* values.
        let skips: Vec<u8> = (0..blocks_per_lane).map(|b| skip_of_block(lane, b)).collect();
        // Count visited blocks by simulating the while-loop walk.
        let mut b = 0usize;
        while b < blocks_per_lane {
            visited_blocks += 1;
            b += 1 + skips[b] as usize;
        }
        // Second pass: encode.
        for (b, chunk) in lane.chunks_mut(BLOCK).enumerate() {
            total_blocks += 1;
            if block_is_zero(chunk) {
                zero_blocks += 1;
            }
            let mut arr: [i8; BLOCK] = chunk.try_into().unwrap();
            encode_last_bits(&mut arr, skips[b])?;
            chunk.copy_from_slice(&arr);
        }
    }
    Ok(EncodedLanes { encoded, lane_len, total_blocks, zero_blocks, visited_blocks })
}

/// Decode an encoded buffer back to INT7 weights (inverse of the weight
/// part of [`encode_lanes`]; skip bits are discarded).
pub fn decode_lanes(encoded: &[i8]) -> Vec<i8> {
    encoded.iter().map(|&w| decode_weight(w)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Config};
    use crate::util::Pcg32;

    #[test]
    fn encode_decode_single_weights() {
        for w in -64i8..=63 {
            let mut block = [w, 0, 0, 0];
            encode_last_bits(&mut block, 0b1010).unwrap();
            assert_eq!(decode_weight(block[0]), w, "weight {w}");
            assert_eq!(decode_skip(&block), 0b1010);
        }
    }

    #[test]
    fn paper_figure6_bit_layout() {
        // Sign bit preserved at 7, value shifted to [6:1], skip at 0.
        let mut block = [-3i8, 63, -64, 0];
        encode_last_bits(&mut block, 0b0101).unwrap();
        // -3 = 0b11111101 → enc = sign1 | (111101)<<1... decoded must be -3.
        assert_eq!(decode_weight(block[0]), -3);
        assert_eq!((block[0] as u8) & 1, 1); // skip bit 0 = 1
        assert_eq!(decode_weight(block[1]), 63);
        assert_eq!((block[1] as u8) & 1, 0); // skip bit 1 = 0
        assert_eq!(decode_weight(block[2]), -64);
        assert_eq!((block[2] as u8) & 1, 1); // skip bit 2 = 1
        assert_eq!(decode_weight(block[3]), 0);
        assert_eq!((block[3] as u8) & 1, 0); // skip bit 3 = 0
    }

    #[test]
    fn int8_out_of_range_rejected() {
        let mut block = [64i8, 0, 0, 0];
        assert!(encode_last_bits(&mut block, 0).is_err());
        let mut block = [-65i8, 0, 0, 0];
        assert!(encode_last_bits(&mut block, 0).is_err());
    }

    #[test]
    fn skip_too_large_rejected() {
        let mut block = [0i8; 4];
        assert!(encode_last_bits(&mut block, 16).is_err());
    }

    #[test]
    fn skip_of_block_counts_runs() {
        // blocks: [nz] [z] [z] [nz] [z]
        let row: Vec<i8> = [
            [1i8, 0, 0, 0],
            [0, 0, 0, 0],
            [0, 0, 0, 0],
            [2, 0, 0, 0],
            [0, 0, 0, 0],
        ]
        .concat();
        assert_eq!(skip_of_block(&row, 0), 2);
        assert_eq!(skip_of_block(&row, 1), 1); // zero block also gets its lookahead
        assert_eq!(skip_of_block(&row, 3), 1);
        assert_eq!(skip_of_block(&row, 4), 0); // last block: nothing follows
    }

    #[test]
    fn skip_saturates_at_15() {
        // 1 non-zero block followed by 20 zero blocks
        let mut row = vec![0i8; 21 * 4];
        row[0] = 7;
        assert_eq!(skip_of_block(&row, 0), 15);
    }

    #[test]
    fn figure5_example() {
        // Fig 5: blocks [nz][z][z][nz][z][nz][z-ish]... codes 2,-,-,1,-,0/1...
        // block1=(4,7,3,1) nz, block2/3 zero, block4 nz, block5 zero,
        // block6=(13,0,12,4) nz, block7=(0,1,0,0) nz.
        let row: Vec<i8> = [
            [4i8, 7, 3, 1],
            [0, 0, 0, 0],
            [0, 0, 0, 0],
            [11, 7, 12, 4],
            [0, 0, 0, 0],
            [13, 0, 12, 4],
            [0, 1, 0, 0],
        ]
        .concat();
        assert_eq!(skip_of_block(&row, 0), 2);
        assert_eq!(skip_of_block(&row, 3), 1);
        assert_eq!(skip_of_block(&row, 5), 0);
        assert_eq!(skip_of_block(&row, 6), 0);
    }

    #[test]
    fn encode_lanes_roundtrip_and_counts() {
        let lane: Vec<i8> = [
            [1i8, -2, 3, -4],
            [0, 0, 0, 0],
            [0, 0, 0, 0],
            [5, 0, -6, 0],
        ]
        .concat();
        let enc = encode_lanes(&lane, 16).unwrap();
        assert_eq!(enc.total_blocks, 4);
        assert_eq!(enc.zero_blocks, 2);
        // walk: block0 (skip 2) → block3 → done ⇒ 2 visited
        assert_eq!(enc.visited_blocks, 2);
        let dec = decode_lanes(&enc.encoded);
        assert_eq!(dec, lane);
    }

    #[test]
    fn leading_zero_blocks_are_visited() {
        // [z][z][nz][nz] — the while loop must visit the leading zero
        // block (it carries its own lookahead to hop over the second).
        let lane: Vec<i8> = [[0i8, 0, 0, 0], [0, 0, 0, 0], [1, 0, 0, 0], [2, 0, 0, 0]].concat();
        let enc = encode_lanes(&lane, 16).unwrap();
        // walk: block0 (zero, skip=1) → block2 → block3 ⇒ 3 visited
        assert_eq!(enc.visited_blocks, 3);
        // decoded zero block is still zero ⇒ MAC contributes nothing
        let dec = decode_lanes(&enc.encoded);
        assert_eq!(&dec[0..8], &[0i8; 8]);
    }

    #[test]
    fn visited_indices_match_walk_count() {
        // blocks: [nz] [z] [z] [nz] [z] — walk: 0 (skip 2) → 3 (skip 1) → end
        let row: Vec<i8> = [
            [1i8, 0, 0, 0],
            [0, 0, 0, 0],
            [0, 0, 0, 0],
            [2, 0, 0, 0],
            [0, 0, 0, 0],
        ]
        .concat();
        assert_eq!(visited_indices(&row), vec![0, 3]);
        assert_eq!(visited_indices(&row).len(), visited_blocks_with_max(&row, MAX_SKIP_BLOCKS));
        // leading zero blocks are themselves visited
        let row2: Vec<i8> =
            [[0i8, 0, 0, 0], [0, 0, 0, 0], [1, 0, 0, 0], [2, 0, 0, 0]].concat();
        assert_eq!(visited_indices(&row2), vec![0, 2, 3]);
    }

    #[test]
    fn bad_lane_len_rejected() {
        assert!(encode_lanes(&[0i8; 8], 3).is_err());
        assert!(encode_lanes(&[0i8; 8], 0).is_err());
        assert!(encode_lanes(&[0i8; 10], 4).is_err());
    }

    #[test]
    fn prop_roundtrip_random_int7_lanes() {
        check(
            Config::default().cases(128),
            |r: &mut Pcg32| {
                let blocks = 1 + r.below(16) as usize;
                (0..blocks * 4)
                    .map(|_| {
                        if r.bernoulli(0.6) {
                            0i32
                        } else {
                            r.range_i32(-64, 63)
                        }
                    })
                    .collect::<Vec<i32>>()
            },
            |lane| {
                let ws: Vec<i8> = lane.iter().map(|&w| w as i8).collect();
                let enc = encode_lanes(&ws, ws.len()).unwrap();
                // 1) weights decode exactly
                if decode_lanes(&enc.encoded) != ws {
                    return false;
                }
                // 2) every block's decoded skip equals skip_of_block
                for b in 0..ws.len() / 4 {
                    let arr: [i8; 4] = enc.encoded[b * 4..b * 4 + 4].try_into().unwrap();
                    if decode_skip(&arr) != skip_of_block(&ws, b) {
                        return false;
                    }
                }
                // 3) the while-loop walk never lands past the end and
                //    covers every non-zero block
                let blocks = ws.len() / 4;
                let mut visited = vec![false; blocks];
                let mut b = 0usize;
                while b < blocks {
                    visited[b] = true;
                    let arr: [i8; 4] = enc.encoded[b * 4..b * 4 + 4].try_into().unwrap();
                    b += 1 + decode_skip(&arr) as usize;
                }
                (0..blocks).all(|b| {
                    visited[b] || block_is_zero(&ws[b * 4..b * 4 + 4])
                })
            },
        );
    }
}
