//! Lookahead weight encoding (the paper's Algorithms 1 & 2).
//!
//! Semi-structured (4:4 block) sparsity is exploited by *pre-encoding* the
//! number of consecutive all-zero weight blocks after each block into the
//! block's own weights: each INT8 weight gives up its post-sign MSB
//! (restricting the dynamic range to INT7, `[-64, 63]`), all lower bits
//! shift left by one, and the freed LSB carries one bit of the 4-bit
//! `skip_blocks` counter (0–15). At runtime the SSSA/CSA CFU extracts the
//! four LSBs of a packed 4-weight word to advance the inner-loop induction
//! variable — zero software overhead.
//!
//! - [`int7`] — INT7 range checks and clamping,
//! - [`lookahead`] — encode (Alg 1 & 2), decode, and verification,
//! - [`pack`] — 4×i8 ↔ u32 register-word packing (byte i ↔ bits 8i+7..8i).
//!
//! Encode → decode roundtrip of one lane (a non-zero block, two zero
//! blocks to skip, a non-zero block):
//!
//! ```
//! use sparse_riscv::encoding::lookahead::{decode_skip, encode_lanes};
//! use sparse_riscv::encoding::pack::{pack4_le, pack4_u32_skip_bits};
//!
//! let ws: Vec<i8> = [[1i8, 2, 3, 4], [0; 4], [0; 4], [5, 6, 7, 8]].concat();
//! let enc = encode_lanes(&ws, 16).unwrap();
//! assert_eq!(enc.total_blocks, 4);
//! assert_eq!(enc.zero_blocks, 2);
//! // Block 0's lookahead bits say "skip the next 2 blocks" — readable
//! // from the software decoder and from the packed-word hardware path.
//! let b0: [i8; 4] = enc.encoded[0..4].try_into().unwrap();
//! assert_eq!(decode_skip(&b0), 2);
//! assert_eq!(pack4_u32_skip_bits(pack4_le(&enc.encoded[0..4])), 2);
//! ```

pub mod int7;
pub mod lookahead;
pub mod pack;

pub use int7::{clamp_int7, is_int7, INT7_MAX, INT7_MIN};
pub use lookahead::{
    decode_skip, decode_weight, encode_lanes, encode_last_bits, skip_of_block, EncodedLanes,
    MAX_SKIP_BLOCKS,
};
pub use pack::{pack4_i8, pack4_u32_skip_bits, unpack4_i8};
