//! Sparsity measurement.

/// Fraction of zero elements (the paper's "sparsity ratio x").
pub fn element_sparsity(ws: &[i8]) -> f64 {
    if ws.is_empty() {
        return 0.0;
    }
    ws.iter().filter(|&&w| w == 0).count() as f64 / ws.len() as f64
}

/// Fraction of all-zero 4-element blocks (4:4 semi-structured sparsity),
/// blocks taken along lanes of length `lane_len`.
pub fn block_sparsity(ws: &[i8], lane_len: usize) -> f64 {
    assert!(lane_len > 0 && lane_len % 4 == 0, "lane_len must be positive multiple of 4");
    assert_eq!(ws.len() % lane_len, 0, "buffer not divisible by lane_len");
    let mut total = 0usize;
    let mut zero = 0usize;
    for lane in ws.chunks(lane_len) {
        for block in lane.chunks(4) {
            total += 1;
            if block.iter().all(|&w| w == 0) {
                zero += 1;
            }
        }
    }
    if total == 0 {
        0.0
    } else {
        zero as f64 / total as f64
    }
}

/// Full sparsity profile of one weight tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct SparsityProfile {
    /// Element-level sparsity (x in the paper).
    pub element: f64,
    /// Block-level (4:4) sparsity.
    pub block: f64,
    /// Element sparsity *within* non-zero blocks — what USSA/CSA's
    /// variable-cycle MAC exploits after SSSA's block skipping.
    pub intra_block: f64,
    /// Total elements.
    pub elements: usize,
    /// Total 4-element blocks.
    pub blocks: usize,
}

impl SparsityProfile {
    /// Measure a buffer of lanes.
    pub fn measure(ws: &[i8], lane_len: usize) -> SparsityProfile {
        assert!(lane_len > 0 && lane_len % 4 == 0);
        assert_eq!(ws.len() % lane_len, 0);
        let mut blocks = 0usize;
        let mut zero_blocks = 0usize;
        let mut zeros = 0usize;
        let mut nz_block_zeros = 0usize;
        let mut nz_block_elems = 0usize;
        for lane in ws.chunks(lane_len) {
            for block in lane.chunks(4) {
                blocks += 1;
                let z = block.iter().filter(|&&w| w == 0).count();
                zeros += z;
                if z == 4 {
                    zero_blocks += 1;
                } else {
                    nz_block_zeros += z;
                    nz_block_elems += 4;
                }
            }
        }
        SparsityProfile {
            element: if ws.is_empty() { 0.0 } else { zeros as f64 / ws.len() as f64 },
            block: if blocks == 0 { 0.0 } else { zero_blocks as f64 / blocks as f64 },
            intra_block: if nz_block_elems == 0 {
                0.0
            } else {
                nz_block_zeros as f64 / nz_block_elems as f64
            },
            elements: ws.len(),
            blocks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn element_sparsity_basic() {
        assert_eq!(element_sparsity(&[0, 0, 1, 0]), 0.75);
        assert_eq!(element_sparsity(&[]), 0.0);
        assert_eq!(element_sparsity(&[1, 2]), 0.0);
    }

    #[test]
    fn block_sparsity_basic() {
        let ws = [[0i8; 4], [1, 0, 0, 0], [0; 4], [0; 4]].concat();
        assert_eq!(block_sparsity(&ws, 16), 0.75);
    }

    #[test]
    fn profile_decomposes() {
        // one zero block + one block with 2 zeros
        let ws = [[0i8; 4], [1, 0, 2, 0]].concat();
        let p = SparsityProfile::measure(&ws, 8);
        assert_eq!(p.element, 6.0 / 8.0);
        assert_eq!(p.block, 0.5);
        assert_eq!(p.intra_block, 0.5);
        assert_eq!(p.blocks, 2);
    }

    #[test]
    #[should_panic]
    fn bad_lane_len_panics() {
        block_sparsity(&[0i8; 8], 6);
    }
}
