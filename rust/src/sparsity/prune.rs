//! Magnitude pruning: unstructured (arbitrary zeros), 4:4
//! semi-structured (whole-block zeros), N:M semi-structured (≤ N
//! non-zeros per M-weight group), and bank-balanced (non-zeros spread
//! evenly across K word banks), matching the sparsity structures of
//! Figure 1(b)/(c) plus the format extensions.
//!
//! The paper applies iterative explainable-AI-ranked pruning offline; the
//! accelerator only requires that the *resulting pattern* conforms
//! (arbitrary zeros for USSA, all-zero 4-blocks for SSSA, ≤ N per group
//! for NM-SSA, balanced banks for BBS). Magnitude ranking produces the
//! same patterns and is the standard proxy.

use super::stats::SparsityProfile;

/// Outcome of a pruning pass.
#[derive(Debug, Clone)]
pub struct PruneReport {
    /// Elements zeroed by this pass.
    pub zeroed: usize,
    /// Profile after pruning.
    pub profile: SparsityProfile,
}

/// Unstructured magnitude pruning: zero the `target` fraction of
/// smallest-|w| elements. Deterministic (stable sort by magnitude, then
/// index). Already-zero weights count toward the target.
pub fn prune_unstructured_magnitude(ws: &mut [i8], lane_len: usize, target: f64) -> PruneReport {
    assert!((0.0..=1.0).contains(&target), "target must be in [0,1]");
    let n = ws.len();
    let want_zeros = (target * n as f64).round() as usize;
    let existing = ws.iter().filter(|&&w| w == 0).count();
    let mut zeroed = 0usize;
    if want_zeros > existing {
        let need = want_zeros - existing;
        // indices of non-zero weights sorted by (|w|, idx)
        let mut idx: Vec<usize> = (0..n).filter(|&i| ws[i] != 0).collect();
        idx.sort_by_key(|&i| ((ws[i] as i32).abs(), i));
        for &i in idx.iter().take(need) {
            ws[i] = 0;
            zeroed += 1;
        }
    }
    PruneReport { zeroed, profile: SparsityProfile::measure(ws, lane_len) }
}

/// Semi-structured (4:4) magnitude pruning: zero the `target` fraction of
/// blocks with the smallest L1 norm. Blocks are 4 consecutive weights
/// along each lane. Already-zero blocks count toward the target.
pub fn prune_blocks_magnitude(ws: &mut [i8], lane_len: usize, target: f64) -> PruneReport {
    assert!((0.0..=1.0).contains(&target), "target must be in [0,1]");
    assert!(lane_len > 0 && lane_len % 4 == 0);
    assert_eq!(ws.len() % lane_len, 0);
    let blocks = ws.len() / 4;
    let want_zero_blocks = (target * blocks as f64).round() as usize;
    let mut norms: Vec<(u32, usize)> = Vec::with_capacity(blocks);
    let mut existing = 0usize;
    for b in 0..blocks {
        let s: u32 = ws[b * 4..b * 4 + 4].iter().map(|&w| (w as i32).unsigned_abs()).sum();
        if s == 0 {
            existing += 1;
        } else {
            norms.push((s, b));
        }
    }
    let mut zeroed = 0usize;
    if want_zero_blocks > existing {
        let need = want_zero_blocks - existing;
        norms.sort();
        for &(_, b) in norms.iter().take(need) {
            for w in &mut ws[b * 4..b * 4 + 4] {
                if *w != 0 {
                    zeroed += 1;
                }
                *w = 0;
            }
        }
    }
    PruneReport { zeroed, profile: SparsityProfile::measure(ws, lane_len) }
}

/// Combined pruning for CSA workloads: first block-prune to `block_target`
/// (semi-structured sparsity x_ss), then unstructured-prune the remaining
/// non-zero weights so that *element* sparsity reaches
/// `block_target + intra_target * (1 - block_target)` — i.e.
/// `intra_target` is the unstructured ratio x_us *within* surviving
/// blocks, matching Figure 10's (x_us, x_ss) parameterization.
pub fn prune_combined(
    ws: &mut [i8],
    lane_len: usize,
    block_target: f64,
    intra_target: f64,
) -> PruneReport {
    prune_blocks_magnitude(ws, lane_len, block_target);
    let elem_target = block_target + intra_target * (1.0 - block_target);
    prune_unstructured_magnitude(ws, lane_len, elem_target)
}

/// N:M semi-structured magnitude pruning: in every group of `m`
/// consecutive weights, keep the `n` largest-|w| weights (ties resolved
/// toward the lowest index) and zero the rest. Deterministic. Groups
/// never straddle lanes because `lane_len % m == 0` is required.
///
/// This is the prepare-time contract of [`crate::isa::DesignKind::NmSsa`]:
/// a layer pruned with `prune_nm(_, _, 2, 4)` runs on NM-SSA without any
/// further weight modification.
pub fn prune_nm(ws: &mut [i8], lane_len: usize, n: usize, m: usize) -> PruneReport {
    assert!(m > 0 && n <= m, "need 0 <= n <= m, m > 0");
    assert!(lane_len > 0 && lane_len % m == 0);
    assert_eq!(ws.len() % lane_len, 0);
    let mut zeroed = 0usize;
    for group in ws.chunks_mut(m) {
        let mut idx: Vec<usize> = (0..m).collect();
        idx.sort_by_key(|&i| (std::cmp::Reverse((group[i] as i32).abs()), i));
        for &i in idx.iter().skip(n) {
            if group[i] != 0 {
                group[i] = 0;
                zeroed += 1;
            }
        }
    }
    PruneReport { zeroed, profile: SparsityProfile::measure(ws, lane_len) }
}

/// Bank-balanced magnitude pruning (MCBBS-style): per lane, the kept
/// non-zeros are distributed across `k` banks so the per-bank kept
/// counts differ by at most one, with each bank keeping its
/// largest-|w| members. A weight's bank is that of its containing
/// 4-weight word: `bank = (index_in_lane / 4) % k` — the same banking
/// the BBS walk charges its balanced-lane cycle bound against.
///
/// The overall element-sparsity target is `target` per lane (rounded to
/// whole elements, split into per-bank quotas of `⌊keep/k⌋` or
/// `⌈keep/k⌉`, the larger quotas going to the lowest bank indices).
/// A bank holding fewer non-zeros than its quota keeps them all, so
/// the max−min ≤ 1 balance invariant is guaranteed whenever every bank
/// has at least its quota available (always true when pruning dense
/// weights, the intended use).
pub fn prune_bank_balanced(ws: &mut [i8], lane_len: usize, target: f64, k: usize) -> PruneReport {
    assert!((0.0..=1.0).contains(&target), "target must be in [0,1]");
    assert!(k > 0, "need at least one bank");
    assert!(lane_len > 0 && lane_len % 4 == 0);
    assert_eq!(ws.len() % lane_len, 0);
    let want_zeros = (target * lane_len as f64).round() as usize;
    let keep_total = lane_len - want_zeros;
    let mut zeroed = 0usize;
    for lane in ws.chunks_mut(lane_len) {
        // Per-bank non-zero indices, largest |w| first (ties → lowest
        // index), so truncating to the quota keeps the heaviest members.
        let mut banks: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (i, &w) in lane.iter().enumerate() {
            if w != 0 {
                banks[(i / 4) % k].push(i);
            }
        }
        for bank in &mut banks {
            bank.sort_by_key(|&i| (std::cmp::Reverse((lane[i] as i32).abs()), i));
        }
        let base = keep_total / k;
        let rem = keep_total % k;
        for (b, bank) in banks.iter().enumerate() {
            let quota = base + usize::from(b < rem);
            for &i in bank.iter().skip(quota) {
                lane[i] = 0;
                zeroed += 1;
            }
        }
    }
    PruneReport { zeroed, profile: SparsityProfile::measure(ws, lane_len) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_weights(n: usize, seed: u64) -> Vec<i8> {
        let mut r = Pcg32::new(seed);
        (0..n)
            .map(|_| {
                // mostly non-zero values in INT7 range
                let v = r.range_i32(-63, 63) as i8;
                if v == 0 {
                    1
                } else {
                    v
                }
            })
            .collect()
    }

    #[test]
    fn unstructured_hits_target() {
        let mut ws = random_weights(1024, 1);
        let rep = prune_unstructured_magnitude(&mut ws, 64, 0.7);
        assert!((rep.profile.element - 0.7).abs() < 0.01, "got {}", rep.profile.element);
    }

    #[test]
    fn unstructured_removes_smallest_first() {
        let mut ws = vec![1i8, -50, 2, 60, -1, 40, 3, -30];
        prune_unstructured_magnitude(&mut ws, 8, 0.5);
        // smallest |w|: 1, -1, 2, 3 zeroed
        assert_eq!(ws, vec![0, -50, 0, 60, 0, 40, 0, -30]);
    }

    #[test]
    fn block_prune_hits_target_blockwise() {
        let mut ws = random_weights(1024, 2);
        let rep = prune_blocks_magnitude(&mut ws, 64, 0.5);
        assert!((rep.profile.block - 0.5).abs() < 0.01, "got {}", rep.profile.block);
        // block pruning creates element sparsity equal to block sparsity
        assert!((rep.profile.element - rep.profile.block).abs() < 0.01);
    }

    #[test]
    fn block_prune_zeroes_whole_blocks_only() {
        let mut ws = random_weights(256, 3);
        prune_blocks_magnitude(&mut ws, 32, 0.4);
        for block in ws.chunks(4) {
            let zeros = block.iter().filter(|&&w| w == 0).count();
            assert!(zeros == 0 || zeros == 4, "partial block zeroed: {block:?}");
        }
    }

    #[test]
    fn combined_reaches_both_ratios() {
        let mut ws = random_weights(4096, 4);
        let rep = prune_combined(&mut ws, 64, 0.4, 0.5);
        assert!((rep.profile.block - 0.4).abs() < 0.02, "block {}", rep.profile.block);
        assert!((rep.profile.intra_block - 0.5).abs() < 0.03, "intra {}", rep.profile.intra_block);
    }

    #[test]
    fn idempotent_at_reached_target() {
        let mut ws = random_weights(512, 5);
        prune_unstructured_magnitude(&mut ws, 64, 0.6);
        let before = ws.clone();
        let rep = prune_unstructured_magnitude(&mut ws, 64, 0.6);
        assert_eq!(ws, before);
        assert_eq!(rep.zeroed, 0);
    }

    #[test]
    fn target_zero_is_noop() {
        let mut ws = random_weights(128, 6);
        let orig = ws.clone();
        prune_unstructured_magnitude(&mut ws, 64, 0.0);
        assert_eq!(ws, orig);
    }

    #[test]
    fn target_one_zeroes_everything() {
        let mut ws = random_weights(128, 7);
        let rep = prune_unstructured_magnitude(&mut ws, 64, 1.0);
        assert!(ws.iter().all(|&w| w == 0));
        assert_eq!(rep.profile.element, 1.0);
    }

    #[test]
    fn nm_keeps_largest_two_per_group() {
        let mut ws = vec![1i8, -50, 2, 60, -1, 40, 3, -30];
        let rep = prune_nm(&mut ws, 8, 2, 4);
        assert_eq!(ws, vec![0, -50, 0, 60, 0, 40, 0, -30]);
        assert_eq!(rep.zeroed, 4);
    }

    #[test]
    fn nm_is_idempotent_and_tie_breaks_to_lowest_index() {
        // Equal magnitudes: the two lowest indices survive.
        let mut ws = vec![5i8, -5, 5, -5];
        prune_nm(&mut ws, 4, 2, 4);
        assert_eq!(ws, vec![5, -5, 0, 0]);
        let before = ws.clone();
        let rep = prune_nm(&mut ws, 4, 2, 4);
        assert_eq!(ws, before);
        assert_eq!(rep.zeroed, 0);
    }

    #[test]
    fn bank_balanced_hits_target_with_balanced_banks() {
        let lane_len = 64;
        let k = 4;
        let mut ws = random_weights(1024, 8);
        let rep = prune_bank_balanced(&mut ws, lane_len, 0.5, k);
        assert!((rep.profile.element - 0.5).abs() < 0.01, "got {}", rep.profile.element);
        for lane in ws.chunks(lane_len) {
            let mut counts = vec![0usize; k];
            for (i, &w) in lane.iter().enumerate() {
                if w != 0 {
                    counts[(i / 4) % k] += 1;
                }
            }
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(max - min <= 1, "unbalanced banks: {counts:?}");
        }
    }

    #[test]
    fn bank_balanced_target_zero_keeps_dense_lanes() {
        let mut ws = random_weights(256, 9);
        let orig = ws.clone();
        let rep = prune_bank_balanced(&mut ws, 32, 0.0, 4);
        assert_eq!(ws, orig);
        assert_eq!(rep.zeroed, 0);
    }
}
