//! Synthetic sparse-weight generators for the benchmark sweeps.
//!
//! Figure 8 sweeps unstructured sparsity with IID zeros (the paper's
//! analytical model assumes IID); Figure 9 sweeps block sparsity; Figure
//! 10 uses combined (x_us, x_ss). All generators emit INT7-ranged weights
//! so every design (including SSSA/CSA which require encodable weights)
//! can run the same tensors.

use crate::util::Pcg32;

fn nonzero_int7(rng: &mut Pcg32) -> i8 {
    loop {
        let w = rng.range_i32(-64, 63) as i8;
        if w != 0 {
            return w;
        }
    }
}

/// IID unstructured sparsity: each weight is zero with probability `x`.
pub fn gen_unstructured_sparse(n: usize, x: f64, rng: &mut Pcg32) -> Vec<i8> {
    assert!((0.0..=1.0).contains(&x));
    (0..n).map(|_| if rng.bernoulli(x) { 0 } else { nonzero_int7(rng) }).collect()
}

/// 4:4 block sparsity: each 4-weight block is all-zero with probability
/// `x_block`; surviving blocks are fully dense.
pub fn gen_block_sparse(n: usize, x_block: f64, rng: &mut Pcg32) -> Vec<i8> {
    assert!((0.0..=1.0).contains(&x_block));
    assert_eq!(n % 4, 0, "n must be a multiple of 4");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n / 4 {
        if rng.bernoulli(x_block) {
            out.extend_from_slice(&[0i8; 4]);
        } else {
            for _ in 0..4 {
                out.push(nonzero_int7(rng));
            }
        }
    }
    out
}

/// Combined sparsity: blocks zero with probability `x_ss`; within
/// surviving blocks each weight is zero with probability `x_us`
/// (Figure 10's parameterization).
pub fn gen_combined_sparse(n: usize, x_us: f64, x_ss: f64, rng: &mut Pcg32) -> Vec<i8> {
    assert!((0.0..=1.0).contains(&x_us));
    assert!((0.0..=1.0).contains(&x_ss));
    assert_eq!(n % 4, 0, "n must be a multiple of 4");
    let mut out = Vec::with_capacity(n);
    for _ in 0..n / 4 {
        if rng.bernoulli(x_ss) {
            out.extend_from_slice(&[0i8; 4]);
        } else {
            for _ in 0..4 {
                out.push(if rng.bernoulli(x_us) { 0 } else { nonzero_int7(rng) });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparsity::stats::SparsityProfile;

    #[test]
    fn unstructured_ratio_close() {
        let mut rng = Pcg32::new(42);
        let ws = gen_unstructured_sparse(40_000, 0.6, &mut rng);
        let p = SparsityProfile::measure(&ws, 40);
        assert!((p.element - 0.6).abs() < 0.01, "element {}", p.element);
    }

    #[test]
    fn block_ratio_close_and_blocks_whole() {
        let mut rng = Pcg32::new(43);
        let ws = gen_block_sparse(40_000, 0.45, &mut rng);
        let p = SparsityProfile::measure(&ws, 40);
        assert!((p.block - 0.45).abs() < 0.02, "block {}", p.block);
        assert!(p.intra_block < 1e-9, "surviving blocks must be dense");
    }

    #[test]
    fn combined_ratios_close() {
        let mut rng = Pcg32::new(44);
        let ws = gen_combined_sparse(80_000, 0.5, 0.3, &mut rng);
        let p = SparsityProfile::measure(&ws, 40);
        // A surviving block can still turn out all-zero from x_us alone
        // (probability x_us^4), so measured block sparsity is
        // x_ss + (1 - x_ss) * x_us^4.
        let expect_block = 0.3 + 0.7 * 0.5f64.powi(4);
        assert!((p.block - expect_block).abs() < 0.02, "block {}", p.block);
        // intra_block measures zeros in surviving blocks, but a fully-zero
        // block can also arise from x_us alone (prob 0.5^4) and is counted
        // as a block-zero; allow that bias.
        assert!((p.intra_block - 0.5).abs() < 0.05, "intra {}", p.intra_block);
    }

    #[test]
    fn all_weights_int7() {
        let mut rng = Pcg32::new(45);
        for ws in [
            gen_unstructured_sparse(1000, 0.3, &mut rng),
            gen_block_sparse(1000, 0.3, &mut rng),
            gen_combined_sparse(1000, 0.3, 0.3, &mut rng),
        ] {
            assert!(ws.iter().all(|&w| (-64..=63).contains(&w)));
        }
    }

    #[test]
    fn extremes() {
        let mut rng = Pcg32::new(46);
        assert!(gen_unstructured_sparse(100, 1.0, &mut rng).iter().all(|&w| w == 0));
        assert!(gen_unstructured_sparse(100, 0.0, &mut rng).iter().all(|&w| w != 0));
    }
}
