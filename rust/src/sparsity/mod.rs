//! Pruning library: unstructured, 4:4 semi-structured (block), N:M
//! semi-structured, bank-balanced, and combined sparsification of
//! quantized weights, plus sparsity statistics and synthetic
//! sparse-weight generators for the benchmark sweeps (Figures 8–10).

pub mod generator;
pub mod prune;
pub mod stats;

pub use generator::{gen_block_sparse, gen_combined_sparse, gen_unstructured_sparse};
pub use prune::{
    prune_bank_balanced, prune_blocks_magnitude, prune_nm, prune_unstructured_magnitude,
    PruneReport,
};
pub use stats::{block_sparsity, element_sparsity, SparsityProfile};
