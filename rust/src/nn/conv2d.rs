//! Quantized 2-D convolution (normal and depthwise), reference
//! implementation with TFLite semantics.

use crate::error::{Error, Result};
use crate::tensor::quant::{QuantParams, Requantizer};
use crate::tensor::{QTensor, Shape};

/// Spatial padding mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    /// No padding; output shrinks by kernel-1.
    Valid,
    /// Zero ("same") padding keeping `out = ceil(in / stride)`.
    Same,
}

/// A quantized conv2d layer (set `depthwise` for per-channel filtering).
///
/// Weight layout: `[out_c][kh][kw][in_c]` for normal conv (lanes along
/// `in_c`, the dimension Algorithm 1 encodes), and `[ch][kh][kw]` for
/// depthwise (lanes along the flattened spatial kernel, zero-padded to a
/// multiple of 4 — see DESIGN.md §Hardware-Adaptation).
#[derive(Debug, Clone)]
pub struct Conv2dOp {
    /// Layer name for reports.
    pub name: String,
    /// INT8 weights (symmetric, zero-point 0).
    pub weights: Vec<i8>,
    /// Per-output-channel i32 bias.
    pub bias: Vec<i32>,
    /// Output channels (= input channels for depthwise).
    pub out_c: usize,
    /// Input channels.
    pub in_c: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Stride (same both dims).
    pub stride: usize,
    /// Padding mode.
    pub padding: Padding,
    /// Depthwise flag.
    pub depthwise: bool,
    /// Input quantization (activations).
    pub input_params: QuantParams,
    /// Weight scale (symmetric).
    pub weight_scale: f32,
    /// Output quantization.
    pub output_params: QuantParams,
    /// Requantizer (folded scales + ReLU clamp).
    pub requant: Requantizer,
}

impl Conv2dOp {
    /// Build a layer, validating weight/bias sizes.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        weights: Vec<i8>,
        bias: Vec<i32>,
        out_c: usize,
        in_c: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        padding: Padding,
        depthwise: bool,
        input_params: QuantParams,
        weight_scale: f32,
        output_params: QuantParams,
        relu: bool,
    ) -> Result<Self> {
        let expect = if depthwise {
            if out_c != in_c {
                return Err(Error::Model(format!(
                    "{name}: depthwise requires out_c == in_c ({out_c} != {in_c})"
                )));
            }
            out_c * kh * kw
        } else {
            out_c * kh * kw * in_c
        };
        if weights.len() != expect {
            return Err(Error::Model(format!(
                "{name}: weight count {} != expected {expect}",
                weights.len()
            )));
        }
        if bias.len() != out_c {
            return Err(Error::Model(format!(
                "{name}: bias count {} != out_c {out_c}",
                bias.len()
            )));
        }
        if stride == 0 {
            return Err(Error::Model(format!("{name}: stride must be >= 1")));
        }
        let requant = Requantizer::new(input_params.scale, weight_scale, &output_params, relu)?;
        Ok(Conv2dOp {
            name: name.to_string(),
            weights,
            bias,
            out_c,
            in_c,
            kh,
            kw,
            stride,
            padding,
            depthwise,
            input_params,
            weight_scale,
            output_params,
            requant,
        })
    }

    /// Padding offsets (top/left) and output spatial dims for an input.
    pub fn geometry(&self, in_h: usize, in_w: usize) -> (usize, usize, i64, i64) {
        match self.padding {
            Padding::Valid => {
                let out_h = (in_h - self.kh) / self.stride + 1;
                let out_w = (in_w - self.kw) / self.stride + 1;
                (out_h, out_w, 0, 0)
            }
            Padding::Same => {
                let out_h = in_h.div_ceil(self.stride);
                let out_w = in_w.div_ceil(self.stride);
                let pad_h =
                    (((out_h - 1) * self.stride + self.kh).saturating_sub(in_h)) as i64 / 2;
                let pad_w =
                    (((out_w - 1) * self.stride + self.kw).saturating_sub(in_w)) as i64 / 2;
                (out_h, out_w, pad_h, pad_w)
            }
        }
    }

    /// Flat index into the weight buffer for normal conv.
    #[inline]
    pub fn w_idx(&self, oc: usize, kh: usize, kw: usize, ic: usize) -> usize {
        ((oc * self.kh + kh) * self.kw + kw) * self.in_c + ic
    }

    /// Flat index for depthwise weights.
    #[inline]
    pub fn dw_idx(&self, ch: usize, kh: usize, kw: usize) -> usize {
        (ch * self.kh + kh) * self.kw + kw
    }

    /// The hardware input-offset constant (`-input_zero_point`).
    #[inline]
    pub fn input_offset(&self) -> i32 {
        -self.input_params.zero_point
    }

    /// Reference forward pass (golden semantics).
    pub fn forward_ref(&self, input: &QTensor) -> Result<QTensor> {
        let ishape = input.shape();
        if ishape.rank() != 4 || ishape.c() != self.in_c {
            return Err(Error::Shape(format!(
                "{}: input {} incompatible with in_c {}",
                self.name,
                ishape,
                self.in_c
            )));
        }
        let (n, in_h, in_w) = (ishape.n(), ishape.h(), ishape.w());
        let (out_h, out_w, pad_h, pad_w) = self.geometry(in_h, in_w);
        let mut out = QTensor::zeros(Shape::nhwc(n, out_h, out_w, self.out_c), self.output_params);
        let offset = self.input_offset();
        let x = input.data();
        for b in 0..n {
            for oh in 0..out_h {
                for ow in 0..out_w {
                    for oc in 0..self.out_c {
                        let mut acc = self.bias[oc];
                        for kh in 0..self.kh {
                            let ih = (oh * self.stride + kh) as i64 - pad_h;
                            if ih < 0 || ih >= in_h as i64 {
                                continue;
                            }
                            for kw in 0..self.kw {
                                let iw = (ow * self.stride + kw) as i64 - pad_w;
                                if iw < 0 || iw >= in_w as i64 {
                                    continue;
                                }
                                let base = ((b * in_h + ih as usize) * in_w + iw as usize)
                                    * self.in_c;
                                if self.depthwise {
                                    let w = self.weights[self.dw_idx(oc, kh, kw)] as i32;
                                    acc += w * (x[base + oc] as i32 + offset);
                                } else {
                                    for ic in 0..self.in_c {
                                        let w = self.weights[self.w_idx(oc, kh, kw, ic)] as i32;
                                        acc += w * (x[base + ic] as i32 + offset);
                                    }
                                }
                            }
                        }
                        out.set(&[b, oh, ow, oc], self.requant.apply(acc));
                    }
                }
            }
        }
        Ok(out)
    }

    /// Total MAC-relevant weight lanes: used by the encoder. Normal conv
    /// lanes run along `in_c` per `(oc, kh, kw)`; depthwise lanes are the
    /// flattened spatial kernel per channel.
    pub fn lane_len(&self) -> usize {
        if self.depthwise {
            self.kh * self.kw
        } else {
            self.in_c
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_op(weights: Vec<i8>, relu: bool) -> Conv2dOp {
        Conv2dOp::new(
            "t",
            weights,
            vec![0, 0],
            2,
            4,
            1,
            1,
            1,
            Padding::Valid,
            false,
            QuantParams::new(1.0, 0).unwrap(),
            1.0,
            QuantParams::new(1.0, 0).unwrap(),
            relu,
        )
        .unwrap()
    }

    #[test]
    fn pointwise_conv_known_values() {
        // 1x1 conv, 4 in channels, 2 out channels, identity-ish scales.
        let weights = vec![
            1, 0, 0, 0, // oc0 picks channel 0
            0, 1, 1, 0, // oc1 sums channels 1+2
        ];
        let op = simple_op(weights, false);
        let input = QTensor::new(
            Shape::nhwc(1, 1, 1, 4),
            vec![5, 6, 7, 8],
            QuantParams::new(1.0, 0).unwrap(),
        )
        .unwrap();
        let out = op.forward_ref(&input).unwrap();
        assert_eq!(out.data(), &[5, 13]);
    }

    #[test]
    fn relu_clamps_negative() {
        let weights = vec![-1, 0, 0, 0, 1, 0, 0, 0];
        let op = simple_op(weights, true);
        let input = QTensor::new(
            Shape::nhwc(1, 1, 1, 4),
            vec![5, 0, 0, 0],
            QuantParams::new(1.0, 0).unwrap(),
        )
        .unwrap();
        let out = op.forward_ref(&input).unwrap();
        assert_eq!(out.data(), &[0, 5]); // -5 clamped to zero point 0
    }

    #[test]
    fn input_zero_point_respected() {
        // x_q = zp → real 0 → contributes nothing.
        let weights = vec![3, 3, 3, 3, 1, 1, 1, 1];
        let mut op = simple_op(weights, false);
        op.input_params = QuantParams::new(1.0, 7).unwrap();
        op.requant = Requantizer::new(1.0, 1.0, &op.output_params, false).unwrap();
        let input = QTensor::new(
            Shape::nhwc(1, 1, 1, 4),
            vec![7, 7, 7, 7],
            op.input_params,
        )
        .unwrap();
        let out = op.forward_ref(&input).unwrap();
        assert_eq!(out.data(), &[0, 0]);
    }

    #[test]
    fn same_padding_geometry() {
        let op = Conv2dOp::new(
            "t",
            vec![0; 2 * 3 * 3 * 4],
            vec![0; 2],
            2,
            4,
            3,
            3,
            1,
            Padding::Same,
            false,
            QuantParams::new(1.0, 0).unwrap(),
            1.0,
            QuantParams::new(1.0, 0).unwrap(),
            false,
        )
        .unwrap();
        let (oh, ow, ph, pw) = op.geometry(8, 8);
        assert_eq!((oh, ow), (8, 8));
        assert_eq!((ph, pw), (1, 1));
    }

    #[test]
    fn valid_padding_geometry_with_stride() {
        let op = Conv2dOp::new(
            "t",
            vec![0; 2 * 3 * 3 * 4],
            vec![0; 2],
            2,
            4,
            3,
            3,
            2,
            Padding::Valid,
            false,
            QuantParams::new(1.0, 0).unwrap(),
            1.0,
            QuantParams::new(1.0, 0).unwrap(),
            false,
        )
        .unwrap();
        let (oh, ow, _, _) = op.geometry(9, 9);
        assert_eq!((oh, ow), (4, 4));
    }

    #[test]
    fn depthwise_identity_kernel() {
        // 3x3 depthwise with center weight 1 = identity (same padding).
        let ch = 4;
        let mut weights = vec![0i8; ch * 9];
        for c in 0..ch {
            weights[c * 9 + 4] = 1; // center tap
        }
        let op = Conv2dOp::new(
            "dw",
            weights,
            vec![0; ch],
            ch,
            ch,
            3,
            3,
            1,
            Padding::Same,
            true,
            QuantParams::new(1.0, 0).unwrap(),
            1.0,
            QuantParams::new(1.0, 0).unwrap(),
            false,
        )
        .unwrap();
        let data: Vec<i8> = (0..2 * 2 * ch as i32).map(|i| (i % 50) as i8).collect();
        let input =
            QTensor::new(Shape::nhwc(1, 2, 2, ch), data.clone(), QuantParams::new(1.0, 0).unwrap())
                .unwrap();
        let out = op.forward_ref(&input).unwrap();
        assert_eq!(out.data(), &data[..]);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let op = simple_op(vec![0; 8], false);
        let input =
            QTensor::zeros(Shape::nhwc(1, 1, 1, 8), QuantParams::new(1.0, 0).unwrap());
        assert!(op.forward_ref(&input).is_err());
    }

    #[test]
    fn bad_construction_rejected() {
        // wrong weight count
        assert!(Conv2dOp::new(
            "t",
            vec![0; 7],
            vec![0; 2],
            2,
            4,
            1,
            1,
            1,
            Padding::Valid,
            false,
            QuantParams::new(1.0, 0).unwrap(),
            1.0,
            QuantParams::new(1.0, 0).unwrap(),
            false,
        )
        .is_err());
        // depthwise out != in
        assert!(Conv2dOp::new(
            "t",
            vec![0; 8 * 9],
            vec![0; 8],
            8,
            4,
            3,
            3,
            1,
            Padding::Same,
            true,
            QuantParams::new(1.0, 0).unwrap(),
            1.0,
            QuantParams::new(1.0, 0).unwrap(),
            false,
        )
        .is_err());
    }
}
