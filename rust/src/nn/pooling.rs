//! Quantized pooling (max / average) over NHWC tensors.
//!
//! Pooling keeps the input quantization parameters (TFLite requires
//! identical input/output scales for pooling ops).

use crate::error::{Error, Result};
use crate::tensor::{QTensor, Shape};

fn pool_geometry(in_h: usize, in_w: usize, k: usize, stride: usize) -> Result<(usize, usize)> {
    if k == 0 || stride == 0 {
        return Err(Error::Model("pool kernel/stride must be >= 1".into()));
    }
    if in_h < k || in_w < k {
        return Err(Error::Shape(format!("pool kernel {k} larger than input {in_h}x{in_w}")));
    }
    Ok(((in_h - k) / stride + 1, (in_w - k) / stride + 1))
}

/// Max pooling with a square `k`×`k` window.
pub fn max_pool2d(input: &QTensor, k: usize, stride: usize) -> Result<QTensor> {
    let s = input.shape();
    if s.rank() != 4 {
        return Err(Error::Shape("max_pool2d expects NHWC".into()));
    }
    let (out_h, out_w) = pool_geometry(s.h(), s.w(), k, stride)?;
    let (n, c) = (s.n(), s.c());
    let x = input.data();
    let mut out = QTensor::zeros(Shape::nhwc(n, out_h, out_w, c), *input.params());
    for b in 0..n {
        for oh in 0..out_h {
            for ow in 0..out_w {
                for ch in 0..c {
                    let mut m = i8::MIN;
                    for ih in oh * stride..oh * stride + k {
                        for iw in ow * stride..ow * stride + k {
                            let v = x[((b * s.h() + ih) * s.w() + iw) * c + ch];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    out.set(&[b, oh, ow, ch], m);
                }
            }
        }
    }
    Ok(out)
}

/// Average pooling with a square `k`×`k` window (TFLite rounding:
/// round-half-away-from-zero on the i32 sum).
pub fn avg_pool2d(input: &QTensor, k: usize, stride: usize) -> Result<QTensor> {
    let s = input.shape();
    if s.rank() != 4 {
        return Err(Error::Shape("avg_pool2d expects NHWC".into()));
    }
    let (out_h, out_w) = pool_geometry(s.h(), s.w(), k, stride)?;
    let (n, c) = (s.n(), s.c());
    let x = input.data();
    let count = (k * k) as i32;
    let mut out = QTensor::zeros(Shape::nhwc(n, out_h, out_w, c), *input.params());
    for b in 0..n {
        for oh in 0..out_h {
            for ow in 0..out_w {
                for ch in 0..c {
                    let mut sum = 0i32;
                    for ih in oh * stride..oh * stride + k {
                        for iw in ow * stride..ow * stride + k {
                            sum += x[((b * s.h() + ih) * s.w() + iw) * c + ch] as i32;
                        }
                    }
                    let avg = if sum >= 0 {
                        (sum + count / 2) / count
                    } else {
                        (sum - count / 2) / count
                    };
                    out.set(&[b, oh, ow, ch], avg.clamp(-128, 127) as i8);
                }
            }
        }
    }
    Ok(out)
}

/// Global average pooling: collapse H×W to 1×1.
pub fn global_avg_pool(input: &QTensor) -> Result<QTensor> {
    let s = input.shape();
    if s.rank() != 4 {
        return Err(Error::Shape("global_avg_pool expects NHWC".into()));
    }
    avg_pool2d(input, s.h().min(s.w()), 1).and_then(|t| {
        // If H != W fall back to explicit averaging.
        if s.h() == s.w() {
            return Ok(t);
        }
        let (n, c) = (s.n(), s.c());
        let x = input.data();
        let count = (s.h() * s.w()) as i32;
        let mut out = QTensor::zeros(Shape::nhwc(n, 1, 1, c), *input.params());
        for b in 0..n {
            for ch in 0..c {
                let mut sum = 0i32;
                for ih in 0..s.h() {
                    for iw in 0..s.w() {
                        sum += x[((b * s.h() + ih) * s.w() + iw) * c + ch] as i32;
                    }
                }
                let avg = if sum >= 0 {
                    (sum + count / 2) / count
                } else {
                    (sum - count / 2) / count
                };
                out.set(&[b, 0, 0, ch], avg.clamp(-128, 127) as i8);
            }
        }
        Ok(out)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::quant::QuantParams;

    fn tensor_2x2x2(vals: Vec<i8>) -> QTensor {
        QTensor::new(Shape::nhwc(1, 2, 2, 2), vals, QuantParams::new(1.0, 0).unwrap()).unwrap()
    }

    #[test]
    fn max_pool_basic() {
        let t = tensor_2x2x2(vec![1, -1, 3, -3, 5, -5, 7, 9]);
        let out = max_pool2d(&t, 2, 2).unwrap();
        assert_eq!(out.shape().dims(), &[1, 1, 1, 2]);
        assert_eq!(out.data(), &[7, 9]);
    }

    #[test]
    fn avg_pool_rounding() {
        let t = tensor_2x2x2(vec![1, -1, 2, -2, 3, -3, 4, -4]);
        let out = avg_pool2d(&t, 2, 2).unwrap();
        // ch0: (1+2+3+4)/4 = 2.5 → 3 (half away from zero)
        // ch1: -2.5 → -3
        assert_eq!(out.data(), &[3, -3]);
    }

    #[test]
    fn pool_stride_one() {
        let t = QTensor::new(
            Shape::nhwc(1, 3, 3, 1),
            vec![1, 2, 3, 4, 5, 6, 7, 8, 9],
            QuantParams::new(1.0, 0).unwrap(),
        )
        .unwrap();
        let out = max_pool2d(&t, 2, 1).unwrap();
        assert_eq!(out.shape().dims(), &[1, 2, 2, 1]);
        assert_eq!(out.data(), &[5, 6, 8, 9]);
    }

    #[test]
    fn global_avg_pool_square() {
        let t = QTensor::new(
            Shape::nhwc(1, 2, 2, 1),
            vec![4, 8, 12, 16],
            QuantParams::new(1.0, 0).unwrap(),
        )
        .unwrap();
        let out = global_avg_pool(&t).unwrap();
        assert_eq!(out.shape().dims(), &[1, 1, 1, 1]);
        assert_eq!(out.data(), &[10]);
    }

    #[test]
    fn too_large_kernel_rejected() {
        let t = tensor_2x2x2(vec![0; 8]);
        assert!(max_pool2d(&t, 3, 1).is_err());
    }
}
