//! Quantized fully-connected (dense) layer.

use crate::error::{Error, Result};
use crate::tensor::quant::{QuantParams, Requantizer};
use crate::tensor::{QTensor, Shape};

/// A quantized dense layer: `out[o] = requant(Σ_i w[o][i] * (x[i]+off) + b[o])`.
///
/// Weight layout `[out][in]`; lanes for the lookahead encoder run along
/// `in` (must be padded to a multiple of 4 by the model builder).
#[derive(Debug, Clone)]
pub struct FullyConnectedOp {
    /// Layer name.
    pub name: String,
    /// INT8 weights, `[out][in]` row-major.
    pub weights: Vec<i8>,
    /// Per-output i32 bias.
    pub bias: Vec<i32>,
    /// Output features.
    pub out_n: usize,
    /// Input features.
    pub in_n: usize,
    /// Input activation params.
    pub input_params: QuantParams,
    /// Weight scale (symmetric).
    pub weight_scale: f32,
    /// Output activation params.
    pub output_params: QuantParams,
    /// Requantizer.
    pub requant: Requantizer,
}

impl FullyConnectedOp {
    /// Build with validation.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        weights: Vec<i8>,
        bias: Vec<i32>,
        out_n: usize,
        in_n: usize,
        input_params: QuantParams,
        weight_scale: f32,
        output_params: QuantParams,
        relu: bool,
    ) -> Result<Self> {
        if weights.len() != out_n * in_n {
            return Err(Error::Model(format!(
                "{name}: weight count {} != {out_n}x{in_n}",
                weights.len()
            )));
        }
        if bias.len() != out_n {
            return Err(Error::Model(format!("{name}: bias count {} != {out_n}", bias.len())));
        }
        let requant = Requantizer::new(input_params.scale, weight_scale, &output_params, relu)?;
        Ok(FullyConnectedOp {
            name: name.to_string(),
            weights,
            bias,
            out_n,
            in_n,
            input_params,
            weight_scale,
            output_params,
            requant,
        })
    }

    /// Hardware input offset.
    #[inline]
    pub fn input_offset(&self) -> i32 {
        -self.input_params.zero_point
    }

    /// Reference forward over a flattened input (batch of vectors
    /// `[N, in_n]` or any shape with `numel = N * in_n`).
    pub fn forward_ref(&self, input: &QTensor) -> Result<QTensor> {
        let numel = input.shape().numel();
        if numel % self.in_n != 0 {
            return Err(Error::Shape(format!(
                "{}: input numel {numel} not divisible by in_n {}",
                self.name, self.in_n
            )));
        }
        let batch = numel / self.in_n;
        let x = input.data();
        let mut out = QTensor::zeros(Shape::d2(batch, self.out_n), self.output_params);
        let offset = self.input_offset();
        for b in 0..batch {
            for o in 0..self.out_n {
                let mut acc = self.bias[o];
                let wrow = &self.weights[o * self.in_n..(o + 1) * self.in_n];
                let xrow = &x[b * self.in_n..(b + 1) * self.in_n];
                for i in 0..self.in_n {
                    acc += wrow[i] as i32 * (xrow[i] as i32 + offset);
                }
                out.set(&[b, o], self.requant.apply(acc));
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op() -> FullyConnectedOp {
        FullyConnectedOp::new(
            "fc",
            vec![1, 2, 3, 4, -1, -2, -3, -4],
            vec![10, -10],
            2,
            4,
            QuantParams::new(1.0, 0).unwrap(),
            1.0,
            QuantParams::new(1.0, 0).unwrap(),
            false,
        )
        .unwrap()
    }

    #[test]
    fn known_values() {
        let input = QTensor::new(
            Shape::d2(1, 4),
            vec![1, 1, 1, 1],
            QuantParams::new(1.0, 0).unwrap(),
        )
        .unwrap();
        let out = op().forward_ref(&input).unwrap();
        // oc0: 1+2+3+4+10 = 20; oc1: -10-10 = -20
        assert_eq!(out.data(), &[20, -20]);
    }

    #[test]
    fn batch_processing() {
        let input = QTensor::new(
            Shape::d2(2, 4),
            vec![1, 0, 0, 0, 0, 1, 0, 0],
            QuantParams::new(1.0, 0).unwrap(),
        )
        .unwrap();
        let out = op().forward_ref(&input).unwrap();
        assert_eq!(out.shape().dims(), &[2, 2]);
        assert_eq!(out.data(), &[11, -11, 12, -12]);
    }

    #[test]
    fn indivisible_input_rejected() {
        let input = QTensor::zeros(Shape::d1(7), QuantParams::new(1.0, 0).unwrap());
        assert!(op().forward_ref(&input).is_err());
    }

    #[test]
    fn construction_validation() {
        assert!(FullyConnectedOp::new(
            "fc",
            vec![0; 7],
            vec![0; 2],
            2,
            4,
            QuantParams::new(1.0, 0).unwrap(),
            1.0,
            QuantParams::new(1.0, 0).unwrap(),
            false
        )
        .is_err());
    }
}
