//! Composable model graphs.
//!
//! A [`Graph`] is a sequence of [`Layer`]s over one streaming activation
//! tensor, with save/add slots for residual connections (sufficient for
//! VGG-style chains, ResNet blocks, and MobileNet inverted residuals).
//! The graph's `forward_ref` runs the golden nn ops; the simulator
//! ([`crate::simulator`]) runs the same graph through the CFU kernels.

use super::activation::{add, relu};
use super::conv2d::Conv2dOp;
use super::fully_connected::FullyConnectedOp;
use super::pooling::{avg_pool2d, global_avg_pool, max_pool2d};
use crate::error::{Error, Result};
use crate::tensor::quant::QuantParams;
use crate::tensor::QTensor;

/// One layer of a model graph.
#[derive(Debug, Clone)]
pub enum Layer {
    /// Convolution (normal or depthwise — the op knows).
    Conv(Conv2dOp),
    /// Fully connected.
    Fc(FullyConnectedOp),
    /// Max pool `k`,`stride`.
    MaxPool {
        /// Window size.
        k: usize,
        /// Stride.
        stride: usize,
    },
    /// Average pool `k`,`stride`.
    AvgPool {
        /// Window size.
        k: usize,
        /// Stride.
        stride: usize,
    },
    /// Global average pool to 1×1.
    GlobalAvgPool,
    /// Standalone ReLU (when not fused).
    Relu,
    /// Save current activation into a residual slot.
    Save(usize),
    /// Shortcut branch: save `conv(current)` (or `current` when `conv` is
    /// `None`) into a slot, leaving the streaming activation unchanged —
    /// ResNet projection shortcuts.
    Shortcut {
        /// Optional 1×1 projection conv applied to the branch.
        conv: Option<Box<Conv2dOp>>,
        /// Destination slot.
        slot: usize,
    },
    /// Add the saved slot into the current activation.
    ResidualAdd {
        /// Slot index to add.
        slot: usize,
        /// Output quantization of the sum.
        out_params: QuantParams,
    },
}

impl Layer {
    /// Layer label for reports.
    pub fn label(&self) -> String {
        match self {
            Layer::Conv(op) => {
                if op.depthwise {
                    format!("dwconv:{}", op.name)
                } else {
                    format!("conv:{}", op.name)
                }
            }
            Layer::Fc(op) => format!("fc:{}", op.name),
            Layer::MaxPool { k, stride } => format!("maxpool{k}s{stride}"),
            Layer::AvgPool { k, stride } => format!("avgpool{k}s{stride}"),
            Layer::GlobalAvgPool => "gap".to_string(),
            Layer::Relu => "relu".to_string(),
            Layer::Save(s) => format!("save{s}"),
            Layer::Shortcut { conv, slot } => match conv {
                Some(op) => format!("proj{slot}:{}", op.name),
                None => format!("shortcut{slot}"),
            },
            Layer::ResidualAdd { slot, .. } => format!("add{slot}"),
        }
    }

    /// Is this a MAC layer the accelerators touch?
    pub fn is_mac_layer(&self) -> bool {
        matches!(
            self,
            Layer::Conv(_) | Layer::Fc(_) | Layer::Shortcut { conv: Some(_), .. }
        )
    }
}

/// A sequential model graph with residual slots.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Model name.
    pub name: String,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
    /// Number of output classes.
    pub classes: usize,
}

impl Graph {
    /// New graph.
    pub fn new(name: &str, layers: Vec<Layer>, classes: usize) -> Self {
        Graph { name: name.to_string(), layers, classes }
    }

    /// Number of MAC layers (conv + fc).
    pub fn mac_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.is_mac_layer()).count()
    }

    /// Total MAC-layer weights.
    pub fn total_weights(&self) -> usize {
        self.mac_weights().iter().map(|ws| ws.len()).sum()
    }

    /// Weight slices of the MAC layers, in the canonical graph order
    /// every per-layer consumer indexes by (the simulator's `mac_idx`
    /// walk, sparsity plans, the explorer's cost matrix).
    pub fn mac_weights(&self) -> Vec<&[i8]> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv(op) => Some(op.weights.as_slice()),
                Layer::Fc(op) => Some(op.weights.as_slice()),
                Layer::Shortcut { conv: Some(op), .. } => Some(op.weights.as_slice()),
                _ => None,
            })
            .collect()
    }

    /// Mutable counterpart of [`Graph::mac_weights`] — same layers, same
    /// order.
    pub fn mac_weights_mut(&mut self) -> Vec<&mut Vec<i8>> {
        self.layers
            .iter_mut()
            .filter_map(|l| match l {
                Layer::Conv(op) => Some(&mut op.weights),
                Layer::Fc(op) => Some(&mut op.weights),
                Layer::Shortcut { conv: Some(op), .. } => Some(&mut op.weights),
                _ => None,
            })
            .collect()
    }

    /// Golden forward pass.
    pub fn forward_ref(&self, input: &QTensor) -> Result<QTensor> {
        let mut cur = input.clone();
        let mut slots: Vec<Option<QTensor>> = vec![None; 8];
        for layer in &self.layers {
            cur = match layer {
                Layer::Conv(op) => op.forward_ref(&cur)?,
                Layer::Fc(op) => op.forward_ref(&cur)?,
                Layer::MaxPool { k, stride } => max_pool2d(&cur, *k, *stride)?,
                Layer::AvgPool { k, stride } => avg_pool2d(&cur, *k, *stride)?,
                Layer::GlobalAvgPool => global_avg_pool(&cur)?,
                Layer::Relu => relu(&cur),
                Layer::Save(s) => {
                    if *s >= slots.len() {
                        return Err(Error::Model(format!("slot {s} out of range")));
                    }
                    slots[*s] = Some(cur.clone());
                    cur
                }
                Layer::Shortcut { conv, slot } => {
                    if *slot >= slots.len() {
                        return Err(Error::Model(format!("slot {slot} out of range")));
                    }
                    slots[*slot] = Some(match conv {
                        Some(op) => op.forward_ref(&cur)?,
                        None => cur.clone(),
                    });
                    cur
                }
                Layer::ResidualAdd { slot, out_params } => {
                    let saved = slots[*slot]
                        .as_ref()
                        .ok_or_else(|| Error::Model(format!("slot {slot} empty at add")))?;
                    add(&cur, saved, *out_params)?
                }
            };
        }
        Ok(cur)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv2d::Padding;
    use crate::tensor::Shape;

    fn identity_params() -> QuantParams {
        QuantParams::new(1.0, 0).unwrap()
    }

    fn pointwise(name: &str, weights: Vec<i8>, out_c: usize, in_c: usize) -> Conv2dOp {
        Conv2dOp::new(
            name,
            weights,
            vec![0; out_c],
            out_c,
            in_c,
            1,
            1,
            1,
            Padding::Valid,
            false,
            identity_params(),
            1.0,
            identity_params(),
            false,
        )
        .unwrap()
    }

    #[test]
    fn sequential_pipeline() {
        // conv (identity on ch0..3) → maxpool 2x2
        let mut w = vec![0i8; 4 * 4];
        for i in 0..4 {
            w[i * 4 + i] = 1;
        }
        let g = Graph::new(
            "t",
            vec![Layer::Conv(pointwise("c", w, 4, 4)), Layer::MaxPool { k: 2, stride: 2 }],
            4,
        );
        let input = QTensor::new(
            Shape::nhwc(1, 2, 2, 4),
            (0..16).map(|i| i as i8).collect(),
            identity_params(),
        )
        .unwrap();
        let out = g.forward_ref(&input).unwrap();
        assert_eq!(out.shape().dims(), &[1, 1, 1, 4]);
        assert_eq!(out.data(), &[12, 13, 14, 15]);
    }

    #[test]
    fn residual_roundtrip() {
        // save → conv(zero weights) → add slot ⇒ output ≈ input
        let g = Graph::new(
            "res",
            vec![
                Layer::Save(0),
                Layer::Conv(pointwise("z", vec![0; 16], 4, 4)),
                Layer::ResidualAdd { slot: 0, out_params: identity_params() },
            ],
            4,
        );
        let input = QTensor::new(
            Shape::nhwc(1, 1, 1, 4),
            vec![5, -6, 7, -8],
            identity_params(),
        )
        .unwrap();
        let out = g.forward_ref(&input).unwrap();
        for (a, b) in out.data().iter().zip(input.data()) {
            assert!((*a as i32 - *b as i32).abs() <= 1, "{a} vs {b}");
        }
    }

    #[test]
    fn empty_slot_errors() {
        let g = Graph::new(
            "bad",
            vec![Layer::ResidualAdd { slot: 0, out_params: identity_params() }],
            2,
        );
        let input = QTensor::zeros(Shape::nhwc(1, 1, 1, 4), identity_params());
        assert!(g.forward_ref(&input).is_err());
    }

    #[test]
    fn stats() {
        let g = Graph::new(
            "s",
            vec![
                Layer::Conv(pointwise("a", vec![0; 16], 4, 4)),
                Layer::Relu,
                Layer::Conv(pointwise("b", vec![0; 16], 4, 4)),
            ],
            4,
        );
        assert_eq!(g.mac_layers(), 2);
        assert_eq!(g.total_weights(), 32);
    }
}
