//! TFLite-style INT8 neural-network operators (reference semantics).
//!
//! These are the *golden* implementations: plain nested loops with
//! bit-exact TFLite arithmetic (i32 accumulation, gemmlowp
//! requantization). The CFU-accelerated kernels in [`crate::kernels`]
//! must produce byte-identical outputs — that equivalence is asserted in
//! tests and (optionally) at simulation time.
//!
//! Layer inventory (what the paper's four models need):
//! conv2d, depthwise conv2d, fully connected, max/avg pooling, ReLU
//! (fused into requantization), residual add, and softmax.

pub mod activation;
pub mod conv2d;
pub mod fully_connected;
pub mod graph;
pub mod pooling;

pub use conv2d::{Conv2dOp, Padding};
pub use fully_connected::FullyConnectedOp;
pub use graph::{Graph, Layer};
pub use pooling::{avg_pool2d, max_pool2d};
