//! Standalone activations: ReLU (when not fused into requantization),
//! residual add, and softmax.

use crate::error::{Error, Result};
use crate::tensor::quant::{
    multiply_by_quantized_multiplier, quantize_multiplier, QuantParams,
};
use crate::tensor::QTensor;

/// Elementwise ReLU in the quantized domain: clamp at the zero point.
pub fn relu(input: &QTensor) -> QTensor {
    let zp = input.params().zero_point.clamp(-128, 127) as i8;
    let mut out = input.clone();
    for v in out.data_mut() {
        if *v < zp {
            *v = zp;
        }
    }
    out
}

/// Quantized residual add (TFLite ADD): rescale both operands to the
/// output scale in i32, add, then clamp. Uses a left-shift of 20 bits of
/// headroom like the TFLite kernel.
pub fn add(a: &QTensor, b: &QTensor, out_params: QuantParams) -> Result<QTensor> {
    if a.shape() != b.shape() {
        return Err(Error::Shape(format!("add shapes differ: {} vs {}", a.shape(), b.shape())));
    }
    const LEFT_SHIFT: i32 = 20;
    let twice_max = 2.0 * a.params().scale.max(b.params().scale) as f64;
    let (mult_a, shift_a) =
        quantize_multiplier(a.params().scale as f64 / twice_max)?;
    let (mult_b, shift_b) =
        quantize_multiplier(b.params().scale as f64 / twice_max)?;
    let (mult_out, shift_out) =
        quantize_multiplier(twice_max / ((1i64 << LEFT_SHIFT) as f64 * out_params.scale as f64))?;
    let mut out = QTensor::zeros(a.shape().clone(), out_params);
    let az = a.params().zero_point;
    let bz = b.params().zero_point;
    let data: Vec<i8> = a
        .data()
        .iter()
        .zip(b.data())
        .map(|(&qa, &qb)| {
            let sa = (qa as i32 - az) << LEFT_SHIFT;
            let sb = (qb as i32 - bz) << LEFT_SHIFT;
            let ra = multiply_by_quantized_multiplier(sa, mult_a, shift_a);
            let rb = multiply_by_quantized_multiplier(sb, mult_b, shift_b);
            let sum = ra + rb;
            let res = multiply_by_quantized_multiplier(sum, mult_out, shift_out)
                + out_params.zero_point;
            res.clamp(-128, 127) as i8
        })
        .collect();
    out.data_mut().copy_from_slice(&data);
    Ok(out)
}

/// Softmax over the last dimension, computed in f32 on dequantized
/// logits (the classification head; not on the accelerated path).
pub fn softmax_f32(logits: &QTensor, classes: usize) -> Result<Vec<f32>> {
    let numel = logits.shape().numel();
    if numel % classes != 0 {
        return Err(Error::Shape(format!(
            "softmax: numel {numel} not divisible by classes {classes}"
        )));
    }
    let reals = logits.to_f32();
    let mut out = Vec::with_capacity(numel);
    for row in reals.chunks(classes) {
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|&x| (x - m).exp()).collect();
        let s: f32 = exps.iter().sum();
        out.extend(exps.iter().map(|&e| e / s));
    }
    Ok(out)
}

/// Argmax per row of the last dimension.
pub fn argmax(logits: &QTensor, classes: usize) -> Result<Vec<usize>> {
    let numel = logits.shape().numel();
    if numel % classes != 0 {
        return Err(Error::Shape(format!(
            "argmax: numel {numel} not divisible by classes {classes}"
        )));
    }
    Ok(logits
        .data()
        .chunks(classes)
        .map(|row| {
            row.iter().enumerate().max_by_key(|&(i, &v)| (v, std::cmp::Reverse(i))).unwrap().0
        })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Shape;

    #[test]
    fn relu_clamps_at_zero_point() {
        let p = QuantParams::new(0.5, -10).unwrap();
        let t = QTensor::new(Shape::d1(4), vec![-50, -10, 0, 50], p).unwrap();
        let r = relu(&t);
        assert_eq!(r.data(), &[-10, -10, 0, 50]);
    }

    #[test]
    fn add_matches_real_arithmetic() {
        let pa = QuantParams::new(0.1, 0).unwrap();
        let pb = QuantParams::new(0.05, 10).unwrap();
        let po = QuantParams::new(0.1, -5).unwrap();
        let a = QTensor::new(Shape::d1(3), vec![10, -20, 50], pa).unwrap(); // 1.0, -2.0, 5.0
        let b = QTensor::new(Shape::d1(3), vec![30, 10, -30], pb).unwrap(); // 1.0, 0.0, -2.0
        let out = add(&a, &b, po).unwrap();
        let real = out.to_f32();
        for (got, expect) in real.iter().zip([2.0f32, -2.0, 3.0]) {
            assert!((got - expect).abs() < 0.1, "{got} vs {expect}");
        }
    }

    #[test]
    fn add_shape_mismatch_rejected() {
        let p = QuantParams::new(0.1, 0).unwrap();
        let a = QTensor::zeros(Shape::d1(3), p);
        let b = QTensor::zeros(Shape::d1(4), p);
        assert!(add(&a, &b, p).is_err());
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = QuantParams::new(0.25, 0).unwrap();
        let t = QTensor::new(Shape::d2(1, 4), vec![0, 10, 20, 5], p).unwrap();
        let probs = softmax_f32(&t, 4).unwrap();
        let s: f32 = probs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(probs[2] > probs[1] && probs[1] > probs[3] && probs[3] > probs[0]);
    }

    #[test]
    fn argmax_rows() {
        let p = QuantParams::new(1.0, 0).unwrap();
        let t = QTensor::new(Shape::d2(2, 3), vec![1, 5, 3, 9, 2, 9], p).unwrap();
        assert_eq!(argmax(&t, 3).unwrap(), vec![1, 0]); // tie → first index
    }
}
