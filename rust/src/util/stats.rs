//! Streaming statistics and percentile helpers used by the bench harness,
//! the simulator metrics, and the coordinator.

/// Welford online mean/variance accumulator.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        OnlineStats { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Unbiased sample variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    /// Sample standard deviation.
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum observation (NaN if empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Maximum observation (NaN if empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Merge another accumulator (parallel reduction).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n as f64;
        let m2 =
            self.m2 + other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Exact percentiles over a stored sample (fine for bench-scale data).
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    xs: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    /// Empty sample.
    pub fn new() -> Self {
        Percentiles { xs: Vec::new(), sorted: true }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
        self.sorted = false;
    }

    /// Number of observations.
    pub fn count(&self) -> usize {
        self.xs.len()
    }

    fn ensure_sorted(&mut self) {
        if !self.sorted {
            // `total_cmp` is a total order over every f64 (NaN sorts after
            // +inf), so a stray non-finite sample can never panic the sort
            // mid-serve; `partial_cmp(..).unwrap()` would.
            self.xs.sort_by(f64::total_cmp);
            self.sorted = true;
        }
    }

    /// Percentile `q` in [0, 100] with linear interpolation.
    pub fn percentile(&mut self, q: f64) -> f64 {
        if self.xs.is_empty() {
            return f64::NAN;
        }
        self.ensure_sorted();
        let q = q.clamp(0.0, 100.0) / 100.0;
        let pos = q * (self.xs.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            self.xs[lo]
        } else {
            let frac = pos - lo as f64;
            self.xs[lo] * (1.0 - frac) + self.xs[hi] * frac
        }
    }

    /// Median shortcut.
    pub fn median(&mut self) -> f64 {
        self.percentile(50.0)
    }
}

/// Geometric mean of a slice (used for model-level speedup summaries).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Relative error |a-b| / max(|b|, eps).
pub fn rel_err(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_mean_var() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
        assert_eq!(s.count(), 8);
    }

    #[test]
    fn merge_matches_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = OnlineStats::new();
        for &x in &xs {
            all.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &xs[..37] {
            a.push(x);
        }
        for &x in &xs[37..] {
            b.push(x);
        }
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.variance() - all.variance()).abs() < 1e-9);
    }

    #[test]
    fn empty_stats() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert!(s.min().is_nan());
    }

    #[test]
    fn percentiles_basic() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert!((p.median() - 50.5).abs() < 1e-9);
        assert!((p.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((p.percentile(100.0) - 100.0).abs() < 1e-9);
        assert!((p.percentile(99.0) - 99.01).abs() < 0.011);
    }

    #[test]
    fn percentiles_survive_nan_samples() {
        // A NaN sample must not panic the sort (the old
        // `partial_cmp().unwrap()` did) and must sort deterministically
        // to the top under `total_cmp`, leaving low percentiles exact.
        let mut p = Percentiles::new();
        for x in [3.0, f64::NAN, 1.0, 2.0] {
            p.push(x);
        }
        assert_eq!(p.percentile(0.0), 1.0);
        assert!((p.median() - 2.5).abs() < 1e-9);
        assert!(p.percentile(100.0).is_nan());
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn rel_err_basic() {
        assert!((rel_err(1.1, 1.0) - 0.1).abs() < 1e-9);
        assert!(rel_err(0.0, 0.0) < 1e-9);
    }
}
