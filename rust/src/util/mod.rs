//! Small self-contained substrates: deterministic PRNG, statistics,
//! logging, and a property-testing helper.
//!
//! The build environment is offline (no `rand`, `proptest`, `env_logger`
//! crates), so these are implemented from scratch. All randomness in the
//! repository flows through [`Pcg32`] seeded explicitly, making every
//! experiment bit-reproducible.

pub mod logging;
pub mod prng;
pub mod proptest;
pub mod stats;

pub use prng::Pcg32;
pub use stats::{OnlineStats, Percentiles};
