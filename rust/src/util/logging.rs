//! Minimal `log` facade backend (no `env_logger` offline).
//!
//! Writes `LEVEL target: message` lines to stderr; level filtered by the
//! `SPARSE_RISCV_LOG` environment variable (error|warn|info|debug|trace,
//! default info).

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

struct StderrLogger {
    max: Level,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            eprintln!("{:5} {}: {}", record.level(), record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: OnceCell<StderrLogger> = OnceCell::new();

/// Install the stderr logger. Idempotent; safe to call from every
/// binary/test entry point.
pub fn init() {
    let level = match std::env::var("SPARSE_RISCV_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger { max: level });
    // set_logger fails if already set — that's fine (tests call init many times).
    let _ = log::set_logger(logger);
    log::set_max_level(LevelFilter::Trace);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
