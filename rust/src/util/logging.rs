//! Minimal stderr logger (no `log`/`env_logger`/`once_cell` offline).
//!
//! Writes `LEVEL target: message` lines to stderr; level filtered by the
//! `SPARSE_RISCV_LOG` environment variable (error|warn|info|debug|trace,
//! default info). The filter is latched on first use so logging is cheap
//! and race-free across worker threads.

use std::sync::OnceLock;

/// Log severity, most severe first (derived `Ord`: `Error < Trace`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable or surprising failures.
    Error,
    /// Suspicious but non-fatal conditions.
    Warn,
    /// High-level progress (default).
    Info,
    /// Detailed diagnostics.
    Debug,
    /// Firehose.
    Trace,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: OnceLock<Level> = OnceLock::new();

fn level_from_env() -> Level {
    match std::env::var("SPARSE_RISCV_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    }
}

/// Install the stderr logger. Idempotent; safe to call from every
/// binary/test entry point. (Without an explicit call, the first log
/// statement latches the level lazily.)
pub fn init() {
    let _ = MAX_LEVEL.set(level_from_env());
}

/// Is a message at `level` currently emitted?
pub fn enabled(level: Level) -> bool {
    level <= *MAX_LEVEL.get_or_init(level_from_env)
}

/// Emit one log line (filtered by the latched level).
pub fn log(level: Level, target: &str, msg: &str) {
    if enabled(level) {
        eprintln!("{:5} {}: {}", level.label(), target, msg);
    }
}

/// `error`-level shortcut.
pub fn error(target: &str, msg: &str) {
    log(Level::Error, target, msg);
}

/// `warn`-level shortcut.
pub fn warn(target: &str, msg: &str) {
    log(Level::Warn, target, msg);
}

/// `info`-level shortcut.
pub fn info(target: &str, msg: &str) {
    log(Level::Info, target, msg);
}

/// `debug`-level shortcut.
pub fn debug(target: &str, msg: &str) {
    log(Level::Debug, target, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        init();
        init();
        info("logging", "smoke test");
    }

    #[test]
    fn severity_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Info < Level::Trace);
        // Error is always emitted regardless of the latched filter.
        assert!(enabled(Level::Error));
    }
}
