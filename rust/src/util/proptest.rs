//! Tiny property-based testing helper (offline substitute for `proptest`).
//!
//! [`check`] runs a property over `n` generated cases; on failure it
//! performs greedy shrinking via the case's [`Shrink`] implementation and
//! panics with the minimal counterexample. Generators are plain closures
//! over [`Pcg32`], so properties stay readable:
//!
//! ```text
//! use sparse_riscv::util::proptest::{check, Config};
//! check(Config::default().cases(64), |rng| rng.range_i32(-128, 127),
//!       |&w| (w as i32) >= -128 && (w as i32) <= 127);
//! ```

use super::prng::Pcg32;

/// Shrinkable test case: yields strictly "smaller" candidate values.
pub trait Shrink: Sized {
    /// Candidate smaller values (tried in order).
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for i32 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            if *self < 0 {
                out.push(-self);
            }
            out.push(self - self.signum());
        }
        out
    }
}

impl Shrink for u32 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out
    }
}

impl Shrink for f64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0.0 {
            out.push(0.0);
            out.push(self / 2.0);
        }
        out
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Halve the vector.
        out.push(self[..self.len() / 2].to_vec());
        // Drop first / last element.
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        // Shrink each element once.
        for i in 0..self.len().min(8) {
            for cand in self[i].shrink() {
                let mut v = self.clone();
                v[i] = cand;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        for a in self.0.shrink() {
            out.push((a, self.1.clone()));
        }
        for b in self.1.shrink() {
            out.push((self.0.clone(), b));
        }
        out
    }
}

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of generated cases.
    pub cases: usize,
    /// PRNG seed (tests are deterministic).
    pub seed: u64,
    /// Maximum shrink iterations.
    pub max_shrink: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 256, seed: 0xC0FFEE, max_shrink: 1000 }
    }
}

impl Config {
    /// Override case count.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Override seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop` over `cfg.cases` values from `gen`; panic with a shrunk
/// counterexample on failure.
pub fn check<T, G, P>(cfg: Config, mut gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: FnMut(&mut Pcg32) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Pcg32::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let value = gen(&mut rng);
        if prop(&value) {
            continue;
        }
        // Shrink.
        let mut minimal = value.clone();
        let mut budget = cfg.max_shrink;
        'outer: while budget > 0 {
            for cand in minimal.shrink() {
                budget -= 1;
                if !prop(&cand) {
                    minimal = cand;
                    continue 'outer;
                }
                if budget == 0 {
                    break;
                }
            }
            break;
        }
        panic!(
            "property failed at case {case_idx}\n  original: {value:?}\n  shrunk:   {minimal:?}"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check(Config::default().cases(128), |r| r.range_i32(-100, 100), |&x| x >= -100 && x <= 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        check(Config::default().cases(512), |r| r.range_i32(0, 1000), |&x| x < 900);
    }

    #[test]
    fn shrink_i32_reaches_zero() {
        // property "x < 1" fails for any x >= 1; the shrinker should land on 1.
        let result = std::panic::catch_unwind(|| {
            check(Config::default().cases(512), |r| r.range_i32(0, 1000), |&x| x < 1);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("shrunk:   1"), "minimal counterexample should be 1, got: {msg}");
    }

    #[test]
    fn vec_shrink_is_smaller() {
        let v = vec![5i32, 6, 7, 8];
        for cand in v.shrink() {
            assert!(
                cand.len() < v.len() || cand.iter().zip(&v).any(|(a, b)| a != b),
                "shrink must change something"
            );
        }
    }
}
