//! Deterministic pseudo-random number generation.
//!
//! PCG32 (O'Neill 2014) seeded through SplitMix64, matching the reference
//! implementation constants. No external crates; every experiment in the
//! repo is reproducible from an explicit `u64` seed.

/// SplitMix64 step — used to expand a user seed into PCG state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG32 (XSH-RR variant): 64-bit state, 32-bit output.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6_364_136_223_846_793_005;

impl Pcg32 {
    /// Create a generator from a seed. Stream constant is derived from the
    /// seed via SplitMix64 so different seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1; // must be odd
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(init_state);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-worker streams).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        let s = (self.next_u64()).wrapping_add(tag.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Pcg32::new(s)
    }

    /// Next uniform `u32`.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next uniform `u64`.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform float in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 high bits → mantissa-exact uniform in [0,1)
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform float in `[0, 1)` with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u32) -> u32 {
        debug_assert!(bound > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64) * (bound as u64);
        let mut l = m as u32;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u32();
                m = (x as u64) * (bound as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        debug_assert!(lo <= hi);
        let span = (hi as i64 - lo as i64 + 1) as u32;
        lo.wrapping_add(self.below(span) as i32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity; this is not a hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k ≤ n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k ({k}) > n ({n})");
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: first k entries are the sample.
        for i in 0..k {
            let j = i + self.below((n - i) as u32) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should diverge, {same} collisions");
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut r = Pcg32::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.range_i32(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn bernoulli_rate_close() {
        let mut r = Pcg32::new(11);
        let n = 50_000;
        let hits = (0..n).filter(|_| r.bernoulli(0.3)).count();
        let rate = hits as f64 / n as f64;
        assert!((rate - 0.3).abs() < 0.01, "rate {rate}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::new(19);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let mut dedup = s.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), 30);
        assert!(dedup.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Pcg32::new(23);
        let mut c1 = parent.fork(0);
        let mut c2 = parent.fork(1);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
