//! Engine v2 substrate: the [`ExecBackend`] trait unifying the accelerator
//! designs behind one execution interface, plus a concurrent
//! prepared-model cache.
//!
//! The paper's flow prepares a model *once* per design (INT7 clamp +
//! lookahead encode + word packing — "bitstream build time") and then
//! serves many inferences against the prepared form. Engine v2 makes that
//! explicit at the system level:
//!
//! - [`ExecBackend`] is the design-agnostic contract (`prepare` once,
//!   `execute` many) that the batch engine, the experiment runner and the
//!   server all drive. [`crate::simulator::SimEngine`] is the cycle-model
//!   implementation; future backends (e.g. a host-native fast-math path
//!   or an RTL co-simulation bridge) plug in here without touching the
//!   coordinator.
//! - [`PreparedCache`] memoizes prepared models keyed by
//!   [`ModelKey`] — (model, design, sparsity config, scale, weight seed) —
//!   so repeated batches, sweeps and multi-design comparisons pay the
//!   (deterministic) build + encode cost once per configuration.

use crate::error::Result;
use crate::isa::DesignKind;
use crate::nn::graph::Graph;
use crate::simulator::{PreparedModel, SimEngine, SimReport};
use crate::tensor::QTensor;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A design-agnostic execution backend: prepare a model once, execute
/// many inferences against the prepared form.
pub trait ExecBackend: Send + Sync {
    /// The accelerator design this backend simulates.
    fn design(&self) -> DesignKind;

    /// Offline preparation (weight packing / lookahead encoding). Not
    /// charged to inference cycles.
    fn prepare(&self, graph: &Graph) -> Result<PreparedModel>;

    /// Run one inference against a prepared model.
    fn execute(&self, model: &PreparedModel, input: &QTensor) -> Result<SimReport>;
}

impl ExecBackend for SimEngine {
    fn design(&self) -> DesignKind {
        self.design
    }

    fn prepare(&self, graph: &Graph) -> Result<PreparedModel> {
        SimEngine::prepare(self, graph)
    }

    fn execute(&self, model: &PreparedModel, input: &QTensor) -> Result<SimReport> {
        SimEngine::run(self, model, input)
    }
}

/// Build the default (cycle-model) backend for a design.
pub fn backend_for(design: DesignKind) -> Box<dyn ExecBackend> {
    Box::new(SimEngine::new(design))
}

/// [`backend_for`] with bit-exact verification against the reference ops.
pub fn verified_backend_for(design: DesignKind, verify: bool) -> Box<dyn ExecBackend> {
    Box::new(SimEngine::new(design).with_verify(verify))
}

/// Cache key identifying one prepared model. Sparsity ratios and the
/// width multiplier are keyed by their IEEE-754 bit patterns: model
/// construction and magnitude pruning are fully deterministic in these
/// parameters, so bit-equal inputs produce bit-equal prepared models.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelKey {
    /// Model zoo identifier.
    pub model: String,
    /// Accelerator design the weights are packed for.
    pub design: DesignKind,
    /// `f64::to_bits` of the unstructured sparsity ratio.
    pub x_us_bits: u64,
    /// `f64::to_bits` of the 4:4 block sparsity ratio.
    pub x_ss_bits: u64,
    /// `f64::to_bits` of the width multiplier.
    pub scale_bits: u64,
    /// Weight RNG seed.
    pub weight_seed: u64,
}

impl ModelKey {
    /// Key a configuration.
    pub fn new(
        model: &str,
        design: DesignKind,
        x_us: f64,
        x_ss: f64,
        scale: f64,
        weight_seed: u64,
    ) -> Self {
        ModelKey {
            model: model.to_string(),
            design,
            x_us_bits: x_us.to_bits(),
            x_ss_bits: x_ss.to_bits(),
            scale_bits: scale.to_bits(),
            weight_seed,
        }
    }
}

/// Thread-safe memoization of prepared models.
///
/// The build closure runs *outside* the lock so distinct configurations
/// prepare concurrently on the worker pool; a lost race simply discards
/// the duplicate (prepared models are deterministic, so either copy is
/// correct).
#[derive(Default)]
pub struct PreparedCache {
    map: Mutex<HashMap<ModelKey, Arc<PreparedModel>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PreparedCache {
    /// Empty cache.
    pub fn new() -> Self {
        PreparedCache::default()
    }

    /// Look up `key`, building (and inserting) the prepared model on a
    /// miss. Returns the shared model plus whether this call hit.
    pub fn get_or_prepare<F>(&self, key: &ModelKey, build: F) -> Result<(Arc<PreparedModel>, bool)>
    where
        F: FnOnce() -> Result<PreparedModel>,
    {
        if let Some(found) = self.map.lock().unwrap().get(key) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok((Arc::clone(found), true));
        }
        // Build without holding the lock (encoding a large model is the
        // expensive part; concurrent misses on different keys must not
        // serialize).
        let built = Arc::new(build()?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().unwrap();
        let entry = map.entry(key.clone()).or_insert_with(|| Arc::clone(&built));
        Ok((Arc::clone(entry), false))
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (i.e. prepared-model builds) so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of cached prepared models.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached model (e.g. between sweeps over different
    /// weight seeds).
    pub fn clear(&self) {
        self.map.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::{apply_sparsity, ModelConfig};
    use crate::models::zoo::build_model;

    fn tiny_graph() -> Graph {
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, 0.5, 0.3);
        info.graph
    }

    #[test]
    fn backend_trait_matches_engine() {
        let graph = tiny_graph();
        let backend = backend_for(DesignKind::Csa);
        assert_eq!(backend.design(), DesignKind::Csa);
        let prepared = backend.prepare(&graph).unwrap();
        let engine = SimEngine::new(DesignKind::Csa);
        let direct = engine.prepare(&graph).unwrap();
        let mut rng = crate::util::Pcg32::new(4);
        let input = crate::models::builder::random_input(
            crate::models::zoo::input_shape("dscnn").unwrap(),
            crate::tensor::quant::QuantParams::new(0.05, 0).unwrap(),
            &mut rng,
        );
        let a = backend.execute(&prepared, &input).unwrap();
        let b = engine.run(&direct, &input).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.output.data(), b.output.data());
    }

    #[test]
    fn cache_hits_after_first_prepare() {
        let graph = tiny_graph();
        let cache = PreparedCache::new();
        let key = ModelKey::new("dscnn", DesignKind::Csa, 0.5, 0.3, 0.07, 0x5EED);
        let backend = backend_for(DesignKind::Csa);
        let (_, hit0) = cache.get_or_prepare(&key, || backend.prepare(&graph)).unwrap();
        let (_, hit1) = cache.get_or_prepare(&key, || backend.prepare(&graph)).unwrap();
        assert!(!hit0);
        assert!(hit1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn distinct_designs_are_distinct_keys() {
        let a = ModelKey::new("dscnn", DesignKind::Csa, 0.5, 0.3, 0.25, 1);
        let b = ModelKey::new("dscnn", DesignKind::Ussa, 0.5, 0.3, 0.25, 1);
        let c = ModelKey::new("dscnn", DesignKind::Csa, 0.5, 0.3, 0.25, 2);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, a.clone());
    }
}
