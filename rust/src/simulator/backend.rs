//! Engine v2 substrate: the [`ExecBackend`] trait unifying the accelerator
//! designs behind one execution interface, plus a concurrent
//! prepared-model cache.
//!
//! The paper's flow prepares a model *once* per design (INT7 clamp +
//! lookahead encode + word packing — "bitstream build time") and then
//! serves many inferences against the prepared form. Engine v2 makes that
//! explicit at the system level:
//!
//! - [`ExecBackend`] is the design-agnostic contract (`prepare` once,
//!   `execute` many) that the batch engine, the experiment runner and the
//!   server all drive. [`crate::simulator::SimEngine`] is the cycle-model
//!   implementation; future backends (e.g. a host-native fast-math path
//!   or an RTL co-simulation bridge) plug in here without touching the
//!   coordinator.
//! - [`PreparedCache`] memoizes prepared models keyed by
//!   [`ModelKey`] — (model, per-layer design assignment, sparsity
//!   config, scale, weight seed) — so repeated batches, sweeps and
//!   multi-design comparisons pay the (deterministic) build + encode
//!   cost once per configuration. Heterogeneous assignments key by the
//!   full per-layer vector, so two assignments differing in one layer
//!   never alias.

use crate::error::Result;
use crate::isa::{DesignAssignment, DesignKind};
use crate::kernels::{ExecMode, HostKernel};
use crate::nn::graph::Graph;
use crate::simulator::{PreparedModel, SimEngine, SimReport};
use crate::tensor::QTensor;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// A design-agnostic execution backend: prepare a model once, execute
/// many inferences against the prepared form.
pub trait ExecBackend: Send + Sync {
    /// The per-layer design assignment this backend simulates (uniform
    /// for the paper's model-wide designs).
    fn assignment(&self) -> DesignAssignment;

    /// Offline preparation (weight packing / lookahead encoding). Not
    /// charged to inference cycles.
    fn prepare(&self, graph: &Graph) -> Result<PreparedModel>;

    /// Run one inference against a prepared model.
    fn execute(&self, model: &PreparedModel, input: &QTensor) -> Result<SimReport>;
}

impl ExecBackend for SimEngine {
    fn assignment(&self) -> DesignAssignment {
        self.assignment.clone()
    }

    fn prepare(&self, graph: &Graph) -> Result<PreparedModel> {
        SimEngine::prepare(self, graph)
    }

    fn execute(&self, model: &PreparedModel, input: &QTensor) -> Result<SimReport> {
        SimEngine::run(self, model, input)
    }
}

/// Build the default (cycle-model) backend for a uniform design.
pub fn backend_for(design: DesignKind) -> Box<dyn ExecBackend> {
    Box::new(SimEngine::new(design))
}

/// [`backend_for`] with bit-exact verification against the reference ops.
pub fn verified_backend_for(design: DesignKind, verify: bool) -> Box<dyn ExecBackend> {
    Box::new(SimEngine::new(design).with_verify(verify))
}

/// Backend with explicit verification and lane execution mode.
pub fn backend_with_mode(
    design: DesignKind,
    verify: bool,
    mode: ExecMode,
) -> Box<dyn ExecBackend> {
    assigned_backend_with_mode(&DesignAssignment::Uniform(design), verify, mode)
}

/// Backend executing a (possibly heterogeneous) per-layer assignment
/// with explicit verification and lane execution mode.
pub fn assigned_backend_with_mode(
    assignment: &DesignAssignment,
    verify: bool,
    mode: ExecMode,
) -> Box<dyn ExecBackend> {
    assigned_backend_tiled(assignment, verify, mode, None)
}

/// [`assigned_backend_with_mode`] with optional intra-layer lane tiling:
/// when a [`crate::coordinator::TilePool`] is supplied (and the mode is
/// the batched default), every MAC layer of a single inference splits
/// its lane dimension across the pool's workers — outputs and cycle
/// totals are invariant in the tile count.
pub fn assigned_backend_tiled(
    assignment: &DesignAssignment,
    verify: bool,
    mode: ExecMode,
    tiling: Option<crate::coordinator::scheduler::TilePool>,
) -> Box<dyn ExecBackend> {
    assigned_backend_full(assignment, verify, mode, tiling, HostKernel::Auto)
}

/// The fully-explicit backend constructor: assignment, verification,
/// lane execution mode, optional intra-layer tiling, and the host-side
/// multiply kernel for the batched path ([`HostKernel`] — host
/// throughput only; outputs and simulated cycles are invariant in it).
pub fn assigned_backend_full(
    assignment: &DesignAssignment,
    verify: bool,
    mode: ExecMode,
    tiling: Option<crate::coordinator::scheduler::TilePool>,
    host_kernel: HostKernel,
) -> Box<dyn ExecBackend> {
    Box::new(
        SimEngine::for_assignment(assignment.clone())
            .with_verify(verify)
            .with_exec_mode(mode)
            .with_tiling(tiling)
            .with_host_kernel(host_kernel),
    )
}

/// The interpreted-oracle backend: per-instruction CFU dispatch — the
/// reference the compiled default path is differentially tested against.
pub fn oracle_backend_for(design: DesignKind) -> Box<dyn ExecBackend> {
    backend_with_mode(design, false, ExecMode::Interpreted)
}

/// Cache key identifying one prepared model. Sparsity ratios and the
/// width multiplier are keyed by their IEEE-754 bit patterns: model
/// construction and magnitude pruning are fully deterministic in these
/// parameters, so bit-equal inputs produce bit-equal prepared models.
///
/// The design component is the full per-layer [`DesignAssignment`]
/// (structural equality/hashing): two assignments differing in even one
/// layer are distinct keys, while `Uniform(d)` and an all-`d` per-layer
/// vector canonicalize to the same key (identical prepared weights).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ModelKey {
    /// Model zoo identifier.
    pub model: String,
    /// Per-layer assignment the weights are packed for.
    pub assignment: DesignAssignment,
    /// `f64::to_bits` of the unstructured sparsity ratio.
    pub x_us_bits: u64,
    /// `f64::to_bits` of the 4:4 block sparsity ratio.
    pub x_ss_bits: u64,
    /// `f64::to_bits` of the width multiplier.
    pub scale_bits: u64,
    /// Weight RNG seed.
    pub weight_seed: u64,
}

impl ModelKey {
    /// Key a uniform-design configuration.
    pub fn new(
        model: &str,
        design: DesignKind,
        x_us: f64,
        x_ss: f64,
        scale: f64,
        weight_seed: u64,
    ) -> Self {
        ModelKey::assigned(model, DesignAssignment::Uniform(design), x_us, x_ss, scale, weight_seed)
    }

    /// Key a per-layer assignment configuration.
    pub fn assigned(
        model: &str,
        assignment: DesignAssignment,
        x_us: f64,
        x_ss: f64,
        scale: f64,
        weight_seed: u64,
    ) -> Self {
        ModelKey {
            model: model.to_string(),
            assignment,
            x_us_bits: x_us.to_bits(),
            x_ss_bits: x_ss.to_bits(),
            scale_bits: scale.to_bits(),
            weight_seed,
        }
    }
}

/// One cached prepared model plus its recency stamp.
struct CacheEntry {
    model: Arc<PreparedModel>,
    last_used: u64,
}

/// Map + logical clock behind the cache mutex.
struct CacheInner {
    map: HashMap<ModelKey, CacheEntry>,
    tick: u64,
}

/// Thread-safe, LRU-bounded memoization of prepared models.
///
/// The build closure runs *outside* the lock so distinct configurations
/// prepare concurrently on the worker pool; a lost race simply discards
/// the duplicate (prepared models are deterministic, so either copy is
/// correct).
///
/// The cache is bounded: once more than `capacity` models are resident,
/// the least-recently-used entries are evicted, so a long-running serve
/// session sweeping many (model, design, sparsity) configurations cannot
/// grow memory without limit. The default capacity is generous — the
/// whole zoo × every design × a few sparsity points fits untouched.
pub struct PreparedCache {
    inner: Mutex<CacheInner>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    integrity_fails: AtomicU64,
}

/// Outcome of one [`PreparedCache::get_or_prepare_checked`] lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheLookup {
    /// The returned model came straight from the cache.
    pub hit: bool,
    /// A cached model failed its integrity checksum during this lookup
    /// and was evicted (the returned model is a fresh re-prepare).
    pub integrity_evicted: bool,
}

impl Default for PreparedCache {
    fn default() -> Self {
        PreparedCache::with_capacity(Self::DEFAULT_CAPACITY)
    }
}

impl PreparedCache {
    /// Default LRU capacity (prepared models).
    pub const DEFAULT_CAPACITY: usize = 64;

    /// Empty cache with the default capacity.
    pub fn new() -> Self {
        PreparedCache::default()
    }

    /// Empty cache bounded to `capacity` prepared models (floored at 1).
    pub fn with_capacity(capacity: usize) -> Self {
        PreparedCache {
            inner: Mutex::new(CacheInner { map: HashMap::new(), tick: 0 }),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            integrity_fails: AtomicU64::new(0),
        }
    }

    /// Look up `key`, building (and inserting) the prepared model on a
    /// miss. Returns the shared model plus whether this call hit.
    pub fn get_or_prepare<F>(&self, key: &ModelKey, build: F) -> Result<(Arc<PreparedModel>, bool)>
    where
        F: FnOnce() -> Result<PreparedModel>,
    {
        let (model, lookup) = self.get_or_prepare_checked(key, build)?;
        Ok((model, lookup.hit))
    }

    /// [`PreparedCache::get_or_prepare`] with the full lookup outcome:
    /// every hit re-verifies the model's prepare-time integrity checksum
    /// ([`PreparedModel::verify_integrity`]); a corrupted model is
    /// evicted, counted in [`PreparedCache::integrity_fails`], and
    /// transparently rebuilt — the caller never observes corrupted
    /// schedule or weight buffers through the cache.
    pub fn get_or_prepare_checked<F>(
        &self,
        key: &ModelKey,
        build: F,
    ) -> Result<(Arc<PreparedModel>, CacheLookup)>
    where
        F: FnOnce() -> Result<PreparedModel>,
    {
        let mut integrity_evicted = false;
        {
            let mut inner = self.inner.lock().unwrap();
            inner.tick += 1;
            let tick = inner.tick;
            if let Some(e) = inner.map.get_mut(key) {
                if e.model.verify_integrity() {
                    e.last_used = tick;
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok((
                        Arc::clone(&e.model),
                        CacheLookup { hit: true, integrity_evicted: false },
                    ));
                }
                // Checksum mismatch: the resident model was corrupted
                // after preparation. Evict and fall through to a clean
                // rebuild below.
                inner.map.remove(key);
                self.integrity_fails.fetch_add(1, Ordering::Relaxed);
                integrity_evicted = true;
            }
        }
        // Build without holding the lock (encoding a large model is the
        // expensive part; concurrent misses on different keys must not
        // serialize).
        let built = Arc::new(build()?);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        let model = match inner.map.entry(key.clone()) {
            Entry::Occupied(mut e) => {
                e.get_mut().last_used = tick;
                Arc::clone(&e.get().model)
            }
            Entry::Vacant(v) => {
                Arc::clone(&v.insert(CacheEntry { model: built, last_used: tick }).model)
            }
        };
        // Evict least-recently-used entries beyond capacity. O(n) scan —
        // the capacity is small and misses are rare by design. The entry
        // just inserted carries the newest stamp, so it is never the one
        // evicted.
        while inner.map.len() > self.capacity {
            let oldest = inner
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| k.clone());
            match oldest {
                Some(k) => {
                    inner.map.remove(&k);
                    self.evictions.fetch_add(1, Ordering::Relaxed);
                }
                None => break,
            }
        }
        Ok((model, CacheLookup { hit: false, integrity_evicted }))
    }

    /// Mutate a cached prepared model **in place** (chaos-tier fault
    /// injection only). Best-effort: succeeds only when the cache holds
    /// the sole reference to the model (i.e. no batch is mid-execution
    /// on it) — `Arc::get_mut` guarantees no reader can observe the
    /// mutation mid-flight. Returns whether the mutation was applied.
    pub fn corrupt_cached<F>(&self, key: &ModelKey, f: F) -> bool
    where
        F: FnOnce(&mut PreparedModel),
    {
        let mut inner = self.inner.lock().unwrap();
        match inner.map.get_mut(key).and_then(|e| Arc::get_mut(&mut e.model)) {
            Some(model) => {
                f(model);
                true
            }
            None => false,
        }
    }

    /// Cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses (i.e. prepared-model builds) so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// LRU evictions so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Integrity-checksum failures detected on cache hits so far (each
    /// one evicted a corrupted model and forced a clean re-prepare).
    pub fn integrity_fails(&self) -> u64 {
        self.integrity_fails.load(Ordering::Relaxed)
    }

    /// Maximum number of resident prepared models.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of cached prepared models.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every cached model (e.g. between sweeps over different
    /// weight seeds).
    pub fn clear(&self) {
        self.inner.lock().unwrap().map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::{apply_sparsity, ModelConfig};
    use crate::models::zoo::build_model;

    fn tiny_graph() -> Graph {
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, 0.5, 0.3);
        info.graph
    }

    #[test]
    fn backend_trait_matches_engine() {
        let graph = tiny_graph();
        let backend = backend_for(DesignKind::Csa);
        assert_eq!(backend.assignment(), DesignAssignment::Uniform(DesignKind::Csa));
        let prepared = backend.prepare(&graph).unwrap();
        let engine = SimEngine::new(DesignKind::Csa);
        let direct = engine.prepare(&graph).unwrap();
        let mut rng = crate::util::Pcg32::new(4);
        let input = crate::models::builder::random_input(
            crate::models::zoo::input_shape("dscnn").unwrap(),
            crate::tensor::quant::QuantParams::new(0.05, 0).unwrap(),
            &mut rng,
        );
        let a = backend.execute(&prepared, &input).unwrap();
        let b = engine.run(&direct, &input).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.output.data(), b.output.data());
    }

    #[test]
    fn cache_hits_after_first_prepare() {
        let graph = tiny_graph();
        let cache = PreparedCache::new();
        let key = ModelKey::new("dscnn", DesignKind::Csa, 0.5, 0.3, 0.07, 0x5EED);
        let backend = backend_for(DesignKind::Csa);
        let (_, hit0) = cache.get_or_prepare(&key, || backend.prepare(&graph)).unwrap();
        let (_, hit1) = cache.get_or_prepare(&key, || backend.prepare(&graph)).unwrap();
        assert!(!hit0);
        assert!(hit1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.len(), 1);
        cache.clear();
        assert!(cache.is_empty());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let graph = tiny_graph();
        let backend = backend_for(DesignKind::Csa);
        let cache = PreparedCache::with_capacity(2);
        assert_eq!(cache.capacity(), 2);
        let key = |seed: u64| ModelKey::new("dscnn", DesignKind::Csa, 0.5, 0.3, 0.07, seed);
        cache.get_or_prepare(&key(1), || backend.prepare(&graph)).unwrap();
        cache.get_or_prepare(&key(2), || backend.prepare(&graph)).unwrap();
        // Touch key 1 so key 2 becomes the LRU victim.
        let (_, hit) = cache.get_or_prepare(&key(1), || backend.prepare(&graph)).unwrap();
        assert!(hit);
        cache.get_or_prepare(&key(3), || backend.prepare(&graph)).unwrap();
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.evictions(), 1);
        // Key 1 survived; key 2 was evicted and must rebuild.
        let (_, hit1) = cache.get_or_prepare(&key(1), || backend.prepare(&graph)).unwrap();
        assert!(hit1, "recently-used entry must survive eviction");
        let (_, hit2) = cache.get_or_prepare(&key(2), || backend.prepare(&graph)).unwrap();
        assert!(!hit2, "LRU entry must have been evicted");
    }

    #[test]
    fn capacity_floors_at_one() {
        let cache = PreparedCache::with_capacity(0);
        assert_eq!(cache.capacity(), 1);
    }

    #[test]
    fn integrity_mismatch_on_hit_evicts_and_rebuilds() {
        let graph = tiny_graph();
        let cache = PreparedCache::new();
        let backend = backend_for(DesignKind::Csa);
        let key = ModelKey::new("dscnn", DesignKind::Csa, 0.5, 0.3, 0.07, 0x5EED);
        let (clean, _) = cache.get_or_prepare(&key, || backend.prepare(&graph)).unwrap();
        assert!(clean.verify_integrity());
        // Corrupt the resident copy in place (sole reference required).
        drop(clean);
        let mut rng = crate::util::Pcg32::new(3);
        assert!(cache.corrupt_cached(&key, |m| {
            assert!(m.corrupt_arena_bit(&mut rng));
        }));
        // The next lookup detects the corruption, evicts, and rebuilds.
        let (rebuilt, lookup) =
            cache.get_or_prepare_checked(&key, || backend.prepare(&graph)).unwrap();
        assert!(!lookup.hit);
        assert!(lookup.integrity_evicted);
        assert!(rebuilt.verify_integrity());
        assert_eq!(cache.integrity_fails(), 1);
        assert_eq!(cache.misses(), 2, "corruption forces a re-prepare");
        // Clean entries keep hitting without integrity churn.
        let (_, lookup2) =
            cache.get_or_prepare_checked(&key, || backend.prepare(&graph)).unwrap();
        assert!(lookup2.hit && !lookup2.integrity_evicted);
        assert_eq!(cache.integrity_fails(), 1);
    }

    #[test]
    fn corrupt_cached_fails_while_model_is_shared() {
        let graph = tiny_graph();
        let cache = PreparedCache::new();
        let backend = backend_for(DesignKind::Ussa);
        let key = ModelKey::new("dscnn", DesignKind::Ussa, 0.5, 0.3, 0.07, 1);
        let (held, _) = cache.get_or_prepare(&key, || backend.prepare(&graph)).unwrap();
        // While a batch holds the Arc, in-place corruption must refuse.
        assert!(!cache.corrupt_cached(&key, |_| panic!("must not run")));
        drop(held);
        assert!(cache.corrupt_cached(&key, |_| {}));
    }

    #[test]
    fn oracle_backend_matches_compiled_default() {
        let graph = tiny_graph();
        let compiled = backend_for(DesignKind::Ussa);
        let oracle = oracle_backend_for(DesignKind::Ussa);
        let prepared = compiled.prepare(&graph).unwrap();
        let mut rng = crate::util::Pcg32::new(7);
        let input = crate::models::builder::random_input(
            crate::models::zoo::input_shape("dscnn").unwrap(),
            crate::tensor::quant::QuantParams::new(0.05, 0).unwrap(),
            &mut rng,
        );
        let a = compiled.execute(&prepared, &input).unwrap();
        let b = oracle.execute(&prepared, &input).unwrap();
        assert_eq!(a.output.data(), b.output.data());
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.cfu_stalls(), b.cfu_stalls());
    }

    #[test]
    fn distinct_designs_are_distinct_keys() {
        let a = ModelKey::new("dscnn", DesignKind::Csa, 0.5, 0.3, 0.25, 1);
        let b = ModelKey::new("dscnn", DesignKind::Ussa, 0.5, 0.3, 0.25, 1);
        let c = ModelKey::new("dscnn", DesignKind::Csa, 0.5, 0.3, 0.25, 2);
        assert_ne!(a, b);
        assert_ne!(a, c);
        assert_eq!(a, a.clone());
    }

    #[test]
    fn heterogeneous_assignments_do_not_alias_keys() {
        // Two assignments differing in exactly one layer must be
        // distinct keys; a uniform assignment and its all-equal
        // per-layer spelling must be the *same* key (identical prepared
        // weights — cache sharing is correct, not aliasing).
        let key = |a: DesignAssignment| ModelKey::assigned("dscnn", a, 0.5, 0.3, 0.25, 1);
        let ab = key(DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::Ussa]));
        let ac = key(DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::Csa]));
        assert_ne!(ab, ac);
        let uniform = key(DesignAssignment::Uniform(DesignKind::Csa));
        let spelled = key(DesignAssignment::per_layer(vec![DesignKind::Csa, DesignKind::Csa]));
        assert_eq!(uniform, spelled);
        assert_ne!(uniform, ac);
    }

    #[test]
    fn cache_separates_heterogeneous_assignments_and_lru_counts_stay_exact() {
        // One-layer-different assignments build separately (no alias) and
        // the LRU hit/miss/evict counters stay correct under eviction
        // pressure from heterogeneous keys.
        let graph = tiny_graph();
        let cache = PreparedCache::with_capacity(2);
        let a1 = DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::BaselineSimd]);
        let a2 = DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::Csa]);
        let key = |a: &DesignAssignment| {
            ModelKey::assigned("dscnn", a.clone(), 0.5, 0.3, 0.07, 0x5EED)
        };
        let build = |a: &DesignAssignment| {
            let backend = assigned_backend_with_mode(a, false, ExecMode::Compiled);
            backend.prepare(&graph)
        };
        let (m1, hit1) = cache.get_or_prepare(&key(&a1), || build(&a1)).unwrap();
        let (m2, hit2) = cache.get_or_prepare(&key(&a2), || build(&a2)).unwrap();
        assert!(!hit1 && !hit2);
        assert_eq!(cache.misses(), 2);
        assert_eq!(m1.assignment, a1);
        assert_eq!(m2.assignment, a2);
        // Same keys hit; counters advance exactly.
        let (_, h) = cache.get_or_prepare(&key(&a1), || build(&a1)).unwrap();
        assert!(h);
        assert_eq!(cache.hits(), 1);
        // A third assignment evicts the LRU entry (a2) at capacity 2.
        let a3 = DesignAssignment::Uniform(DesignKind::Ussa);
        cache.get_or_prepare(&key(&a3), || build(&a3)).unwrap();
        assert_eq!(cache.evictions(), 1);
        assert_eq!(cache.len(), 2);
        let (_, h1) = cache.get_or_prepare(&key(&a1), || build(&a1)).unwrap();
        assert!(h1, "recently-used heterogeneous entry survives");
        let (_, h2) = cache.get_or_prepare(&key(&a2), || build(&a2)).unwrap();
        assert!(!h2, "LRU heterogeneous entry was evicted");
        assert_eq!(cache.misses(), 4);
    }

    #[test]
    fn heterogeneous_backend_executes_per_layer_designs() {
        let graph = tiny_graph();
        let n = graph.mac_layers();
        let designs: Vec<DesignKind> = (0..n)
            .map(|i| if i % 2 == 0 { DesignKind::Csa } else { DesignKind::BaselineSimd })
            .collect();
        let assignment = DesignAssignment::per_layer(designs);
        let backend = assigned_backend_with_mode(&assignment, true, ExecMode::Compiled);
        assert_eq!(backend.assignment(), assignment);
        let prepared = backend.prepare(&graph).unwrap();
        let mut rng = crate::util::Pcg32::new(11);
        let input = crate::models::builder::random_input(
            crate::models::zoo::input_shape("dscnn").unwrap(),
            crate::tensor::quant::QuantParams::new(0.05, 0).unwrap(),
            &mut rng,
        );
        let report = backend.execute(&prepared, &input).unwrap();
        assert!(report.total_cycles > 0);
        assert_eq!(report.assignment, assignment);
        // The heterogeneous oracle agrees bit-for-bit.
        let oracle = assigned_backend_with_mode(&assignment, false, ExecMode::Interpreted);
        let o = oracle.execute(&prepared, &input).unwrap();
        assert_eq!(o.output.data(), report.output.data());
        assert_eq!(o.total_cycles, report.total_cycles);
    }
}
