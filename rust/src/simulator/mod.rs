//! Layer-by-layer cycle simulator.
//!
//! [`engine::SimEngine`] prepares a model graph once per accelerator
//! design (weight packing + lookahead encoding at "bitstream build time",
//! exactly like the paper's pre-processing) and then simulates inference
//! requests: every MAC layer runs through the CFU kernels with full cycle
//! accounting; cheap layers (pooling, ReLU, residual add) are charged
//! per-element software costs identical across designs.

//! Engine v2 ([`backend`]) layers a design-agnostic [`backend::ExecBackend`]
//! trait and a prepared-model cache on top, so the coordinator can batch
//! inferences across designs and models without re-preparing weights.
//! Both layers are generic over a per-layer
//! [`crate::isa::DesignAssignment`]: one inference can run SSSA on
//! block-sparse conv layers and the SIMD baseline on layers that need
//! full INT8 weights (the co-design the [`crate::explorer`] automates).

pub mod backend;
pub mod engine;

pub use backend::{
    assigned_backend_full, assigned_backend_tiled, assigned_backend_with_mode, backend_for,
    backend_with_mode, oracle_backend_for, verified_backend_for, CacheLookup, ExecBackend,
    ModelKey, PreparedCache,
};
pub use engine::{LayerStats, PreparedModel, SimEngine, SimReport};
