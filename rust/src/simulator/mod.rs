//! Layer-by-layer cycle simulator.
//!
//! [`engine::SimEngine`] prepares a model graph once per accelerator
//! design (weight packing + lookahead encoding at "bitstream build time",
//! exactly like the paper's pre-processing) and then simulates inference
//! requests: every MAC layer runs through the CFU kernels with full cycle
//! accounting; cheap layers (pooling, ReLU, residual add) are charged
//! per-element software costs identical across designs.

pub mod engine;

pub use engine::{LayerStats, PreparedModel, SimEngine, SimReport};
