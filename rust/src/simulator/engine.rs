//! The simulation engine.

use crate::coordinator::scheduler::TilePool;
use crate::cpu::{CostModel, CycleCounter};
use crate::error::{Error, Result};
use crate::isa::{DesignAssignment, DesignKind};
use crate::kernels::{ExecMode, HostKernel, PreparedConv, PreparedFc};
use crate::nn::activation::{add, relu};
use crate::nn::graph::{Graph, Layer};
use crate::nn::pooling::{avg_pool2d, global_avg_pool, max_pool2d};
use crate::tensor::QTensor;

/// Per-layer simulation statistics.
#[derive(Debug, Clone)]
pub struct LayerStats {
    /// Layer label.
    pub label: String,
    /// Total cycles.
    pub cycles: u64,
    /// CFU (MAC-unit) cycles.
    pub cfu_cycles: u64,
    /// Retired instructions.
    pub instrs: u64,
    /// Bytes loaded.
    pub loaded_bytes: u64,
    /// Weight element sparsity of the layer (MAC layers only).
    pub weight_sparsity: f64,
}

/// Result of simulating one inference.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Model name.
    pub model: String,
    /// Per-layer design assignment simulated (uniform for the paper's
    /// model-wide designs).
    pub assignment: DesignAssignment,
    /// Total cycles across all layers.
    pub total_cycles: u64,
    /// Total CFU (MAC-unit) cycles.
    pub mac_cycles: u64,
    /// Per-layer breakdown.
    pub layers: Vec<LayerStats>,
    /// Final activation tensor.
    pub output: QTensor,
    /// Aggregate instruction/cycle counter (for energy estimation).
    pub counter: CycleCounter,
}

impl SimReport {
    /// Wall time at a clock frequency.
    pub fn seconds_at(&self, clock_hz: u64) -> f64 {
        self.total_cycles as f64 / clock_hz as f64
    }

    /// Compact assignment label for reports (design name when uniform).
    pub fn design_label(&self) -> String {
        self.assignment.label()
    }

    /// CFU stall cycles of this inference (multi-cycle MAC waits).
    pub fn cfu_stalls(&self) -> u64 {
        self.counter.cfu_stalls()
    }

    /// Bytes loaded by the simulated kernels.
    pub fn loaded_bytes(&self) -> u64 {
        self.counter.loaded_bytes()
    }
}

/// A prepared layer: weights packed for the target design.
enum PreparedLayer {
    Conv(PreparedConv),
    Fc(PreparedFc),
    MaxPool { k: usize, stride: usize },
    AvgPool { k: usize, stride: usize },
    GlobalAvgPool,
    Relu,
    Save(usize),
    Shortcut { conv: Option<PreparedConv>, slot: usize },
    ResidualAdd { slot: usize, out_params: crate::tensor::quant::QuantParams },
}

/// A model prepared for one design assignment (weights packed/encoded
/// once, each MAC layer for its assigned design).
pub struct PreparedModel {
    /// Model name.
    pub name: String,
    /// Assignment the model is prepared for.
    pub assignment: DesignAssignment,
    layers: Vec<PreparedLayer>,
    /// Number of output classes.
    pub classes: usize,
    /// INT8→INT7 clamped weight count (SSSA/CSA designs).
    pub clamped_weights: usize,
    /// Integrity checksum of every MAC layer's packed-weight + schedule
    /// buffers, taken at prepare time (see [`PreparedModel::verify_integrity`]).
    checksum: u64,
}

impl PreparedModel {
    /// Every MAC layer's packed lanes, in graph order.
    fn mac_lanes(&self) -> Vec<&crate::kernels::PreparedLanes> {
        let mut out = Vec::new();
        for layer in &self.layers {
            match layer {
                PreparedLayer::Conv(p) => out.push(&p.lanes),
                PreparedLayer::Fc(p) => out.push(&p.lanes),
                PreparedLayer::Shortcut { conv: Some(p), .. } => out.push(&p.lanes),
                _ => {}
            }
        }
        out
    }

    /// Mutable view of every MAC layer's packed lanes (fault injection).
    fn mac_lanes_mut(&mut self) -> Vec<&mut crate::kernels::PreparedLanes> {
        let mut out = Vec::new();
        for layer in &mut self.layers {
            match layer {
                PreparedLayer::Conv(p) => out.push(&mut p.lanes),
                PreparedLayer::Fc(p) => out.push(&mut p.lanes),
                PreparedLayer::Shortcut { conv: Some(p), .. } => out.push(&mut p.lanes),
                _ => {}
            }
        }
        out
    }

    /// Recompute the model-wide integrity checksum: each MAC layer's
    /// [`crate::kernels::PreparedLanes::checksum`] folded with its layer
    /// index (so swapping two identical layers' buffers still changes
    /// the digest).
    pub fn integrity_checksum(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for (i, lanes) in self.mac_lanes().into_iter().enumerate() {
            h ^= lanes.checksum().rotate_left((i as u32 % 63) + 1);
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// The checksum stored at prepare time.
    pub fn stored_checksum(&self) -> u64 {
        self.checksum
    }

    /// Verify the packed-weight and schedule buffers against the
    /// prepare-time checksum. `false` means the prepared model was
    /// corrupted after preparation (e.g. an SEU bit flip) and must not
    /// be trusted: the prepared cache evicts and re-prepares on this.
    pub fn verify_integrity(&self) -> bool {
        self.integrity_checksum() == self.checksum
    }

    /// Flip one bit in some MAC layer's packed weight words, chosen by
    /// `rng` (the weight-memory SEU fault model; chaos tier only).
    /// Returns `false` when the model has no packed words to corrupt.
    pub fn corrupt_weight_bit(&mut self, rng: &mut crate::util::Pcg32) -> bool {
        let mut lanes = self.mac_lanes_mut();
        if lanes.is_empty() {
            return false;
        }
        let l = rng.below(lanes.len() as u32) as usize;
        let word = rng.next_u32() as usize;
        let bit = rng.below(32);
        lanes[l].flip_word_bit(word, bit)
    }

    /// Flip one bit in some MAC layer's compiled [`crate::kernels::ScheduleArena`]
    /// (the configuration-memory SEU fault model; chaos tier only).
    pub fn corrupt_arena_bit(&mut self, rng: &mut crate::util::Pcg32) -> bool {
        let mut lanes = self.mac_lanes_mut();
        if lanes.is_empty() {
            return false;
        }
        let l = rng.below(lanes.len() as u32) as usize;
        let entry = rng.next_u32() as usize;
        let bit = rng.below(32);
        lanes[l].arena.flip_visited_bit(entry, bit)
    }
}

/// Simulation engine: per-layer design assignment + CPU cost model +
/// verification toggle + lane execution mode.
#[derive(Debug, Clone)]
pub struct SimEngine {
    /// Per-layer accelerator assignment (uniform for the paper's
    /// model-wide designs).
    pub assignment: DesignAssignment,
    /// CPU instruction cost model.
    pub cost_model: CostModel,
    /// Verify every MAC layer output against the golden nn op.
    pub verify: bool,
    /// Lane execution path: batch-amortized arena execution (default),
    /// the per-lane compiled walk, or the interpreted CFU oracle.
    pub exec_mode: ExecMode,
    /// Optional intra-layer tiling: when set (and the mode is the
    /// batched default), every MAC layer's lane dimension is split
    /// across this pool's workers, one [`CycleCounter`] per tile,
    /// merged deterministically in tile order — a *single* inference
    /// uses all cores. Outputs and every cycle total are invariant in
    /// the tile count (differential tier).
    pub tiling: Option<TilePool>,
    /// Host-side multiply routine for the batched path ([`HostKernel`]):
    /// `Auto` (default) picks the fastest available SWAR/SIMD kernel.
    /// Outputs and simulated cycles are invariant in this choice
    /// (differential tier) — it only changes host wall-clock.
    pub host_kernel: HostKernel,
}

impl SimEngine {
    /// Engine with the VexRiscv cost model (batched arena execution)
    /// running one design on every MAC layer.
    pub fn new(design: DesignKind) -> Self {
        SimEngine::for_assignment(DesignAssignment::Uniform(design))
    }

    /// Engine executing a (possibly heterogeneous) per-layer assignment.
    pub fn for_assignment(assignment: DesignAssignment) -> Self {
        SimEngine {
            assignment,
            cost_model: CostModel::vexriscv(),
            verify: false,
            exec_mode: ExecMode::default(),
            tiling: None,
            host_kernel: HostKernel::Auto,
        }
    }

    /// Enable bit-exact verification against the reference ops.
    pub fn with_verify(mut self, verify: bool) -> Self {
        self.verify = verify;
        self
    }

    /// Use a custom cost model (e.g. [`CostModel::mac_only`]).
    pub fn with_cost_model(mut self, m: CostModel) -> Self {
        self.cost_model = m;
        self
    }

    /// Force a lane execution mode (e.g. the interpreted oracle for
    /// differential runs).
    pub fn with_exec_mode(mut self, mode: ExecMode) -> Self {
        self.exec_mode = mode;
        self
    }

    /// Enable intra-layer lane tiling across a worker pool (applies to
    /// the batched default mode; the per-lane and interpreted modes stay
    /// single-threaded reference paths).
    pub fn with_tiling(mut self, tiling: Option<TilePool>) -> Self {
        self.tiling = tiling;
        self
    }

    /// Force a host-side multiply kernel for the batched path (e.g.
    /// `Scalar` as the oracle in differential runs, or an explicit SIMD
    /// kernel in benches).
    pub fn with_host_kernel(mut self, kernel: HostKernel) -> Self {
        self.host_kernel = kernel;
        self
    }

    /// Run one MAC kernel under this engine's mode and tiling config.
    fn run_conv(&self, p: &PreparedConv, input: &QTensor) -> Result<crate::kernels::KernelRun> {
        match (&self.tiling, self.exec_mode) {
            (Some(tp), ExecMode::Batched) if tp.workers() > 1 => p.run_tiled_kernel(
                input,
                &self.cost_model,
                tp.pool(),
                tp.workers(),
                self.host_kernel,
            ),
            _ => p.run_with_kernel(input, &self.cost_model, self.exec_mode, self.host_kernel),
        }
    }

    /// [`SimEngine::run_conv`] for dense layers.
    fn run_fc(&self, p: &PreparedFc, input: &QTensor) -> Result<crate::kernels::KernelRun> {
        match (&self.tiling, self.exec_mode) {
            (Some(tp), ExecMode::Batched) if tp.workers() > 1 => p.run_tiled_kernel(
                input,
                &self.cost_model,
                tp.pool(),
                tp.workers(),
                self.host_kernel,
            ),
            _ => p.run_with_kernel(input, &self.cost_model, self.exec_mode, self.host_kernel),
        }
    }

    /// Prepare a graph: pack (and for SSSA/CSA lookahead-encode) every
    /// MAC layer's weights for its assigned design. This is the paper's
    /// offline pre-processing — it is *not* charged to inference cycles.
    ///
    /// MAC layers (convolutions, fully-connected layers, projection
    /// shortcuts) are indexed in graph order; layer `i` is packed for
    /// `self.assignment.design_for(i)`.
    pub fn prepare(&self, graph: &Graph) -> Result<PreparedModel> {
        let mut layers = Vec::with_capacity(graph.layers.len());
        let mut clamped = 0usize;
        let mut mac_idx = 0usize;
        for layer in &graph.layers {
            layers.push(match layer {
                Layer::Conv(op) => {
                    let p = PreparedConv::new(op, self.assignment.design_for(mac_idx))?;
                    mac_idx += 1;
                    clamped += p.lanes.clamped;
                    PreparedLayer::Conv(p)
                }
                Layer::Fc(op) => {
                    let p = PreparedFc::new(op, self.assignment.design_for(mac_idx))?;
                    mac_idx += 1;
                    clamped += p.lanes.clamped;
                    PreparedLayer::Fc(p)
                }
                Layer::MaxPool { k, stride } => {
                    PreparedLayer::MaxPool { k: *k, stride: *stride }
                }
                Layer::AvgPool { k, stride } => {
                    PreparedLayer::AvgPool { k: *k, stride: *stride }
                }
                Layer::GlobalAvgPool => PreparedLayer::GlobalAvgPool,
                Layer::Relu => PreparedLayer::Relu,
                Layer::Save(s) => PreparedLayer::Save(*s),
                Layer::Shortcut { conv, slot } => PreparedLayer::Shortcut {
                    conv: match conv {
                        Some(op) => {
                            let p =
                                PreparedConv::new(op, self.assignment.design_for(mac_idx))?;
                            mac_idx += 1;
                            clamped += p.lanes.clamped;
                            Some(p)
                        }
                        None => None,
                    },
                    slot: *slot,
                },
                Layer::ResidualAdd { slot, out_params } => {
                    PreparedLayer::ResidualAdd { slot: *slot, out_params: *out_params }
                }
            });
        }
        let mut model = PreparedModel {
            name: graph.name.clone(),
            assignment: self.assignment.clone(),
            layers,
            classes: graph.classes,
            clamped_weights: clamped,
            checksum: 0,
        };
        model.checksum = model.integrity_checksum();
        Ok(model)
    }

    /// Simulate one inference.
    pub fn run(&self, model: &PreparedModel, input: &QTensor) -> Result<SimReport> {
        if model.assignment != self.assignment {
            return Err(Error::Sim(format!(
                "model prepared for {} but engine is {}",
                model.assignment, self.assignment
            )));
        }
        let mut cur = input.clone();
        let mut slots: Vec<Option<QTensor>> = vec![None; 8];
        let mut stats = Vec::new();
        let mut total = CycleCounter::new(self.cost_model.clone());
        for layer in &model.layers {
            let (next, layer_stat) = self.run_layer(layer, cur, &mut slots)?;
            if let Some(s) = &layer_stat {
                total.merge(&s.1);
                stats.push(LayerStats {
                    label: s.0.clone(),
                    cycles: s.1.cycles(),
                    cfu_cycles: s.1.cfu_cycles(),
                    instrs: s.1.total_instrs(),
                    loaded_bytes: s.1.loaded_bytes(),
                    weight_sparsity: s.2,
                });
            }
            cur = next;
        }
        Ok(SimReport {
            model: model.name.clone(),
            assignment: self.assignment.clone(),
            total_cycles: total.cycles(),
            mac_cycles: total.cfu_cycles(),
            layers: stats,
            output: cur,
            counter: total,
        })
    }

    #[allow(clippy::type_complexity)]
    fn run_layer(
        &self,
        layer: &PreparedLayer,
        cur: QTensor,
        slots: &mut [Option<QTensor>],
    ) -> Result<(QTensor, Option<(String, CycleCounter, f64)>)> {
        Ok(match layer {
            PreparedLayer::Conv(p) => {
                let run = self.run_conv(p, &cur)?;
                if self.verify {
                    let reference = p.reference_op().forward_ref(&cur)?;
                    if reference.data() != run.output.data() {
                        return Err(Error::Sim(format!(
                            "verification failed for layer {}",
                            p.op.name
                        )));
                    }
                }
                let sparsity = crate::sparsity::stats::element_sparsity(&p.op.weights);
                (run.output, Some((format!("conv:{}", p.op.name), run.counter, sparsity)))
            }
            PreparedLayer::Fc(p) => {
                let run = self.run_fc(p, &cur)?;
                if self.verify {
                    let reference = p.reference_op().forward_ref(&cur)?;
                    if reference.data() != run.output.data() {
                        return Err(Error::Sim(format!(
                            "verification failed for layer {}",
                            p.op.name
                        )));
                    }
                }
                let sparsity = crate::sparsity::stats::element_sparsity(&p.op.weights);
                (run.output, Some((format!("fc:{}", p.op.name), run.counter, sparsity)))
            }
            PreparedLayer::MaxPool { k, stride } => {
                let out = max_pool2d(&cur, *k, *stride)?;
                let mut c = CycleCounter::new(self.cost_model.clone());
                // k*k compares + 1 store per output element
                c.alu(out.shape().numel() as u64 * (k * k) as u64);
                c.store_words(out.shape().numel() as u64);
                (out, Some((format!("maxpool{k}"), c, 0.0)))
            }
            PreparedLayer::AvgPool { k, stride } => {
                let out = avg_pool2d(&cur, *k, *stride)?;
                let mut c = CycleCounter::new(self.cost_model.clone());
                c.alu(out.shape().numel() as u64 * ((k * k) as u64 + 2));
                c.store_words(out.shape().numel() as u64);
                (out, Some((format!("avgpool{k}"), c, 0.0)))
            }
            PreparedLayer::GlobalAvgPool => {
                let n_in = cur.shape().numel() as u64;
                let out = global_avg_pool(&cur)?;
                let mut c = CycleCounter::new(self.cost_model.clone());
                c.alu(n_in + out.shape().numel() as u64 * 2);
                c.store_words(out.shape().numel() as u64);
                (out, Some(("gap".to_string(), c, 0.0)))
            }
            PreparedLayer::Relu => {
                let out = relu(&cur);
                let mut c = CycleCounter::new(self.cost_model.clone());
                c.alu(out.shape().numel() as u64);
                (out, Some(("relu".to_string(), c, 0.0)))
            }
            PreparedLayer::Save(s) => {
                slots[*s] = Some(cur.clone());
                (cur, None)
            }
            PreparedLayer::Shortcut { conv, slot } => {
                match conv {
                    Some(p) => {
                        let run = self.run_conv(p, &cur)?;
                        if self.verify {
                            let reference = p.reference_op().forward_ref(&cur)?;
                            if reference.data() != run.output.data() {
                                return Err(Error::Sim(format!(
                                    "verification failed for projection {}",
                                    p.op.name
                                )));
                            }
                        }
                        let sparsity =
                            crate::sparsity::stats::element_sparsity(&p.op.weights);
                        slots[*slot] = Some(run.output);
                        (cur, Some((format!("proj:{}", p.op.name), run.counter, sparsity)))
                    }
                    None => {
                        slots[*slot] = Some(cur.clone());
                        (cur, None)
                    }
                }
            }
            PreparedLayer::ResidualAdd { slot, out_params } => {
                let saved = slots[*slot]
                    .take()
                    .ok_or_else(|| Error::Sim(format!("slot {slot} empty at add")))?;
                let out = add(&cur, &saved, *out_params)?;
                let mut c = CycleCounter::new(self.cost_model.clone());
                // ~4 ALU ops per element (rescale×2, add, clamp)
                c.alu(out.shape().numel() as u64 * 4);
                (out, Some(("add".to_string(), c, 0.0)))
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::{apply_sparsity, random_input, ModelConfig};
    use crate::models::zoo::build_model;
    use crate::util::Pcg32;

    fn dscnn_setup(x_us: f64, x_ss: f64) -> (crate::nn::graph::Graph, QTensor) {
        let cfg = ModelConfig { scale: 0.125, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, x_us, x_ss);
        let mut rng = Pcg32::new(9);
        let input = random_input(info.input_shape.clone(), cfg.act_params(), &mut rng);
        (info.graph, input)
    }

    #[test]
    fn verified_run_all_designs() {
        let (graph, input) = dscnn_setup(0.5, 0.3);
        for design in DesignKind::ALL {
            let engine = SimEngine::new(design).with_verify(true);
            let prepared = engine.prepare(&graph).unwrap();
            let report = engine.run(&prepared, &input).unwrap();
            assert!(report.total_cycles > 0, "{design}");
            assert_eq!(report.output.shape().numel(), 12);
        }
    }

    #[test]
    fn batched_default_equals_interpreted_oracle_full_model() {
        // Whole-model differential: the default batched path and the
        // per-lane compiled path must match the interpreted CFU oracle
        // bit-for-bit on outputs AND on every aggregate counter, for
        // every design.
        let (graph, input) = dscnn_setup(0.5, 0.3);
        for design in DesignKind::ALL {
            let batched = SimEngine::new(design);
            assert_eq!(batched.exec_mode, ExecMode::Batched, "batched must be the default");
            let compiled = SimEngine::new(design).with_exec_mode(ExecMode::Compiled);
            let oracle = SimEngine::new(design).with_exec_mode(ExecMode::Interpreted);
            let prepared = batched.prepare(&graph).unwrap();
            let a = batched.run(&prepared, &input).unwrap();
            for (tag, engine) in [("compiled", compiled), ("oracle", oracle)] {
                let b = engine.run(&prepared, &input).unwrap();
                assert_eq!(a.output.data(), b.output.data(), "{design}/{tag}: outputs");
                assert_eq!(a.total_cycles, b.total_cycles, "{design}/{tag}: cycles");
                assert_eq!(a.mac_cycles, b.mac_cycles, "{design}/{tag}: mac cycles");
                assert_eq!(a.cfu_stalls(), b.cfu_stalls(), "{design}/{tag}: stalls");
                assert_eq!(a.loaded_bytes(), b.loaded_bytes(), "{design}/{tag}: loaded bytes");
                assert_eq!(
                    a.counter.total_instrs(),
                    b.counter.total_instrs(),
                    "{design}/{tag}: instrs"
                );
            }
        }
    }

    #[test]
    fn tiled_inference_invariant_in_thread_count() {
        // Intra-layer tiling must not change outputs, cycle totals or
        // any other counter: 1-thread tiling, N-thread tiling and the
        // untiled engine all agree bit-for-bit on a full model.
        use crate::coordinator::scheduler::TilePool;
        let (graph, input) = dscnn_setup(0.5, 0.3);
        for design in [DesignKind::Csa, DesignKind::Sssa, DesignKind::BaselineSimd] {
            let untiled = SimEngine::new(design);
            let prepared = untiled.prepare(&graph).unwrap();
            let base = untiled.run(&prepared, &input).unwrap();
            for threads in [1usize, 2, 5] {
                let tiled = SimEngine::new(design).with_tiling(Some(TilePool::new(threads)));
                let r = tiled.run(&prepared, &input).unwrap();
                assert_eq!(r.output.data(), base.output.data(), "{design} t{threads}: outputs");
                assert_eq!(r.total_cycles, base.total_cycles, "{design} t{threads}: cycles");
                assert_eq!(r.mac_cycles, base.mac_cycles, "{design} t{threads}: mac");
                assert_eq!(r.cfu_stalls(), base.cfu_stalls(), "{design} t{threads}: stalls");
                assert_eq!(
                    r.counter.total_instrs(),
                    base.counter.total_instrs(),
                    "{design} t{threads}: instrs"
                );
                assert_eq!(
                    r.loaded_bytes(),
                    base.loaded_bytes(),
                    "{design} t{threads}: loaded bytes"
                );
            }
        }
    }

    #[test]
    fn host_kernel_choice_never_changes_outputs_or_cycles() {
        // Full-model invariance: every available SWAR/SIMD host kernel
        // (and Auto) must match the scalar-kernel engine bit-for-bit on
        // outputs and every aggregate counter.
        let (graph, input) = dscnn_setup(0.5, 0.3);
        for design in [DesignKind::Csa, DesignKind::BaselineSimd] {
            let scalar = SimEngine::new(design).with_host_kernel(HostKernel::Scalar);
            let prepared = scalar.prepare(&graph).unwrap();
            let base = scalar.run(&prepared, &input).unwrap();
            let mut kernels = HostKernel::available_kernels();
            kernels.push(HostKernel::Auto);
            for kernel in kernels {
                let engine = SimEngine::new(design).with_host_kernel(kernel);
                let r = engine.run(&prepared, &input).unwrap();
                assert_eq!(r.output.data(), base.output.data(), "{design} {kernel}: outputs");
                assert_eq!(r.total_cycles, base.total_cycles, "{design} {kernel}: cycles");
                assert_eq!(r.mac_cycles, base.mac_cycles, "{design} {kernel}: mac");
                assert_eq!(
                    r.counter.total_instrs(),
                    base.counter.total_instrs(),
                    "{design} {kernel}: instrs"
                );
            }
        }
    }

    #[test]
    fn csa_beats_baselines_on_combined_sparsity() {
        let (graph, input) = dscnn_setup(0.6, 0.4);
        let mut cycles = std::collections::HashMap::new();
        for design in DesignKind::ALL {
            let engine = SimEngine::new(design);
            let prepared = engine.prepare(&graph).unwrap();
            cycles.insert(design, engine.run(&prepared, &input).unwrap().total_cycles);
        }
        assert!(cycles[&DesignKind::Csa] < cycles[&DesignKind::BaselineSequential]);
        assert!(cycles[&DesignKind::Sssa] < cycles[&DesignKind::BaselineSimd]);
        assert!(cycles[&DesignKind::Ussa] < cycles[&DesignKind::BaselineSequential]);
    }

    #[test]
    fn outputs_identical_across_int7_designs() {
        // All designs compute the same network when weights are INT7 —
        // except NM-SSA, whose prepare-time 2:4 enforcement legitimately
        // zeroes excess group members and so changes the function.
        let (graph, input) = dscnn_setup(0.5, 0.2);
        let mut outputs = Vec::new();
        for design in DesignKind::ALL.into_iter().filter(|d| !d.enforces_structure()) {
            let engine = SimEngine::new(design);
            let prepared = engine.prepare(&graph).unwrap();
            assert_eq!(prepared.clamped_weights, 0, "builder weights are INT7 already");
            outputs.push(engine.run(&prepared, &input).unwrap().output);
        }
        for o in &outputs[1..] {
            assert_eq!(o.data(), outputs[0].data());
        }
    }

    #[test]
    fn design_mismatch_rejected() {
        let (graph, input) = dscnn_setup(0.0, 0.0);
        let e1 = SimEngine::new(DesignKind::Csa);
        let prepared = e1.prepare(&graph).unwrap();
        let e2 = SimEngine::new(DesignKind::Ussa);
        assert!(e2.run(&prepared, &input).is_err());
    }

    #[test]
    fn assignment_mismatch_rejected() {
        use crate::isa::DesignAssignment;
        let (graph, input) = dscnn_setup(0.2, 0.2);
        let a = DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::Csa]);
        let prepared = SimEngine::for_assignment(a).prepare(&graph).unwrap();
        let other =
            SimEngine::for_assignment(DesignAssignment::per_layer(vec![
                DesignKind::Sssa,
                DesignKind::Ussa,
            ]));
        assert!(other.run(&prepared, &input).is_err());
    }

    #[test]
    fn heterogeneous_matches_uniform_per_layer() {
        use crate::isa::DesignAssignment;
        // Alternate SSSA / baseline-simd across MAC layers: every MAC
        // layer's cycle total must equal the same layer under the
        // uniform engine of its assigned design, outputs stay bit-exact
        // (verify), and the compiled path must match the interpreted
        // oracle under the heterogeneous assignment too.
        let (graph, input) = dscnn_setup(0.5, 0.3);
        let n = graph.mac_layers();
        let designs: Vec<DesignKind> = (0..n)
            .map(|i| if i % 2 == 0 { DesignKind::Sssa } else { DesignKind::BaselineSimd })
            .collect();
        let assignment = DesignAssignment::per_layer(designs.clone());
        let engine = SimEngine::for_assignment(assignment.clone()).with_verify(true);
        let prepared = engine.prepare(&graph).unwrap();
        let report = engine.run(&prepared, &input).unwrap();
        assert_eq!(report.assignment, assignment);

        let mac_stats = |r: &SimReport| -> Vec<(String, u64)> {
            r.layers
                .iter()
                .filter(|l| {
                    l.label.starts_with("conv")
                        || l.label.starts_with("fc")
                        || l.label.starts_with("proj")
                })
                .map(|l| (l.label.clone(), l.cycles))
                .collect()
        };
        let hetero = mac_stats(&report);
        assert_eq!(hetero.len(), n);
        for d in [DesignKind::Sssa, DesignKind::BaselineSimd] {
            let e = SimEngine::new(d);
            let p = e.prepare(&graph).unwrap();
            let uni = mac_stats(&e.run(&p, &input).unwrap());
            for (i, (h, u)) in hetero.iter().zip(&uni).enumerate() {
                assert_eq!(h.0, u.0, "layer order must match");
                if designs[i] == d {
                    assert_eq!(h.1, u.1, "layer {i} under {d}");
                }
            }
        }

        let oracle = SimEngine::for_assignment(assignment).with_exec_mode(ExecMode::Interpreted);
        let o = oracle.run(&prepared, &input).unwrap();
        assert_eq!(o.output.data(), report.output.data());
        assert_eq!(o.total_cycles, report.total_cycles);
    }

    #[test]
    fn layer_stats_cover_mac_layers() {
        let (graph, input) = dscnn_setup(0.3, 0.2);
        let engine = SimEngine::new(DesignKind::BaselineSimd);
        let prepared = engine.prepare(&graph).unwrap();
        let report = engine.run(&prepared, &input).unwrap();
        let mac_stats =
            report.layers.iter().filter(|l| l.label.starts_with("conv") || l.label.starts_with("fc")).count();
        assert_eq!(mac_stats, graph.mac_layers());
    }
}
