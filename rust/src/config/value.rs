//! Strict JSON value type + parser + serializer.
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for config and weight-interchange files). Numbers are
//! kept as `f64`; integer accessors validate integrality.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`
    Null,
    /// `true`/`false`
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object (ordered for deterministic serialization).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Value> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        self.write_json(&mut s);
        s
    }

    fn write_json(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if is_integral(*n) {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write_json(out);
                }
                out.push(']');
            }
            Value::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write_json(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    /// Object field.
    pub fn get(&self, key: &str) -> Result<&Value> {
        match self {
            Value::Obj(m) => {
                m.get(key).ok_or_else(|| Error::Config(format!("missing key '{key}'")))
            }
            _ => Err(Error::Config(format!("expected object when reading '{key}'"))),
        }
    }

    /// Optional object field.
    pub fn get_opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => Err(Error::Config(format!("expected number, got {self:?}"))),
        }
    }

    /// As integer (validates integrality).
    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            return Err(Error::Config(format!("expected integer, got {n}")));
        }
        Ok(n as i64)
    }

    /// As usize.
    pub fn as_usize(&self) -> Result<usize> {
        let i = self.as_i64()?;
        if i < 0 {
            return Err(Error::Config(format!("expected non-negative integer, got {i}")));
        }
        Ok(i as usize)
    }

    /// As str.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => Err(Error::Config(format!("expected string, got {self:?}"))),
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => Err(Error::Config(format!("expected bool, got {self:?}"))),
        }
    }

    /// As array.
    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(xs) => Ok(xs),
            _ => Err(Error::Config(format!("expected array, got {self:?}"))),
        }
    }

    /// Array of f64.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    /// Array of i8 (validating range) — used for weight interchange.
    pub fn as_i8_vec(&self) -> Result<Vec<i8>> {
        self.as_arr()?
            .iter()
            .map(|v| {
                let i = v.as_i64()?;
                if !(-128..=127).contains(&i) {
                    return Err(Error::Config(format!("value {i} out of i8 range")));
                }
                Ok(i as i8)
            })
            .collect()
    }

    /// Build an object from pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
}

/// True when an `f64` renders exactly as an `i64` — the one rule shared
/// by the JSON serializer and the human-facing metric tables
/// ([`crate::analysis::report::fmt_compact`]), so both always agree on
/// how a number is displayed.
pub fn is_integral(x: f64) -> bool {
    x.fract() == 0.0 && x.abs() < 9e15
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Config(format!("json parse error at byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => self.string().map(Value::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected character '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(xs)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(m)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex in \\u escape"))?;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("surrogate \\u escape unsupported"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let end = start + width;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Value::Num).map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(Value::parse(r#""hi\n""#).unwrap(), Value::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_i64().unwrap(), 2);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn roundtrip_serialize_parse() {
        let v = Value::obj(vec![
            ("name", Value::Str("dscnn \"v2\"".into())),
            ("sparsity", Value::Num(0.75)),
            ("layers", Value::Arr(vec![Value::Num(1.0), Value::Num(2.0)])),
            ("flag", Value::Bool(true)),
            ("none", Value::Null),
        ]);
        let json = v.to_json();
        assert_eq!(Value::parse(&json).unwrap(), v);
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Value::Num(42.0).to_json(), "42");
        assert_eq!(Value::Num(0.5).to_json(), "0.5");
    }

    #[test]
    fn errors_reported() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("tru").is_err());
        assert!(Value::parse(r#"{"a":1} extra"#).is_err());
        assert!(Value::parse("").is_err());
    }

    #[test]
    fn i8_vec_ranges() {
        let v = Value::parse("[-128, 127, 0]").unwrap();
        assert_eq!(v.as_i8_vec().unwrap(), vec![-128, 127, 0]);
        assert!(Value::parse("[128]").unwrap().as_i8_vec().is_err());
        assert!(Value::parse("[1.5]").unwrap().as_i8_vec().is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Value::parse(r#""héllo ☃ é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃ é");
    }

    #[test]
    fn missing_key_error_message() {
        let v = Value::parse(r#"{"a":1}"#).unwrap();
        let e = v.get("b").unwrap_err();
        assert!(e.to_string().contains("'b'"));
    }
}
