//! Configuration system.
//!
//! [`value`] implements a strict JSON parser/serializer (no `serde`
//! offline); [`experiment`] defines the typed experiment configurations
//! the coordinator consumes (design choice, model, sparsity levels,
//! simulator options) with JSON (de)serialization and validation.
//! Weight/model interchange with the Python layer (train.py exports)
//! also flows through [`value`].

pub mod experiment;
pub mod value;

pub use experiment::{ExperimentConfig, SimOptions, SweepConfig};
pub use value::Value;
