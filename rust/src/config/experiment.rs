//! Typed experiment configuration consumed by the coordinator.

use super::value::Value;
use crate::error::{Error, Result};
use crate::isa::DesignKind;

/// Simulator options.
#[derive(Debug, Clone, PartialEq)]
pub struct SimOptions {
    /// RNG seed for synthetic weights/inputs.
    pub seed: u64,
    /// Worker threads for the coordinator (0 = auto).
    pub threads: usize,
    /// Verify kernel outputs against the reference nn ops.
    pub verify: bool,
    /// Clock frequency (Hz) used to convert cycles to wall time
    /// (paper: 100 MHz LiteX SoC).
    pub clock_hz: u64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { seed: 0xDEAD_BEEF, threads: 0, verify: true, clock_hz: 100_000_000 }
    }
}

/// One experiment: a model, a design, sparsity levels.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Experiment name (report label).
    pub name: String,
    /// Model zoo identifier (`vgg16`, `resnet56`, `mobilenetv2`, `dscnn`).
    pub model: String,
    /// Accelerator designs to evaluate.
    pub designs: Vec<DesignKind>,
    /// Unstructured sparsity within surviving blocks (x_us).
    pub x_us: f64,
    /// Semi-structured 4:4 block sparsity (x_ss).
    pub x_ss: f64,
    /// Batch of inference requests to simulate.
    pub batch: usize,
    /// Simulator options.
    pub sim: SimOptions,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            name: "default".into(),
            model: "dscnn".into(),
            designs: vec![DesignKind::BaselineSimd, DesignKind::Csa],
            x_us: 0.5,
            x_ss: 0.3,
            batch: 1,
            sim: SimOptions::default(),
        }
    }
}

impl ExperimentConfig {
    /// Validate ranges.
    pub fn validate(&self) -> Result<()> {
        for (label, x) in [("x_us", self.x_us), ("x_ss", self.x_ss)] {
            if !(0.0..=1.0).contains(&x) {
                return Err(Error::Config(format!("{label} must be in [0,1], got {x}")));
            }
        }
        if self.designs.is_empty() {
            return Err(Error::Config("at least one design required".into()));
        }
        if self.batch == 0 {
            return Err(Error::Config("batch must be >= 1".into()));
        }
        Ok(())
    }

    /// Parse from a JSON value.
    pub fn from_value(v: &Value) -> Result<ExperimentConfig> {
        let d = ExperimentConfig::default();
        let designs = match v.get_opt("designs") {
            Some(arr) => arr
                .as_arr()?
                .iter()
                .map(|x| {
                    let s = x.as_str()?;
                    DesignKind::parse(s)
                        .ok_or_else(|| Error::Config(format!("unknown design '{s}'")))
                })
                .collect::<Result<Vec<_>>>()?,
            None => d.designs.clone(),
        };
        let sim = match v.get_opt("sim") {
            Some(s) => SimOptions {
                seed: s.get_opt("seed").map(|x| x.as_i64()).transpose()?.map(|i| i as u64)
                    .unwrap_or(d.sim.seed),
                threads: s.get_opt("threads").map(|x| x.as_usize()).transpose()?
                    .unwrap_or(d.sim.threads),
                verify: s.get_opt("verify").map(|x| x.as_bool()).transpose()?
                    .unwrap_or(d.sim.verify),
                clock_hz: s.get_opt("clock_hz").map(|x| x.as_i64()).transpose()?
                    .map(|i| i as u64).unwrap_or(d.sim.clock_hz),
            },
            None => d.sim.clone(),
        };
        let cfg = ExperimentConfig {
            name: v.get_opt("name").map(|x| x.as_str().map(String::from)).transpose()?
                .unwrap_or(d.name),
            model: v.get_opt("model").map(|x| x.as_str().map(String::from)).transpose()?
                .unwrap_or(d.model),
            designs,
            x_us: v.get_opt("x_us").map(|x| x.as_f64()).transpose()?.unwrap_or(d.x_us),
            x_ss: v.get_opt("x_ss").map(|x| x.as_f64()).transpose()?.unwrap_or(d.x_ss),
            batch: v.get_opt("batch").map(|x| x.as_usize()).transpose()?.unwrap_or(d.batch),
            sim,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from JSON text.
    pub fn from_json(json: &str) -> Result<ExperimentConfig> {
        ExperimentConfig::from_value(&Value::parse(json)?)
    }

    /// Serialize to a JSON value.
    pub fn to_value(&self) -> Value {
        Value::obj(vec![
            ("name", Value::Str(self.name.clone())),
            ("model", Value::Str(self.model.clone())),
            (
                "designs",
                Value::Arr(
                    self.designs.iter().map(|d| Value::Str(d.name().to_string())).collect(),
                ),
            ),
            ("x_us", Value::Num(self.x_us)),
            ("x_ss", Value::Num(self.x_ss)),
            ("batch", Value::Num(self.batch as f64)),
            (
                "sim",
                Value::obj(vec![
                    ("seed", Value::Num(self.sim.seed as f64)),
                    ("threads", Value::Num(self.sim.threads as f64)),
                    ("verify", Value::Bool(self.sim.verify)),
                    ("clock_hz", Value::Num(self.sim.clock_hz as f64)),
                ]),
            ),
        ])
    }
}

/// A sweep over sparsity values (Figures 8/9 harness input).
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Sparsity grid.
    pub sparsities: Vec<f64>,
    /// Elements per measured lane.
    pub lane_len: usize,
    /// Lanes per measurement (statistical mass).
    pub lanes: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            sparsities: (0..=19).map(|i| i as f64 * 0.05).collect(),
            lane_len: 256,
            lanes: 64,
            seed: 0xFEED,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        ExperimentConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let cfg = ExperimentConfig {
            name: "fig10-dscnn".into(),
            model: "dscnn".into(),
            designs: vec![DesignKind::Csa, DesignKind::BaselineSimd],
            x_us: 0.6,
            x_ss: 0.25,
            batch: 4,
            sim: SimOptions { seed: 7, threads: 2, verify: false, clock_hz: 100_000_000 },
        };
        let json = cfg.to_value().to_json();
        let back = ExperimentConfig::from_json(&json).unwrap();
        assert_eq!(back, cfg);
    }

    #[test]
    fn partial_json_uses_defaults() {
        let cfg = ExperimentConfig::from_json(r#"{"model": "vgg16", "x_ss": 0.4}"#).unwrap();
        assert_eq!(cfg.model, "vgg16");
        assert_eq!(cfg.x_ss, 0.4);
        assert_eq!(cfg.x_us, ExperimentConfig::default().x_us);
    }

    #[test]
    fn invalid_ranges_rejected() {
        assert!(ExperimentConfig::from_json(r#"{"x_us": 1.5}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"batch": 0}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"designs": []}"#).is_err());
        assert!(ExperimentConfig::from_json(r#"{"designs": ["warp"]}"#).is_err());
    }
}
