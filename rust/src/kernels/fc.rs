//! CFU-accelerated fully-connected kernel.

use super::lane::{prepare_lanes, run_lane, run_lane_compiled, PreparedLanes, INPUT_COST_DENSE};
use super::{ExecMode, KernelRun};
use crate::cfu::AnyCfu;
use crate::cpu::{CostModel, CycleCounter};
use crate::encoding::pack::pack4_le;
use crate::error::{Error, Result};
use crate::isa::DesignKind;
use crate::nn::fully_connected::FullyConnectedOp;
use crate::tensor::{QTensor, Shape};

/// A dense layer prepared for one accelerator design.
#[derive(Debug, Clone)]
pub struct PreparedFc {
    /// The layer with effective (possibly INT7-clamped) weights.
    pub op: FullyConnectedOp,
    /// Target design.
    pub design: DesignKind,
    /// Packed weight lanes (one lane per output neuron).
    pub lanes: PreparedLanes,
}

impl PreparedFc {
    /// Prepare; `in_n` must be a multiple of 4.
    pub fn new(op: &FullyConnectedOp, design: DesignKind) -> Result<Self> {
        if op.in_n % 4 != 0 {
            return Err(Error::Model(format!(
                "{}: in_n {} must be a multiple of 4 (pad features)",
                op.name, op.in_n
            )));
        }
        let lanes = prepare_lanes(&op.weights, op.in_n, design)?;
        let mut eff = op.clone();
        eff.weights = lanes.effective_weights.clone();
        Ok(PreparedFc { op: eff, design, lanes })
    }

    /// Reference op view (effective weights).
    pub fn reference_op(&self) -> &FullyConnectedOp {
        &self.op
    }

    /// Run over a batch of flattened inputs through the compiled lane
    /// schedules (the default execution path).
    pub fn run(&self, input: &QTensor, model: &CostModel) -> Result<KernelRun> {
        self.run_with_mode(input, model, ExecMode::Compiled)
    }

    /// Run under an explicit [`ExecMode`].
    pub fn run_with_mode(
        &self,
        input: &QTensor,
        model: &CostModel,
        mode: ExecMode,
    ) -> Result<KernelRun> {
        let op = &self.op;
        let numel = input.shape().numel();
        if numel % op.in_n != 0 {
            return Err(Error::Shape(format!(
                "{}: input numel {numel} not divisible by in_n {}",
                op.name, op.in_n
            )));
        }
        let batch = numel / op.in_n;
        let x = input.data();
        let mut out = QTensor::zeros(Shape::d2(batch, op.out_n), op.output_params);
        let mut counter = CycleCounter::new(model.clone());
        match mode {
            ExecMode::Compiled => {
                let input_offset = op.input_offset();
                // Packed-input reuse: the shared input row is packed once
                // and read by every output neuron's lane (the interpreted
                // oracle re-packs it out_n times).
                let mut xwords = vec![0u32; op.in_n / 4];
                for b in 0..batch {
                    let xrow = &x[b * op.in_n..(b + 1) * op.in_n];
                    for (j, w) in xwords.iter_mut().enumerate() {
                        *w = pack4_le(&xrow[j * 4..j * 4 + 4]);
                    }
                    for o in 0..op.out_n {
                        counter.load_words(1); // bias
                        counter.alu(1);
                        counter.alu(2); // lane base setup
                        let acc = run_lane_compiled(
                            self.lanes.lane_schedule(o),
                            input_offset,
                            INPUT_COST_DENSE,
                            |j| xwords[j],
                            op.bias[o],
                            &mut counter,
                        );
                        counter.alu(6); // requantize
                        counter.store_words(1);
                        out.set(&[b, o], op.requant.apply(acc));
                    }
                }
            }
            ExecMode::Interpreted => {
                let mut cfu = AnyCfu::new(self.design, op.input_offset());
                for b in 0..batch {
                    let xrow = &x[b * op.in_n..(b + 1) * op.in_n];
                    for o in 0..op.out_n {
                        counter.load_words(1); // bias
                        counter.alu(1);
                        let mut acc = op.bias[o];
                        counter.alu(2); // lane base setup
                        acc = run_lane(
                            self.design,
                            &mut cfu,
                            self.lanes.lane_words(o),
                            |j| {
                                let p = j * 4;
                                (pack4_le(&xrow[p..p + 4]), 1, 0)
                            },
                            acc,
                            &mut counter,
                        )?;
                        counter.alu(6); // requantize
                        counter.store_words(1);
                        out.set(&[b, o], op.requant.apply(acc));
                    }
                }
            }
        }
        Ok(KernelRun { output: out, counter })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::quant::QuantParams;
    use crate::util::Pcg32;

    fn random_fc(seed: u64, out_n: usize, in_n: usize, sparsity: f64) -> FullyConnectedOp {
        let mut rng = Pcg32::new(seed);
        let weights: Vec<i8> = (0..out_n * in_n)
            .map(|_| {
                if rng.bernoulli(sparsity) {
                    0
                } else {
                    rng.range_i32(-64, 63) as i8
                }
            })
            .collect();
        let bias: Vec<i32> = (0..out_n).map(|_| rng.range_i32(-200, 200)).collect();
        FullyConnectedOp::new(
            "fc",
            weights,
            bias,
            out_n,
            in_n,
            QuantParams::new(0.1, 4).unwrap(),
            0.05,
            QuantParams::new(0.2, -6).unwrap(),
            false,
        )
        .unwrap()
    }

    #[test]
    fn kernel_matches_reference_all_designs() {
        let op = random_fc(21, 10, 64, 0.55);
        let mut rng = Pcg32::new(22);
        let data: Vec<i8> = (0..2 * 64).map(|_| rng.range_i32(-128, 127) as i8).collect();
        let input =
            QTensor::new(Shape::d2(2, 64), data, QuantParams::new(0.1, 4).unwrap()).unwrap();
        for design in DesignKind::ALL {
            let prep = PreparedFc::new(&op, design).unwrap();
            let run = prep.run(&input, &CostModel::vexriscv()).unwrap();
            let reference = prep.reference_op().forward_ref(&input).unwrap();
            assert_eq!(run.output.data(), reference.data(), "{design}");
        }
    }

    #[test]
    fn compiled_equals_interpreted_outputs_and_cycles() {
        let op = random_fc(27, 12, 64, 0.6);
        let mut rng = Pcg32::new(28);
        let data: Vec<i8> = (0..3 * 64).map(|_| rng.range_i32(-128, 127) as i8).collect();
        let input =
            QTensor::new(Shape::d2(3, 64), data, QuantParams::new(0.1, 4).unwrap()).unwrap();
        for design in DesignKind::ALL {
            let prep = PreparedFc::new(&op, design).unwrap();
            let model = CostModel::vexriscv();
            let c = prep.run_with_mode(&input, &model, ExecMode::Compiled).unwrap();
            let i = prep.run_with_mode(&input, &model, ExecMode::Interpreted).unwrap();
            assert_eq!(c.output.data(), i.output.data(), "{design}: outputs");
            assert_eq!(c.counter.cycles(), i.counter.cycles(), "{design}: cycles");
            assert_eq!(c.counter.total_instrs(), i.counter.total_instrs(), "{design}: instrs");
            assert_eq!(c.counter.cfu_stalls(), i.counter.cfu_stalls(), "{design}: stalls");
            assert_eq!(c.counter.loaded_bytes(), i.counter.loaded_bytes(), "{design}: loads");
        }
    }

    #[test]
    fn unaligned_features_rejected() {
        let op = random_fc(23, 4, 63, 0.0);
        // in_n=63 not multiple of 4 — but FullyConnectedOp::new succeeded,
        // preparation must reject.
        assert!(PreparedFc::new(&op, DesignKind::Csa).is_err());
    }

    #[test]
    fn csa_faster_than_baseline_on_sparse_rows() {
        let op = random_fc(25, 16, 256, 0.0);
        let mut sparse = op.clone();
        crate::sparsity::prune::prune_combined(&mut sparse.weights, 256, 0.4, 0.5);
        let mut rng = Pcg32::new(26);
        let data: Vec<i8> = (0..256).map(|_| rng.range_i32(-128, 127) as i8).collect();
        let input =
            QTensor::new(Shape::d1(256), data, QuantParams::new(0.1, 4).unwrap()).unwrap();
        let base = PreparedFc::new(&sparse, DesignKind::BaselineSimd)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap()
            .counter
            .cycles();
        let csa = PreparedFc::new(&sparse, DesignKind::Csa)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap()
            .counter
            .cycles();
        assert!(csa < base, "csa {csa} !< baseline {base}");
    }
}
