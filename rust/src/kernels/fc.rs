//! CFU-accelerated fully-connected kernel.

use super::lane::{
    prepare_lanes, run_lane, run_lane_batched, run_lane_compiled, PreparedLanes, INPUT_COST_DENSE,
};
use super::{tile_ranges_weighted, ExecMode, HostKernel, KernelRun};
use crate::cfu::AnyCfu;
use crate::coordinator::scheduler::JobPool;
use crate::cpu::{CostModel, CycleCounter};
use crate::encoding::pack::pack4_le;
use crate::error::{Error, Result};
use crate::isa::DesignKind;
use crate::nn::fully_connected::FullyConnectedOp;
use crate::tensor::{QTensor, Shape};

/// A dense layer prepared for one accelerator design.
#[derive(Debug, Clone)]
pub struct PreparedFc {
    /// The layer with effective (possibly INT7-clamped) weights.
    pub op: FullyConnectedOp,
    /// Target design.
    pub design: DesignKind,
    /// Packed weight lanes (one lane per output neuron).
    pub lanes: PreparedLanes,
}

impl PreparedFc {
    /// Prepare; `in_n` must be a multiple of 4.
    pub fn new(op: &FullyConnectedOp, design: DesignKind) -> Result<Self> {
        if op.in_n % 4 != 0 {
            return Err(Error::Model(format!(
                "{}: in_n {} must be a multiple of 4 (pad features)",
                op.name, op.in_n
            )));
        }
        let lanes = prepare_lanes(&op.weights, op.in_n, design)?;
        let mut eff = op.clone();
        eff.weights = lanes.effective_weights.clone();
        Ok(PreparedFc { op: eff, design, lanes })
    }

    /// Reference op view (effective weights).
    pub fn reference_op(&self) -> &FullyConnectedOp {
        &self.op
    }

    /// Validate the flattened input and return the row count.
    fn check_batch(&self, input: &QTensor) -> Result<usize> {
        let numel = input.shape().numel();
        if numel % self.op.in_n != 0 {
            return Err(Error::Shape(format!(
                "{}: input numel {numel} not divisible by in_n {}",
                self.op.name, self.op.in_n
            )));
        }
        Ok(numel / self.op.in_n)
    }

    /// Pack every input row into CFU operand words once: `batch × nb`
    /// words, row-major. Both the batched path and every lane of the
    /// per-lane compiled path read from this shared packing.
    fn pack_rows(&self, x: &[i8], batch: usize) -> Vec<u32> {
        let in_n = self.op.in_n;
        let nb = in_n / 4;
        let mut xwords = vec![0u32; batch * nb];
        for b in 0..batch {
            let xrow = &x[b * in_n..(b + 1) * in_n];
            for (j, w) in xwords[b * nb..(b + 1) * nb].iter_mut().enumerate() {
                *w = pack4_le(&xrow[j * 4..j * 4 + 4]);
            }
        }
        xwords
    }

    /// Batch-amortized execution of a contiguous range of output lanes:
    /// each lane's arena slice is walked once, streaming every packed
    /// input row against each visited block. `out` is a `batch ×
    /// lanes.len()` row-major tile buffer (for the full range it *is*
    /// the output tensor's layout).
    ///
    /// Per-(row, output) bookkeeping — bias load, accumulator init, lane
    /// base setup, requantize, store — is charged in one scaled bulk
    /// flush, identical in total to the interpreted loop's per-output
    /// charges.
    fn run_lanes_batched(
        &self,
        xwords: &[u32],
        batch: usize,
        lanes: std::ops::Range<usize>,
        kernel: HostKernel,
        out: &mut [i8],
        counter: &mut CycleCounter,
    ) -> Result<()> {
        let op = &self.op;
        let nb = op.in_n / 4;
        let width = lanes.len();
        let input_offset = op.input_offset();
        // 1 bias load + 9 ALU (init 1, lane setup 2, requantize 6) + 1
        // store per (row, output) — the same totals the row-major paths
        // charge piecewise.
        let per = (batch * width) as u64;
        counter.charge_bulk(per * 9, per, per, 0, 0, 0, 0);
        let mut accs = vec![0i32; batch];
        for o in lanes.clone() {
            accs.fill(op.bias[o]);
            run_lane_batched(
                self.lanes.lane_schedule(o),
                input_offset,
                INPUT_COST_DENSE,
                kernel,
                |b, j| xwords[b * nb + j],
                &mut accs,
                counter,
            )?;
            let col = o - lanes.start;
            for (b, &acc) in accs.iter().enumerate() {
                out[b * width + col] = op.requant.apply(acc);
            }
        }
        Ok(())
    }

    /// Run over a batch of flattened inputs through the schedule arena's
    /// batch-amortized path (the default execution mode).
    pub fn run(&self, input: &QTensor, model: &CostModel) -> Result<KernelRun> {
        self.run_with_mode(input, model, ExecMode::default())
    }

    /// Run under an explicit [`ExecMode`] with the default (`Auto`) host
    /// kernel.
    pub fn run_with_mode(
        &self,
        input: &QTensor,
        model: &CostModel,
        mode: ExecMode,
    ) -> Result<KernelRun> {
        self.run_with_kernel(input, model, mode, HostKernel::Auto)
    }

    /// Run under an explicit [`ExecMode`] and [`HostKernel`]. The kernel
    /// only affects the batched path's host throughput; outputs and every
    /// simulated counter total are identical across kernels.
    pub fn run_with_kernel(
        &self,
        input: &QTensor,
        model: &CostModel,
        mode: ExecMode,
        kernel: HostKernel,
    ) -> Result<KernelRun> {
        let op = &self.op;
        let batch = self.check_batch(input)?;
        let x = input.data();
        let mut out = QTensor::zeros(Shape::d2(batch, op.out_n), op.output_params);
        let mut counter = CycleCounter::new(model.clone());
        match mode {
            ExecMode::Batched => {
                let xwords = self.pack_rows(x, batch);
                self.run_lanes_batched(
                    &xwords,
                    batch,
                    0..op.out_n,
                    kernel,
                    out.data_mut(),
                    &mut counter,
                )?;
            }
            ExecMode::Compiled => {
                let input_offset = op.input_offset();
                // Packed-input reuse: the shared input row is packed once
                // and read by every output neuron's lane (the interpreted
                // oracle re-packs it out_n times).
                let mut xwords = vec![0u32; op.in_n / 4];
                let out_data = out.data_mut();
                for b in 0..batch {
                    let xrow = &x[b * op.in_n..(b + 1) * op.in_n];
                    for (j, w) in xwords.iter_mut().enumerate() {
                        *w = pack4_le(&xrow[j * 4..j * 4 + 4]);
                    }
                    // Direct row-slice writes: no per-element multi-dim
                    // index math in the hot loop.
                    let orow = &mut out_data[b * op.out_n..(b + 1) * op.out_n];
                    for (o, slot) in orow.iter_mut().enumerate() {
                        counter.load_words(1); // bias
                        counter.alu(3); // acc init + lane base setup
                        let acc = run_lane_compiled(
                            self.lanes.lane_schedule(o),
                            input_offset,
                            INPUT_COST_DENSE,
                            |j| xwords[j],
                            op.bias[o],
                            &mut counter,
                        );
                        counter.alu(6); // requantize
                        counter.store_words(1);
                        *slot = op.requant.apply(acc);
                    }
                }
            }
            ExecMode::Interpreted => {
                let mut cfu = AnyCfu::new(self.design, op.input_offset());
                let out_data = out.data_mut();
                for b in 0..batch {
                    let xrow = &x[b * op.in_n..(b + 1) * op.in_n];
                    let orow = &mut out_data[b * op.out_n..(b + 1) * op.out_n];
                    for (o, slot) in orow.iter_mut().enumerate() {
                        counter.load_words(1); // bias
                        counter.alu(1);
                        let mut acc = op.bias[o];
                        counter.alu(2); // lane base setup
                        acc = run_lane(
                            &self.lanes,
                            o,
                            &mut cfu,
                            |j| {
                                let p = j * 4;
                                (pack4_le(&xrow[p..p + 4]), 1, 0)
                            },
                            acc,
                            &mut counter,
                        )?;
                        counter.alu(6); // requantize
                        counter.store_words(1);
                        *slot = op.requant.apply(acc);
                    }
                }
            }
        }
        Ok(KernelRun { output: out, counter })
    }

    /// Batched execution with the output-lane dimension tiled across a
    /// worker pool: each tile runs the batch-amortized loop over its
    /// contiguous lane range with its own [`CycleCounter`],
    /// writing a tile-local buffer; tiles are then merged
    /// *deterministically in tile order*, so outputs and every counter
    /// total are invariant in the tile/thread count (asserted by the
    /// differential tier).
    pub fn run_tiled(
        &self,
        input: &QTensor,
        model: &CostModel,
        pool: &JobPool,
        tiles: usize,
    ) -> Result<KernelRun> {
        self.run_tiled_kernel(input, model, pool, tiles, HostKernel::Auto)
    }

    /// [`run_tiled`](Self::run_tiled) with an explicit [`HostKernel`].
    ///
    /// Tile boundaries balance *work*, not lane count: lanes are split by
    /// cumulative visited-block length ([`tile_ranges_weighted`]), so a
    /// few dense output neurons cannot serialize a tile while the sparse
    /// ones idle. The merge stays in tile order — outputs and counter
    /// totals are invariant in the tile/thread count and in the weighting.
    pub fn run_tiled_kernel(
        &self,
        input: &QTensor,
        model: &CostModel,
        pool: &JobPool,
        tiles: usize,
        kernel: HostKernel,
    ) -> Result<KernelRun> {
        let op = &self.op;
        let batch = self.check_batch(input)?;
        let x = input.data();
        let xwords = self.pack_rows(x, batch);
        let weights: Vec<u64> =
            (0..op.out_n).map(|o| self.lanes.lane_schedule(o).visited_blocks() as u64).collect();
        let ranges = tile_ranges_weighted(&weights, tiles);
        let parts: Vec<Result<(Vec<i8>, CycleCounter)>> =
            pool.scoped_map(ranges.clone(), |r| {
                let mut counter = CycleCounter::new(model.clone());
                let mut buf = vec![0i8; batch * r.len()];
                self.run_lanes_batched(&xwords, batch, r, kernel, &mut buf, &mut counter)?;
                Ok((buf, counter))
            });
        let mut out = QTensor::zeros(Shape::d2(batch, op.out_n), op.output_params);
        let mut counter = CycleCounter::new(model.clone());
        let out_data = out.data_mut();
        for (range, part) in ranges.into_iter().zip(parts) {
            let (buf, c) = part?;
            counter.merge(&c);
            let width = range.len();
            for b in 0..batch {
                out_data[(b * op.out_n + range.start)..(b * op.out_n + range.end)]
                    .copy_from_slice(&buf[b * width..(b + 1) * width]);
            }
        }
        Ok(KernelRun { output: out, counter })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::quant::QuantParams;
    use crate::util::Pcg32;

    fn random_fc(seed: u64, out_n: usize, in_n: usize, sparsity: f64) -> FullyConnectedOp {
        let mut rng = Pcg32::new(seed);
        let weights: Vec<i8> = (0..out_n * in_n)
            .map(|_| {
                if rng.bernoulli(sparsity) {
                    0
                } else {
                    rng.range_i32(-64, 63) as i8
                }
            })
            .collect();
        let bias: Vec<i32> = (0..out_n).map(|_| rng.range_i32(-200, 200)).collect();
        FullyConnectedOp::new(
            "fc",
            weights,
            bias,
            out_n,
            in_n,
            QuantParams::new(0.1, 4).unwrap(),
            0.05,
            QuantParams::new(0.2, -6).unwrap(),
            false,
        )
        .unwrap()
    }

    fn random_batch_input(seed: u64, batch: usize, in_n: usize) -> QTensor {
        let mut rng = Pcg32::new(seed);
        let data: Vec<i8> =
            (0..batch * in_n).map(|_| rng.range_i32(-128, 127) as i8).collect();
        QTensor::new(Shape::d2(batch, in_n), data, QuantParams::new(0.1, 4).unwrap()).unwrap()
    }

    fn assert_runs_identical(a: &KernelRun, b: &KernelRun, tag: &str) {
        assert_eq!(a.output.data(), b.output.data(), "{tag}: outputs");
        assert_eq!(a.counter.cycles(), b.counter.cycles(), "{tag}: cycles");
        assert_eq!(a.counter.total_instrs(), b.counter.total_instrs(), "{tag}: instrs");
        assert_eq!(a.counter.cfu_cycles(), b.counter.cfu_cycles(), "{tag}: cfu cycles");
        assert_eq!(a.counter.cfu_stalls(), b.counter.cfu_stalls(), "{tag}: stalls");
        assert_eq!(a.counter.loaded_bytes(), b.counter.loaded_bytes(), "{tag}: loads");
        assert_eq!(a.counter.stored_bytes(), b.counter.stored_bytes(), "{tag}: stores");
    }

    #[test]
    fn kernel_matches_reference_all_designs() {
        let op = random_fc(21, 10, 64, 0.55);
        let input = random_batch_input(22, 2, 64);
        for design in DesignKind::ALL {
            let prep = PreparedFc::new(&op, design).unwrap();
            let run = prep.run(&input, &CostModel::vexriscv()).unwrap();
            let reference = prep.reference_op().forward_ref(&input).unwrap();
            assert_eq!(run.output.data(), reference.data(), "{design}");
        }
    }

    #[test]
    fn all_modes_equal_outputs_and_cycles() {
        // Batched (default), per-lane compiled and the interpreted
        // oracle must agree bit-for-bit on outputs and every counter
        // total — including batch 1 and odd batch sizes.
        let op = random_fc(27, 12, 64, 0.6);
        for &batch in &[1usize, 3, 8] {
            let input = random_batch_input(28 + batch as u64, batch, 64);
            for design in DesignKind::ALL {
                let prep = PreparedFc::new(&op, design).unwrap();
                let model = CostModel::vexriscv();
                let b = prep.run_with_mode(&input, &model, ExecMode::Batched).unwrap();
                let c = prep.run_with_mode(&input, &model, ExecMode::Compiled).unwrap();
                let i = prep.run_with_mode(&input, &model, ExecMode::Interpreted).unwrap();
                assert_runs_identical(&b, &c, &format!("{design} b{batch} batched-vs-compiled"));
                assert_runs_identical(&b, &i, &format!("{design} b{batch} batched-vs-oracle"));
            }
        }
    }

    #[test]
    fn tiled_equals_batched_any_tile_count() {
        let op = random_fc(29, 13, 64, 0.5);
        let input = random_batch_input(30, 5, 64);
        let model = CostModel::vexriscv();
        for design in DesignKind::ALL {
            let prep = PreparedFc::new(&op, design).unwrap();
            let base = prep.run_with_mode(&input, &model, ExecMode::Batched).unwrap();
            for tiles in [1usize, 2, 4, 32] {
                let pool = JobPool::new(3);
                let t = prep.run_tiled(&input, &model, &pool, tiles).unwrap();
                assert_runs_identical(&base, &t, &format!("{design} tiles={tiles}"));
            }
        }
    }

    #[test]
    fn every_host_kernel_matches_the_scalar_oracle() {
        // SWAR and (where available) SIMD host kernels must be
        // bit-identical to the scalar batched loop — outputs AND every
        // counter total — at batch sizes around the SIMD pair width.
        let op = random_fc(41, 11, 64, 0.5);
        let model = CostModel::vexriscv();
        for &batch in &[1usize, 3, 8] {
            let input = random_batch_input(42 + batch as u64, batch, 64);
            for design in DesignKind::ALL {
                let prep = PreparedFc::new(&op, design).unwrap();
                let scalar = prep
                    .run_with_kernel(&input, &model, ExecMode::Batched, HostKernel::Scalar)
                    .unwrap();
                for kernel in HostKernel::available_kernels() {
                    let run =
                        prep.run_with_kernel(&input, &model, ExecMode::Batched, kernel).unwrap();
                    assert_runs_identical(&scalar, &run, &format!("{design} b{batch} {kernel}"));
                }
            }
        }
    }

    #[test]
    fn more_tiles_than_lanes_never_dispatches_empty_work() {
        // Regression: out_n=1 with many requested tiles used to create
        // empty lane ranges; now a single tile runs and outputs match.
        let op = random_fc(43, 1, 32, 0.4);
        let input = random_batch_input(44, 3, 32);
        let model = CostModel::vexriscv();
        for design in [DesignKind::BaselineSimd, DesignKind::Csa] {
            let prep = PreparedFc::new(&op, design).unwrap();
            let base = prep.run_with_mode(&input, &model, ExecMode::Batched).unwrap();
            for tiles in [2usize, 8] {
                let pool = JobPool::new(2);
                let t = prep.run_tiled(&input, &model, &pool, tiles).unwrap();
                assert_runs_identical(&base, &t, &format!("{design} out_n=1 tiles={tiles}"));
            }
        }
    }

    #[test]
    fn weighted_tiling_matches_batched_on_skewed_sparsity() {
        // Half the output neurons fully dense, half fully zero: the
        // weighted split must still cover every lane exactly once and
        // reproduce the batched totals bit-for-bit.
        let mut op = random_fc(45, 12, 64, 0.0);
        for o in 6..12 {
            op.weights[o * 64..(o + 1) * 64].fill(0);
        }
        let input = random_batch_input(46, 4, 64);
        let model = CostModel::vexriscv();
        for design in [DesignKind::Sssa, DesignKind::Csa] {
            let prep = PreparedFc::new(&op, design).unwrap();
            let base = prep.run_with_mode(&input, &model, ExecMode::Batched).unwrap();
            for tiles in [2usize, 3, 4] {
                let pool = JobPool::new(3);
                let t = prep.run_tiled(&input, &model, &pool, tiles).unwrap();
                assert_runs_identical(&base, &t, &format!("{design} skew tiles={tiles}"));
            }
        }
    }

    #[test]
    fn unaligned_features_rejected() {
        let op = random_fc(23, 4, 63, 0.0);
        // in_n=63 not multiple of 4 — but FullyConnectedOp::new succeeded,
        // preparation must reject.
        assert!(PreparedFc::new(&op, DesignKind::Csa).is_err());
    }

    #[test]
    fn csa_faster_than_baseline_on_sparse_rows() {
        let op = random_fc(25, 16, 256, 0.0);
        let mut sparse = op.clone();
        crate::sparsity::prune::prune_combined(&mut sparse.weights, 256, 0.4, 0.5);
        let mut rng = Pcg32::new(26);
        let data: Vec<i8> = (0..256).map(|_| rng.range_i32(-128, 127) as i8).collect();
        let input =
            QTensor::new(Shape::d1(256), data, QuantParams::new(0.1, 4).unwrap()).unwrap();
        let base = PreparedFc::new(&sparse, DesignKind::BaselineSimd)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap()
            .counter
            .cycles();
        let csa = PreparedFc::new(&sparse, DesignKind::Csa)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap()
            .counter
            .cycles();
        assert!(csa < base, "csa {csa} !< baseline {base}");
    }
}
