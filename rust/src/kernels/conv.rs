//! CFU-accelerated convolution kernel (normal + depthwise).

use super::lane::{prepare_lanes, run_lane, PreparedLanes};
use super::KernelRun;
use crate::cfu::AnyCfu;
use crate::cpu::{CostModel, CycleCounter};
use crate::encoding::pack::pack4_i8;
use crate::error::{Error, Result};
use crate::isa::DesignKind;
use crate::nn::conv2d::Conv2dOp;
use crate::tensor::{QTensor, Shape};

/// A conv layer prepared for one accelerator design: weights packed (and
/// for SSSA/CSA lookahead-encoded) per lane.
#[derive(Debug, Clone)]
pub struct PreparedConv {
    /// The underlying layer description.
    pub op: Conv2dOp,
    /// Target design.
    pub design: DesignKind,
    /// Packed weight lanes.
    pub lanes: PreparedLanes,
    /// Padded lane length (depthwise pads `kh*kw` up to a multiple of 4).
    pub lane_len: usize,
    /// Per-tap (kh, kw) lookup for the depthwise gather (avoids div/mod
    /// in the hot loop — EXPERIMENTS.md §Perf).
    dw_taps: Vec<(usize, usize)>,
}

impl PreparedConv {
    /// Prepare a layer for a design.
    ///
    /// Normal conv requires `in_c % 4 == 0` (the model builders pad input
    /// channels); depthwise lanes are the `kh*kw` taps zero-padded to a
    /// multiple of 4.
    pub fn new(op: &Conv2dOp, design: DesignKind) -> Result<Self> {
        if op.depthwise {
            let taps = op.kh * op.kw;
            let lane_len = taps.div_ceil(4) * 4;
            let mut padded = vec![0i8; op.out_c * lane_len];
            for ch in 0..op.out_c {
                for t in 0..taps {
                    padded[ch * lane_len + t] = op.weights[ch * taps + t];
                }
            }
            let lanes = prepare_lanes(&padded, lane_len, design)?;
            let dw_taps =
                (0..taps).map(|t| (t / op.kw, t % op.kw)).collect();
            Ok(PreparedConv {
                op: Self::with_effective(op, &lanes, lane_len),
                design,
                lanes,
                lane_len,
                dw_taps,
            })
        } else {
            if op.in_c % 4 != 0 {
                return Err(Error::Model(format!(
                    "{}: in_c {} must be a multiple of 4 (pad input channels)",
                    op.name, op.in_c
                )));
            }
            let lanes = prepare_lanes(&op.weights, op.in_c, design)?;
            Ok(PreparedConv {
                op: Self::with_effective(op, &lanes, op.in_c),
                design,
                lanes,
                lane_len: op.in_c,
                dw_taps: Vec::new(),
            })
        }
    }

    /// Clone of the op with the *effective* (possibly INT7-clamped)
    /// weights — the exact values the CFU multiplies. Running
    /// [`Conv2dOp::forward_ref`] on this clone must match the kernel
    /// output bit-for-bit.
    fn with_effective(op: &Conv2dOp, lanes: &PreparedLanes, lane_len: usize) -> Conv2dOp {
        let mut eff = op.clone();
        if op.depthwise {
            let taps = op.kh * op.kw;
            for ch in 0..op.out_c {
                for t in 0..taps {
                    eff.weights[ch * taps + t] = lanes.effective_weights[ch * lane_len + t];
                }
            }
        } else {
            eff.weights = lanes.effective_weights.clone();
        }
        eff
    }

    /// Reference op view (effective weights).
    pub fn reference_op(&self) -> &Conv2dOp {
        &self.op
    }

    /// Run the kernel over an NHWC input under a CPU cost model.
    pub fn run(&self, input: &QTensor, model: &CostModel) -> Result<KernelRun> {
        let op = &self.op;
        let ishape = input.shape();
        if ishape.rank() != 4 || ishape.c() != op.in_c {
            return Err(Error::Shape(format!(
                "{}: input {} incompatible with in_c {}",
                op.name, ishape, op.in_c
            )));
        }
        let (n, in_h, in_w) = (ishape.n(), ishape.h(), ishape.w());
        let (out_h, out_w, pad_h, pad_w) = op.geometry(in_h, in_w);
        let mut out =
            QTensor::zeros(Shape::nhwc(n, out_h, out_w, op.out_c), op.output_params);
        let mut counter = CycleCounter::new(model.clone());
        let mut cfu = AnyCfu::new(self.design, op.input_offset());
        let x = input.data();
        let input_zp = op.input_params.zero_point.clamp(-128, 127) as i8;

        let out_data = out.data_mut();
        let mut out_idx = 0usize;
        for b in 0..n {
            for oh in 0..out_h {
                for ow in 0..out_w {
                    for oc in 0..op.out_c {
                        // Per-output-position software charges accumulated
                        // locally, flushed once (§Perf): bias load + move,
                        // bounds tests, lane setup, requantize + store.
                        let mut alu = 1u64; // acc init move
                        let mut taken = 0u64;
                        let mut not_taken = 0u64;
                        let mut acc = op.bias[oc];
                        if op.depthwise {
                            acc = self.run_depthwise_lane(
                                &mut cfu,
                                &mut counter,
                                x,
                                (b, oh, ow, oc),
                                (in_h, in_w, pad_h, pad_w),
                                input_zp,
                                acc,
                            )?;
                        } else {
                            for kh in 0..op.kh {
                                let ih = (oh * op.stride + kh) as i64 - pad_h;
                                // bounds test per kernel row
                                alu += 1;
                                let oob_h = ih < 0 || ih >= in_h as i64;
                                if oob_h {
                                    taken += 1;
                                    continue;
                                }
                                not_taken += 1;
                                for kw in 0..op.kw {
                                    let iw = (ow * op.stride + kw) as i64 - pad_w;
                                    alu += 1;
                                    let oob_w = iw < 0 || iw >= in_w as i64;
                                    if oob_w {
                                        taken += 1;
                                        continue;
                                    }
                                    not_taken += 1;
                                    let lane_idx = (oc * op.kh + kh) * op.kw + kw;
                                    let base = ((b * in_h + ih as usize) * in_w
                                        + iw as usize)
                                        * op.in_c;
                                    // lane setup (base pointer arithmetic)
                                    alu += 2;
                                    acc = run_lane(
                                        self.design,
                                        &mut cfu,
                                        self.lanes.lane_words(lane_idx),
                                        |j| {
                                            let p = base + j * 4;
                                            (
                                                pack4_i8(&[
                                                    x[p],
                                                    x[p + 1],
                                                    x[p + 2],
                                                    x[p + 3],
                                                ]),
                                                1,
                                                0,
                                            )
                                        },
                                        acc,
                                        &mut counter,
                                    )?;
                                }
                            }
                        }
                        // requantize (~6 ALU: mul-high, shift, add zp, clamp x2, pack)
                        alu += 6;
                        counter.charge_bulk(alu, 1, 1, taken, not_taken, 0, 0);
                        out_data[out_idx] = op.requant.apply(acc);
                        out_idx += 1;
                    }
                }
            }
        }
        Ok(KernelRun { output: out, counter })
    }

    /// Depthwise inner loop: the lane is the channel's padded tap list;
    /// input words are gathered (4 byte loads + 3 packing ALU ops per
    /// block), with padding positions supplying the input zero point.
    #[allow(clippy::too_many_arguments)]
    fn run_depthwise_lane(
        &self,
        cfu: &mut AnyCfu,
        counter: &mut CycleCounter,
        x: &[i8],
        pos: (usize, usize, usize, usize),
        geom: (usize, usize, i64, i64),
        input_zp: i8,
        acc: i32,
    ) -> Result<i32> {
        let op = &self.op;
        let (b, oh, ow, oc) = pos;
        let (in_h, in_w, pad_h, pad_w) = geom;
        let taps = op.kh * op.kw;
        let base_h = (oh * op.stride) as i64 - pad_h;
        let base_w = (ow * op.stride) as i64 - pad_w;
        let dw_taps = &self.dw_taps;
        run_lane(
            self.design,
            cfu,
            self.lanes.lane_words(oc),
            |j| {
                let mut lanes4 = [input_zp; 4];
                let t0 = j * 4;
                let end = (t0 + 4).min(taps);
                for t in t0..end {
                    let (kh, kw) = dw_taps[t];
                    let ih = base_h + kh as i64;
                    let iw = base_w + kw as i64;
                    if ih >= 0 && ih < in_h as i64 && iw >= 0 && iw < in_w as i64 {
                        lanes4[t - t0] =
                            x[((b * in_h + ih as usize) * in_w + iw as usize) * op.in_c + oc];
                    }
                }
                // gather: 4 byte loads + 3 packing ops
                (pack4_i8(&lanes4), 4, 3)
            },
            acc,
            counter,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv2d::Padding;
    use crate::tensor::quant::QuantParams;
    use crate::util::Pcg32;

    fn qp(scale: f32, zp: i32) -> QuantParams {
        QuantParams::new(scale, zp).unwrap()
    }

    fn random_conv(
        seed: u64,
        out_c: usize,
        in_c: usize,
        k: usize,
        stride: usize,
        padding: Padding,
        depthwise: bool,
        sparsity: f64,
    ) -> Conv2dOp {
        let mut rng = Pcg32::new(seed);
        let n = if depthwise { out_c * k * k } else { out_c * k * k * in_c };
        let weights: Vec<i8> = (0..n)
            .map(|_| {
                if rng.bernoulli(sparsity) {
                    0
                } else {
                    rng.range_i32(-64, 63) as i8
                }
            })
            .collect();
        let bias: Vec<i32> = (0..out_c).map(|_| rng.range_i32(-500, 500)).collect();
        Conv2dOp::new(
            "t",
            weights,
            bias,
            out_c,
            in_c,
            k,
            k,
            stride,
            padding,
            depthwise,
            qp(0.05, -3),
            0.02,
            qp(0.08, 5),
            true,
        )
        .unwrap()
    }

    fn random_input(seed: u64, h: usize, w: usize, c: usize) -> QTensor {
        let mut rng = Pcg32::new(seed);
        let data: Vec<i8> = (0..h * w * c).map(|_| rng.range_i32(-128, 127) as i8).collect();
        QTensor::new(Shape::nhwc(1, h, w, c), data, qp(0.05, -3)).unwrap()
    }

    #[test]
    fn kernel_matches_reference_all_designs() {
        let op = random_conv(1, 8, 8, 3, 1, Padding::Same, false, 0.5);
        let input = random_input(2, 6, 6, 8);
        for design in DesignKind::ALL {
            let prep = PreparedConv::new(&op, design).unwrap();
            let run = prep.run(&input, &CostModel::vexriscv()).unwrap();
            let reference = prep.reference_op().forward_ref(&input).unwrap();
            assert_eq!(run.output.data(), reference.data(), "{design}");
        }
    }

    #[test]
    fn kernel_matches_reference_strided_valid() {
        let op = random_conv(3, 4, 12, 3, 2, Padding::Valid, false, 0.6);
        let input = random_input(4, 9, 9, 12);
        for design in DesignKind::ALL {
            let prep = PreparedConv::new(&op, design).unwrap();
            let run = prep.run(&input, &CostModel::vexriscv()).unwrap();
            let reference = prep.reference_op().forward_ref(&input).unwrap();
            assert_eq!(run.output.data(), reference.data(), "{design}");
        }
    }

    #[test]
    fn depthwise_matches_reference_all_designs() {
        let op = random_conv(5, 8, 8, 3, 1, Padding::Same, true, 0.4);
        let input = random_input(6, 5, 5, 8);
        for design in DesignKind::ALL {
            let prep = PreparedConv::new(&op, design).unwrap();
            let run = prep.run(&input, &CostModel::vexriscv()).unwrap();
            let reference = prep.reference_op().forward_ref(&input).unwrap();
            assert_eq!(run.output.data(), reference.data(), "{design}");
        }
    }

    #[test]
    fn sparsity_speeds_up_sssa_and_csa() {
        let dense = random_conv(7, 8, 16, 3, 1, Padding::Same, false, 0.0);
        let mut sparse = dense.clone();
        // block-prune 60%
        crate::sparsity::prune::prune_blocks_magnitude(&mut sparse.weights, 16, 0.6);
        let input = random_input(8, 5, 5, 16);
        for design in [DesignKind::Sssa, DesignKind::Csa] {
            let c_dense = PreparedConv::new(&dense, design)
                .unwrap()
                .run(&input, &CostModel::vexriscv())
                .unwrap()
                .counter
                .cycles();
            let c_sparse = PreparedConv::new(&sparse, design)
                .unwrap()
                .run(&input, &CostModel::vexriscv())
                .unwrap()
                .counter
                .cycles();
            assert!(
                (c_sparse as f64) < 0.7 * c_dense as f64,
                "{design}: sparse {c_sparse} vs dense {c_dense}"
            );
        }
    }

    #[test]
    fn baseline_cycles_independent_of_sparsity() {
        let dense = random_conv(9, 4, 8, 3, 1, Padding::Same, false, 0.0);
        let mut sparse = dense.clone();
        crate::sparsity::prune::prune_unstructured_magnitude(&mut sparse.weights, 8, 0.9);
        let input = random_input(10, 5, 5, 8);
        let cd = PreparedConv::new(&dense, DesignKind::BaselineSimd)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap()
            .counter
            .cycles();
        let cs = PreparedConv::new(&sparse, DesignKind::BaselineSimd)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap()
            .counter
            .cycles();
        assert_eq!(cd, cs);
    }

    #[test]
    fn unaligned_channels_rejected() {
        let op = random_conv(11, 4, 6, 1, 1, Padding::Valid, false, 0.0);
        assert!(PreparedConv::new(&op, DesignKind::BaselineSimd).is_err());
    }
}
