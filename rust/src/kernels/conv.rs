//! CFU-accelerated convolution kernel (normal + depthwise).

use super::lane::{
    prepare_lanes, run_lane, run_lane_batched, run_lane_compiled, PreparedLanes,
    INPUT_COST_DENSE, INPUT_COST_GATHER,
};
use super::{tile_ranges_weighted, ExecMode, HostKernel, KernelRun};
use crate::cfu::AnyCfu;
use crate::coordinator::scheduler::JobPool;
use crate::cpu::{CostModel, CycleCounter};
use crate::encoding::pack::{pack4_i8, pack4_le};
use crate::error::{Error, Result};
use crate::isa::DesignKind;
use crate::nn::conv2d::Conv2dOp;
use crate::tensor::{QTensor, Shape};

/// Gather one depthwise input word from precomputed tap base indices:
/// `tap_base[t] + oc` is the byte for tap `t`, or the input zero point
/// when the tap is padding (`tap_base[t] < 0`). Padded tail lanes beyond
/// `taps` also supply the zero point.
#[inline]
fn dw_gather_word(
    x: &[i8],
    tap_base: &[i64],
    taps: usize,
    oc: usize,
    input_zp: i8,
    j: usize,
) -> u32 {
    let mut lanes4 = [input_zp; 4];
    let t0 = j * 4;
    let end = (t0 + 4).min(taps);
    for (k, &tb) in tap_base[t0..end].iter().enumerate() {
        if tb >= 0 {
            lanes4[k] = x[tb as usize + oc];
        }
    }
    pack4_i8(&lanes4)
}

/// A conv layer prepared for one accelerator design: weights packed (and
/// for SSSA/CSA lookahead-encoded) per lane.
#[derive(Debug, Clone)]
pub struct PreparedConv {
    /// The underlying layer description.
    pub op: Conv2dOp,
    /// Target design.
    pub design: DesignKind,
    /// Packed weight lanes.
    pub lanes: PreparedLanes,
    /// Padded lane length (depthwise pads `kh*kw` up to a multiple of 4).
    pub lane_len: usize,
    /// Per-tap (kh, kw) lookup for the depthwise gather (avoids div/mod
    /// in the hot loop — EXPERIMENTS.md §Perf).
    dw_taps: Vec<(usize, usize)>,
}

impl PreparedConv {
    /// Prepare a layer for a design.
    ///
    /// Normal conv requires `in_c % 4 == 0` (the model builders pad input
    /// channels); depthwise lanes are the `kh*kw` taps zero-padded to a
    /// multiple of 4.
    pub fn new(op: &Conv2dOp, design: DesignKind) -> Result<Self> {
        if op.depthwise {
            let taps = op.kh * op.kw;
            let lane_len = taps.div_ceil(4) * 4;
            let mut padded = vec![0i8; op.out_c * lane_len];
            for ch in 0..op.out_c {
                for t in 0..taps {
                    padded[ch * lane_len + t] = op.weights[ch * taps + t];
                }
            }
            let lanes = prepare_lanes(&padded, lane_len, design)?;
            let dw_taps = (0..taps).map(|t| (t / op.kw, t % op.kw)).collect();
            Ok(PreparedConv {
                op: Self::with_effective(op, &lanes, lane_len),
                design,
                lanes,
                lane_len,
                dw_taps,
            })
        } else {
            if op.in_c % 4 != 0 {
                return Err(Error::Model(format!(
                    "{}: in_c {} must be a multiple of 4 (pad input channels)",
                    op.name, op.in_c
                )));
            }
            let lanes = prepare_lanes(&op.weights, op.in_c, design)?;
            Ok(PreparedConv {
                op: Self::with_effective(op, &lanes, op.in_c),
                design,
                lanes,
                lane_len: op.in_c,
                dw_taps: Vec::new(),
            })
        }
    }

    /// Clone of the op with the *effective* (possibly INT7-clamped)
    /// weights — the exact values the CFU multiplies. Running
    /// [`Conv2dOp::forward_ref`] on this clone must match the kernel
    /// output bit-for-bit.
    fn with_effective(op: &Conv2dOp, lanes: &PreparedLanes, lane_len: usize) -> Conv2dOp {
        let mut eff = op.clone();
        if op.depthwise {
            let taps = op.kh * op.kw;
            for ch in 0..op.out_c {
                for t in 0..taps {
                    eff.weights[ch * taps + t] = lanes.effective_weights[ch * lane_len + t];
                }
            }
        } else {
            eff.weights = lanes.effective_weights.clone();
        }
        eff
    }

    /// Reference op view (effective weights).
    pub fn reference_op(&self) -> &Conv2dOp {
        &self.op
    }

    /// Run the kernel over an NHWC input under a CPU cost model, through
    /// the schedule arena's batch-amortized path (the default execution
    /// mode).
    pub fn run(&self, input: &QTensor, model: &CostModel) -> Result<KernelRun> {
        self.run_with_mode(input, model, ExecMode::default())
    }

    /// Run under an explicit [`ExecMode`] — `Interpreted` is the
    /// per-instruction CFU oracle the compiled and batched paths are
    /// differentially tested against (bit-identical outputs and cycle
    /// totals).
    pub fn run_with_mode(
        &self,
        input: &QTensor,
        model: &CostModel,
        mode: ExecMode,
    ) -> Result<KernelRun> {
        self.run_with_kernel(input, model, mode, HostKernel::Auto)
    }

    /// Run under an explicit [`ExecMode`] and [`HostKernel`]. The kernel
    /// only affects the batched path's host throughput; outputs and every
    /// simulated counter total are identical across kernels.
    pub fn run_with_kernel(
        &self,
        input: &QTensor,
        model: &CostModel,
        mode: ExecMode,
        kernel: HostKernel,
    ) -> Result<KernelRun> {
        match mode {
            ExecMode::Batched => self.run_batched(input, model, kernel),
            ExecMode::Compiled => self.run_compiled(input, model),
            ExecMode::Interpreted => self.run_interpreted(input, model),
        }
    }

    /// Validate the input shape and resolve the output geometry.
    fn check_geometry(
        &self,
        input: &QTensor,
    ) -> Result<(usize, usize, usize, usize, usize, i64, i64)> {
        let op = &self.op;
        let ishape = input.shape();
        if ishape.rank() != 4 || ishape.c() != op.in_c {
            return Err(Error::Shape(format!(
                "{}: input {} incompatible with in_c {}",
                op.name, ishape, op.in_c
            )));
        }
        let (n, in_h, in_w) = (ishape.n(), ishape.h(), ishape.w());
        let (out_h, out_w, pad_h, pad_w) = op.geometry(in_h, in_w);
        Ok((n, in_h, in_w, out_h, out_w, pad_h, pad_w))
    }

    /// Precompute the oc-invariant gather base index of every depthwise
    /// tap for one output position: `tap_base[t] + oc` is the input byte
    /// of tap `t` (via the prepare-time `dw_taps` lookup), or `-1` when
    /// the tap falls in padding. Fully out-of-bounds kernel rows are
    /// marked wholesale before the lane runs (the depthwise analogue of
    /// the normal-conv `oob_h` early-continue) — host-side work only;
    /// the modelled gather charges are untouched.
    fn fill_dw_tap_bases(
        &self,
        tap_base: &mut [i64],
        b: usize,
        oh: usize,
        ow: usize,
        geom: (usize, usize, i64, i64),
    ) {
        let op = &self.op;
        let (in_h, in_w, pad_h, pad_w) = geom;
        let base_h = (oh * op.stride) as i64 - pad_h;
        let base_w = (ow * op.stride) as i64 - pad_w;
        for kh in 0..op.kh {
            let ih = base_h + kh as i64;
            let row = kh * op.kw;
            if ih < 0 || ih >= in_h as i64 {
                tap_base[row..row + op.kw].fill(-1);
                continue;
            }
            let row_base = (b * in_h + ih as usize) * in_w;
            for (t, slot) in tap_base[row..row + op.kw].iter_mut().enumerate() {
                let (_, kw) = self.dw_taps[row + t];
                let iw = base_w + kw as i64;
                *slot = if iw < 0 || iw >= in_w as i64 {
                    -1
                } else {
                    ((row_base + iw as usize) * op.in_c) as i64
                };
            }
        }
    }

    /// Batch-amortized execution over a contiguous `ocs` range of output
    /// channels (the lane-tiling dimension): for every output position
    /// the input window is packed once per image, then each output
    /// channel's lane slices are walked once with **all images streamed
    /// against each visited block** ([`run_lane_batched`]). `out` is a
    /// `positions × ocs.len()` buffer (for the full range it *is* the
    /// NHWC output layout).
    ///
    /// Cycle accounting is exact: the per-(image, channel) bookkeeping —
    /// accumulator init, bounds tests, lane setup, requantize, bias load
    /// and store — depends only on the output position, so it is charged
    /// in one scaled bulk flush; lane charges flush scaled by the image
    /// count inside [`run_lane_batched`].
    fn run_lanes_batched(
        &self,
        x: &[i8],
        geom: (usize, usize, usize, usize, usize, i64, i64),
        ocs: std::ops::Range<usize>,
        kernel: HostKernel,
        out: &mut [i8],
        counter: &mut CycleCounter,
    ) -> Result<()> {
        let op = &self.op;
        let (n, in_h, in_w, out_h, out_w, pad_h, pad_w) = geom;
        let width = ocs.len();
        let input_offset = op.input_offset();
        let per = (n * width) as u64;
        let mut accs = vec![0i32; n];
        if op.depthwise {
            let taps = op.kh * op.kw;
            let input_zp = op.input_params.zero_point.clamp(-128, 127) as i8;
            let mut tap_base = vec![-1i64; n * taps];
            for oh in 0..out_h {
                for ow in 0..out_w {
                    for b in 0..n {
                        self.fill_dw_tap_bases(
                            &mut tap_base[b * taps..(b + 1) * taps],
                            b,
                            oh,
                            ow,
                            (in_h, in_w, pad_h, pad_w),
                        );
                    }
                    // acc-init + requantize ALU, bias load, store per
                    // (image, channel) — identical to the row-major flush.
                    counter.charge_bulk(per * 7, per, per, 0, 0, 0, 0);
                    for oc in ocs.clone() {
                        accs.fill(op.bias[oc]);
                        run_lane_batched(
                            self.lanes.lane_schedule(oc),
                            input_offset,
                            INPUT_COST_GATHER,
                            kernel,
                            |b, j| {
                                dw_gather_word(
                                    x,
                                    &tap_base[b * taps..(b + 1) * taps],
                                    taps,
                                    oc,
                                    input_zp,
                                    j,
                                )
                            },
                            &mut accs,
                            counter,
                        )?;
                        let col = oc - ocs.start;
                        for (b, &acc) in accs.iter().enumerate() {
                            let p = (b * out_h + oh) * out_w + ow;
                            out[p * width + col] = op.requant.apply(acc);
                        }
                    }
                }
            }
        } else {
            let nb = op.in_c / 4;
            let kk = op.kh * op.kw;
            let mut win_words = vec![0u32; n * kk * nb];
            let mut row_ok = vec![false; op.kh];
            let mut tap_ok = vec![false; kk];
            let mut valid: Vec<(usize, usize, usize)> = Vec::with_capacity(kk);
            for oh in 0..out_h {
                for ow in 0..out_w {
                    // Window validity is batch-invariant; the packed
                    // words are per image, packed once and reused by
                    // every output channel (the interpreted oracle
                    // re-packs per oc).
                    for kh in 0..op.kh {
                        let ih = (oh * op.stride + kh) as i64 - pad_h;
                        let ok_h = ih >= 0 && ih < in_h as i64;
                        row_ok[kh] = ok_h;
                        if !ok_h {
                            continue;
                        }
                        for kw in 0..op.kw {
                            let t = kh * op.kw + kw;
                            let iw = (ow * op.stride + kw) as i64 - pad_w;
                            let ok_w = iw >= 0 && iw < in_w as i64;
                            tap_ok[t] = ok_w;
                            if !ok_w {
                                continue;
                            }
                            for b in 0..n {
                                let base =
                                    ((b * in_h + ih as usize) * in_w + iw as usize) * op.in_c;
                                let dst = &mut win_words[(b * kk + t) * nb..(b * kk + t + 1) * nb];
                                for (j, w) in dst.iter_mut().enumerate() {
                                    *w = pack4_le(&x[base + j * 4..base + j * 4 + 4]);
                                }
                            }
                        }
                    }
                    // Per-(image, channel) bookkeeping — identical
                    // pattern to the interpreted loop, batch- and
                    // channel-invariant, so computed once per position:
                    // acc init, per-row and per-tap bounds tests, lane
                    // setup, requantize.
                    valid.clear();
                    let mut alu_pp = 1u64;
                    let mut taken_pp = 0u64;
                    let mut nt_pp = 0u64;
                    for kh in 0..op.kh {
                        alu_pp += 1;
                        if !row_ok[kh] {
                            taken_pp += 1;
                            continue;
                        }
                        nt_pp += 1;
                        for kw in 0..op.kw {
                            let t = kh * op.kw + kw;
                            alu_pp += 1;
                            if !tap_ok[t] {
                                taken_pp += 1;
                                continue;
                            }
                            nt_pp += 1;
                            alu_pp += 2; // lane base setup
                            valid.push((kh, kw, t));
                        }
                    }
                    alu_pp += 6; // requantize
                    counter.charge_bulk(per * alu_pp, per, per, per * taken_pp, per * nt_pp, 0, 0);
                    for oc in ocs.clone() {
                        accs.fill(op.bias[oc]);
                        for &(kh, kw, t) in &valid {
                            let lane_idx = (oc * op.kh + kh) * op.kw + kw;
                            run_lane_batched(
                                self.lanes.lane_schedule(lane_idx),
                                input_offset,
                                INPUT_COST_DENSE,
                                kernel,
                                |b, j| win_words[(b * kk + t) * nb + j],
                                &mut accs,
                                counter,
                            )?;
                        }
                        let col = oc - ocs.start;
                        for (b, &acc) in accs.iter().enumerate() {
                            let p = (b * out_h + oh) * out_w + ow;
                            out[p * width + col] = op.requant.apply(acc);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Per-channel tiling weight: a channel's host work is the total
    /// visited-block length of its lanes (all `kh*kw` tap lanes for
    /// normal conv, the single padded tap lane for depthwise).
    fn channel_weights(&self) -> Vec<u64> {
        let op = &self.op;
        (0..op.out_c)
            .map(|oc| {
                if op.depthwise {
                    self.lanes.lane_schedule(oc).visited_blocks() as u64
                } else {
                    let kk = op.kh * op.kw;
                    (0..kk)
                        .map(|t| self.lanes.lane_schedule(oc * kk + t).visited_blocks() as u64)
                        .sum()
                }
            })
            .collect()
    }

    /// The default batch-amortized path over the full channel range.
    fn run_batched(
        &self,
        input: &QTensor,
        model: &CostModel,
        kernel: HostKernel,
    ) -> Result<KernelRun> {
        let op = &self.op;
        let geom = self.check_geometry(input)?;
        let (n, _, _, out_h, out_w, _, _) = geom;
        let mut out = QTensor::zeros(Shape::nhwc(n, out_h, out_w, op.out_c), op.output_params);
        let mut counter = CycleCounter::new(model.clone());
        self.run_lanes_batched(
            input.data(),
            geom,
            0..op.out_c,
            kernel,
            out.data_mut(),
            &mut counter,
        )?;
        Ok(KernelRun { output: out, counter })
    }

    /// Batched execution with the output-channel (lane) dimension tiled
    /// across a worker pool: each tile runs the batch-amortized loop
    /// over its contiguous channel range with its own [`CycleCounter`]
    /// into a tile-local buffer; tiles merge *deterministically in tile
    /// order*, so outputs and every counter total are invariant in the
    /// tile/thread count (asserted by the differential tier).
    ///
    /// Host-side trade-off: the per-position window packing (and
    /// depthwise tap-base fill) is channel-independent, so each tile
    /// repeats it for its own range — tiling pays off when the per-lane
    /// MAC work (`out_c × lane length`) dominates that setup, which is
    /// the case for the compute-heavy layers tiling targets. Layers
    /// where packing dominates (tiny `out_c`, large spatial extent)
    /// gain little; simulated cycles are unaffected either way.
    pub fn run_tiled(
        &self,
        input: &QTensor,
        model: &CostModel,
        pool: &JobPool,
        tiles: usize,
    ) -> Result<KernelRun> {
        self.run_tiled_kernel(input, model, pool, tiles, HostKernel::Auto)
    }

    /// [`run_tiled`](Self::run_tiled) with an explicit [`HostKernel`].
    ///
    /// Tile boundaries balance *work*, not channel count: channels are
    /// split by cumulative visited-block length ([`tile_ranges_weighted`],
    /// summed over each channel's tap lanes), so a few dense filters
    /// cannot serialize a tile while the sparse ones idle.
    pub fn run_tiled_kernel(
        &self,
        input: &QTensor,
        model: &CostModel,
        pool: &JobPool,
        tiles: usize,
        kernel: HostKernel,
    ) -> Result<KernelRun> {
        let op = &self.op;
        let geom = self.check_geometry(input)?;
        let (n, _, _, out_h, out_w, _, _) = geom;
        let positions = n * out_h * out_w;
        let x = input.data();
        let ranges = tile_ranges_weighted(&self.channel_weights(), tiles);
        let parts: Vec<Result<(Vec<i8>, CycleCounter)>> =
            pool.scoped_map(ranges.clone(), |r| {
                let mut counter = CycleCounter::new(model.clone());
                let mut buf = vec![0i8; positions * r.len()];
                self.run_lanes_batched(x, geom, r, kernel, &mut buf, &mut counter)?;
                Ok((buf, counter))
            });
        let mut out = QTensor::zeros(Shape::nhwc(n, out_h, out_w, op.out_c), op.output_params);
        let mut counter = CycleCounter::new(model.clone());
        let out_data = out.data_mut();
        for (range, part) in ranges.into_iter().zip(parts) {
            let (buf, c) = part?;
            counter.merge(&c);
            let width = range.len();
            for p in 0..positions {
                out_data[(p * op.out_c + range.start)..(p * op.out_c + range.end)]
                    .copy_from_slice(&buf[p * width..(p + 1) * width]);
            }
        }
        Ok(KernelRun { output: out, counter })
    }

    /// Table-driven row-major execution: per-lane compiled schedules
    /// plus packed-input reuse (each valid input window word is packed
    /// once per output position and shared across all `out_c` lanes).
    /// Kept as the pre-interchange comparison point for the batched
    /// default.
    fn run_compiled(&self, input: &QTensor, model: &CostModel) -> Result<KernelRun> {
        let op = &self.op;
        let (n, in_h, in_w, out_h, out_w, pad_h, pad_w) = self.check_geometry(input)?;
        let mut out =
            QTensor::zeros(Shape::nhwc(n, out_h, out_w, op.out_c), op.output_params);
        let mut counter = CycleCounter::new(model.clone());
        let x = input.data();
        let input_zp = op.input_params.zero_point.clamp(-128, 127) as i8;
        let input_offset = op.input_offset();
        let out_data = out.data_mut();
        let mut out_idx = 0usize;
        if op.depthwise {
            let taps = op.kh * op.kw;
            let mut tap_base = vec![-1i64; taps];
            for b in 0..n {
                for oh in 0..out_h {
                    for ow in 0..out_w {
                        self.fill_dw_tap_bases(&mut tap_base, b, oh, ow, (in_h, in_w, pad_h, pad_w));
                        for oc in 0..op.out_c {
                            let acc = run_lane_compiled(
                                self.lanes.lane_schedule(oc),
                                input_offset,
                                INPUT_COST_GATHER,
                                |j| dw_gather_word(x, &tap_base, taps, oc, input_zp, j),
                                op.bias[oc],
                                &mut counter,
                            );
                            // acc-init + requantize ALU, bias load, store —
                            // identical to the interpreted path's flush.
                            counter.charge_bulk(7, 1, 1, 0, 0, 0, 0);
                            out_data[out_idx] = op.requant.apply(acc);
                            out_idx += 1;
                        }
                    }
                }
            }
        } else {
            let nb = op.in_c / 4;
            let kk = op.kh * op.kw;
            let mut win_words = vec![0u32; kk * nb];
            let mut row_ok = vec![false; op.kh];
            let mut tap_ok = vec![false; kk];
            for b in 0..n {
                for oh in 0..out_h {
                    for ow in 0..out_w {
                        // Pack the input window once; every oc reuses it
                        // (the interpreted oracle re-packs per oc).
                        for kh in 0..op.kh {
                            let ih = (oh * op.stride + kh) as i64 - pad_h;
                            let ok_h = ih >= 0 && ih < in_h as i64;
                            row_ok[kh] = ok_h;
                            if !ok_h {
                                continue;
                            }
                            for kw in 0..op.kw {
                                let t = kh * op.kw + kw;
                                let iw = (ow * op.stride + kw) as i64 - pad_w;
                                let ok_w = iw >= 0 && iw < in_w as i64;
                                tap_ok[t] = ok_w;
                                if !ok_w {
                                    continue;
                                }
                                let base =
                                    ((b * in_h + ih as usize) * in_w + iw as usize) * op.in_c;
                                let dst = &mut win_words[t * nb..(t + 1) * nb];
                                for (j, w) in dst.iter_mut().enumerate() {
                                    *w = pack4_le(&x[base + j * 4..base + j * 4 + 4]);
                                }
                            }
                        }
                        for oc in 0..op.out_c {
                            // Modelled charges identical to the
                            // interpreted loop: acc init, per-row and
                            // per-tap bounds tests, lane setup, requant.
                            let mut alu = 1u64;
                            let mut taken = 0u64;
                            let mut not_taken = 0u64;
                            let mut acc = op.bias[oc];
                            for kh in 0..op.kh {
                                alu += 1;
                                if !row_ok[kh] {
                                    taken += 1;
                                    continue;
                                }
                                not_taken += 1;
                                for kw in 0..op.kw {
                                    let t = kh * op.kw + kw;
                                    alu += 1;
                                    if !tap_ok[t] {
                                        taken += 1;
                                        continue;
                                    }
                                    not_taken += 1;
                                    alu += 2;
                                    let lane_idx = (oc * op.kh + kh) * op.kw + kw;
                                    let words = &win_words[t * nb..(t + 1) * nb];
                                    acc = run_lane_compiled(
                                        self.lanes.lane_schedule(lane_idx),
                                        input_offset,
                                        INPUT_COST_DENSE,
                                        |j| words[j],
                                        acc,
                                        &mut counter,
                                    );
                                }
                            }
                            alu += 6;
                            counter.charge_bulk(alu, 1, 1, taken, not_taken, 0, 0);
                            out_data[out_idx] = op.requant.apply(acc);
                            out_idx += 1;
                        }
                    }
                }
            }
        }
        Ok(KernelRun { output: out, counter })
    }

    /// The interpreted oracle: every MAC/`inc_indvar` dispatched through
    /// the CFU functional models.
    fn run_interpreted(&self, input: &QTensor, model: &CostModel) -> Result<KernelRun> {
        let op = &self.op;
        let (n, in_h, in_w, out_h, out_w, pad_h, pad_w) = self.check_geometry(input)?;
        let mut out =
            QTensor::zeros(Shape::nhwc(n, out_h, out_w, op.out_c), op.output_params);
        let mut counter = CycleCounter::new(model.clone());
        let mut cfu = AnyCfu::new(self.design, op.input_offset());
        let x = input.data();
        let input_zp = op.input_params.zero_point.clamp(-128, 127) as i8;
        let taps = op.kh * op.kw;
        let mut tap_base = vec![-1i64; taps];

        let out_data = out.data_mut();
        let mut out_idx = 0usize;
        for b in 0..n {
            for oh in 0..out_h {
                for ow in 0..out_w {
                    if op.depthwise {
                        self.fill_dw_tap_bases(&mut tap_base, b, oh, ow, (in_h, in_w, pad_h, pad_w));
                    }
                    for oc in 0..op.out_c {
                        // Per-output-position software charges accumulated
                        // locally, flushed once (§Perf): bias load + move,
                        // bounds tests, lane setup, requantize + store.
                        let mut alu = 1u64; // acc init move
                        let mut taken = 0u64;
                        let mut not_taken = 0u64;
                        let mut acc = op.bias[oc];
                        if op.depthwise {
                            acc = self.run_depthwise_lane(
                                &mut cfu,
                                &mut counter,
                                x,
                                &tap_base,
                                oc,
                                input_zp,
                                acc,
                            )?;
                        } else {
                            for kh in 0..op.kh {
                                let ih = (oh * op.stride + kh) as i64 - pad_h;
                                // bounds test per kernel row
                                alu += 1;
                                let oob_h = ih < 0 || ih >= in_h as i64;
                                if oob_h {
                                    taken += 1;
                                    continue;
                                }
                                not_taken += 1;
                                for kw in 0..op.kw {
                                    let iw = (ow * op.stride + kw) as i64 - pad_w;
                                    alu += 1;
                                    let oob_w = iw < 0 || iw >= in_w as i64;
                                    if oob_w {
                                        taken += 1;
                                        continue;
                                    }
                                    not_taken += 1;
                                    let lane_idx = (oc * op.kh + kh) * op.kw + kw;
                                    let base = ((b * in_h + ih as usize) * in_w
                                        + iw as usize)
                                        * op.in_c;
                                    // lane setup (base pointer arithmetic)
                                    alu += 2;
                                    acc = run_lane(
                                        &self.lanes,
                                        lane_idx,
                                        &mut cfu,
                                        |j| {
                                            let p = base + j * 4;
                                            (pack4_le(&x[p..p + 4]), 1, 0)
                                        },
                                        acc,
                                        &mut counter,
                                    )?;
                                }
                            }
                        }
                        // requantize (~6 ALU: mul-high, shift, add zp, clamp x2, pack)
                        alu += 6;
                        counter.charge_bulk(alu, 1, 1, taken, not_taken, 0, 0);
                        out_data[out_idx] = op.requant.apply(acc);
                        out_idx += 1;
                    }
                }
            }
        }
        Ok(KernelRun { output: out, counter })
    }

    /// Depthwise inner loop (interpreted): the lane is the channel's
    /// padded tap list; input words are gathered through the precomputed
    /// tap bases (4 byte loads + 3 packing ALU ops per block), with
    /// padding positions supplying the input zero point.
    #[allow(clippy::too_many_arguments)]
    fn run_depthwise_lane(
        &self,
        cfu: &mut AnyCfu,
        counter: &mut CycleCounter,
        x: &[i8],
        tap_base: &[i64],
        oc: usize,
        input_zp: i8,
        acc: i32,
    ) -> Result<i32> {
        let taps = self.op.kh * self.op.kw;
        run_lane(
            &self.lanes,
            oc,
            cfu,
            |j| (dw_gather_word(x, tap_base, taps, oc, input_zp, j), 4, 3),
            acc,
            counter,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::conv2d::Padding;
    use crate::tensor::quant::QuantParams;
    use crate::util::Pcg32;

    fn qp(scale: f32, zp: i32) -> QuantParams {
        QuantParams::new(scale, zp).unwrap()
    }

    fn random_conv(
        seed: u64,
        out_c: usize,
        in_c: usize,
        k: usize,
        stride: usize,
        padding: Padding,
        depthwise: bool,
        sparsity: f64,
    ) -> Conv2dOp {
        let mut rng = Pcg32::new(seed);
        let n = if depthwise { out_c * k * k } else { out_c * k * k * in_c };
        let weights: Vec<i8> = (0..n)
            .map(|_| {
                if rng.bernoulli(sparsity) {
                    0
                } else {
                    rng.range_i32(-64, 63) as i8
                }
            })
            .collect();
        let bias: Vec<i32> = (0..out_c).map(|_| rng.range_i32(-500, 500)).collect();
        Conv2dOp::new(
            "t",
            weights,
            bias,
            out_c,
            in_c,
            k,
            k,
            stride,
            padding,
            depthwise,
            qp(0.05, -3),
            0.02,
            qp(0.08, 5),
            true,
        )
        .unwrap()
    }

    fn random_input_n(seed: u64, n: usize, h: usize, w: usize, c: usize) -> QTensor {
        let mut rng = Pcg32::new(seed);
        let data: Vec<i8> =
            (0..n * h * w * c).map(|_| rng.range_i32(-128, 127) as i8).collect();
        QTensor::new(Shape::nhwc(n, h, w, c), data, qp(0.05, -3)).unwrap()
    }

    fn random_input(seed: u64, h: usize, w: usize, c: usize) -> QTensor {
        random_input_n(seed, 1, h, w, c)
    }

    fn assert_runs_identical(a: &KernelRun, b: &KernelRun, tag: &str) {
        assert_eq!(a.output.data(), b.output.data(), "{tag}: outputs");
        assert_eq!(a.counter.cycles(), b.counter.cycles(), "{tag}: cycles");
        assert_eq!(a.counter.total_instrs(), b.counter.total_instrs(), "{tag}: instrs");
        assert_eq!(a.counter.cfu_cycles(), b.counter.cfu_cycles(), "{tag}: cfu");
        assert_eq!(a.counter.cfu_stalls(), b.counter.cfu_stalls(), "{tag}: stalls");
        assert_eq!(a.counter.loaded_bytes(), b.counter.loaded_bytes(), "{tag}: loads");
        assert_eq!(a.counter.stored_bytes(), b.counter.stored_bytes(), "{tag}: stores");
    }

    #[test]
    fn kernel_matches_reference_all_designs() {
        let op = random_conv(1, 8, 8, 3, 1, Padding::Same, false, 0.5);
        let input = random_input(2, 6, 6, 8);
        for design in DesignKind::ALL {
            let prep = PreparedConv::new(&op, design).unwrap();
            let run = prep.run(&input, &CostModel::vexriscv()).unwrap();
            let reference = prep.reference_op().forward_ref(&input).unwrap();
            assert_eq!(run.output.data(), reference.data(), "{design}");
        }
    }

    #[test]
    fn kernel_matches_reference_strided_valid() {
        let op = random_conv(3, 4, 12, 3, 2, Padding::Valid, false, 0.6);
        let input = random_input(4, 9, 9, 12);
        for design in DesignKind::ALL {
            let prep = PreparedConv::new(&op, design).unwrap();
            let run = prep.run(&input, &CostModel::vexriscv()).unwrap();
            let reference = prep.reference_op().forward_ref(&input).unwrap();
            assert_eq!(run.output.data(), reference.data(), "{design}");
        }
    }

    #[test]
    fn depthwise_matches_reference_all_designs() {
        let op = random_conv(5, 8, 8, 3, 1, Padding::Same, true, 0.4);
        let input = random_input(6, 5, 5, 8);
        for design in DesignKind::ALL {
            let prep = PreparedConv::new(&op, design).unwrap();
            let run = prep.run(&input, &CostModel::vexriscv()).unwrap();
            let reference = prep.reference_op().forward_ref(&input).unwrap();
            assert_eq!(run.output.data(), reference.data(), "{design}");
        }
    }

    #[test]
    fn all_modes_equal_outputs_and_cycles() {
        // Normal conv with Same padding, strided Valid, and depthwise
        // with a padded tail (9 taps → 12-lane), at image batch sizes 1
        // and 3: the batched default and the per-lane compiled path must
        // both match the interpreted CFU oracle on outputs AND every
        // counter.
        let cases = [
            (
                random_conv(31, 8, 8, 3, 1, Padding::Same, false, 0.5),
                random_input_n(32, 3, 6, 6, 8),
            ),
            (
                random_conv(33, 4, 12, 3, 2, Padding::Valid, false, 0.6),
                random_input_n(34, 1, 9, 9, 12),
            ),
            (
                random_conv(35, 8, 8, 3, 1, Padding::Same, true, 0.4),
                random_input_n(36, 3, 5, 5, 8),
            ),
        ];
        for (op, input) in &cases {
            for design in DesignKind::ALL {
                let prep = PreparedConv::new(op, design).unwrap();
                let model = CostModel::vexriscv();
                let b = prep.run_with_mode(input, &model, ExecMode::Batched).unwrap();
                let c = prep.run_with_mode(input, &model, ExecMode::Compiled).unwrap();
                let i = prep.run_with_mode(input, &model, ExecMode::Interpreted).unwrap();
                let tag = format!("{design} depthwise={}", op.depthwise);
                assert_runs_identical(&b, &c, &format!("{tag} batched-vs-compiled"));
                assert_runs_identical(&b, &i, &format!("{tag} batched-vs-oracle"));
            }
        }
    }

    #[test]
    fn tiled_equals_batched_any_tile_count() {
        let cases = [
            random_conv(41, 8, 8, 3, 1, Padding::Same, false, 0.5),
            random_conv(43, 8, 8, 3, 1, Padding::Same, true, 0.4),
        ];
        let input = random_input_n(42, 2, 5, 5, 8);
        let model = CostModel::vexriscv();
        for op in &cases {
            for design in [DesignKind::Csa, DesignKind::BaselineSimd] {
                let prep = PreparedConv::new(op, design).unwrap();
                let base = prep.run_with_mode(&input, &model, ExecMode::Batched).unwrap();
                for tiles in [1usize, 3, 8, 16] {
                    let pool = JobPool::new(2);
                    let t = prep.run_tiled(&input, &model, &pool, tiles).unwrap();
                    assert_runs_identical(
                        &base,
                        &t,
                        &format!("{design} dw={} tiles={tiles}", op.depthwise),
                    );
                }
            }
        }
    }

    #[test]
    fn every_host_kernel_matches_the_scalar_oracle() {
        // Normal + depthwise conv, multi-image batch: SWAR and any
        // available SIMD kernel must be bit-identical to the scalar
        // batched loop on outputs and every counter total.
        let cases = [
            random_conv(51, 8, 8, 3, 1, Padding::Same, false, 0.5),
            random_conv(53, 8, 8, 3, 1, Padding::Same, true, 0.4),
        ];
        let input = random_input_n(52, 3, 5, 5, 8);
        let model = CostModel::vexriscv();
        for op in &cases {
            for design in [DesignKind::Csa, DesignKind::BaselineSimd] {
                let prep = PreparedConv::new(op, design).unwrap();
                let scalar = prep
                    .run_with_kernel(&input, &model, ExecMode::Batched, HostKernel::Scalar)
                    .unwrap();
                for kernel in HostKernel::available_kernels() {
                    let run =
                        prep.run_with_kernel(&input, &model, ExecMode::Batched, kernel).unwrap();
                    assert_runs_identical(
                        &scalar,
                        &run,
                        &format!("{design} dw={} {kernel}", op.depthwise),
                    );
                }
            }
        }
    }

    #[test]
    fn more_tiles_than_channels_never_dispatches_empty_work() {
        // Regression: out_c=1 with many requested tiles used to create
        // empty channel ranges; now a single tile runs and outputs match.
        let cases = [
            random_conv(55, 1, 8, 3, 1, Padding::Same, false, 0.4),
            random_conv(57, 1, 1, 3, 1, Padding::Same, true, 0.3),
        ];
        let input_norm = random_input_n(56, 2, 5, 5, 8);
        let input_dw = random_input_n(58, 2, 5, 5, 1);
        let model = CostModel::vexriscv();
        for op in &cases {
            let input = if op.depthwise { &input_dw } else { &input_norm };
            let prep = PreparedConv::new(op, DesignKind::Csa).unwrap();
            let base = prep.run_with_mode(input, &model, ExecMode::Batched).unwrap();
            for tiles in [2usize, 8] {
                let pool = JobPool::new(2);
                let t = prep.run_tiled(input, &model, &pool, tiles).unwrap();
                assert_runs_identical(
                    &base,
                    &t,
                    &format!("out_c=1 dw={} tiles={tiles}", op.depthwise),
                );
            }
        }
    }

    #[test]
    fn default_run_is_batched() {
        let op = random_conv(37, 4, 8, 3, 1, Padding::Same, false, 0.3);
        let input = random_input(38, 5, 5, 8);
        let prep = PreparedConv::new(&op, DesignKind::Csa).unwrap();
        let model = CostModel::vexriscv();
        let a = prep.run(&input, &model).unwrap();
        let b = prep.run_with_mode(&input, &model, ExecMode::Batched).unwrap();
        assert_eq!(a.output.data(), b.output.data());
        assert_eq!(a.counter.cycles(), b.counter.cycles());
    }

    #[test]
    fn sparsity_speeds_up_sssa_and_csa() {
        let dense = random_conv(7, 8, 16, 3, 1, Padding::Same, false, 0.0);
        let mut sparse = dense.clone();
        // block-prune 60%
        crate::sparsity::prune::prune_blocks_magnitude(&mut sparse.weights, 16, 0.6);
        let input = random_input(8, 5, 5, 16);
        for design in [DesignKind::Sssa, DesignKind::Csa] {
            let c_dense = PreparedConv::new(&dense, design)
                .unwrap()
                .run(&input, &CostModel::vexriscv())
                .unwrap()
                .counter
                .cycles();
            let c_sparse = PreparedConv::new(&sparse, design)
                .unwrap()
                .run(&input, &CostModel::vexriscv())
                .unwrap()
                .counter
                .cycles();
            assert!(
                (c_sparse as f64) < 0.7 * c_dense as f64,
                "{design}: sparse {c_sparse} vs dense {c_dense}"
            );
        }
    }

    #[test]
    fn baseline_cycles_independent_of_sparsity() {
        let dense = random_conv(9, 4, 8, 3, 1, Padding::Same, false, 0.0);
        let mut sparse = dense.clone();
        crate::sparsity::prune::prune_unstructured_magnitude(&mut sparse.weights, 8, 0.9);
        let input = random_input(10, 5, 5, 8);
        let cd = PreparedConv::new(&dense, DesignKind::BaselineSimd)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap()
            .counter
            .cycles();
        let cs = PreparedConv::new(&sparse, DesignKind::BaselineSimd)
            .unwrap()
            .run(&input, &CostModel::vexriscv())
            .unwrap()
            .counter
            .cycles();
        assert_eq!(cd, cs);
    }

    #[test]
    fn unaligned_channels_rejected() {
        let op = random_conv(11, 4, 6, 1, 1, Padding::Valid, false, 0.0);
        assert!(PreparedConv::new(&op, DesignKind::BaselineSimd).is_err());
    }
}
