//! Lane preparation and the accelerated inner loop shared by conv and fc.
//!
//! A *lane* is one contiguous run of weights walked by the innermost loop
//! (input channels for normal conv, padded spatial taps for depthwise,
//! input features for fc). Weights are pre-packed into the 32-bit words
//! the CFU consumes — for SSSA/CSA after lookahead encoding (the paper's
//! build-time pre-processing of Algorithm 1).
//!
//! ## The schedule arena
//!
//! The paper's premise is that the sparsity schedule is known at build
//! time — so the simulator compiles it at prepare time instead of
//! re-discovering it per inference. [`prepare_lanes`] materializes one
//! [`ScheduleArena`] per layer: a single flat CSR-style buffer of
//! `(block_idx, w_word)` pairs covering every lane's visited-block walk
//! (the SSSA/CSA lookahead walk, or every block for the baselines/USSA,
//! with the weights pre-decoded per visited block), a lane-offset table
//! into that buffer, and a parallel [`BulkCharge`] table holding each
//! lane's total instruction counts (ALU/loads/branches/CFU
//! issues+stalls — all pure functions of the packed weights). There is no
//! per-lane heap allocation: [`PreparedLanes::lane_schedule`] hands out a
//! borrowed [`LaneScheduleRef`] view, and iterating lanes is a linear
//! scan of one contiguous allocation.
//!
//! ## Execution paths over the arena
//!
//! - [`run_lane_compiled`] walks one lane's slice for one input row — a
//!   tight dot-product loop and a single counter flush;
//! - [`run_lane_batched`] interchanges the loops: it walks the lane's
//!   slice **once** and streams every packed input row of a batch
//!   against each visited block, amortizing schedule decode and weight
//!   reads across the batch. Cycle accounting stays exact because the
//!   lane's [`BulkCharge`] is flushed scaled by the row count
//!   ([`CycleCounter::charge_scaled`] — all counter totals are linear in
//!   the charge counts).
//!
//! Both are bit-identical in outputs *and* cycle totals to the
//! interpreted [`run_lane`] CFU oracle (asserted by the differential
//! tier).

use super::HostKernel;
use crate::cfu::{dot4_words, AnyCfu};
use crate::cpu::{BulkCharge, CycleCounter};
use crate::encoding::int7::clamp_slice_int7;
use crate::encoding::lookahead::encode_lanes;
use crate::encoding::pack::{pack4_i8, pack4_le, pack4_u32_skip_bits};
use crate::error::{Error, Result};
use crate::isa::{CfuOpcode, DesignKind};

/// Lanes per BSR tile: an 8×8 block spans 8 consecutive lanes.
pub const BSR_BLOCK_LANES: usize = 8;
/// Packed words per BSR tile along the lane: 8 weights = 2 words.
pub const BSR_BLOCK_WORDS: usize = 2;
/// Weight banks of the BBS design (a word's bank is `word_idx % K`).
pub const BBS_BANKS: usize = 4;

/// 8×8 block-occupancy bitmap of a BSR-prepared layer, computed at pack
/// time across lanes: a tile is *occupied* iff any of its ≤ 8 lanes has
/// a non-zero word in its ≤ 2 word columns. The walk skips unoccupied
/// tiles wholesale — every lane of a tile group shares one bitmap row.
#[derive(Debug, Clone)]
pub struct BsrOccupancy {
    /// Tile columns per lane (`blocks_per_lane.div_ceil(BSR_BLOCK_WORDS)`).
    pub cols: usize,
    /// Lane groups (`lanes.div_ceil(BSR_BLOCK_LANES)`).
    pub groups: usize,
    /// Row-major `groups × cols` bitmap.
    pub occupied: Vec<bool>,
}

impl BsrOccupancy {
    /// Is the tile at `(group, col)` occupied?
    #[inline]
    pub fn is_occupied(&self, group: usize, col: usize) -> bool {
        self.occupied[group * self.cols + col]
    }

    /// The bitmap row shared by every lane of `group`.
    #[inline]
    pub fn group_row(&self, group: usize) -> &[bool] {
        &self.occupied[group * self.cols..(group + 1) * self.cols]
    }
}

/// Flat CSR storage of every lane's compiled schedule: what each lane's
/// inner loop will do, decided entirely at prepare time from the packed
/// weights, stored in one contiguous allocation instead of one `Vec` per
/// lane.
#[derive(Debug, Clone)]
pub struct ScheduleArena {
    /// Interleaved `(block_idx, w_word)` per *visited* block, all lanes
    /// back to back in lane order. For SSSA/CSA the walk follows the
    /// lookahead skip bits and `w_word` holds the already-decoded INT7
    /// weights; for the baselines/USSA every block is visited and
    /// `w_word` is the raw packed word.
    visited: Vec<(u32, u32)>,
    /// CSR offsets into `visited`: lane `l` owns
    /// `visited[offsets[l]..offsets[l + 1]]`. Length `lanes + 1`.
    offsets: Vec<u32>,
    /// Per-lane total instruction counts of the modelled loop shape,
    /// excluding the call-site-dependent input materialization (see
    /// [`InputCost`]). Parallel to the lane dimension.
    charges: Vec<BulkCharge>,
}

impl ScheduleArena {
    /// Arena with room reserved for `lanes` lanes of up to
    /// `blocks_per_lane` visited blocks each.
    fn with_capacity(lanes: usize, blocks_per_lane: usize) -> Self {
        let mut offsets = Vec::with_capacity(lanes + 1);
        offsets.push(0);
        ScheduleArena {
            visited: Vec::with_capacity(lanes * blocks_per_lane),
            offsets,
            charges: Vec::with_capacity(lanes),
        }
    }

    /// Number of lanes compiled into the arena.
    pub fn lanes(&self) -> usize {
        self.charges.len()
    }

    /// Total visited blocks across every lane (the arena's flat length).
    pub fn total_visited(&self) -> usize {
        self.visited.len()
    }

    /// Borrowed schedule view of one lane.
    #[inline]
    pub fn lane(&self, lane: usize) -> LaneScheduleRef<'_> {
        let lo = self.offsets[lane] as usize;
        let hi = self.offsets[lane + 1] as usize;
        LaneScheduleRef { visited: &self.visited[lo..hi], charge: &self.charges[lane] }
    }

    /// FNV-1a checksum over the arena's CSR buffers (visited entries +
    /// offset table). Cheap enough to verify on every prepared-cache
    /// hit; any single bit flip in the schedule changes it.
    pub fn checksum(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for &(b, w) in &self.visited {
            h = fnv1a_u32(h, b);
            h = fnv1a_u32(h, w);
        }
        for &o in &self.offsets {
            h = fnv1a_u32(h, o);
        }
        h
    }

    /// Flip one bit of a visited entry's weight word — the
    /// `ScheduleArena` fault model for SEU injection (chaos tier only;
    /// the integrity checksum is what detects this in production).
    /// No-op (returns `false`) when the arena is empty.
    pub fn flip_visited_bit(&mut self, entry: usize, bit: u32) -> bool {
        if self.visited.is_empty() {
            return false;
        }
        let e = entry % self.visited.len();
        self.visited[e].1 ^= 1 << (bit % 32);
        true
    }
}

/// FNV-1a 64-bit offset basis.
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a 64-bit prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold the 4 little-endian bytes of `v` into an FNV-1a state.
#[inline]
fn fnv1a_u32(mut h: u64, v: u32) -> u64 {
    for b in v.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Borrowed view of one lane's compiled schedule inside the
/// [`ScheduleArena`] — the visited-block slice plus the lane's bulk
/// charge. `Copy`, so call sites pass it by value.
#[derive(Debug, Clone, Copy)]
pub struct LaneScheduleRef<'a> {
    /// `(block_idx, w_word)` per visited block, in walk order.
    pub visited: &'a [(u32, u32)],
    /// Total instruction counts of the lane's modelled loop shape.
    /// Flushing this through [`CycleCounter::charge`] reproduces the
    /// interpreted loop's charges exactly under any cost model.
    pub charge: &'a BulkCharge,
}

impl LaneScheduleRef<'_> {
    /// Blocks the compiled loop visits.
    pub fn visited_blocks(&self) -> usize {
        self.visited.len()
    }
}

/// Per-visited-block input materialization cost: the loads/ALU ops the
/// modelled loop spends producing one packed input word (on top of the
/// weight-word load already in the lane's [`BulkCharge`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InputCost {
    /// Loads per block.
    pub loads: u64,
    /// Extra ALU ops per block.
    pub alus: u64,
}

/// Contiguous NHWC channels: one `lw x` per block.
pub const INPUT_COST_DENSE: InputCost = InputCost { loads: 1, alus: 0 };
/// Depthwise spatial gather: 4 byte loads + 3 packing ops per block.
pub const INPUT_COST_GATHER: InputCost = InputCost { loads: 4, alus: 3 };

/// Weights of one layer, packed per-lane into CFU operand words.
#[derive(Debug, Clone)]
pub struct PreparedLanes {
    /// Packed 32-bit weight words, lane-major.
    pub words: Vec<u32>,
    /// Blocks (words) per lane.
    pub blocks_per_lane: usize,
    /// Number of lanes.
    pub lanes: usize,
    /// Design the words were packed for.
    pub design: DesignKind,
    /// Weights clamped from INT8 to INT7 during preparation (SSSA/CSA
    /// only — the paper's dynamic-range restriction).
    pub clamped: usize,
    /// Weights zeroed at prepare time to enforce the 2:4 group
    /// constraint (NM-SSA only; 0 for every other design).
    pub nm_pruned: usize,
    /// 8×8 tile-occupancy bitmap (BSR only, `None` otherwise).
    pub bsr: Option<BsrOccupancy>,
    /// Weights actually used for compute (post-clamp, post-N:M
    /// enforcement) — lets callers verify against a reference op run
    /// with identical weights.
    pub effective_weights: Vec<i8>,
    /// Flat compiled schedules of every lane (visited blocks + bulk
    /// charges in CSR form) — the default execution path; the
    /// interpreted CFU walk stays as the differential oracle.
    pub arena: ScheduleArena,
}

impl PreparedLanes {
    /// FNV-1a checksum over the layer's packed-weight and schedule
    /// buffers: the raw packed words (what the interpreted oracle
    /// reads), the post-clamp effective weights, and the compiled
    /// [`ScheduleArena`] CSR buffers (what the batched/compiled paths
    /// read). Computed once at prepare time and re-verified on every
    /// prepared-cache hit.
    pub fn checksum(&self) -> u64 {
        let mut h = FNV_OFFSET;
        for &w in &self.words {
            h = fnv1a_u32(h, w);
        }
        for &w in &self.effective_weights {
            h ^= w as u8 as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
        // Mix rather than concatenate: two layers whose buffers differ
        // only in the words/arena split must not collide.
        h ^ self.arena.checksum().rotate_left(17)
    }

    /// Flip one bit of a packed weight word — the weight-memory SEU
    /// fault model (chaos tier only). No-op (returns `false`) when the
    /// layer has no packed words.
    pub fn flip_word_bit(&mut self, word: usize, bit: u32) -> bool {
        if self.words.is_empty() {
            return false;
        }
        let w = word % self.words.len();
        self.words[w] ^= 1 << (bit % 32);
        true
    }
}

/// Pack a weight buffer of `lanes × lane_len` into CFU words for a design.
///
/// `lane_len` must be a positive multiple of 4. For SSSA/CSA the weights
/// are clamped to INT7 and lookahead-encoded (Algorithms 1 & 2). For
/// NM-SSA the 2:4 group constraint is enforced (smallest-|w| members of
/// over-full groups are zeroed, counted in
/// [`PreparedLanes::nm_pruned`]). For BSR the cross-lane 8×8
/// tile-occupancy bitmap is computed before the schedules are compiled.
pub fn prepare_lanes(weights: &[i8], lane_len: usize, design: DesignKind) -> Result<PreparedLanes> {
    if lane_len == 0 || lane_len % 4 != 0 {
        return Err(Error::Encoding(format!("lane_len {lane_len} not a positive multiple of 4")));
    }
    if weights.is_empty() || weights.len() % lane_len != 0 {
        return Err(Error::Encoding(format!(
            "weight buffer {} not divisible by lane_len {lane_len}",
            weights.len()
        )));
    }
    let lanes = weights.len() / lane_len;
    let blocks_per_lane = lane_len / 4;
    // Prepare-path allocation: encode_lanes already copies, so the
    // clamped buffer itself becomes `effective_weights` (no third copy —
    // this runs once per cached prepared model, but large models encode
    // hundreds of layers).
    let (buf, clamped, nm_pruned, effective_weights) = if design.uses_lookahead_encoding() {
        let mut ws = weights.to_vec();
        let clamped = clamp_slice_int7(&mut ws);
        let enc = encode_lanes(&ws, lane_len)?;
        (enc.encoded, clamped, 0, ws)
    } else if design.enforces_structure() {
        let mut ws = weights.to_vec();
        let rep = crate::sparsity::prune_nm(&mut ws, lane_len, 2, 4);
        (ws.clone(), 0, rep.zeroed, ws)
    } else {
        (weights.to_vec(), 0, 0, weights.to_vec())
    };
    let words: Vec<u32> = buf.chunks(4).map(pack4_le).collect();
    let bsr = (design == DesignKind::Bsr).then(|| {
        let cols = blocks_per_lane.div_ceil(BSR_BLOCK_WORDS);
        let groups = lanes.div_ceil(BSR_BLOCK_LANES);
        let mut occupied = vec![false; groups * cols];
        for (lane, lane_words) in words.chunks_exact(blocks_per_lane).enumerate() {
            for (j, &w) in lane_words.iter().enumerate() {
                if w != 0 {
                    occupied[(lane / BSR_BLOCK_LANES) * cols + j / BSR_BLOCK_WORDS] = true;
                }
            }
        }
        BsrOccupancy { cols, groups, occupied }
    });
    let mut arena = ScheduleArena::with_capacity(lanes, blocks_per_lane);
    for (lane, lane_words) in words.chunks_exact(blocks_per_lane).enumerate() {
        let occ = bsr.as_ref().map(|b| b.group_row(lane / BSR_BLOCK_LANES));
        compile_lane_into(design, lane_words, occ, &mut arena)?;
    }
    Ok(PreparedLanes {
        words,
        blocks_per_lane,
        lanes,
        design,
        clamped,
        nm_pruned,
        bsr,
        effective_weights,
        arena,
    })
}

/// Compile one lane's schedule from its packed words straight into the
/// arena: the visited-block walk, the per-visited-block decoded weight
/// word, and the lane's total instruction charges. Everything here is a
/// pure function of the packed weights (plus, for BSR, the lane group's
/// occupancy bitmap row) — exactly the information Algorithm 1 bakes
/// into the weight stream offline.
///
/// Loop-shape charges (see the module docs of [`crate::kernels`]): the
/// baselines'/USSA's `for` shape spends 4 ALU + 1 CFU per visited block,
/// SSSA/CSA's `while` shape 3 ALU + 2 CFU; NM-SSA probes every group
/// (1 ALU + 1 load + 1 `nm_lookahead`) and spends 2 ALU + 1 load + 1
/// `nm_mac` more per occupied group; BSR spends 3 ALU + 1 descriptor
/// load per occupied tile column and 4 ALU + 1 load + 1 `bsr_mac` per
/// word inside it (skipped tiles cost nothing); BBS sets up its
/// [`BBS_BANKS`] bank descriptors per lane (1 ALU + 1 load each), spends
/// 4 ALU + 2 loads (index + weight) + 1 `bbs_mac` per non-zero word, and
/// stalls for the lock-step bank drain (`K·max_bank − visited`).
///
/// Errors with [`Error::Encoding`] if the arena's visited-block count no
/// longer fits the u32 CSR offset table (a silent `as u32` truncation
/// here would make later lanes alias earlier schedules).
fn compile_lane_into(
    design: DesignKind,
    words: &[u32],
    bsr_occ: Option<&[bool]>,
    arena: &mut ScheduleArena,
) -> Result<()> {
    let nblocks = words.len();
    let start = arena.visited.len();
    let charge = match design {
        DesignKind::BaselineSimd | DesignKind::BaselineSequential | DesignKind::Ussa => {
            let mut cfu_stalls = 0u64;
            for (j, &w) in words.iter().enumerate() {
                let mac_cycles = match design {
                    DesignKind::BaselineSimd => crate::cfu::baseline::simd_mac_cycles(),
                    DesignKind::BaselineSequential => crate::cfu::baseline::seq_mac_cycles(),
                    _ => crate::cfu::ussa::vcmac_cycles(w),
                };
                cfu_stalls += (mac_cycles as u64).saturating_sub(1);
                arena.visited.push((j as u32, w));
            }
            // Every block visited; branch taken except on lane exit.
            let n = (arena.visited.len() - start) as u64;
            BulkCharge {
                alu: n * 4,
                loads: n,
                stores: 0,
                branches_taken: n - 1,
                branches_not_taken: 1,
                cfu_issues: n,
                cfu_stalls,
            }
        }
        DesignKind::Sssa | DesignKind::Csa => {
            // The lookahead walk of Listings 2/3, driven by the same skip
            // bits the inc_indvar datapath reads. sssa_mac is 1 cycle
            // (no stall); csa_vcmac stalls per non-zero decoded weight.
            let mut cfu_stalls = 0u64;
            let mut j = 0usize;
            while j < nblocks {
                let w = words[j];
                if design == DesignKind::Csa {
                    cfu_stalls += (crate::cfu::csa::vcmac_cycles(w) as u64).saturating_sub(1);
                }
                // Store the decoded weights: the run loop multiplies
                // without per-block shift work, and `inc_indvar` never
                // stalls (1 cycle), so no extra charge.
                arena.visited.push((j as u32, pack4_i8(&crate::cfu::sssa::decode_weights(w))));
                j += 1 + pack4_u32_skip_bits(w) as usize;
            }
            // At least block 0 is always visited.
            let n = (arena.visited.len() - start) as u64;
            BulkCharge {
                alu: n * 3,
                loads: n,
                stores: 0,
                branches_taken: n - 1,
                branches_not_taken: 1,
                cfu_issues: n * 2,
                cfu_stalls,
            }
        }
        DesignKind::NmSsa => {
            // Probe every 2:4 group with the fixed-cycle lookahead;
            // only occupied groups reach the MAC.
            for (j, &w) in words.iter().enumerate() {
                if w != 0 {
                    arena.visited.push((j as u32, w));
                }
            }
            let n = nblocks as u64;
            let v = (arena.visited.len() - start) as u64;
            BulkCharge {
                alu: n + 2 * v,
                loads: n + v,
                stores: 0,
                branches_taken: n - 1,
                branches_not_taken: 1,
                cfu_issues: n + v,
                cfu_stalls: 0,
            }
        }
        DesignKind::Bsr => {
            let occ = bsr_occ.expect("BSR schedules need the lane group's occupancy row");
            let mut cols_visited = 0u64;
            for (col, &occupied) in occ.iter().enumerate() {
                if !occupied {
                    continue;
                }
                cols_visited += 1;
                for (j, &w) in words
                    .iter()
                    .enumerate()
                    .skip(col * BSR_BLOCK_WORDS)
                    .take(BSR_BLOCK_WORDS)
                {
                    arena.visited.push((j as u32, w));
                }
            }
            let v = (arena.visited.len() - start) as u64;
            BulkCharge {
                alu: 3 * cols_visited + 4 * v,
                loads: cols_visited + v,
                stores: 0,
                branches_taken: cols_visited.saturating_sub(1),
                branches_not_taken: 1,
                cfu_issues: v,
                cfu_stalls: 0,
            }
        }
        DesignKind::Bbs => {
            let mut bank_counts = [0u64; BBS_BANKS];
            for (j, &w) in words.iter().enumerate() {
                if w != 0 {
                    arena.visited.push((j as u32, w));
                    bank_counts[j % BBS_BANKS] += 1;
                }
            }
            let v = (arena.visited.len() - start) as u64;
            let max_bank = bank_counts.into_iter().max().unwrap_or(0);
            BulkCharge {
                alu: BBS_BANKS as u64 + 4 * v,
                loads: BBS_BANKS as u64 + 2 * v,
                stores: 0,
                branches_taken: v.saturating_sub(1),
                branches_not_taken: 1,
                cfu_issues: v,
                cfu_stalls: (BBS_BANKS as u64 * max_bank).saturating_sub(v),
            }
        }
    };
    arena.charges.push(charge);
    let end = u32::try_from(arena.visited.len()).map_err(|_| {
        Error::Encoding(format!(
            "schedule arena overflow: {} visited blocks exceed the u32 CSR offset range",
            arena.visited.len()
        ))
    })?;
    arena.offsets.push(end);
    Ok(())
}

impl PreparedLanes {
    /// Word slice of one lane.
    #[inline]
    pub fn lane_words(&self, lane: usize) -> &[u32] {
        let b = self.blocks_per_lane;
        &self.words[lane * b..(lane + 1) * b]
    }

    /// Borrowed compiled schedule of one lane (a view into the arena).
    #[inline]
    pub fn lane_schedule(&self, lane: usize) -> LaneScheduleRef<'_> {
        self.arena.lane(lane)
    }
}

/// Execute the inner loop over one lane of a prepared layer,
/// accumulating into `acc` — the interpreted CFU oracle every compiled
/// path is differentially tested against.
///
/// `input_word(j)` supplies the packed input word for block `j` and the
/// count of loads/ALU ops spent materializing it (1 load for contiguous
/// NHWC channels; 4 byte-loads + 3 packs for depthwise gathers).
///
/// Takes the whole [`PreparedLanes`] (not just the lane's words) because
/// the walk may need prepare-time format metadata: BSR skips tiles via
/// the cross-lane occupancy bitmap, which no single lane's words can
/// reconstruct.
///
/// Returns the updated accumulator. Charges every instruction of the
/// loop shapes documented in [`crate::kernels`] and [`compile_lane_into`].
#[inline]
pub fn run_lane<F>(
    prep: &PreparedLanes,
    lane: usize,
    cfu: &mut AnyCfu,
    mut input_word: F,
    acc: i32,
    counter: &mut CycleCounter,
) -> Result<i32>
where
    F: FnMut(usize) -> (u32, u64, u64),
{
    let design = prep.design;
    let lane_words = prep.lane_words(lane);
    let nblocks = lane_words.len();
    let mut acc = acc;
    // Per-block instruction charges are accumulated locally and flushed
    // to the counter once per lane (charge_bulk) — ~2.5× faster hot
    // loop with identical totals (EXPERIMENTS.md §Perf).
    let mut alu = 0u64;
    let mut loads = 0u64;
    let mut taken = 0u64;
    let mut not_taken = 0u64;
    let mut cfu_issues = 0u64;
    let mut cfu_stalls = 0u64;
    match design {
        DesignKind::BaselineSimd | DesignKind::BaselineSequential | DesignKind::Ussa => {
            let mac_op = match design {
                DesignKind::BaselineSimd => CfuOpcode::CfuSimdMac,
                DesignKind::BaselineSequential => CfuOpcode::CfuSeqMac,
                _ => CfuOpcode::UssaVcMac,
            };
            for j in 0..nblocks {
                // add a_w; lw w; add a_x (+gather); lw x; add acc; addi i
                let (x_word, x_loads, x_alus) = input_word(j);
                alu += 4 + x_alus;
                loads += 1 + x_loads;
                // cfu mac
                let resp = cfu.execute(mac_op, lane_words[j], x_word)?;
                cfu_issues += 1;
                cfu_stalls += (resp.cycles as u64).saturating_sub(1);
                acc = acc.wrapping_add(resp.rd as i32);
                // loop branch (taken except on exit)
                if j + 1 != nblocks {
                    taken += 1;
                } else {
                    not_taken += 1;
                }
            }
        }
        DesignKind::Sssa | DesignKind::Csa => {
            let (mac_op, inc_op) = if design == DesignKind::Sssa {
                (CfuOpcode::SssaMac, CfuOpcode::SssaIncIndvar)
            } else {
                (CfuOpcode::CsaVcMac, CfuOpcode::CsaIncIndvar)
            };
            let mut j = 0usize;
            while j < nblocks {
                // add a_w; lw w; add a_x (+gather); lw x; add acc
                let (x_word, x_loads, x_alus) = input_word(j);
                alu += 3 + x_alus;
                loads += 1 + x_loads;
                // cfu mac
                let resp = cfu.execute(mac_op, lane_words[j], x_word)?;
                cfu_issues += 1;
                cfu_stalls += (resp.cycles as u64).saturating_sub(1);
                acc = acc.wrapping_add(resp.rd as i32);
                // cfu inc_indvar (replaces the addi): i_bytes = 4*j
                let i_bytes = (4 * j) as u32;
                let inc = cfu.execute(inc_op, lane_words[j], i_bytes)?;
                cfu_issues += 1;
                cfu_stalls += (inc.cycles as u64).saturating_sub(1);
                let next = (inc.rd / 4) as usize;
                debug_assert!(next > j, "inc_indvar must advance");
                // loop branch
                if next < nblocks {
                    taken += 1;
                } else {
                    not_taken += 1;
                }
                j = next;
            }
        }
        DesignKind::NmSsa => {
            for j in 0..nblocks {
                // addi i; lw w
                alu += 1;
                loads += 1;
                // cfu nm_lookahead: fixed-cycle group probe
                let probe = cfu.execute(CfuOpcode::NmLookahead, lane_words[j], 0)?;
                cfu_issues += 1;
                cfu_stalls += (probe.cycles as u64).saturating_sub(1);
                if probe.rd != 0 {
                    // add a_x (+gather); lw x; add acc
                    let (x_word, x_loads, x_alus) = input_word(j);
                    alu += 2 + x_alus;
                    loads += 1 + x_loads;
                    // cfu nm_mac
                    let resp = cfu.execute(CfuOpcode::NmMac, lane_words[j], x_word)?;
                    cfu_issues += 1;
                    cfu_stalls += (resp.cycles as u64).saturating_sub(1);
                    acc = acc.wrapping_add(resp.rd as i32);
                }
                // loop branch (taken except on exit)
                if j + 1 != nblocks {
                    taken += 1;
                } else {
                    not_taken += 1;
                }
            }
        }
        DesignKind::Bsr => {
            // The tile walk follows the pack-time occupancy bitmap;
            // unoccupied tiles are skipped without any charge.
            let occ = prep
                .bsr
                .as_ref()
                .ok_or_else(|| Error::Sim("BSR lane walk without an occupancy bitmap".into()))?
                .group_row(lane / BSR_BLOCK_LANES);
            let mut cols_visited = 0u64;
            for (col, &occupied) in occ.iter().enumerate() {
                if !occupied {
                    continue;
                }
                cols_visited += 1;
                // lw tile descriptor; add a_w; add a_x; addi col
                alu += 3;
                loads += 1;
                let lo = col * BSR_BLOCK_WORDS;
                let hi = (lo + BSR_BLOCK_WORDS).min(nblocks);
                for j in lo..hi {
                    // add a_w; lw w; add a_x (+gather); lw x; add acc; addi i
                    let (x_word, x_loads, x_alus) = input_word(j);
                    alu += 4 + x_alus;
                    loads += 1 + x_loads;
                    // cfu bsr_mac
                    let resp = cfu.execute(CfuOpcode::BsrMac, lane_words[j], x_word)?;
                    cfu_issues += 1;
                    cfu_stalls += (resp.cycles as u64).saturating_sub(1);
                    acc = acc.wrapping_add(resp.rd as i32);
                }
            }
            // Tile loop branch: taken between occupied tiles, one exit.
            taken += cols_visited.saturating_sub(1);
            not_taken += 1;
        }
        DesignKind::Bbs => {
            // Bank-descriptor setup: one pointer init + index-list load
            // per bank.
            alu += BBS_BANKS as u64;
            loads += BBS_BANKS as u64;
            let mut bank_counts = [0u64; BBS_BANKS];
            let mut visited = 0u64;
            for j in 0..nblocks {
                // Zero words are absent from the bank index lists — the
                // walk never touches them (that is the format).
                if lane_words[j] == 0 {
                    continue;
                }
                visited += 1;
                bank_counts[j % BBS_BANKS] += 1;
                // lw idx; add a_w; lw w; add a_x (+gather); lw x; add
                // acc; addi i
                let (x_word, x_loads, x_alus) = input_word(j);
                alu += 4 + x_alus;
                loads += 2 + x_loads;
                // cfu bbs_mac
                let resp = cfu.execute(CfuOpcode::BbsMac, lane_words[j], x_word)?;
                cfu_issues += 1;
                cfu_stalls += (resp.cycles as u64).saturating_sub(1);
                acc = acc.wrapping_add(resp.rd as i32);
            }
            // Entry loop branch: taken between visited words, one exit.
            taken += visited.saturating_sub(1);
            not_taken += 1;
            // Lock-step bank drain: the busiest bank bounds the lane,
            // idle banks stall behind it.
            let max_bank = bank_counts.into_iter().max().unwrap_or(0);
            cfu_stalls += (BBS_BANKS as u64 * max_bank).saturating_sub(visited);
        }
    }
    counter.charge_bulk(alu, loads, 0, taken, not_taken, cfu_issues, cfu_stalls);
    Ok(acc)
}

/// Execute one lane through its compiled schedule for a single input
/// row.
///
/// `input_word(j)` supplies the packed input word for block `j`; its
/// modelled cost is the uniform per-block `input_cost` (dense `lw` or
/// depthwise gather), added to the schedule's precomputed charge at the
/// single flush. The accumulation is the same wrapping INT7/INT8 dot
/// product every CFU MAC reduces to, so outputs and cycle totals are
/// bit-identical to [`run_lane`] (differential tier).
#[inline]
pub fn run_lane_compiled<F>(
    schedule: LaneScheduleRef<'_>,
    input_offset: i32,
    input_cost: InputCost,
    mut input_word: F,
    acc: i32,
    counter: &mut CycleCounter,
) -> i32
where
    F: FnMut(usize) -> u32,
{
    let mut acc = acc;
    for &(j, w_word) in schedule.visited {
        acc = acc.wrapping_add(dot4_words(w_word, input_word(j as usize), input_offset));
    }
    let n = schedule.visited.len() as u64;
    let c = schedule.charge;
    counter.charge_bulk(
        c.alu + n * input_cost.alus,
        c.loads + n * input_cost.loads,
        c.stores,
        c.branches_taken,
        c.branches_not_taken,
        c.cfu_issues,
        c.cfu_stalls,
    );
    acc
}

/// Execute one lane's compiled schedule against **all rows of a batch**
/// at once — the loop-interchanged arena path.
///
/// Where [`run_lane_compiled`] re-walks the schedule per input row, this
/// walks the lane's arena slice once and streams every row's packed
/// input word (`input_word(row, j)`) against each visited block, so
/// schedule decode and weight-word reads are amortized across the batch
/// on the host. `accs` carries one accumulator per row (pre-seeded with
/// the bias) and is updated in place.
///
/// Cycle accounting stays exact: the lane's [`BulkCharge`] plus the
/// per-block input cost is flushed scaled by `accs.len()`
/// ([`CycleCounter::charge_scaled`]) — every counter total is linear in
/// the charge counts, so the interchange cannot change simulated cycles,
/// instruction counts, stalls or byte traffic (differential tier).
///
/// `kernel` picks the host-side multiply routine ([`HostKernel`]):
/// `Scalar` is the per-word oracle loop; the SWAR/SIMD kernels compute
/// several rows per step with bit-identical wrapping-i32 results (see
/// [`crate::cfu::hostdot`]). The kernel choice only changes *host*
/// throughput — the scaled charge above is independent of it, so
/// simulated cycles cannot drift.
///
/// Errors if the scaled charge flush overflows u64
/// ([`CycleCounter::charge_scaled`]).
#[inline]
pub fn run_lane_batched<F>(
    schedule: LaneScheduleRef<'_>,
    input_offset: i32,
    input_cost: InputCost,
    kernel: HostKernel,
    mut input_word: F,
    accs: &mut [i32],
    counter: &mut CycleCounter,
) -> Result<()>
where
    F: FnMut(usize, usize) -> u32,
{
    match kernel.resolve() {
        HostKernel::Scalar => {
            for &(j, w_word) in schedule.visited {
                let j = j as usize;
                for (row, acc) in accs.iter_mut().enumerate() {
                    *acc = acc.wrapping_add(dot4_words(w_word, input_word(row, j), input_offset));
                }
            }
        }
        resolved => {
            // Multi-row path: materialize each block's input words into a
            // fixed-size scratch chunk and hand whole row slices to the
            // SWAR/SIMD kernel. The chunk lives on the stack (no per-call
            // allocation) and bounds the scratch footprint for big batches.
            let rows_fn = resolved.rows_fn();
            const ROW_CHUNK: usize = 64;
            let mut xbuf = [0u32; ROW_CHUNK];
            for &(j, w_word) in schedule.visited {
                let j = j as usize;
                let mut start = 0usize;
                while start < accs.len() {
                    let len = (accs.len() - start).min(ROW_CHUNK);
                    for (slot, row) in xbuf[..len].iter_mut().zip(start..start + len) {
                        *slot = input_word(row, j);
                    }
                    rows_fn(w_word, input_offset, &xbuf[..len], &mut accs[start..start + len]);
                    start += len;
                }
            }
        }
    }
    let n = schedule.visited.len() as u64;
    let c = schedule.charge;
    let per_row = BulkCharge {
        alu: c.alu + n * input_cost.alus,
        loads: c.loads + n * input_cost.loads,
        ..*c
    };
    counter.charge_scaled(&per_row, accs.len() as u64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfu::AnyCfu;
    use crate::cpu::CostModel;
    use crate::encoding::pack::unpack4_i8;

    /// Dense input word supplier: contiguous channels, 1 load, 0 extra alu.
    fn dense_input(xs: Vec<i8>) -> impl FnMut(usize) -> (u32, u64, u64) {
        move |j| (pack4_le(&xs[j * 4..j * 4 + 4]), 1, 0)
    }

    /// Assert two counters agree on every observable total.
    fn assert_counters_equal(a: &CycleCounter, b: &CycleCounter, ctx: &str) {
        use crate::cpu::InstrClass;
        assert_eq!(a.cycles(), b.cycles(), "{ctx}: cycles");
        assert_eq!(a.total_instrs(), b.total_instrs(), "{ctx}: instrs");
        assert_eq!(a.cfu_cycles(), b.cfu_cycles(), "{ctx}: cfu cycles");
        assert_eq!(a.cfu_stalls(), b.cfu_stalls(), "{ctx}: cfu stalls");
        assert_eq!(a.loaded_bytes(), b.loaded_bytes(), "{ctx}: loaded bytes");
        assert_eq!(a.stored_bytes(), b.stored_bytes(), "{ctx}: stored bytes");
        for class in [
            InstrClass::Alu,
            InstrClass::Load,
            InstrClass::Store,
            InstrClass::Branch,
            InstrClass::Cfu,
        ] {
            assert_eq!(a.instr_count(class), b.instr_count(class), "{ctx}: {class:?}");
        }
    }

    fn dot(ws: &[i8], xs: &[i8], off: i32) -> i32 {
        ws.iter().zip(xs).map(|(&w, &x)| w as i32 * (x as i32 + off)).sum()
    }

    #[test]
    fn all_designs_same_acc_int7_weights() {
        // ≤ 2 non-zeros per 4-weight group so NM-SSA's prepare-time
        // enforcement is a no-op and every design computes the same dot.
        let ws: Vec<i8> = vec![1, -2, 0, 0, 0, 0, 0, 0, 5, 0, -6, 0, 7, 0, 0, -10];
        let xs: Vec<i8> = (0..16).map(|i| (i * 3 - 20) as i8).collect();
        let expect = dot(&ws, &xs, 128);
        for design in DesignKind::ALL {
            let prep = prepare_lanes(&ws, 16, design).unwrap();
            assert_eq!(prep.nm_pruned, 0, "{design}");
            let mut cfu = AnyCfu::new(design, 128);
            let mut counter = CycleCounter::new(CostModel::vexriscv());
            let acc =
                run_lane(&prep, 0, &mut cfu, dense_input(xs.clone()), 0, &mut counter).unwrap();
            assert_eq!(acc, expect, "{design}");
            assert!(counter.cycles() > 0);
        }
    }

    #[test]
    fn nm_enforcement_zeroes_excess_group_members() {
        // Group 0 has 3 non-zeros: the smallest-|w| member is zeroed at
        // prepare time and the walk computes with the enforced weights.
        let ws: Vec<i8> = vec![1, -2, 0, 4, 0, 0, 0, 0];
        let xs: Vec<i8> = vec![3; 8];
        let prep = prepare_lanes(&ws, 8, DesignKind::NmSsa).unwrap();
        assert_eq!(prep.nm_pruned, 1);
        assert_eq!(&prep.effective_weights[..4], &[0, -2, 0, 4]);
        let mut cfu = AnyCfu::new(DesignKind::NmSsa, 0);
        let mut counter = CycleCounter::new(CostModel::vexriscv());
        let acc = run_lane(&prep, 0, &mut cfu, dense_input(xs.clone()), 0, &mut counter).unwrap();
        assert_eq!(acc, dot(&prep.effective_weights, &xs, 0));
        // Only the occupied group is visited.
        assert_eq!(prep.lane_schedule(0).visited_blocks(), 1);
    }

    #[test]
    fn sssa_visits_fewer_blocks() {
        // lane: [nz][z][z][nz] → SSSA visits 2 blocks, baseline 4.
        let ws: Vec<i8> = [[1i8, 2, 3, 4], [0; 4], [0; 4], [5, 6, 7, 8]].concat();
        let xs: Vec<i8> = vec![1; 16];
        let mut base_counter = CycleCounter::new(CostModel::vexriscv());
        let mut cfu = AnyCfu::new(DesignKind::BaselineSimd, 0);
        let prep = prepare_lanes(&ws, 16, DesignKind::BaselineSimd).unwrap();
        run_lane(&prep, 0, &mut cfu, dense_input(xs.clone()), 0, &mut base_counter).unwrap();

        let mut sssa_counter = CycleCounter::new(CostModel::vexriscv());
        let mut cfu = AnyCfu::new(DesignKind::Sssa, 0);
        let prep = prepare_lanes(&ws, 16, DesignKind::Sssa).unwrap();
        run_lane(&prep, 0, &mut cfu, dense_input(xs.clone()), 0, &mut sssa_counter).unwrap();
        assert!(
            sssa_counter.cycles() < base_counter.cycles(),
            "sssa {} !< baseline {}",
            sssa_counter.cycles(),
            base_counter.cycles()
        );
        // 2 loads vs 4 loads of weight words
        assert_eq!(sssa_counter.loaded_bytes(), base_counter.loaded_bytes() / 2);
    }

    #[test]
    fn ussa_stalls_scale_with_nonzeros() {
        let dense: Vec<i8> = vec![1; 16];
        let sparse: Vec<i8> = vec![1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1];
        let xs: Vec<i8> = vec![2; 16];
        let mut cycles = Vec::new();
        for ws in [&dense, &sparse] {
            let prep = prepare_lanes(ws, 16, DesignKind::Ussa).unwrap();
            let mut cfu = AnyCfu::new(DesignKind::Ussa, 0);
            let mut counter = CycleCounter::new(CostModel::vexriscv());
            run_lane(&prep, 0, &mut cfu, dense_input(xs.clone()), 0, &mut counter).unwrap();
            cycles.push(counter.cycles());
        }
        // dense: 4 cycles MAC per block; sparse: 1 cycle per block
        assert_eq!(cycles[0] - cycles[1], 4 * 3); // 3 stall cycles fewer per block
    }

    #[test]
    fn compiled_matches_interpreted_every_design() {
        // Random sparse lanes (including INT7-clamp candidates at ±64+):
        // the compiled schedule must reproduce the interpreted walk's
        // accumulator AND every counter total, per design and cost model.
        let mut rng = crate::util::Pcg32::new(0xC0DE);
        for trial in 0..24 {
            let blocks = 1 + rng.below(12) as usize;
            let lane_len = blocks * 4;
            let ws: Vec<i8> = (0..lane_len)
                .map(|_| {
                    if rng.bernoulli(0.6) {
                        0
                    } else {
                        rng.range_i32(-128, 127) as i8
                    }
                })
                .collect();
            let xs: Vec<i8> = (0..lane_len).map(|_| rng.range_i32(-128, 127) as i8).collect();
            let offset = rng.range_i32(0, 255);
            for design in DesignKind::ALL {
                for model in [CostModel::vexriscv(), CostModel::mac_only()] {
                    let prep = prepare_lanes(&ws, lane_len, design).unwrap();
                    let mut cfu = AnyCfu::new(design, offset);
                    let mut c_int = CycleCounter::new(model.clone());
                    let a_int =
                        run_lane(&prep, 0, &mut cfu, dense_input(xs.clone()), 7, &mut c_int)
                            .unwrap();
                    let mut c_cmp = CycleCounter::new(model.clone());
                    let a_cmp = run_lane_compiled(
                        prep.lane_schedule(0),
                        offset,
                        INPUT_COST_DENSE,
                        |j| pack4_le(&xs[j * 4..j * 4 + 4]),
                        7,
                        &mut c_cmp,
                    );
                    assert_eq!(a_int, a_cmp, "trial {trial} {design}: accumulator");
                    assert_counters_equal(&c_int, &c_cmp, &format!("trial {trial} {design}"));
                }
            }
        }
    }

    #[test]
    fn batched_matches_compiled_per_row_exactly() {
        // The loop-interchanged batched walk must land on the same
        // accumulators AND the same counter totals as running the
        // compiled path row by row, for every design, batch size
        // (including 1 and odd sizes) and cost model.
        let mut rng = crate::util::Pcg32::new(0xBA7C);
        for trial in 0..12 {
            let blocks = 1 + rng.below(8) as usize;
            let lane_len = blocks * 4;
            let ws: Vec<i8> = (0..lane_len)
                .map(|_| {
                    if rng.bernoulli(0.55) {
                        0
                    } else {
                        rng.range_i32(-64, 63) as i8
                    }
                })
                .collect();
            let offset = rng.range_i32(0, 255);
            // 67 crosses the SIMD kernels' 64-row chunk boundary.
            for &batch in &[1usize, 2, 5, 8, 67] {
                let rows: Vec<Vec<i8>> = (0..batch)
                    .map(|_| {
                        (0..lane_len).map(|_| rng.range_i32(-128, 127) as i8).collect()
                    })
                    .collect();
                for design in DesignKind::ALL {
                    for model in [CostModel::vexriscv(), CostModel::mac_only()] {
                        let prep = prepare_lanes(&ws, lane_len, design).unwrap();
                        let mut c_row = CycleCounter::new(model.clone());
                        let per_row: Vec<i32> = rows
                            .iter()
                            .map(|xs| {
                                run_lane_compiled(
                                    prep.lane_schedule(0),
                                    offset,
                                    INPUT_COST_GATHER,
                                    |j| pack4_le(&xs[j * 4..j * 4 + 4]),
                                    11,
                                    &mut c_row,
                                )
                            })
                            .collect();
                        // Every available host kernel must reproduce the
                        // per-row walk bit-exactly — accumulators AND
                        // counter totals.
                        for kernel in HostKernel::available_kernels() {
                            let mut c_bat = CycleCounter::new(model.clone());
                            let mut accs = vec![11i32; batch];
                            run_lane_batched(
                                prep.lane_schedule(0),
                                offset,
                                INPUT_COST_GATHER,
                                kernel,
                                |row, j| pack4_le(&rows[row][j * 4..j * 4 + 4]),
                                &mut accs,
                                &mut c_bat,
                            )
                            .unwrap();
                            assert_eq!(
                                accs, per_row,
                                "trial {trial} {design} b{batch} {kernel}: accs"
                            );
                            assert_counters_equal(
                                &c_row,
                                &c_bat,
                                &format!("trial {trial} {design} b{batch} {kernel}"),
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn arena_is_flat_and_csr_offsets_cover_every_lane() {
        // Multi-lane buffer: the arena must hold every lane's walk back
        // to back, with offsets slicing out exactly the per-lane
        // schedules (compared against single-lane preparations).
        let mut rng = crate::util::Pcg32::new(0xA2E7A);
        let lane_len = 16usize;
        let lanes = 6usize;
        let ws: Vec<i8> = (0..lanes * lane_len)
            .map(|_| {
                if rng.bernoulli(0.6) {
                    0
                } else {
                    rng.range_i32(-64, 63) as i8
                }
            })
            .collect();
        for design in DesignKind::ALL {
            let prep = prepare_lanes(&ws, lane_len, design).unwrap();
            assert_eq!(prep.arena.lanes(), lanes, "{design}");
            let mut total = 0usize;
            for l in 0..lanes {
                let a = prep.lane_schedule(l);
                // BSR schedules are not lane-independent (the occupancy
                // bitmap spans 8-lane tile groups), so the solo-lane
                // comparison only applies to the other designs.
                if design != DesignKind::Bsr {
                    let solo =
                        prepare_lanes(&ws[l * lane_len..(l + 1) * lane_len], lane_len, design)
                            .unwrap();
                    let b = solo.lane_schedule(0);
                    assert_eq!(a.visited, b.visited, "{design} lane {l}: visited");
                    assert_eq!(a.charge, b.charge, "{design} lane {l}: charge");
                }
                total += a.visited_blocks();
            }
            assert_eq!(prep.arena.total_visited(), total, "{design}: flat length");
        }
    }

    #[test]
    fn bsr_occupancy_is_shared_across_tile_group() {
        // 8 lanes, one tile column; a single non-zero in lane 5 makes
        // the whole 8×8 tile occupied — every lane of the group walks
        // its words, lanes of an unoccupied group walk nothing.
        let lane_len = 8usize;
        let mut ws = vec![0i8; 16 * lane_len];
        ws[5 * lane_len + 2] = 9;
        let prep = prepare_lanes(&ws, lane_len, DesignKind::Bsr).unwrap();
        let occ = prep.bsr.as_ref().unwrap();
        assert_eq!((occ.groups, occ.cols), (2, 1));
        assert!(occ.is_occupied(0, 0));
        assert!(!occ.is_occupied(1, 0));
        for l in 0..8 {
            assert_eq!(prep.lane_schedule(l).visited_blocks(), 2, "lane {l}");
        }
        for l in 8..16 {
            assert_eq!(prep.lane_schedule(l).visited_blocks(), 0, "lane {l}");
        }
    }

    #[test]
    fn compiled_all_zero_lane_every_design() {
        let ws = vec![0i8; 16];
        let xs: Vec<i8> = (0..16).map(|i| (i * 5 - 30) as i8).collect();
        for design in DesignKind::ALL {
            let prep = prepare_lanes(&ws, 16, design).unwrap();
            let mut cfu = AnyCfu::new(design, 128);
            let mut c_int = CycleCounter::new(CostModel::vexriscv());
            let a_int =
                run_lane(&prep, 0, &mut cfu, dense_input(xs.clone()), 3, &mut c_int).unwrap();
            let mut c_cmp = CycleCounter::new(CostModel::vexriscv());
            let a_cmp = run_lane_compiled(
                prep.lane_schedule(0),
                128,
                INPUT_COST_DENSE,
                |j| pack4_le(&xs[j * 4..j * 4 + 4]),
                3,
                &mut c_cmp,
            );
            assert_eq!(a_int, 3, "{design}: all-zero lane must leave acc unchanged");
            assert_eq!(a_int, a_cmp, "{design}");
            assert_counters_equal(&c_int, &c_cmp, &format!("all-zero {design}"));
            // The batched walk agrees too, at any batch size.
            let mut c_bat = CycleCounter::new(CostModel::vexriscv());
            let mut accs = vec![3i32; 3];
            run_lane_batched(
                prep.lane_schedule(0),
                128,
                INPUT_COST_DENSE,
                HostKernel::Scalar,
                |_, j| pack4_le(&xs[j * 4..j * 4 + 4]),
                &mut accs,
                &mut c_bat,
            )
            .unwrap();
            assert_eq!(accs, vec![3; 3], "{design}: batched all-zero accs");
            // SSSA/CSA visit only the leading zero block of the lane;
            // the format designs skip an all-zero lane entirely; the
            // baselines/USSA visit every block.
            let expect_visited = match design {
                DesignKind::Sssa | DesignKind::Csa => 1,
                DesignKind::NmSsa | DesignKind::Bsr | DesignKind::Bbs => 0,
                _ => 4,
            };
            assert_eq!(prep.lane_schedule(0).visited_blocks(), expect_visited, "{design}");
        }
    }

    #[test]
    fn schedule_walk_matches_software_oracle() {
        // The compiled walk (driven by packed skip bits) must equal the
        // software-side visited_indices oracle over the clamped weights.
        let mut rng = crate::util::Pcg32::new(0x5C4ED);
        for _ in 0..16 {
            let blocks = 2 + rng.below(20) as usize;
            let ws: Vec<i8> = (0..blocks * 4)
                .map(|_| {
                    if rng.bernoulli(0.7) {
                        0
                    } else {
                        rng.range_i32(-64, 63) as i8
                    }
                })
                .collect();
            for design in [DesignKind::Sssa, DesignKind::Csa] {
                let prep = prepare_lanes(&ws, ws.len(), design).unwrap();
                let expect = crate::encoding::lookahead::visited_indices(&prep.effective_weights);
                let s = prep.lane_schedule(0);
                let got: Vec<usize> = s.visited.iter().map(|&(j, _)| j as usize).collect();
                assert_eq!(got, expect, "{design}");
            }
        }
    }

    #[test]
    fn schedule_charge_counts_are_exact() {
        // Hand-check one lane: [nz][z][z][nz] under CSA.
        let ws: Vec<i8> = [[1i8, 0, 2, 0], [0; 4], [0; 4], [0, 3, 0, 0]].concat();
        let prep = prepare_lanes(&ws, 16, DesignKind::Csa).unwrap();
        let s = prep.lane_schedule(0);
        assert_eq!(s.visited_blocks(), 2); // block 0 (skip 2) → block 3
        let c = s.charge;
        assert_eq!(c.alu, 2 * 3);
        assert_eq!(c.loads, 2);
        assert_eq!(c.branches_taken, 1);
        assert_eq!(c.branches_not_taken, 1);
        assert_eq!(c.cfu_issues, 2 * 2); // vcmac + inc_indvar per visited block
        assert_eq!(c.cfu_stalls, 1); // block 0 has 2 nz (1 stall), block 3 has 1 nz (0)
    }

    #[test]
    fn prepare_rejects_bad_shapes() {
        assert!(prepare_lanes(&[0i8; 8], 6, DesignKind::BaselineSimd).is_err());
        assert!(prepare_lanes(&[0i8; 10], 4, DesignKind::BaselineSimd).is_err());
        assert!(prepare_lanes(&[], 4, DesignKind::BaselineSimd).is_err());
    }

    #[test]
    fn int8_weights_clamped_for_encoded_designs() {
        let ws: Vec<i8> = vec![127, -128, 0, 0, 1, 2, 3, 4];
        let prep = prepare_lanes(&ws, 8, DesignKind::Csa).unwrap();
        assert_eq!(prep.clamped, 2);
        assert_eq!(prep.effective_weights[0], 63);
        assert_eq!(prep.effective_weights[1], -64);
        // decoded weights in the packed words must be the clamped values
        let w0 = unpack4_i8(prep.words[0]);
        assert_eq!(w0[0] >> 1, 63);
        assert_eq!(w0[1] >> 1, -64);
    }

    #[test]
    fn baseline_keeps_full_int8() {
        let ws: Vec<i8> = vec![127, -128, 0, 0];
        let prep = prepare_lanes(&ws, 4, DesignKind::BaselineSimd).unwrap();
        assert_eq!(prep.clamped, 0);
        assert_eq!(prep.effective_weights, ws);
    }
}
