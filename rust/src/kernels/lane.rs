//! Lane preparation and the accelerated inner loop shared by conv and fc.
//!
//! A *lane* is one contiguous run of weights walked by the innermost loop
//! (input channels for normal conv, padded spatial taps for depthwise,
//! input features for fc). Weights are pre-packed into the 32-bit words
//! the CFU consumes — for SSSA/CSA after lookahead encoding (the paper's
//! build-time pre-processing of Algorithm 1).

use crate::cfu::AnyCfu;
use crate::cpu::CycleCounter;
use crate::encoding::int7::clamp_slice_int7;
use crate::encoding::lookahead::encode_lanes;
use crate::encoding::pack::pack4_i8;
use crate::error::{Error, Result};
use crate::isa::{CfuOpcode, DesignKind};

/// Weights of one layer, packed per-lane into CFU operand words.
#[derive(Debug, Clone)]
pub struct PreparedLanes {
    /// Packed 32-bit weight words, lane-major.
    pub words: Vec<u32>,
    /// Blocks (words) per lane.
    pub blocks_per_lane: usize,
    /// Number of lanes.
    pub lanes: usize,
    /// Design the words were packed for.
    pub design: DesignKind,
    /// Weights clamped from INT8 to INT7 during preparation (SSSA/CSA
    /// only — the paper's dynamic-range restriction).
    pub clamped: usize,
    /// Weights actually used for compute (post-clamp) — lets callers
    /// verify against a reference op run with identical weights.
    pub effective_weights: Vec<i8>,
}

/// Pack a weight buffer of `lanes × lane_len` into CFU words for a design.
///
/// `lane_len` must be a positive multiple of 4. For SSSA/CSA the weights
/// are clamped to INT7 and lookahead-encoded (Algorithms 1 & 2).
pub fn prepare_lanes(weights: &[i8], lane_len: usize, design: DesignKind) -> Result<PreparedLanes> {
    if lane_len == 0 || lane_len % 4 != 0 {
        return Err(Error::Encoding(format!("lane_len {lane_len} not a positive multiple of 4")));
    }
    if weights.is_empty() || weights.len() % lane_len != 0 {
        return Err(Error::Encoding(format!(
            "weight buffer {} not divisible by lane_len {lane_len}",
            weights.len()
        )));
    }
    let lanes = weights.len() / lane_len;
    let blocks_per_lane = lane_len / 4;
    // Prepare-path allocation: encode_lanes already copies, so the
    // clamped buffer itself becomes `effective_weights` (no third copy —
    // this runs once per cached prepared model, but large models encode
    // hundreds of layers).
    let (buf, clamped, effective_weights) = if design.uses_lookahead_encoding() {
        let mut ws = weights.to_vec();
        let clamped = clamp_slice_int7(&mut ws);
        let enc = encode_lanes(&ws, lane_len)?;
        (enc.encoded, clamped, ws)
    } else {
        (weights.to_vec(), 0, weights.to_vec())
    };
    let words = buf
        .chunks(4)
        .map(|b| pack4_i8(&[b[0], b[1], b[2], b[3]]))
        .collect();
    Ok(PreparedLanes {
        words,
        blocks_per_lane,
        lanes,
        design,
        clamped,
        effective_weights,
    })
}

impl PreparedLanes {
    /// Word slice of one lane.
    #[inline]
    pub fn lane_words(&self, lane: usize) -> &[u32] {
        let b = self.blocks_per_lane;
        &self.words[lane * b..(lane + 1) * b]
    }
}

/// Execute the inner loop over one lane, accumulating into `acc`.
///
/// `input_word(j)` supplies the packed input word for block `j` and the
/// count of loads/ALU ops spent materializing it (1 load for contiguous
/// NHWC channels; 4 byte-loads + 3 packs for depthwise gathers).
///
/// Returns the updated accumulator. Charges every instruction of the
/// loop shapes documented in [`crate::kernels`].
#[inline]
pub fn run_lane<F>(
    design: DesignKind,
    cfu: &mut AnyCfu,
    lane_words: &[u32],
    mut input_word: F,
    acc: i32,
    counter: &mut CycleCounter,
) -> Result<i32>
where
    F: FnMut(usize) -> (u32, u64, u64),
{
    let nblocks = lane_words.len();
    let mut acc = acc;
    // Per-block instruction charges are accumulated locally and flushed
    // to the counter once per lane (charge_bulk) — ~2.5× faster hot
    // loop with identical totals (EXPERIMENTS.md §Perf).
    let mut alu = 0u64;
    let mut loads = 0u64;
    let mut taken = 0u64;
    let mut not_taken = 0u64;
    let mut cfu_issues = 0u64;
    let mut cfu_stalls = 0u64;
    match design {
        DesignKind::BaselineSimd | DesignKind::BaselineSequential | DesignKind::Ussa => {
            let mac_op = match design {
                DesignKind::BaselineSimd => CfuOpcode::CfuSimdMac,
                DesignKind::BaselineSequential => CfuOpcode::CfuSeqMac,
                _ => CfuOpcode::UssaVcMac,
            };
            for j in 0..nblocks {
                // add a_w; lw w; add a_x (+gather); lw x; add acc; addi i
                let (x_word, x_loads, x_alus) = input_word(j);
                alu += 4 + x_alus;
                loads += 1 + x_loads;
                // cfu mac
                let resp = cfu.execute(mac_op, lane_words[j], x_word)?;
                cfu_issues += 1;
                cfu_stalls += (resp.cycles as u64).saturating_sub(1);
                acc = acc.wrapping_add(resp.rd as i32);
                // loop branch (taken except on exit)
                if j + 1 != nblocks {
                    taken += 1;
                } else {
                    not_taken += 1;
                }
            }
        }
        DesignKind::Sssa | DesignKind::Csa => {
            let (mac_op, inc_op) = if design == DesignKind::Sssa {
                (CfuOpcode::SssaMac, CfuOpcode::SssaIncIndvar)
            } else {
                (CfuOpcode::CsaVcMac, CfuOpcode::CsaIncIndvar)
            };
            let mut j = 0usize;
            while j < nblocks {
                // add a_w; lw w; add a_x (+gather); lw x; add acc
                let (x_word, x_loads, x_alus) = input_word(j);
                alu += 3 + x_alus;
                loads += 1 + x_loads;
                // cfu mac
                let resp = cfu.execute(mac_op, lane_words[j], x_word)?;
                cfu_issues += 1;
                cfu_stalls += (resp.cycles as u64).saturating_sub(1);
                acc = acc.wrapping_add(resp.rd as i32);
                // cfu inc_indvar (replaces the addi): i_bytes = 4*j
                let i_bytes = (4 * j) as u32;
                let inc = cfu.execute(inc_op, lane_words[j], i_bytes)?;
                cfu_issues += 1;
                cfu_stalls += (inc.cycles as u64).saturating_sub(1);
                let next = (inc.rd / 4) as usize;
                debug_assert!(next > j, "inc_indvar must advance");
                // loop branch
                if next < nblocks {
                    taken += 1;
                } else {
                    not_taken += 1;
                }
                j = next;
            }
        }
    }
    counter.charge_bulk(alu, loads, 0, taken, not_taken, cfu_issues, cfu_stalls);
    Ok(acc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cfu::AnyCfu;
    use crate::cpu::CostModel;
    use crate::encoding::pack::unpack4_i8;

    /// Dense input word supplier: contiguous channels, 1 load, 0 extra alu.
    fn dense_input(xs: Vec<i8>) -> impl FnMut(usize) -> (u32, u64, u64) {
        move |j| {
            let b = &xs[j * 4..j * 4 + 4];
            (pack4_i8(&[b[0], b[1], b[2], b[3]]), 1, 0)
        }
    }

    fn dot(ws: &[i8], xs: &[i8], off: i32) -> i32 {
        ws.iter().zip(xs).map(|(&w, &x)| w as i32 * (x as i32 + off)).sum()
    }

    #[test]
    fn all_designs_same_acc_int7_weights() {
        let ws: Vec<i8> = vec![1, -2, 0, 4, 0, 0, 0, 0, 5, 0, -6, 0, 7, 8, 9, -10];
        let xs: Vec<i8> = (0..16).map(|i| (i * 3 - 20) as i8).collect();
        let expect = dot(&ws, &xs, 128);
        for design in DesignKind::ALL {
            let prep = prepare_lanes(&ws, 16, design).unwrap();
            let mut cfu = AnyCfu::new(design, 128);
            let mut counter = CycleCounter::new(CostModel::vexriscv());
            let acc = run_lane(
                design,
                &mut cfu,
                prep.lane_words(0),
                dense_input(xs.clone()),
                0,
                &mut counter,
            )
            .unwrap();
            assert_eq!(acc, expect, "{design}");
            assert!(counter.cycles() > 0);
        }
    }

    #[test]
    fn sssa_visits_fewer_blocks() {
        // lane: [nz][z][z][nz] → SSSA visits 2 blocks, baseline 4.
        let ws: Vec<i8> = [[1i8, 2, 3, 4], [0; 4], [0; 4], [5, 6, 7, 8]].concat();
        let xs: Vec<i8> = vec![1; 16];
        let mut base_counter = CycleCounter::new(CostModel::vexriscv());
        let mut cfu = AnyCfu::new(DesignKind::BaselineSimd, 0);
        let prep = prepare_lanes(&ws, 16, DesignKind::BaselineSimd).unwrap();
        run_lane(
            DesignKind::BaselineSimd,
            &mut cfu,
            prep.lane_words(0),
            dense_input(xs.clone()),
            0,
            &mut base_counter,
        )
        .unwrap();

        let mut sssa_counter = CycleCounter::new(CostModel::vexriscv());
        let mut cfu = AnyCfu::new(DesignKind::Sssa, 0);
        let prep = prepare_lanes(&ws, 16, DesignKind::Sssa).unwrap();
        run_lane(
            DesignKind::Sssa,
            &mut cfu,
            prep.lane_words(0),
            dense_input(xs.clone()),
            0,
            &mut sssa_counter,
        )
        .unwrap();
        assert!(
            sssa_counter.cycles() < base_counter.cycles(),
            "sssa {} !< baseline {}",
            sssa_counter.cycles(),
            base_counter.cycles()
        );
        // 2 loads vs 4 loads of weight words
        assert_eq!(sssa_counter.loaded_bytes(), base_counter.loaded_bytes() / 2);
    }

    #[test]
    fn ussa_stalls_scale_with_nonzeros() {
        let dense: Vec<i8> = vec![1; 16];
        let sparse: Vec<i8> = vec![1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1];
        let xs: Vec<i8> = vec![2; 16];
        let mut cycles = Vec::new();
        for ws in [&dense, &sparse] {
            let prep = prepare_lanes(ws, 16, DesignKind::Ussa).unwrap();
            let mut cfu = AnyCfu::new(DesignKind::Ussa, 0);
            let mut counter = CycleCounter::new(CostModel::vexriscv());
            run_lane(
                DesignKind::Ussa,
                &mut cfu,
                prep.lane_words(0),
                dense_input(xs.clone()),
                0,
                &mut counter,
            )
            .unwrap();
            cycles.push(counter.cycles());
        }
        // dense: 4 cycles MAC per block; sparse: 1 cycle per block
        assert_eq!(cycles[0] - cycles[1], 4 * 3); // 3 stall cycles fewer per block
    }

    #[test]
    fn prepare_rejects_bad_shapes() {
        assert!(prepare_lanes(&[0i8; 8], 6, DesignKind::BaselineSimd).is_err());
        assert!(prepare_lanes(&[0i8; 10], 4, DesignKind::BaselineSimd).is_err());
        assert!(prepare_lanes(&[], 4, DesignKind::BaselineSimd).is_err());
    }

    #[test]
    fn int8_weights_clamped_for_encoded_designs() {
        let ws: Vec<i8> = vec![127, -128, 0, 0, 1, 2, 3, 4];
        let prep = prepare_lanes(&ws, 8, DesignKind::Csa).unwrap();
        assert_eq!(prep.clamped, 2);
        assert_eq!(prep.effective_weights[0], 63);
        assert_eq!(prep.effective_weights[1], -64);
        // decoded weights in the packed words must be the clamped values
        let w0 = unpack4_i8(prep.words[0]);
        assert_eq!(w0[0] >> 1, 63);
        assert_eq!(w0[1] >> 1, -64);
    }

    #[test]
    fn baseline_keeps_full_int8() {
        let ws: Vec<i8> = vec![127, -128, 0, 0];
        let prep = prepare_lanes(&ws, 4, DesignKind::BaselineSimd).unwrap();
        assert_eq!(prep.clamped, 0);
        assert_eq!(prep.effective_weights, ws);
    }
}
