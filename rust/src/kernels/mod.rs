//! CFU-specialized compute kernels (Listings 1, 2, 3 of the paper).
//!
//! Each kernel runs the *actual* integer arithmetic through the CFU
//! functional models while charging every instruction of the inner-loop
//! code shape to a [`crate::cpu::CycleCounter`]. Outputs are therefore
//! bit-exact against [`crate::nn`]'s golden ops (asserted in tests), and
//! cycle counts are comparable across designs.
//!
//! ## Modelled instruction sequences (per 4-weight block)
//!
//! Baseline / USSA (`for` loop, Listing 1):
//! `add a_w` · `lw w` · `add a_x` · `lw x` · `cfu mac` · `add acc` ·
//! `addi i` · `blt` — 4 ALU, 2 loads, 1 CFU, 1 branch.
//!
//! SSSA / CSA (`while` loop, Listings 2/3):
//! `add a_w` · `lw w` · `add a_x` · `lw x` · `cfu mac` · `add acc` ·
//! `cfu inc_indvar` · `bltu` — 3 ALU, 2 loads, 2 CFU, 1 branch.
//!
//! The `inc_indvar` custom instruction *replaces* the `addi`, so a
//! visited block costs the same CPU overhead in both shapes; the savings
//! come from visiting fewer blocks (SSSA) and/or fewer MAC stall cycles
//! (USSA/CSA).
//!
//! ## Execution modes
//!
//! Both loop shapes above are pure functions of the packed weights, so
//! the kernels run them two ways:
//!
//! - [`ExecMode::Compiled`] (default) — [`lane::run_lane_compiled`] over
//!   the [`lane::LaneSchedule`]s materialized at prepare time: a plain
//!   dot-product loop plus one bulk counter flush per lane;
//! - [`ExecMode::Interpreted`] — [`lane::run_lane`] dispatching every
//!   MAC/`inc_indvar` through the CFU functional models, kept as the
//!   differential oracle.
//!
//! Outputs and cycle totals are bit-identical between the modes
//! (asserted across designs × models by the differential tier).

pub mod conv;
pub mod fc;
pub mod lane;

pub use conv::PreparedConv;
pub use fc::PreparedFc;
pub use lane::{prepare_lanes, run_lane, run_lane_compiled, LaneSchedule, PreparedLanes};

use crate::cpu::CycleCounter;
use crate::tensor::QTensor;

/// How the kernels execute their MAC lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Table-driven execution over prepare-time [`LaneSchedule`]s (the
    /// default host path).
    #[default]
    Compiled,
    /// Per-instruction CFU dispatch — the reference oracle the compiled
    /// path is differentially tested against.
    Interpreted,
}

impl ExecMode {
    /// Short name for logs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Compiled => "compiled",
            ExecMode::Interpreted => "interpreted",
        }
    }
}

/// Output of one kernel invocation.
#[derive(Debug, Clone)]
pub struct KernelRun {
    /// Computed activation tensor (bit-exact vs the reference op).
    pub output: QTensor,
    /// Cycle/instruction accounting for the whole layer.
    pub counter: CycleCounter,
}
