//! CFU-specialized compute kernels (Listings 1, 2, 3 of the paper).
//!
//! Each kernel runs the *actual* integer arithmetic through the CFU
//! functional models while charging every instruction of the inner-loop
//! code shape to a [`crate::cpu::CycleCounter`]. Outputs are therefore
//! bit-exact against [`crate::nn`]'s golden ops (asserted in tests), and
//! cycle counts are comparable across designs.
//!
//! ## Modelled instruction sequences (per 4-weight block)
//!
//! Baseline / USSA (`for` loop, Listing 1):
//! `add a_w` · `lw w` · `add a_x` · `lw x` · `cfu mac` · `add acc` ·
//! `addi i` · `blt` — 4 ALU, 2 loads, 1 CFU, 1 branch.
//!
//! SSSA / CSA (`while` loop, Listings 2/3):
//! `add a_w` · `lw w` · `add a_x` · `lw x` · `cfu mac` · `add acc` ·
//! `cfu inc_indvar` · `bltu` — 3 ALU, 2 loads, 2 CFU, 1 branch.
//!
//! The `inc_indvar` custom instruction *replaces* the `addi`, so a
//! visited block costs the same CPU overhead in both shapes; the savings
//! come from visiting fewer blocks (SSSA) and/or fewer MAC stall cycles
//! (USSA/CSA).
//!
//! ## Execution modes
//!
//! The loop shapes above are pure functions of the packed weights, so
//! the kernels run them three ways over the same prepare-time
//! [`lane::ScheduleArena`]:
//!
//! - [`ExecMode::Batched`] (default) — [`lane::run_lane_batched`]
//!   interchanges the loops: each lane's arena slice is walked once and
//!   every input row of the batch is streamed against each visited
//!   block, amortizing schedule decode and weight reads across the
//!   batch. With intra-layer tiling enabled (see
//!   [`crate::simulator::SimEngine`]) the lane dimension additionally
//!   splits across worker threads, one [`crate::cpu::CycleCounter`] per
//!   tile, merged deterministically in tile order.
//! - [`ExecMode::Compiled`] — [`lane::run_lane_compiled`] re-walks each
//!   lane's schedule per input row (the pre-interchange host path, kept
//!   as a bench/differential comparison point);
//! - [`ExecMode::Interpreted`] — [`lane::run_lane`] dispatching every
//!   MAC/`inc_indvar` through the CFU functional models, kept as the
//!   differential oracle.
//!
//! Outputs and cycle totals are bit-identical between all modes
//! (asserted across designs × models × batch sizes × tile counts by the
//! differential tier): the cycle model charges per-lane
//! [`crate::cpu::BulkCharge`]s whose conversion to counter totals is
//! linear, so loop interchange and lane tiling cannot change any
//! simulated metric.

pub mod conv;
pub mod fc;
pub mod lane;

pub use conv::PreparedConv;
pub use fc::PreparedFc;
pub use lane::{
    prepare_lanes, run_lane, run_lane_batched, run_lane_compiled, LaneScheduleRef, PreparedLanes,
    ScheduleArena,
};

use crate::cpu::CycleCounter;
use crate::tensor::QTensor;

/// How the kernels execute their MAC lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Batch-amortized execution over the prepare-time schedule arena:
    /// each lane's visited slice is walked once per layer with every
    /// input row streamed against it (the default host path).
    #[default]
    Batched,
    /// Per-lane, row-major table-driven execution over the same arena —
    /// the pre-interchange compiled path, kept as a comparison point.
    Compiled,
    /// Per-instruction CFU dispatch — the reference oracle the compiled
    /// and batched paths are differentially tested against.
    Interpreted,
}

impl ExecMode {
    /// Short name for logs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Batched => "batched",
            ExecMode::Compiled => "compiled",
            ExecMode::Interpreted => "interpreted",
        }
    }
}

/// Output of one kernel invocation.
#[derive(Debug, Clone)]
pub struct KernelRun {
    /// Computed activation tensor (bit-exact vs the reference op).
    pub output: QTensor,
    /// Cycle/instruction accounting for the whole layer.
    pub counter: CycleCounter,
}

/// Env var overriding how [`HostKernel::Auto`] resolves
/// (`scalar`/`swar`/`sse2`/`neon`). CI forces both code paths through
/// it; explicit kernel choices ignore it, so kernel-sweep tests stay
/// deterministic under a forced environment.
pub const HOST_KERNEL_ENV: &str = "SPARSE_RISCV_HOST_KERNEL";

/// Host-side arithmetic kernel for the batched lane walk.
///
/// Selects how [`lane::run_lane_batched`] multiplies each visited packed
/// weight word against the batch's packed input rows. Purely a *host
/// throughput* choice: simulated cycles come from prepare-time
/// [`crate::cpu::BulkCharge`]s, so every variant is cycle-invariant and
/// bit-identical in outputs (pinned by the differential tier).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum HostKernel {
    /// Resolve at run time: the [`HOST_KERNEL_ENV`] override when set to
    /// an available kernel, else the best available SIMD/SWAR kernel.
    #[default]
    Auto,
    /// The per-row scalar loop — the host-side oracle the other kernels
    /// are differentially pinned against.
    Scalar,
    /// Portable u64-SWAR kernel (two 32-bit-field multiplies per row).
    Swar,
    /// SSE2 `pmaddwd` kernel, two rows per multiply (x86-64 only).
    Sse2,
    /// NEON `smull` kernel, two rows per multiply (aarch64 only).
    Neon,
}

impl HostKernel {
    /// Every selectable kernel, including ones this host may not support.
    pub const ALL: [HostKernel; 5] = [
        HostKernel::Auto,
        HostKernel::Scalar,
        HostKernel::Swar,
        HostKernel::Sse2,
        HostKernel::Neon,
    ];

    /// Parse a CLI/env name (`auto`/`scalar`/`swar`/`sse2`/`neon`).
    pub fn parse(s: &str) -> Option<HostKernel> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(HostKernel::Auto),
            "scalar" => Some(HostKernel::Scalar),
            "swar" => Some(HostKernel::Swar),
            "sse2" => Some(HostKernel::Sse2),
            "neon" => Some(HostKernel::Neon),
            _ => None,
        }
    }

    /// Short name for flags, labels and reports.
    pub fn name(self) -> &'static str {
        match self {
            HostKernel::Auto => "auto",
            HostKernel::Scalar => "scalar",
            HostKernel::Swar => "swar",
            HostKernel::Sse2 => "sse2",
            HostKernel::Neon => "neon",
        }
    }

    /// Whether this host can run the kernel. `Auto`, `Scalar` and `Swar`
    /// always can; the `std::arch` variants answer per target, with SSE2
    /// re-confirmed by runtime feature detection.
    pub fn available(self) -> bool {
        match self {
            HostKernel::Auto | HostKernel::Scalar | HostKernel::Swar => true,
            HostKernel::Sse2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    std::arch::is_x86_feature_detected!("sse2")
                }
                #[cfg(not(target_arch = "x86_64"))]
                {
                    false
                }
            }
            // NEON (ASIMD) is part of the aarch64 baseline ISA.
            HostKernel::Neon => cfg!(target_arch = "aarch64"),
        }
    }

    /// Total resolution to a concrete, available kernel: `Auto` takes
    /// the cached [`HOST_KERNEL_ENV`] override when it names an
    /// available kernel, else the best available (SIMD over SWAR); an
    /// explicitly requested kernel this host cannot run degrades to the
    /// portable SWAR path (the CLI rejects that case up front with a
    /// clear error instead).
    pub fn resolve(self) -> HostKernel {
        match self {
            HostKernel::Auto => env_override().unwrap_or_else(best_available),
            k if k.available() => k,
            _ => HostKernel::Swar,
        }
    }

    /// The concrete kernels this host can run (for differential sweeps).
    pub fn available_kernels() -> Vec<HostKernel> {
        [HostKernel::Scalar, HostKernel::Swar, HostKernel::Sse2, HostKernel::Neon]
            .into_iter()
            .filter(|k| k.available())
            .collect()
    }

    /// The multi-row dot kernel to run per visited block (`Auto` and
    /// unavailable variants fall back to the portable SWAR kernel; the
    /// scalar path is dispatched separately in `run_lane_batched`).
    pub(crate) fn rows_fn(self) -> fn(u32, i32, &[u32], &mut [i32]) {
        match self {
            HostKernel::Scalar => crate::cfu::hostdot::dot4_rows_scalar,
            HostKernel::Swar => crate::cfu::hostdot::dot4_rows_swar,
            #[cfg(target_arch = "x86_64")]
            HostKernel::Sse2 => crate::cfu::hostdot::dot4_rows_sse2,
            #[cfg(target_arch = "aarch64")]
            HostKernel::Neon => crate::cfu::hostdot::dot4_rows_neon,
            _ => crate::cfu::hostdot::dot4_rows_swar,
        }
    }
}

impl std::fmt::Display for HostKernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Cached [`HOST_KERNEL_ENV`] parse (checked once per process). `auto`
/// and unavailable kernels are ignored rather than erroring: the
/// override is a CI forcing knob, not a correctness input.
fn env_override() -> Option<HostKernel> {
    static OVERRIDE: std::sync::OnceLock<Option<HostKernel>> = std::sync::OnceLock::new();
    *OVERRIDE.get_or_init(|| {
        std::env::var(HOST_KERNEL_ENV)
            .ok()
            .and_then(|v| HostKernel::parse(&v))
            .filter(|k| *k != HostKernel::Auto && k.available())
    })
}

fn best_available() -> HostKernel {
    if HostKernel::Sse2.available() {
        HostKernel::Sse2
    } else if HostKernel::Neon.available() {
        HostKernel::Neon
    } else {
        HostKernel::Swar
    }
}

/// Split `n` lanes into at most `tiles` contiguous near-equal ranges
/// (the intra-layer tiling grid). The split depends only on `(n,
/// tiles)`, so a given tile count always produces the same deterministic
/// partition. Every returned range is non-empty (`n = 0` yields no
/// tiles), so empty tiles are never dispatched as scoped jobs.
pub fn tile_ranges(n: usize, tiles: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let tiles = tiles.clamp(1, n);
    let base = n / tiles;
    let extra = n % tiles;
    let mut out = Vec::with_capacity(tiles);
    let mut start = 0usize;
    for t in 0..tiles {
        let len = base + usize::from(t < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(out.last().map_or(0, |r| r.end), n, "tiles must cover all lanes");
    out
}

/// Split `weights.len()` lanes into at most `tiles` contiguous ranges of
/// near-equal *cumulative weight* (here: per-lane visited-block counts
/// from the [`lane::ScheduleArena`]). A count-based split serializes a
/// layer whose dense lanes cluster in one tile; cutting at cumulative
/// weight quantiles keeps tile work balanced under skewed sparsity.
/// Deterministic in `(weights, tiles)`; every range is non-empty and the
/// ranges cover `0..weights.len()` exactly. All-zero weights (or a
/// single tile) fall back to the count split.
pub fn tile_ranges_weighted(weights: &[u64], tiles: usize) -> Vec<std::ops::Range<usize>> {
    let n = weights.len();
    let total: u64 = weights.iter().sum();
    let tiles = tiles.clamp(1, n.max(1));
    if n == 0 || total == 0 || tiles == 1 {
        return tile_ranges(n, tiles);
    }
    // prefix[i] = total weight of lanes [0, i).
    let mut prefix = Vec::with_capacity(n + 1);
    let mut acc = 0u64;
    prefix.push(0u64);
    for &w in weights {
        acc += w;
        prefix.push(acc);
    }
    let mut out = Vec::with_capacity(tiles);
    let mut start = 0usize;
    for k in 1..=tiles {
        let end = if k == tiles {
            n
        } else {
            // Cut where the cumulative weight crosses the k-th quantile,
            // clamped so this tile takes at least one lane and leaves at
            // least one for each remaining tile (`start` stays strictly
            // below `n - (tiles - k)` by induction, so the clamp bounds
            // are always ordered).
            let target = (total as u128 * k as u128 / tiles as u128) as u64;
            let cut = prefix.partition_point(|&p| p < target);
            cut.clamp(start + 1, n - (tiles - k))
        };
        out.push(start..end);
        start = end;
    }
    debug_assert_eq!(out.last().map_or(0, |r| r.end), n, "tiles must cover all lanes");
    out
}

#[cfg(test)]
mod tests {
    use super::{tile_ranges, tile_ranges_weighted, HostKernel};

    #[test]
    fn tile_ranges_cover_exactly_once() {
        for n in [1usize, 2, 7, 16, 33] {
            for tiles in [1usize, 2, 3, 8, 64] {
                let ranges = tile_ranges(n, tiles);
                assert!(ranges.len() <= tiles.max(1));
                assert_eq!(ranges.first().unwrap().start, 0);
                assert_eq!(ranges.last().unwrap().end, n);
                for pair in ranges.windows(2) {
                    assert_eq!(pair[0].end, pair[1].start, "contiguous n={n} tiles={tiles}");
                    assert!(!pair[0].is_empty());
                }
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
            }
        }
    }

    #[test]
    fn tile_ranges_never_dispatch_empty_tiles() {
        // More tiles than lanes: one tile per lane, never an empty range.
        for n in [1usize, 2, 5] {
            for tiles in [n + 1, 4 * n, 64] {
                let ranges = tile_ranges(n, tiles);
                assert_eq!(ranges.len(), n, "n={n} tiles={tiles}");
                assert!(ranges.iter().all(|r| r.len() == 1));
            }
        }
        // Zero lanes: no jobs at all rather than a dispatched 0..0 tile.
        assert!(tile_ranges(0, 4).is_empty());
    }

    fn assert_partition(ranges: &[std::ops::Range<usize>], n: usize, tag: &str) {
        assert_eq!(ranges.first().unwrap().start, 0, "{tag}");
        assert_eq!(ranges.last().unwrap().end, n, "{tag}");
        for pair in ranges.windows(2) {
            assert_eq!(pair[0].end, pair[1].start, "{tag}: contiguous");
        }
        assert!(ranges.iter().all(|r| !r.is_empty()), "{tag}: non-empty");
    }

    #[test]
    fn weighted_tiles_balance_skewed_weights() {
        // One dense lane dominating a count split: the weighted split
        // must isolate it instead of pairing it with half the layer.
        let weights = [1000u64, 1, 1, 1, 1, 1, 1, 1];
        let ranges = tile_ranges_weighted(&weights, 2);
        assert_partition(&ranges, weights.len(), "skewed");
        assert_eq!(ranges[0], 0..1, "dense lane gets its own tile");
        assert_eq!(ranges[1], 1..8);

        // Uniform weights degrade to the near-equal count split.
        let uniform = [5u64; 12];
        assert_eq!(tile_ranges_weighted(&uniform, 3), tile_ranges(12, 3));

        // All-zero weights (a fully-pruned layer) fall back cleanly.
        assert_eq!(tile_ranges_weighted(&[0u64; 7], 3), tile_ranges(7, 3));
    }

    #[test]
    fn weighted_tiles_cover_exactly_once_on_random_weights() {
        let mut rng = crate::util::Pcg32::new(0x71E5);
        for n in [1usize, 2, 3, 9, 40] {
            for tiles in [1usize, 2, 3, 8, 64] {
                let weights: Vec<u64> =
                    (0..n).map(|_| rng.below(50) as u64 * u64::from(rng.bernoulli(0.6))).collect();
                let ranges = tile_ranges_weighted(&weights, tiles);
                assert!(ranges.len() <= tiles.min(n).max(1));
                assert_partition(&ranges, n, &format!("n={n} tiles={tiles}"));
            }
        }
    }

    #[test]
    fn host_kernel_parse_name_roundtrip() {
        for k in HostKernel::ALL {
            assert_eq!(HostKernel::parse(k.name()), Some(k));
            assert_eq!(k.to_string(), k.name());
        }
        assert_eq!(HostKernel::parse("SWAR"), Some(HostKernel::Swar));
        assert_eq!(HostKernel::parse("avx512"), None);
        assert_eq!(HostKernel::default(), HostKernel::Auto);
    }

    #[test]
    fn host_kernel_resolution_is_total_and_available() {
        for k in HostKernel::ALL {
            let r = k.resolve();
            assert_ne!(r, HostKernel::Auto, "{k} must resolve to a concrete kernel");
            assert!(r.available(), "{k} resolved to unavailable {r}");
            // Resolution is idempotent.
            assert_eq!(r.resolve(), r);
        }
        // An explicitly chosen available kernel is honored verbatim.
        for k in HostKernel::available_kernels() {
            assert_eq!(k.resolve(), k);
            assert_ne!(k, HostKernel::Auto);
        }
        // The portable kernels exist everywhere.
        let avail = HostKernel::available_kernels();
        assert!(avail.contains(&HostKernel::Scalar));
        assert!(avail.contains(&HostKernel::Swar));
    }
}
