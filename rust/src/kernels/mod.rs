//! CFU-specialized compute kernels (Listings 1, 2, 3 of the paper).
//!
//! Each kernel runs the *actual* integer arithmetic through the CFU
//! functional models while charging every instruction of the inner-loop
//! code shape to a [`crate::cpu::CycleCounter`]. Outputs are therefore
//! bit-exact against [`crate::nn`]'s golden ops (asserted in tests), and
//! cycle counts are comparable across designs.
//!
//! ## Modelled instruction sequences (per 4-weight block)
//!
//! Baseline / USSA (`for` loop, Listing 1):
//! `add a_w` · `lw w` · `add a_x` · `lw x` · `cfu mac` · `add acc` ·
//! `addi i` · `blt` — 4 ALU, 2 loads, 1 CFU, 1 branch.
//!
//! SSSA / CSA (`while` loop, Listings 2/3):
//! `add a_w` · `lw w` · `add a_x` · `lw x` · `cfu mac` · `add acc` ·
//! `cfu inc_indvar` · `bltu` — 3 ALU, 2 loads, 2 CFU, 1 branch.
//!
//! The `inc_indvar` custom instruction *replaces* the `addi`, so a
//! visited block costs the same CPU overhead in both shapes; the savings
//! come from visiting fewer blocks (SSSA) and/or fewer MAC stall cycles
//! (USSA/CSA).
//!
//! ## Execution modes
//!
//! The loop shapes above are pure functions of the packed weights, so
//! the kernels run them three ways over the same prepare-time
//! [`lane::ScheduleArena`]:
//!
//! - [`ExecMode::Batched`] (default) — [`lane::run_lane_batched`]
//!   interchanges the loops: each lane's arena slice is walked once and
//!   every input row of the batch is streamed against each visited
//!   block, amortizing schedule decode and weight reads across the
//!   batch. With intra-layer tiling enabled (see
//!   [`crate::simulator::SimEngine`]) the lane dimension additionally
//!   splits across worker threads, one [`crate::cpu::CycleCounter`] per
//!   tile, merged deterministically in tile order.
//! - [`ExecMode::Compiled`] — [`lane::run_lane_compiled`] re-walks each
//!   lane's schedule per input row (the pre-interchange host path, kept
//!   as a bench/differential comparison point);
//! - [`ExecMode::Interpreted`] — [`lane::run_lane`] dispatching every
//!   MAC/`inc_indvar` through the CFU functional models, kept as the
//!   differential oracle.
//!
//! Outputs and cycle totals are bit-identical between all modes
//! (asserted across designs × models × batch sizes × tile counts by the
//! differential tier): the cycle model charges per-lane
//! [`crate::cpu::BulkCharge`]s whose conversion to counter totals is
//! linear, so loop interchange and lane tiling cannot change any
//! simulated metric.

pub mod conv;
pub mod fc;
pub mod lane;

pub use conv::PreparedConv;
pub use fc::PreparedFc;
pub use lane::{
    prepare_lanes, run_lane, run_lane_batched, run_lane_compiled, LaneScheduleRef, PreparedLanes,
    ScheduleArena,
};

use crate::cpu::CycleCounter;
use crate::tensor::QTensor;

/// How the kernels execute their MAC lanes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Batch-amortized execution over the prepare-time schedule arena:
    /// each lane's visited slice is walked once per layer with every
    /// input row streamed against it (the default host path).
    #[default]
    Batched,
    /// Per-lane, row-major table-driven execution over the same arena —
    /// the pre-interchange compiled path, kept as a comparison point.
    Compiled,
    /// Per-instruction CFU dispatch — the reference oracle the compiled
    /// and batched paths are differentially tested against.
    Interpreted,
}

impl ExecMode {
    /// Short name for logs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::Batched => "batched",
            ExecMode::Compiled => "compiled",
            ExecMode::Interpreted => "interpreted",
        }
    }
}

/// Output of one kernel invocation.
#[derive(Debug, Clone)]
pub struct KernelRun {
    /// Computed activation tensor (bit-exact vs the reference op).
    pub output: QTensor,
    /// Cycle/instruction accounting for the whole layer.
    pub counter: CycleCounter,
}

/// Split `n` lanes into at most `tiles` contiguous near-equal ranges
/// (the intra-layer tiling grid). The split depends only on `(n,
/// tiles)`, so a given tile count always produces the same deterministic
/// partition.
pub fn tile_ranges(n: usize, tiles: usize) -> Vec<std::ops::Range<usize>> {
    let tiles = tiles.clamp(1, n.max(1));
    let base = n / tiles;
    let extra = n % tiles;
    let mut out = Vec::with_capacity(tiles);
    let mut start = 0usize;
    for t in 0..tiles {
        let len = base + usize::from(t < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::tile_ranges;

    #[test]
    fn tile_ranges_cover_exactly_once() {
        for n in [1usize, 2, 7, 16, 33] {
            for tiles in [1usize, 2, 3, 8, 64] {
                let ranges = tile_ranges(n, tiles);
                assert!(ranges.len() <= tiles.max(1));
                assert_eq!(ranges.first().unwrap().start, 0);
                assert_eq!(ranges.last().unwrap().end, n);
                for pair in ranges.windows(2) {
                    assert_eq!(pair[0].end, pair[1].start, "contiguous n={n} tiles={tiles}");
                    assert!(!pair[0].is_empty());
                }
                let total: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(total, n);
            }
        }
    }
}
