//! Instruction cycle costs of the modelled core.

/// Per-class cycle costs for a VexRiscv-like five-stage in-order core.
///
/// Defaults correspond to the "full" VexRiscv configuration used by CFU
/// Playground: single-issue, 1-cycle ALU ops, 1-cycle cached loads and
/// stores, taken branches flush the front-end (1 + 2 penalty cycles),
/// and custom instructions occupy the pipeline for one issue cycle plus
/// any CFU stall cycles (charged separately from [`super::CycleCounter`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CostModel {
    /// Integer ALU op (add/sub/shift/logic/compare).
    pub alu: u64,
    /// Load (cache hit).
    pub load: u64,
    /// Store (cache hit).
    pub store: u64,
    /// Taken branch/jump (includes pipeline flush).
    pub branch_taken: u64,
    /// Not-taken branch.
    pub branch_not_taken: u64,
    /// CFU instruction issue slot (stall cycles added per-response).
    pub cfu_issue: u64,
}

impl CostModel {
    /// VexRiscv five-stage defaults (CFU Playground configuration).
    ///
    /// ```
    /// use sparse_riscv::cpu::CostModel;
    ///
    /// let m = CostModel::vexriscv();
    /// assert_eq!(m.alu, 1);
    /// assert_eq!(m.branch_taken, 3); // taken branches flush the front-end
    /// assert_eq!(m.cfu_issue, 1);    // CFU stalls are charged separately
    /// ```
    pub fn vexriscv() -> Self {
        CostModel {
            alu: 1,
            load: 1,
            store: 1,
            branch_taken: 3,
            branch_not_taken: 1,
            cfu_issue: 1,
        }
    }

    /// An idealized core where only CFU cycles count — used to isolate
    /// the MAC-unit speedups the paper's analytical model describes
    /// (Figures 8/9 "observed" series measure the accelerated inner
    /// loop; this mode removes the common loop overhead from both sides
    /// of the ratio).
    pub fn mac_only() -> Self {
        CostModel {
            alu: 0,
            load: 0,
            store: 0,
            branch_taken: 0,
            branch_not_taken: 0,
            cfu_issue: 1,
        }
    }
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel::vexriscv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vexriscv_defaults_sane() {
        let m = CostModel::vexriscv();
        assert_eq!(m.alu, 1);
        assert!(m.branch_taken > m.branch_not_taken);
        assert_eq!(m.cfu_issue, 1);
    }

    #[test]
    fn mac_only_zeroes_cpu_side() {
        let m = CostModel::mac_only();
        assert_eq!(m.alu + m.load + m.store + m.branch_taken + m.branch_not_taken, 0);
        assert_eq!(m.cfu_issue, 1);
    }
}
