//! Cycle and instruction accounting.

use super::cost_model::CostModel;
use crate::cfu::CfuResponse;
use crate::error::{Error, Result};

/// Instruction classes tracked by the counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InstrClass {
    /// Integer ALU.
    Alu,
    /// Load.
    Load,
    /// Store.
    Store,
    /// Branch (taken or not).
    Branch,
    /// CFU custom instruction.
    Cfu,
}

/// Precomputed instruction counts for a whole lane (or any other
/// code region), flushed to a [`CycleCounter`] in one call.
///
/// The counts are *cost-model independent* — cycle conversion happens at
/// flush time via [`CycleCounter::charge`] — so a charge compiled once at
/// prepare time replays identically under any [`CostModel`] (vexriscv,
/// mac-only, custom).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct BulkCharge {
    /// Integer ALU instructions.
    pub alu: u64,
    /// Word loads.
    pub loads: u64,
    /// Word stores.
    pub stores: u64,
    /// Taken branches.
    pub branches_taken: u64,
    /// Not-taken branches.
    pub branches_not_taken: u64,
    /// CFU instructions issued.
    pub cfu_issues: u64,
    /// Total CFU stall cycles (multi-cycle response waits).
    pub cfu_stalls: u64,
}

/// Accumulates cycles and instruction counts for one simulated kernel run.
#[derive(Debug, Clone)]
pub struct CycleCounter {
    model: CostModel,
    cycles: u64,
    instrs: [u64; 5],
    /// Stall cycles spent waiting on multi-cycle CFU responses.
    cfu_stall_cycles: u64,
    /// Cycles attributable to the CFU (issue + stall) — the "MAC unit"
    /// share used for Figure 8/9 style accounting.
    cfu_total_cycles: u64,
    /// Bytes moved by loads (memory-traffic model).
    loaded_bytes: u64,
    /// Bytes moved by stores.
    stored_bytes: u64,
}

impl CycleCounter {
    /// New counter under a cost model.
    pub fn new(model: CostModel) -> Self {
        CycleCounter {
            model,
            cycles: 0,
            instrs: [0; 5],
            cfu_stall_cycles: 0,
            cfu_total_cycles: 0,
            loaded_bytes: 0,
            stored_bytes: 0,
        }
    }

    /// Charge `n` ALU instructions.
    #[inline]
    pub fn alu(&mut self, n: u64) {
        self.instrs[0] += n;
        self.cycles += n * self.model.alu;
    }

    /// Charge `n` word loads.
    #[inline]
    pub fn load_words(&mut self, n: u64) {
        self.instrs[1] += n;
        self.cycles += n * self.model.load;
        self.loaded_bytes += n * 4;
    }

    /// Charge `n` word stores.
    #[inline]
    pub fn store_words(&mut self, n: u64) {
        self.instrs[2] += n;
        self.cycles += n * self.model.store;
        self.stored_bytes += n * 4;
    }

    /// Charge one branch.
    #[inline]
    pub fn branch(&mut self, taken: bool) {
        self.instrs[3] += 1;
        self.cycles +=
            if taken { self.model.branch_taken } else { self.model.branch_not_taken };
    }

    /// Charge one CFU instruction given its response.
    #[inline]
    pub fn cfu(&mut self, resp: &CfuResponse) {
        self.instrs[4] += 1;
        let stall = (resp.cycles as u64).saturating_sub(1);
        let total = self.model.cfu_issue + stall;
        self.cycles += total;
        self.cfu_stall_cycles += stall;
        self.cfu_total_cycles += total;
    }

    /// Total cycles so far.
    pub fn cycles(&self) -> u64 {
        self.cycles
    }

    /// Instruction count for a class.
    pub fn instr_count(&self, class: InstrClass) -> u64 {
        self.instrs[class as usize]
    }

    /// Total retired instructions.
    pub fn total_instrs(&self) -> u64 {
        self.instrs.iter().sum()
    }

    /// CFU stall cycles.
    pub fn cfu_stalls(&self) -> u64 {
        self.cfu_stall_cycles
    }

    /// CFU issue+stall cycles (the MAC-unit share).
    pub fn cfu_cycles(&self) -> u64 {
        self.cfu_total_cycles
    }

    /// Bytes loaded.
    pub fn loaded_bytes(&self) -> u64 {
        self.loaded_bytes
    }

    /// Bytes stored.
    pub fn stored_bytes(&self) -> u64 {
        self.stored_bytes
    }

    /// Bulk charge: the same totals as the per-instruction methods but
    /// one call per *lane* instead of several per *block* — the hot-path
    /// optimization recorded in EXPERIMENTS.md §Perf. `cfu_issues` CFU
    /// instructions with `cfu_stalls` total stall cycles are charged
    /// alongside plain instruction counts.
    #[allow(clippy::too_many_arguments)]
    #[inline]
    pub fn charge_bulk(
        &mut self,
        alu: u64,
        loads: u64,
        stores: u64,
        branches_taken: u64,
        branches_not_taken: u64,
        cfu_issues: u64,
        cfu_stalls: u64,
    ) {
        self.instrs[0] += alu;
        self.instrs[1] += loads;
        self.instrs[2] += stores;
        self.instrs[3] += branches_taken + branches_not_taken;
        self.instrs[4] += cfu_issues;
        let cfu_total = cfu_issues * self.model.cfu_issue + cfu_stalls;
        self.cycles += alu * self.model.alu
            + loads * self.model.load
            + stores * self.model.store
            + branches_taken * self.model.branch_taken
            + branches_not_taken * self.model.branch_not_taken
            + cfu_total;
        self.cfu_stall_cycles += cfu_stalls;
        self.cfu_total_cycles += cfu_total;
        self.loaded_bytes += loads * 4;
        self.stored_bytes += stores * 4;
    }

    /// Flush a precomputed [`BulkCharge`] (the compiled-lane-schedule
    /// flush path; totals identical to charging each instruction).
    #[inline]
    pub fn charge(&mut self, c: &BulkCharge) {
        self.charge_bulk(
            c.alu,
            c.loads,
            c.stores,
            c.branches_taken,
            c.branches_not_taken,
            c.cfu_issues,
            c.cfu_stalls,
        );
    }

    /// Flush a [`BulkCharge`] `times` over: the batch-amortized charge
    /// path. Every counter field is a linear function of the charge
    /// counts, so one scaled flush lands on exactly the totals `times`
    /// individual [`CycleCounter::charge`] calls would — the invariant
    /// that keeps loop-interchanged (batched) execution cycle-identical
    /// to the row-major walk (asserted below and by the differential
    /// tier).
    ///
    /// The count × row multiplications are checked: an absurdly large
    /// batch surfaces [`Error::Sim`] instead of silently wrapping the
    /// counter totals the perf gates compare.
    #[inline]
    pub fn charge_scaled(&mut self, c: &BulkCharge, times: u64) -> Result<()> {
        let scale = |n: u64| {
            n.checked_mul(times).ok_or_else(|| {
                Error::Sim(format!("bulk charge count {n} x {times} rows overflows u64"))
            })
        };
        self.charge_bulk(
            scale(c.alu)?,
            scale(c.loads)?,
            scale(c.stores)?,
            scale(c.branches_taken)?,
            scale(c.branches_not_taken)?,
            scale(c.cfu_issues)?,
            scale(c.cfu_stalls)?,
        );
        Ok(())
    }

    /// Merge another counter (parallel layer/tile simulation): every
    /// observable total is summed, so merging per-tile counters in tile
    /// order reproduces the single-counter totals exactly.
    pub fn merge(&mut self, other: &CycleCounter) {
        self.cycles += other.cycles;
        for i in 0..self.instrs.len() {
            self.instrs[i] += other.instrs[i];
        }
        self.cfu_stall_cycles += other.cfu_stall_cycles;
        self.cfu_total_cycles += other.cfu_total_cycles;
        self.loaded_bytes += other.loaded_bytes;
        self.stored_bytes += other.stored_bytes;
    }

    /// Convert cycles to seconds at a clock frequency.
    pub fn seconds_at(&self, clock_hz: u64) -> f64 {
        self.cycles as f64 / clock_hz as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charges_match_model() {
        let mut c = CycleCounter::new(CostModel::vexriscv());
        c.alu(3); // 3
        c.load_words(2); // 2
        c.store_words(1); // 1
        c.branch(true); // 3
        c.branch(false); // 1
        c.cfu(&CfuResponse { rd: 0, cycles: 4 }); // 1 issue + 3 stall
        assert_eq!(c.cycles(), 3 + 2 + 1 + 3 + 1 + 4);
        assert_eq!(c.total_instrs(), 3 + 2 + 1 + 2 + 1);
        assert_eq!(c.cfu_stalls(), 3);
        assert_eq!(c.cfu_cycles(), 4);
        assert_eq!(c.loaded_bytes(), 8);
        assert_eq!(c.stored_bytes(), 4);
    }

    #[test]
    fn single_cycle_cfu_no_stall() {
        let mut c = CycleCounter::new(CostModel::vexriscv());
        c.cfu(&CfuResponse { rd: 0, cycles: 1 });
        assert_eq!(c.cycles(), 1);
        assert_eq!(c.cfu_stalls(), 0);
    }

    #[test]
    fn mac_only_counts_only_cfu() {
        let mut c = CycleCounter::new(CostModel::mac_only());
        c.alu(10);
        c.load_words(10);
        c.branch(true);
        c.cfu(&CfuResponse { rd: 0, cycles: 2 });
        assert_eq!(c.cycles(), 2);
    }

    #[test]
    fn merge_adds_everything() {
        let mut a = CycleCounter::new(CostModel::vexriscv());
        a.alu(5);
        let mut b = CycleCounter::new(CostModel::vexriscv());
        b.load_words(2);
        b.cfu(&CfuResponse { rd: 0, cycles: 3 });
        a.merge(&b);
        assert_eq!(a.cycles(), 5 + 2 + 3);
        assert_eq!(a.instr_count(InstrClass::Alu), 5);
        assert_eq!(a.instr_count(InstrClass::Load), 2);
        assert_eq!(a.instr_count(InstrClass::Cfu), 1);
    }

    #[test]
    fn charge_bulk_equals_individual_charges() {
        let mut a = CycleCounter::new(CostModel::vexriscv());
        a.alu(7);
        a.load_words(3);
        a.store_words(2);
        a.branch(true);
        a.branch(true);
        a.branch(false);
        a.cfu(&CfuResponse { rd: 0, cycles: 3 });
        a.cfu(&CfuResponse { rd: 0, cycles: 1 });
        let mut b = CycleCounter::new(CostModel::vexriscv());
        b.charge_bulk(7, 3, 2, 2, 1, 2, 2);
        assert_eq!(a.cycles(), b.cycles());
        assert_eq!(a.total_instrs(), b.total_instrs());
        assert_eq!(a.cfu_cycles(), b.cfu_cycles());
        assert_eq!(a.cfu_stalls(), b.cfu_stalls());
        assert_eq!(a.loaded_bytes(), b.loaded_bytes());
        assert_eq!(a.stored_bytes(), b.stored_bytes());
    }

    #[test]
    fn bulk_charge_struct_equals_charge_bulk() {
        let c = BulkCharge {
            alu: 7,
            loads: 3,
            stores: 2,
            branches_taken: 2,
            branches_not_taken: 1,
            cfu_issues: 2,
            cfu_stalls: 2,
        };
        let mut a = CycleCounter::new(CostModel::vexriscv());
        a.charge(&c);
        let mut b = CycleCounter::new(CostModel::vexriscv());
        b.charge_bulk(7, 3, 2, 2, 1, 2, 2);
        assert_eq!(a.cycles(), b.cycles());
        assert_eq!(a.total_instrs(), b.total_instrs());
        assert_eq!(a.cfu_cycles(), b.cfu_cycles());
        assert_eq!(a.loaded_bytes(), b.loaded_bytes());
    }

    #[test]
    fn charge_scaled_equals_repeated_charges() {
        let c = BulkCharge {
            alu: 5,
            loads: 4,
            stores: 1,
            branches_taken: 3,
            branches_not_taken: 1,
            cfu_issues: 6,
            cfu_stalls: 9,
        };
        for model in [CostModel::vexriscv(), CostModel::mac_only()] {
            let mut a = CycleCounter::new(model.clone());
            for _ in 0..7 {
                a.charge(&c);
            }
            let mut b = CycleCounter::new(model);
            b.charge_scaled(&c, 7).unwrap();
            assert_eq!(a.cycles(), b.cycles());
            assert_eq!(a.total_instrs(), b.total_instrs());
            assert_eq!(a.cfu_cycles(), b.cfu_cycles());
            assert_eq!(a.cfu_stalls(), b.cfu_stalls());
            assert_eq!(a.loaded_bytes(), b.loaded_bytes());
            assert_eq!(a.stored_bytes(), b.stored_bytes());
        }
    }

    #[test]
    fn charge_scaled_overflow_is_an_error_not_a_wrap() {
        let c = BulkCharge { alu: u64::MAX / 2, ..Default::default() };
        let mut a = CycleCounter::new(CostModel::vexriscv());
        // In range: exactly representable.
        a.charge_scaled(&c, 2).unwrap();
        // One more row would wrap the ALU count — must surface Error::Sim
        // instead of silently corrupting the totals.
        let mut b = CycleCounter::new(CostModel::vexriscv());
        let err = b.charge_scaled(&c, 3).unwrap_err();
        assert!(err.to_string().starts_with("simulation error:"), "{err}");
        assert!(err.to_string().contains("overflows"), "{err}");
        // The failed flush must not have partially charged anything.
        assert_eq!(b.cycles(), 0);
        assert_eq!(b.total_instrs(), 0);
    }

    #[test]
    fn seconds_at_clock() {
        let mut c = CycleCounter::new(CostModel::vexriscv());
        c.alu(100_000_000);
        assert!((c.seconds_at(100_000_000) - 1.0).abs() < 1e-12);
    }
}
