//! VexRiscv-like CPU timing model.
//!
//! The paper's SoC is a VexRiscv five-stage in-order soft core at 100 MHz
//! (CFU Playground / LiteX). Reported speedups are ratios of clock-cycle
//! counts of the same convolution kernels under different CFUs, so an
//! instruction-class cycle-cost model reproduces them without RTL:
//! every instruction the kernel's inner loops would execute is charged
//! through [`CostModel`], and CFU instructions additionally stall the
//! pipeline for `cycles - 1` (the valid/ready handshake of Fig 3).
//!
//! [`CycleCounter`] accumulates cycles and per-class instruction counts;
//! the kernel implementations in [`crate::kernels`] drive it.

pub mod cost_model;
pub mod counter;

pub use cost_model::CostModel;
pub use counter::{BulkCharge, CycleCounter, InstrClass};
