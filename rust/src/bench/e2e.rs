//! End-to-end batched-throughput sweep: every zoo model under every
//! requested design, batch-scheduled on the engine-v2
//! [`crate::coordinator::BatchEngine`], at one worker thread vs many.
//!
//! Shared by the `sparse-riscv bench-e2e` subcommand and the
//! `benches/e2e_throughput.rs` cargo bench so the CLI and the bench
//! cannot drift apart.

use crate::coordinator::batch::{BatchEngine, BatchOptions, BatchReport, BatchSpec};
use crate::error::Result;
use crate::isa::DesignKind;
use crate::metrics::MetricRecord;
use crate::simulator::PreparedCache;
use crate::util::stats::geomean;
use std::sync::Arc;

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct E2eConfig {
    /// Model zoo identifiers to run.
    pub models: Vec<String>,
    /// Accelerator designs to run.
    pub designs: Vec<DesignKind>,
    /// Requests per batch (the acceptance floor is 8).
    pub batch: usize,
    /// Worker threads for the multi-threaded side (0 = auto).
    pub threads: usize,
    /// Model width multiplier.
    pub scale: f64,
    /// Unstructured sparsity within surviving blocks.
    pub x_us: f64,
    /// 4:4 block sparsity.
    pub x_ss: f64,
    /// Request RNG seed.
    pub seed: u64,
    /// SoC clock (simulated-latency conversion).
    pub clock_hz: u64,
}

impl Default for E2eConfig {
    fn default() -> Self {
        E2eConfig {
            models: crate::models::zoo::model_names().iter().map(|s| s.to_string()).collect(),
            designs: vec![
                DesignKind::BaselineSimd,
                DesignKind::Sssa,
                DesignKind::Ussa,
                DesignKind::Csa,
            ],
            batch: 8,
            threads: 0,
            scale: 0.1,
            x_us: 0.5,
            x_ss: 0.3,
            seed: 42,
            clock_hz: 100_000_000,
        }
    }
}

/// One measured cell of the sweep.
#[derive(Debug, Clone)]
pub struct E2eRow {
    /// Worker threads used.
    pub threads: usize,
    /// Aggregated batch report (model/design/latency/cycles inside).
    pub report: BatchReport,
}

/// Sweep outcome.
#[derive(Debug, Clone)]
pub struct E2eSummary {
    /// One row per (model, design, thread-count).
    pub rows: Vec<E2eRow>,
    /// Aggregate host inferences/sec with one worker.
    pub agg_single: f64,
    /// Aggregate host inferences/sec with `threads` workers.
    pub agg_multi: f64,
    /// Worker count of the multi-threaded side (resolved).
    pub multi_threads: usize,
}

impl E2eSummary {
    /// Multi-thread over single-thread aggregate throughput ratio.
    pub fn scaling(&self) -> f64 {
        if self.agg_single <= 0.0 {
            return 0.0;
        }
        self.agg_multi / self.agg_single
    }

    /// Geometric-mean per-cell throughput ratio (threads=N vs threads=1).
    pub fn geomean_scaling(&self) -> f64 {
        let ratios: Vec<f64> = self
            .rows
            .chunks(2)
            .filter(|pair| pair.len() == 2)
            .map(|pair| {
                let single = pair[0].report.host_throughput();
                let multi = pair[1].report.host_throughput();
                if single > 0.0 {
                    multi / single
                } else {
                    1.0
                }
            })
            .collect();
        geomean(&ratios)
    }
}

/// Convert a sweep into structured metric records — one per (model,
/// design, thread-side) cell plus an aggregate record.
///
/// Rows come in (threads=1, threads=N) pairs; ids use the stable labels
/// `t1`/`tN` instead of the resolved worker count, which varies by
/// machine (cycle metrics are identical across thread counts by the
/// engine's determinism contract, so both cells stay comparable
/// everywhere).
pub fn to_records(cfg: &E2eConfig, summary: &E2eSummary) -> Vec<MetricRecord> {
    let mut records = Vec::with_capacity(summary.rows.len() + 1);
    for pair in summary.rows.chunks(2) {
        for (side, row) in pair.iter().enumerate() {
            let label = if side == 0 { "t1" } else { "tN" };
            let r = &row.report;
            let spec = BatchSpec {
                x_us: cfg.x_us,
                x_ss: cfg.x_ss,
                scale: cfg.scale,
                ..BatchSpec::assigned(&r.model, r.assignment.clone())
            };
            records.push(r.to_metric(
                &format!("e2e/{}/{}/{label}", r.model, r.design_label()),
                &spec,
                cfg.batch as u64,
                row.threads as u64,
                cfg.clock_hz,
            ));
        }
    }
    records.push(
        MetricRecord::new("e2e/aggregate")
            .context("", "", cfg.x_us, cfg.x_ss, cfg.scale, cfg.batch as u64, 0)
            .with_value("host_inf_s_t1", summary.agg_single)
            .with_value("host_inf_s_tn", summary.agg_multi)
            .with_value("host_scaling", summary.scaling())
            .with_value("host_scaling_geomean", summary.geomean_scaling()),
    );
    records
}

/// Run the sweep: for each (model, design), one batch at threads = 1 and
/// one at threads = N, sharing a prepared-model cache that is warmed
/// before timing so both sides measure pure batch execution.
pub fn run_e2e(cfg: &E2eConfig) -> Result<E2eSummary> {
    let cache = Arc::new(PreparedCache::new());
    let single = BatchEngine::with_cache(
        BatchOptions { threads: 1, clock_hz: cfg.clock_hz, ..Default::default() },
        Arc::clone(&cache),
    );
    let multi = BatchEngine::with_cache(
        BatchOptions { threads: cfg.threads, clock_hz: cfg.clock_hz, ..Default::default() },
        Arc::clone(&cache),
    );

    let specs: Vec<BatchSpec> = cfg
        .models
        .iter()
        .flat_map(|m| {
            cfg.designs.iter().map(move |&d| BatchSpec {
                x_us: cfg.x_us,
                x_ss: cfg.x_ss,
                scale: cfg.scale,
                ..BatchSpec::new(m, d)
            })
        })
        .collect();

    // Warm the shared cache (the paper's offline pre-processing) so the
    // timed passes compare execution, not preparation.
    for spec in &specs {
        single.prepared(spec)?;
    }

    let mut rows = Vec::with_capacity(specs.len() * 2);
    let (mut done_single, mut wall_single) = (0u64, 0.0f64);
    let (mut done_multi, mut wall_multi) = (0u64, 0.0f64);
    for (i, spec) in specs.iter().enumerate() {
        let reqs = BatchEngine::gen_requests(&spec.model, cfg.batch, cfg.seed + i as u64)?;
        let a = single.run_batch(spec, reqs.clone())?;
        done_single += a.completed;
        wall_single += a.wall_seconds;
        rows.push(E2eRow { threads: 1, report: a });
        let b = multi.run_batch(spec, reqs)?;
        done_multi += b.completed;
        wall_multi += b.wall_seconds;
        rows.push(E2eRow { threads: multi.workers(), report: b });
    }
    Ok(E2eSummary {
        rows,
        agg_single: if wall_single > 0.0 { done_single as f64 / wall_single } else { 0.0 },
        agg_multi: if wall_multi > 0.0 { done_multi as f64 / wall_multi } else { 0.0 },
        multi_threads: multi.workers(),
    })
}

/// Render the sweep as an aligned table plus the scaling summary.
pub fn render(cfg: &E2eConfig, summary: &E2eSummary) -> String {
    use crate::analysis::report::{f2, Table};
    let mut t = Table::new(
        &format!(
            "e2e batched throughput (batch={}, scale={}, x_us={}, x_ss={})",
            cfg.batch, cfg.scale, cfg.x_us, cfg.x_ss
        ),
        &[
            "model",
            "design",
            "threads",
            "host wall s",
            "host inf/s",
            "sim inf/s",
            "p50 ms",
            "p99 ms",
            "stall %",
        ],
    );
    for row in &summary.rows {
        let r = &row.report;
        let stall_pct = if r.total_cycles > 0 {
            100.0 * r.cfu_stalls as f64 / r.total_cycles as f64
        } else {
            0.0
        };
        t.row(&[
            r.model.clone(),
            r.design_label(),
            row.threads.to_string(),
            format!("{:.4}", r.wall_seconds),
            f2(r.host_throughput()),
            f2(r.sim_throughput(cfg.clock_hz)),
            format!("{:.3}", r.p50 * 1e3),
            format!("{:.3}", r.p99 * 1e3),
            f2(stall_pct),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "aggregate host throughput: {} inf/s @1 thread vs {} inf/s @{} threads — {}x scaling (geomean per-cell {}x)\n",
        f2(summary.agg_single),
        f2(summary.agg_multi),
        summary.multi_threads,
        f2(summary.scaling()),
        f2(summary.geomean_scaling()),
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_models_by_designs_by_threads() {
        // Tiny sweep: 2 models × 2 designs × 2 thread counts.
        let cfg = E2eConfig {
            models: vec!["dscnn".into(), "resnet56".into()],
            designs: vec![DesignKind::BaselineSimd, DesignKind::Csa],
            batch: 2,
            threads: 2,
            scale: 0.07,
            ..Default::default()
        };
        let summary = run_e2e(&cfg).unwrap();
        assert_eq!(summary.rows.len(), 2 * 2 * 2);
        for row in &summary.rows {
            assert_eq!(row.report.completed, 2);
            assert!(row.report.cache_hit, "cache was pre-warmed");
            assert!(row.report.total_cycles > 0);
        }
        let rendered = render(&cfg, &summary);
        assert!(rendered.contains("dscnn"));
        assert!(rendered.contains("CSA"));
        assert!(rendered.contains("aggregate host throughput"));
    }

    #[test]
    fn records_are_stable_across_thread_resolution() {
        let cfg = E2eConfig {
            models: vec!["dscnn".into()],
            designs: vec![DesignKind::Csa],
            batch: 2,
            threads: 3,
            scale: 0.07,
            ..Default::default()
        };
        let summary = run_e2e(&cfg).unwrap();
        let records = to_records(&cfg, &summary);
        // 1 model × 1 design × 2 thread sides + 1 aggregate.
        assert_eq!(records.len(), 3);
        let t1 = records.iter().find(|r| r.id == "e2e/dscnn/CSA/t1").unwrap();
        let tn = records.iter().find(|r| r.id == "e2e/dscnn/CSA/tN").unwrap();
        // Cycle metrics are thread-invariant (determinism contract), so
        // both sides of the pair carry identical gated values.
        for m in ["total_cycles", "cfu_cycles", "cfu_stalls", "loaded_bytes", "p50_ms"] {
            assert_eq!(t1.get(m), tn.get(m), "{m} differs across thread sides");
        }
        assert!(t1.get("total_cycles").unwrap() > 0.0);
        // The serve-path host throughput rides along as an informational
        // metric so compiled-path speedups show up in baseline diffs.
        assert!(t1.get("host_infer_per_s").unwrap() > 0.0);
        assert!(!crate::metrics::spec_for("host_infer_per_s").gate);
        let agg = records.iter().find(|r| r.id == "e2e/aggregate").unwrap();
        assert!(agg.get("host_scaling").is_some());
    }
}
