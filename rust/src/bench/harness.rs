//! Wall-clock micro-benchmark runner.

use crate::metrics::MetricRecord;
use crate::util::stats::{OnlineStats, Percentiles};
use std::time::Instant;

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// Measured iterations.
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup: 3, iters: 10 }
    }
}

/// Result of a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub label: String,
    /// Mean seconds per iteration.
    pub mean_s: f64,
    /// Standard deviation.
    pub stddev_s: f64,
    /// Median seconds.
    pub median_s: f64,
    /// Minimum seconds.
    pub min_s: f64,
    /// Iterations measured.
    pub iters: usize,
}

impl BenchResult {
    /// Throughput implied by the mean iteration time when each iteration
    /// processes `items_per_iter` items (e.g. inferences per batch).
    pub fn items_per_sec(&self, items_per_iter: usize) -> f64 {
        if self.mean_s <= 0.0 {
            return 0.0;
        }
        items_per_iter as f64 / self.mean_s
    }

    /// Emit the wall-clock result as a structured metric record. All
    /// values use the ungated `wall_*` namespace: host timing varies
    /// across machines and must never gate CI, but persisting it gives
    /// perf PRs a trend line.
    pub fn to_metric(&self, id: &str) -> MetricRecord {
        MetricRecord::new(id)
            .with_value("wall_mean_ms", self.mean_s * 1e3)
            .with_value("wall_median_ms", self.median_s * 1e3)
            .with_value("wall_min_ms", self.min_s * 1e3)
            .with_value("wall_stddev_ms", self.stddev_s * 1e3)
    }

    /// Render one line, auto-scaling units.
    pub fn render(&self) -> String {
        fn scale(s: f64) -> String {
            if s >= 1.0 {
                format!("{s:.3} s")
            } else if s >= 1e-3 {
                format!("{:.3} ms", s * 1e3)
            } else if s >= 1e-6 {
                format!("{:.3} µs", s * 1e6)
            } else {
                format!("{:.1} ns", s * 1e9)
            }
        }
        format!(
            "{:40} mean {:>12}  median {:>12}  min {:>12}  (±{:.1}%, n={})",
            self.label,
            scale(self.mean_s),
            scale(self.median_s),
            scale(self.min_s),
            if self.mean_s > 0.0 { 100.0 * self.stddev_s / self.mean_s } else { 0.0 },
            self.iters
        )
    }
}

/// Run a closure under the harness and report timing.
pub fn bench_fn<F: FnMut()>(label: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup {
        f();
    }
    let mut stats = OnlineStats::new();
    let mut pcts = Percentiles::new();
    for _ in 0..cfg.iters.max(1) {
        let t0 = Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        stats.push(dt);
        pcts.push(dt);
    }
    BenchResult {
        label: label.to_string(),
        mean_s: stats.mean(),
        stddev_s: stats.stddev(),
        median_s: pcts.median(),
        min_s: stats.min(),
        iters: cfg.iters.max(1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_work() {
        let mut acc = 0u64;
        let r = bench_fn("spin", &BenchConfig { warmup: 1, iters: 5 }, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
        });
        assert!(acc > 0);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s + 1e-12);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn render_contains_label() {
        let r = bench_fn("my-label", &BenchConfig { warmup: 0, iters: 1 }, || {});
        assert!(r.render().contains("my-label"));
    }

    #[test]
    fn to_metric_uses_ungated_wall_namespace() {
        let r = bench_fn("lbl", &BenchConfig { warmup: 0, iters: 2 }, || {});
        let rec = r.to_metric("micro/lbl");
        assert_eq!(rec.id, "micro/lbl");
        for name in rec.values.keys() {
            assert!(
                !crate::metrics::spec_for(name).gate,
                "wall metric '{name}' must not gate CI"
            );
        }
        assert!(rec.get("wall_mean_ms").is_some());
    }

    #[test]
    fn items_per_sec_scales_with_batch() {
        let r = BenchResult {
            label: "t".into(),
            mean_s: 0.5,
            stddev_s: 0.0,
            median_s: 0.5,
            min_s: 0.5,
            iters: 1,
        };
        assert!((r.items_per_sec(8) - 16.0).abs() < 1e-12);
        assert_eq!(
            BenchResult { mean_s: 0.0, ..r }.items_per_sec(8),
            0.0
        );
    }
}
