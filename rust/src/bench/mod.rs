//! Minimal benchmark harness (offline substitute for `criterion`).
//!
//! Used by the `benches/` binaries (`cargo bench` with `harness = false`):
//! warmup, timed iterations, and mean/stddev/percentile reporting via
//! [`crate::util::stats`]. Wall-clock timing is for *harness* performance
//! (the L3 perf pass); the paper's metrics are simulated clock cycles,
//! which are deterministic and need no statistical treatment.
//!
//! [`e2e`] hosts the batched end-to-end throughput sweep shared by the
//! `bench-e2e` CLI subcommand and `benches/e2e_throughput.rs`, and
//! [`explore`] the design-space-explorer sweep (explored-vs-uniform
//! speedup on a canonical mixed-sparsity workload). Both the sweeps and
//! [`harness::BenchResult`] emit structured
//! [`crate::metrics::MetricRecord`]s so every benchmark feeds the
//! committed `BENCH_*.json` baselines (see [`crate::metrics`]).

pub mod e2e;
pub mod explore;
pub mod harness;

pub use e2e::{run_e2e, to_records, E2eConfig, E2eSummary};
pub use explore::{explore_mixed, mixed_scenario, run_explore_bench};
pub use harness::{bench_fn, BenchConfig, BenchResult};
