//! Minimal benchmark harness (offline substitute for `criterion`).
//!
//! Used by the `benches/` binaries (`cargo bench` with `harness = false`):
//! warmup, timed iterations, and mean/stddev/percentile reporting via
//! [`crate::util::stats`]. Wall-clock timing is for *harness* performance
//! (the L3 perf pass); the paper's metrics are simulated clock cycles,
//! which are deterministic and need no statistical treatment.

pub mod harness;

pub use harness::{bench_fn, BenchConfig, BenchResult};
