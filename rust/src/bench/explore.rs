//! Explorer sweep: explored-vs-best-uniform speedup per zoo model on a
//! canonical mixed-sparsity workload.
//!
//! Shared by the `bench-e2e` subcommand (which appends these records to
//! the `BENCH_e2e.json` sink) and `benches/explore.rs`, so the perf
//! gates can track the explorer's wins once baselines are seeded. The
//! metrics are informational (`explore_*` in the registry): the
//! heterogeneous-vs-uniform gap is a *capability* number, gated later
//! when a baseline deliberately commits it.

use crate::error::Result;
use crate::explorer::{explore, profile_graph, Exploration, ExplorerOptions};
use crate::metrics::MetricRecord;
use crate::models::builder::{
    apply_prune_plan, apply_sparsity_plan, widen_weights_to_int8, LayerPrune, ModelConfig,
};
use crate::models::zoo::build_model;
use crate::nn::graph::Graph;
use crate::tensor::Shape;

/// Per-layer sparsity of the scenario's hidden layers (block-heavy, the
/// SSSA-friendly side of the mix) — also the `(x_us, x_ss)` context the
/// metric records carry, since a per-layer plan has no single ratio.
pub const HIDDEN_SPARSITY: (f64, f64) = (0.5, 0.5);
/// Per-layer sparsity of the widened stem/head layers (unstructured
/// only, no skippable blocks).
pub const EDGE_SPARSITY: (f64, f64) = (0.4, 0.0);

/// Build the canonical mixed co-design workload for one zoo model:
/// hidden layers get [`HIDDEN_SPARSITY`] and a 2:4 structure pass on
/// top (block-sparse *and* N:M-compliant, so both the lookahead designs
/// and NM-SSA are lossless-eligible there); the stem and classifier
/// head get [`EDGE_SPARSITY`] only and are widened to full INT8 range
/// (unstructured and wide, so lossless deployments must keep a baseline
/// design there — the realistic mixed-range case the explorer exists
/// for). Deterministic in (model, scale).
pub fn mixed_scenario(model: &str, scale: f64) -> Result<(Graph, Shape)> {
    let cfg = ModelConfig { scale, ..Default::default() };
    let mut info = build_model(model, &cfg)?;
    let n = info.graph.mac_layers();
    let widened = if n > 1 { vec![0, n - 1] } else { vec![0] };
    let plan: Vec<(f64, f64)> = (0..n)
        .map(|i| if widened.contains(&i) { EDGE_SPARSITY } else { HIDDEN_SPARSITY })
        .collect();
    apply_sparsity_plan(&mut info.graph, &plan);
    // 2:4 enforcement only zeroes surplus non-zeros inside surviving
    // words, so the block/word skip structure above is unchanged — the
    // hidden layers merely become NM-SSA-feasible under lossless mode.
    let nm_plan: Vec<LayerPrune> = (0..n)
        .map(|i| {
            if widened.contains(&i) {
                LayerPrune::Combined { x_us: 0.0, x_ss: 0.0 }
            } else {
                LayerPrune::Nm { n: 2, m: 4 }
            }
        })
        .collect();
    apply_prune_plan(&mut info.graph, &nm_plan)?;
    widen_weights_to_int8(&mut info.graph, &widened);
    Ok((info.graph, info.input_shape))
}

/// Explore one model's mixed scenario (lossless, unbudgeted, all
/// candidate designs).
pub fn explore_mixed(model: &str, scale: f64) -> Result<Exploration> {
    let (graph, input_shape) = mixed_scenario(model, scale)?;
    let opts = ExplorerOptions::default();
    let table = profile_graph(&graph, &input_shape, &opts.candidates, &opts.cost_model)?;
    explore(&table, &opts)
}

/// Convert one exploration into its informational metric record
/// (`explore/<model>`). `(x_us, x_ss)` is the caller's representative
/// sparsity context — the canonical sweep passes [`HIDDEN_SPARSITY`],
/// the `explore` CLI its actual plan's leading entry.
pub fn to_record(
    model: &str,
    scale: f64,
    (x_us, x_ss): (f64, f64),
    result: &Exploration,
) -> MetricRecord {
    MetricRecord::new(&format!("explore/{model}"))
        .context(model, &result.best.assignment.label(), x_us, x_ss, scale, 0, 0)
        .with_value("explore_best_cycles", result.best.total_cycles as f64)
        .with_value("explore_uniform_cycles", result.best_uniform.total_cycles as f64)
        .with_value("explore_speedup", result.speedup_vs_uniform())
        .with_value("explore_frontier_size", result.frontier.len() as f64)
        .with_value("explore_luts", result.best.resources.luts as f64)
        .with_value("explore_dsps", result.best.resources.dsps as f64)
}

/// Run the sweep over several models, returning one record per model.
pub fn run_explore_bench(models: &[String], scale: f64) -> Result<Vec<MetricRecord>> {
    let mut records = Vec::with_capacity(models.len());
    for model in models {
        let result = explore_mixed(model, scale)?;
        records.push(to_record(model, scale, HIDDEN_SPARSITY, &result));
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_scenario_yields_strict_heterogeneous_win() {
        let result = explore_mixed("dscnn", 0.07).unwrap();
        assert!(result.speedup_vs_uniform() > 1.0, "{}", result.speedup_vs_uniform());
        assert!(!result.best.assignment.is_uniform());
        let rec = to_record("dscnn", 0.07, HIDDEN_SPARSITY, &result);
        assert_eq!(rec.id, "explore/dscnn");
        assert!(rec.get("explore_speedup").unwrap() > 1.0);
        assert!(rec.get("explore_best_cycles").unwrap() > 0.0);
        assert!(rec.get("explore_frontier_size").unwrap() >= 1.0);
        // Informational: explorer records never gate until a baseline
        // deliberately commits them.
        assert!(!crate::metrics::spec_for("explore_best_cycles").gate);
        assert!(!crate::metrics::spec_for("explore_speedup").gate);
    }

    #[test]
    fn sweep_is_deterministic() {
        let a = run_explore_bench(&["dscnn".to_string()], 0.07).unwrap();
        let b = run_explore_bench(&["dscnn".to_string()], 0.07).unwrap();
        assert_eq!(a, b);
    }
}
