//! Declarative argument parsing.

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Specification of one option/flag/positional.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// Long name without dashes (`"sparsity"` → `--sparsity`).
    pub name: &'static str,
    /// Help text.
    pub help: &'static str,
    /// Default value; `None` makes the argument required.
    pub default: Option<String>,
    /// Boolean flag (no value).
    pub is_flag: bool,
}

impl ArgSpec {
    /// Option with a default value.
    pub fn opt(name: &'static str, default: &str, help: &'static str) -> Self {
        ArgSpec { name, help, default: Some(default.to_string()), is_flag: false }
    }

    /// Required option.
    pub fn required(name: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, default: None, is_flag: false }
    }

    /// Boolean flag.
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, default: Some(String::new()), is_flag: true }
    }
}

/// A (sub)command with its argument specs.
#[derive(Debug, Clone)]
pub struct Command {
    /// Command name (binary name for the root command).
    pub name: &'static str,
    /// One-line description.
    pub about: &'static str,
    /// Named options and flags.
    pub args: Vec<ArgSpec>,
    /// Subcommands (if non-empty the first positional selects one).
    pub subcommands: Vec<Command>,
}

impl Command {
    /// New command.
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, args: Vec::new(), subcommands: Vec::new() }
    }

    /// Add an argument spec.
    pub fn arg(mut self, spec: ArgSpec) -> Self {
        self.args.push(spec);
        self
    }

    /// Add a subcommand.
    pub fn subcommand(mut self, cmd: Command) -> Self {
        self.subcommands.push(cmd);
        self
    }

    /// Render help text.
    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        if !self.subcommands.is_empty() {
            s.push_str(" <SUBCOMMAND>");
        }
        if !self.args.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        s.push('\n');
        if !self.args.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for a in &self.args {
                let meta = if a.is_flag { String::new() } else { " <VALUE>".to_string() };
                let dflt = match (&a.default, a.is_flag) {
                    (Some(d), false) if !d.is_empty() => format!(" [default: {d}]"),
                    (None, _) => " [required]".to_string(),
                    _ => String::new(),
                };
                s.push_str(&format!("  --{}{}\n      {}{}\n", a.name, meta, a.help, dflt));
            }
        }
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for c in &self.subcommands {
                s.push_str(&format!("  {:14} {}\n", c.name, c.about));
            }
        }
        s
    }

    /// Parse a token list (excluding argv[0]).
    pub fn parse(&self, tokens: &[String]) -> Result<ParsedArgs> {
        // Help short-circuits.
        if tokens.iter().any(|t| t == "--help" || t == "-h") {
            return Ok(ParsedArgs {
                command_path: vec![self.name.to_string()],
                values: HashMap::new(),
                positionals: Vec::new(),
                help: Some(self.help_text()),
            });
        }
        // Subcommand dispatch.
        if !self.subcommands.is_empty() {
            match tokens.first() {
                Some(tok) if !tok.starts_with('-') => {
                    let sub = self
                        .subcommands
                        .iter()
                        .find(|c| c.name == tok)
                        .ok_or_else(|| Error::Cli(format!("unknown subcommand '{tok}'")))?;
                    let mut parsed = sub.parse(&tokens[1..])?;
                    parsed.command_path.insert(0, self.name.to_string());
                    return Ok(parsed);
                }
                _ => {
                    return Err(Error::Cli(format!(
                        "expected a subcommand; try '{} --help'",
                        self.name
                    )));
                }
            }
        }
        let mut values: HashMap<String, String> = HashMap::new();
        let mut positionals = Vec::new();
        let mut i = 0;
        while i < tokens.len() {
            let tok = &tokens[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .args
                    .iter()
                    .find(|a| a.name == key)
                    .ok_or_else(|| Error::Cli(format!("unknown option '--{key}'")))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(Error::Cli(format!("flag '--{key}' takes no value")));
                    }
                    values.insert(key, "true".to_string());
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            tokens
                                .get(i)
                                .cloned()
                                .ok_or_else(|| Error::Cli(format!("option '--{key}' needs a value")))?
                        }
                    };
                    values.insert(key, val);
                }
            } else {
                positionals.push(tok.clone());
            }
            i += 1;
        }
        // Apply defaults / required checks.
        for spec in &self.args {
            if values.contains_key(spec.name) {
                continue;
            }
            match &spec.default {
                Some(d) if spec.is_flag => {
                    let _ = d;
                    values.insert(spec.name.to_string(), "false".to_string());
                }
                Some(d) => {
                    values.insert(spec.name.to_string(), d.clone());
                }
                None => {
                    return Err(Error::Cli(format!("missing required option '--{}'", spec.name)))
                }
            }
        }
        Ok(ParsedArgs {
            command_path: vec![self.name.to_string()],
            values,
            positionals,
            help: None,
        })
    }

    /// Parse the process arguments.
    pub fn parse_env(&self) -> Result<ParsedArgs> {
        let tokens: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&tokens)
    }
}

/// Parsed argument values.
#[derive(Debug, Clone)]
pub struct ParsedArgs {
    /// Command path, e.g. `["sparse-riscv", "bench"]`.
    pub command_path: Vec<String>,
    /// Resolved option values (defaults applied).
    pub values: HashMap<String, String>,
    /// Positional arguments.
    pub positionals: Vec<String>,
    /// Help text, if `--help` was requested.
    pub help: Option<String>,
}

impl ParsedArgs {
    /// Leaf subcommand name.
    pub fn subcommand(&self) -> &str {
        self.command_path.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// String value (defaults are always present, so missing = program bug).
    pub fn get(&self, name: &str) -> Result<&str> {
        self.values
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::Cli(format!("internal: option '{name}' not declared")))
    }

    /// Typed accessors.
    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name)?
            .parse()
            .map_err(|e| Error::Cli(format!("option '--{name}' expects a number: {e}")))
    }

    /// Parse as usize.
    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name)?
            .parse()
            .map_err(|e| Error::Cli(format!("option '--{name}' expects an integer: {e}")))
    }

    /// Parse as u64.
    pub fn get_u64(&self, name: &str) -> Result<u64> {
        self.get(name)?
            .parse()
            .map_err(|e| Error::Cli(format!("option '--{name}' expects an integer: {e}")))
    }

    /// Flag state.
    pub fn get_flag(&self, name: &str) -> Result<bool> {
        Ok(self.get(name)? == "true")
    }

    /// Comma-separated list value, trimmed, empty entries dropped
    /// (`--models "a, b,"` → `["a", "b"]`; empty value → empty list).
    pub fn get_list(&self, name: &str) -> Result<Vec<String>> {
        Ok(self
            .get(name)?
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|t| t.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("tool", "test tool")
            .arg(ArgSpec::opt("sparsity", "0.5", "sparsity ratio"))
            .arg(ArgSpec::required("model", "model name"))
            .arg(ArgSpec::flag("verbose", "chatty output"))
    }

    #[test]
    fn defaults_and_required() {
        let p = cmd().parse(&toks(&["--model", "dscnn"])).unwrap();
        assert_eq!(p.get("sparsity").unwrap(), "0.5");
        assert_eq!(p.get("model").unwrap(), "dscnn");
        assert!(!p.get_flag("verbose").unwrap());
    }

    #[test]
    fn missing_required_errors() {
        assert!(cmd().parse(&toks(&[])).is_err());
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = cmd().parse(&toks(&["--model=vgg16", "--sparsity=0.9", "--verbose"])).unwrap();
        assert_eq!(p.get("model").unwrap(), "vgg16");
        assert_eq!(p.get_f64("sparsity").unwrap(), 0.9);
        assert!(p.get_flag("verbose").unwrap());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(cmd().parse(&toks(&["--model", "x", "--bogus", "1"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cmd().parse(&toks(&["--model", "x", "--verbose=yes"])).is_err());
    }

    #[test]
    fn subcommand_dispatch() {
        let root = Command::new("root", "r")
            .subcommand(Command::new("run", "run things").arg(ArgSpec::opt("n", "3", "count")));
        let p = root.parse(&toks(&["run", "--n", "7"])).unwrap();
        assert_eq!(p.command_path, vec!["root", "run"]);
        assert_eq!(p.subcommand(), "run");
        assert_eq!(p.get_usize("n").unwrap(), 7);
    }

    #[test]
    fn unknown_subcommand_rejected() {
        let root = Command::new("root", "r").subcommand(Command::new("run", "x"));
        assert!(root.parse(&toks(&["fly"])).is_err());
    }

    #[test]
    fn help_requested() {
        let p = cmd().parse(&toks(&["--help"])).unwrap();
        let h = p.help.unwrap();
        assert!(h.contains("--sparsity"));
        assert!(h.contains("[default: 0.5]"));
        assert!(h.contains("[required]"));
    }

    #[test]
    fn positionals_collected() {
        let c = Command::new("t", "t").arg(ArgSpec::opt("k", "v", "h"));
        let p = c.parse(&toks(&["a", "--k", "x", "b"])).unwrap();
        assert_eq!(p.positionals, vec!["a", "b"]);
    }

    #[test]
    fn list_values_split_and_trim() {
        let c = Command::new("t", "t").arg(ArgSpec::opt("designs", "sssa,ussa", "list"));
        let p = c.parse(&toks(&[])).unwrap();
        assert_eq!(p.get_list("designs").unwrap(), vec!["sssa", "ussa"]);
        let p = c.parse(&toks(&["--designs", " csa , simd ,"])).unwrap();
        assert_eq!(p.get_list("designs").unwrap(), vec!["csa", "simd"]);
        let p = c.parse(&toks(&["--designs", ""])).unwrap();
        assert!(p.get_list("designs").unwrap().is_empty());
    }

    #[test]
    fn typed_parse_errors() {
        let p = cmd().parse(&toks(&["--model", "m", "--sparsity", "abc"])).unwrap();
        assert!(p.get_f64("sparsity").is_err());
    }
}
