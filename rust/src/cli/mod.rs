//! Minimal declarative CLI parser (offline substitute for `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`
//! options with defaults, and positional arguments, plus generated
//! `--help` text. Used by the `sparse-riscv` binary, the examples, and
//! the bench harness.

pub mod parser;

pub use parser::{ArgSpec, Command, ParsedArgs};
