//! The [`MetricRecord`] schema and the metric registry.
//!
//! A record is one measured configuration — (model, design, sparsity
//! point, batch/threads) — plus a flat map of named metric values.
//! Metric *names* carry semantics through the registry
//! ([`METRIC_SPECS`]): direction (lower/higher is better), whether the
//! metric is deterministic and therefore CI-gated, and its regression
//! tolerance. Wall-clock metrics (`wall_*`, `host_*`) are recorded for
//! trend tracking but never gate, because CI machines are noisy;
//! simulated cycle counts are exact for a fixed seed and gate tightly.

use crate::config::value::Value;
use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Which direction of change is an improvement.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    /// Smaller values are better (cycles, latency, bytes).
    LowerIsBetter,
    /// Larger values are better (speedups, throughput, accuracy).
    HigherIsBetter,
}

/// Registry entry describing one metric name (or name prefix).
#[derive(Debug, Clone, Copy)]
pub struct MetricSpec {
    /// Metric name, or prefix when `prefix` is set.
    pub name: &'static str,
    /// Match by prefix instead of exact name.
    pub prefix: bool,
    /// Improvement direction.
    pub better: Direction,
    /// Deterministic metric: regressions beyond tolerance gate CI.
    pub gate: bool,
    /// Relative regression tolerance (fraction of the baseline value).
    pub rel_tol: f64,
    /// Absolute slack: deltas at or below this never count as
    /// regressions (guards tiny counts against relative-tolerance noise).
    pub abs_floor: f64,
}

/// The metric registry. Exact names first, then prefixes; unknown names
/// fall back to an ungated spec so future metrics are forward-compatible.
pub const METRIC_SPECS: &[MetricSpec] = &[
    // Deterministic simulator counters (exact for a fixed seed).
    MetricSpec {
        name: "total_cycles",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.02,
        abs_floor: 16.0,
    },
    MetricSpec {
        name: "cfu_cycles",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.02,
        abs_floor: 16.0,
    },
    MetricSpec {
        name: "cfu_stalls",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.05,
        abs_floor: 64.0,
    },
    MetricSpec {
        name: "loaded_bytes",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.02,
        abs_floor: 64.0,
    },
    // Simulated latency percentiles are derived from cycle counts at a
    // fixed clock — deterministic, gated.
    MetricSpec {
        name: "p50_ms",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.05,
        abs_floor: 1e-4,
    },
    MetricSpec {
        name: "p99_ms",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.05,
        abs_floor: 1e-4,
    },
    // Simulated device throughput: deterministic (derived from gated
    // cycle counts) but deliberately informational — gating it would
    // double-fail every total_cycles regression.
    MetricSpec {
        name: "sim_inf_s",
        prefix: false,
        better: Direction::HigherIsBetter,
        gate: false,
        rel_tol: 0.02,
        abs_floor: 0.0,
    },
    // Figure/table series: cycle-ratio speedups and sparsity ratios.
    MetricSpec {
        name: "speedup",
        prefix: true,
        better: Direction::HigherIsBetter,
        gate: true,
        rel_tol: 0.05,
        abs_floor: 0.02,
    },
    MetricSpec {
        name: "cycles",
        prefix: true,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.02,
        abs_floor: 16.0,
    },
    MetricSpec {
        name: "visited_ratio",
        prefix: true,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.05,
        abs_floor: 0.01,
    },
    MetricSpec {
        name: "accuracy",
        prefix: true,
        better: Direction::HigherIsBetter,
        gate: true,
        rel_tol: 0.02,
        abs_floor: 0.005,
    },
    // FPGA resource estimates (structural, deterministic).
    MetricSpec {
        name: "luts",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.01,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "ffs",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.01,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "dsps",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: true,
        rel_tol: 0.0,
        abs_floor: 0.0,
    },
    // Explorer sweep: deterministic but deliberately informational —
    // the explored-vs-uniform gap becomes gated when a baseline
    // deliberately commits it (the speedup direction is higher-better,
    // the cycle/size/resource values lower-better).
    MetricSpec {
        name: "explore_speedup",
        prefix: false,
        better: Direction::HigherIsBetter,
        gate: false,
        rel_tol: 0.05,
        abs_floor: 0.01,
    },
    MetricSpec {
        name: "explore_frontier_size",
        prefix: false,
        better: Direction::HigherIsBetter,
        gate: false,
        rel_tol: 0.0,
        abs_floor: 0.0,
    },
    MetricSpec {
        name: "explore_",
        prefix: true,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.02,
        abs_floor: 1.0,
    },
    // Network-serving counters: informational (wall-clock-dependent),
    // with explicit directions — the generic `host_` prefix below is
    // higher-is-better, which would misread a shedding or queue-depth
    // improvement as a loss.
    MetricSpec {
        name: "host_shed_total",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_queue_depth_max",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_failed",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_batch_mean",
        prefix: false,
        better: Direction::HigherIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 0.5,
    },
    // Fault-injection / supervised-recovery counters: informational
    // (they track the chaos plan and the recovery machinery, not code
    // quality), lower-is-better so a noisier chaos run reads as a
    // regression in trend diffs rather than an improvement.
    MetricSpec {
        name: "host_integrity_fail",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_degraded_total",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_batcher_restarts",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_retry_total",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_gave_up",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    // Fleet serving counters: informational, lower-is-better — crashes,
    // sheds, failovers, rebalances, and deadline misses are costs, and
    // the generic `host_` prefix would read them as wins.
    MetricSpec {
        name: "host_fleet_failed",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_fleet_shed",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_fleet_failovers",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_fleet_rebalances",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_fleet_crashes",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    MetricSpec {
        name: "host_fleet_deadline_misses",
        prefix: false,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 1.0,
    },
    // Host wall-clock: informational only, never gated. The generous
    // tolerance keeps run-to-run jitter out of the diff table; only
    // swings beyond it get flagged (still non-fatal).
    MetricSpec {
        name: "wall_",
        prefix: true,
        better: Direction::LowerIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 0.0,
    },
    MetricSpec {
        name: "host_",
        prefix: true,
        better: Direction::HigherIsBetter,
        gate: false,
        rel_tol: 0.25,
        abs_floor: 0.0,
    },
];

/// Ungated fallback for names the registry does not know.
pub const UNKNOWN_METRIC: MetricSpec = MetricSpec {
    name: "",
    prefix: false,
    better: Direction::LowerIsBetter,
    gate: false,
    rel_tol: 0.0,
    abs_floor: 0.0,
};

/// Look up the spec for a metric name: exact match wins, then the first
/// matching prefix, then the ungated fallback.
pub fn spec_for(name: &str) -> MetricSpec {
    for s in METRIC_SPECS {
        if !s.prefix && s.name == name {
            return *s;
        }
    }
    for s in METRIC_SPECS {
        if s.prefix && name.starts_with(s.name) {
            return *s;
        }
    }
    UNKNOWN_METRIC
}

/// One measured configuration with its named metric values.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricRecord {
    /// Unique key within a store, e.g. `"e2e/dscnn/CSA/t1"`.
    pub id: String,
    /// Model zoo identifier (empty for non-model benches).
    pub model: String,
    /// Accelerator design name (empty when not design-specific).
    pub design: String,
    /// Unstructured sparsity within surviving blocks.
    pub x_us: f64,
    /// 4:4 block sparsity.
    pub x_ss: f64,
    /// Model width multiplier.
    pub scale: f64,
    /// Requests per batch (0 when not batched).
    pub batch: u64,
    /// Worker threads (0 = auto / not applicable).
    pub threads: u64,
    /// Metric name → value.
    pub values: BTreeMap<String, f64>,
}

impl MetricRecord {
    /// Empty record with an id.
    pub fn new(id: &str) -> Self {
        MetricRecord {
            id: id.to_string(),
            model: String::new(),
            design: String::new(),
            x_us: 0.0,
            x_ss: 0.0,
            scale: 0.0,
            batch: 0,
            threads: 0,
            values: BTreeMap::new(),
        }
    }

    /// Builder: set the workload context.
    #[allow(clippy::too_many_arguments)]
    pub fn context(
        mut self,
        model: &str,
        design: &str,
        x_us: f64,
        x_ss: f64,
        scale: f64,
        batch: u64,
        threads: u64,
    ) -> Self {
        self.model = model.to_string();
        self.design = design.to_string();
        self.x_us = x_us;
        self.x_ss = x_ss;
        self.scale = scale;
        self.batch = batch;
        self.threads = threads;
        self
    }

    /// Builder: add a metric value.
    pub fn with_value(mut self, name: &str, v: f64) -> Self {
        self.set(name, v);
        self
    }

    /// Add or overwrite a metric value.
    pub fn set(&mut self, name: &str, v: f64) {
        self.values.insert(name.to_string(), v);
    }

    /// Read a metric value.
    pub fn get(&self, name: &str) -> Option<f64> {
        self.values.get(name).copied()
    }

    /// Serialize to a JSON value.
    pub fn to_value(&self) -> Value {
        let values = Value::Obj(
            self.values.iter().map(|(k, v)| (k.clone(), Value::Num(*v))).collect(),
        );
        Value::obj(vec![
            ("id", Value::Str(self.id.clone())),
            ("model", Value::Str(self.model.clone())),
            ("design", Value::Str(self.design.clone())),
            ("x_us", Value::Num(self.x_us)),
            ("x_ss", Value::Num(self.x_ss)),
            ("scale", Value::Num(self.scale)),
            ("batch", Value::Num(self.batch as f64)),
            ("threads", Value::Num(self.threads as f64)),
            ("values", values),
        ])
    }

    /// Deserialize from a JSON value. Context fields other than `id`
    /// default when absent, so hand-trimmed baselines stay loadable.
    pub fn from_value(v: &Value) -> Result<Self> {
        let id = v.get("id")?.as_str()?.to_string();
        let mut rec = MetricRecord::new(&id);
        if let Some(m) = v.get_opt("model") {
            rec.model = m.as_str()?.to_string();
        }
        if let Some(d) = v.get_opt("design") {
            rec.design = d.as_str()?.to_string();
        }
        if let Some(x) = v.get_opt("x_us") {
            rec.x_us = x.as_f64()?;
        }
        if let Some(x) = v.get_opt("x_ss") {
            rec.x_ss = x.as_f64()?;
        }
        if let Some(x) = v.get_opt("scale") {
            rec.scale = x.as_f64()?;
        }
        if let Some(x) = v.get_opt("batch") {
            rec.batch = x.as_i64()?.max(0) as u64;
        }
        if let Some(x) = v.get_opt("threads") {
            rec.threads = x.as_i64()?.max(0) as u64;
        }
        match v.get_opt("values") {
            Some(Value::Obj(m)) => {
                for (k, val) in m {
                    rec.values.insert(k.clone(), val.as_f64()?);
                }
            }
            Some(other) => {
                return Err(Error::Config(format!(
                    "record '{id}': 'values' must be an object, got {other:?}"
                )));
            }
            None => {}
        }
        Ok(rec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_exact_beats_prefix() {
        // "total_cycles" must hit the exact entry, not the "cycles" prefix
        // (which would only match names *starting with* "cycles" anyway).
        let s = spec_for("total_cycles");
        assert_eq!(s.name, "total_cycles");
        assert!(s.gate);
        let s = spec_for("cycles_full_loop");
        assert_eq!(s.name, "cycles");
        assert!(s.prefix);
    }

    #[test]
    fn registry_wall_and_host_are_ungated() {
        assert!(!spec_for("wall_mean_ms").gate);
        assert!(!spec_for("host_inf_s").gate);
        assert_eq!(spec_for("host_inf_s").better, Direction::HigherIsBetter);
    }

    #[test]
    fn registry_serving_counters_override_host_prefix_direction() {
        // Exact serving entries beat the higher-is-better host_ prefix:
        // fewer sheds and shallower queues are improvements.
        for name in [
            "host_shed_total",
            "host_queue_depth_max",
            "host_failed",
            "host_integrity_fail",
            "host_degraded_total",
            "host_batcher_restarts",
            "host_retry_total",
            "host_gave_up",
            "host_fleet_failed",
            "host_fleet_shed",
            "host_fleet_failovers",
            "host_fleet_rebalances",
            "host_fleet_crashes",
            "host_fleet_deadline_misses",
        ] {
            let s = spec_for(name);
            assert_eq!(s.name, name, "{name} must hit its exact entry");
            assert_eq!(s.better, Direction::LowerIsBetter, "{name}");
            assert!(!s.gate, "{name} is wall-clock-driven, never gated");
        }
        let s = spec_for("host_batch_mean");
        assert_eq!(s.better, Direction::HigherIsBetter);
        assert!(!s.gate);
        // Serving wall percentiles ride the wall_ prefix.
        assert!(!spec_for("wall_p999_ms").gate);
        assert_eq!(spec_for("wall_p999_ms").better, Direction::LowerIsBetter);
    }

    #[test]
    fn registry_unknown_falls_back_ungated() {
        let s = spec_for("completely_new_metric");
        assert!(!s.gate);
    }

    #[test]
    fn record_json_roundtrip() {
        let rec = MetricRecord::new("e2e/dscnn/CSA/t1")
            .context("dscnn", "CSA", 0.5, 0.3, 0.1, 8, 1)
            .with_value("total_cycles", 123456.0)
            .with_value("p50_ms", 1.25)
            .with_value("host_inf_s", 42.5);
        let json = rec.to_value().to_json();
        let back = MetricRecord::from_value(&Value::parse(&json).unwrap()).unwrap();
        assert_eq!(back, rec);
        assert_eq!(back.get("total_cycles"), Some(123456.0));
    }

    #[test]
    fn record_from_minimal_json() {
        let v = Value::parse(r#"{"id":"x","values":{"speedup_csa":4.9}}"#).unwrap();
        let rec = MetricRecord::from_value(&v).unwrap();
        assert_eq!(rec.id, "x");
        assert_eq!(rec.model, "");
        assert_eq!(rec.get("speedup_csa"), Some(4.9));
    }

    #[test]
    fn record_rejects_bad_values_shape() {
        let v = Value::parse(r#"{"id":"x","values":[1,2]}"#).unwrap();
        assert!(MetricRecord::from_value(&v).is_err());
        let v = Value::parse(r#"{"values":{}}"#).unwrap();
        assert!(MetricRecord::from_value(&v).is_err());
    }
}
