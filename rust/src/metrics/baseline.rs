//! The [`BaselineStore`]: a versioned collection of [`MetricRecord`]s
//! persisted as `BENCH_*.json` at the repo root.
//!
//! Stores are committed to git and diffed across commits, so
//! serialization is deterministic (sorted keys, stable float formatting
//! via [`crate::config::value::Value`]) and pretty-printed for reviewable
//! diffs. A store with no records is a *bootstrap* placeholder: checking
//! against it seeds it from the fresh run instead of failing, so the
//! first release run on a machine with the toolchain establishes the
//! baseline (see `DESIGN.md`, "Perf telemetry").

use super::record::MetricRecord;
use crate::config::value::Value;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Current on-disk schema version.
pub const SCHEMA_VERSION: i64 = 1;

/// A persistent, diffable set of metric records keyed by record id.
#[derive(Debug, Clone, PartialEq)]
pub struct BaselineStore {
    /// Schema version (bumped on incompatible layout changes).
    pub schema: i64,
    /// Free-form provenance note — conventionally the regeneration
    /// command, e.g. `cargo run --release -- bench-e2e --json BENCH_e2e.json`.
    pub note: String,
    /// Records keyed by [`MetricRecord::id`].
    pub records: BTreeMap<String, MetricRecord>,
}

impl BaselineStore {
    /// Empty store with a provenance note.
    pub fn new(note: &str) -> Self {
        BaselineStore { schema: SCHEMA_VERSION, note: note.to_string(), records: BTreeMap::new() }
    }

    /// Number of records.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when the store holds no records (bootstrap placeholder).
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Insert or replace a record (keyed by its id).
    pub fn insert(&mut self, rec: MetricRecord) {
        self.records.insert(rec.id.clone(), rec);
    }

    /// Upsert a batch of records.
    pub fn merge(&mut self, records: Vec<MetricRecord>) {
        for r in records {
            self.insert(r);
        }
    }

    /// Look up a record by id.
    pub fn get(&self, id: &str) -> Option<&MetricRecord> {
        self.records.get(id)
    }

    /// Build a store holding the given records.
    pub fn from_records(note: &str, records: Vec<MetricRecord>) -> Self {
        let mut s = BaselineStore::new(note);
        s.merge(records);
        s
    }

    /// Serialize to a JSON value.
    pub fn to_value(&self) -> Value {
        let records = Value::Obj(
            self.records.iter().map(|(k, r)| (k.clone(), r.to_value())).collect(),
        );
        Value::obj(vec![
            ("schema", Value::Num(self.schema as f64)),
            ("note", Value::Str(self.note.clone())),
            ("records", records),
        ])
    }

    /// Serialize to pretty-printed JSON (stable ordering, 2-space
    /// indent) — the committed `BENCH_*.json` format.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        write_pretty(&self.to_value(), 0, &mut out);
        out.push('\n');
        out
    }

    /// Parse a store from JSON text.
    pub fn from_json(src: &str) -> Result<Self> {
        let v = Value::parse(src)?;
        let schema = v.get("schema")?.as_i64()?;
        if schema > SCHEMA_VERSION {
            return Err(Error::Config(format!(
                "baseline schema {schema} is newer than supported {SCHEMA_VERSION}"
            )));
        }
        let note = match v.get_opt("note") {
            Some(n) => n.as_str()?.to_string(),
            None => String::new(),
        };
        let mut store = BaselineStore { schema, note, records: BTreeMap::new() };
        match v.get_opt("records") {
            Some(Value::Obj(m)) => {
                for (key, rv) in m {
                    let rec = MetricRecord::from_value(rv)?;
                    if rec.id != *key {
                        return Err(Error::Config(format!(
                            "baseline record key '{key}' disagrees with record id '{}'",
                            rec.id
                        )));
                    }
                    store.records.insert(key.clone(), rec);
                }
            }
            Some(other) => {
                return Err(Error::Config(format!(
                    "baseline 'records' must be an object, got {other:?}"
                )));
            }
            None => {}
        }
        Ok(store)
    }

    /// Load a store from a file.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let src = std::fs::read_to_string(path).map_err(|e| {
            Error::Config(format!("cannot read baseline '{}': {e}", path.display()))
        })?;
        Self::from_json(&src)
            .map_err(|e| Error::Config(format!("baseline '{}': {e}", path.display())))
    }

    /// Write the store to a file (creating parent directories).
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json())?;
        Ok(())
    }

    /// Load the store at `path` (or start a new one with `note`), upsert
    /// `records`, and save it back. Used by the bench binaries to fold
    /// their series into a shared `BENCH_figs.json`.
    pub fn upsert_file(
        path: impl AsRef<Path>,
        note: &str,
        records: Vec<MetricRecord>,
    ) -> Result<Self> {
        let path = path.as_ref();
        let mut store = if path.exists() {
            Self::load(path)?
        } else {
            BaselineStore::new(note)
        };
        store.merge(records);
        store.save(path)?;
        Ok(store)
    }
}

/// Recursive pretty printer over [`Value`] (2-space indent). Scalars use
/// the same formatting as the compact serializer, so pretty and compact
/// forms parse to identical values.
fn write_pretty(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Arr(xs) if !xs.is_empty() => {
            out.push_str("[\n");
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&"  ".repeat(indent + 1));
                write_pretty(x, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push(']');
        }
        Value::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&"  ".repeat(indent + 1));
                out.push_str(&Value::Str(k.clone()).to_json());
                out.push_str(": ");
                write_pretty(val, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push('}');
        }
        other => out.push_str(&other.to_json()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: &str, cycles: f64) -> MetricRecord {
        MetricRecord::new(id)
            .context("dscnn", "CSA", 0.5, 0.3, 0.1, 8, 1)
            .with_value("total_cycles", cycles)
    }

    #[test]
    fn store_json_roundtrip() {
        let store = BaselineStore::from_records(
            "regen: cargo run --release -- bench-e2e --json BENCH_e2e.json",
            vec![rec("a", 100.0), rec("b", 200.0)],
        );
        let json = store.to_json();
        let back = BaselineStore::from_json(&json).unwrap();
        assert_eq!(back, store);
        assert_eq!(back.get("b").unwrap().get("total_cycles"), Some(200.0));
    }

    #[test]
    fn pretty_output_is_indented_and_parseable() {
        let store = BaselineStore::from_records("n", vec![rec("a", 1.0)]);
        let json = store.to_json();
        assert!(json.contains("\n  \"records\""), "{json}");
        assert!(json.ends_with('\n'));
        assert_eq!(Value::parse(&json).unwrap(), store.to_value());
    }

    #[test]
    fn empty_store_is_bootstrap() {
        let store = BaselineStore::new("seed me");
        assert!(store.is_empty());
        let back = BaselineStore::from_json(&store.to_json()).unwrap();
        assert!(back.is_empty());
        assert_eq!(back.note, "seed me");
    }

    #[test]
    fn insert_upserts_by_id() {
        let mut store = BaselineStore::new("");
        store.insert(rec("a", 1.0));
        store.insert(rec("a", 2.0));
        assert_eq!(store.len(), 1);
        assert_eq!(store.get("a").unwrap().get("total_cycles"), Some(2.0));
    }

    #[test]
    fn newer_schema_rejected() {
        let json = r#"{"schema": 999, "note": "", "records": {}}"#;
        assert!(BaselineStore::from_json(json).is_err());
    }

    #[test]
    fn mismatched_record_key_rejected() {
        let json = r#"{"schema":1,"records":{"a":{"id":"b","values":{}}}}"#;
        assert!(BaselineStore::from_json(json).is_err());
    }

    #[test]
    fn file_roundtrip_and_upsert() {
        let dir = std::env::temp_dir().join(format!("srv-metrics-{}", std::process::id()));
        let path = dir.join("store.json");
        let store = BaselineStore::from_records("n", vec![rec("a", 1.0)]);
        store.save(&path).unwrap();
        let back = BaselineStore::load(&path).unwrap();
        assert_eq!(back, store);
        let merged =
            BaselineStore::upsert_file(&path, "n", vec![rec("a", 5.0), rec("c", 3.0)]).unwrap();
        assert_eq!(merged.len(), 2);
        assert_eq!(merged.get("a").unwrap().get("total_cycles"), Some(5.0));
        let reloaded = BaselineStore::load(&path).unwrap();
        assert_eq!(reloaded, merged);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_missing_file_errors_with_path() {
        let e = BaselineStore::load("/nonexistent/store.json").unwrap_err();
        assert!(e.to_string().contains("nonexistent"), "{e}");
    }
}
