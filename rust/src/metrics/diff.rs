//! Baseline diff engine: compare a fresh run against a committed
//! [`BaselineStore`] with per-metric tolerances.
//!
//! Only metrics the registry marks `gate` (deterministic simulator
//! counters, figure speedups, resource estimates) can fail the verdict;
//! wall-clock metrics are reported but informational. A *regression* is
//! a change in the metric's worse direction that exceeds both the
//! absolute floor and the relative tolerance — improvements beyond
//! tolerance are surfaced (so stale baselines get refreshed) but pass.

use super::baseline::BaselineStore;
use super::record::{spec_for, Direction};
use crate::analysis::report::{fmt_compact, Table};
use crate::config::value::Value;

/// Outcome of comparing one metric of one record.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Bit-identical to the baseline.
    Unchanged,
    /// Changed, but within tolerance.
    WithinTol,
    /// Better than the baseline beyond tolerance.
    Improved,
    /// Worse than the baseline beyond tolerance (fails if gated).
    Regressed,
}

impl Status {
    /// Short label for tables / JSON.
    pub fn label(&self) -> &'static str {
        match self {
            Status::Unchanged => "=",
            Status::WithinTol => "~",
            Status::Improved => "improved",
            Status::Regressed => "REGRESSED",
        }
    }
}

/// One compared metric.
#[derive(Debug, Clone)]
pub struct MetricDelta {
    /// Record id the metric belongs to.
    pub id: String,
    /// Metric name.
    pub metric: String,
    /// Baseline value.
    pub old: f64,
    /// Fresh value.
    pub new: f64,
    /// Signed relative change `(new - old) / |old|` (0 when old is 0).
    pub rel_change: f64,
    /// Whether the metric gates the verdict.
    pub gated: bool,
    /// Comparison outcome.
    pub status: Status,
}

/// Tolerance scaling for a diff run.
#[derive(Debug, Clone, Copy)]
pub struct Tolerances {
    /// Multiplier applied to every registry tolerance (CLI `--tol-scale`;
    /// 0 makes every gated metric exact-match).
    pub scale: f64,
}

impl Default for Tolerances {
    fn default() -> Self {
        Tolerances { scale: 1.0 }
    }
}

/// Full result of diffing two stores.
#[derive(Debug, Clone)]
pub struct DiffReport {
    /// Per-metric comparisons for records present in both stores.
    pub deltas: Vec<MetricDelta>,
    /// Record ids only in the fresh store (not gating: new coverage).
    pub new_records: Vec<String>,
    /// Record ids only in the baseline (gating: lost coverage).
    pub missing_records: Vec<String>,
    /// Metric names present in the baseline record but not the fresh one.
    pub missing_metrics: Vec<(String, String)>,
}

impl DiffReport {
    /// Gated regressions (what fails the verdict), plus lost coverage.
    pub fn failures(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .deltas
            .iter()
            .filter(|d| d.gated && d.status == Status::Regressed)
            .map(|d| {
                format!(
                    "{} :: {} regressed {:+.2}% ({} -> {})",
                    d.id,
                    d.metric,
                    d.rel_change * 100.0,
                    fmt_compact(d.old),
                    fmt_compact(d.new)
                )
            })
            .collect();
        for id in &self.missing_records {
            out.push(format!("{id} :: record missing from the fresh run"));
        }
        for (id, m) in &self.missing_metrics {
            let gated = spec_for(m).gate;
            if gated {
                out.push(format!("{id} :: gated metric '{m}' missing from the fresh run"));
            }
        }
        out
    }

    /// True when no gated metric regressed and no baseline coverage was
    /// lost.
    pub fn passed(&self) -> bool {
        self.failures().is_empty()
    }

    /// Count of metrics compared.
    pub fn compared(&self) -> usize {
        self.deltas.len()
    }

    /// Human-readable table: changed metrics first, identical ones
    /// summarized in the footer.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "metrics diff (baseline -> fresh)",
            &["record", "metric", "baseline", "fresh", "change", "gate", "status"],
        );
        let mut unchanged = 0usize;
        for d in &self.deltas {
            if d.status == Status::Unchanged {
                unchanged += 1;
                continue;
            }
            // Ungated metrics can't fail the verdict; soften their labels
            // so wall-clock jitter doesn't read like a CI failure.
            let status = match (d.gated, d.status) {
                (false, Status::Regressed) => "worse (info)".to_string(),
                (false, Status::Improved) => "better (info)".to_string(),
                _ => d.status.label().to_string(),
            };
            t.row(&[
                d.id.clone(),
                d.metric.clone(),
                fmt_compact(d.old),
                fmt_compact(d.new),
                format!("{:+.2}%", d.rel_change * 100.0),
                if d.gated { "yes" } else { "info" }.to_string(),
                status,
            ]);
        }
        let mut out = if t.is_empty() {
            format!("metrics diff: no changed metrics ({unchanged} identical)\n")
        } else {
            t.render()
        };
        if !t.is_empty() {
            out.push_str(&format!("({unchanged} metrics identical, not shown)\n"));
        }
        for id in &self.new_records {
            out.push_str(&format!("new record (not in baseline): {id}\n"));
        }
        for id in &self.missing_records {
            out.push_str(&format!("MISSING record (in baseline, not in run): {id}\n"));
        }
        for (id, m) in &self.missing_metrics {
            out.push_str(&format!("missing metric: {id} :: {m}\n"));
        }
        let verdict = if self.passed() { "PASS" } else { "FAIL" };
        let regressed =
            self.deltas.iter().filter(|d| d.gated && d.status == Status::Regressed).count();
        let lost = self.failures().len() - regressed;
        out.push_str(&format!(
            "verdict: {verdict} ({} compared, {regressed} regressions, {lost} coverage losses)\n",
            self.compared(),
        ));
        out
    }

    /// Machine-readable verdict JSON (for CI annotations / tooling).
    pub fn to_verdict_json(&self) -> String {
        let deltas: Vec<Value> = self
            .deltas
            .iter()
            .filter(|d| d.status != Status::Unchanged)
            .map(|d| {
                Value::obj(vec![
                    ("id", Value::Str(d.id.clone())),
                    ("metric", Value::Str(d.metric.clone())),
                    ("old", Value::Num(d.old)),
                    ("new", Value::Num(d.new)),
                    ("rel_change", Value::Num(d.rel_change)),
                    ("gated", Value::Bool(d.gated)),
                    ("status", Value::Str(d.status.label().to_string())),
                ])
            })
            .collect();
        Value::obj(vec![
            ("passed", Value::Bool(self.passed())),
            ("compared", Value::Num(self.compared() as f64)),
            (
                "failures",
                Value::Arr(self.failures().into_iter().map(Value::Str).collect()),
            ),
            ("changed", Value::Arr(deltas)),
            (
                "new_records",
                Value::Arr(self.new_records.iter().cloned().map(Value::Str).collect()),
            ),
        ])
        .to_json()
    }
}

/// Compare one metric value against its baseline under the registry
/// spec scaled by `tol`.
pub fn compare_metric(name: &str, old: f64, new: f64, tol: &Tolerances) -> (Status, bool) {
    let spec = spec_for(name);
    if new == old {
        return (Status::Unchanged, spec.gate);
    }
    // Positive `worse` means the change moved in the metric's bad
    // direction.
    let worse = match spec.better {
        Direction::LowerIsBetter => new - old,
        Direction::HigherIsBetter => old - new,
    };
    let rel = if old.abs() > f64::EPSILON { worse.abs() / old.abs() } else { f64::INFINITY };
    // Both tolerance terms scale, so `--tol-scale 0` really is an exact
    // match for gated metrics (the absolute floor shrinks with it).
    let beyond = worse.abs() > spec.abs_floor * tol.scale && rel > spec.rel_tol * tol.scale;
    let status = match (worse > 0.0, beyond) {
        (_, false) => Status::WithinTol,
        (true, true) => Status::Regressed,
        (false, true) => Status::Improved,
    };
    (status, spec.gate)
}

/// Diff a fresh store against a baseline.
pub fn diff(baseline: &BaselineStore, fresh: &BaselineStore, tol: &Tolerances) -> DiffReport {
    let mut report = DiffReport {
        deltas: Vec::new(),
        new_records: Vec::new(),
        missing_records: Vec::new(),
        missing_metrics: Vec::new(),
    };
    for (id, old_rec) in &baseline.records {
        let Some(new_rec) = fresh.get(id) else {
            report.missing_records.push(id.clone());
            continue;
        };
        for (metric, &old) in &old_rec.values {
            let Some(new) = new_rec.get(metric) else {
                report.missing_metrics.push((id.clone(), metric.clone()));
                continue;
            };
            let (status, gated) = compare_metric(metric, old, new, tol);
            let rel_change = if old.abs() > f64::EPSILON { (new - old) / old.abs() } else { 0.0 };
            report.deltas.push(MetricDelta {
                id: id.clone(),
                metric: metric.clone(),
                old,
                new,
                rel_change,
                gated,
                status,
            });
        }
    }
    for id in fresh.records.keys() {
        if baseline.get(id).is_none() {
            report.new_records.push(id.clone());
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::record::MetricRecord;

    fn store(pairs: &[(&str, &str, f64)]) -> BaselineStore {
        let mut s = BaselineStore::new("t");
        for &(id, metric, v) in pairs {
            let rec = match s.records.remove(id) {
                Some(r) => r.with_value(metric, v),
                None => MetricRecord::new(id).with_value(metric, v),
            };
            s.insert(rec);
        }
        s
    }

    #[test]
    fn exact_equal_is_unchanged_and_passes() {
        let a = store(&[("r", "total_cycles", 1000.0)]);
        let d = diff(&a, &a.clone(), &Tolerances::default());
        assert_eq!(d.deltas.len(), 1);
        assert_eq!(d.deltas[0].status, Status::Unchanged);
        assert!(d.passed());
    }

    #[test]
    fn just_inside_tolerance_passes() {
        // total_cycles: rel_tol 0.02, abs_floor 16. +1.9% on 10_000 is
        // inside; +190 also clears the floor, so the floor isn't the
        // deciding term.
        let a = store(&[("r", "total_cycles", 10_000.0)]);
        let b = store(&[("r", "total_cycles", 10_190.0)]);
        let d = diff(&a, &b, &Tolerances::default());
        assert_eq!(d.deltas[0].status, Status::WithinTol);
        assert!(d.passed());
    }

    #[test]
    fn just_outside_tolerance_fails() {
        // +2.1% on 10_000 cycles: beyond rel_tol 0.02 and abs_floor 16.
        let a = store(&[("r", "total_cycles", 10_000.0)]);
        let b = store(&[("r", "total_cycles", 10_210.0)]);
        let d = diff(&a, &b, &Tolerances::default());
        assert_eq!(d.deltas[0].status, Status::Regressed);
        assert!(!d.passed());
        assert_eq!(d.failures().len(), 1);
        assert!(d.failures()[0].contains("total_cycles"), "{:?}", d.failures());
    }

    #[test]
    fn abs_floor_shields_tiny_counts() {
        // cfu_stalls: rel_tol 0.05, abs_floor 64. 10 -> 20 is +100%
        // relative but only +10 absolute — inside the floor, passes.
        let a = store(&[("r", "cfu_stalls", 10.0)]);
        let b = store(&[("r", "cfu_stalls", 20.0)]);
        let d = diff(&a, &b, &Tolerances::default());
        assert_eq!(d.deltas[0].status, Status::WithinTol);
        assert!(d.passed());
    }

    #[test]
    fn improvement_beyond_tolerance_passes_but_is_flagged() {
        let a = store(&[("r", "total_cycles", 10_000.0)]);
        let b = store(&[("r", "total_cycles", 8_000.0)]);
        let d = diff(&a, &b, &Tolerances::default());
        assert_eq!(d.deltas[0].status, Status::Improved);
        assert!(d.passed());
        assert!(d.render().contains("improved"));
    }

    #[test]
    fn direction_respected_for_higher_is_better() {
        // speedup_*: higher is better — a drop fails, a gain passes.
        let a = store(&[("r", "speedup_csa", 5.0)]);
        let drop = store(&[("r", "speedup_csa", 4.0)]);
        let gain = store(&[("r", "speedup_csa", 6.0)]);
        assert!(!diff(&a, &drop, &Tolerances::default()).passed());
        let d = diff(&a, &gain, &Tolerances::default());
        assert_eq!(d.deltas[0].status, Status::Improved);
        assert!(d.passed());
    }

    #[test]
    fn wall_metrics_never_fail() {
        let a = store(&[("r", "wall_mean_ms", 10.0), ("r", "host_inf_s", 100.0)]);
        let b = store(&[("r", "wall_mean_ms", 500.0), ("r", "host_inf_s", 1.0)]);
        let d = diff(&a, &b, &Tolerances::default());
        assert!(d.passed());
        assert!(d.deltas.iter().all(|x| !x.gated));
    }

    #[test]
    fn missing_record_fails_new_record_passes() {
        let a = store(&[("gone", "total_cycles", 1.0)]);
        let b = store(&[("added", "total_cycles", 1.0)]);
        let d = diff(&a, &b, &Tolerances::default());
        assert_eq!(d.missing_records, vec!["gone".to_string()]);
        assert_eq!(d.new_records, vec!["added".to_string()]);
        assert!(!d.passed());
        let d2 = diff(&BaselineStore::new(""), &b, &Tolerances::default());
        assert!(d2.passed(), "new coverage alone must not fail");
    }

    #[test]
    fn missing_gated_metric_fails_missing_info_metric_passes() {
        let a = store(&[("r", "total_cycles", 1.0), ("r", "wall_mean_ms", 2.0)]);
        let only_wall = store(&[("r", "wall_mean_ms", 2.0)]);
        let d = diff(&a, &only_wall, &Tolerances::default());
        assert!(!d.passed());
        let only_cycles = store(&[("r", "total_cycles", 1.0)]);
        let d = diff(&a, &only_cycles, &Tolerances::default());
        assert!(d.passed(), "losing an info metric must not fail");
    }

    #[test]
    fn tol_scale_zero_makes_gated_exact() {
        let a = store(&[("r", "total_cycles", 10_000.0)]);
        let b = store(&[("r", "total_cycles", 10_017.0)]);
        assert!(diff(&a, &b, &Tolerances::default()).passed());
        // scale 0: any delta beyond the absolute floor regresses.
        assert!(!diff(&a, &b, &Tolerances { scale: 0.0 }).passed());
    }

    #[test]
    fn verdict_json_parses_and_reports_failure() {
        let a = store(&[("r", "total_cycles", 10_000.0)]);
        let b = store(&[("r", "total_cycles", 20_000.0)]);
        let d = diff(&a, &b, &Tolerances::default());
        let v = Value::parse(&d.to_verdict_json()).unwrap();
        assert!(!v.get("passed").unwrap().as_bool().unwrap());
        assert_eq!(v.get("failures").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn render_mentions_regression_and_verdict() {
        let a = store(&[("r", "total_cycles", 10_000.0)]);
        let b = store(&[("r", "total_cycles", 20_000.0)]);
        let out = diff(&a, &b, &Tolerances::default()).render();
        assert!(out.contains("REGRESSED"), "{out}");
        assert!(out.contains("verdict: FAIL"), "{out}");
        let clean = diff(&a, &a.clone(), &Tolerances::default()).render();
        assert!(clean.contains("verdict: PASS"), "{clean}");
    }
}
