//! Structured performance telemetry: metric records, the committed
//! baseline store, and the regression diff engine.
//!
//! This is the load-bearing reporting layer for every perf-sensitive
//! path in the repo:
//!
//! - [`record::MetricRecord`] — one measured configuration (model,
//!   design, sparsity point, batch/threads) with named metric values;
//!   the registry ([`record::METRIC_SPECS`]) classifies each metric as
//!   deterministic-and-gated (simulated cycles, CFU stalls, bytes,
//!   p50/p99 simulated latency, figure speedups) or informational
//!   wall-clock (`wall_*`, `host_*`);
//! - [`baseline::BaselineStore`] — reads/writes `BENCH_e2e.json` /
//!   `BENCH_figs.json` at the repo root (pretty, deterministic JSON so
//!   committed baselines diff cleanly);
//! - [`diff`] — compares a fresh run against the committed baseline
//!   with per-metric tolerances and produces a human table plus a
//!   machine verdict (`sparse-riscv metrics diff`, `bench-e2e --check`).
//!
//! Bench binaries fold their series into a store via
//! [`sink_records_env`]: set `BENCH_JSON=BENCH_figs.json` and run
//! `cargo bench` to (re)generate the figure baselines deliberately.

pub mod baseline;
pub mod diff;
pub mod record;

pub use baseline::{BaselineStore, SCHEMA_VERSION};
pub use diff::{diff, DiffReport, MetricDelta, Status, Tolerances};
pub use record::{spec_for, Direction, MetricRecord, MetricSpec, METRIC_SPECS};

use crate::error::Result;

/// Environment variable naming the store the bench binaries write into.
pub const BENCH_JSON_ENV: &str = "BENCH_JSON";

/// Upsert `records` into the store named by the `BENCH_JSON` environment
/// variable, if set. Returns the path written, or `None` when the
/// variable is unset (print-only run). Used at the end of every
/// `benches/*.rs` target so one `BENCH_JSON=BENCH_figs.json cargo bench`
/// sweep regenerates the committed figure baseline.
pub fn sink_records_env(note: &str, records: &[MetricRecord]) -> Result<Option<String>> {
    let Ok(path) = std::env::var(BENCH_JSON_ENV) else {
        return Ok(None);
    };
    if path.is_empty() {
        return Ok(None);
    }
    BaselineStore::upsert_file(&path, note, records.to_vec())?;
    Ok(Some(path))
}

/// Convenience for bench mains: sink records and print a one-line
/// confirmation (or nothing when `BENCH_JSON` is unset). Panics on I/O
/// failure — bench binaries have no error channel beyond exit status.
pub fn sink_and_report(note: &str, records: &[MetricRecord]) {
    match sink_records_env(note, records) {
        Ok(Some(path)) => {
            println!("metrics: wrote {} record(s) into {path}", records.len());
        }
        Ok(None) => {}
        Err(e) => panic!("metrics sink failed: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_is_noop_without_env() {
        // The test harness does not set BENCH_JSON; guard against a
        // polluted environment before asserting the no-op.
        if std::env::var(BENCH_JSON_ENV).is_ok() {
            return;
        }
        let recs = vec![MetricRecord::new("x").with_value("total_cycles", 1.0)];
        assert!(sink_records_env("n", &recs).unwrap().is_none());
    }
}
