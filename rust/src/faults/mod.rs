//! Deterministic fault injection for chaos testing the serving stack.
//!
//! Edge deployments of the paper's accelerators face SEU bit flips in
//! weight/configuration memories, transient compute faults, and flaky
//! networks. This module provides a seeded, fully replayable
//! [`FaultPlan`] that the batch engine and network front-end consult at
//! well-defined *fault sites*: packed-weight and schedule-arena bit
//! flips, transient per-lane compute faults, batcher-thread panics,
//! connection-level faults (drop, stall, truncate), and device-level
//! faults for the fleet router (crash, slow device, and persistent
//! corruption storms confined to one device).
//!
//! ## Determinism contract
//!
//! The decision for the *n*-th event at a site is a pure function of
//! `(plan seed, site tag, n)` — each site keeps its own atomic event
//! counter and derives a fresh [`Pcg32`] stream per event, so a replay
//! with the same seed and the same per-site event counts injects the
//! identical fault schedule **regardless of thread interleaving**. The
//! same PRNG discipline as [`crate::coordinator::loadgen`]'s seeded
//! traces.
//!
//! ## Zero-cost when disabled
//!
//! Every site whose rate is `0.0` short-circuits before touching any
//! counter or PRNG state, and the plan itself is threaded through the
//! stack as `Option<Arc<FaultPlan>>` defaulting to `None` — with no plan
//! (or a zero-rate plan) the serving path is bit-identical to a build
//! without this module, which is what lets the differential and
//! `serve_net` tiers run unchanged.

use crate::util::Pcg32;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

/// Number of distinct fault sites (length of [`FaultSite::ALL`]).
const SITES: usize = 10;

/// A place in the serving stack where the plan may inject a fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultSite {
    /// Flip one bit of a packed weight word in a cached prepared model.
    WeightFlip,
    /// Flip one bit of a `ScheduleArena` visited entry in a cached model.
    ArenaFlip,
    /// Transient compute fault: one request's lane outputs are perturbed
    /// (detected by redundant re-execution in the batch engine).
    LaneTransient,
    /// Panic the batcher thread before it drains a batch.
    BatcherPanic,
    /// Close an inference connection without answering.
    ConnDrop,
    /// Stall an inference response by a bounded random delay.
    ConnStall,
    /// Truncate an inference response mid-body and close.
    ConnTruncate,
    /// Crash one fleet device: it stops answering and its in-flight
    /// requests must fail over to a surviving replica.
    DeviceCrash,
    /// Make one fleet device hang/slow so requests against it miss
    /// their deadline and the router routes around it.
    DeviceSlow,
    /// Persistent-corruption storm confined to one fleet device: every
    /// cached model on the victim keeps taking integrity strikes.
    DeviceCorrupt,
}

impl FaultSite {
    /// Every site, in counter-index order.
    pub const ALL: [FaultSite; SITES] = [
        FaultSite::WeightFlip,
        FaultSite::ArenaFlip,
        FaultSite::LaneTransient,
        FaultSite::BatcherPanic,
        FaultSite::ConnDrop,
        FaultSite::ConnStall,
        FaultSite::ConnTruncate,
        FaultSite::DeviceCrash,
        FaultSite::DeviceSlow,
        FaultSite::DeviceCorrupt,
    ];

    /// Stable human-readable name (used in logs and `/healthz`).
    pub fn name(self) -> &'static str {
        match self {
            FaultSite::WeightFlip => "weight_flip",
            FaultSite::ArenaFlip => "arena_flip",
            FaultSite::LaneTransient => "lane_transient",
            FaultSite::BatcherPanic => "batcher_panic",
            FaultSite::ConnDrop => "conn_drop",
            FaultSite::ConnStall => "conn_stall",
            FaultSite::ConnTruncate => "conn_truncate",
            FaultSite::DeviceCrash => "device_crash",
            FaultSite::DeviceSlow => "device_slow",
            FaultSite::DeviceCorrupt => "device_corrupt",
        }
    }

    fn index(self) -> usize {
        match self {
            FaultSite::WeightFlip => 0,
            FaultSite::ArenaFlip => 1,
            FaultSite::LaneTransient => 2,
            FaultSite::BatcherPanic => 3,
            FaultSite::ConnDrop => 4,
            FaultSite::ConnStall => 5,
            FaultSite::ConnTruncate => 6,
            FaultSite::DeviceCrash => 7,
            FaultSite::DeviceSlow => 8,
            FaultSite::DeviceCorrupt => 9,
        }
    }

    /// Fixed per-site mixing constant so two sites with the same event
    /// index never share a PRNG stream.
    fn tag(self) -> u64 {
        // Arbitrary odd constants; stability matters (replayability of a
        // given seed across builds), not the values themselves.
        const TAGS: [u64; SITES] = [
            0x9E37_79B9_7F4A_7C15,
            0xBF58_476D_1CE4_E5B9,
            0x94D0_49BB_1331_11EB,
            0xD6E8_FEB8_6659_FD93,
            0xA076_1D64_78BD_642F,
            0xE703_7ED1_A0B4_28DB,
            0x8EBC_6AF0_9C88_C6E3,
            0xC2B2_AE3D_27D4_EB4F,
            0x1656_67B1_9E37_79F9,
            0x27D4_EB2F_1656_67C5,
        ];
        TAGS[self.index()]
    }
}

/// Per-site injection probabilities in `[0, 1]`.
///
/// `Default` is all-zero (no faults), so `FaultRates { conn_drop: 0.1,
/// ..Default::default() }` enables exactly one site.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultRates {
    /// Rate for [`FaultSite::WeightFlip`].
    pub weight_flip: f64,
    /// Rate for [`FaultSite::ArenaFlip`].
    pub arena_flip: f64,
    /// Rate for [`FaultSite::LaneTransient`].
    pub lane_transient: f64,
    /// Rate for [`FaultSite::BatcherPanic`].
    pub batcher_panic: f64,
    /// Rate for [`FaultSite::ConnDrop`].
    pub conn_drop: f64,
    /// Rate for [`FaultSite::ConnStall`].
    pub conn_stall: f64,
    /// Rate for [`FaultSite::ConnTruncate`].
    pub conn_truncate: f64,
    /// Rate for [`FaultSite::DeviceCrash`].
    pub device_crash: f64,
    /// Rate for [`FaultSite::DeviceSlow`].
    pub device_slow: f64,
    /// Rate for [`FaultSite::DeviceCorrupt`].
    pub device_corrupt: f64,
}

impl FaultRates {
    /// The rate configured for `site`.
    pub fn rate(&self, site: FaultSite) -> f64 {
        match site {
            FaultSite::WeightFlip => self.weight_flip,
            FaultSite::ArenaFlip => self.arena_flip,
            FaultSite::LaneTransient => self.lane_transient,
            FaultSite::BatcherPanic => self.batcher_panic,
            FaultSite::ConnDrop => self.conn_drop,
            FaultSite::ConnStall => self.conn_stall,
            FaultSite::ConnTruncate => self.conn_truncate,
            FaultSite::DeviceCrash => self.device_crash,
            FaultSite::DeviceSlow => self.device_slow,
            FaultSite::DeviceCorrupt => self.device_corrupt,
        }
    }

    /// True when any site has a positive rate.
    pub fn any(&self) -> bool {
        FaultSite::ALL.iter().any(|&s| self.rate(s) > 0.0)
    }
}

/// A seeded, replayable fault-injection schedule.
///
/// Shared as `Arc<FaultPlan>` between the batch engine and the network
/// front-end so both draw from the same per-site event streams; all
/// methods take `&self` and are thread-safe.
pub struct FaultPlan {
    seed: u64,
    rates: FaultRates,
    events: [AtomicU64; SITES],
    injected: [AtomicU64; SITES],
}

impl FaultPlan {
    /// A plan injecting faults at `rates`, deterministically from `seed`.
    pub fn new(seed: u64, rates: FaultRates) -> Self {
        FaultPlan {
            seed,
            rates,
            events: Default::default(),
            injected: Default::default(),
        }
    }

    /// A plan that never fires (all rates zero).
    pub fn disabled() -> Self {
        FaultPlan::new(0, FaultRates::default())
    }

    /// The plan's seed.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The configured rates.
    pub fn rates(&self) -> FaultRates {
        self.rates
    }

    /// True when any site can ever fire.
    pub fn enabled(&self) -> bool {
        self.rates.any()
    }

    /// Record one event at `site` and decide whether it faults.
    ///
    /// Returns `Some(rng)` when the fault fires; the returned stream is
    /// unique to `(seed, site, event index)` and should be used to draw
    /// the fault's parameters (which bit to flip, how long to stall, …)
    /// so those are replayable too. Zero-rate sites return `None`
    /// without touching any shared state.
    pub fn decide(&self, site: FaultSite) -> Option<Pcg32> {
        let rate = self.rates.rate(site);
        if rate <= 0.0 {
            return None;
        }
        let n = self.events[site.index()].fetch_add(1, Ordering::Relaxed);
        let mut rng = Pcg32::new(self.seed ^ site.tag()).fork(n);
        if rng.bernoulli(rate) {
            self.injected[site.index()].fetch_add(1, Ordering::Relaxed);
            Some(rng)
        } else {
            None
        }
    }

    /// Events recorded at `site` so far (fired or not).
    pub fn events(&self, site: FaultSite) -> u64 {
        self.events[site.index()].load(Ordering::Relaxed)
    }

    /// Faults actually injected at `site` so far.
    pub fn injected(&self, site: FaultSite) -> u64 {
        self.injected[site.index()].load(Ordering::Relaxed)
    }

    /// Total faults injected across all sites.
    pub fn total_injected(&self) -> u64 {
        FaultSite::ALL.iter().map(|&s| self.injected(s)).sum()
    }
}

impl fmt::Debug for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "FaultPlan {{ seed: {}, injected: [", self.seed)?;
        for (i, site) in FaultSite::ALL.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{}: {}", site.name(), self.injected(*site))?;
        }
        write!(f, "] }}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn all_rates(p: f64) -> FaultRates {
        FaultRates {
            weight_flip: p,
            arena_flip: p,
            lane_transient: p,
            batcher_panic: p,
            conn_drop: p,
            conn_stall: p,
            conn_truncate: p,
            device_crash: p,
            device_slow: p,
            device_corrupt: p,
        }
    }

    #[test]
    fn zero_rate_site_never_counts_events() {
        let plan = FaultPlan::disabled();
        assert!(!plan.enabled());
        for site in FaultSite::ALL {
            for _ in 0..100 {
                assert!(plan.decide(site).is_none());
            }
            assert_eq!(plan.events(site), 0, "{}", site.name());
            assert_eq!(plan.injected(site), 0);
        }
        assert_eq!(plan.total_injected(), 0);
    }

    #[test]
    fn rate_one_always_fires_with_unique_parameter_streams() {
        let plan = FaultPlan::new(7, all_rates(1.0));
        assert!(plan.enabled());
        let mut a = plan.decide(FaultSite::ConnDrop).expect("fires");
        let mut b = plan.decide(FaultSite::ConnDrop).expect("fires");
        // Distinct events draw from distinct streams.
        assert_ne!(
            (a.next_u64(), a.next_u64()),
            (b.next_u64(), b.next_u64())
        );
        assert_eq!(plan.events(FaultSite::ConnDrop), 2);
        assert_eq!(plan.injected(FaultSite::ConnDrop), 2);
    }

    #[test]
    fn same_seed_replays_the_identical_schedule() {
        let a = FaultPlan::new(0xC0FFEE, all_rates(0.3));
        let b = FaultPlan::new(0xC0FFEE, all_rates(0.3));
        for site in FaultSite::ALL {
            let da: Vec<bool> = (0..256).map(|_| a.decide(site).is_some()).collect();
            let db: Vec<bool> = (0..256).map(|_| b.decide(site).is_some()).collect();
            assert_eq!(da, db, "site {}", site.name());
            assert!(da.iter().any(|&x| x), "rate 0.3 fired never at {}", site.name());
            assert!(da.iter().any(|&x| !x), "rate 0.3 fired always at {}", site.name());
        }
        assert_eq!(a.total_injected(), b.total_injected());
    }

    #[test]
    fn different_sites_use_independent_streams() {
        let plan = FaultPlan::new(42, all_rates(0.5));
        let d1: Vec<bool> =
            (0..128).map(|_| plan.decide(FaultSite::WeightFlip).is_some()).collect();
        let d2: Vec<bool> =
            (0..128).map(|_| plan.decide(FaultSite::ArenaFlip).is_some()).collect();
        assert_ne!(d1, d2, "site tags must decorrelate the schedules");
    }

    #[test]
    fn injected_count_is_interleaving_independent() {
        // The set of firing event indices is fixed by (seed, site), so
        // however threads interleave, N total events inject the same
        // number of faults a single thread would.
        let site = FaultSite::BatcherPanic;
        let rates = FaultRates { batcher_panic: 0.4, ..Default::default() };
        let solo = FaultPlan::new(99, rates);
        for _ in 0..400 {
            solo.decide(site);
        }
        let expected = solo.injected(site);

        let shared = Arc::new(FaultPlan::new(99, rates));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let p = Arc::clone(&shared);
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        p.decide(site);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(shared.events(site), 400);
        assert_eq!(shared.injected(site), expected);
    }

    #[test]
    fn debug_render_names_sites() {
        let plan = FaultPlan::new(1, all_rates(1.0));
        plan.decide(FaultSite::ConnStall);
        let s = format!("{plan:?}");
        assert!(s.contains("seed: 1"), "{s}");
        assert!(s.contains("conn_stall: 1"), "{s}");
    }
}
