//! Structural LUT/FF/BRAM/DSP estimator for the CFU designs.

use crate::isa::DesignKind;

/// Resource vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResourceUsage {
    /// Look-up tables.
    pub luts: u32,
    /// Slice flip-flops.
    pub ffs: u32,
    /// Block RAMs.
    pub brams: u32,
    /// DSP slices.
    pub dsps: u32,
}

impl ResourceUsage {
    /// Elementwise add.
    pub fn add(&self, other: &ResourceUsage) -> ResourceUsage {
        ResourceUsage {
            luts: self.luts + other.luts,
            ffs: self.ffs + other.ffs,
            brams: self.brams + other.brams,
            dsps: self.dsps + other.dsps,
        }
    }

    /// Scale by a count.
    pub fn times(&self, n: u32) -> ResourceUsage {
        ResourceUsage {
            luts: self.luts * n,
            ffs: self.ffs * n,
            brams: self.brams * n,
            dsps: self.dsps * n,
        }
    }
}

/// Baseline VexRiscv + LiteX SoC (w/o CFU) on the XC7A35T, per Table III
/// (average of the three reported builds).
pub const BASELINE_SOC: ResourceUsage =
    ResourceUsage { luts: 2471, ffs: 1474, brams: 9, dsps: 4 };

/// RTL components with 7-series mapping costs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Component {
    /// 8-bit zero comparator (NOR reduction).
    ZeroComparator8,
    /// One 8-bit 4:1 alignment mux (per output lane of Fig 7).
    AlignMux8x4,
    /// 8×8 signed multiplier (maps to one DSP48).
    Mult8x8Dsp,
    /// 32-bit accumulator register + adder.
    Accumulator32,
    /// Sequential-MAC control FSM (variable-cycle scheduling).
    SeqMacFsm,
    /// Case-signal control logic (Fig 7 control block).
    CaseControl,
    /// Skip-bit extraction + 7-bit increment adder + shifter (Fig 4).
    LookaheadInc,
    /// 7-bit weight extraction (shift/sign-extend network).
    WeightDecode7,
    /// 32-bit operand/result handshake registers (CPU–CFU interface).
    OperandRegs,
    /// SIMD adder tree for 4 parallel products.
    AdderTree4,
}

impl Component {
    /// Per-component cost (LUTs, FFs, DSPs).
    pub fn cost(&self) -> ResourceUsage {
        let (luts, ffs, dsps) = match self {
            Component::ZeroComparator8 => (3, 0, 0),
            Component::AlignMux8x4 => (8, 0, 0),
            Component::Mult8x8Dsp => (0, 0, 1),
            Component::Accumulator32 => (8, 32, 0),
            Component::SeqMacFsm => (6, 5, 0),
            Component::CaseControl => (6, 0, 0),
            Component::LookaheadInc => (9, 0, 0),
            Component::WeightDecode7 => (2, 0, 0),
            Component::OperandRegs => (0, 36, 0),
            Component::AdderTree4 => (24, 0, 0),
        };
        ResourceUsage { luts, ffs, brams: 0, dsps }
    }
}

/// Inventory of one design: (component, count) pairs.
pub fn inventory(design: DesignKind) -> Vec<(Component, u32)> {
    match design {
        // 4 parallel multipliers exist in the baseline SoC's CFU already
        // (the TFLite SIMD MAC); Table III reports *increments* over that
        // baseline, so the baseline inventory is empty.
        DesignKind::BaselineSimd => vec![],
        // Sequential baseline: one multiplier time-shared over 4 cycles.
        DesignKind::BaselineSequential => vec![
            (Component::Mult8x8Dsp, 1),
            (Component::Accumulator32, 1),
            (Component::SeqMacFsm, 1),
            (Component::OperandRegs, 1),
        ],
        // USSA (Fig 7): zero comparators, case control, two 4-lane
        // alignment mux sets, sequential MAC.
        DesignKind::Ussa => vec![
            (Component::ZeroComparator8, 4),
            (Component::CaseControl, 1),
            (Component::AlignMux8x4, 2), // weight + input mux banks
            (Component::Mult8x8Dsp, 1),
            (Component::Accumulator32, 1),
            (Component::SeqMacFsm, 1),
            (Component::OperandRegs, 1),
        ],
        // SSSA (Fig 4): lookahead extraction + 4 parallel 7-bit
        // multiplies (one extra DSP beyond the baseline's four — the
        // datapath muxing shares the rest) + adder tree + decode.
        DesignKind::Sssa => vec![
            (Component::LookaheadInc, 1),
            (Component::WeightDecode7, 4),
            (Component::Mult8x8Dsp, 1),
            (Component::AdderTree4, 2),
            (Component::Accumulator32, 1),
            (Component::OperandRegs, 1),
            (Component::CaseControl, 1),
        ],
        // CSA: lookahead path + variable-cycle MAC path combined; two
        // extra DSPs per Table III.
        DesignKind::Csa => vec![
            (Component::LookaheadInc, 1),
            (Component::WeightDecode7, 4),
            (Component::ZeroComparator8, 4),
            (Component::CaseControl, 1),
            (Component::AlignMux8x4, 2),
            (Component::Mult8x8Dsp, 2),
            (Component::Accumulator32, 2),
            (Component::SeqMacFsm, 1),
            (Component::OperandRegs, 1),
        ],
        // NM-SSA: group-occupancy probe (reuses the lookahead increment
        // datapath) + alignment muxes that compact the ≤N survivors of
        // each M-group in front of a shared multiplier.
        DesignKind::NmSsa => vec![
            (Component::LookaheadInc, 1),
            (Component::CaseControl, 1),
            (Component::AlignMux8x4, 2),
            (Component::Mult8x8Dsp, 1),
            (Component::Accumulator32, 1),
            (Component::OperandRegs, 1),
        ],
        // BSR: block-descriptor control + a parallel adder tree over the
        // words of an occupied 8×8 tile column.
        DesignKind::Bsr => vec![
            (Component::CaseControl, 1),
            (Component::AdderTree4, 1),
            (Component::SeqMacFsm, 1),
            (Component::Mult8x8Dsp, 1),
            (Component::Accumulator32, 1),
            (Component::OperandRegs, 1),
        ],
        // BBS: per-bank zero comparators + crossbar muxes feeding K
        // balanced lanes through a shared sequential MAC.
        DesignKind::Bbs => vec![
            (Component::SeqMacFsm, 1),
            (Component::CaseControl, 1),
            (Component::AlignMux8x4, 2),
            (Component::ZeroComparator8, 4),
            (Component::Mult8x8Dsp, 1),
            (Component::Accumulator32, 1),
            (Component::OperandRegs, 1),
        ],
    }
}

/// Estimate the resource increment of a design's CFU over the baseline
/// SoC.
pub fn estimate_cfu(design: DesignKind) -> ResourceUsage {
    inventory(design)
        .into_iter()
        .fold(ResourceUsage::default(), |acc, (c, n)| acc.add(&c.cost().times(n)))
}

/// Paper-published increments (Table III), for side-by-side reporting.
pub fn paper_increment(design: DesignKind) -> Option<ResourceUsage> {
    match design {
        DesignKind::Ussa => Some(ResourceUsage { luts: 34, ffs: 93, brams: 0, dsps: 1 }),
        DesignKind::Sssa => Some(ResourceUsage { luts: 95, ffs: 97, brams: 0, dsps: 1 }),
        DesignKind::Csa => Some(ResourceUsage { luts: 108, ffs: 121, brams: 0, dsps: 2 }),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn increments_close_to_paper() {
        // The structural estimate should land within ~50% of the paper's
        // synthesized increments (synthesis is heuristic; the point is
        // the *order of magnitude*: tens of LUTs, ~100 FFs, 1–2 DSPs).
        for design in [DesignKind::Ussa, DesignKind::Sssa, DesignKind::Csa] {
            let est = estimate_cfu(design);
            let paper = paper_increment(design).unwrap();
            assert_eq!(est.dsps, paper.dsps, "{design}: DSP count must match exactly");
            assert_eq!(est.brams, 0, "{design}: CFUs use no BRAM");
            let lut_ratio = est.luts as f64 / paper.luts as f64;
            assert!((0.3..=2.5).contains(&lut_ratio), "{design}: LUT ratio {lut_ratio}");
            let ff_ratio = est.ffs as f64 / paper.ffs as f64;
            assert!((0.3..=2.5).contains(&ff_ratio), "{design}: FF ratio {ff_ratio}");
        }
    }

    #[test]
    fn csa_costs_more_than_parts() {
        // CSA merges USSA's variable-cycle path with SSSA's lookahead
        // path (it does not need SSSA's parallel adder tree, so LUTs are
        // compared against USSA only — matching Table III's ordering
        // where CSA > USSA and CSA ≈ SSSA + USSA's FF/DSP budget).
        let csa = estimate_cfu(DesignKind::Csa);
        let ussa = estimate_cfu(DesignKind::Ussa);
        let sssa = estimate_cfu(DesignKind::Sssa);
        assert!(csa.luts > ussa.luts);
        assert!(csa.ffs >= ussa.ffs.max(sssa.ffs));
        assert!(csa.dsps >= ussa.dsps.max(sssa.dsps));
    }

    #[test]
    fn increments_are_small_fraction_of_soc() {
        // Paper: "less than 4%" LUT increase (CSA 4.39%).
        for design in [DesignKind::Ussa, DesignKind::Sssa, DesignKind::Csa] {
            let est = estimate_cfu(design);
            let pct = est.luts as f64 / BASELINE_SOC.luts as f64;
            assert!(pct < 0.08, "{design}: {pct}");
        }
    }

    #[test]
    fn format_design_increments_are_modest() {
        // The three format CFUs stay in the same envelope as the paper's
        // designs: one extra DSP, no BRAM, a few dozen LUTs — and they
        // have no Table III row to report against.
        for design in [DesignKind::NmSsa, DesignKind::Bsr, DesignKind::Bbs] {
            let est = estimate_cfu(design);
            assert_eq!(est.dsps, 1, "{design}: one extra DSP");
            assert_eq!(est.brams, 0, "{design}: no BRAM");
            let pct = est.luts as f64 / BASELINE_SOC.luts as f64;
            assert!(pct < 0.04, "{design}: LUT increment {pct}");
            assert!(paper_increment(design).is_none(), "{design}: not in Table III");
        }
    }

    #[test]
    fn usage_arith() {
        let a = ResourceUsage { luts: 1, ffs: 2, brams: 3, dsps: 4 };
        let b = a.times(2).add(&a);
        assert_eq!(b, ResourceUsage { luts: 3, ffs: 6, brams: 9, dsps: 12 });
    }
}
