//! FPGA resource estimation (Table III substitute).
//!
//! Without a synthesis flow (Vivado/SymbiFlow) in the loop, resource
//! usage is *estimated structurally*: each CFU design is decomposed into
//! RTL-level components (comparators, alignment muxes, multipliers,
//! accumulators, FSM state, operand registers) with per-component
//! LUT/FF/DSP costs typical of Xilinx 7-series (XC7A35T) mapping. The
//! bench harness prints the estimate next to the paper's published
//! numbers; deviations are expected (synthesis is heuristic) and
//! documented in EXPERIMENTS.md.

pub mod fpga;

pub use fpga::{estimate_cfu, Component, ResourceUsage, BASELINE_SOC};
