//! Design-space explorer: per-layer hardware/software co-design.
//!
//! The paper evaluates four accelerator designs *uniformly* over each
//! model, but its central claim — co-design — cuts finer: the best
//! design depends on each layer's sparsity structure and weight range.
//! Block-sparse layers favour SSSA's lookahead skipping; layers whose
//! weights need the full INT8 dynamic range cannot use the INT7
//! lookahead designs without clamping (Section III-B), so a lossless
//! deployment must fall back to a baseline there; and every design that
//! an assignment uses costs FPGA resources (Table III). Daghero et al.
//! (PAPERS.md) show per-layer kernel selection is where the real
//! speedup lives — this module automates it:
//!
//! 1. [`profile_graph`] measures the exact (layer × design) cycle
//!    matrix — one uniform simulation per candidate, decomposed from
//!    the simulator's per-layer stats (cycle counts are
//!    activation-independent, so one inference suffices);
//! 2. [`explore`] searches the assignment space. Per-layer costs are
//!    independent, so the per-layer lower bound of a design subset is
//!    *tight* — the `k^L` assignment space collapses to at most
//!    `2^k − 1` subset optima, each found by a per-layer argmin.
//!    Over-budget and layer-infeasible subsets are skipped before their
//!    optimum is computed; subsets whose (cheap, tight) bound is already
//!    dominated by an explored point are dropped before materializing a
//!    frontier point;
//! 3. the result is a Pareto frontier of (total cycles, LUT/FF/DSP
//!    increment) plus the cycle-argmin assignment and the best
//!    *uniform* design for comparison.
//!
//! The chosen [`DesignAssignment`] feeds straight into the
//! heterogeneous execution stack (`SimEngine::for_assignment`,
//! `BatchSpec::assigned`, `serve --assignment`).
//!
//! ```
//! use sparse_riscv::explorer::{explore, profile_graph, ExplorerOptions};
//! use sparse_riscv::models::builder::{apply_sparsity, ModelConfig};
//! use sparse_riscv::models::zoo::build_model;
//!
//! // A toy DSCNN with combined sparsity, explored over all designs.
//! let cfg = ModelConfig { scale: 0.07, ..Default::default() };
//! let mut info = build_model("dscnn", &cfg).unwrap();
//! apply_sparsity(&mut info.graph, 0.5, 0.4);
//! let opts = ExplorerOptions::default();
//! let table = profile_graph(
//!     &info.graph,
//!     &info.input_shape,
//!     &opts.candidates,
//!     &opts.cost_model,
//! )
//! .unwrap();
//! let result = explore(&table, &opts).unwrap();
//! assert!(!result.frontier.is_empty());
//! // The explored optimum is never worse than the best uniform design.
//! assert!(result.best.total_cycles <= result.best_uniform.total_cycles);
//! ```

pub mod cost;
pub mod pareto;

pub use cost::{profile_graph, CostTable, LayerCost};
pub use pareto::{pareto_filter, ParetoPoint};

use crate::analysis::codesign::{assignment_cost, design_cost, designs_cost, within_budget};
use crate::analysis::report::{f2, pct, Table};
use crate::cpu::CostModel;
use crate::error::{Error, Result};
use crate::isa::{DesignAssignment, DesignKind};
use crate::resources::fpga::ResourceUsage;

/// Explorer configuration.
#[derive(Debug, Clone)]
pub struct ExplorerOptions {
    /// Candidate designs (columns of the cost matrix).
    pub candidates: Vec<DesignKind>,
    /// Lossless mode (default): designs that would clamp a layer's
    /// weights to INT7 are infeasible *on that layer*, so the chosen
    /// assignment stays bit-exact against the INT8 reference model.
    pub lossless: bool,
    /// Optional LUT/FF/DSP budget for the combined CFU build.
    pub budget: Option<ResourceUsage>,
    /// CPU cost model used for profiling.
    pub cost_model: CostModel,
}

impl Default for ExplorerOptions {
    fn default() -> Self {
        ExplorerOptions {
            candidates: DesignKind::ALL.to_vec(),
            lossless: true,
            budget: None,
            cost_model: CostModel::vexriscv(),
        }
    }
}

/// Is `design` usable on `layer` under the fidelity constraint? Two
/// ways a design can be lossy on a layer: the INT7 lookahead encodings
/// clamp INT8 weights, and NM-SSA's prepare-time 2:4 enforcement zeroes
/// weights beyond the per-group budget.
fn layer_feasible(layer: &LayerCost, design: DesignKind, lossless: bool) -> bool {
    if !lossless {
        return true;
    }
    !((design.uses_lookahead_encoding() && layer.int8_weights > 0)
        || (design.enforces_structure() && layer.nm_excess > 0))
}

/// Outcome of one exploration.
#[derive(Debug, Clone)]
pub struct Exploration {
    /// The profiled cost matrix the search ran on.
    pub table: CostTable,
    /// Non-dominated (cycles, resources) points, ascending cycles.
    pub frontier: Vec<ParetoPoint>,
    /// Cycle-argmin assignment within the budget.
    pub best: ParetoPoint,
    /// Best feasible *uniform* design within the budget — the paper's
    /// model-wide baseline the heterogeneous assignment is measured
    /// against.
    pub best_uniform: ParetoPoint,
    /// Every feasible uniform design within the budget.
    pub uniforms: Vec<ParetoPoint>,
    /// Design subsets that contributed a candidate point.
    pub subsets_evaluated: usize,
    /// Design subsets discarded: over budget or layer-infeasible
    /// (skipped before their optimum is computed), or bound-dominated
    /// by an already-explored point (dropped before materializing a
    /// frontier point — the argmin pass itself is O(layers × subset)
    /// either way).
    pub subsets_pruned: usize,
}

impl Exploration {
    /// Cycles of the best uniform design over the explored optimum
    /// (≥ 1; > 1 means heterogeneous execution strictly wins).
    pub fn speedup_vs_uniform(&self) -> f64 {
        self.best_uniform.total_cycles as f64 / self.best.total_cycles as f64
    }

    /// Render the per-layer matrix and the frontier as aligned tables.
    pub fn render(&self) -> String {
        let mut headers: Vec<String> =
            vec!["layer".into(), "sparsity".into(), "int8-w".into(), "nm-x".into()];
        headers.extend(self.table.candidates.iter().map(|d| d.name().to_string()));
        headers.push("best".into());
        let header_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("per-layer cycles ({})", self.table.model),
            &header_refs,
        );
        for (l, layer) in self.table.layers.iter().enumerate() {
            let mut row = vec![
                layer.label.clone(),
                pct(layer.sparsity),
                layer.int8_weights.to_string(),
                layer.nm_excess.to_string(),
            ];
            row.extend(layer.cycles.iter().map(|c| c.to_string()));
            row.push(self.best.assignment.design_for(l).name().to_string());
            t.row(&row);
        }
        let mut out = t.render();
        out.push_str(&format!(
            "non-MAC overhead: {} cycles   subsets evaluated {} / pruned {}\n\n",
            self.table.overhead_cycles, self.subsets_evaluated, self.subsets_pruned
        ));

        let mut f = Table::new(
            "Pareto frontier (cycles vs FPGA resource increment)",
            &["assignment", "cycles", "speedup", "LUTs", "FFs", "DSPs"],
        );
        for p in &self.frontier {
            f.row(&[
                p.assignment.label(),
                p.total_cycles.to_string(),
                f2(self.best_uniform.total_cycles as f64 / p.total_cycles as f64),
                p.resources.luts.to_string(),
                p.resources.ffs.to_string(),
                p.resources.dsps.to_string(),
            ]);
        }
        out.push_str(&f.render());
        out.push_str(&format!(
            "best assignment: {} ({} cycles, +{} LUTs, +{} DSPs)\n  spec: {}\n",
            self.best.assignment.label(),
            self.best.total_cycles,
            self.best.resources.luts,
            self.best.resources.dsps,
            self.best.assignment.spec(),
        ));
        out.push_str(&format!(
            "best uniform:    {} ({} cycles) — explored speedup {}x\n",
            self.best_uniform.assignment.label(),
            self.best_uniform.total_cycles,
            f2(self.speedup_vs_uniform()),
        ));
        out
    }
}

/// Resource-cheapness ordering key used for deterministic tie-breaks
/// (prefer the design costing fewer DSPs, then LUTs, then FFs).
fn cheapness(d: DesignKind) -> (u32, u32, u32) {
    let c = design_cost(d);
    (c.dsps, c.luts, c.ffs)
}

/// Search the assignment space of a profiled model.
///
/// Because per-layer costs are independent, each design subset's
/// per-layer lower bound is tight and achieved by the per-layer argmin,
/// so the search is exact with at most `2^candidates − 1` evaluations.
/// Subsets over the budget or with an infeasible layer are skipped
/// before their optimum is computed; subsets whose bound is dominated
/// by an already-explored point are dropped without materializing a
/// point (their argmin re-appears under the smaller subset of designs
/// it actually uses).
pub fn explore(table: &CostTable, opts: &ExplorerOptions) -> Result<Exploration> {
    let k = table.candidates.len();
    if k == 0 || k > 16 {
        return Err(Error::Cli(format!("explorer supports 1..=16 candidate designs, got {k}")));
    }
    let feasible: Vec<Vec<bool>> = table
        .layers
        .iter()
        .map(|layer| {
            table
                .candidates
                .iter()
                .map(|&d| layer_feasible(layer, d, opts.lossless))
                .collect()
        })
        .collect();
    for (l, row) in feasible.iter().enumerate() {
        if !row.iter().any(|&f| f) {
            return Err(Error::Cli(format!(
                "layer '{}' has no feasible candidate design (INT8 weights exclude the \
                 lookahead designs — add a baseline candidate or allow lossy clamping)",
                table.layers[l].label
            )));
        }
    }
    // Candidate indices ordered cheapest-first so per-layer cycle ties
    // resolve to the design costing the least resources.
    let mut order: Vec<usize> = (0..k).collect();
    order.sort_by_key(|&ci| (cheapness(table.candidates[ci]), ci));

    let mut points: Vec<ParetoPoint> = Vec::new();
    let mut uniforms: Vec<ParetoPoint> = Vec::new();
    let mut evaluated = 0usize;
    let mut pruned = 0usize;

    // The subset's exact optimum (its tight per-layer lower bound), or
    // None when some layer has no feasible member.
    let optimum = |members: &[usize]| -> Option<(u64, Vec<usize>)> {
        let mut choice = Vec::with_capacity(table.layers.len());
        let mut bound = table.overhead_cycles;
        for (l, layer) in table.layers.iter().enumerate() {
            let mut best: Option<(u64, usize)> = None;
            for &ci in members {
                if feasible[l][ci] {
                    let c = layer.cycles[ci];
                    let improves = match best {
                        Some((bc, _)) => c < bc,
                        None => true,
                    };
                    if improves {
                        best = Some((c, ci));
                    }
                }
            }
            let (c, ci) = best?;
            bound += c;
            choice.push(ci);
        }
        Some((bound, choice))
    };

    // Uniform pass first: the paper's model-wide baselines, recorded
    // exactly (never bound-pruned) so the explored-vs-uniform speedup is
    // measured against the true best uniform design.
    for &ci in &order {
        let d = table.candidates[ci];
        let cost = design_cost(d);
        if opts.budget.as_ref().is_some_and(|b| !within_budget(&cost, b)) {
            pruned += 1;
            continue;
        }
        if !(0..table.layers.len()).all(|l| feasible[l][ci]) {
            pruned += 1;
            continue;
        }
        let total = table.total_for(&DesignAssignment::Uniform(d))?;
        let point = ParetoPoint {
            assignment: DesignAssignment::Uniform(d),
            total_cycles: total,
            resources: cost,
        };
        evaluated += 1;
        uniforms.push(point.clone());
        points.push(point);
    }
    if uniforms.is_empty() {
        return Err(Error::Cli(
            "no uniform design is feasible within the budget — widen the budget or add a \
             baseline candidate"
                .into(),
        ));
    }

    // Multi-design subsets (≥ 2 members).
    for mask in 1u32..(1u32 << k) {
        if mask.count_ones() < 2 {
            continue;
        }
        let members: Vec<usize> =
            order.iter().copied().filter(|&ci| mask & (1 << ci) != 0).collect();
        let subset: Vec<DesignKind> = members.iter().map(|&ci| table.candidates[ci]).collect();
        let subset_cost = designs_cost(&subset);
        if opts.budget.as_ref().is_some_and(|b| !within_budget(&subset_cost, b)) {
            pruned += 1;
            continue;
        }
        let Some((bound, choice)) = optimum(&members) else {
            pruned += 1;
            continue;
        };
        // Per-layer lower-bound prune: a point at least as fast and no
        // more expensive already exists, so this subset's optimum is
        // dominated (its argmin over fewer designs appears under the
        // smaller subset's own mask).
        if points
            .iter()
            .any(|p| p.total_cycles <= bound && within_budget(&p.resources, &subset_cost))
        {
            pruned += 1;
            continue;
        }
        evaluated += 1;
        let assignment = DesignAssignment::per_layer(
            choice.iter().map(|&ci| table.candidates[ci]).collect(),
        );
        let resources = assignment_cost(&assignment);
        if !points.iter().any(|p| p.assignment == assignment) {
            points.push(ParetoPoint { assignment, total_cycles: bound, resources });
        }
    }

    let min_point = |pts: &[ParetoPoint]| -> ParetoPoint {
        pts.iter()
            .min_by_key(|p| {
                (p.total_cycles, p.resources.dsps, p.resources.luts, p.resources.ffs)
            })
            .expect("non-empty point set")
            .clone()
    };
    let best = min_point(&points);
    let best_uniform = min_point(&uniforms);
    Ok(Exploration {
        table: table.clone(),
        frontier: pareto_filter(&points),
        best,
        best_uniform,
        uniforms,
        subsets_evaluated: evaluated,
        subsets_pruned: pruned,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::{
        apply_sparsity, apply_sparsity_plan, widen_weights_to_int8, ModelConfig,
    };
    use crate::models::zoo::build_model;

    fn profiled(x_us: f64, x_ss: f64) -> CostTable {
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, x_us, x_ss);
        profile_graph(
            &info.graph,
            &info.input_shape,
            &DesignKind::ALL,
            &CostModel::vexriscv(),
        )
        .unwrap()
    }

    #[test]
    fn uniform_int7_model_is_won_by_sssa() {
        // All-INT7 weights: SSSA is feasible everywhere and per-block
        // cost-equal to the SIMD baseline while visiting fewer blocks,
        // so the explored optimum is the uniform SSSA assignment.
        let table = profiled(0.5, 0.4);
        let result = explore(&table, &ExplorerOptions::default()).unwrap();
        assert_eq!(
            result.best.assignment,
            DesignAssignment::Uniform(DesignKind::Sssa)
        );
        assert_eq!(result.best.total_cycles, result.best_uniform.total_cycles);
        assert!((result.speedup_vs_uniform() - 1.0).abs() < 1e-12);
        assert!(result.subsets_pruned > 0, "supersets of the optimum must be bound-pruned");
        // The frontier trades resources for cycles: its cheapest point
        // is the free SIMD baseline, its fastest is SSSA.
        let cheapest = result.frontier.iter().min_by_key(|p| p.resources.luts).unwrap();
        assert_eq!(cheapest.resources.luts, 0);
        assert_eq!(result.frontier[0].total_cycles, result.best.total_cycles);
    }

    #[test]
    fn budget_excludes_expensive_designs() {
        let table = profiled(0.5, 0.4);
        // 0 extra DSPs: only the SIMD baseline fits (every CFU adds ≥1).
        let opts = ExplorerOptions {
            budget: Some(ResourceUsage {
                luts: u32::MAX,
                ffs: u32::MAX,
                brams: u32::MAX,
                dsps: 0,
            }),
            ..Default::default()
        };
        let result = explore(&table, &opts).unwrap();
        assert_eq!(
            result.best.assignment,
            DesignAssignment::Uniform(DesignKind::BaselineSimd)
        );
        assert_eq!(result.frontier.len(), 1);
    }

    #[test]
    fn int8_layers_force_heterogeneous_strict_win() {
        // Mixed per-layer sparsity + INT8 stem/head: lossless mode bars
        // the lookahead designs from the widened layers, so the best
        // uniform design is the SIMD baseline while the explorer mixes
        // SSSA onto the block-sparse INT7 layers — a strict cycle win.
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        let n = info.graph.mac_layers();
        let plan: Vec<(f64, f64)> = (0..n)
            .map(|i| if i == 0 || i == n - 1 { (0.4, 0.0) } else { (0.5, 0.5) })
            .collect();
        apply_sparsity_plan(&mut info.graph, &plan);
        widen_weights_to_int8(&mut info.graph, &[0, n - 1]);
        // Pinned to the five paper designs: the format designs (BBS in
        // particular) are INT8-clean and lossless-feasible on the widened
        // layers, which would change which uniform design wins — the
        // format × lossless interactions are covered by
        // `rust/tests/explorer.rs`.
        let table = profile_graph(
            &info.graph,
            &info.input_shape,
            &DesignKind::ALL[..5],
            &CostModel::vexriscv(),
        )
        .unwrap();
        assert!(table.layers[0].int8_weights > 0);
        let result = explore(&table, &ExplorerOptions::default()).unwrap();
        assert!(!result.best.assignment.is_uniform());
        assert_eq!(
            result.best_uniform.assignment,
            DesignAssignment::Uniform(DesignKind::BaselineSimd)
        );
        assert!(
            result.best.total_cycles < result.best_uniform.total_cycles,
            "hetero {} !< uniform {}",
            result.best.total_cycles,
            result.best_uniform.total_cycles
        );
        assert!(result.speedup_vs_uniform() > 1.0);
        // Widened layers run the free SIMD baseline; at least one sparse
        // INT7 layer runs a lookahead design.
        assert_eq!(result.best.assignment.design_for(0), DesignKind::BaselineSimd);
        assert_eq!(result.best.assignment.design_for(n - 1), DesignKind::BaselineSimd);
        assert!(result
            .best
            .assignment
            .expand(n)
            .iter()
            .any(|d| d.uses_lookahead_encoding()));
        // Lossy mode lifts the constraint and returns to uniform SSSA.
        let lossy = explore(
            &table,
            &ExplorerOptions { lossless: false, ..Default::default() },
        )
        .unwrap();
        assert!(lossy.best.total_cycles <= result.best.total_cycles);
        assert_eq!(lossy.best.assignment, DesignAssignment::Uniform(DesignKind::Sssa));
        // Rendering covers both tables.
        let rendered = result.render();
        assert!(rendered.contains("per-layer cycles"));
        assert!(rendered.contains("Pareto frontier"));
        assert!(rendered.contains("best assignment: hetero:"));
    }

    #[test]
    fn lookahead_only_candidates_fail_cleanly_on_int8_layers() {
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, 0.4, 0.3);
        widen_weights_to_int8(&mut info.graph, &[0]);
        let table = profile_graph(
            &info.graph,
            &info.input_shape,
            &[DesignKind::Sssa, DesignKind::Csa],
            &CostModel::vexriscv(),
        )
        .unwrap();
        let err = explore(&table, &ExplorerOptions::default());
        assert!(err.is_err());
        // Lossy mode accepts the clamping and succeeds.
        let ok = explore(
            &table,
            &ExplorerOptions { lossless: false, ..Default::default() },
        );
        assert!(ok.is_ok());
    }
}
