//! Per-layer cost profiling: one uniform simulation per candidate
//! design yields the full (layer × design) cycle matrix.
//!
//! Cycle counts in this simulator are pure functions of the prepared
//! weights and the layer geometry — activation *values* never change a
//! schedule (the differential tier pins this). One inference per
//! candidate design over a zeros input therefore measures the exact
//! per-layer cycle cost of every (layer, design) pair, and the cost of
//! any heterogeneous assignment is the design-independent overhead plus
//! the sum of its per-layer picks (asserted in
//! `rust/tests/explorer.rs`).

use crate::cpu::CostModel;
use crate::error::{Error, Result};
use crate::isa::{DesignAssignment, DesignKind};
use crate::nn::graph::{Graph, Layer};
use crate::simulator::SimEngine;
use crate::tensor::quant::QuantParams;
use crate::tensor::{QTensor, Shape};

/// Cycle and fidelity profile of one MAC layer.
#[derive(Debug, Clone)]
pub struct LayerCost {
    /// Layer label as the simulator reports it (`conv:…`, `fc:…`,
    /// `proj:…`).
    pub label: String,
    /// Simulated cycles of this layer under each candidate design
    /// (indexed like [`CostTable::candidates`]).
    pub cycles: Vec<u64>,
    /// Weights outside the INT7 dynamic range — non-zero means the
    /// SSSA/CSA lookahead designs would clamp (lossy) on this layer.
    pub int8_weights: usize,
    /// Non-zero weights beyond the 2:4 budget, summed over the layer's
    /// 4-weight groups — non-zero means NM-SSA's prepare-time structure
    /// enforcement would zero weights (lossy) on this layer.
    pub nm_excess: usize,
    /// Element sparsity of the layer's weights.
    pub sparsity: f64,
}

/// Non-zero weights beyond an N=2 budget per M=4 group (the amount
/// NM-SSA enforcement would zero at prepare time), counted on the same
/// lane-major, word-aligned layout `prepare_lanes` consumes. Depthwise
/// lanes are `kh*kw` taps zero-padded to a word multiple before
/// packing, so their 2:4 groups restart at every lane — chunking the
/// raw buffer would let groups straddle lane boundaries and disagree
/// with what enforcement actually zeroes.
fn nm_excess_of(layer: &Layer) -> usize {
    fn group_excess(ws: &[i8]) -> usize {
        ws.chunks(4)
            .map(|g| g.iter().filter(|&&w| w != 0).count().saturating_sub(2))
            .sum()
    }
    match layer {
        Layer::Conv(op) if op.depthwise => {
            op.weights.chunks(op.kh * op.kw).map(group_excess).sum()
        }
        Layer::Conv(op) => group_excess(&op.weights),
        Layer::Fc(op) => group_excess(&op.weights),
        Layer::Shortcut { conv: Some(op), .. } => group_excess(&op.weights),
        _ => 0,
    }
}

/// The (layer × design) cycle matrix of one pruned model, plus the
/// design-independent overhead (pooling, activation, residual layers).
#[derive(Debug, Clone)]
pub struct CostTable {
    /// Model name (from the graph).
    pub model: String,
    /// Candidate designs, in column order.
    pub candidates: Vec<DesignKind>,
    /// One row per MAC layer, in graph order.
    pub layers: Vec<LayerCost>,
    /// Cycles spent outside MAC layers — identical across designs.
    pub overhead_cycles: u64,
}

impl CostTable {
    /// Exact total cycles of an assignment over this table: overhead
    /// plus each MAC layer's cycles under its assigned design. Errors
    /// if the assignment uses a design that is not a candidate column.
    pub fn total_for(&self, assignment: &DesignAssignment) -> Result<u64> {
        let mut total = self.overhead_cycles;
        for (l, layer) in self.layers.iter().enumerate() {
            let d = assignment.design_for(l);
            let ci = self
                .candidates
                .iter()
                .position(|&c| c == d)
                .ok_or_else(|| Error::Cli(format!("design {d} not among the candidates")))?;
            total += layer.cycles[ci];
        }
        Ok(total)
    }
}

/// Is this a MAC-layer stat row of a [`crate::simulator::SimReport`]?
fn is_mac_label(label: &str) -> bool {
    label.starts_with("conv:") || label.starts_with("fc:") || label.starts_with("proj")
}

/// Profile a pruned graph: one uniform simulation per candidate design,
/// decomposed into the per-layer cycle matrix.
pub fn profile_graph(
    graph: &Graph,
    input_shape: &Shape,
    candidates: &[DesignKind],
    cost_model: &CostModel,
) -> Result<CostTable> {
    if candidates.is_empty() {
        return Err(Error::Cli("explorer needs at least one candidate design".into()));
    }
    let mut unique = Vec::new();
    for &d in candidates {
        if !unique.contains(&d) {
            unique.push(d);
        }
    }
    let candidates = unique;
    // Cycle counts are activation-independent, so a zeros input profiles
    // every layer exactly.
    let input = QTensor::zeros(input_shape.clone(), QuantParams::new(1.0, 0)?);
    let weights = graph.mac_weights();
    let mac_ops: Vec<&Layer> = graph.layers.iter().filter(|l| l.is_mac_layer()).collect();
    let mut layers: Vec<LayerCost> = weights
        .iter()
        .zip(&mac_ops)
        .map(|(ws, layer)| LayerCost {
            label: String::new(),
            cycles: vec![0u64; candidates.len()],
            int8_weights: ws.iter().filter(|&&w| !crate::encoding::int7::is_int7(w)).count(),
            nm_excess: nm_excess_of(layer),
            sparsity: crate::sparsity::stats::element_sparsity(ws),
        })
        .collect();
    let mut overhead: Option<u64> = None;
    for (ci, &design) in candidates.iter().enumerate() {
        let engine = SimEngine::new(design).with_cost_model(cost_model.clone());
        let prepared = engine.prepare(graph)?;
        let report = engine.run(&prepared, &input)?;
        let mac_stats: Vec<_> =
            report.layers.iter().filter(|s| is_mac_label(&s.label)).collect();
        if mac_stats.len() != layers.len() {
            return Err(Error::Sim(format!(
                "profile: {} MAC stat rows for {} MAC layers",
                mac_stats.len(),
                layers.len()
            )));
        }
        let mac_sum: u64 = mac_stats.iter().map(|s| s.cycles).sum();
        let this_overhead = report.total_cycles - mac_sum;
        match overhead {
            None => overhead = Some(this_overhead),
            Some(prev) if prev != this_overhead => {
                return Err(Error::Sim(format!(
                    "profile: non-MAC overhead differs across designs ({prev} vs {this_overhead})"
                )));
            }
            _ => {}
        }
        for (l, stat) in mac_stats.iter().enumerate() {
            layers[l].cycles[ci] = stat.cycles;
            if ci == 0 {
                layers[l].label = stat.label.clone();
            } else if layers[l].label != stat.label {
                return Err(Error::Sim(format!(
                    "profile: layer order diverged ({} vs {})",
                    layers[l].label, stat.label
                )));
            }
        }
    }
    Ok(CostTable {
        model: graph.name.clone(),
        candidates,
        layers,
        overhead_cycles: overhead.unwrap_or(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::{apply_sparsity, ModelConfig};
    use crate::models::zoo::build_model;

    #[test]
    fn table_decomposes_uniform_totals_exactly() {
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, 0.5, 0.3);
        let table = profile_graph(
            &info.graph,
            &info.input_shape,
            &DesignKind::ALL,
            &CostModel::vexriscv(),
        )
        .unwrap();
        assert_eq!(table.layers.len(), info.graph.mac_layers());
        // total_for(Uniform(d)) must reproduce the engine's total.
        let input = QTensor::zeros(info.input_shape.clone(), QuantParams::new(1.0, 0).unwrap());
        for &d in &table.candidates {
            let engine = SimEngine::new(d);
            let prepared = engine.prepare(&info.graph).unwrap();
            let report = engine.run(&prepared, &input).unwrap();
            let predicted = table.total_for(&DesignAssignment::Uniform(d)).unwrap();
            assert_eq!(predicted, report.total_cycles, "{d}");
        }
        // SSSA exploits the block sparsity: strictly fewer cycles than
        // the SIMD baseline on this pruned model.
        let sssa = table.total_for(&DesignAssignment::Uniform(DesignKind::Sssa)).unwrap();
        let simd =
            table.total_for(&DesignAssignment::Uniform(DesignKind::BaselineSimd)).unwrap();
        assert!(sssa < simd, "sssa {sssa} !< simd {simd}");
    }

    #[test]
    fn duplicate_candidates_are_deduped_and_unknown_design_rejected() {
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let info = build_model("dscnn", &cfg).unwrap();
        let table = profile_graph(
            &info.graph,
            &info.input_shape,
            &[DesignKind::Csa, DesignKind::Csa],
            &CostModel::vexriscv(),
        )
        .unwrap();
        assert_eq!(table.candidates, vec![DesignKind::Csa]);
        assert!(table.total_for(&DesignAssignment::Uniform(DesignKind::Ussa)).is_err());
        assert!(profile_graph(
            &info.graph,
            &info.input_shape,
            &[],
            &CostModel::vexriscv()
        )
        .is_err());
    }
}
