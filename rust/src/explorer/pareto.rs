//! Pareto frontier over (cycles, FPGA resources).
//!
//! A candidate assignment is kept only if no other point is at least as
//! good in *every* dimension — simulated cycles, LUTs, FFs and DSPs —
//! and strictly better in one. This is the trade-off Zhu et al. weigh
//! for structured-sparse CNN accelerators (PAPERS.md): more CFU logic
//! buys fewer cycles, and the right point depends on the device budget.

use crate::isa::DesignAssignment;
use crate::resources::fpga::ResourceUsage;

/// One explored point: an assignment with its exact cycle total and
/// FPGA resource increment.
#[derive(Debug, Clone)]
pub struct ParetoPoint {
    /// The per-layer assignment (canonicalized — uniform when all
    /// layers agree).
    pub assignment: DesignAssignment,
    /// Total simulated cycles of one inference under the assignment.
    pub total_cycles: u64,
    /// LUT/FF/DSP increment of the combined CFU build (see
    /// [`crate::analysis::codesign`]).
    pub resources: ResourceUsage,
}

impl ParetoPoint {
    /// The comparison vector: (cycles, LUTs, FFs, DSPs).
    fn key(&self) -> (u64, u32, u32, u32) {
        (self.total_cycles, self.resources.luts, self.resources.ffs, self.resources.dsps)
    }

    /// Weak dominance in every dimension plus strict in at least one.
    pub fn dominates(&self, other: &ParetoPoint) -> bool {
        let (c0, l0, f0, d0) = self.key();
        let (c1, l1, f1, d1) = other.key();
        let le = c0 <= c1 && l0 <= l1 && f0 <= f1 && d0 <= d1;
        le && (c0 < c1 || l0 < l1 || f0 < f1 || d0 < d1)
    }
}

/// Keep the non-dominated points, sorted by ascending cycles (resources
/// break ties); exact duplicates in all four dimensions collapse to the
/// first occurrence.
pub fn pareto_filter(points: &[ParetoPoint]) -> Vec<ParetoPoint> {
    let mut kept: Vec<ParetoPoint> = Vec::new();
    for p in points {
        if points.iter().any(|q| q.dominates(p)) {
            continue;
        }
        if kept.iter().any(|q| q.key() == p.key()) {
            continue;
        }
        kept.push(p.clone());
    }
    kept.sort_by_key(|p| p.key());
    kept
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::DesignKind;

    fn point(cycles: u64, luts: u32, dsps: u32) -> ParetoPoint {
        ParetoPoint {
            assignment: DesignAssignment::Uniform(DesignKind::BaselineSimd),
            total_cycles: cycles,
            resources: ResourceUsage { luts, ffs: 0, brams: 0, dsps },
        }
    }

    #[test]
    fn dominated_points_are_dropped() {
        let pts = vec![
            point(100, 0, 0),  // cheap but slow
            point(50, 95, 1),  // fast but costly
            point(60, 100, 1), // dominated by the 50-cycle point
            point(100, 10, 0), // dominated by the first point
        ];
        let frontier = pareto_filter(&pts);
        assert_eq!(frontier.len(), 2);
        assert_eq!(frontier[0].total_cycles, 50);
        assert_eq!(frontier[1].total_cycles, 100);
        assert_eq!(frontier[1].resources.luts, 0);
    }

    #[test]
    fn incomparable_points_both_survive_and_duplicates_collapse() {
        let pts = vec![point(100, 0, 0), point(50, 95, 1), point(50, 95, 1)];
        let frontier = pareto_filter(&pts);
        assert_eq!(frontier.len(), 2);
        assert!(!frontier[0].dominates(&frontier[1]));
        assert!(!frontier[1].dominates(&frontier[0]));
    }
}
