//! PJRT runtime: load and execute JAX-lowered HLO artifacts.
//!
//! The Python layer (`python/compile/aot.py`) lowers the L2 model graph
//! (which calls the L1 Pallas kernels) to HLO **text** once at build
//! time; [`pjrt`] loads that text through the `xla` crate
//! (`HloModuleProto::from_text_file` → `XlaComputation` → PJRT CPU
//! client) and executes it from Rust. Python never runs at request time.
//! The real client requires the `xla-client` cargo feature; the default
//! (offline) build substitutes an API-compatible stub.
//!
//! [`model_io`] imports the quantized weights exported by
//! `python/compile/train.py` (JSON) and reconstructs the same network as
//! a [`crate::nn::Graph`] so the cycle simulator and the PJRT path can
//! be cross-checked on identical parameters (the e2e example).

pub mod model_io;
pub mod pjrt;

pub use model_io::import_graph;
pub use pjrt::PjrtRuntime;
