//! Import of Python-trained quantized models (JSON interchange).
//!
//! `python/compile/train.py` exports the trained tiny model as a JSON
//! document; this module reconstructs it as a [`Graph`] with the *same*
//! integer parameters, so the Rust simulator computes the same network
//! the JAX/PJRT artifact does.

use crate::config::value::Value;
use crate::error::{Error, Result};
use crate::nn::conv2d::{Conv2dOp, Padding};
use crate::nn::fully_connected::FullyConnectedOp;
use crate::nn::graph::{Graph, Layer};
use crate::tensor::quant::QuantParams;
use crate::tensor::Shape;

fn params_of(v: &Value, scale_key: &str, zp_key: &str) -> Result<QuantParams> {
    QuantParams::new(v.get(scale_key)?.as_f64()? as f32, v.get(zp_key)?.as_i64()? as i32)
}

fn padding_of(v: &Value) -> Result<Padding> {
    match v.get("padding")?.as_str()? {
        "same" => Ok(Padding::Same),
        "valid" => Ok(Padding::Valid),
        other => Err(Error::Config(format!("unknown padding '{other}'"))),
    }
}

fn bias_of(v: &Value) -> Result<Vec<i32>> {
    v.get("bias")?
        .as_arr()?
        .iter()
        .map(|x| x.as_i64().map(|i| i as i32))
        .collect()
}

/// Parse a model JSON document into a [`Graph`] plus its input shape.
pub fn import_graph(json: &str) -> Result<(Graph, Shape)> {
    let doc = Value::parse(json)?;
    let name = doc.get("name")?.as_str()?.to_string();
    let classes = doc.get("classes")?.as_usize()?;
    let ishape: Vec<usize> = doc
        .get("input_shape")?
        .as_arr()?
        .iter()
        .map(|v| v.as_usize())
        .collect::<Result<Vec<_>>>()?;
    let input_shape = Shape::new(&ishape)?;
    let mut layers = Vec::new();
    for (li, lv) in doc.get("layers")?.as_arr()?.iter().enumerate() {
        let kind = lv.get("kind")?.as_str()?;
        let layer = match kind {
            "conv" => {
                let lname = lv.get("name")?.as_str()?;
                let op = Conv2dOp::new(
                    lname,
                    lv.get("weights")?.as_i8_vec()?,
                    bias_of(lv)?,
                    lv.get("out_c")?.as_usize()?,
                    lv.get("in_c")?.as_usize()?,
                    lv.get("kh")?.as_usize()?,
                    lv.get("kw")?.as_usize()?,
                    lv.get("stride")?.as_usize()?,
                    padding_of(lv)?,
                    lv.get("depthwise")?.as_bool()?,
                    params_of(lv, "input_scale", "input_zp")?,
                    lv.get("weight_scale")?.as_f64()? as f32,
                    params_of(lv, "output_scale", "output_zp")?,
                    lv.get("relu")?.as_bool()?,
                )?;
                Layer::Conv(op)
            }
            "fc" => {
                let lname = lv.get("name")?.as_str()?;
                let op = FullyConnectedOp::new(
                    lname,
                    lv.get("weights")?.as_i8_vec()?,
                    bias_of(lv)?,
                    lv.get("out_n")?.as_usize()?,
                    lv.get("in_n")?.as_usize()?,
                    params_of(lv, "input_scale", "input_zp")?,
                    lv.get("weight_scale")?.as_f64()? as f32,
                    params_of(lv, "output_scale", "output_zp")?,
                    lv.get("relu")?.as_bool()?,
                )?;
                Layer::Fc(op)
            }
            "maxpool" => Layer::MaxPool {
                k: lv.get("k")?.as_usize()?,
                stride: lv.get("stride")?.as_usize()?,
            },
            "avgpool" => Layer::AvgPool {
                k: lv.get("k")?.as_usize()?,
                stride: lv.get("stride")?.as_usize()?,
            },
            "gap" => Layer::GlobalAvgPool,
            "relu" => Layer::Relu,
            other => {
                return Err(Error::Config(format!("layer {li}: unknown kind '{other}'")))
            }
        };
        layers.push(layer);
    }
    Ok((Graph::new(&name, layers, classes), input_shape))
}

/// Load a model JSON file.
pub fn import_graph_file<P: AsRef<std::path::Path>>(path: P) -> Result<(Graph, Shape)> {
    let path = path.as_ref();
    if !path.exists() {
        return Err(Error::Config(format!(
            "model file {} not found — run `make artifacts` first",
            path.display()
        )));
    }
    let json = std::fs::read_to_string(path)?;
    import_graph(&json)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::QTensor;

    fn sample_json() -> String {
        r#"{
          "name": "tiny", "classes": 4, "input_shape": [1, 4, 4, 4],
          "layers": [
            {"kind": "conv", "name": "c1", "out_c": 4, "in_c": 4,
             "kh": 3, "kw": 3, "stride": 1, "padding": "same",
             "depthwise": false, "relu": true,
             "weights": [REPLACED],
             "bias": [0, 1, -1, 2],
             "input_scale": 0.05, "input_zp": 0,
             "weight_scale": 0.02,
             "output_scale": 0.05, "output_zp": 0},
            {"kind": "gap"},
            {"kind": "fc", "name": "head", "out_n": 4, "in_n": 4,
             "weights": [1,0,0,0, 0,1,0,0, 0,0,1,0, 0,0,0,1],
             "bias": [0,0,0,0],
             "input_scale": 0.05, "input_zp": 0,
             "weight_scale": 0.02,
             "output_scale": 0.1, "output_zp": 0,
             "relu": false}
          ]
        }"#
        .replace(
            "[REPLACED]",
            &format!(
                "[{}]",
                (0..4 * 3 * 3 * 4).map(|i| ((i % 13) as i32 - 6).to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            ),
        )
    }

    #[test]
    fn imports_and_runs() {
        let (graph, shape) = import_graph(&sample_json()).unwrap();
        assert_eq!(graph.name, "tiny");
        assert_eq!(graph.classes, 4);
        assert_eq!(shape.dims(), &[1, 4, 4, 4]);
        assert_eq!(graph.mac_layers(), 2);
        let input = QTensor::zeros(shape, QuantParams::new(0.05, 0).unwrap());
        let out = graph.forward_ref(&input).unwrap();
        assert_eq!(out.shape().numel(), 4);
    }

    #[test]
    fn unknown_kind_rejected() {
        let json = r#"{"name":"x","classes":2,"input_shape":[1,2,2,4],
            "layers":[{"kind":"transformer"}]}"#;
        assert!(import_graph(json).is_err());
    }

    #[test]
    fn missing_file_mentions_make() {
        let err = import_graph_file("/nope/model.json").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
