//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! The `xla` crate cannot be fetched in the offline build environment, so
//! the real client is gated behind the `xla-client` cargo feature (which
//! additionally requires adding `xla` as a local/vendored dependency).
//! The default build ships an API-compatible stub whose constructors
//! return a clear [`Error::Runtime`], keeping every caller (examples,
//! cross-layer tests) compiling; the cross-layer tests self-skip when the
//! artifacts are absent, which is always the case without the real
//! client.

#[cfg(not(feature = "xla-client"))]
use crate::error::{Error, Result};

#[cfg(feature = "xla-client")]
mod client {
    use crate::error::{Error, Result};

    /// A PJRT CPU runtime holding compiled executables.
    pub struct PjrtRuntime {
        client: xla::PjRtClient,
    }

    /// One compiled HLO module.
    pub struct Loaded {
        exe: xla::PjRtLoadedExecutable,
    }

    impl PjrtRuntime {
        /// Create the CPU client.
        pub fn cpu() -> Result<Self> {
            Ok(PjrtRuntime { client: xla::PjRtClient::cpu()? })
        }

        /// Platform string (diagnostics).
        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO **text** artifact (the interchange format — jax ≥ 0.5
        /// serialized protos are rejected by xla_extension 0.5.1; see
        /// DESIGN.md) and compile it.
        pub fn load_hlo_text<P: AsRef<std::path::Path>>(&self, path: P) -> Result<Loaded> {
            let path = path.as_ref();
            if !path.exists() {
                return Err(Error::Runtime(format!(
                    "HLO artifact {} not found — run `make artifacts` first",
                    path.display()
                )));
            }
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            Ok(Loaded { exe })
        }
    }

    impl Loaded {
        /// Execute with f32 inputs of given shapes; returns the flattened
        /// f32 outputs (the module is lowered with `return_tuple=True`).
        pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let lit = xla::Literal::vec1(data);
                    lit.reshape(dims).map_err(Error::from)
                })
                .collect::<Result<Vec<_>>>()?;
            let result =
                self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            parts
                .into_iter()
                .map(|p| p.to_vec::<f32>().map_err(Error::from))
                .collect::<Result<Vec<_>>>()
        }
    }
}

#[cfg(feature = "xla-client")]
pub use client::{Loaded, PjrtRuntime};

/// Stub error shared by every entry point of the default build.
#[cfg(not(feature = "xla-client"))]
fn unavailable() -> Error {
    Error::Runtime(
        "PJRT backend unavailable: built without the `xla-client` feature \
         (add the `xla` crate as a local dependency and rebuild with \
         `--features xla-client`)"
            .to_string(),
    )
}

/// A PJRT CPU runtime (offline stub — every constructor errors).
#[cfg(not(feature = "xla-client"))]
pub struct PjrtRuntime {
    _priv: (),
}

/// One compiled HLO module (offline stub — unconstructible).
#[cfg(not(feature = "xla-client"))]
pub struct Loaded {
    _priv: (),
}

#[cfg(not(feature = "xla-client"))]
impl PjrtRuntime {
    /// Create the CPU client — always errors in the stub build.
    pub fn cpu() -> Result<Self> {
        Err(unavailable())
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Load an HLO text artifact — always errors in the stub build.
    pub fn load_hlo_text<P: AsRef<std::path::Path>>(&self, path: P) -> Result<Loaded> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "HLO artifact {} not found — run `make artifacts` first",
                path.display()
            )));
        }
        Err(unavailable())
    }
}

#[cfg(not(feature = "xla-client"))]
impl Loaded {
    /// Execute with f32 inputs — unreachable in the stub build
    /// ([`Loaded`] cannot be constructed), kept for API parity.
    pub fn run_f32(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable())
    }
}

#[cfg(all(test, not(feature = "xla-client")))]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = match PjrtRuntime::cpu() {
            Err(e) => e,
            Ok(_) => panic!("stub must not construct a client"),
        };
        assert!(err.to_string().contains("xla-client"), "{err}");
    }
}
