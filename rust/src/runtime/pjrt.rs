//! Thin wrapper over the `xla` crate's PJRT CPU client.

use crate::error::{Error, Result};

/// A PJRT CPU runtime holding compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
}

/// One compiled HLO module.
pub struct Loaded {
    exe: xla::PjRtLoadedExecutable,
}

impl PjrtRuntime {
    /// Create the CPU client.
    pub fn cpu() -> Result<Self> {
        Ok(PjrtRuntime { client: xla::PjRtClient::cpu()? })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** artifact (the interchange format — jax ≥ 0.5
    /// serialized protos are rejected by xla_extension 0.5.1; see
    /// DESIGN.md) and compile it.
    pub fn load_hlo_text<P: AsRef<std::path::Path>>(&self, path: P) -> Result<Loaded> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "HLO artifact {} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(Loaded { exe })
    }
}

impl Loaded {
    /// Execute with f32 inputs of given shapes; returns the flattened
    /// f32 outputs (the module is lowered with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                let lit = xla::Literal::vec1(data);
                lit.reshape(dims).map_err(Error::from)
            })
            .collect::<Result<Vec<_>>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(Error::from))
            .collect::<Result<Vec<_>>>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_boots() {
        let rt = PjrtRuntime::cpu().unwrap();
        assert!(!rt.platform().is_empty());
    }

    #[test]
    fn missing_artifact_is_clear_error() {
        let rt = PjrtRuntime::cpu().unwrap();
        let err = match rt.load_hlo_text("/nonexistent/model.hlo.txt") {
            Err(e) => e,
            Ok(_) => panic!("expected error for missing artifact"),
        };
        assert!(err.to_string().contains("make artifacts"));
    }
}
