//! # sparse-riscv
//!
//! Reproduction of *"Hardware/Software Co-Design of RISC-V Extensions for
//! Accelerating Sparse DNNs on FPGAs"* (Sabih et al., 2025) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The paper extends a VexRiscv soft core with Custom Functional Units
//! (CFUs) that exploit semi-structured (SSSA), unstructured (USSA), and
//! combined (CSA) weight sparsity. This crate provides:
//!
//! - bit-accurate functional + cycle models of the four CFU designs
//!   ([`cfu`]),
//! - a VexRiscv-like instruction cycle-cost model and kernel executor
//!   ([`cpu`], [`kernels`]),
//! - the lookahead weight encoding of Algorithms 1 & 2 ([`encoding`]),
//! - a pruning library for unstructured and 4:4 semi-structured sparsity
//!   ([`sparsity`]),
//! - TFLite-style INT8 quantized tensor and NN ops ([`tensor`], [`nn`]),
//! - the paper's four evaluation models ([`models`]) and a layer-by-layer
//!   cycle simulator ([`simulator`]), generic over per-layer
//!   [`isa::DesignAssignment`]s (heterogeneous execution),
//! - a design-space explorer that turns per-layer sparsity stats, the
//!   cycle model and the FPGA resource model into a Pareto frontier and
//!   an argmin per-layer assignment ([`explorer`]),
//! - an FPGA resource estimator reproducing Table III ([`resources`]),
//! - analytical speedup models for Figures 8/9 and the co-design
//!   resource pricing ([`analysis`]),
//! - an experiment coordinator with a threaded scheduler, a request
//!   serving loop, a dependency-free TCP/HTTP front-end with
//!   continuous batching and overload shedding, an open-loop load
//!   generator, and fleet-scale multi-device serving with placement
//!   and replica failover ([`coordinator`]),
//! - structured perf telemetry: metric records, the committed
//!   `BENCH_*.json` baseline store, and the CI regression diff engine
//!   ([`metrics`]),
//! - deterministic fault injection + supervised recovery: seeded
//!   replayable fault plans, prepared-schedule integrity checksums with
//!   oracle-fallback degradation, and panic-isolated batcher
//!   supervision ([`faults`]),
//! - a PJRT runtime that loads JAX-lowered HLO text artifacts ([`runtime`]),
//! - offline-friendly substrates: CLI parser ([`cli`]), config system
//!   ([`config`]), bench harness ([`bench`]), PRNG/stats/property testing
//!   ([`util`]).
//!
//! See `README.md` for the quickstart and CLI tour, `DESIGN.md` for the
//! hardware-substitution rationale and the experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

#![warn(missing_docs)]

pub mod analysis;
pub mod bench;
pub mod cfu;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod cpu;
pub mod encoding;
pub mod error;
pub mod explorer;
pub mod faults;
pub mod isa;
pub mod kernels;
pub mod metrics;
pub mod models;
pub mod nn;
pub mod resources;
pub mod runtime;
pub mod simulator;
pub mod sparsity;
pub mod tensor;
pub mod util;

pub use error::{Error, Result};
