//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror` in the offline
//! build environment); the rendered messages are part of the CLI
//! contract and are asserted by the end-to-end tests.

use std::fmt;

/// Errors produced by the sparse-riscv library.
#[derive(Debug)]
pub enum Error {
    /// Tensor shape mismatch or invalid dimension.
    Shape(String),

    /// Quantization parameter or range violation.
    Quant(String),

    /// Lookahead encoding violation (e.g. weight outside INT7 range).
    Encoding(String),

    /// Configuration parse or validation failure.
    Config(String),

    /// CLI argument error.
    Cli(String),

    /// Model definition / graph construction error.
    Model(String),

    /// Simulator invariant violation.
    Sim(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// Coordinator scheduling failure.
    Coordinator(String),

    /// I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Shape(m) => write!(f, "shape error: {m}"),
            Error::Quant(m) => write!(f, "quantization error: {m}"),
            Error::Encoding(m) => write!(f, "encoding error: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Cli(m) => write!(f, "cli error: {m}"),
            Error::Model(m) => write!(f, "model error: {m}"),
            Error::Sim(m) => write!(f, "simulation error: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Coordinator(m) => write!(f, "coordinator error: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "xla-client")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_are_stable() {
        assert_eq!(Error::Shape("x".into()).to_string(), "shape error: x");
        assert_eq!(Error::Cli("bad flag".into()).to_string(), "cli error: bad flag");
        assert_eq!(Error::Config("x_us".into()).to_string(), "config error: x_us");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(e.to_string().starts_with("io error:"));
        assert!(std::error::Error::source(&e).is_some());
        assert!(std::error::Error::source(&Error::Sim("s".into())).is_none());
    }
}
