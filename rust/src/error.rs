//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the sparse-riscv library.
#[derive(Error, Debug)]
pub enum Error {
    /// Tensor shape mismatch or invalid dimension.
    #[error("shape error: {0}")]
    Shape(String),

    /// Quantization parameter or range violation.
    #[error("quantization error: {0}")]
    Quant(String),

    /// Lookahead encoding violation (e.g. weight outside INT7 range).
    #[error("encoding error: {0}")]
    Encoding(String),

    /// Configuration parse or validation failure.
    #[error("config error: {0}")]
    Config(String),

    /// CLI argument error.
    #[error("cli error: {0}")]
    Cli(String),

    /// Model definition / graph construction error.
    #[error("model error: {0}")]
    Model(String),

    /// Simulator invariant violation.
    #[error("simulation error: {0}")]
    Sim(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator scheduling failure.
    #[error("coordinator error: {0}")]
    Coordinator(String),

    /// I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
