//! L3 coordinator: the system glue that owns process lifecycle, worker
//! threads, experiment orchestration, and the request-serving loop.
//!
//! - [`scheduler`] — a generic threaded job pool (std threads + channels;
//!   no tokio offline), with per-item, chunked and scoped (borrowing)
//!   parallel map plus the [`scheduler::TilePool`] handle used for
//!   intra-layer lane tiling,
//! - [`batch`] — engine v2: batched multi-design inference with a
//!   prepared-model cache and aggregated per-batch reports,
//! - [`runner`] — experiment orchestration: build model → prune → prepare
//!   per design → simulate the batch at (design × request) granularity →
//!   collect speedups,
//! - [`serve`] — a closed-loop inference server over the cycle simulator
//!   with latency/throughput metrics (simulated clock + host wall clock).

pub mod batch;
pub mod runner;
pub mod scheduler;
pub mod serve;

pub use batch::{BatchEngine, BatchOptions, BatchReport, BatchSpec};
pub use runner::{run_experiment, DesignResult, ExperimentResult};
pub use scheduler::{JobPool, TilePool};
pub use serve::{ServeMetrics, ServeOptions, Server};
