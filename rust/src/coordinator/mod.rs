//! L3 coordinator: the system glue that owns process lifecycle, worker
//! threads, experiment orchestration, and the request-serving loop.
//!
//! - [`scheduler`] — a generic work-stealing-free threaded job pool
//!   (std threads + channels; no tokio offline),
//! - [`runner`] — experiment orchestration: build model → prune → prepare
//!   per design → simulate batch → collect speedups,
//! - [`serve`] — a closed-loop inference server over the cycle simulator
//!   with latency/throughput metrics (simulated clock + host wall clock).

pub mod runner;
pub mod scheduler;
pub mod serve;

pub use runner::{run_experiment, DesignResult, ExperimentResult};
pub use scheduler::JobPool;
pub use serve::{ServeMetrics, ServeOptions, Server};
