//! L3 coordinator: the system glue that owns process lifecycle, worker
//! threads, experiment orchestration, and the request-serving loop.
//!
//! - [`scheduler`] — a generic threaded job pool (std threads + channels;
//!   no tokio offline), with per-item, chunked and scoped (borrowing)
//!   parallel map plus the [`scheduler::TilePool`] handle used for
//!   intra-layer lane tiling,
//! - [`batch`] — engine v2: batched multi-design inference with a
//!   prepared-model cache and aggregated per-batch reports,
//! - [`runner`] — experiment orchestration: build model → prune → prepare
//!   per design → simulate the batch at (design × request) granularity →
//!   collect speedups,
//! - [`serve`] — a closed-loop in-process inference server over the cycle
//!   simulator with latency/throughput metrics (simulated clock + host
//!   wall clock) — the *debug* serving path,
//! - [`net`] — the *production* serving path: a dependency-free
//!   TCP + HTTP/1.1 front-end with continuous batching (size/deadline
//!   triggers), bounded admission queues with 503 + `Retry-After`
//!   shedding, and graceful drain on shutdown,
//! - [`loadgen`] — a deterministic open-loop load generator (Poisson and
//!   bursty arrivals) plus the minimal HTTP client used to replay traces
//!   against [`net::NetServer`],
//! - [`fleet`] — fleet-scale multi-device serving: N simulated devices
//!   behind a placement/routing layer with cache-affinity routing,
//!   hot-model replication, device-level fault domains, and replica
//!   failover that preserves the ledger invariant fleet-wide.

pub mod batch;
pub mod fleet;
pub mod loadgen;
pub mod net;
pub mod runner;
pub mod scheduler;
pub mod serve;

pub use batch::{BatchEngine, BatchOptions, BatchReport, BatchSpec};
pub use fleet::{Fleet, FleetOptions, FleetReport, Submission, TenantTrace};
pub use loadgen::{Arrival, LoadReport, TraceConfig};
pub use net::{NetHandle, NetOptions, NetServer, NetStats};
pub use runner::{run_experiment, DesignResult, ExperimentResult};
pub use scheduler::{JobPool, TilePool};
pub use serve::{ServeMetrics, ServeOptions, Server};

/// Poison-recovering mutex lock: take the guard even when another
/// thread panicked while holding it.
///
/// The coordinator's shared state (admission queues, stats, the
/// degraded-key map) is only ever mutated through small, invariant-
/// preserving critical sections, so data behind a poisoned mutex is
/// still coherent — what must *not* happen is one panicked batcher
/// turning every subsequent `.lock().unwrap()` into a cascading panic
/// that wedges the whole server. Supervised recovery (batcher respawn,
/// `catch_unwind` around batch execution) depends on this helper.
pub fn lock_clean<T>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}
