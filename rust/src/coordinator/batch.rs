//! Engine v2: batched multi-design inference over the cycle simulator.
//!
//! One [`BatchEngine`] owns a worker pool and a prepared-model cache and
//! executes *batches* of inference requests for any (model, per-layer
//! design assignment, sparsity) configuration:
//!
//! - the prepared model (built + pruned + lookahead-encoded weights) is
//!   cached across batches keyed by [`crate::simulator::ModelKey`], so a
//!   serving loop pays the paper's offline pre-processing once;
//! - the requests of one batch are scheduled across the
//!   [`super::scheduler::JobPool`] workers (chunked to amortize channel
//!   overhead), each worker driving the shared
//!   [`crate::simulator::ExecBackend`];
//! - results aggregate into a [`BatchReport`]: total/CFU cycles, CFU
//!   stall cycles, memory traffic, and simulated-latency mean/p50/p99 via
//!   [`crate::util::stats`].
//!
//! This is the substrate the CLI `serve`/`bench-e2e` commands and the
//! end-to-end throughput bench build on.

use super::lock_clean;
use super::scheduler::{JobPool, TilePool};
use crate::error::Result;
use crate::faults::{FaultPlan, FaultSite};
use crate::isa::{DesignAssignment, DesignKind};
use crate::kernels::{ExecMode, HostKernel};
use crate::metrics::MetricRecord;
use crate::models::builder::{apply_sparsity, random_input, ModelConfig};
use crate::models::zoo::{build_model, input_shape};
use crate::simulator::{
    assigned_backend_full, ExecBackend, ModelKey, PreparedCache, PreparedModel,
};
use crate::tensor::quant::QuantParams;
use crate::tensor::QTensor;
use crate::util::stats::{OnlineStats, Percentiles};
use crate::util::Pcg32;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One batchable workload: which prepared model to run.
#[derive(Debug, Clone, PartialEq)]
pub struct BatchSpec {
    /// Model zoo identifier.
    pub model: String,
    /// Per-layer accelerator assignment (uniform for one model-wide
    /// design).
    pub assignment: DesignAssignment,
    /// Unstructured sparsity within surviving blocks.
    pub x_us: f64,
    /// 4:4 block sparsity.
    pub x_ss: f64,
    /// Model width multiplier.
    pub scale: f64,
    /// Weight RNG seed (model construction).
    pub weight_seed: u64,
}

impl BatchSpec {
    /// Uniform-design spec with the repo-default sparsity/scale/seed.
    pub fn new(model: &str, design: DesignKind) -> Self {
        BatchSpec::assigned(model, DesignAssignment::Uniform(design))
    }

    /// Per-layer assignment spec (e.g. the explorer's argmin) with the
    /// repo-default sparsity/scale/seed.
    pub fn assigned(model: &str, assignment: DesignAssignment) -> Self {
        BatchSpec {
            model: model.to_string(),
            assignment,
            x_us: 0.5,
            x_ss: 0.3,
            scale: 0.125,
            weight_seed: ModelConfig::default().seed,
        }
    }

    pub(crate) fn key(&self) -> ModelKey {
        ModelKey::assigned(
            &self.model,
            self.assignment.clone(),
            self.x_us,
            self.x_ss,
            self.scale,
            self.weight_seed,
        )
    }

    fn model_config(&self) -> ModelConfig {
        ModelConfig { scale: self.scale, seed: self.weight_seed, ..Default::default() }
    }
}

/// Aggregated result of one batch.
#[derive(Debug, Clone)]
pub struct BatchReport {
    /// Model name.
    pub model: String,
    /// Per-layer design assignment executed.
    pub assignment: DesignAssignment,
    /// Requests completed.
    pub completed: u64,
    /// Total simulated cycles over the batch.
    pub total_cycles: u64,
    /// CFU (MAC-unit) cycles over the batch.
    pub cfu_cycles: u64,
    /// CFU stall cycles (multi-cycle MAC waits) over the batch.
    pub cfu_stalls: u64,
    /// Bytes loaded by the simulated kernels over the batch.
    pub loaded_bytes: u64,
    /// Per-request simulated latency stats (seconds at the SoC clock).
    pub latency: OnlineStats,
    /// Per-request simulated latencies (seconds), in request order —
    /// kept so percentiles stay exact when reports are merged.
    pub latencies: Vec<f64>,
    /// Median simulated latency (seconds).
    pub p50: f64,
    /// 99th-percentile simulated latency (seconds).
    pub p99: f64,
    /// Host wall-clock seconds for the batch.
    pub wall_seconds: f64,
    /// Whether the prepared model came from the cache.
    pub cache_hit: bool,
    /// Cumulative prepared-model cache hits at report time.
    pub cache_hits: u64,
    /// Cumulative prepared-model cache misses (builds) at report time.
    pub cache_misses: u64,
    /// Cumulative prepared-model LRU evictions at report time.
    pub cache_evictions: u64,
    /// Per-request predicted classes (argmax of the head).
    pub predictions: Vec<usize>,
    /// Per-request simulated cycle counts, in request order — lets the
    /// network serving layer answer each request with its own exact
    /// cycle cost even though requests execute inside a shared batch.
    pub request_cycles: Vec<u64>,
}

impl BatchReport {
    /// Compact assignment label for tables and metric records (the
    /// design name when uniform).
    pub fn design_label(&self) -> String {
        self.assignment.label()
    }

    /// Host-side throughput (inferences per wall second).
    pub fn host_throughput(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.wall_seconds
    }

    /// Simulated single-core device throughput at a clock frequency
    /// (inferences per simulated second).
    pub fn sim_throughput(&self, clock_hz: u64) -> f64 {
        if self.total_cycles == 0 {
            return 0.0;
        }
        self.completed as f64 * clock_hz as f64 / self.total_cycles as f64
    }

    /// Fold another batch of the same spec into this report (used when a
    /// request stream is served as several batches).
    pub fn merge(&mut self, other: &BatchReport) {
        self.absorb(other);
        self.recompute_percentiles();
    }

    /// Accumulate everything except p50/p99 — the stream loop absorbs
    /// many batches and recomputes percentiles once at the end, instead
    /// of re-sorting the whole sample vector per batch.
    fn absorb(&mut self, other: &BatchReport) {
        self.completed += other.completed;
        self.total_cycles += other.total_cycles;
        self.cfu_cycles += other.cfu_cycles;
        self.cfu_stalls += other.cfu_stalls;
        self.loaded_bytes += other.loaded_bytes;
        self.latency.merge(&other.latency);
        self.latencies.extend_from_slice(&other.latencies);
        self.wall_seconds += other.wall_seconds;
        self.cache_hit &= other.cache_hit;
        // Cache counters are cumulative snapshots — keep the latest.
        self.cache_hits = self.cache_hits.max(other.cache_hits);
        self.cache_misses = self.cache_misses.max(other.cache_misses);
        self.cache_evictions = self.cache_evictions.max(other.cache_evictions);
        self.predictions.extend_from_slice(&other.predictions);
        self.request_cycles.extend_from_slice(&other.request_cycles);
    }

    /// Emit this report as a structured [`MetricRecord`] (the telemetry
    /// layer every perf gate and trend dashboard reads). Deterministic
    /// simulator counters gate CI; `wall_*`/`host_*` values ride along
    /// as informational wall-clock metrics.
    pub fn to_metric(
        &self,
        id: &str,
        spec: &BatchSpec,
        batch: u64,
        threads: u64,
        clock_hz: u64,
    ) -> MetricRecord {
        MetricRecord::new(id)
            .context(
                &self.model,
                &self.design_label(),
                spec.x_us,
                spec.x_ss,
                spec.scale,
                batch,
                threads,
            )
            .with_value("total_cycles", self.total_cycles as f64)
            .with_value("cfu_cycles", self.cfu_cycles as f64)
            .with_value("cfu_stalls", self.cfu_stalls as f64)
            .with_value("loaded_bytes", self.loaded_bytes as f64)
            .with_value("p50_ms", self.p50 * 1e3)
            .with_value("p99_ms", self.p99 * 1e3)
            .with_value("sim_inf_s", self.sim_throughput(clock_hz))
            // Informational serve-path throughput (host_ prefix → never
            // gated): makes compiled-path host speedups visible in
            // baseline diffs.
            .with_value("host_infer_per_s", self.host_throughput())
            .with_value("wall_s", self.wall_seconds)
    }

    /// Recompute p50/p99 over the raw samples — exact, unlike merging
    /// the summary percentile values.
    fn recompute_percentiles(&mut self) {
        let mut pcts = Percentiles::new();
        for &s in &self.latencies {
            pcts.push(s);
        }
        if pcts.count() > 0 {
            self.p50 = pcts.percentile(50.0);
            self.p99 = pcts.percentile(99.0);
        }
    }
}

/// Batch engine options.
#[derive(Debug, Clone)]
pub struct BatchOptions {
    /// Worker threads (0 = available parallelism).
    pub threads: usize,
    /// SoC clock for simulated-latency conversion.
    pub clock_hz: u64,
    /// Verify every MAC layer against the golden reference ops.
    pub verify: bool,
    /// Lane execution path: batch-amortized arena execution (default),
    /// the per-lane compiled walk, or the interpreted CFU oracle.
    pub exec_mode: ExecMode,
    /// LRU capacity of the prepared-model cache (ignored when an
    /// external cache is shared via [`BatchEngine::with_cache`]).
    pub cache_capacity: usize,
    /// Intra-layer tile workers: `> 1` splits every MAC layer's lane
    /// dimension of each single inference across a dedicated tile pool
    /// (so one large request uses all cores, not just cross-request
    /// parallelism); `0`/`1` disables tiling. The tile pool is separate
    /// from the request pool — sharing one pool for both levels could
    /// deadlock with every request worker waiting on tile jobs.
    pub tile_threads: usize,
    /// Host-side multiply kernel for the batched path ([`HostKernel`]):
    /// `Auto` picks the fastest available SWAR/SIMD routine. Outputs and
    /// simulated counters are invariant in this choice.
    pub host_kernel: HostKernel,
    /// Seeded fault-injection plan (chaos testing). `None` — the default
    /// everywhere — makes every fault hook a no-op, so production and
    /// differential-tier behavior is bit-identical to a plan-free build.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for BatchOptions {
    fn default() -> Self {
        BatchOptions {
            threads: 0,
            clock_hz: 100_000_000,
            verify: false,
            exec_mode: ExecMode::default(),
            cache_capacity: PreparedCache::DEFAULT_CAPACITY,
            tile_threads: 0,
            host_kernel: HostKernel::Auto,
            faults: None,
        }
    }
}

/// Per-request measurement collected by the workers.
struct ReqStat {
    cycles: u64,
    cfu_cycles: u64,
    cfu_stalls: u64,
    loaded_bytes: u64,
    pred: usize,
}

/// Consecutive integrity strikes on one key before the engine stops
/// re-preparing and pins the key to the interpreted-oracle backend
/// (graceful degradation: slower, but the oracle path's simplicity is
/// the bit-trustworthy reference).
const DEGRADE_STRIKES: u32 = 2;

/// Most distinct model keys the integrity-strike ledger tracks at once.
/// A long corruption storm over many keys would otherwise grow the
/// ledger without bound; at the cap the least-recently-struck key is
/// evicted (and counted), which at worst forgets one strike and makes a
/// noisy key take one extra strike to degrade.
const STRIKE_CAP: usize = 64;

/// LRU-bounded integrity-strike ledger: most-recently-touched keys sit
/// at the back of `entries`, so eviction pops the front. Linear scans
/// are fine — the ledger never exceeds the (small) cap.
struct StrikeLedger {
    cap: usize,
    entries: Vec<(ModelKey, u32)>,
    evictions: u64,
}

impl StrikeLedger {
    fn new(cap: usize) -> Self {
        StrikeLedger { cap: cap.max(1), entries: Vec::new(), evictions: 0 }
    }

    /// Record one strike against `key`, evicting the least-recently
    /// struck entry if the ledger is full.
    fn strike(&mut self, key: &ModelKey) {
        if let Some(i) = self.entries.iter().position(|(k, _)| k == key) {
            let (k, s) = self.entries.remove(i);
            self.entries.push((k, s.saturating_add(1)));
            return;
        }
        if self.entries.len() >= self.cap {
            self.entries.remove(0);
            self.evictions += 1;
        }
        self.entries.push((key.clone(), 1));
    }

    /// Whether `key` has struck out; a degraded hit refreshes the key's
    /// recency so actively-served degraded models stay pinned.
    fn is_degraded(&mut self, key: &ModelKey) -> bool {
        match self.entries.iter().position(|(k, s)| k == key && *s >= DEGRADE_STRIKES) {
            Some(i) => {
                let e = self.entries.remove(i);
                self.entries.push(e);
                true
            }
            None => false,
        }
    }

    fn degraded_keys(&self) -> usize {
        self.entries.iter().filter(|(_, s)| *s >= DEGRADE_STRIKES).count()
    }
}

/// The batched multi-design inference engine.
pub struct BatchEngine {
    pool: JobPool,
    /// Dedicated pool for intra-layer lane tiling (separate from the
    /// request pool to rule out cross-level deadlock).
    tiling: Option<TilePool>,
    cache: Arc<PreparedCache>,
    opts: BatchOptions,
    /// Integrity strikes per model key; keys at [`DEGRADE_STRIKES`] run
    /// on the interpreted-oracle backend from then on. LRU-bounded at
    /// [`STRIKE_CAP`] keys so a corruption storm cannot grow it forever.
    strikes: Mutex<StrikeLedger>,
    /// Batches executed in degraded (oracle-fallback) mode.
    degraded_runs: AtomicU64,
    /// Transient lane faults detected by redundant re-execution and
    /// answered with the clean re-run. Shared with worker closures.
    transient_corrected: Arc<AtomicU64>,
}

impl BatchEngine {
    /// Engine with a fresh cache (LRU-bounded by `opts.cache_capacity`).
    pub fn new(opts: BatchOptions) -> Self {
        let cache = Arc::new(PreparedCache::with_capacity(opts.cache_capacity));
        BatchEngine::with_cache(opts, cache)
    }

    /// Engine sharing an existing cache (e.g. one cache across several
    /// thread-count configurations in a bench sweep).
    pub fn with_cache(opts: BatchOptions, cache: Arc<PreparedCache>) -> Self {
        let tiling = (opts.tile_threads > 1).then(|| TilePool::new(opts.tile_threads));
        BatchEngine {
            pool: JobPool::new(opts.threads),
            tiling,
            cache,
            opts,
            strikes: Mutex::new(StrikeLedger::new(STRIKE_CAP)),
            degraded_runs: AtomicU64::new(0),
            transient_corrected: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Worker threads serving this engine.
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// Intra-layer tile workers (0 when tiling is disabled).
    pub fn tile_workers(&self) -> usize {
        self.tiling.as_ref().map_or(0, TilePool::workers)
    }

    /// The prepared-model cache (inspection / sharing).
    pub fn cache(&self) -> &Arc<PreparedCache> {
        &self.cache
    }

    /// The fault-injection plan this engine consults, if any.
    pub fn fault_plan(&self) -> Option<&Arc<FaultPlan>> {
        self.opts.faults.as_ref()
    }

    /// Integrity-checksum failures detected on prepared-cache hits.
    pub fn integrity_fails(&self) -> u64 {
        self.cache.integrity_fails()
    }

    /// Batches executed in degraded (interpreted-oracle) mode.
    pub fn degraded_runs(&self) -> u64 {
        self.degraded_runs.load(Ordering::Relaxed)
    }

    /// Transient lane faults detected (by redundant re-execution) and
    /// corrected so far.
    pub fn transient_corrected(&self) -> u64 {
        self.transient_corrected.load(Ordering::Relaxed)
    }

    /// Model keys currently pinned to the degraded oracle backend.
    pub fn degraded_keys(&self) -> usize {
        lock_clean(&self.strikes).degraded_keys()
    }

    /// Bound on distinct keys the integrity-strike ledger tracks.
    pub fn strike_cap(&self) -> usize {
        STRIKE_CAP
    }

    /// Keys evicted from the strike ledger to stay within the cap.
    pub fn strike_evictions(&self) -> u64 {
        lock_clean(&self.strikes).evictions
    }

    /// Record one integrity strike against a key.
    fn note_integrity_strike(&self, key: &ModelKey) {
        lock_clean(&self.strikes).strike(key);
    }

    /// Whether a key has struck out and runs on the oracle backend.
    fn is_degraded(&self, key: &ModelKey) -> bool {
        lock_clean(&self.strikes).is_degraded(key)
    }

    /// Synthesize a deterministic request batch for a model (quantized
    /// random activations, as the serving examples use).
    pub fn gen_requests(model: &str, n: usize, seed: u64) -> Result<Vec<QTensor>> {
        let shape = input_shape(model)?;
        let params = QuantParams::new(ModelConfig::default().act_scale, 0)?;
        let mut rng = Pcg32::new(seed);
        Ok((0..n).map(|_| random_input(shape.clone(), params, &mut rng)).collect())
    }

    /// Build the execution backend for a spec under this engine's options.
    fn backend(&self, assignment: &DesignAssignment) -> Box<dyn ExecBackend> {
        assigned_backend_full(
            assignment,
            self.opts.verify,
            self.opts.exec_mode,
            self.tiling.clone(),
            self.opts.host_kernel,
        )
    }

    /// Fetch (or build) the prepared model for a spec.
    pub fn prepared(&self, spec: &BatchSpec) -> Result<(Arc<PreparedModel>, bool)> {
        let backend = self.backend(&spec.assignment);
        self.prepared_with(spec, backend.as_ref())
    }

    fn prepared_with(
        &self,
        spec: &BatchSpec,
        backend: &dyn ExecBackend,
    ) -> Result<(Arc<PreparedModel>, bool)> {
        self.cache.get_or_prepare(&spec.key(), || {
            let mut info = build_model(&spec.model, &spec.model_config())?;
            apply_sparsity(&mut info.graph, spec.x_us, spec.x_ss);
            backend.prepare(&info.graph)
        })
    }

    /// Execute a batch of requests, scheduling them across the worker
    /// pool, and aggregate the per-request reports.
    ///
    /// When a fault plan is installed, this is also where the memory SEU
    /// and transient-compute fault sites live — and where the recovery
    /// ladder engages: the prepared cache detects corrupted models via
    /// the prepare-time checksum and transparently re-prepares; a key
    /// that keeps striking out is pinned to the interpreted-oracle
    /// backend (degraded but bit-trustworthy); transient lane faults are
    /// detected by redundant re-execution and answered with the clean
    /// re-run. With no plan every hook is a no-op.
    pub fn run_batch(&self, spec: &BatchSpec, requests: Vec<QTensor>) -> Result<BatchReport> {
        let t0 = Instant::now();
        let key = spec.key();
        // Chaos: flip bits in the *cached* prepared model before this
        // batch looks it up, exactly like an SEU landing between batches.
        // Best-effort by design — `corrupt_cached` only lands when no
        // other batch still holds the model.
        if let Some(plan) = &self.opts.faults {
            if let Some(mut rng) = plan.decide(FaultSite::WeightFlip) {
                self.cache.corrupt_cached(&key, |m| {
                    m.corrupt_weight_bit(&mut rng);
                });
            }
            if let Some(mut rng) = plan.decide(FaultSite::ArenaFlip) {
                self.cache.corrupt_cached(&key, |m| {
                    m.corrupt_arena_bit(&mut rng);
                });
            }
        }
        let build_backend: Arc<dyn ExecBackend> = Arc::from(self.backend(&spec.assignment));
        let (prepared, lookup) = self.cache.get_or_prepare_checked(&key, || {
            let mut info = build_model(&spec.model, &spec.model_config())?;
            apply_sparsity(&mut info.graph, spec.x_us, spec.x_ss);
            build_backend.prepare(&info.graph)
        })?;
        if lookup.integrity_evicted {
            self.note_integrity_strike(&key);
        }
        let cache_hit = lookup.hit;
        // Degradation ladder: a key with repeated integrity strikes runs
        // on the interpreted CFU oracle from now on. Outputs and cycle
        // totals are bit-identical to the default path (differential
        // tier), so degradation costs host speed only.
        let backend: Arc<dyn ExecBackend> = if self.is_degraded(&key) {
            self.degraded_runs.fetch_add(1, Ordering::Relaxed);
            Arc::from(assigned_backend_full(
                &spec.assignment,
                self.opts.verify,
                ExecMode::Interpreted,
                None,
                self.opts.host_kernel,
            ))
        } else {
            build_backend
        };
        let classes = prepared.classes;
        let n = requests.len();
        // Chunk so each job carries several requests: keeps channel
        // overhead negligible while still spreading a batch over every
        // worker.
        let chunk = n.div_ceil(self.pool.workers() * 4).max(1);
        let stats: Vec<Result<ReqStat>> = {
            let prepared = Arc::clone(&prepared);
            let backend = Arc::clone(&backend);
            let faults = self.opts.faults.clone();
            let corrected = Arc::clone(&self.transient_corrected);
            self.pool.map_chunked(requests, chunk, move |req| {
                let mut report = backend.execute(&prepared, &req)?;
                if let Some(plan) = &faults {
                    if let Some(mut rng) = plan.decide(FaultSite::LaneTransient) {
                        // Transient compute fault: this run's output is
                        // perturbed by one bit flip. Detection is real
                        // temporal redundancy — re-execute (the simulator
                        // is deterministic) and compare; on mismatch the
                        // clean re-run answers the request.
                        let mut faulty = report.output.data().to_vec();
                        if !faulty.is_empty() {
                            let i = rng.below(faulty.len() as u32) as usize;
                            faulty[i] ^= 1 << rng.below(8) as u8;
                        }
                        let redo = backend.execute(&prepared, &req)?;
                        if faulty.as_slice() != redo.output.data() {
                            corrected.fetch_add(1, Ordering::Relaxed);
                        }
                        report = redo;
                    }
                }
                let pred = crate::nn::activation::argmax(&report.output, classes)?[0];
                Ok(ReqStat {
                    cycles: report.total_cycles,
                    cfu_cycles: report.mac_cycles,
                    cfu_stalls: report.cfu_stalls(),
                    loaded_bytes: report.loaded_bytes(),
                    pred,
                })
            })
        };

        let mut latency = OnlineStats::new();
        let mut report = BatchReport {
            model: spec.model.clone(),
            assignment: spec.assignment.clone(),
            completed: 0,
            total_cycles: 0,
            cfu_cycles: 0,
            cfu_stalls: 0,
            loaded_bytes: 0,
            latency: OnlineStats::new(),
            latencies: Vec::with_capacity(n),
            p50: 0.0,
            p99: 0.0,
            wall_seconds: 0.0,
            cache_hit,
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            cache_evictions: self.cache.evictions(),
            predictions: Vec::with_capacity(n),
            request_cycles: Vec::with_capacity(n),
        };
        for s in stats {
            let s = s?;
            report.completed += 1;
            report.total_cycles += s.cycles;
            report.cfu_cycles += s.cfu_cycles;
            report.cfu_stalls += s.cfu_stalls;
            report.loaded_bytes += s.loaded_bytes;
            let seconds = s.cycles as f64 / self.opts.clock_hz as f64;
            // A non-finite sample (clock_hz 0, counter overflow) would
            // poison every percentile downstream — keep the invariant
            // that `latencies` holds only finite values.
            debug_assert!(seconds.is_finite(), "non-finite latency sample: {seconds}");
            if seconds.is_finite() {
                latency.push(seconds);
                report.latencies.push(seconds);
            }
            report.predictions.push(s.pred);
            report.request_cycles.push(s.cycles);
        }
        report.latency = latency;
        report.recompute_percentiles();
        report.wall_seconds = t0.elapsed().as_secs_f64();
        Ok(report)
    }

    /// Serve a request stream as consecutive batches of `batch` requests
    /// (the CLI `serve --batch N` path); later batches hit the
    /// prepared-model cache.
    pub fn run_stream(
        &self,
        spec: &BatchSpec,
        requests: Vec<QTensor>,
        batch: usize,
    ) -> Result<BatchReport> {
        let batch = batch.max(1);
        let mut merged: Option<BatchReport> = None;
        let mut rest = requests;
        while !rest.is_empty() {
            let tail = rest.split_off(rest.len().min(batch));
            let head = std::mem::replace(&mut rest, tail);
            let r = self.run_batch(spec, head)?;
            match &mut merged {
                Some(m) => m.absorb(&r),
                None => merged = Some(r),
            }
        }
        let mut merged = merged
            .ok_or_else(|| crate::error::Error::Coordinator("empty request stream".into()))?;
        // Percentiles once over the whole stream, not once per batch.
        merged.recompute_percentiles();
        Ok(merged)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec(design: DesignKind) -> BatchSpec {
        BatchSpec { scale: 0.07, ..BatchSpec::new("dscnn", design) }
    }

    #[test]
    fn batch_matches_sequential_engine() {
        let spec = tiny_spec(DesignKind::Csa);
        let reqs = BatchEngine::gen_requests("dscnn", 4, 11).unwrap();
        let engine = BatchEngine::new(BatchOptions { threads: 3, ..Default::default() });
        let report = engine.run_batch(&spec, reqs.clone()).unwrap();
        assert_eq!(report.completed, 4);

        // Reference: run the same prepared model sequentially.
        let (prepared, _) = engine.prepared(&spec).unwrap();
        let backend = crate::simulator::backend_for(DesignKind::Csa);
        let mut cycles = 0u64;
        for (r, &per_req) in reqs.iter().zip(&report.request_cycles) {
            let direct = backend.execute(&prepared, r).unwrap().total_cycles;
            assert_eq!(per_req, direct, "per-request cycles must match a direct run");
            cycles += direct;
        }
        assert_eq!(report.total_cycles, cycles);
        assert_eq!(report.request_cycles.len(), 4);
        assert!(report.cfu_cycles > 0);
        assert!(report.loaded_bytes > 0);
        assert!(report.p50 > 0.0 && report.p99 >= report.p50);
    }

    #[test]
    fn stream_reuses_cache_across_batches() {
        let spec = tiny_spec(DesignKind::Sssa);
        let reqs = BatchEngine::gen_requests("dscnn", 6, 12).unwrap();
        let engine = BatchEngine::new(BatchOptions { threads: 2, ..Default::default() });
        let report = engine.run_stream(&spec, reqs, 2).unwrap();
        assert_eq!(report.completed, 6);
        assert_eq!(report.predictions.len(), 6);
        // 3 batches: 1 miss then 2 hits.
        assert_eq!(engine.cache().misses(), 1);
        assert_eq!(engine.cache().hits(), 2);
    }

    #[test]
    fn report_emits_metric_record() {
        let spec = tiny_spec(DesignKind::Csa);
        let reqs = BatchEngine::gen_requests("dscnn", 3, 21).unwrap();
        let engine = BatchEngine::new(BatchOptions::default());
        let report = engine.run_batch(&spec, reqs).unwrap();
        let rec = report.to_metric("e2e/dscnn/CSA/t1", &spec, 3, 1, 100_000_000);
        assert_eq!(rec.id, "e2e/dscnn/CSA/t1");
        assert_eq!(rec.model, "dscnn");
        assert_eq!(rec.design, "CSA");
        assert_eq!(rec.get("total_cycles"), Some(report.total_cycles as f64));
        assert!(rec.get("p99_ms").unwrap() >= rec.get("p50_ms").unwrap());
        assert!(rec.get("host_infer_per_s").unwrap() > 0.0);
        // Cycle metrics must be gated, wall metrics must not.
        assert!(crate::metrics::spec_for("total_cycles").gate);
        assert!(!crate::metrics::spec_for("wall_s").gate);
        assert!(!crate::metrics::spec_for("host_infer_per_s").gate);
    }

    #[test]
    fn every_exec_mode_matches_batched_default_engine() {
        // The full engine path under the per-lane compiled mode and the
        // interpreted oracle must land on the same cycles, stalls and
        // predictions as the batch-amortized default.
        let spec = tiny_spec(DesignKind::Csa);
        let reqs = BatchEngine::gen_requests("dscnn", 3, 31).unwrap();
        let batched = BatchEngine::new(BatchOptions::default());
        let a = batched.run_batch(&spec, reqs.clone()).unwrap();
        for mode in [ExecMode::Compiled, ExecMode::Interpreted] {
            let other = BatchEngine::new(BatchOptions { exec_mode: mode, ..Default::default() });
            let b = other.run_batch(&spec, reqs.clone()).unwrap();
            assert_eq!(a.total_cycles, b.total_cycles, "{}", mode.name());
            assert_eq!(a.cfu_cycles, b.cfu_cycles, "{}", mode.name());
            assert_eq!(a.cfu_stalls, b.cfu_stalls, "{}", mode.name());
            assert_eq!(a.loaded_bytes, b.loaded_bytes, "{}", mode.name());
            assert_eq!(a.predictions, b.predictions, "{}", mode.name());
        }
    }

    #[test]
    fn intra_layer_tiling_invariant_and_composes_with_request_threads() {
        // tile_threads must change neither outputs nor any simulated
        // counter, at any (request-threads × tile-threads) combination.
        let spec = tiny_spec(DesignKind::Csa);
        let reqs = BatchEngine::gen_requests("dscnn", 4, 51).unwrap();
        let base = BatchEngine::new(BatchOptions { threads: 1, ..Default::default() });
        assert_eq!(base.tile_workers(), 0, "tiling off by default");
        let a = base.run_batch(&spec, reqs.clone()).unwrap();
        for (threads, tile_threads) in [(1usize, 3usize), (2, 2), (3, 4)] {
            let engine = BatchEngine::new(BatchOptions {
                threads,
                tile_threads,
                ..Default::default()
            });
            assert_eq!(engine.tile_workers(), tile_threads);
            let b = engine.run_batch(&spec, reqs.clone()).unwrap();
            let tag = format!("threads={threads} tiles={tile_threads}");
            assert_eq!(a.total_cycles, b.total_cycles, "{tag}: cycles");
            assert_eq!(a.cfu_cycles, b.cfu_cycles, "{tag}: cfu");
            assert_eq!(a.cfu_stalls, b.cfu_stalls, "{tag}: stalls");
            assert_eq!(a.loaded_bytes, b.loaded_bytes, "{tag}: bytes");
            assert_eq!(a.predictions, b.predictions, "{tag}: predictions");
        }
    }

    #[test]
    fn forced_host_kernels_match_default_engine() {
        // The engine under every available host kernel (and the scalar
        // oracle) must produce the same cycles, stalls and predictions as
        // the Auto default.
        let spec = tiny_spec(DesignKind::Csa);
        let reqs = BatchEngine::gen_requests("dscnn", 3, 61).unwrap();
        let auto = BatchEngine::new(BatchOptions::default());
        let a = auto.run_batch(&spec, reqs.clone()).unwrap();
        for kernel in HostKernel::available_kernels() {
            let forced =
                BatchEngine::new(BatchOptions { host_kernel: kernel, ..Default::default() });
            let b = forced.run_batch(&spec, reqs.clone()).unwrap();
            assert_eq!(a.total_cycles, b.total_cycles, "{kernel}: cycles");
            assert_eq!(a.cfu_cycles, b.cfu_cycles, "{kernel}: cfu");
            assert_eq!(a.cfu_stalls, b.cfu_stalls, "{kernel}: stalls");
            assert_eq!(a.loaded_bytes, b.loaded_bytes, "{kernel}: bytes");
            assert_eq!(a.predictions, b.predictions, "{kernel}: predictions");
        }
    }

    #[test]
    fn report_carries_cache_counters() {
        let spec = tiny_spec(DesignKind::Sssa);
        let reqs = BatchEngine::gen_requests("dscnn", 4, 32).unwrap();
        let engine = BatchEngine::new(BatchOptions::default());
        let streamed = engine.run_stream(&spec, reqs, 2).unwrap();
        // 2 batches: 1 build then 1 hit, no evictions at default capacity.
        assert_eq!(streamed.cache_misses, 1);
        assert_eq!(streamed.cache_hits, 1);
        assert_eq!(streamed.cache_evictions, 0);
    }

    #[test]
    fn tiny_cache_capacity_evicts_and_still_serves() {
        let reqs = BatchEngine::gen_requests("dscnn", 1, 33).unwrap();
        let engine =
            BatchEngine::new(BatchOptions { cache_capacity: 1, ..Default::default() });
        let a = engine.run_batch(&tiny_spec(DesignKind::Csa), reqs.clone()).unwrap();
        let b = engine.run_batch(&tiny_spec(DesignKind::Ussa), reqs.clone()).unwrap();
        let c = engine.run_batch(&tiny_spec(DesignKind::Csa), reqs).unwrap();
        assert_eq!(a.completed + b.completed + c.completed, 3);
        // Capacity 1: the USSA build evicted CSA, the CSA re-run evicted
        // USSA — every batch was a build, two were evictions.
        assert_eq!(engine.cache().misses(), 3);
        assert_eq!(engine.cache().evictions(), 2);
        assert_eq!(c.cache_evictions, 2);
        assert_eq!(engine.cache().len(), 1);
        // Correctness is unaffected by eviction (same prepared weights).
        assert_eq!(a.total_cycles, c.total_cycles);
        assert_eq!(a.predictions, c.predictions);
    }

    #[test]
    fn heterogeneous_spec_runs_and_matches_direct_engine() {
        let assignment =
            DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::BaselineSimd]);
        let spec = BatchSpec { scale: 0.07, ..BatchSpec::assigned("dscnn", assignment.clone()) };
        let reqs = BatchEngine::gen_requests("dscnn", 3, 41).unwrap();
        let engine = BatchEngine::new(BatchOptions::default());
        let report = engine.run_batch(&spec, reqs.clone()).unwrap();
        assert_eq!(report.completed, 3);
        assert_eq!(report.design_label(), "hetero:sb");
        // Agreement with the heterogeneous engine driven directly.
        let (prepared, _) = engine.prepared(&spec).unwrap();
        let backend =
            crate::simulator::assigned_backend_with_mode(&assignment, false, ExecMode::Compiled);
        let mut cycles = 0u64;
        for r in &reqs {
            cycles += backend.execute(&prepared, r).unwrap().total_cycles;
        }
        assert_eq!(report.total_cycles, cycles);
        // A uniform spec afterwards must not alias the heterogeneous key.
        let uni = BatchSpec { scale: 0.07, ..BatchSpec::new("dscnn", DesignKind::Sssa) };
        engine.run_batch(&uni, reqs).unwrap();
        assert_eq!(engine.cache().misses(), 2);
    }

    #[test]
    fn zero_rate_fault_plan_changes_nothing() {
        let spec = tiny_spec(DesignKind::Csa);
        let reqs = BatchEngine::gen_requests("dscnn", 3, 77).unwrap();
        let clean = BatchEngine::new(BatchOptions::default());
        let a = clean.run_batch(&spec, reqs.clone()).unwrap();
        let chaotic = BatchEngine::new(BatchOptions {
            faults: Some(Arc::new(FaultPlan::disabled())),
            ..Default::default()
        });
        let b = chaotic.run_batch(&spec, reqs).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.request_cycles, b.request_cycles);
        assert_eq!(chaotic.fault_plan().unwrap().total_injected(), 0);
        assert_eq!(chaotic.integrity_fails(), 0);
        assert_eq!(chaotic.degraded_runs(), 0);
        assert_eq!(chaotic.transient_corrected(), 0);
    }

    #[test]
    fn transient_lane_faults_are_corrected_and_invisible_in_answers() {
        let spec = tiny_spec(DesignKind::Csa);
        let reqs = BatchEngine::gen_requests("dscnn", 4, 88).unwrap();
        let clean = BatchEngine::new(BatchOptions::default());
        let a = clean.run_batch(&spec, reqs.clone()).unwrap();
        let plan = Arc::new(crate::faults::FaultPlan::new(
            9,
            crate::faults::FaultRates { lane_transient: 1.0, ..Default::default() },
        ));
        let chaotic = BatchEngine::new(BatchOptions {
            threads: 2,
            faults: Some(Arc::clone(&plan)),
            ..Default::default()
        });
        let b = chaotic.run_batch(&spec, reqs).unwrap();
        // Every request faulted; redundant re-execution detected each
        // one and answered with the clean run — responses and cycle
        // accounting are indistinguishable from the fault-free engine.
        assert_eq!(plan.injected(FaultSite::LaneTransient), 4);
        assert_eq!(chaotic.transient_corrected(), 4);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.request_cycles, b.request_cycles);
    }

    #[test]
    fn repeated_corruption_degrades_key_to_oracle_with_clean_answers() {
        let spec = tiny_spec(DesignKind::Csa);
        let reqs = BatchEngine::gen_requests("dscnn", 2, 99).unwrap();
        let engine = BatchEngine::new(BatchOptions::default());
        let baseline = engine.run_batch(&spec, reqs.clone()).unwrap();
        // Corrupt the cached model in place before each of the next two
        // batches: each lookup detects the mismatch, evicts, re-prepares
        // and strikes the key; at two strikes the key degrades.
        let key = spec.key();
        let mut rng = Pcg32::new(5);
        for round in 0..2u32 {
            assert!(
                engine.cache().corrupt_cached(&key, |m| {
                    assert!(m.corrupt_arena_bit(&mut rng));
                }),
                "round {round}: cache must hold the sole reference between batches"
            );
            let r = engine.run_batch(&spec, reqs.clone()).unwrap();
            // Detection + re-prepare keeps every answer bit-identical.
            assert_eq!(r.predictions, baseline.predictions, "round {round}");
            assert_eq!(r.total_cycles, baseline.total_cycles, "round {round}");
        }
        assert_eq!(engine.integrity_fails(), 2);
        assert_eq!(engine.degraded_keys(), 1);
        // The degraded batch runs on the interpreted oracle — same bits.
        let degraded = engine.run_batch(&spec, reqs).unwrap();
        assert_eq!(engine.degraded_runs(), 1);
        assert_eq!(degraded.predictions, baseline.predictions);
        assert_eq!(degraded.total_cycles, baseline.total_cycles);
        assert_eq!(degraded.request_cycles, baseline.request_cycles);
    }

    #[test]
    fn strike_ledger_is_bounded_and_counts_evictions() {
        fn key(seed: u64) -> ModelKey {
            ModelKey::assigned(
                "dscnn",
                DesignAssignment::Uniform(DesignKind::Csa),
                0.5,
                0.3,
                0.07,
                seed,
            )
        }
        let mut ledger = StrikeLedger::new(4);
        // Degrade one key, then storm many distinct keys past the cap.
        for _ in 0..DEGRADE_STRIKES {
            ledger.strike(&key(0));
        }
        assert!(ledger.is_degraded(&key(0)));
        for seed in 1..=8u64 {
            ledger.strike(&key(seed));
        }
        assert!(ledger.entries.len() <= 4, "ledger must stay within its cap");
        assert_eq!(ledger.evictions, 5, "9 distinct keys through a cap of 4");
        // The degraded key was least-recently-touched once the storm
        // rolled through — bounded memory trades away its pin.
        assert!(!ledger.is_degraded(&key(0)));
        // Re-striking a resident key refreshes recency without evicting.
        ledger.strike(&key(8));
        assert_eq!(ledger.evictions, 5);
        // The engine surfaces the cap and eviction counter.
        let engine = BatchEngine::new(BatchOptions::default());
        assert_eq!(engine.strike_cap(), STRIKE_CAP);
        assert_eq!(engine.strike_evictions(), 0);
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let spec = tiny_spec(DesignKind::Ussa);
        let reqs = BatchEngine::gen_requests("dscnn", 5, 13).unwrap();
        let one = BatchEngine::new(BatchOptions { threads: 1, ..Default::default() });
        let four = BatchEngine::new(BatchOptions { threads: 4, ..Default::default() });
        let a = one.run_batch(&spec, reqs.clone()).unwrap();
        let b = four.run_batch(&spec, reqs).unwrap();
        assert_eq!(a.total_cycles, b.total_cycles);
        assert_eq!(a.predictions, b.predictions);
        assert_eq!(a.cfu_stalls, b.cfu_stalls);
    }
}
