//! Closed-loop inference serving over the cycle simulator.
//!
//! Models the deployed TinyML system: requests arrive, worker threads run
//! them through the prepared (encoded) model, and metrics track both the
//! *simulated device time* (cycles at the SoC clock) and host wall time.
//! Demonstrates that the rust coordinator owns the request path end to
//! end — Python never appears here.
//!
//! Since engine v2 the server drives the design through the
//! [`crate::simulator::ExecBackend`] trait (the same substrate as
//! [`super::batch::BatchEngine`]), so swapping the execution backend
//! never touches the serving loop.

use super::scheduler::JobPool;
use crate::error::Result;
use crate::isa::{DesignAssignment, DesignKind};
use crate::kernels::HostKernel;
use crate::nn::graph::Graph;
use crate::simulator::{assigned_backend_full, ExecBackend, PreparedModel};
use crate::tensor::QTensor;
use crate::util::stats::{OnlineStats, Percentiles};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Server options.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// SoC clock for simulated-latency conversion.
    pub clock_hz: u64,
    /// Verify outputs against the reference ops.
    pub verify: bool,
    /// Host-side multiply kernel for the batched path ([`HostKernel`]):
    /// `Auto` picks the fastest available SWAR/SIMD routine. Predictions
    /// and simulated cycles are invariant in this choice.
    pub host_kernel: HostKernel,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            threads: 0,
            clock_hz: 100_000_000,
            verify: false,
            host_kernel: HostKernel::Auto,
        }
    }
}

/// Serving metrics.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    /// Requests completed.
    pub completed: u64,
    /// Simulated device latency stats (seconds at the SoC clock).
    pub sim_latency: OnlineStats,
    /// Simulated latency percentiles.
    pub sim_percentiles: Percentiles,
    /// Host wall-clock seconds for the whole batch.
    pub wall_seconds: f64,
    /// Total simulated cycles.
    pub total_cycles: u64,
    /// CFU stall cycles over the batch (multi-cycle MAC waits).
    pub cfu_stalls: u64,
    /// Bytes loaded by the simulated kernels over the batch.
    pub loaded_bytes: u64,
}

impl ServeMetrics {
    /// Simulated device throughput (inferences/sec at the SoC clock),
    /// assuming sequential execution on the single-core SoC.
    pub fn sim_throughput(&self) -> f64 {
        let mean = self.sim_latency.mean();
        if mean <= 0.0 {
            return 0.0;
        }
        1.0 / mean
    }

    /// Host-side throughput (inferences per wall second).
    pub fn host_throughput(&self) -> f64 {
        if self.wall_seconds <= 0.0 {
            return 0.0;
        }
        self.completed as f64 / self.wall_seconds
    }
}

/// An inference server bound to one design assignment.
pub struct Server {
    backend: Arc<dyn ExecBackend>,
    prepared: Arc<PreparedModel>,
    pool: JobPool,
    clock_hz: u64,
}

impl Server {
    /// Prepare a model for serving on one uniform design.
    pub fn new(graph: &Graph, design: DesignKind, opts: &ServeOptions) -> Result<Self> {
        Server::new_assigned(graph, &DesignAssignment::Uniform(design), opts)
    }

    /// Prepare a model for serving on a (possibly heterogeneous)
    /// per-layer assignment — e.g. the explorer's argmin fed straight
    /// into the serving loop.
    pub fn new_assigned(
        graph: &Graph,
        assignment: &DesignAssignment,
        opts: &ServeOptions,
    ) -> Result<Self> {
        let backend: Arc<dyn ExecBackend> = Arc::from(assigned_backend_full(
            assignment,
            opts.verify,
            crate::kernels::ExecMode::default(),
            None,
            opts.host_kernel,
        ));
        let prepared = Arc::new(backend.prepare(graph)?);
        Ok(Server {
            backend,
            prepared,
            pool: JobPool::new(opts.threads),
            clock_hz: opts.clock_hz,
        })
    }

    /// Assignment served (uniform for the single-design constructor).
    pub fn assignment(&self) -> DesignAssignment {
        self.backend.assignment()
    }

    /// Serve a batch of requests; returns per-request predicted classes
    /// and aggregate metrics.
    pub fn serve_batch(&self, requests: Vec<QTensor>) -> Result<(Vec<usize>, ServeMetrics)> {
        let t0 = Instant::now();
        let backend = Arc::clone(&self.backend);
        let prepared = Arc::clone(&self.prepared);
        let classes = self.prepared.classes;
        let metrics = Arc::new(Mutex::new(ServeMetrics::default()));
        let clock = self.clock_hz;
        let m2 = Arc::clone(&metrics);
        let outputs: Vec<Result<usize>> = self.pool.map(requests, move |req| {
            let report = backend.execute(&prepared, &req)?;
            let pred = crate::nn::activation::argmax(&report.output, classes)?[0];
            let mut m = m2.lock().unwrap();
            m.completed += 1;
            m.total_cycles += report.total_cycles;
            m.cfu_stalls += report.cfu_stalls();
            m.loaded_bytes += report.loaded_bytes();
            let lat = report.seconds_at(clock);
            m.sim_latency.push(lat);
            m.sim_percentiles.push(lat);
            Ok(pred)
        });
        let mut preds = Vec::with_capacity(outputs.len());
        for o in outputs {
            preds.push(o?);
        }
        // Workers may still hold their Arc clones for an instant after
        // delivering results, so clone out of the mutex instead of
        // unwrapping the Arc.
        let mut metrics = metrics.lock().unwrap().clone();
        metrics.wall_seconds = t0.elapsed().as_secs_f64();
        Ok((preds, metrics))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::builder::{apply_sparsity, random_input, ModelConfig};
    use crate::models::zoo::build_model;
    use crate::util::Pcg32;

    #[test]
    fn serves_batch_with_metrics() {
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, 0.5, 0.3);
        let server = Server::new(
            &info.graph,
            DesignKind::Csa,
            &ServeOptions { threads: 2, ..Default::default() },
        )
        .unwrap();
        let mut rng = Pcg32::new(5);
        let reqs: Vec<QTensor> = (0..6)
            .map(|_| random_input(info.input_shape.clone(), cfg.act_params(), &mut rng))
            .collect();
        let (preds, metrics) = server.serve_batch(reqs).unwrap();
        assert_eq!(preds.len(), 6);
        assert!(preds.iter().all(|&p| p < 12));
        assert_eq!(metrics.completed, 6);
        assert!(metrics.total_cycles > 0);
        assert!(metrics.loaded_bytes > 0);
        assert!(metrics.sim_latency.mean() > 0.0);
        assert!(metrics.wall_seconds > 0.0);
        assert!(metrics.sim_throughput() > 0.0);
        assert!(metrics.host_throughput() > 0.0);
    }

    #[test]
    fn deterministic_predictions_across_designs() {
        // Same INT7 weights ⇒ every design must predict identically.
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, 0.4, 0.2);
        let mut rng = Pcg32::new(6);
        let reqs: Vec<QTensor> = (0..3)
            .map(|_| random_input(info.input_shape.clone(), cfg.act_params(), &mut rng))
            .collect();
        let mut all_preds = Vec::new();
        for design in [DesignKind::BaselineSimd, DesignKind::Ussa, DesignKind::Csa] {
            let server =
                Server::new(&info.graph, design, &ServeOptions::default()).unwrap();
            assert_eq!(server.assignment(), DesignAssignment::Uniform(design));
            let (preds, _) = server.serve_batch(reqs.clone()).unwrap();
            all_preds.push(preds);
        }
        assert_eq!(all_preds[0], all_preds[1]);
        assert_eq!(all_preds[0], all_preds[2]);
    }

    #[test]
    fn heterogeneous_server_serves_verified() {
        // A per-layer assignment drives the same serving loop, with
        // bit-exact verification on, and predicts identically to a
        // uniform server (INT7 weights ⇒ design-invariant outputs).
        let cfg = ModelConfig { scale: 0.07, ..Default::default() };
        let mut info = build_model("dscnn", &cfg).unwrap();
        apply_sparsity(&mut info.graph, 0.5, 0.3);
        let assignment =
            DesignAssignment::per_layer(vec![DesignKind::Sssa, DesignKind::BaselineSimd]);
        let server = Server::new_assigned(
            &info.graph,
            &assignment,
            &ServeOptions { verify: true, ..Default::default() },
        )
        .unwrap();
        assert_eq!(server.assignment(), assignment);
        let mut rng = Pcg32::new(8);
        let reqs: Vec<QTensor> = (0..4)
            .map(|_| random_input(info.input_shape.clone(), cfg.act_params(), &mut rng))
            .collect();
        let (preds, metrics) = server.serve_batch(reqs.clone()).unwrap();
        assert_eq!(preds.len(), 4);
        assert!(metrics.total_cycles > 0);
        let uniform = Server::new(&info.graph, DesignKind::Sssa, &ServeOptions::default()).unwrap();
        let (uni_preds, _) = uniform.serve_batch(reqs).unwrap();
        assert_eq!(preds, uni_preds);
    }
}
