//! Fleet-scale multi-device serving: placement, replication, failover.
//!
//! A [`Fleet`] simulates N small-FPGA devices, each a full [`BatchEngine`]
//! with its own prepared-model cache and a per-device resource budget
//! (LUT/FF/BRAM/DSP, costed from `resources::fpga::estimate_cfu`). In
//! front of the devices sits a placement/routing layer:
//!
//! - **Cache-affinity routing** — a model spec is *placed* on one or more
//!   devices; requests for that spec are only ever routed to holders, so
//!   each device's `PreparedCache` stays warm for the models it owns.
//! - **Replication for hot models** — once a spec's hit count crosses
//!   `hot_threshold`, it is replicated (best-fit by LUT headroom) up to
//!   `replicas` devices.
//! - **Admission** — a request is shed (503) only when *every* replica of
//!   its spec is saturated (per-device backlog at `device_queue`).
//!
//! The robustness core mirrors PR 8 one level up. Device-level fault
//! sites ([`FaultSite::DeviceCrash`], [`FaultSite::DeviceSlow`],
//! [`FaultSite::DeviceCorrupt`]) crash a device, put it in a slow spell,
//! or confine a persistent-corruption storm to it. The router detects a
//! dead device either at send time or via periodic health probes; an
//! accepted request whose device died is **failed over** to a surviving
//! replica and the dead device's models are re-placed under the resource
//! budget. The fleet-wide ledger invariant is preserved throughout:
//! `accepted == completed + failed`, with shed requests accounted
//! separately — no request is ever lost to a crash.
//!
//! Determinism: device selection, placement, fault decisions, and the
//! tenant trace generator are all pure functions of seeds and submission
//! order, and simulated cycle totals come from prepare-time schedules, so
//! outputs *and* cycle counts are bit-identical across replays and
//! invariant to which replica served a request.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use super::batch::{BatchEngine, BatchOptions, BatchReport, BatchSpec};
use super::loadgen::{arrival_offsets, Arrival, TraceConfig};
use super::lock_clean;
use crate::config::Value;
use crate::error::{Error, Result};
use crate::faults::{FaultPlan, FaultSite};
use crate::isa::DesignKind;
use crate::metrics::MetricRecord;
use crate::resources::{estimate_cfu, ResourceUsage};
use crate::tensor::QTensor;
use crate::util::{Pcg32, Percentiles};

/// Virtual-time service multiplier while a device is in a slow spell.
const SLOW_FACTOR: f64 = 8.0;
/// Stream tag for storm bit-flip RNGs (odd, fixed).
const STORM_TAG: u64 = 0x5707_0051_0B17_F11B;
/// Stream tag for the Zipf tenant-popularity stream.
const ZIPF_TAG: u64 = 0x21BF_7E4A_0D15_7A1F;
/// Stream tag for per-request input seeds.
const INPUT_TAG: u64 = 0x1A9B_0CAF_E77E_4A57;

/// Fleet construction options.
#[derive(Debug, Clone)]
pub struct FleetOptions {
    /// Number of simulated devices (clamped to at least 1).
    pub devices: usize,
    /// Replication factor for hot models.
    pub replicas: usize,
    /// Spec hit count at which a model is considered hot and replicated.
    pub hot_threshold: u64,
    /// Per-device backlog bound; admission sheds only when every replica
    /// is at this bound.
    pub device_queue: usize,
    /// Health-probe period in submissions (every N-th submission probes
    /// all devices).
    pub probe_every: u64,
    /// Virtual-time request deadline in seconds; a sojourn beyond it
    /// counts as a deadline miss and flags slow devices.
    pub deadline_s: f64,
    /// Per-device CFU resource budget (over `BASELINE_SOC`).
    pub budget: ResourceUsage,
    /// Options for each device's `BatchEngine`.
    pub engine: BatchOptions,
    /// Device-level fault plan (also handed to each engine via
    /// `engine.faults` by callers that want engine-level sites armed).
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            devices: 3,
            replicas: 2,
            hot_threshold: 8,
            device_queue: 64,
            probe_every: 4,
            deadline_s: 0.050,
            budget: ResourceUsage { luts: 300, ffs: 400, brams: 2, dsps: 6 },
            engine: BatchOptions::default(),
            faults: None,
        }
    }
}

/// A completed routed batch.
#[derive(Debug)]
pub struct Routed {
    /// Device that produced the result.
    pub device: usize,
    /// Whether the batch was re-routed after its first device died.
    pub failed_over: bool,
    /// The engine report (bit-identical to a single-engine run).
    pub report: BatchReport,
}

/// Outcome of a fleet submission.
#[derive(Debug)]
pub enum Submission {
    /// The batch ran on a device (possibly after failover).
    Done(Routed),
    /// Every replica was saturated, or no device is alive: 503.
    Shed,
}

/// Router-side state for one device.
struct DeviceCtl {
    /// Ground truth: the device still answers.
    alive: bool,
    /// Router knowledge: the device has been observed dead (probe or
    /// send-time failure) and its models re-placed.
    detected_dead: bool,
    /// Ground truth: slow spell active until this submission sequence.
    slow_until: u64,
    /// Router knowledge: deadline misses or probes flagged the device
    /// slow; routing prefers other replicas until a probe clears it.
    detected_slow: bool,
    /// Corruption storm confined to this device until this sequence.
    storm_until: u64,
    /// Resource budget consumed by placed models.
    used: ResourceUsage,
    /// Placed model specs with their resource cost, oldest first.
    placed: Vec<(String, ResourceUsage)>,
    /// Requests currently executing on the device.
    inflight: u64,
    /// Virtual completion times of queued work (monotonic per device).
    queue_done: Vec<f64>,
    /// Latest virtual completion time ever observed.
    last_done: f64,
    /// Busy time (virtual service time, or wall time in live mode).
    busy_s: f64,
    /// Requests completed by this device.
    completed: u64,
    /// Simulated cycles accumulated by this device.
    cycles: u64,
}

impl DeviceCtl {
    fn new() -> DeviceCtl {
        DeviceCtl {
            alive: true,
            detected_dead: false,
            slow_until: 0,
            detected_slow: false,
            storm_until: 0,
            used: ResourceUsage::default(),
            placed: Vec::new(),
            inflight: 0,
            queue_done: Vec::new(),
            last_done: 0.0,
            busy_s: 0.0,
            completed: 0,
            cycles: 0,
        }
    }
}

/// Placement record for one model spec.
#[derive(Default)]
struct PlaceInfo {
    /// Devices currently holding the spec.
    devices: Vec<usize>,
    /// Routed request count (drives hot-model replication).
    hits: u64,
    /// Resource cost of one replica.
    cost: ResourceUsage,
}

/// Fleet-wide counters (the ledger plus robustness telemetry).
#[derive(Default)]
struct FleetCounters {
    accepted: u64,
    completed: u64,
    failed: u64,
    shed: u64,
    failovers: u64,
    rebalances: u64,
    replications: u64,
    evictions: u64,
    crashes: u64,
    slow_spells: u64,
    storms: u64,
    probes: u64,
    deadline_misses: u64,
    total_cycles: u64,
    failover_ms: Percentiles,
}

/// All mutable router state, behind one mutex.
struct FleetCtl {
    devs: Vec<DeviceCtl>,
    placements: HashMap<String, PlaceInfo>,
    seq: u64,
    counters: FleetCounters,
}

impl FleetCtl {
    /// Devices the router believes it can route to.
    fn routable_count(&self) -> usize {
        self.devs.iter().filter(|d| !d.detected_dead).count()
    }

    /// Routable devices currently holding `key`, ascending.
    fn holders(&self, key: &str) -> Vec<usize> {
        let info = self.placements.get(key);
        let mut v: Vec<usize> = info.map(|i| i.devices.clone()).unwrap_or_default();
        v.retain(|&d| !self.devs[d].detected_dead);
        v.sort_unstable();
        v
    }

    fn holder_count(&self, key: &str) -> usize {
        self.holders(key).len()
    }

    /// Replication target for `key`: 1 for cold specs, up to
    /// `opts.replicas` once the hit count crosses the hot threshold.
    fn desired_replicas(&self, key: &str, opts: &FleetOptions) -> usize {
        let routable = self.routable_count();
        if routable == 0 {
            return 0;
        }
        let hot = self.placements.get(key).is_some_and(|info| info.hits >= opts.hot_threshold);
        if hot {
            opts.replicas.clamp(1, routable)
        } else {
            1
        }
    }

    /// Place one replica of `key` on the best-fit device (max LUT
    /// headroom among routable non-holders that fit the budget). With
    /// `force`, availability beats budget: evict oldest-placed models
    /// from the roomiest device until the new one fits.
    fn place_one(
        &mut self,
        key: &str,
        cost: ResourceUsage,
        budget: &ResourceUsage,
        force: bool,
    ) -> Option<usize> {
        let holders = self.holders(key);
        let mut fit_best: Option<(u32, usize)> = None;
        let mut any_best: Option<(u32, usize)> = None;
        for (i, dev) in self.devs.iter().enumerate() {
            if dev.detected_dead || holders.contains(&i) {
                continue;
            }
            let head = budget.luts.saturating_sub(dev.used.luts);
            let better_fit = match fit_best {
                Some((h, _)) => head > h,
                None => true,
            };
            if fits(&dev.used, &cost, budget) && better_fit {
                fit_best = Some((head, i));
            }
            let better_any = match any_best {
                Some((h, _)) => head > h,
                None => true,
            };
            if better_any {
                any_best = Some((head, i));
            }
        }
        let target = match (fit_best, any_best) {
            (Some((_, i)), _) => i,
            (None, Some((_, i))) if force => i,
            _ => return None,
        };
        if fit_best.is_none() {
            while !fits(&self.devs[target].used, &cost, budget)
                && !self.devs[target].placed.is_empty()
            {
                let (evicted, _) = self.devs[target].placed.remove(0);
                if let Some(info) = self.placements.get_mut(&evicted) {
                    info.devices.retain(|&d| d != target);
                }
                self.counters.evictions += 1;
                self.devs[target].used = placed_usage(&self.devs[target].placed);
            }
        }
        self.devs[target].placed.push((key.to_string(), cost));
        self.devs[target].used = self.devs[target].used.add(&cost);
        self.placements
            .entry(key.to_string())
            .or_default()
            .devices
            .push(target);
        Some(target)
    }

    /// Ensure `key` is placed on its desired replica count; returns the
    /// routable holders, or `None` when no device can take it (fleet
    /// fully dead).
    fn ensure_placed(
        &mut self,
        key: &str,
        cost: ResourceUsage,
        opts: &FleetOptions,
        record_hit: bool,
    ) -> Option<Vec<usize>> {
        if self.routable_count() == 0 {
            return None;
        }
        {
            let info = self.placements.entry(key.to_string()).or_default();
            if record_hit {
                info.hits += 1;
            }
            info.cost = cost;
        }
        let desired = self.desired_replicas(key, opts).max(1);
        while self.holder_count(key) < desired {
            let scale_up = self.holder_count(key) >= 1;
            if self.place_one(key, cost, &opts.budget, !scale_up).is_none() {
                break;
            }
            if scale_up {
                self.counters.replications += 1;
            }
        }
        let holders = self.holders(key);
        if holders.is_empty() {
            None
        } else {
            Some(holders)
        }
    }

    /// React to an observed device death: mark it, drop its placements,
    /// and restore each displaced model's replication on survivors.
    fn on_dead_detected(&mut self, dead: usize, opts: &FleetOptions) {
        if self.devs[dead].detected_dead {
            return;
        }
        self.devs[dead].detected_dead = true;
        self.devs[dead].detected_slow = false;
        let moved = std::mem::take(&mut self.devs[dead].placed);
        self.devs[dead].used = ResourceUsage::default();
        for (key, cost) in moved {
            if let Some(info) = self.placements.get_mut(&key) {
                info.devices.retain(|&d| d != dead);
            }
            let desired = self.desired_replicas(&key, opts).max(1);
            while self.holder_count(&key) < desired {
                let force = self.holder_count(&key) == 0;
                if self.place_one(&key, cost, &opts.budget, force).is_none() {
                    break;
                }
                self.counters.rebalances += 1;
            }
        }
    }

    /// Periodic health probe: refresh slow flags from ground truth and
    /// detect crashed devices that have not yet failed a send.
    fn probe(&mut self, now: u64, opts: &FleetOptions) {
        self.counters.probes += self.devs.len() as u64;
        for d in self.devs.iter_mut() {
            d.detected_slow = d.slow_until > now;
        }
        let dead: Vec<usize> = self
            .devs
            .iter()
            .enumerate()
            .filter(|(_, d)| !d.alive && !d.detected_dead)
            .map(|(i, _)| i)
            .collect();
        for d in dead {
            self.on_dead_detected(d, opts);
        }
    }
}

/// Budget check for adding `cost` on top of `used`.
fn fits(used: &ResourceUsage, cost: &ResourceUsage, budget: &ResourceUsage) -> bool {
    let total = used.add(cost);
    total.luts <= budget.luts
        && total.ffs <= budget.ffs
        && total.brams <= budget.brams
        && total.dsps <= budget.dsps
}

/// Recompute a device's usage from its placed set (no subtraction on
/// `ResourceUsage`, so eviction recomputes).
fn placed_usage(placed: &[(String, ResourceUsage)]) -> ResourceUsage {
    placed.iter().fold(ResourceUsage::default(), |acc, (_, c)| acc.add(c))
}

/// Placement key for a spec — same shape as the net layer's queue key.
fn place_key(spec: &BatchSpec) -> String {
    format!(
        "{}|{}|{}|{}|{}|{}",
        spec.model,
        spec.assignment.label(),
        spec.x_us,
        spec.x_ss,
        spec.scale,
        spec.weight_seed
    )
}

/// Resource cost of one replica: the sum of CFU estimates over the
/// designs the assignment actually uses.
fn spec_cost(spec: &BatchSpec) -> ResourceUsage {
    spec.assignment
        .designs_used()
        .into_iter()
        .fold(ResourceUsage::default(), |acc, d| acc.add(&estimate_cfu(d)))
}

/// Pending work on a device as seen at `arrival_s` (virtual time), or
/// just in-flight batches in live mode.
fn backlog(dev: &DeviceCtl, arrival_s: Option<f64>) -> usize {
    let queued = match arrival_s {
        Some(at) => dev.queue_done.iter().filter(|&&done| done > at).count(),
        None => 0,
    };
    queued + dev.inflight as usize
}

/// Deterministic device choice: prefer not-slow, then least backlog,
/// then least lifetime cycles, then lowest id.
fn choose(ctl: &FleetCtl, candidates: &[usize], arrival_s: Option<f64>) -> usize {
    let mut best = candidates[0];
    for &d in &candidates[1..] {
        let dev = &ctl.devs[d];
        let cur = &ctl.devs[best];
        let kd = (dev.detected_slow, backlog(dev, arrival_s), dev.cycles, d);
        let kb = (cur.detected_slow, backlog(cur, arrival_s), cur.cycles, best);
        if kd < kb {
            best = d;
        }
    }
    best
}

/// Fire device-level fault sites for this submission. A crash always
/// hits the device the batch was just routed to (so every crash
/// exercises an accepted-request failover) and is suppressed when it
/// would kill the last live device; slow spells and storms pick a
/// seeded victim among live devices.
fn pump_faults(ctl: &mut FleetCtl, plan: &FaultPlan, serving: usize, now: u64) {
    if plan.decide(FaultSite::DeviceCrash).is_some() {
        let alive = ctl.devs.iter().filter(|d| d.alive).count();
        if alive >= 2 && ctl.devs[serving].alive {
            ctl.devs[serving].alive = false;
            ctl.counters.crashes += 1;
        }
    }
    if let Some(mut rng) = plan.decide(FaultSite::DeviceSlow) {
        let alive: Vec<usize> = ctl
            .devs
            .iter()
            .enumerate()
            .filter(|(_, d)| d.alive)
            .map(|(i, _)| i)
            .collect();
        if !alive.is_empty() {
            let v = alive[rng.below(alive.len() as u32) as usize];
            ctl.devs[v].slow_until = now + 3 + u64::from(rng.below(6));
            ctl.counters.slow_spells += 1;
        }
    }
    if let Some(mut rng) = plan.decide(FaultSite::DeviceCorrupt) {
        let alive: Vec<usize> = ctl
            .devs
            .iter()
            .enumerate()
            .filter(|(_, d)| d.alive)
            .map(|(i, _)| i)
            .collect();
        if !alive.is_empty() {
            let v = alive[rng.below(alive.len() as u32) as usize];
            ctl.devs[v].storm_until = now + 2 + u64::from(rng.below(4));
            ctl.counters.storms += 1;
        }
    }
}

/// N simulated devices behind a placement/routing layer with replica
/// failover. See the module docs for the full contract.
pub struct Fleet {
    engines: Vec<BatchEngine>,
    ctl: Mutex<FleetCtl>,
    opts: FleetOptions,
    started: Instant,
}

impl Fleet {
    /// Build a fleet of `opts.devices` engines (at least one).
    pub fn new(opts: FleetOptions) -> Fleet {
        let n = opts.devices.max(1);
        let opts = FleetOptions { devices: n, ..opts };
        let engines = (0..n).map(|_| BatchEngine::new(opts.engine.clone())).collect();
        let devs = (0..n).map(|_| DeviceCtl::new()).collect();
        Fleet {
            engines,
            ctl: Mutex::new(FleetCtl {
                devs,
                placements: HashMap::new(),
                seq: 0,
                counters: FleetCounters::default(),
            }),
            opts,
            started: Instant::now(),
        }
    }

    /// Construction options (devices clamped).
    pub fn options(&self) -> &FleetOptions {
        &self.opts
    }

    /// Number of devices in the fleet.
    pub fn device_count(&self) -> usize {
        self.engines.len()
    }

    /// Devices still alive (ground truth).
    pub fn alive_devices(&self) -> usize {
        lock_clean(&self.ctl).devs.iter().filter(|d| d.alive).count()
    }

    /// The engine simulating one device (tests and benches).
    pub fn engine(&self, device: usize) -> &BatchEngine {
        &self.engines[device]
    }

    /// Kill one device (chaos hook). Refuses to kill the last live
    /// device or an already-dead one; detection still happens through
    /// the normal probe/send paths.
    pub fn crash_device(&self, device: usize) -> bool {
        let mut ctl = lock_clean(&self.ctl);
        if device >= ctl.devs.len() || !ctl.devs[device].alive {
            return false;
        }
        if ctl.devs.iter().filter(|d| d.alive).count() <= 1 {
            return false;
        }
        ctl.devs[device].alive = false;
        ctl.counters.crashes += 1;
        true
    }

    /// Route and run one batch. `arrival_s` is the request's virtual
    /// arrival time (trace mode); `None` means live mode (wall-clock
    /// accounting, backlog from in-flight counts only).
    pub fn submit(
        &self,
        spec: &BatchSpec,
        requests: Vec<QTensor>,
        arrival_s: Option<f64>,
    ) -> Result<Submission> {
        let n = requests.len() as u64;
        if n == 0 {
            return Err(Error::Coordinator("empty fleet submission".into()));
        }
        let key = place_key(spec);
        let cost = spec_cost(spec);

        let (device, now, slow, storm, failed_over) = {
            let mut ctl = lock_clean(&self.ctl);
            ctl.seq += 1;
            let now = ctl.seq;
            if now % self.opts.probe_every.max(1) == 0 {
                ctl.probe(now, &self.opts);
            }
            let Some(holders) = ctl.ensure_placed(&key, cost, &self.opts, true) else {
                ctl.counters.shed += n;
                return Ok(Submission::Shed);
            };
            let cap = self.opts.device_queue.max(1);
            let open: Vec<usize> = holders
                .iter()
                .copied()
                .filter(|&d| backlog(&ctl.devs[d], arrival_s) < cap)
                .collect();
            if open.is_empty() {
                ctl.counters.shed += n;
                return Ok(Submission::Shed);
            }
            let mut device = choose(&ctl, &open, arrival_s);
            ctl.counters.accepted += n;
            if let Some(plan) = &self.opts.faults {
                pump_faults(&mut ctl, plan, device, now);
            }
            let mut failed_over = false;
            if !ctl.devs[device].alive {
                // Send-time failure detection: the accepted batch fails
                // over to a surviving replica and the dead device's
                // models are re-placed under the budget. The ledger
                // keeps the batch — it completes elsewhere or counts as
                // failed, never disappears.
                ctl.counters.failovers += n;
                ctl.on_dead_detected(device, &self.opts);
                device = loop {
                    let next = ctl.ensure_placed(&key, cost, &self.opts, false);
                    let Some(holders) = next else {
                        ctl.counters.failed += n;
                        return Err(Error::Coordinator(
                            "fleet: no surviving replica for failover".into(),
                        ));
                    };
                    let d2 = choose(&ctl, &holders, arrival_s);
                    if ctl.devs[d2].alive {
                        break d2;
                    }
                    ctl.on_dead_detected(d2, &self.opts);
                };
                failed_over = true;
            }
            ctl.devs[device].inflight += 1;
            let dev = &ctl.devs[device];
            (device, now, dev.slow_until > now, dev.storm_until > now, failed_over)
        };

        if storm {
            if let Some(plan) = &self.opts.faults {
                // Persistent-corruption storm confined to this device:
                // flip a cached weight bit before the run; the engine's
                // integrity check detects it and recovers (or degrades)
                // deterministically, so outputs stay bit-identical.
                let mut rng = Pcg32::new(plan.seed() ^ STORM_TAG).fork(now);
                self.engines[device].cache().corrupt_cached(&spec.key(), |m| {
                    m.corrupt_weight_bit(&mut rng);
                });
            }
        }
        if slow && arrival_s.is_none() {
            // Live mode has no virtual clock; model the hang as a real
            // stall so request deadlines can observe it.
            thread::sleep(Duration::from_millis(2));
        }

        let t0 = Instant::now();
        let result = self.engines[device].run_batch(spec, requests);
        let wall = t0.elapsed().as_secs_f64();

        let mut ctl = lock_clean(&self.ctl);
        ctl.devs[device].inflight = ctl.devs[device].inflight.saturating_sub(1);
        let report = match result {
            Ok(report) => report,
            Err(e) => {
                ctl.counters.failed += n;
                return Err(e);
            }
        };
        ctl.counters.completed += n;
        ctl.counters.total_cycles += report.total_cycles;
        let clock = self.opts.engine.clock_hz.max(1);
        let mut service = report.total_cycles as f64 / clock as f64;
        if slow {
            service *= SLOW_FACTOR;
        }
        let mut missed = false;
        {
            let dev = &mut ctl.devs[device];
            dev.completed += n;
            dev.cycles += report.total_cycles;
            match arrival_s {
                Some(at) => {
                    dev.busy_s += service;
                    dev.queue_done.retain(|&done| done > at);
                    let start = dev.queue_done.last().copied().unwrap_or(at).max(at);
                    let done = start + service;
                    dev.queue_done.push(done);
                    dev.last_done = dev.last_done.max(done);
                    missed = done - at > self.opts.deadline_s;
                }
                None => dev.busy_s += wall,
            }
        }
        if missed {
            ctl.counters.deadline_misses += n;
            // Request-deadline detection: a device that blows deadlines
            // during a slow spell is routed around until the next probe
            // observes it healthy again.
            if ctl.devs[device].slow_until > now {
                ctl.devs[device].detected_slow = true;
            }
        }
        if failed_over {
            ctl.counters.failover_ms.push(wall * 1e3);
        }
        Ok(Submission::Done(Routed { device, failed_over, report }))
    }

    /// Engine-compatible entry point: route one batch and return its
    /// report, turning a fleet-wide shed into an error (the net layer
    /// maps it to a 5xx).
    pub fn run_batch(&self, spec: &BatchSpec, requests: Vec<QTensor>) -> Result<BatchReport> {
        match self.submit(spec, requests, None)? {
            Submission::Done(routed) => Ok(routed.report),
            Submission::Shed => {
                Err(Error::Coordinator("fleet saturated: every replica at capacity".into()))
            }
        }
    }

    /// Integrity-check failures summed over all devices.
    pub fn integrity_fails(&self) -> u64 {
        self.engines.iter().map(|e| e.integrity_fails()).sum()
    }

    /// Degraded (oracle-path) runs summed over all devices.
    pub fn degraded_runs(&self) -> u64 {
        self.engines.iter().map(|e| e.degraded_runs()).sum()
    }

    /// Transparently re-prepared corruptions summed over all devices.
    pub fn transient_corrected(&self) -> u64 {
        self.engines.iter().map(|e| e.transient_corrected()).sum()
    }

    /// Currently-degraded model keys summed over all devices.
    pub fn degraded_keys(&self) -> usize {
        self.engines.iter().map(|e| e.degraded_keys()).sum()
    }

    /// Strike-ledger evictions summed over all devices.
    pub fn strike_evictions(&self) -> u64 {
        self.engines.iter().map(|e| e.strike_evictions()).sum()
    }

    /// Per-device strike-ledger capacity (uniform across the fleet).
    pub fn strike_cap(&self) -> usize {
        self.engines[0].strike_cap()
    }

    /// Snapshot the fleet-wide ledger, robustness counters, and
    /// per-device utilization/cache telemetry.
    pub fn report(&self) -> FleetReport {
        let mut ctl = lock_clean(&self.ctl);
        let wall = self.started.elapsed().as_secs_f64();
        let virtual_span = ctl.devs.iter().map(|d| d.last_done).fold(0.0_f64, f64::max);
        let span_s = if virtual_span > 0.0 {
            virtual_span
        } else {
            wall.max(1e-9)
        };
        let alive = ctl.devs.iter().filter(|d| d.alive).count();
        let per_device: Vec<DeviceReport> = ctl
            .devs
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let cache = self.engines[i].cache();
                let (hits, misses) = (cache.hits(), cache.misses());
                let lookups = hits + misses;
                DeviceReport {
                    device: i,
                    alive: d.alive,
                    placed: d.placed.len(),
                    completed: d.completed,
                    cycles: d.cycles,
                    utilization: (d.busy_s / span_s).min(1.0),
                    cache_hits: hits,
                    cache_misses: misses,
                    cache_hit_rate: if lookups > 0 {
                        hits as f64 / lookups as f64
                    } else {
                        0.0
                    },
                    integrity_fails: self.engines[i].integrity_fails(),
                    degraded_keys: self.engines[i].degraded_keys(),
                }
            })
            .collect();
        let fo = ctl.counters.failover_ms.count();
        let failover_p50_ms = if fo > 0 {
            ctl.counters.failover_ms.percentile(50.0)
        } else {
            0.0
        };
        let failover_p99_ms = if fo > 0 {
            ctl.counters.failover_ms.percentile(99.0)
        } else {
            0.0
        };
        let c = &ctl.counters;
        FleetReport {
            devices: self.engines.len(),
            alive,
            accepted: c.accepted,
            completed: c.completed,
            failed: c.failed,
            shed: c.shed,
            failovers: c.failovers,
            rebalances: c.rebalances,
            replications: c.replications,
            evictions: c.evictions,
            crashes: c.crashes,
            slow_spells: c.slow_spells,
            storms: c.storms,
            probes: c.probes,
            deadline_misses: c.deadline_misses,
            total_cycles: c.total_cycles,
            failover_p50_ms,
            failover_p99_ms,
            span_s,
            wall_seconds: wall,
            per_device,
        }
    }
}

/// Telemetry for one device in a [`FleetReport`].
#[derive(Debug, Clone)]
pub struct DeviceReport {
    /// Device id.
    pub device: usize,
    /// Still alive (ground truth).
    pub alive: bool,
    /// Models currently placed.
    pub placed: usize,
    /// Requests completed.
    pub completed: u64,
    /// Simulated cycles accumulated.
    pub cycles: u64,
    /// Busy fraction of the fleet span, in `[0, 1]`.
    pub utilization: f64,
    /// Prepared-cache hits.
    pub cache_hits: u64,
    /// Prepared-cache misses.
    pub cache_misses: u64,
    /// Hit fraction of cache lookups, in `[0, 1]`.
    pub cache_hit_rate: f64,
    /// Integrity-check failures on this device.
    pub integrity_fails: u64,
    /// Currently-degraded model keys on this device.
    pub degraded_keys: usize,
}

impl DeviceReport {
    /// JSON form.
    pub fn to_value(&self) -> Value {
        Value::obj(vec![
            ("device", Value::Num(self.device as f64)),
            ("alive", Value::Bool(self.alive)),
            ("placed", Value::Num(self.placed as f64)),
            ("completed", Value::Num(self.completed as f64)),
            ("cycles", Value::Num(self.cycles as f64)),
            ("utilization", Value::Num(self.utilization)),
            ("cache_hits", Value::Num(self.cache_hits as f64)),
            ("cache_misses", Value::Num(self.cache_misses as f64)),
            ("cache_hit_rate", Value::Num(self.cache_hit_rate)),
            ("integrity_fails", Value::Num(self.integrity_fails as f64)),
            ("degraded_keys", Value::Num(self.degraded_keys as f64)),
        ])
    }
}

/// Fleet-wide snapshot: ledger, robustness counters, per-device stats.
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// Devices in the fleet.
    pub devices: usize,
    /// Devices still alive.
    pub alive: usize,
    /// Requests admitted past the saturation check.
    pub accepted: u64,
    /// Accepted requests that produced a result.
    pub completed: u64,
    /// Accepted requests that errored (including failovers with no
    /// surviving replica).
    pub failed: u64,
    /// Requests shed because every replica was saturated.
    pub shed: u64,
    /// Accepted requests re-routed after their device died.
    pub failovers: u64,
    /// Replicas restored on survivors after device deaths.
    pub rebalances: u64,
    /// Hot-model replica scale-ups.
    pub replications: u64,
    /// Placements evicted by forced (availability-over-budget) placement.
    pub evictions: u64,
    /// Device crashes (injected plus `crash_device`).
    pub crashes: u64,
    /// Slow spells started.
    pub slow_spells: u64,
    /// Corruption storms started.
    pub storms: u64,
    /// Individual device health probes performed.
    pub probes: u64,
    /// Requests whose virtual sojourn exceeded the deadline.
    pub deadline_misses: u64,
    /// Simulated cycles over all completed batches.
    pub total_cycles: u64,
    /// Median wall latency of failed-over requests, ms (0 if none).
    pub failover_p50_ms: f64,
    /// p99 wall latency of failed-over requests, ms (0 if none).
    pub failover_p99_ms: f64,
    /// Fleet span: max virtual completion time, or wall time in live
    /// mode.
    pub span_s: f64,
    /// Wall-clock lifetime of the fleet at snapshot time.
    pub wall_seconds: f64,
    /// Per-device telemetry.
    pub per_device: Vec<DeviceReport>,
}

impl FleetReport {
    /// The fleet-wide ledger invariant: every accepted request either
    /// completed or failed — none lost to a crash.
    pub fn ledger_holds(&self) -> bool {
        self.accepted == self.completed + self.failed
    }

    /// Aggregate throughput in requests per (virtual) second.
    pub fn throughput(&self) -> f64 {
        self.completed as f64 / self.span_s.max(1e-9)
    }

    /// JSON form.
    pub fn to_value(&self) -> Value {
        let devs: Vec<Value> = self.per_device.iter().map(DeviceReport::to_value).collect();
        Value::obj(vec![
            ("devices", Value::Num(self.devices as f64)),
            ("alive", Value::Num(self.alive as f64)),
            ("accepted", Value::Num(self.accepted as f64)),
            ("completed", Value::Num(self.completed as f64)),
            ("failed", Value::Num(self.failed as f64)),
            ("shed", Value::Num(self.shed as f64)),
            ("failovers", Value::Num(self.failovers as f64)),
            ("rebalances", Value::Num(self.rebalances as f64)),
            ("replications", Value::Num(self.replications as f64)),
            ("evictions", Value::Num(self.evictions as f64)),
            ("crashes", Value::Num(self.crashes as f64)),
            ("slow_spells", Value::Num(self.slow_spells as f64)),
            ("storms", Value::Num(self.storms as f64)),
            ("probes", Value::Num(self.probes as f64)),
            ("deadline_misses", Value::Num(self.deadline_misses as f64)),
            ("total_cycles", Value::Num(self.total_cycles as f64)),
            ("throughput_rps", Value::Num(self.throughput())),
            ("failover_p50_ms", Value::Num(self.failover_p50_ms)),
            ("failover_p99_ms", Value::Num(self.failover_p99_ms)),
            ("span_s", Value::Num(self.span_s)),
            ("ledger_holds", Value::Bool(self.ledger_holds())),
            ("per_device", Value::Arr(devs)),
        ])
    }

    /// Metric records: one fleet-level record under `id`, plus one
    /// `"{id}/dev{i}"` record per device.
    pub fn to_records(&self, id: &str) -> Vec<MetricRecord> {
        let fleet_record = MetricRecord::new(id)
            .with_value("host_fleet_throughput", self.throughput())
            .with_value("host_fleet_devices", self.devices as f64)
            .with_value("host_fleet_alive", self.alive as f64)
            .with_value("host_fleet_accepted", self.accepted as f64)
            .with_value("host_fleet_completed", self.completed as f64)
            .with_value("host_fleet_failed", self.failed as f64)
            .with_value("host_fleet_shed", self.shed as f64)
            .with_value("host_fleet_failovers", self.failovers as f64)
            .with_value("host_fleet_rebalances", self.rebalances as f64)
            .with_value("host_fleet_replications", self.replications as f64)
            .with_value("host_fleet_crashes", self.crashes as f64)
            .with_value("host_fleet_deadline_misses", self.deadline_misses as f64)
            .with_value("wall_failover_p50_ms", self.failover_p50_ms)
            .with_value("wall_failover_p99_ms", self.failover_p99_ms);
        let mut records = vec![fleet_record];
        for d in &self.per_device {
            records.push(
                MetricRecord::new(&format!("{id}/dev{}", d.device))
                    .with_value("host_completed", d.completed as f64)
                    .with_value("host_util", d.utilization)
                    .with_value("host_cache_hit_rate", d.cache_hit_rate)
                    .with_value("host_integrity_fail", d.integrity_fails as f64),
            );
        }
        records
    }
}

/// Seeded multi-tenant traffic mix: `tenants` model specs with Zipf
/// popularity, Poisson arrivals from `loadgen`'s deterministic streams.
#[derive(Debug, Clone)]
pub struct TenantTrace {
    /// Number of tenant model specs.
    pub tenants: usize,
    /// Total requests in the trace.
    pub requests: usize,
    /// Mean arrival rate, requests per virtual second.
    pub rate: f64,
    /// Zipf skew exponent for tenant popularity.
    pub zipf_s: f64,
    /// Master seed for popularity, arrivals, and inputs.
    pub seed: u64,
    /// Model width multiplier for every tenant spec.
    pub scale: f64,
}

impl Default for TenantTrace {
    fn default() -> Self {
        TenantTrace {
            tenants: 6,
            requests: 96,
            rate: 400.0,
            zipf_s: 1.1,
            seed: 0xF1EE7,
            scale: 0.07,
        }
    }
}

/// One spec per tenant: distinct weight seeds (distinct models) over a
/// rotating design mix, so placement must juggle real variety.
pub fn tenant_specs(trace: &TenantTrace) -> Vec<BatchSpec> {
    const DESIGNS: [DesignKind; 3] = [DesignKind::Csa, DesignKind::Sssa, DesignKind::Ussa];
    (0..trace.tenants.max(1))
        .map(|t| {
            let mut spec = BatchSpec::new("dscnn", DESIGNS[t % DESIGNS.len()]);
            spec.scale = trace.scale;
            spec.weight_seed = 0x7E40 + t as u64;
            spec
        })
        .collect()
}

/// Zipf-popular tenant index per request (deterministic in the seed).
pub fn tenant_assignment(trace: &TenantTrace) -> Vec<usize> {
    let tenants = trace.tenants.max(1);
    let weights: Vec<f64> = (0..tenants)
        .map(|i| 1.0 / ((i + 1) as f64).powf(trace.zipf_s))
        .collect();
    let total: f64 = weights.iter().sum();
    let mut rng = Pcg32::new(trace.seed ^ ZIPF_TAG);
    (0..trace.requests)
        .map(|_| {
            let mut x = rng.next_f64() * total;
            for (i, w) in weights.iter().enumerate() {
                if x < *w {
                    return i;
                }
                x -= w;
            }
            tenants - 1
        })
        .collect()
}

/// Virtual arrival time (seconds) of each request, via `loadgen`'s
/// deterministic Poisson stream.
pub fn tenant_arrivals(trace: &TenantTrace) -> Vec<f64> {
    let cfg = TraceConfig {
        requests: trace.requests,
        rate: trace.rate,
        arrival: Arrival::Poisson,
        burst: 8,
        seed: trace.seed,
        retries: 0,
    };
    arrival_offsets(&cfg).into_iter().map(|d| d.as_secs_f64()).collect()
}

/// Deterministic input seed for request `i` of a trace.
pub fn tenant_input_seed(trace: &TenantTrace, i: usize) -> u64 {
    let mut rng = Pcg32::new(trace.seed ^ INPUT_TAG).fork(i as u64);
    rng.next_u64()
}

/// Outcome of one trace request, comparable across replays and against
/// a single-engine oracle.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimOutcome {
    /// Request index in the trace.
    pub request: usize,
    /// Tenant the request belongs to.
    pub tenant: usize,
    /// Shed by admission (503): no device ran it.
    pub shed: bool,
    /// Device that served it (`usize::MAX` when shed).
    pub device: usize,
    /// Argmax prediction (0 when shed).
    pub prediction: usize,
    /// Simulated cycles (0 when shed).
    pub cycles: u64,
    /// Re-routed after a device death.
    pub failed_over: bool,
}

/// Replay a tenant trace through the fleet, single-threaded and fully
/// deterministic. Returns one outcome per request, in trace order.
pub fn run_tenant_trace(fleet: &Fleet, trace: &TenantTrace) -> Result<Vec<SimOutcome>> {
    let specs = tenant_specs(trace);
    let tenants = tenant_assignment(trace);
    let arrivals = tenant_arrivals(trace);
    let mut out = Vec::with_capacity(tenants.len());
    for (i, (&tenant, &at)) in tenants.iter().zip(arrivals.iter()).enumerate() {
        let spec = &specs[tenant];
        let input = BatchEngine::gen_requests(&spec.model, 1, tenant_input_seed(trace, i))?;
        match fleet.submit(spec, input, Some(at))? {
            Submission::Done(routed) => out.push(SimOutcome {
                request: i,
                tenant,
                shed: false,
                device: routed.device,
                prediction: routed.report.predictions[0],
                cycles: routed.report.total_cycles,
                failed_over: routed.failed_over,
            }),
            Submission::Shed => out.push(SimOutcome {
                request: i,
                tenant,
                shed: true,
                device: usize::MAX,
                prediction: 0,
                cycles: 0,
                failed_over: false,
            }),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_trace() -> TenantTrace {
        TenantTrace { tenants: 3, requests: 24, ..TenantTrace::default() }
    }

    fn quiet_opts() -> FleetOptions {
        let engine = BatchOptions { threads: 1, ..BatchOptions::default() };
        FleetOptions { engine, probe_every: 1000, ..FleetOptions::default() }
    }

    #[test]
    fn zipf_assignment_is_deterministic_and_skewed() {
        let trace = TenantTrace { tenants: 4, requests: 400, ..TenantTrace::default() };
        let a = tenant_assignment(&trace);
        let b = tenant_assignment(&trace);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| t < 4));
        let count = |t: usize| a.iter().filter(|&&x| x == t).count();
        assert!(count(0) > count(3), "Zipf head must beat the tail");
        let arrivals = tenant_arrivals(&trace);
        assert_eq!(arrivals.len(), 400);
        assert!(arrivals.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn fleet_matches_single_engine_oracle_and_replays_deterministically() {
        let trace = small_trace();
        let fleet = Fleet::new(quiet_opts());
        let outcomes = run_tenant_trace(&fleet, &trace).unwrap();
        let replay = run_tenant_trace(&Fleet::new(quiet_opts()), &trace).unwrap();
        assert_eq!(outcomes, replay, "same seed must replay identically");

        let oracle = BatchEngine::new(quiet_opts().engine);
        let specs = tenant_specs(&trace);
        for o in &outcomes {
            assert!(!o.shed, "unsaturated fleet must not shed");
            assert!(!o.failed_over);
            let seed = tenant_input_seed(&trace, o.request);
            let input = BatchEngine::gen_requests("dscnn", 1, seed).unwrap();
            let want = oracle.run_batch(&specs[o.tenant], input).unwrap();
            assert_eq!(o.prediction, want.predictions[0], "request {}", o.request);
            assert_eq!(o.cycles, want.total_cycles, "request {}", o.request);
        }
        let report = fleet.report();
        assert!(report.ledger_holds());
        assert_eq!(report.accepted, trace.requests as u64);
        assert_eq!(report.completed, trace.requests as u64);
        assert_eq!(report.failed + report.shed, 0);
    }

    #[test]
    fn saturation_sheds_but_ledger_holds() {
        let opts = FleetOptions { devices: 1, device_queue: 1, ..quiet_opts() };
        let fleet = Fleet::new(opts);
        let spec = tenant_specs(&small_trace()).remove(0);
        let mut shed = 0;
        for i in 0..3 {
            let input = BatchEngine::gen_requests("dscnn", 1, i).unwrap();
            match fleet.submit(&spec, input, Some(0.0)).unwrap() {
                Submission::Done(_) => {}
                Submission::Shed => shed += 1,
            }
        }
        assert_eq!(shed, 2, "cap-1 queue at one instant admits exactly one");
        let report = fleet.report();
        assert!(report.ledger_holds());
        assert_eq!(report.accepted, 1);
        assert_eq!(report.completed, 1);
        assert_eq!(report.shed, 2);
    }

    #[test]
    fn hot_models_replicate_and_budget_is_respected() {
        let opts = FleetOptions { hot_threshold: 2, replicas: 2, ..quiet_opts() };
        let fleet = Fleet::new(opts);
        let spec = tenant_specs(&small_trace()).remove(0);
        for i in 0..4 {
            let input = BatchEngine::gen_requests("dscnn", 1, 100 + i).unwrap();
            let got = fleet.submit(&spec, input, Some(i as f64)).unwrap();
            assert!(matches!(got, Submission::Done(_)));
        }
        let report = fleet.report();
        assert!(report.replications >= 1, "hot spec must scale out");
        assert!(report.ledger_holds());
        let holders: usize = report.per_device.iter().filter(|d| d.placed > 0).count();
        assert!(holders >= 2, "replicas must land on distinct devices");
        assert_eq!(report.evictions, 0, "one spec fits every budget");
    }

    #[test]
    fn crash_fails_over_without_losing_requests_and_stays_bit_identical() {
        let fleet = Fleet::new(FleetOptions { replicas: 1, ..quiet_opts() });
        let spec = tenant_specs(&small_trace()).remove(0);
        let input = BatchEngine::gen_requests("dscnn", 1, 7).unwrap();
        let before = match fleet.submit(&spec, input.clone(), Some(0.0)).unwrap() {
            Submission::Done(routed) => routed,
            Submission::Shed => panic!("must admit"),
        };
        assert!(fleet.crash_device(before.device));
        assert_eq!(fleet.alive_devices(), 2);
        let after = match fleet.submit(&spec, input, Some(1.0)).unwrap() {
            Submission::Done(routed) => routed,
            Submission::Shed => panic!("must fail over, not shed"),
        };
        assert!(after.failed_over, "sole holder died: request must fail over");
        assert_ne!(after.device, before.device);
        assert_eq!(after.report.predictions, before.report.predictions);
        assert_eq!(after.report.total_cycles, before.report.total_cycles);
        let report = fleet.report();
        assert!(report.ledger_holds());
        assert_eq!(report.accepted, 2);
        assert_eq!(report.completed, 2);
        assert_eq!(report.crashes, 1);
        assert!(report.failovers >= 1);
        assert!(report.rebalances >= 1, "dead device's model must be re-placed");
        assert!(report.failover_p50_ms >= 0.0);
    }

    #[test]
    fn crash_device_refuses_last_survivor() {
        let fleet = Fleet::new(FleetOptions { devices: 2, ..quiet_opts() });
        assert!(fleet.crash_device(0));
        assert!(!fleet.crash_device(0), "already dead");
        assert!(!fleet.crash_device(1), "never kill the last device");
        assert_eq!(fleet.alive_devices(), 1);
    }

    #[test]
    fn spec_cost_sums_designs_used() {
        let spec = BatchSpec::new("dscnn", DesignKind::Csa);
        let cost = spec_cost(&spec);
        assert_eq!(cost, estimate_cfu(DesignKind::Csa));
        assert!(cost.luts > 0);
    }
}
