//! Production serving path: a dependency-free TCP + minimal HTTP/1.1
//! JSON front-end over the [`BatchEngine`], with continuous batching and
//! overload shedding.
//!
//! Where [`super::serve`] is the in-process debug loop (the "UART" of
//! the debug-vs-production split), [`NetServer`] is the network front
//! door:
//!
//! - a listener thread accepts concurrent connections (one handler
//!   thread per connection, keep-alive + pipelining supported);
//! - `POST /v1/infer` requests are admitted into a per-spec queue and
//!   coalesced into dynamic batches by a dedicated batcher thread —
//!   a batch fires when it reaches [`NetOptions::batch_max`] requests
//!   *or* when the oldest queued request has waited
//!   [`NetOptions::batch_deadline`] (continuous batching);
//! - admission queues are bounded: beyond
//!   [`NetOptions::queue_capacity`] the request is shed with
//!   `503 + Retry-After` instead of building unbounded backlog;
//! - shutdown (`POST /shutdown`, [`NetServer::shutdown`], or a
//!   [`NetHandle`]) drains every in-flight and queued request before
//!   the batcher threads exit — accepted requests are never lost.
//!
//! Simulated results are invariant in the network layer by
//! construction: every request executes independently inside
//! [`BatchEngine::run_batch`] and its cycle counts come from
//! prepare-time schedules, so batch composition changes wall-clock
//! behavior only. Wall-clock percentiles, queue depth, shed counts and
//! the batch-size histogram are exported as informational
//! `wall_*`/`host_*` metrics via [`NetStats::to_record`].
//!
//! The layer is also built to *survive* faults, injected
//! ([`crate::faults::FaultPlan`] via [`NetOptions::faults`]) or real:
//! batcher threads run under a supervisor that respawns them with
//! capped exponential backoff after a panic; batch execution is wrapped
//! in `catch_unwind` so an engine panic becomes a `500` for the batch's
//! waiters instead of a lost batch; every shared-state lock recovers
//! from poisoning ([`super::lock_clean`]); the per-request
//! [`NetOptions::request_timeout`] watchdog turns a hung batch into a
//! `500` instead of a pinned connection thread; and `GET /healthz`
//! reports `ok`/`degraded`/`draining` with the fault counters.
//! Connection faults (drop/stall/truncate) apply only to the
//! `POST /v1/infer` data path, so graceful drain is never broken by a
//! chaos plan.

use super::batch::{BatchEngine, BatchReport, BatchSpec};
use super::fleet::Fleet;
use super::lock_clean;
use crate::config::value::Value;
use crate::error::Result;
use crate::faults::{FaultPlan, FaultSite};
use crate::isa::{DesignAssignment, DesignKind};
use crate::metrics::MetricRecord;
use crate::models::builder::{random_input, ModelConfig};
use crate::models::zoo::input_shape;
use crate::tensor::quant::QuantParams;
use crate::tensor::QTensor;
use crate::util::logging;
use crate::util::stats::Percentiles;
use crate::util::Pcg32;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Network front-end options.
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Batch size that fires a batch immediately (the size trigger of
    /// the continuous batcher). Normalized to at least 1.
    pub batch_max: usize,
    /// Maximum time the oldest queued request waits before its batch
    /// fires regardless of size (the deadline trigger).
    pub batch_deadline: Duration,
    /// Bounded admission-queue depth per spec; requests beyond it are
    /// shed with `503 + Retry-After`. Normalized to at least 1.
    pub queue_capacity: usize,
    /// Socket read timeout — a peer that stalls mid-request (slow
    /// loris) gets `408` and the connection thread is reclaimed.
    pub read_timeout: Duration,
    /// End-to-end cap on one admitted request (queue wait + batch
    /// execution); `500` on expiry so a stuck batcher cannot pin
    /// connection threads forever.
    pub request_timeout: Duration,
    /// Maximum accepted request-body size in bytes (`413` beyond it).
    pub max_body: usize,
    /// Maximum accepted header-block size in bytes (`431` beyond it).
    pub max_header: usize,
    /// SoC clock for the `sim_ms` field of infer responses.
    pub clock_hz: u64,
    /// Value of the `Retry-After` header (seconds) on shed responses.
    pub retry_after_s: u64,
    /// Seeded chaos plan for the network layer's own fault sites
    /// (batcher panics, connection drop/stall/truncate). `None` — the
    /// default — disables every site. Share the same plan with
    /// [`super::BatchOptions::faults`] so one seed replays the whole
    /// stack's fault schedule.
    pub faults: Option<Arc<FaultPlan>>,
}

impl Default for NetOptions {
    fn default() -> Self {
        NetOptions {
            batch_max: 16,
            batch_deadline: Duration::from_millis(5),
            queue_capacity: 256,
            read_timeout: Duration::from_secs(5),
            request_timeout: Duration::from_secs(60),
            max_body: 1 << 20,
            max_header: 8192,
            clock_hz: 100_000_000,
            retry_after_s: 1,
            faults: None,
        }
    }
}

/// Successful engine-side result for one admitted request.
struct InferOk {
    prediction: usize,
    cycles: u64,
    batch_size: usize,
}

/// Batcher → connection-thread response channel. `String` (not the
/// crate error) so one engine failure clones across a whole batch.
type RespTx = mpsc::Sender<std::result::Result<InferOk, String>>;

/// One admitted request waiting in an admission queue.
struct Pending {
    input: QTensor,
    resp: RespTx,
    enqueued: Instant,
}

struct QueueInner {
    pending: VecDeque<Pending>,
}

/// Per-spec admission queue with its batcher wakeup condvar.
struct ModelQueue {
    spec: BatchSpec,
    inner: Mutex<QueueInner>,
    cv: Condvar,
}

#[derive(Default)]
struct StatsInner {
    accepted: u64,
    completed: u64,
    failed: u64,
    shed: u64,
    rejected: u64,
    batches: u64,
    batch_hist: BTreeMap<u64, u64>,
    queue_depth_max: u64,
    batcher_restarts: u64,
    wall: Percentiles,
}

/// The inference back-end behind the server: one engine, or a
/// multi-device [`Fleet`] that routes every batch through placement
/// and replica failover. The serving layer is agnostic — both expose
/// the same `run_batch` and robustness counters.
enum Backend {
    Single(BatchEngine),
    Fleet(Arc<Fleet>),
}

impl Backend {
    fn run_batch(&self, spec: &BatchSpec, inputs: Vec<QTensor>) -> Result<BatchReport> {
        match self {
            Backend::Single(engine) => engine.run_batch(spec, inputs),
            Backend::Fleet(fleet) => fleet.run_batch(spec, inputs),
        }
    }

    fn integrity_fails(&self) -> u64 {
        match self {
            Backend::Single(engine) => engine.integrity_fails(),
            Backend::Fleet(fleet) => fleet.integrity_fails(),
        }
    }

    fn degraded_runs(&self) -> u64 {
        match self {
            Backend::Single(engine) => engine.degraded_runs(),
            Backend::Fleet(fleet) => fleet.degraded_runs(),
        }
    }

    fn transient_corrected(&self) -> u64 {
        match self {
            Backend::Single(engine) => engine.transient_corrected(),
            Backend::Fleet(fleet) => fleet.transient_corrected(),
        }
    }

    fn degraded_keys(&self) -> usize {
        match self {
            Backend::Single(engine) => engine.degraded_keys(),
            Backend::Fleet(fleet) => fleet.degraded_keys(),
        }
    }

    fn strike_cap(&self) -> usize {
        match self {
            Backend::Single(engine) => engine.strike_cap(),
            Backend::Fleet(fleet) => fleet.strike_cap(),
        }
    }

    fn strike_evictions(&self) -> u64 {
        match self {
            Backend::Single(engine) => engine.strike_evictions(),
            Backend::Fleet(fleet) => fleet.strike_evictions(),
        }
    }

    fn fleet(&self) -> Option<&Arc<Fleet>> {
        match self {
            Backend::Single(_) => None,
            Backend::Fleet(fleet) => Some(fleet),
        }
    }
}

struct Shared {
    engine: Backend,
    opts: NetOptions,
    queues: Mutex<HashMap<String, Arc<ModelQueue>>>,
    batchers: Mutex<Vec<JoinHandle<()>>>,
    stats: Mutex<StatsInner>,
    shutdown: AtomicBool,
}

/// Counter snapshot of a running (or drained) [`NetServer`].
#[derive(Debug, Clone)]
pub struct NetStats {
    /// Requests admitted into an admission queue.
    pub accepted: u64,
    /// Admitted requests answered `200`.
    pub completed: u64,
    /// Admitted requests answered `500` (engine error or timeout).
    pub failed: u64,
    /// Requests shed with `503` (queue full or shutting down).
    pub shed: u64,
    /// Frames rejected before admission (`4xx`/`501` parse failures).
    pub rejected: u64,
    /// Batches executed by the continuous batchers.
    pub batches: u64,
    /// Batch-size histogram: executed batch size → occurrence count.
    pub batch_hist: BTreeMap<u64, u64>,
    /// Deepest admission-queue depth observed at enqueue time.
    pub queue_depth_max: u64,
    /// Batcher threads respawned by the supervisor after a panic.
    pub batcher_restarts: u64,
    /// Prepared-model integrity-checksum failures detected (and healed
    /// by eviction + re-prepare) on cache hits.
    pub integrity_fails: u64,
    /// Batches the engine executed in degraded (interpreted-oracle)
    /// mode after repeated integrity strikes on a key.
    pub degraded_runs: u64,
    /// Transient lane faults detected by redundant re-execution and
    /// answered with the clean re-run.
    pub transient_corrected: u64,
    /// Median end-to-end wall latency of completed requests (ms).
    pub wall_p50_ms: f64,
    /// 99th-percentile end-to-end wall latency (ms).
    pub wall_p99_ms: f64,
    /// 99.9th-percentile end-to-end wall latency (ms).
    pub wall_p999_ms: f64,
}

impl NetStats {
    /// Mean executed batch size (0 when no batch has run).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        let total: u64 = self.batch_hist.iter().map(|(size, count)| size * count).sum();
        total as f64 / self.batches as f64
    }

    /// Serialize for the `GET /stats` endpoint and CLI summaries.
    pub fn to_value(&self) -> Value {
        let hist = Value::Obj(
            self.batch_hist
                .iter()
                .map(|(k, v)| (k.to_string(), Value::Num(*v as f64)))
                .collect(),
        );
        Value::obj(vec![
            ("accepted", Value::Num(self.accepted as f64)),
            ("completed", Value::Num(self.completed as f64)),
            ("failed", Value::Num(self.failed as f64)),
            ("shed", Value::Num(self.shed as f64)),
            ("rejected", Value::Num(self.rejected as f64)),
            ("batches", Value::Num(self.batches as f64)),
            ("batch_hist", hist),
            ("batch_mean", Value::Num(self.mean_batch_size())),
            ("queue_depth_max", Value::Num(self.queue_depth_max as f64)),
            ("batcher_restarts", Value::Num(self.batcher_restarts as f64)),
            ("integrity_fails", Value::Num(self.integrity_fails as f64)),
            ("degraded_runs", Value::Num(self.degraded_runs as f64)),
            ("transient_corrected", Value::Num(self.transient_corrected as f64)),
            ("wall_p50_ms", Value::Num(self.wall_p50_ms)),
            ("wall_p99_ms", Value::Num(self.wall_p99_ms)),
            ("wall_p999_ms", Value::Num(self.wall_p999_ms)),
        ])
    }

    /// Emit the serving counters as an informational [`MetricRecord`]
    /// (`wall_*`/`host_*` names — tracked in baselines, never gated).
    pub fn to_record(&self, id: &str) -> MetricRecord {
        MetricRecord::new(id)
            .with_value("wall_p50_ms", self.wall_p50_ms)
            .with_value("wall_p99_ms", self.wall_p99_ms)
            .with_value("wall_p999_ms", self.wall_p999_ms)
            .with_value("host_shed_total", self.shed as f64)
            .with_value("host_queue_depth_max", self.queue_depth_max as f64)
            .with_value("host_batch_mean", self.mean_batch_size())
            .with_value("host_accepted", self.accepted as f64)
            .with_value("host_completed", self.completed as f64)
            .with_value("host_integrity_fail", self.integrity_fails as f64)
            .with_value("host_degraded_total", self.degraded_runs as f64)
            .with_value("host_batcher_restarts", self.batcher_restarts as f64)
    }
}

/// Cloneable remote control for a running server (shutdown + stats from
/// another thread, e.g. a CLI watchdog), without owning the listener.
#[derive(Clone)]
pub struct NetHandle {
    shared: Arc<Shared>,
}

impl NetHandle {
    /// Begin graceful shutdown (idempotent): stop accepting, drain
    /// queued work, let `join` return.
    pub fn shutdown(&self) {
        begin_shutdown(&self.shared);
    }

    /// Current counter snapshot.
    pub fn stats(&self) -> NetStats {
        snapshot(&self.shared)
    }
}

/// The TCP/HTTP serving front-end. See the module docs for the
/// queue/batcher architecture.
pub struct NetServer {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (use port 0 for an ephemeral port) and start the
    /// accept loop over `engine`.
    pub fn bind(addr: &str, engine: BatchEngine, opts: NetOptions) -> Result<NetServer> {
        NetServer::bind_backend(addr, Backend::Single(engine), opts)
    }

    /// Bind `addr` and serve over a multi-device [`Fleet`]: every batch
    /// routes through placement, hot-model replication, and replica
    /// failover, so a device crash mid-serve degrades to a failover
    /// instead of an outage.
    pub fn bind_fleet(addr: &str, fleet: Arc<Fleet>, opts: NetOptions) -> Result<NetServer> {
        NetServer::bind_backend(addr, Backend::Fleet(fleet), opts)
    }

    fn bind_backend(addr: &str, engine: Backend, mut opts: NetOptions) -> Result<NetServer> {
        opts.batch_max = opts.batch_max.max(1);
        opts.queue_capacity = opts.queue_capacity.max(1);
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        // Non-blocking listener so the accept loop can poll the shutdown
        // flag instead of parking in `accept` forever.
        listener.set_nonblocking(true)?;
        let shared = Arc::new(Shared {
            engine,
            opts,
            queues: Mutex::new(HashMap::new()),
            batchers: Mutex::new(Vec::new()),
            stats: Mutex::new(StatsInner::default()),
            shutdown: AtomicBool::new(false),
        });
        let accept = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("serve-net-accept".into())
                .spawn(move || accept_loop(listener, shared))?
        };
        Ok(NetServer { shared, addr: local, accept: Some(accept) })
    }

    /// The bound local address (resolves an ephemeral port request).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Begin graceful shutdown (idempotent); `join` completes the drain.
    pub fn shutdown(&self) {
        begin_shutdown(&self.shared);
    }

    /// Cloneable control handle (shutdown + stats) for other threads.
    pub fn handle(&self) -> NetHandle {
        NetHandle { shared: Arc::clone(&self.shared) }
    }

    /// Current counter snapshot.
    pub fn stats(&self) -> NetStats {
        snapshot(&self.shared)
    }

    /// Block until shutdown has been requested and every queued request
    /// has drained, then return the final counters. Request shutdown
    /// first via [`NetServer::shutdown`], a [`NetHandle`], or
    /// `POST /shutdown`.
    pub fn join(mut self) -> NetStats {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let batchers: Vec<JoinHandle<()>> =
            std::mem::take(&mut *lock_clean(&self.shared.batchers));
        for h in batchers {
            let _ = h.join();
        }
        snapshot(&self.shared)
    }
}

fn begin_shutdown(shared: &Arc<Shared>) {
    shared.shutdown.store(true, Ordering::SeqCst);
    // Wake every batcher so the drain-then-exit path runs promptly.
    for q in lock_clean(&shared.queues).values() {
        q.cv.notify_all();
    }
}

fn snapshot(shared: &Arc<Shared>) -> NetStats {
    let mut stats = lock_clean(&shared.stats);
    // An idle server reports 0.0 — `Value::Num(NaN)` would serialize as
    // invalid JSON.
    let (p50, p99, p999) = if stats.wall.count() == 0 {
        (0.0, 0.0, 0.0)
    } else {
        (
            stats.wall.percentile(50.0),
            stats.wall.percentile(99.0),
            stats.wall.percentile(99.9),
        )
    };
    NetStats {
        accepted: stats.accepted,
        completed: stats.completed,
        failed: stats.failed,
        shed: stats.shed,
        rejected: stats.rejected,
        batches: stats.batches,
        batch_hist: stats.batch_hist.clone(),
        queue_depth_max: stats.queue_depth_max,
        batcher_restarts: stats.batcher_restarts,
        integrity_fails: shared.engine.integrity_fails(),
        degraded_runs: shared.engine.degraded_runs(),
        transient_corrected: shared.engine.transient_corrected(),
        wall_p50_ms: p50,
        wall_p99_ms: p99,
        wall_p999_ms: p999,
    }
}

// ---- listener + connection threads ------------------------------------

fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    while !shared.shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let shared = Arc::clone(&shared);
                match std::thread::Builder::new()
                    .name("serve-net-conn".into())
                    .spawn(move || handle_connection(stream, shared))
                {
                    Ok(h) => conns.push(h),
                    Err(e) => logging::warn("net", &format!("connection spawn failed: {e}")),
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                logging::warn("net", &format!("accept failed: {e}"));
                std::thread::sleep(Duration::from_millis(10));
            }
        }
        // Reap finished handler threads so a long-lived server does not
        // accumulate JoinHandles.
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
}

fn handle_connection(stream: TcpStream, shared: Arc<Shared>) {
    // Accepted sockets can inherit the listener's non-blocking flag on
    // some platforms; the handler wants blocking reads under a timeout.
    if stream.set_nonblocking(false).is_err() {
        return;
    }
    if stream.set_read_timeout(Some(shared.opts.read_timeout)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let mut out = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut reader = FrameReader::new(stream);
    loop {
        match reader.read_frame(&shared.opts) {
            Frame::Closed => break,
            Frame::Fail(reply) => {
                lock_clean(&shared.stats).rejected += 1;
                let _ = write_response(&mut out, &reply, false);
                break;
            }
            Frame::Request(req) => {
                let keep = req.keep_alive && !shared.shutdown.load(Ordering::SeqCst);
                // Chaos: connection faults hit only the infer data path
                // so control-plane traffic (/healthz, /stats, /shutdown)
                // always works and graceful drain cannot be broken.
                let fault = (req.method == "POST" && req.path == "/v1/infer")
                    .then(|| shared.opts.faults.as_deref())
                    .flatten();
                if let Some(plan) = fault {
                    if plan.decide(FaultSite::ConnDrop).is_some() {
                        // Die before admission: the peer sees the
                        // connection close without a response and
                        // retries; nothing was accepted, nothing is
                        // lost.
                        break;
                    }
                }
                let reply = route(&req, &shared);
                if let Some(plan) = fault {
                    if let Some(mut rng) = plan.decide(FaultSite::ConnStall) {
                        // Bounded stall (5–45 ms): long enough to skew
                        // tail latency, far below client timeouts.
                        let ms = u64::from(rng.below(40)) + 5;
                        std::thread::sleep(Duration::from_millis(ms));
                    }
                    if plan.decide(FaultSite::ConnTruncate).is_some() {
                        // The request was served (counters moved); the
                        // peer gets half a response and must retry.
                        let bytes = render_response(&reply, false);
                        let _ = out.write_all(&bytes[..bytes.len() / 2]);
                        let _ = out.flush();
                        break;
                    }
                }
                if write_response(&mut out, &reply, keep).is_err() || !keep {
                    break;
                }
            }
        }
    }
    let _ = out.shutdown(Shutdown::Both);
}

// ---- minimal HTTP/1.1 framing -----------------------------------------

/// One parsed HTTP request.
struct HttpRequest {
    method: String,
    path: String,
    keep_alive: bool,
    body: Vec<u8>,
}

/// An HTTP response about to be written.
struct Reply {
    code: u16,
    reason: &'static str,
    body: String,
    extra: Vec<(&'static str, String)>,
}

impl Reply {
    fn json(code: u16, reason: &'static str, body: String) -> Reply {
        Reply { code, reason, body, extra: Vec::new() }
    }

    fn error(code: u16, reason: &'static str, msg: &str) -> Reply {
        let body = Value::obj(vec![("error", Value::Str(msg.to_string()))]).to_json();
        Reply::json(code, reason, body)
    }
}

/// Outcome of reading one frame off a connection.
enum Frame {
    /// A well-formed request.
    Request(HttpRequest),
    /// A malformed/oversized/timed-out frame: write this terminal
    /// response and close (the connection offset is unrecoverable).
    Fail(Reply),
    /// Clean EOF between requests.
    Closed,
}

/// Stateful request reader: buffers across reads so keep-alive and
/// pipelined requests (several frames arriving in one segment) work.
struct FrameReader<R: Read> {
    inner: R,
    buf: Vec<u8>,
    pos: usize,
}

/// First index of `needle` in `haystack`.
pub(crate) fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

impl<R: Read> FrameReader<R> {
    fn new(inner: R) -> Self {
        FrameReader { inner, buf: Vec::new(), pos: 0 }
    }

    fn fill(&mut self) -> std::io::Result<usize> {
        let mut chunk = [0u8; 4096];
        loop {
            match self.inner.read(&mut chunk) {
                Ok(n) => {
                    self.buf.extend_from_slice(&chunk[..n]);
                    return Ok(n);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            }
        }
    }

    /// Read and parse the next request. Every malformed input maps to a
    /// `4xx`/`501` [`Frame::Fail`] — never a panic or an unbounded read.
    fn read_frame(&mut self, opts: &NetOptions) -> Frame {
        // Drop the bytes consumed by the previous frame; pipelined
        // excess stays buffered.
        self.buf.drain(..self.pos);
        self.pos = 0;

        // Accumulate until the header terminator.
        let header_end = loop {
            if let Some(i) = find_subslice(&self.buf, b"\r\n\r\n") {
                break i;
            }
            if self.buf.len() > opts.max_header {
                return Frame::Fail(Reply::error(
                    431,
                    "Request Header Fields Too Large",
                    "header block exceeds the size limit",
                ));
            }
            match self.fill() {
                Ok(0) if self.buf.is_empty() => return Frame::Closed,
                Ok(0) => {
                    return Frame::Fail(Reply::error(
                        400,
                        "Bad Request",
                        "connection closed mid-header",
                    ));
                }
                Ok(_) => {}
                Err(e) if is_timeout(&e) => {
                    return Frame::Fail(Reply::error(
                        408,
                        "Request Timeout",
                        "timed out reading the request header",
                    ));
                }
                Err(_) => return Frame::Closed,
            }
        };

        // Parse the header block into owned values (the borrow of `buf`
        // ends with this block; the body read below extends it again).
        let (method, path, keep_alive, content_length) = {
            let head = match std::str::from_utf8(&self.buf[..header_end]) {
                Ok(h) => h,
                Err(_) => {
                    return Frame::Fail(Reply::error(
                        400,
                        "Bad Request",
                        "header block is not valid UTF-8",
                    ));
                }
            };
            let mut lines = head.split("\r\n");
            let request_line = lines.next().unwrap_or("");
            let mut parts = request_line.split(' ');
            let (method, path, version) =
                match (parts.next(), parts.next(), parts.next(), parts.next()) {
                    (Some(m), Some(p), Some(v), None) if !m.is_empty() && !p.is_empty() => {
                        (m.to_string(), p.to_string(), v)
                    }
                    _ => {
                        return Frame::Fail(Reply::error(
                            400,
                            "Bad Request",
                            "malformed request line",
                        ));
                    }
                };
            if !version.starts_with("HTTP/1.") {
                return Frame::Fail(Reply::error(
                    400,
                    "Bad Request",
                    "unsupported HTTP version",
                ));
            }
            if method != "GET" && method != "POST" {
                return Frame::Fail(Reply::error(
                    405,
                    "Method Not Allowed",
                    "only GET and POST are served",
                ));
            }
            let mut keep_alive = true;
            let mut content_length: Option<usize> = None;
            let mut fields = 0usize;
            for line in lines {
                if line.is_empty() {
                    continue;
                }
                fields += 1;
                if fields > 100 {
                    return Frame::Fail(Reply::error(
                        431,
                        "Request Header Fields Too Large",
                        "too many header fields",
                    ));
                }
                let Some((name, value)) = line.split_once(':') else {
                    return Frame::Fail(Reply::error(
                        400,
                        "Bad Request",
                        "malformed header field",
                    ));
                };
                let name = name.trim().to_ascii_lowercase();
                let value = value.trim();
                match name.as_str() {
                    "content-length" => {
                        let Ok(n) = value.parse::<usize>() else {
                            return Frame::Fail(Reply::error(
                                400,
                                "Bad Request",
                                "unparseable Content-Length",
                            ));
                        };
                        if content_length.is_some_and(|prev| prev != n) {
                            return Frame::Fail(Reply::error(
                                400,
                                "Bad Request",
                                "conflicting Content-Length fields",
                            ));
                        }
                        content_length = Some(n);
                    }
                    "connection" => {
                        if value.eq_ignore_ascii_case("close") {
                            keep_alive = false;
                        }
                    }
                    "transfer-encoding" => {
                        return Frame::Fail(Reply::error(
                            501,
                            "Not Implemented",
                            "Transfer-Encoding is not supported; send Content-Length",
                        ));
                    }
                    _ => {}
                }
            }
            (method, path, keep_alive, content_length)
        };

        let body_len = match content_length {
            Some(n) => n,
            None if method == "POST" => {
                return Frame::Fail(Reply::error(
                    411,
                    "Length Required",
                    "POST requires Content-Length",
                ));
            }
            None => 0,
        };
        if body_len > opts.max_body {
            return Frame::Fail(Reply::error(
                413,
                "Payload Too Large",
                "request body exceeds the size limit",
            ));
        }

        let body_start = header_end + 4;
        while self.buf.len() < body_start + body_len {
            match self.fill() {
                Ok(0) => {
                    return Frame::Fail(Reply::error(
                        400,
                        "Bad Request",
                        "connection closed mid-body",
                    ));
                }
                Ok(_) => {}
                Err(e) if is_timeout(&e) => {
                    return Frame::Fail(Reply::error(
                        408,
                        "Request Timeout",
                        "timed out reading the request body",
                    ));
                }
                Err(_) => return Frame::Closed,
            }
        }
        self.pos = body_start + body_len;
        let body = self.buf[body_start..self.pos].to_vec();
        Frame::Request(HttpRequest { method, path, keep_alive, body })
    }
}

/// Serialize a response to its wire bytes (shared by the normal write
/// path and the truncating connection-fault path).
fn render_response(reply: &Reply, keep_alive: bool) -> Vec<u8> {
    let mut head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n",
        reply.code,
        reply.reason,
        reply.body.len()
    );
    for (name, value) in &reply.extra {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str(if keep_alive {
        "Connection: keep-alive\r\n\r\n"
    } else {
        "Connection: close\r\n\r\n"
    });
    let mut bytes = head.into_bytes();
    bytes.extend_from_slice(reply.body.as_bytes());
    bytes
}

fn write_response<W: Write>(out: &mut W, reply: &Reply, keep_alive: bool) -> std::io::Result<()> {
    out.write_all(&render_response(reply, keep_alive))?;
    out.flush()
}

// ---- routing + admission ----------------------------------------------

fn route(req: &HttpRequest, shared: &Arc<Shared>) -> Reply {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => Reply::json(200, "OK", healthz_body(shared)),
        ("GET", "/stats") => Reply::json(200, "OK", snapshot(shared).to_value().to_json()),
        ("POST", "/shutdown") => {
            begin_shutdown(shared);
            Reply::json(200, "OK", "{\"ok\":true,\"draining\":true}".to_string())
        }
        ("POST", "/v1/infer") => infer(req, shared),
        _ => Reply::error(404, "Not Found", "unknown route"),
    }
}

/// `GET /healthz` body: liveness (`ok` — the server answered at all)
/// plus a recovery-state summary. `status` is `"draining"` once
/// shutdown began, `"degraded"` while any model key is pinned to the
/// oracle-fallback backend, `"ok"` otherwise; the counters expose the
/// supervision machinery (integrity failures healed, degraded batches,
/// batcher respawns, transient faults corrected, total injected
/// faults).
fn healthz_body(shared: &Arc<Shared>) -> String {
    let status = if shared.shutdown.load(Ordering::SeqCst) {
        "draining"
    } else if shared.engine.degraded_keys() > 0 {
        "degraded"
    } else {
        "ok"
    };
    let injected = shared.opts.faults.as_ref().map_or(0, |p| p.total_injected());
    let mut fields = vec![
        ("ok", Value::Bool(true)),
        ("status", Value::Str(status.to_string())),
        ("integrity_fails", Value::Num(shared.engine.integrity_fails() as f64)),
        ("degraded_runs", Value::Num(shared.engine.degraded_runs() as f64)),
        ("degraded_keys", Value::Num(shared.engine.degraded_keys() as f64)),
        ("degraded_keys_cap", Value::Num(shared.engine.strike_cap() as f64)),
        ("strike_evictions", Value::Num(shared.engine.strike_evictions() as f64)),
        ("transient_corrected", Value::Num(shared.engine.transient_corrected() as f64)),
        ("batcher_restarts", Value::Num(lock_clean(&shared.stats).batcher_restarts as f64)),
        ("faults_injected", Value::Num(injected as f64)),
    ];
    if let Some(fleet) = shared.engine.fleet() {
        fields.push(("fleet_devices", Value::Num(fleet.device_count() as f64)));
        fields.push(("fleet_alive", Value::Num(fleet.alive_devices() as f64)));
    }
    Value::obj(fields).to_json()
}

/// Parse an infer-request body into a [`BatchSpec`] and its input
/// tensor. The input is either an explicit `"input"` i8 array or a
/// deterministic `"seed"` — the seed path generates exactly what
/// [`BatchEngine::gen_requests`]`(model, 1, seed)` generates, so
/// network-path results can be compared bit-for-bit against direct
/// engine calls.
fn parse_infer(v: &Value) -> std::result::Result<(BatchSpec, QTensor), String> {
    let model = match v.get_opt("model") {
        Some(m) => m.as_str().map_err(|e| e.to_string())?.to_string(),
        None => "dscnn".to_string(),
    };
    let assignment = match (v.get_opt("assignment"), v.get_opt("design")) {
        (Some(a), _) => {
            let s = a.as_str().map_err(|e| e.to_string())?;
            DesignAssignment::parse(s).ok_or_else(|| format!("unknown assignment '{s}'"))?
        }
        (None, Some(d)) => {
            let s = d.as_str().map_err(|e| e.to_string())?;
            DesignKind::parse(s)
                .map(DesignAssignment::Uniform)
                .ok_or_else(|| format!("unknown design '{s}'"))?
        }
        (None, None) => DesignAssignment::Uniform(DesignKind::Csa),
    };
    let mut spec = BatchSpec::assigned(&model, assignment);
    if let Some(x) = v.get_opt("x_us") {
        let x = x.as_f64().map_err(|e| e.to_string())?;
        if !(0.0..=1.0).contains(&x) {
            return Err(format!("x_us {x} outside [0, 1]"));
        }
        spec.x_us = x;
    }
    if let Some(x) = v.get_opt("x_ss") {
        let x = x.as_f64().map_err(|e| e.to_string())?;
        if !(0.0..=1.0).contains(&x) {
            return Err(format!("x_ss {x} outside [0, 1]"));
        }
        spec.x_ss = x;
    }
    if let Some(x) = v.get_opt("scale") {
        let x = x.as_f64().map_err(|e| e.to_string())?;
        if !(x > 0.0 && x <= 1.0) {
            return Err(format!("scale {x} outside (0, 1]"));
        }
        spec.scale = x;
    }
    if let Some(x) = v.get_opt("weight_seed") {
        spec.weight_seed = x.as_i64().map_err(|e| e.to_string())?.max(0) as u64;
    }
    let shape = input_shape(&spec.model).map_err(|e| e.to_string())?;
    let params = QuantParams::new(ModelConfig::default().act_scale, 0)
        .map_err(|e| e.to_string())?;
    let input = match v.get_opt("input") {
        Some(arr) => {
            let data = arr.as_i8_vec().map_err(|e| e.to_string())?;
            QTensor::new(shape, data, params).map_err(|e| e.to_string())?
        }
        None => {
            let seed = match v.get_opt("seed") {
                Some(s) => s.as_i64().map_err(|e| e.to_string())?.max(0) as u64,
                None => 0,
            };
            let mut rng = Pcg32::new(seed);
            random_input(shape, params, &mut rng)
        }
    };
    Ok((spec, input))
}

fn infer(req: &HttpRequest, shared: &Arc<Shared>) -> Reply {
    let t0 = Instant::now();
    let parsed = std::str::from_utf8(&req.body)
        .map_err(|_| "request body is not valid UTF-8".to_string())
        .and_then(|s| Value::parse(s).map_err(|e| e.to_string()))
        .and_then(|v| parse_infer(&v));
    let (spec, input) = match parsed {
        Ok(p) => p,
        Err(msg) => {
            lock_clean(&shared.stats).rejected += 1;
            return Reply::error(400, "Bad Request", &msg);
        }
    };
    let model = spec.model.clone();
    let design_label = spec.assignment.label();
    let queue = queue_for(shared, spec);
    let (tx, rx) = mpsc::channel();

    // Admission. The shutdown check must sit *under the queue lock*:
    // the batcher exits only once shutdown is set AND the queue is
    // empty, so an admission racing the flag could otherwise enqueue
    // into a queue no batcher will ever drain again.
    let depth = {
        let mut inner = lock_clean(&queue.inner);
        if shared.shutdown.load(Ordering::SeqCst) {
            drop(inner);
            return shed_reply(shared, "server is shutting down");
        }
        if inner.pending.len() >= shared.opts.queue_capacity {
            drop(inner);
            return shed_reply(shared, "admission queue is full, retry later");
        }
        inner.pending.push_back(Pending { input, resp: tx, enqueued: t0 });
        let depth = inner.pending.len() as u64;
        queue.cv.notify_one();
        depth
    };
    {
        let mut stats = lock_clean(&shared.stats);
        stats.accepted += 1;
        stats.queue_depth_max = stats.queue_depth_max.max(depth);
    }

    match rx.recv_timeout(shared.opts.request_timeout) {
        Ok(Ok(ok)) => {
            let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
            {
                let mut stats = lock_clean(&shared.stats);
                stats.completed += 1;
                stats.wall.push(wall_ms);
            }
            let sim_ms = ok.cycles as f64 / shared.opts.clock_hz as f64 * 1e3;
            let body = Value::obj(vec![
                ("model", Value::Str(model)),
                ("design", Value::Str(design_label)),
                ("prediction", Value::Num(ok.prediction as f64)),
                ("cycles", Value::Num(ok.cycles as f64)),
                ("sim_ms", Value::Num(sim_ms)),
                ("batch", Value::Num(ok.batch_size as f64)),
                ("wall_ms", Value::Num(wall_ms)),
            ]);
            Reply::json(200, "OK", body.to_json())
        }
        Ok(Err(msg)) => {
            lock_clean(&shared.stats).failed += 1;
            Reply::error(500, "Internal Server Error", &msg)
        }
        // The per-request watchdog: a hung batch answers `500` after
        // `request_timeout` instead of pinning this connection thread.
        Err(_) => {
            lock_clean(&shared.stats).failed += 1;
            Reply::error(500, "Internal Server Error", "request timed out in the engine")
        }
    }
}

fn shed_reply(shared: &Arc<Shared>, msg: &str) -> Reply {
    lock_clean(&shared.stats).shed += 1;
    let mut reply = Reply::error(503, "Service Unavailable", msg);
    reply.extra.push(("Retry-After", shared.opts.retry_after_s.to_string()));
    reply
}

/// Get or create the admission queue for a spec, lazily spawning its
/// batcher thread on first use.
fn queue_for(shared: &Arc<Shared>, spec: BatchSpec) -> Arc<ModelQueue> {
    let key = format!(
        "{}|{}|{}|{}|{}|{}",
        spec.model,
        spec.assignment.label(),
        spec.x_us,
        spec.x_ss,
        spec.scale,
        spec.weight_seed
    );
    let mut queues = lock_clean(&shared.queues);
    if let Some(q) = queues.get(&key) {
        return Arc::clone(q);
    }
    let queue = Arc::new(ModelQueue {
        spec,
        inner: Mutex::new(QueueInner { pending: VecDeque::new() }),
        cv: Condvar::new(),
    });
    queues.insert(key, Arc::clone(&queue));
    let handle = {
        let queue = Arc::clone(&queue);
        let shared = Arc::clone(shared);
        std::thread::Builder::new()
            .name("serve-net-batcher".into())
            .spawn(move || supervise_batcher(queue, shared))
    };
    match handle {
        // Lock order queues → batchers (the only nesting in the module).
        Ok(h) => lock_clean(&shared.batchers).push(h),
        Err(e) => logging::warn("net", &format!("batcher spawn failed: {e}")),
    }
    queue
}

// ---- continuous batcher -----------------------------------------------

/// Run one spec's batcher under supervision: a panicking batcher
/// (injected fault or real bug) is respawned in place with capped
/// exponential backoff instead of silently orphaning its admission
/// queue — queued requests stay queued across the restart, so the
/// accepted-is-never-lost invariant survives batcher crashes. A clean
/// return (shutdown drain complete) ends supervision.
fn supervise_batcher(queue: Arc<ModelQueue>, shared: Arc<Shared>) {
    let mut backoff = Duration::from_millis(10);
    loop {
        let run = catch_unwind(AssertUnwindSafe(|| {
            batcher_loop(&queue, &shared);
        }));
        match run {
            Ok(()) => return,
            Err(_) => {
                lock_clean(&shared.stats).batcher_restarts += 1;
                logging::warn(
                    "net",
                    &format!("batcher for {} panicked; respawning", queue.spec.model),
                );
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    }
}

fn batcher_loop(queue: &ModelQueue, shared: &Arc<Shared>) {
    loop {
        // Chaos: a batcher crash *before* the drain leaves every queued
        // request in place for the respawned batcher. (Crashing after
        // the drain would need request re-queueing to preserve the
        // invariant; the engine-side panic path is covered separately by
        // the `catch_unwind` in `run_one_batch`.)
        if let Some(plan) = &shared.opts.faults {
            if plan.decide(FaultSite::BatcherPanic).is_some() {
                panic!("injected batcher fault (chaos plan)");
            }
        }
        let batch: Vec<Pending> = {
            let mut inner = lock_clean(&queue.inner);
            // Wait for work. Exit only when shutdown is set AND the
            // queue is empty — accepted requests always drain.
            loop {
                if !inner.pending.is_empty() {
                    break;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                // No deadline exists while the queue is empty — park on
                // the condvar (woken by `infer`'s enqueue notify and
                // `begin_shutdown`'s broadcast) with a long defensive
                // timeout instead of a busy 50 ms tick.
                let (guard, _) = queue
                    .cv
                    .wait_timeout(inner, Duration::from_secs(1))
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                inner = guard;
            }
            // Continuous batching: fire on the size threshold, on
            // shutdown (drain what is there), or when the *oldest*
            // queued request reaches the deadline.
            loop {
                if inner.pending.len() >= shared.opts.batch_max
                    || shared.shutdown.load(Ordering::SeqCst)
                {
                    break;
                }
                let age = inner
                    .pending
                    .front()
                    .map_or(Duration::ZERO, |p| p.enqueued.elapsed());
                if age >= shared.opts.batch_deadline {
                    break;
                }
                let (guard, _) = queue
                    .cv
                    .wait_timeout(inner, shared.opts.batch_deadline - age)
                    .unwrap_or_else(std::sync::PoisonError::into_inner);
                inner = guard;
            }
            let n = inner.pending.len().min(shared.opts.batch_max);
            inner.pending.drain(..n).collect()
        };
        run_one_batch(&queue.spec, batch, shared);
    }
}

fn run_one_batch(spec: &BatchSpec, batch: Vec<Pending>, shared: &Arc<Shared>) {
    let n = batch.len();
    {
        let mut stats = lock_clean(&shared.stats);
        stats.batches += 1;
        *stats.batch_hist.entry(n as u64).or_insert(0) += 1;
    }
    let mut senders: Vec<RespTx> = Vec::with_capacity(n);
    let mut inputs: Vec<QTensor> = Vec::with_capacity(n);
    for p in batch {
        senders.push(p.resp);
        inputs.push(p.input);
    }
    // `catch_unwind` so an engine panic (a worker job that panicked
    // makes `run_batch` itself panic on the missing result) degrades to
    // a `500` for every waiter in the batch — the requests were already
    // drained from the queue, so losing them here would break the
    // accepted-is-never-lost invariant.
    let result = catch_unwind(AssertUnwindSafe(|| shared.engine.run_batch(spec, inputs)))
        .unwrap_or_else(|_| {
            Err(crate::error::Error::Coordinator("batch execution panicked".into()))
        });
    match result {
        Ok(report) => {
            for (i, tx) in senders.iter().enumerate() {
                let ok = InferOk {
                    prediction: report.predictions.get(i).copied().unwrap_or(0),
                    cycles: report.request_cycles.get(i).copied().unwrap_or(0),
                    batch_size: n,
                };
                // A send error means the connection thread gave up
                // (client disconnect / request timeout) — drop it.
                let _ = tx.send(Ok(ok));
            }
        }
        Err(e) => {
            let msg = e.to_string();
            for tx in &senders {
                let _ = tx.send(Err(msg.clone()));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn opts() -> NetOptions {
        NetOptions::default()
    }

    fn frame_of(raw: &[u8]) -> Frame {
        FrameReader::new(Cursor::new(raw.to_vec())).read_frame(&opts())
    }

    fn fail_code(f: Frame) -> u16 {
        match f {
            Frame::Fail(r) => r.code,
            Frame::Request(_) => panic!("expected Fail, got Request"),
            Frame::Closed => panic!("expected Fail, got Closed"),
        }
    }

    #[test]
    fn parses_simple_get() {
        let f = frame_of(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n");
        match f {
            Frame::Request(r) => {
                assert_eq!(r.method, "GET");
                assert_eq!(r.path, "/healthz");
                assert!(r.keep_alive);
                assert!(r.body.is_empty());
            }
            _ => panic!("expected Request"),
        }
    }

    #[test]
    fn parses_post_with_body_and_close() {
        let raw = b"POST /v1/infer HTTP/1.1\r\nContent-Length: 8\r\nConnection: close\r\n\r\n{\"a\":1} ";
        match frame_of(raw) {
            Frame::Request(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.body, b"{\"a\":1} ");
                assert!(!r.keep_alive);
            }
            _ => panic!("expected Request"),
        }
    }

    #[test]
    fn pipelined_requests_parse_in_order() {
        let raw =
            b"POST /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhiGET /b HTTP/1.1\r\n\r\n".to_vec();
        let mut reader = FrameReader::new(Cursor::new(raw));
        match reader.read_frame(&opts()) {
            Frame::Request(r) => {
                assert_eq!(r.path, "/a");
                assert_eq!(r.body, b"hi");
            }
            _ => panic!("expected first Request"),
        }
        match reader.read_frame(&opts()) {
            Frame::Request(r) => {
                assert_eq!(r.path, "/b");
                assert!(r.body.is_empty());
            }
            _ => panic!("expected second Request"),
        }
        match reader.read_frame(&opts()) {
            Frame::Closed => {}
            _ => panic!("expected Closed at EOF"),
        }
    }

    #[test]
    fn empty_connection_is_clean_close() {
        match frame_of(b"") {
            Frame::Closed => {}
            _ => panic!("expected Closed"),
        }
    }

    #[test]
    fn post_without_content_length_is_411() {
        assert_eq!(fail_code(frame_of(b"POST /v1/infer HTTP/1.1\r\nHost: x\r\n\r\n")), 411);
    }

    #[test]
    fn oversized_body_is_413() {
        let raw = format!(
            "POST /v1/infer HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
            opts().max_body + 1
        );
        assert_eq!(fail_code(frame_of(raw.as_bytes())), 413);
    }

    #[test]
    fn malformed_frames_are_4xx() {
        // Bad request line (two tokens).
        assert_eq!(fail_code(frame_of(b"GET /x\r\n\r\n")), 400);
        // Bad version.
        assert_eq!(fail_code(frame_of(b"GET /x SPDY/9\r\n\r\n")), 400);
        // Unsupported method.
        assert_eq!(fail_code(frame_of(b"DELETE /x HTTP/1.1\r\n\r\n")), 405);
        // Header field without a colon.
        assert_eq!(fail_code(frame_of(b"GET /x HTTP/1.1\r\nnocolon\r\n\r\n")), 400);
        // Unparseable Content-Length.
        assert_eq!(
            fail_code(frame_of(b"POST /x HTTP/1.1\r\nContent-Length: two\r\n\r\n")),
            400
        );
        // Conflicting duplicate Content-Length.
        assert_eq!(
            fail_code(frame_of(
                b"POST /x HTTP/1.1\r\nContent-Length: 1\r\nContent-Length: 2\r\n\r\nx"
            )),
            400
        );
        // Chunked bodies are not implemented.
        assert_eq!(
            fail_code(frame_of(
                b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"
            )),
            501
        );
    }

    #[test]
    fn truncated_header_and_body_are_400() {
        assert_eq!(fail_code(frame_of(b"GET /x HTTP/1.1\r\nHost:")), 400);
        assert_eq!(
            fail_code(frame_of(b"POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort")),
            400
        );
    }

    #[test]
    fn giant_header_is_431() {
        let mut raw = b"GET /x HTTP/1.1\r\nX-Pad: ".to_vec();
        let pad = vec![b'a'; opts().max_header + 16];
        raw.extend_from_slice(&pad);
        assert_eq!(fail_code(frame_of(&raw)), 431);
    }

    /// Reader that yields its prefix then stalls like a read timeout —
    /// a slow-loris peer under `SO_RCVTIMEO`.
    struct Stall {
        data: Vec<u8>,
        served: usize,
    }

    impl Read for Stall {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.served < self.data.len() {
                let n = out.len().min(self.data.len() - self.served);
                out[..n].copy_from_slice(&self.data[self.served..self.served + n]);
                self.served += n;
                Ok(n)
            } else {
                Err(ErrorKind::WouldBlock.into())
            }
        }
    }

    #[test]
    fn slow_loris_times_out_with_408() {
        // Stalls mid-header.
        let r = FrameReader::new(Stall {
            data: b"GET /x HTTP/1.1\r\nHost: slow".to_vec(),
            served: 0,
        })
        .read_frame(&opts());
        assert_eq!(fail_code(r), 408);
        // Stalls mid-body.
        let r = FrameReader::new(Stall {
            data: b"POST /x HTTP/1.1\r\nContent-Length: 64\r\n\r\npartial".to_vec(),
            served: 0,
        })
        .read_frame(&opts());
        assert_eq!(fail_code(r), 408);
    }

    #[test]
    fn response_wire_format() {
        let mut out: Vec<u8> = Vec::new();
        let mut reply = Reply::error(503, "Service Unavailable", "full");
        reply.extra.push(("Retry-After", "1".to_string()));
        write_response(&mut out, &reply, false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("{\"error\":\"full\"}"));
        let cl = format!("Content-Length: {}\r\n", "{\"error\":\"full\"}".len());
        assert!(text.contains(&cl));
    }

    #[test]
    fn parse_infer_defaults_and_validation() {
        let (spec, input) = parse_infer(&Value::parse("{}").unwrap()).unwrap();
        assert_eq!(spec.model, "dscnn");
        assert_eq!(spec.assignment, DesignAssignment::Uniform(DesignKind::Csa));
        // Default seed path matches gen_requests(model, 1, 0) exactly.
        let direct = BatchEngine::gen_requests("dscnn", 1, 0).unwrap();
        assert_eq!(input.data(), direct[0].data());

        let v = Value::parse(r#"{"model":"dscnn","design":"sssa","seed":7,"scale":0.1}"#)
            .unwrap();
        let (spec, input) = parse_infer(&v).unwrap();
        assert_eq!(spec.assignment, DesignAssignment::Uniform(DesignKind::Sssa));
        assert_eq!(spec.scale, 0.1);
        let direct = BatchEngine::gen_requests("dscnn", 1, 7).unwrap();
        assert_eq!(input.data(), direct[0].data());

        for bad in [
            r#"{"design":"warp9"}"#,
            r#"{"x_us":1.5}"#,
            r#"{"x_ss":-0.1}"#,
            r#"{"scale":0.0}"#,
            r#"{"model":"not-a-model"}"#,
            r#"{"input":[1,2,3]}"#,
            r#"{"input":[999]}"#,
        ] {
            assert!(parse_infer(&Value::parse(bad).unwrap()).is_err(), "{bad}");
        }
    }

    #[test]
    fn stats_record_uses_informational_registry_entries() {
        let stats = NetStats {
            accepted: 10,
            completed: 8,
            failed: 0,
            shed: 2,
            rejected: 1,
            batches: 3,
            batch_hist: BTreeMap::from([(2, 2), (4, 1)]),
            queue_depth_max: 5,
            batcher_restarts: 1,
            integrity_fails: 2,
            degraded_runs: 3,
            transient_corrected: 4,
            wall_p50_ms: 1.0,
            wall_p99_ms: 2.0,
            wall_p999_ms: 3.0,
        };
        assert!((stats.mean_batch_size() - 8.0 / 3.0).abs() < 1e-12);
        let rec = stats.to_record("serve/net");
        assert_eq!(rec.get("host_shed_total"), Some(2.0));
        assert_eq!(rec.get("host_queue_depth_max"), Some(5.0));
        assert_eq!(rec.get("host_integrity_fail"), Some(2.0));
        assert_eq!(rec.get("host_degraded_total"), Some(3.0));
        assert_eq!(rec.get("host_batcher_restarts"), Some(1.0));
        assert!(rec.get("wall_p99_ms").is_some());
        // Shed/queue-depth/fault counters must be lower-is-better (the
        // generic host_ prefix direction would misread a shedding or
        // recovery fix as a loss) and everything here must stay ungated.
        for name in [
            "host_shed_total",
            "host_queue_depth_max",
            "host_integrity_fail",
            "host_degraded_total",
            "host_batcher_restarts",
        ] {
            let spec = crate::metrics::spec_for(name);
            assert!(!spec.gate, "{name}");
            assert_eq!(spec.better, crate::metrics::Direction::LowerIsBetter, "{name}");
        }
        assert!(!crate::metrics::spec_for("wall_p999_ms").gate);
        assert!(!crate::metrics::spec_for("host_batch_mean").gate);
        // /stats JSON stays parseable (no NaN leakage on idle servers).
        let json = stats.to_value().to_json();
        let back = Value::parse(&json).unwrap();
        assert_eq!(back.get("batch_mean").unwrap().as_f64().unwrap(), stats.mean_batch_size());
    }

    #[test]
    fn find_subslice_basics() {
        assert_eq!(find_subslice(b"abcd\r\n\r\nef", b"\r\n\r\n"), Some(4));
        assert_eq!(find_subslice(b"abcd", b"\r\n\r\n"), None);
        assert_eq!(find_subslice(b"", b"\r\n\r\n"), None);
    }
}
