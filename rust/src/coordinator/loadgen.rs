//! Open-loop load generator for the [`super::net`] serving front-end.
//!
//! Open-loop means arrival times are fixed *before* the run (drawn from
//! a seeded [`Pcg32`]), so request timing never adapts to server
//! latency — the honest way to measure overload behavior: a server
//! that slows down under a 1000 req/s trace still receives 1000 req/s.
//! Two arrival processes are provided:
//!
//! - [`Arrival::Poisson`] — exponential inter-arrival gaps at `rate`
//!   requests/second (memoryless steady load);
//! - [`Arrival::Burst`] — groups of `burst` simultaneous requests with
//!   exponential gaps between groups at `rate / burst` bursts/second
//!   (same mean rate, maximally bunched — the shedding stressor).
//!
//! Traces are deterministic for a `(requests, rate, arrival, burst,
//! seed)` tuple, so CI failures replay exactly. The module doubles as
//! the repo's minimal HTTP/1.1 *client* ([`http_request`] /
//! [`parse_response`]), used by the loopback integration tier.

use crate::config::value::Value;
use crate::metrics::MetricRecord;
use crate::util::stats::Percentiles;
use crate::util::Pcg32;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Arrival process of an open-loop trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arrival {
    /// Exponential inter-arrival gaps (steady Poisson load).
    Poisson,
    /// Bursts of simultaneous requests with exponential gaps between
    /// bursts (same mean rate, bunched).
    Burst,
}

impl Arrival {
    /// Parse a CLI name.
    pub fn parse(s: &str) -> Option<Arrival> {
        match s.trim().to_ascii_lowercase().as_str() {
            "poisson" => Some(Arrival::Poisson),
            "burst" | "bursty" => Some(Arrival::Burst),
            _ => None,
        }
    }

    /// Canonical name.
    pub fn name(&self) -> &'static str {
        match self {
            Arrival::Poisson => "poisson",
            Arrival::Burst => "burst",
        }
    }
}

/// One deterministic open-loop trace.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Total requests to send.
    pub requests: usize,
    /// Mean offered load in requests per second.
    pub rate: f64,
    /// Arrival process.
    pub arrival: Arrival,
    /// Burst size (only read for [`Arrival::Burst`]); normalized to at
    /// least 1.
    pub burst: usize,
    /// Trace RNG seed.
    pub seed: u64,
    /// Retries per request on a retryable outcome (transport error,
    /// shed, `500`, malformed response) before the last outcome counts.
    /// Each retry backs off with seeded jitter; a shed's `Retry-After`
    /// is honored (capped). `0` — the default — preserves the strict
    /// one-shot trace semantics.
    pub retries: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            requests: 64,
            rate: 200.0,
            arrival: Arrival::Poisson,
            burst: 8,
            seed: 0x10AD,
            retries: 0,
        }
    }
}

/// Exponential sample with mean `1/rate` (inverse-CDF of `U(0,1)`).
fn exp_gap(rng: &mut Pcg32, rate: f64) -> Duration {
    let u = rng.next_f64();
    // `1 - u` keeps the argument in (0, 1] so `ln` stays finite; the
    // clamp keeps gaps strictly positive and bounded.
    let secs = (-(1.0 - u).ln() / rate.max(1e-9)).clamp(1e-9, 3600.0);
    Duration::from_secs_f64(secs)
}

/// Precompute the arrival offset of every request from trace start.
/// Deterministic in the config; offsets are non-decreasing.
pub fn arrival_offsets(cfg: &TraceConfig) -> Vec<Duration> {
    let mut rng = Pcg32::new(cfg.seed);
    let mut offsets = Vec::with_capacity(cfg.requests);
    let mut t = Duration::ZERO;
    match cfg.arrival {
        Arrival::Poisson => {
            for _ in 0..cfg.requests {
                t += exp_gap(&mut rng, cfg.rate);
                offsets.push(t);
            }
        }
        Arrival::Burst => {
            let burst = cfg.burst.max(1);
            while offsets.len() < cfg.requests {
                t += exp_gap(&mut rng, cfg.rate / burst as f64);
                for _ in 0..burst.min(cfg.requests - offsets.len()) {
                    offsets.push(t);
                }
            }
        }
    }
    offsets
}

/// A parsed HTTP response (client side).
#[derive(Debug, Clone)]
pub struct HttpResponse {
    /// Status code.
    pub code: u16,
    /// Header fields in wire order (names lowercased).
    pub headers: Vec<(String, String)>,
    /// Response body.
    pub body: String,
}

impl HttpResponse {
    /// Case-insensitive header lookup.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(n, _)| *n == name).map(|(_, v)| v.as_str())
    }
}

/// Parse raw response bytes (status line + headers + body to EOF).
pub fn parse_response(raw: &[u8]) -> std::result::Result<HttpResponse, String> {
    let header_end = super::net::find_subslice(raw, b"\r\n\r\n")
        .ok_or_else(|| "response has no header terminator".to_string())?;
    let head = std::str::from_utf8(&raw[..header_end])
        .map_err(|_| "response header is not valid UTF-8".to_string())?;
    let mut lines = head.split("\r\n");
    let status = lines.next().unwrap_or("");
    let mut parts = status.splitn(3, ' ');
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(format!("bad status line '{status}'"));
    }
    let code: u16 = parts
        .next()
        .and_then(|c| c.parse().ok())
        .ok_or_else(|| format!("bad status code in '{status}'"))?;
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (name, value) =
            line.split_once(':').ok_or_else(|| format!("bad header line '{line}'"))?;
        headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
    }
    let body = String::from_utf8(raw[header_end + 4..].to_vec())
        .map_err(|_| "response body is not valid UTF-8".to_string())?;
    Ok(HttpResponse { code, headers, body })
}

/// One blocking HTTP/1.1 request over a fresh connection
/// (`Connection: close`, body read to EOF). Errors are transport-level
/// (connect/write/read); malformed responses come back from
/// [`parse_response`].
pub fn http_request(
    addr: &str,
    method: &str,
    path: &str,
    body: &str,
    timeout: Duration,
) -> std::result::Result<HttpResponse, String> {
    let stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream.set_read_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    stream.set_write_timeout(Some(timeout)).map_err(|e| e.to_string())?;
    let _ = stream.set_nodelay(true);
    let mut stream = stream;
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes()).map_err(|e| format!("write: {e}"))?;
    stream.write_all(body.as_bytes()).map_err(|e| format!("write: {e}"))?;
    stream.flush().map_err(|e| format!("flush: {e}"))?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw).map_err(|e| format!("read: {e}"))?;
    parse_response(&raw)
}

/// How one trace response was classified.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Class {
    Ok,
    Shed,
    Failed,
    Malformed,
}

/// Classify a serving response: `200` with a JSON body carrying a
/// prediction is `Ok`; `503` with JSON *and* `Retry-After` is a
/// well-formed shed; anything else that reached us is malformed or a
/// server failure.
fn classify(resp: &HttpResponse) -> Class {
    let json_ok = Value::parse(&resp.body).is_ok();
    match resp.code {
        200 => {
            let has_pred = Value::parse(&resp.body)
                .ok()
                .is_some_and(|v| v.get_opt("prediction").is_some());
            if has_pred {
                Class::Ok
            } else {
                Class::Malformed
            }
        }
        503 => {
            if json_ok && resp.header("retry-after").is_some() {
                Class::Shed
            } else {
                Class::Malformed
            }
        }
        500 => Class::Failed,
        _ => Class::Malformed,
    }
}

/// Aggregated result of one trace replay.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    /// Requests sent (the full trace, open loop).
    pub sent: u64,
    /// `200` responses with a well-formed prediction body.
    pub ok: u64,
    /// Well-formed `503 + Retry-After` shed responses.
    pub shed: u64,
    /// Transport errors and `500`s (excluding retry-exhausted requests,
    /// which count under `gave_up`).
    pub failed: u64,
    /// Requests that exhausted a configured retry budget and still
    /// ended in a transport error or `500`. Kept distinct from `failed`
    /// so fleet failover accounting can tell "failed once, one-shot"
    /// from "the client gave up after riding out every retry".
    pub gave_up: u64,
    /// Responses that were not well-formed JSON with the expected
    /// status semantics.
    pub malformed: u64,
    /// Retry attempts performed across all requests (0 unless
    /// [`TraceConfig::retries`] is set and outcomes warranted them).
    pub retried: u64,
    /// Client-observed median latency of `Ok` responses (ms).
    pub wall_p50_ms: f64,
    /// Client-observed p99 latency of `Ok` responses (ms).
    pub wall_p99_ms: f64,
    /// Client-observed p99.9 latency of `Ok` responses (ms).
    pub wall_p999_ms: f64,
}

impl LoadReport {
    /// Every response was either a good `200` or a well-formed shed —
    /// including none that burned through a retry budget and gave up.
    pub fn well_formed(&self) -> bool {
        self.malformed == 0
            && self.failed == 0
            && self.gave_up == 0
            && self.ok + self.shed == self.sent
    }

    /// Serialize for CLI output.
    pub fn to_value(&self) -> Value {
        Value::obj(vec![
            ("sent", Value::Num(self.sent as f64)),
            ("ok", Value::Num(self.ok as f64)),
            ("shed", Value::Num(self.shed as f64)),
            ("failed", Value::Num(self.failed as f64)),
            ("gave_up", Value::Num(self.gave_up as f64)),
            ("malformed", Value::Num(self.malformed as f64)),
            ("retried", Value::Num(self.retried as f64)),
            ("well_formed", Value::Bool(self.well_formed())),
            ("wall_p50_ms", Value::Num(self.wall_p50_ms)),
            ("wall_p99_ms", Value::Num(self.wall_p99_ms)),
            ("wall_p999_ms", Value::Num(self.wall_p999_ms)),
        ])
    }

    /// Emit client-side counters as an informational [`MetricRecord`].
    pub fn to_record(&self, id: &str) -> MetricRecord {
        MetricRecord::new(id)
            .with_value("wall_p50_ms", self.wall_p50_ms)
            .with_value("wall_p99_ms", self.wall_p99_ms)
            .with_value("wall_p999_ms", self.wall_p999_ms)
            .with_value("host_ok", self.ok as f64)
            .with_value("host_shed_total", self.shed as f64)
            .with_value("host_failed", self.failed as f64)
            .with_value("host_gave_up", self.gave_up as f64)
            .with_value("host_retry_total", self.retried as f64)
    }
}

/// Attempt one request up to `1 + retries` times, sleeping between
/// attempts with seeded-jittered backoff. A shed's `Retry-After` is
/// honored up to a 300 ms cap (so seeded chaos runs stay fast); other
/// retryable outcomes (transport error, `500`, malformed) back off
/// exponentially from 10 ms, capped at 200 ms. Returns the final
/// attempt's class, its wall latency in ms, the retries performed, and
/// whether the request *gave up* (exhausted a nonzero retry budget and
/// still ended in a transport error or `500`).
fn request_with_retries(
    addr: &str,
    body: &str,
    timeout: Duration,
    retries: usize,
    rng: &mut Pcg32,
) -> (Class, f64, u64, bool) {
    let mut attempt = 0u64;
    loop {
        let t0 = Instant::now();
        let outcome = http_request(addr, "POST", "/v1/infer", body, timeout);
        let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
        let (class, retry_after_ms) = match &outcome {
            Ok(resp) => {
                let after = resp
                    .header("retry-after")
                    .and_then(|v| v.parse::<u64>().ok())
                    .map(|secs| secs.saturating_mul(1000));
                (classify(resp), after)
            }
            Err(_) => (Class::Failed, None),
        };
        if class == Class::Ok || attempt >= retries as u64 {
            let gave_up = retries > 0 && class == Class::Failed;
            return (class, wall_ms, attempt, gave_up);
        }
        attempt += 1;
        let backoff = match retry_after_ms {
            Some(ms) => ms.min(300),
            None => (10u64 << (attempt - 1).min(5)).min(200),
        };
        std::thread::sleep(Duration::from_millis(backoff + u64::from(rng.below(10))));
    }
}

/// Replay a trace against a server: request `i` fires at its precomputed
/// offset (open loop) with body `bodies[i % bodies.len()]` (`{}` when
/// `bodies` is empty). Blocks until every response (or timeout) is in.
/// With [`TraceConfig::retries`] set, each request retries retryable
/// outcomes with jittered backoff before its final outcome counts —
/// the arrival schedule itself never adapts (retries delay only their
/// own request's resolution).
pub fn run_trace(
    addr: &str,
    trace: &TraceConfig,
    bodies: &[String],
    timeout: Duration,
) -> LoadReport {
    let offsets = arrival_offsets(trace);
    let n = offsets.len();
    let (tx, rx) = mpsc::channel::<(Class, f64, u64, bool)>();
    let start = Instant::now();
    // Backoff jitter stream, independent of the arrival stream so
    // enabling retries never reshapes the offered trace.
    let mut jitter_base = Pcg32::new(trace.seed ^ 0xBACC_0FF5);
    let mut handles = Vec::with_capacity(n);
    for (i, offset) in offsets.into_iter().enumerate() {
        let body = if bodies.is_empty() {
            "{}".to_string()
        } else {
            bodies[i % bodies.len()].clone()
        };
        let addr = addr.to_string();
        let tx = tx.clone();
        let retries = trace.retries;
        let mut rng = jitter_base.fork(i as u64);
        let handle = std::thread::Builder::new()
            .name("loadgen".into())
            .spawn(move || {
                std::thread::sleep(offset.saturating_sub(start.elapsed()));
                let out = request_with_retries(&addr, &body, timeout, retries, &mut rng);
                let _ = tx.send(out);
            });
        match handle {
            Ok(h) => handles.push(h),
            Err(_) => {
                // Spawn failure: count the request as failed client-side.
                let _ = tx.send((Class::Failed, 0.0, 0, false));
            }
        }
    }
    drop(tx);

    let mut report = LoadReport { sent: n as u64, ..Default::default() };
    let mut wall = Percentiles::new();
    for (class, wall_ms, retried, gave_up) in rx {
        report.retried += retried;
        match class {
            Class::Ok => {
                report.ok += 1;
                wall.push(wall_ms);
            }
            Class::Shed => report.shed += 1,
            Class::Failed if gave_up => report.gave_up += 1,
            Class::Failed => report.failed += 1,
            Class::Malformed => report.malformed += 1,
        }
    }
    for h in handles {
        let _ = h.join();
    }
    if wall.count() > 0 {
        report.wall_p50_ms = wall.percentile(50.0);
        report.wall_p99_ms = wall.percentile(99.0);
        report.wall_p999_ms = wall.percentile(99.9);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_offsets_are_deterministic_and_monotone() {
        let cfg = TraceConfig { requests: 50, rate: 500.0, ..Default::default() };
        let a = arrival_offsets(&cfg);
        let b = arrival_offsets(&cfg);
        assert_eq!(a, b, "same seed must replay the same trace");
        assert_eq!(a.len(), 50);
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "offsets must be non-decreasing");
        assert!(a.iter().all(|d| *d > Duration::ZERO));
        let c = arrival_offsets(&TraceConfig { seed: 99, ..cfg });
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn poisson_mean_rate_is_roughly_honored() {
        let cfg = TraceConfig {
            requests: 2000,
            rate: 1000.0,
            arrival: Arrival::Poisson,
            ..Default::default()
        };
        let offsets = arrival_offsets(&cfg);
        let span = offsets.last().unwrap().as_secs_f64();
        let rate = cfg.requests as f64 / span;
        assert!(
            (rate - 1000.0).abs() < 150.0,
            "empirical rate {rate:.0} too far from 1000"
        );
    }

    #[test]
    fn burst_offsets_bunch_into_groups() {
        let cfg = TraceConfig {
            requests: 20,
            rate: 400.0,
            arrival: Arrival::Burst,
            burst: 5,
            seed: 7,
            retries: 0,
        };
        let offsets = arrival_offsets(&cfg);
        assert_eq!(offsets.len(), 20);
        for group in offsets.chunks(5) {
            assert!(
                group.iter().all(|d| *d == group[0]),
                "requests within a burst fire simultaneously"
            );
        }
        assert!(offsets[0] < offsets[5], "bursts are separated by gaps");
    }

    #[test]
    fn arrival_parse_names() {
        assert_eq!(Arrival::parse("poisson"), Some(Arrival::Poisson));
        assert_eq!(Arrival::parse("BURSTY"), Some(Arrival::Burst));
        assert_eq!(Arrival::parse("uniform"), None);
        assert_eq!(Arrival::Burst.name(), "burst");
    }

    #[test]
    fn parse_response_roundtrip() {
        let raw = b"HTTP/1.1 503 Service Unavailable\r\nContent-Type: application/json\r\n\
                    Retry-After: 1\r\nContent-Length: 16\r\n\r\n{\"error\":\"full\"}";
        let resp = parse_response(raw).unwrap();
        assert_eq!(resp.code, 503);
        assert_eq!(resp.header("retry-after"), Some("1"));
        assert_eq!(resp.header("RETRY-AFTER"), Some("1"));
        assert_eq!(resp.body, "{\"error\":\"full\"}");
    }

    #[test]
    fn parse_response_rejects_garbage() {
        assert!(parse_response(b"").is_err());
        assert!(parse_response(b"not http at all\r\n\r\n").is_err());
        assert!(parse_response(b"HTTP/1.1 twohundred OK\r\n\r\n").is_err());
        assert!(parse_response(b"HTTP/1.1 200 OK\r\nbadheader\r\n\r\n").is_err());
    }

    #[test]
    fn classification_covers_the_contract() {
        let ok = HttpResponse {
            code: 200,
            headers: vec![],
            body: "{\"prediction\":3}".to_string(),
        };
        assert_eq!(classify(&ok), Class::Ok);
        let shed = HttpResponse {
            code: 503,
            headers: vec![("retry-after".to_string(), "1".to_string())],
            body: "{\"error\":\"full\"}".to_string(),
        };
        assert_eq!(classify(&shed), Class::Shed);
        // A 503 without Retry-After violates the shedding contract.
        let bad_shed = HttpResponse { headers: vec![], ..shed.clone() };
        assert_eq!(classify(&bad_shed), Class::Malformed);
        // A 200 whose body is not the infer schema is malformed.
        let bad_ok = HttpResponse { body: "hello".to_string(), ..ok.clone() };
        assert_eq!(classify(&bad_ok), Class::Malformed);
        let failed = HttpResponse { code: 500, ..ok };
        assert_eq!(classify(&failed), Class::Failed);
    }

    #[test]
    fn report_counters_and_record() {
        let report = LoadReport {
            sent: 10,
            ok: 7,
            shed: 3,
            wall_p50_ms: 1.0,
            wall_p99_ms: 2.0,
            wall_p999_ms: 2.5,
            ..Default::default()
        };
        assert!(report.well_formed());
        let rec = report.to_record("loadgen/dscnn");
        assert_eq!(rec.get("host_ok"), Some(7.0));
        assert_eq!(rec.get("host_shed_total"), Some(3.0));
        assert_eq!(rec.get("host_retry_total"), Some(0.0));
        // Retries are informational and lower-is-better in baselines.
        let retry_spec = crate::metrics::spec_for("host_retry_total");
        assert!(!retry_spec.gate);
        assert_eq!(retry_spec.better, crate::metrics::Direction::LowerIsBetter);
        // A report with retries stays well-formed: retries change how an
        // outcome was reached, not what it was.
        let retried = LoadReport { retried: 5, ..report.clone() };
        assert!(retried.well_formed());
        assert_eq!(retried.to_record("x").get("host_retry_total"), Some(5.0));
        let lossy = LoadReport { failed: 1, ..report.clone() };
        assert!(!lossy.well_formed());
        // Retry-exhausted requests land in their own column and break
        // well-formedness just like a plain failure would.
        let exhausted = LoadReport { gave_up: 2, ..report.clone() };
        assert!(!exhausted.well_formed());
        assert_eq!(exhausted.to_record("x").get("host_gave_up"), Some(2.0));
        let gave_up_spec = crate::metrics::spec_for("host_gave_up");
        assert!(!gave_up_spec.gate);
        assert_eq!(gave_up_spec.better, crate::metrics::Direction::LowerIsBetter);
        let short = LoadReport { shed: 2, ..report };
        assert!(!short.well_formed(), "ok + shed must account for every sent request");
        let json = lossy.to_value().to_json();
        assert!(Value::parse(&json).is_ok());
    }
}
