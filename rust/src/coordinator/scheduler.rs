//! Threaded job pool.
//!
//! A fixed pool of std threads consuming boxed jobs from a shared
//! channel; results are returned in submission order. This is the
//! parallel substrate for the experiment runner (designs × batches) and
//! the benchmark sweeps.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool.
pub struct JobPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl JobPool {
    /// Spawn a pool with `threads` workers (0 = available parallelism).
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            threads
        };
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("sparse-riscv-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        JobPool { tx: Some(tx), workers }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }

    /// Map a function over items in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            results[i] = Some(r);
        }
        results.into_iter().map(|r| r.unwrap()).collect()
    }

    /// [`JobPool::map`] with `chunk` items per submitted job: one channel
    /// round-trip per chunk instead of per item, which matters when the
    /// per-item work is small (e.g. tiny-model inferences in a large
    /// batch). Results preserve input order.
    pub fn map_chunked<T, R, F>(&self, items: Vec<T>, chunk: usize, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let chunk = chunk.max(1);
        let mut chunks: Vec<Vec<T>> = Vec::new();
        let mut rest = items;
        while !rest.is_empty() {
            let tail = rest.split_off(rest.len().min(chunk));
            chunks.push(std::mem::replace(&mut rest, tail));
        }
        let n = chunks.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, Vec<R>)>();
        for (i, chunk_items) in chunks.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let rs: Vec<R> = chunk_items.into_iter().map(|it| f(it)).collect();
                let _ = rtx.send((i, rs));
            });
        }
        drop(rtx);
        let mut results: Vec<Option<Vec<R>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, rs) = rrx.recv().expect("worker result");
            results[i] = Some(rs);
        }
        results.into_iter().flat_map(|r| r.unwrap()).collect()
    }
}

impl Drop for JobPool {
    fn drop(&mut self) {
        // Close the channel, then join workers.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = JobPool::new(4);
        let out = pool.map((0..100).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<usize>>());
    }

    #[test]
    fn jobs_actually_run_concurrently_on_multiple_workers() {
        let pool = JobPool::new(4);
        assert_eq!(pool.workers(), 4);
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let out = pool.map(vec![(); 64], move |_| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(out.len(), 64);
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = JobPool::new(2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&flag);
        pool.submit(move || {
            f2.store(7, Ordering::SeqCst);
        });
        drop(pool); // must join without deadlock
        assert_eq!(flag.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn zero_threads_uses_available_parallelism() {
        let pool = JobPool::new(0);
        assert!(pool.workers() >= 1);
    }

    #[test]
    fn map_chunked_matches_map() {
        let pool = JobPool::new(3);
        let items: Vec<usize> = (0..50).collect();
        let a = pool.map(items.clone(), |x| x * 3 + 1);
        for chunk in [1usize, 4, 7, 50, 100] {
            let b = pool.map_chunked(items.clone(), chunk, |x| x * 3 + 1);
            assert_eq!(a, b, "chunk={chunk}");
        }
        // Empty input: no jobs, empty output.
        let e: Vec<usize> = pool.map_chunked(Vec::<usize>::new(), 8, |x| x);
        assert!(e.is_empty());
    }
}
