//! Threaded job pool.
//!
//! A fixed pool of std threads consuming boxed jobs from a shared
//! channel; results are returned in submission order. This is the
//! parallel substrate for the experiment runner (designs × batches), the
//! benchmark sweeps, and — via [`JobPool::scoped_map`] /
//! [`TilePool`] — the intra-layer lane tiling of a single inference.

use std::fmt;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed-size thread pool.
pub struct JobPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl JobPool {
    /// Spawn a pool with `threads` workers (0 = available parallelism).
    pub fn new(threads: usize) -> Self {
        let threads = if threads == 0 {
            thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        } else {
            threads
        };
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..threads)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("sparse-riscv-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            // Contain panicking jobs: an unwinding job
                            // would otherwise kill this worker, stranding
                            // queued jobs (their result senders keep the
                            // channel open, so a scoped_map caller would
                            // hang instead of reaching its abort path)
                            // and shrinking the pool for the rest of the
                            // process. The caller still observes the
                            // missing result (map panics, scoped_map
                            // aborts) — only the pool stays healthy.
                            Ok(job) => {
                                let _ = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                );
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        JobPool { tx: Some(tx), workers }
    }

    /// Number of workers.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(f))
            .expect("worker pool hung up");
    }

    /// Map a function over items in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("worker result");
            results[i] = Some(r);
        }
        results.into_iter().map(|r| r.unwrap()).collect()
    }

    /// [`JobPool::map`] with `chunk` items per submitted job: one channel
    /// round-trip per chunk instead of per item, which matters when the
    /// per-item work is small (e.g. tiny-model inferences in a large
    /// batch). Results preserve input order.
    pub fn map_chunked<T, R, F>(&self, items: Vec<T>, chunk: usize, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let chunk = chunk.max(1);
        let mut chunks: Vec<Vec<T>> = Vec::new();
        let mut rest = items;
        while !rest.is_empty() {
            let tail = rest.split_off(rest.len().min(chunk));
            chunks.push(std::mem::replace(&mut rest, tail));
        }
        let n = chunks.len();
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, Vec<R>)>();
        for (i, chunk_items) in chunks.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            self.submit(move || {
                let rs: Vec<R> = chunk_items.into_iter().map(|it| f(it)).collect();
                let _ = rtx.send((i, rs));
            });
        }
        drop(rtx);
        let mut results: Vec<Option<Vec<R>>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, rs) = rrx.recv().expect("worker result");
            results[i] = Some(rs);
        }
        results.into_iter().flat_map(|r| r.unwrap()).collect()
    }

    /// [`JobPool::map`] over items and a closure that may **borrow from
    /// the caller's stack** — the substrate for intra-layer tiling,
    /// where each tile job reads the layer's prepared weights and input
    /// activations by reference instead of `Arc`-wrapping every layer
    /// input.
    ///
    /// The call does not return until every submitted job has finished
    /// (all results are received below), which is what makes handing
    /// non-`'static` borrows to the pool's worker threads sound; the
    /// lifetime is erased only for the window this function provably
    /// outlives. If a job panics on a worker, its result can never
    /// arrive and the borrows it holds can no longer be proven dead, so
    /// the process aborts rather than risk the caller unwinding while a
    /// worker still references its stack (mirroring `std::thread::scope`
    /// semantics, where a panicked scope job also tears down the scope).
    pub fn scoped_map<'s, T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 's,
        R: Send + 's,
        F: Fn(T) -> R + Send + Sync + 's,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let f = Arc::new(f);
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = Arc::clone(&f);
            let rtx = rtx.clone();
            let job: Box<dyn FnOnce() + Send + 's> = Box::new(move || {
                let r = f(item);
                // Release this job's share of the closure (and with it
                // every `'s` borrow the job still holds) BEFORE
                // signalling completion: once the caller has received
                // all n results, no worker can still be between send and
                // drop while referencing caller-borrowed data. The
                // result `r` itself is moved into the channel and owned
                // by the caller before scoped_map returns.
                drop(f);
                let _ = rtx.send((i, r));
            });
            // SAFETY: the job's borrows live for 's, and this function
            // blocks until every job has sent its result (or aborts the
            // process if one cannot), so no worker can touch the
            // borrowed data after scoped_map returns. The transmute only
            // erases the lifetime parameter of an otherwise identical
            // fat pointer.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 's>, Job>(job)
            };
            self.tx
                .as_ref()
                .expect("pool already shut down")
                .send(job)
                .expect("worker pool hung up");
        }
        drop(rtx);
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut received = 0usize;
        while received < n {
            match rrx.recv() {
                Ok((i, r)) => {
                    results[i] = Some(r);
                    received += 1;
                }
                // A tile job panicked on a worker: its borrows into our
                // caller's frame cannot be proven released, so unwinding
                // from here would be unsound. Fail hard instead.
                Err(_) => {
                    eprintln!("scoped_map: worker died before completing a scoped job; aborting");
                    std::process::abort();
                }
            }
        }
        results.into_iter().map(|r| r.unwrap()).collect()
    }
}

/// Cloneable, `Debug`-able handle to a [`JobPool`] dedicated to
/// intra-layer lane tiling. Kept separate from any request-level pool:
/// tile jobs are submitted from inside request jobs and block on their
/// completion, so sharing one pool for both levels could deadlock with
/// every worker waiting on tile jobs that have no worker left to run
/// them.
#[derive(Clone)]
pub struct TilePool {
    pool: Arc<JobPool>,
}

impl TilePool {
    /// Pool with `threads` tile workers (0 = available parallelism).
    pub fn new(threads: usize) -> Self {
        TilePool { pool: Arc::new(JobPool::new(threads)) }
    }

    /// Number of tile workers (the natural tile count).
    pub fn workers(&self) -> usize {
        self.pool.workers()
    }

    /// The underlying pool (for [`JobPool::scoped_map`]).
    pub fn pool(&self) -> &JobPool {
        &self.pool
    }
}

impl fmt::Debug for TilePool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TilePool({} workers)", self.workers())
    }
}

impl Drop for JobPool {
    fn drop(&mut self) {
        // Close the channel, then join workers.
        self.tx.take();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = JobPool::new(4);
        let out = pool.map((0..100).collect::<Vec<usize>>(), |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<usize>>());
    }

    #[test]
    fn jobs_actually_run_concurrently_on_multiple_workers() {
        let pool = JobPool::new(4);
        assert_eq!(pool.workers(), 4);
        let counter = Arc::new(AtomicUsize::new(0));
        let c2 = Arc::clone(&counter);
        let out = pool.map(vec![(); 64], move |_| {
            c2.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(out.len(), 64);
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = JobPool::new(2);
        let flag = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&flag);
        pool.submit(move || {
            f2.store(7, Ordering::SeqCst);
        });
        drop(pool); // must join without deadlock
        assert_eq!(flag.load(Ordering::SeqCst), 7);
    }

    #[test]
    fn zero_threads_uses_available_parallelism() {
        let pool = JobPool::new(0);
        assert!(pool.workers() >= 1);
    }

    #[test]
    fn scoped_map_borrows_caller_stack() {
        let pool = JobPool::new(3);
        // Borrowed, non-'static input data: the whole point of the API.
        let base: Vec<u64> = (0..40).collect();
        let slice: &[u64] = &base;
        let out = pool.scoped_map((0..base.len()).collect::<Vec<usize>>(), |i| slice[i] * 2);
        assert_eq!(out, base.iter().map(|x| x * 2).collect::<Vec<u64>>());
        // Empty input: no jobs, empty output.
        let e: Vec<u64> = pool.scoped_map(Vec::<usize>::new(), |i| slice[i]);
        assert!(e.is_empty());
    }

    #[test]
    fn scoped_map_preserves_order_under_contention() {
        let pool = JobPool::new(4);
        let data: Vec<usize> = (0..200).collect();
        let out = pool.scoped_map(data.clone(), |x| x * x);
        assert_eq!(out, data.iter().map(|x| x * x).collect::<Vec<usize>>());
    }

    #[test]
    fn tile_pool_reports_workers() {
        let tp = TilePool::new(2);
        assert_eq!(tp.workers(), 2);
        assert_eq!(format!("{tp:?}"), "TilePool(2 workers)");
        let tp2 = tp.clone();
        assert_eq!(tp2.workers(), 2);
    }

    #[test]
    fn map_chunked_matches_map() {
        let pool = JobPool::new(3);
        let items: Vec<usize> = (0..50).collect();
        let a = pool.map(items.clone(), |x| x * 3 + 1);
        for chunk in [1usize, 4, 7, 50, 100] {
            let b = pool.map_chunked(items.clone(), chunk, |x| x * 3 + 1);
            assert_eq!(a, b, "chunk={chunk}");
        }
        // Empty input: no jobs, empty output.
        let e: Vec<usize> = pool.map_chunked(Vec::<usize>::new(), 8, |x| x);
        assert!(e.is_empty());
    }
}
