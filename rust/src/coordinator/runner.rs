//! Experiment orchestration.
//!
//! Engine v2: the runner drives every design through the
//! [`crate::simulator::ExecBackend`] trait and fans work out at
//! *(design × request)* granularity — preparation happens once per design
//! in parallel, then each inference is an independent job, so a batch
//! keeps every worker busy even when fewer designs than threads are
//! requested.

use super::scheduler::JobPool;
use crate::config::experiment::ExperimentConfig;
use crate::error::{Error, Result};
use crate::isa::DesignKind;
use crate::models::builder::{apply_sparsity, random_input, ModelConfig};
use crate::models::zoo::build_model;
use crate::simulator::{verified_backend_for, ExecBackend, PreparedModel, SimReport};
use crate::util::Pcg32;
use std::sync::Arc;

/// Per-design experiment outcome.
#[derive(Debug, Clone)]
pub struct DesignResult {
    /// The design.
    pub design: DesignKind,
    /// Total cycles over the batch.
    pub total_cycles: u64,
    /// MAC-unit cycles over the batch.
    pub mac_cycles: u64,
    /// Per-request reports.
    pub reports: Vec<SimReport>,
    /// Speedup vs the SIMD baseline (total cycles).
    pub speedup_vs_simd: f64,
    /// Speedup vs the sequential baseline (total cycles).
    pub speedup_vs_seq: f64,
}

/// Outcome of one experiment.
#[derive(Debug, Clone)]
pub struct ExperimentResult {
    /// Config echo.
    pub config: ExperimentConfig,
    /// Measured weight sparsity after pruning (element / block).
    pub element_sparsity: f64,
    /// Block sparsity.
    pub block_sparsity: f64,
    /// One entry per requested design.
    pub designs: Vec<DesignResult>,
}

/// Run an experiment: build + prune the model, simulate the batch on
/// every requested design (plus the two baselines for speedup
/// denominators), in parallel across (design × request) jobs.
pub fn run_experiment(cfg: &ExperimentConfig, model_cfg: &ModelConfig) -> Result<ExperimentResult> {
    cfg.validate()?;
    let mut info = build_model(&cfg.model, model_cfg)?;
    apply_sparsity(&mut info.graph, cfg.x_us, cfg.x_ss);

    // Measure achieved sparsity over all MAC layers.
    let (mut zeros, mut total, mut zero_blocks, mut blocks) = (0usize, 0usize, 0usize, 0usize);
    for ws in info.graph.mac_weights() {
        zeros += ws.iter().filter(|&&w| w == 0).count();
        total += ws.len();
        for b in ws.chunks(4) {
            blocks += 1;
            if b.iter().all(|&w| w == 0) {
                zero_blocks += 1;
            }
        }
    }

    // Inputs for the batch (shared across designs for comparability).
    let mut rng = Pcg32::new(cfg.sim.seed);
    let inputs: Vec<_> = (0..cfg.batch)
        .map(|_| {
            random_input(
                info.input_shape.clone(),
                crate::tensor::quant::QuantParams::new(model_cfg.act_scale, 0).unwrap(),
                &mut rng,
            )
        })
        .collect();

    // Always include both baselines (speedup denominators).
    let mut designs = cfg.designs.clone();
    for d in [DesignKind::BaselineSimd, DesignKind::BaselineSequential] {
        if !designs.contains(&d) {
            designs.push(d);
        }
    }

    let graph = Arc::new(info.graph);
    let inputs = Arc::new(inputs);
    let verify = cfg.sim.verify;
    let pool = JobPool::new(cfg.sim.threads);

    // Phase 1: prepare once per design, in parallel.
    let backends: Vec<Arc<dyn ExecBackend>> = designs
        .iter()
        .map(|&d| Arc::from(verified_backend_for(d, verify)))
        .collect();
    let prep_results: Vec<Result<PreparedModel>> = {
        let graph = Arc::clone(&graph);
        pool.map(backends.clone(), move |backend| backend.prepare(&graph))
    };
    let mut prepared: Vec<Arc<PreparedModel>> = Vec::with_capacity(designs.len());
    for p in prep_results {
        prepared.push(Arc::new(p?));
    }

    // Phase 2: fan out (design, request) pairs.
    let batch = inputs.len();
    let pairs: Vec<(usize, usize)> =
        (0..designs.len()).flat_map(|d| (0..batch).map(move |r| (d, r))).collect();
    let backends = Arc::new(backends);
    let prepared_shared = Arc::new(prepared);
    let run_results: Vec<Result<SimReport>> = {
        let backends = Arc::clone(&backends);
        let prepared = Arc::clone(&prepared_shared);
        let inputs = Arc::clone(&inputs);
        pool.map(pairs, move |(d, r)| backends[d].execute(&prepared[d], &inputs[r]))
    };

    // Regroup per design, in request order (map preserves order).
    let mut collected: Vec<(DesignKind, u64, u64, Vec<SimReport>)> = Vec::new();
    let mut it = run_results.into_iter();
    for &design in &designs {
        let mut reports = Vec::with_capacity(batch);
        for _ in 0..batch {
            reports.push(it.next().expect("report per pair")?);
        }
        let total: u64 = reports.iter().map(|rep| rep.total_cycles).sum();
        let mac: u64 = reports.iter().map(|rep| rep.mac_cycles).sum();
        collected.push((design, total, mac, reports));
    }

    let base_simd = collected
        .iter()
        .find(|(d, ..)| *d == DesignKind::BaselineSimd)
        .map(|(_, c, ..)| *c)
        .ok_or_else(|| Error::Coordinator("missing SIMD baseline".into()))?;
    let base_seq = collected
        .iter()
        .find(|(d, ..)| *d == DesignKind::BaselineSequential)
        .map(|(_, c, ..)| *c)
        .ok_or_else(|| Error::Coordinator("missing sequential baseline".into()))?;

    let designs = collected
        .into_iter()
        .filter(|(d, ..)| cfg.designs.contains(d))
        .map(|(design, total_cycles, mac_cycles, reports)| DesignResult {
            design,
            total_cycles,
            mac_cycles,
            reports,
            speedup_vs_simd: base_simd as f64 / total_cycles as f64,
            speedup_vs_seq: base_seq as f64 / total_cycles as f64,
        })
        .collect();

    Ok(ExperimentResult {
        config: cfg.clone(),
        element_sparsity: zeros as f64 / total.max(1) as f64,
        block_sparsity: zero_blocks as f64 / blocks.max(1) as f64,
        designs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::experiment::SimOptions;

    fn tiny_cfg(designs: Vec<DesignKind>, x_us: f64, x_ss: f64) -> ExperimentConfig {
        ExperimentConfig {
            name: "test".into(),
            model: "dscnn".into(),
            designs,
            x_us,
            x_ss,
            batch: 1,
            sim: SimOptions { seed: 1, threads: 2, verify: true, clock_hz: 100_000_000 },
        }
    }

    fn tiny_model() -> ModelConfig {
        ModelConfig { scale: 0.07, ..Default::default() }
    }

    #[test]
    fn experiment_produces_speedups() {
        let cfg = tiny_cfg(vec![DesignKind::Csa, DesignKind::Sssa], 0.6, 0.4);
        let res = run_experiment(&cfg, &tiny_model()).unwrap();
        assert_eq!(res.designs.len(), 2);
        assert!((res.block_sparsity - 0.4).abs() < 0.1, "block {}", res.block_sparsity);
        let csa = res.designs.iter().find(|d| d.design == DesignKind::Csa).unwrap();
        // At scale 0.07 the DSCNN lanes are only 1–2 blocks long, so the
        // skip chains are short; the full-size benches (fig10) show the
        // paper-range speedups. Here we only require a clear win.
        assert!(csa.speedup_vs_seq > 1.2, "csa speedup {}", csa.speedup_vs_seq);
    }

    #[test]
    fn baseline_speedup_is_unity() {
        let cfg = tiny_cfg(vec![DesignKind::BaselineSimd], 0.3, 0.3);
        let res = run_experiment(&cfg, &tiny_model()).unwrap();
        let b = &res.designs[0];
        assert!((b.speedup_vs_simd - 1.0).abs() < 1e-12);
    }

    #[test]
    fn dense_model_no_speedup_for_sssa() {
        let cfg = tiny_cfg(vec![DesignKind::Sssa], 0.0, 0.0);
        let res = run_experiment(&cfg, &tiny_model()).unwrap();
        let s = &res.designs[0];
        // With no zero blocks SSSA ≈ baseline (identical per-block cost).
        assert!(s.speedup_vs_simd <= 1.05, "{}", s.speedup_vs_simd);
        assert!(s.speedup_vs_simd > 0.9, "{}", s.speedup_vs_simd);
    }

    #[test]
    fn pair_fanout_keeps_report_order_per_design() {
        // batch > 1 and several designs: reports must stay grouped by
        // design in request order (identical to a sequential run).
        let mut cfg = tiny_cfg(vec![DesignKind::Csa, DesignKind::Ussa], 0.5, 0.3);
        cfg.batch = 3;
        cfg.sim.threads = 4;
        cfg.sim.verify = false;
        let par = run_experiment(&cfg, &tiny_model()).unwrap();
        cfg.sim.threads = 1;
        let seq = run_experiment(&cfg, &tiny_model()).unwrap();
        assert_eq!(par.designs.len(), seq.designs.len());
        for (a, b) in par.designs.iter().zip(&seq.designs) {
            assert_eq!(a.design, b.design);
            assert_eq!(a.total_cycles, b.total_cycles);
            assert_eq!(a.reports.len(), 3);
            for (ra, rb) in a.reports.iter().zip(&b.reports) {
                assert_eq!(ra.total_cycles, rb.total_cycles);
                assert_eq!(ra.output.data(), rb.output.data());
            }
        }
    }
}
